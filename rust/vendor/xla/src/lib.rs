//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The artifact execution path ([`crate::PjRtClient`] and friends)
//! needs the XLA/PJRT shared library, which offline build hosts don't
//! have. This stub keeps the exact API surface `strads::runtime` uses
//! so the crate compiles everywhere: [`PjRtClient::cpu`] fails with
//! [`Error::Unavailable`], which the callers already treat as "no
//! artifact store" (the runtime_roundtrip suite skips, the CLI
//! `--artifacts` paths report the error, and the pure-rust native
//! backends — the tier-1 test surface — are unaffected).
//!
//! To run the real PJRT path, point the workspace `xla` dependency at
//! the actual bindings; no `strads` source changes are needed.

use std::fmt;
use std::path::Path;

/// Stub error: every entry point reports the runtime as unavailable.
#[derive(Debug)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(why) => write!(f, "{why}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error::Unavailable(
        "PJRT runtime not linked into this build (offline xla stub); \
         swap rust/vendor/xla for the real xla-rs bindings to enable artifacts",
    ))
}

/// Element types transferable to/from device buffers.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Stub of the PJRT client; construction always fails.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }
}

/// Stub device buffer (never constructed — the client cannot exist).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Stub compiled executable (never constructed).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Stub host literal (never constructed).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        unavailable()
    }
}

/// Stub XLA computation.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err}").contains("offline xla stub"));
    }
}
