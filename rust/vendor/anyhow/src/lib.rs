//! Offline drop-in subset of the `anyhow` error crate.
//!
//! This build vendors every dependency (see `rust/vendor/`), so the
//! real crates.io `anyhow` is not available. This crate implements the
//! slice of its API the workspace actually uses — `Error`, `Result`,
//! `anyhow!` / `bail!` / `ensure!`, `Context` on `Result` and `Option`,
//! and `Error::msg` — with the same observable semantics: `Display`
//! shows the outermost message, `{:#}` shows the full cause chain, and
//! `Debug` renders a "Caused by:" list. Swap in the real crate by
//! pointing the workspace dependency back at crates.io.

use std::error::Error as StdError;
use std::fmt;

/// Drop-in for `anyhow::Error`: an erased error plus context layers.
///
/// Messages are stored root-cause first; the last entry is the
/// outermost context. Like the real `anyhow::Error`, this type
/// deliberately does NOT implement `std::error::Error`, which is what
/// lets the blanket `From<E: StdError>` conversion below coexist with
/// the reflexive `From<Error>`.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (`anyhow::Error::msg`).
    pub fn msg<M: fmt::Display + Send + Sync + 'static>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display + Send + Sync + 'static>(mut self, context: C) -> Self {
        self.chain.push(context.to_string());
        self
    }

    /// The root cause message (innermost layer).
    pub fn root_cause(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }

    /// Messages from outermost context down to the root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut layers = self.chain.iter().rev();
        match layers.next() {
            Some(outer) => write!(f, "{outer}")?,
            None => write!(f, "unknown error")?,
        }
        if f.alternate() {
            for layer in layers {
                write!(f, ": {layer}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut layers = self.chain.iter().rev();
        match layers.next() {
            Some(outer) => write!(f, "{outer}")?,
            None => write!(f, "unknown error")?,
        }
        let causes: Vec<&String> = layers.collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in causes.iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        // Flatten the std source() chain into our layered form.
        let mut chain = Vec::new();
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        chain.reverse(); // root cause first
        chain.push(err.to_string());
        Error { chain }
    }
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Drop-in for `anyhow::Context`: attach context to fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Drop-in for `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Drop-in for `anyhow::bail!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Drop-in for `anyhow::ensure!`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing file");
    }

    #[test]
    fn context_layers_render_outermost_first() {
        let e: Result<()> = std::result::Result::Err(io_err()).context("opening config");
        let e = e.unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing file");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");

        fn check(x: usize) -> Result<usize> {
            ensure!(x > 2, "x too small: {x}");
            if x > 100 {
                bail!("x too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(check(5).unwrap(), 5);
        assert_eq!(format!("{}", check(1).unwrap_err()), "x too small: 1");
        assert_eq!(format!("{}", check(200).unwrap_err()), "x too big: 200");
        let e = anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
    }
}
