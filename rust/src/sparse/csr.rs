//! Compressed sparse row matrix with O(1) per-row nnz — the quantity the
//! STRADS load balancer (paper §2 step 3) equalizes across blocks.

use super::Coo;

#[derive(Clone, Debug)]
pub struct CsrMatrix {
    nrows: usize,
    ncols: usize,
    /// Row pointer array, len nrows + 1.
    indptr: Vec<usize>,
    /// Column indices, sorted within each row.
    indices: Vec<u32>,
    vals: Vec<f32>,
}

impl CsrMatrix {
    /// Build from COO triplets; duplicates are summed.
    pub fn from_coo(coo: &Coo) -> Self {
        let mut counts = vec![0usize; coo.nrows + 1];
        for &r in &coo.rows {
            counts[r as usize + 1] += 1;
        }
        for i in 1..counts.len() {
            counts[i] += counts[i - 1];
        }
        let indptr = counts.clone();
        let mut cursor = counts;
        let mut indices = vec![0u32; coo.nnz()];
        let mut vals = vec![0.0f32; coo.nnz()];
        for k in 0..coo.nnz() {
            let r = coo.rows[k] as usize;
            let pos = cursor[r];
            indices[pos] = coo.cols[k];
            vals[pos] = coo.vals[k];
            cursor[r] += 1;
        }
        let mut m =
            CsrMatrix { nrows: coo.nrows, ncols: coo.ncols, indptr, indices, vals };
        m.sort_and_dedup_rows();
        m
    }

    fn sort_and_dedup_rows(&mut self) {
        let mut new_indices = Vec::with_capacity(self.indices.len());
        let mut new_vals = Vec::with_capacity(self.vals.len());
        let mut new_indptr = Vec::with_capacity(self.indptr.len());
        new_indptr.push(0);
        let mut row_buf: Vec<(u32, f32)> = Vec::new();
        for r in 0..self.nrows {
            row_buf.clear();
            for k in self.indptr[r]..self.indptr[r + 1] {
                row_buf.push((self.indices[k], self.vals[k]));
            }
            row_buf.sort_unstable_by_key(|&(c, _)| c);
            let mut i = 0;
            while i < row_buf.len() {
                let (c, mut v) = row_buf[i];
                let mut j = i + 1;
                while j < row_buf.len() && row_buf[j].0 == c {
                    v += row_buf[j].1;
                    j += 1;
                }
                new_indices.push(c);
                new_vals.push(v);
                i = j;
            }
            new_indptr.push(new_indices.len());
        }
        self.indices = new_indices;
        self.vals = new_vals;
        self.indptr = new_indptr;
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// nnz of one row — O(1).
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// Offset of row `i`'s first entry in the flat value order — O(1).
    /// (The MF backends keep per-entry residuals aligned with this.)
    #[inline]
    pub fn row_start(&self, i: usize) -> usize {
        self.indptr[i]
    }

    /// (column index, value) pairs of one row.
    #[inline]
    pub fn row(&self, i: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        self.indices[lo..hi].iter().map(|&c| c as usize).zip(self.vals[lo..hi].iter().copied())
    }

    /// Per-column nnz histogram (O(nnz)).
    pub fn col_nnz(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.ncols];
        for &c in &self.indices {
            counts[c as usize] += 1;
        }
        counts
    }

    /// Transposed copy (CSR of A^T = CSC of A) — used to drive the MF
    /// column (H) sweeps with the same row-block machinery.
    pub fn transpose(&self) -> CsrMatrix {
        let mut coo = Coo::new(self.ncols, self.nrows);
        for i in 0..self.nrows {
            for (j, v) in self.row(i) {
                coo.push(j, i, v);
            }
        }
        CsrMatrix::from_coo(&coo)
    }

    /// Materialize the dense row-major value matrix and the 0/1 mask —
    /// the device-upload form consumed by the MF AOT graphs.
    pub fn to_dense_row_major(&self) -> (Vec<f32>, Vec<f32>) {
        let mut dense = vec![0.0f32; self.nrows * self.ncols];
        let mut mask = vec![0.0f32; self.nrows * self.ncols];
        for i in 0..self.nrows {
            for (j, v) in self.row(i) {
                dense[i * self.ncols + j] = v;
                mask[i * self.ncols + j] = 1.0;
            }
        }
        (dense, mask)
    }

    /// Frobenius-squared error over observed entries against a low-rank
    /// factorization: sum_{(i,j) in Omega} (a_ij - w_i . h_j)^2, with W
    /// row-major [nrows, k] and H row-major [k, ncols].
    pub fn sq_error(&self, w: &[f32], h: &[f32], k: usize) -> f64 {
        let mut acc = 0.0f64;
        for i in 0..self.nrows {
            let wi = &w[i * k..(i + 1) * k];
            for (j, a) in self.row(i) {
                let mut pred = 0.0f32;
                for t in 0..k {
                    pred += wi[t] * h[t * self.ncols + j];
                }
                let d = (a - pred) as f64;
                acc += d * d;
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        let mut coo = Coo::new(3, 4);
        coo.push(0, 1, 2.0);
        coo.push(0, 3, 1.0);
        coo.push(2, 0, 5.0);
        coo.push(2, 0, 1.0); // duplicate -> summed
        coo.push(1, 2, -1.0);
        CsrMatrix::from_coo(&coo)
    }

    #[test]
    fn build_and_query() {
        let m = sample();
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row_nnz(0), 2);
        assert_eq!(m.row_nnz(1), 1);
        assert_eq!(m.row_nnz(2), 1);
        let row0: Vec<_> = m.row(0).collect();
        assert_eq!(row0, vec![(1, 2.0), (3, 1.0)]);
        let row2: Vec<_> = m.row(2).collect();
        assert_eq!(row2, vec![(0, 6.0)]); // duplicates summed
    }

    #[test]
    fn col_nnz_histogram() {
        let m = sample();
        assert_eq!(m.col_nnz(), vec![1, 1, 1, 1]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.nrows(), 4);
        assert_eq!(t.ncols(), 3);
        assert_eq!(t.nnz(), m.nnz());
        let tt = t.transpose();
        for i in 0..3 {
            let a: Vec<_> = m.row(i).collect();
            let b: Vec<_> = tt.row(i).collect();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn dense_and_mask() {
        let m = sample();
        let (dense, mask) = m.to_dense_row_major();
        assert_eq!(dense[0 * 4 + 1], 2.0);
        assert_eq!(mask[0 * 4 + 1], 1.0);
        assert_eq!(mask[0 * 4 + 0], 0.0);
        assert_eq!(mask.iter().sum::<f32>(), 4.0);
    }

    #[test]
    fn sq_error_zero_for_exact_factors() {
        // rank-1 exact: a_ij = u_i v_j on observed entries
        let u = [1.0f32, 2.0, 3.0];
        let v = [0.5f32, 1.0, 1.5, 2.0];
        let mut coo = Coo::new(3, 4);
        for i in 0..3 {
            for j in 0..4 {
                if (i + j) % 2 == 0 {
                    coo.push(i, j, u[i] * v[j]);
                }
            }
        }
        let m = CsrMatrix::from_coo(&coo);
        let err = m.sq_error(&u, &v, 1);
        assert!(err < 1e-10, "err {err}");
    }
}
