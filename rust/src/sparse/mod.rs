//! Sparse matrix substrate for the MF application (ratings matrices are
//! stored sparsely on the host; the dense+mask form is only materialized
//! at device-upload time).

pub mod csr;

pub use csr::CsrMatrix;

/// A COO triplet batch — the interchange form produced by the data
/// generators and consumed by [`CsrMatrix::from_coo`].
#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub nrows: usize,
    pub ncols: usize,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Coo {
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Coo { nrows, ncols, rows: Vec::new(), cols: Vec::new(), vals: Vec::new() }
    }

    pub fn push(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.nrows && j < self.ncols);
        self.rows.push(i as u32);
        self.cols.push(j as u32);
        self.vals.push(v);
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }
}
