//! Fenwick (binary indexed) tree over non-negative weights with
//! O(log n) point update and O(log n) weighted sampling.
//!
//! This is the engine behind the SAP importance distribution
//! `p(j) ∝ δβ_j + η` (paper §2 step 1 / §4): the scheduler keeps one
//! weight per owned variable, bumps it on every progress report, and
//! draws candidate sets by inverse-CDF descent down the tree — so both
//! the priority update (step 4) and the candidate draw (step 1) stay
//! logarithmic, which is what lets the scheduler outpace the workers.

#[derive(Clone, Debug)]
pub struct Fenwick {
    /// 1-based partial sums; tree[i] covers a range ending at i.
    tree: Vec<f64>,
    /// Mirror of the raw weights for O(1) reads and exact overwrites.
    weights: Vec<f64>,
}

impl Fenwick {
    /// All-zero tree over `n` items.
    pub fn new(n: usize) -> Self {
        Fenwick { tree: vec![0.0; n + 1], weights: vec![0.0; n] }
    }

    /// Build from initial weights in O(n).
    pub fn from_weights(ws: &[f64]) -> Self {
        let mut f = Fenwick::new(ws.len());
        for (i, &w) in ws.iter().enumerate() {
            f.set(i, w);
        }
        f
    }

    pub fn len(&self) -> usize {
        self.weights.len()
    }

    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }

    /// Current weight of item `i`.
    #[inline]
    pub fn get(&self, i: usize) -> f64 {
        self.weights[i]
    }

    /// Total weight.
    #[inline]
    pub fn total(&self) -> f64 {
        self.prefix_sum(self.weights.len())
    }

    /// Overwrite the weight of item `i` (must be >= 0 and finite).
    pub fn set(&mut self, i: usize, w: f64) {
        debug_assert!(w.is_finite() && w >= 0.0, "weight must be finite >= 0, got {w}");
        let delta = w - self.weights[i];
        self.weights[i] = w;
        let mut k = i + 1;
        while k < self.tree.len() {
            self.tree[k] += delta;
            k += k & k.wrapping_neg();
        }
    }

    /// Sum of weights for items [0, n).
    pub fn prefix_sum(&self, n: usize) -> f64 {
        let mut k = n.min(self.weights.len());
        let mut s = 0.0;
        while k > 0 {
            s += self.tree[k];
            k -= k & k.wrapping_neg();
        }
        s
    }

    /// Largest index i such that prefix_sum(i) <= target, i.e. the item
    /// whose CDF bucket contains `target`. O(log n) bit-descent.
    pub fn search(&self, mut target: f64) -> usize {
        let n = self.weights.len();
        let mut pos = 0usize;
        let mut mask = n.next_power_of_two();
        while mask > 0 {
            let next = pos + mask;
            if next < self.tree.len() && self.tree[next] < target {
                pos = next;
                target -= self.tree[next];
            }
            mask >>= 1;
        }
        pos.min(n - 1)
    }

    /// Draw one index with probability proportional to its weight.
    /// Returns None if all weights are zero.
    pub fn sample(&self, rng: &mut super::Rng) -> Option<usize> {
        let total = self.total();
        if total <= 0.0 {
            return None;
        }
        // Nudge away from exact 0, where `search` semantics are ambiguous.
        let target = rng.f64() * total + f64::MIN_POSITIVE;
        Some(self.search(target))
    }

    /// Draw up to `k` *distinct* indices by sampling-with-removal: each
    /// drawn index has its weight temporarily zeroed, and all weights are
    /// restored before returning. This is exactly "sample k items without
    /// replacement ∝ weight" and costs O(k log n).
    pub fn sample_distinct(&mut self, k: usize, rng: &mut super::Rng) -> Vec<usize> {
        let mut out = Vec::with_capacity(k);
        let mut saved: Vec<(usize, f64)> = Vec::with_capacity(k);
        for _ in 0..k {
            match self.sample(rng) {
                Some(i) => {
                    saved.push((i, self.weights[i]));
                    self.set(i, 0.0);
                    out.push(i);
                }
                None => break,
            }
        }
        for (i, w) in saved {
            self.set(i, w);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn prefix_sums_match_naive() {
        let ws: Vec<f64> = (0..37).map(|i| (i % 5) as f64 * 0.5).collect();
        let f = Fenwick::from_weights(&ws);
        let mut acc = 0.0;
        for i in 0..=ws.len() {
            assert!((f.prefix_sum(i) - acc).abs() < 1e-12, "prefix {i}");
            if i < ws.len() {
                acc += ws[i];
            }
        }
    }

    #[test]
    fn set_then_get_roundtrip() {
        let mut f = Fenwick::new(10);
        f.set(3, 2.5);
        f.set(9, 1.0);
        f.set(3, 0.25);
        assert_eq!(f.get(3), 0.25);
        assert!((f.total() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn search_finds_owning_bucket() {
        let f = Fenwick::from_weights(&[1.0, 0.0, 2.0, 1.0]);
        assert_eq!(f.search(0.5), 0);
        assert_eq!(f.search(1.5), 2); // item 1 has zero weight
        assert_eq!(f.search(2.999), 2);
        assert_eq!(f.search(3.5), 3);
    }

    #[test]
    fn sampling_frequencies_track_weights() {
        let ws = [1.0, 3.0, 0.0, 6.0];
        let f = Fenwick::from_weights(&ws);
        let mut rng = Rng::new(42);
        let mut counts = [0usize; 4];
        let n = 100_000;
        for _ in 0..n {
            counts[f.sample(&mut rng).unwrap()] += 1;
        }
        assert_eq!(counts[2], 0);
        let total: f64 = ws.iter().sum();
        for (i, &w) in ws.iter().enumerate() {
            if w == 0.0 {
                continue;
            }
            let expect = w / total * n as f64;
            let got = counts[i] as f64;
            assert!((got - expect).abs() < 0.05 * n as f64, "item {i}: {got} vs {expect}");
        }
    }

    #[test]
    fn sample_distinct_is_distinct_and_restores_weights() {
        let ws: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        let mut f = Fenwick::from_weights(&ws);
        let before = f.total();
        let mut rng = Rng::new(1);
        let picks = f.sample_distinct(8, &mut rng);
        assert_eq!(picks.len(), 8);
        let set: std::collections::HashSet<_> = picks.iter().collect();
        assert_eq!(set.len(), 8);
        assert!((f.total() - before).abs() < 1e-9);
    }

    #[test]
    fn sample_distinct_exhausts_gracefully() {
        let mut f = Fenwick::from_weights(&[0.0, 1.0, 0.0]);
        let mut rng = Rng::new(1);
        let picks = f.sample_distinct(5, &mut rng);
        assert_eq!(picks, vec![1]);
    }

    #[test]
    fn zero_total_yields_none() {
        let f = Fenwick::new(4);
        let mut rng = Rng::new(1);
        assert!(f.sample(&mut rng).is_none());
    }
}
