//! Minimal sectioned key=value config format (a TOML subset), used for
//! experiment presets in `configs/`. Offline-vendored builds have no
//! toml crate, and the configs only need scalars:
//!
//! ```text
//! # comment
//! workers = 240
//! lambda = 5e-4
//!
//! [sap]
//! rho = 0.1
//! shards = 4
//! ```

use std::collections::BTreeMap;

/// Parsed config: `section.key -> raw value string` (top-level keys use
/// an empty section, addressed simply as `key`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct KvConf {
    entries: BTreeMap<String, String>,
}

impl KvConf {
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = v.trim().trim_matches('"').to_string();
            entries.insert(key, val);
        }
        Ok(KvConf { entries })
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("reading {path:?}: {e}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, String> {
        self.get(key)
            .map(|v| v.parse::<f64>().map_err(|e| format!("{key}: {e}")))
            .transpose()
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>, String> {
        self.get(key)
            .map(|v| v.parse::<usize>().map_err(|e| format!("{key}: {e}")))
            .transpose()
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>, String> {
        self.get(key)
            .map(|v| v.parse::<u64>().map_err(|e| format!("{key}: {e}")))
            .transpose()
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let c = KvConf::parse(
            "# preset\nworkers = 240\nlambda = 5e-4\n\n[sap]\nrho = 0.1\nshards = 4\n",
        )
        .unwrap();
        assert_eq!(c.get_usize("workers").unwrap(), Some(240));
        assert_eq!(c.get_f64("lambda").unwrap(), Some(5e-4));
        assert_eq!(c.get_f64("sap.rho").unwrap(), Some(0.1));
        assert_eq!(c.get_usize("sap.shards").unwrap(), Some(4));
        assert_eq!(c.get("nope"), None);
    }

    #[test]
    fn strips_comments_and_quotes() {
        let c = KvConf::parse("name = \"adlike\"  # dataset\n").unwrap();
        assert_eq!(c.get("name"), Some("adlike"));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(KvConf::parse("just a line\n").is_err());
    }

    #[test]
    fn type_errors_are_reported() {
        let c = KvConf::parse("workers = many\n").unwrap();
        assert!(c.get_usize("workers").is_err());
    }
}
