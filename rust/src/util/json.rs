//! Minimal JSON parser — just enough for `artifacts/manifest.json`.
//!
//! The build environment is fully offline and the vendored crate set
//! does not include serde_json, so we parse the (machine-generated,
//! well-formed) manifest with a small recursive-descent parser. It
//! supports the complete JSON grammar except for exotic number forms
//! (hex, etc., which JSON does not allow anyway) and does not aim for
//! speed — the manifest is a few KiB, read once at startup.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("unexpected end")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i..self.i + 4).ok_or("bad \\u")?,
                            )
                            .map_err(|_| "bad \\u")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u hex")?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", e as char)),
                    }
                }
                _ => {
                    // copy raw UTF-8 bytes through
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                        && self.b[self.i] >= 0x20
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "invalid utf8")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad num")?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {s}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
            "version": 1,
            "artifacts": [
                {"name": "a", "kind": "lasso_update", "file": "a.hlo.txt",
                 "params": {"n": 128, "j": 256, "p": 16, "dataset": "tiny"}}
            ]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("a"));
        assert_eq!(arts[0].get("params").unwrap().get("p").unwrap().as_usize(), Some(16));
    }

    #[test]
    fn all_value_kinds() {
        let j = Json::parse(r#"{"a": [1, -2.5e3, true, false, null, "x\nyA"]}"#).unwrap();
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[1].as_f64(), Some(-2500.0));
        assert_eq!(a[2], Json::Bool(true));
        assert_eq!(a[4], Json::Null);
        assert_eq!(a[5].as_str(), Some("x\nyA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn nested_depth() {
        let j = Json::parse(r#"{"a":{"b":{"c":[{"d":1}]}}}"#).unwrap();
        let d = j.get("a").unwrap().get("b").unwrap().get("c").unwrap().as_arr().unwrap()[0]
            .get("d")
            .unwrap()
            .as_usize();
        assert_eq!(d, Some(1));
    }
}
