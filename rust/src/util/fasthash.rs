//! Multiplicative (Fibonacci) hasher for small integer keys — the
//! scheduler's dependency memo does ~60k lookups per round, where
//! std's SipHash costs more than the hash-map probe itself. Not DoS
//! resistant; use only for internal integer keys.

use std::hash::{BuildHasherDefault, Hasher};

/// 64-bit multiplicative hasher (fxhash-style fold).
#[derive(Default)]
pub struct FastHasher {
    state: u64,
}

const K: u64 = 0x517cc1b727220a95; // 2^64 / golden ratio, odd

impl Hasher for FastHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // generic path: fold 8 bytes at a time
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.write_u64(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.state = (self.state.rotate_left(5) ^ v).wrapping_mul(K);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.write_u64(v as u64);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }
}

/// Drop-in HashMap with the fast hasher.
pub type FastHashMap<K, V> = std::collections::HashMap<K, V, BuildHasherDefault<FastHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_keys_distinct_buckets_mostly() {
        let mut m: FastHashMap<(u32, u32), u32> = FastHashMap::default();
        for a in 0..100u32 {
            for b in 0..100u32 {
                m.insert((a, b), a * 1000 + b);
            }
        }
        assert_eq!(m.len(), 10_000);
        assert_eq!(m[&(7, 93)], 7_093);
    }

    #[test]
    fn hash_spreads_sequential_keys() {
        use std::hash::{BuildHasher, BuildHasherDefault};
        let bh: BuildHasherDefault<FastHasher> = Default::default();
        let mut low_bits = std::collections::HashSet::new();
        for i in 0..1024u64 {
            let h = bh.hash_one(i);
            low_bits.insert(h & 0xff);
        }
        // sequential keys should cover most of the 256 low-bit buckets
        assert!(low_bits.len() > 200, "only {} buckets", low_bits.len());
    }
}
