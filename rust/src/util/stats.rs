//! Streaming statistics (Welford) used by the metrics layer and the
//! load-balance diagnostics (block-size variance is the quantity the
//! paper's Fig 5 story hinges on).

/// Online mean/variance/min/max accumulator.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// max/mean — the straggler ratio; 1.0 means perfectly balanced.
    pub fn imbalance(&self) -> f64 {
        if self.n == 0 || self.mean == 0.0 {
            1.0
        } else {
            self.max / self.mean
        }
    }
}

impl std::iter::FromIterator<f64> for OnlineStats {
    fn from_iter<I: IntoIterator<Item = f64>>(iter: I) -> Self {
        let mut s = OnlineStats::new();
        for x in iter {
            s.push(x);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let s: OnlineStats = xs.iter().copied().collect();
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 4.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn imbalance_of_uniform_is_one() {
        let s: OnlineStats = [3.0, 3.0, 3.0].iter().copied().collect();
        assert!((s.imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_safe() {
        let s = OnlineStats::new();
        assert_eq!(s.variance(), 0.0);
        assert_eq!(s.imbalance(), 1.0);
    }
}
