//! Deterministic PRNG: xoshiro256** seeded through SplitMix64.
//!
//! Every stochastic component in the system (data generation, SAP
//! candidate sampling, the Shotgun baseline, proptest-free unit tests)
//! draws from this generator so that an experiment is a pure function of
//! its config + seed. We implement it ourselves rather than pulling in
//! `rand` to keep the scheduler hot loop free of trait-object dispatch
//! and to pin the stream across toolchain upgrades.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the full 256-bit state from a single u64 via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent child stream (used to give each scheduler
    /// shard / worker its own generator without sharing state).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n64 = n as u64;
        let threshold = n64.wrapping_neg() % n64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n64 as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (we only need the marginal stream
    /// to be deterministic, not maximally fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Zipf(s) sample over ranks {0, .., n-1} by inverse-CDF on a
    /// precomputed table (see [`ZipfTable`]). Provided here for one-off
    /// draws; bulk generation should use the table directly.
    pub fn zipf(&mut self, table: &ZipfTable) -> usize {
        table.sample(self)
    }
}

/// Precomputed Zipf CDF for power-law popularity draws (MF datasets).
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    pub fn new(n: usize, exponent: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for r in 0..n {
            acc += 1.0 / ((r + 1) as f64).powf(exponent);
            cdf.push(acc);
        }
        let z = acc;
        for v in cdf.iter_mut() {
            *v /= z;
        }
        ZipfTable { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(12345);
        let mut b = Rng::new(12345);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_uniform_enough() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c} out of range");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(99);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(3);
        for _ in 0..100 {
            let v = r.sample_distinct(50, 12);
            assert_eq!(v.len(), 12);
            let set: std::collections::HashSet<_> = v.iter().collect();
            assert_eq!(set.len(), 12);
            assert!(v.iter().all(|&x| x < 50));
        }
    }

    #[test]
    fn zipf_is_head_heavy() {
        let table = ZipfTable::new(1000, 1.2);
        let mut r = Rng::new(5);
        let mut head = 0;
        for _ in 0..10_000 {
            if table.sample(&mut r) < 10 {
                head += 1;
            }
        }
        // top-1% of ranks should collect far more than 1% of mass
        assert!(head > 2_000, "head draws {head}");
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(11);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }
}
