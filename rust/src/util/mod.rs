//! Small self-contained substrates: deterministic RNG, Fenwick-tree
//! weighted sampling (the p(j) engine), streaming statistics, and —
//! because this build is fully offline-vendored — a minimal JSON parser
//! (artifact manifest) and a key=value config format (presets).

pub mod fasthash;
pub mod fenwick;
pub mod json;
pub mod kvconf;
pub mod rng;
pub mod stats;

pub use fasthash::FastHashMap;
pub use fenwick::Fenwick;
pub use json::Json;
pub use kvconf::KvConf;
pub use rng::Rng;
pub use stats::OnlineStats;
