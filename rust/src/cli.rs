//! Minimal CLI argument parser (the vendored crate set has no clap).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and one
//! positional subcommand; unknown flags are hard errors with a usage
//! hint, and every flag is typed through [`Args::get`]-style accessors.
//!
//! Distributed-run knobs (the `distributed` / `staleness-sweep`
//! subcommands; see the USAGE string in `main.rs`):
//!
//! * `--staleness N|async` — the SSP bound `s`: a worker's pull may
//!   read parameter-server state at most `s` rounds behind its own
//!   round (`0` = BSP barrier, exactly the engine semantics; `async`
//!   removes the gate entirely).
//! * `--ps-shards N` — number of server shards: hash partitions for
//!   unregistered keys. Registered dense segments are single epoch
//!   slabs (read concurrency via `Arc`-shared epochs) and ignore this.
//! * `--republish-tol F` — incremental-republish tolerance: after each
//!   applied round the coordinator republishes only derived-state
//!   entries (e.g. Lasso residual cells) that moved by more than `F`
//!   since their last publish, plus a periodic full re-sync. `0`
//!   (default) is lossless — skip only bitwise-unchanged entries;
//!   negative restores a full republish every round.
//! * `--dense-segments 0|1` — register the problem's contiguous key
//!   ranges as immutable f32 epoch slabs (zero-copy `Arc` range pulls,
//!   copy-on-publish writes, zero hash probes, 4 bytes/cell pull
//!   wire); `0` keeps everything on the hashed f64 `Cell` path.
//! * `--pipeline 0|1` — gate-driven pipelining: with `s > 0`, dispatch
//!   rounds past the staleness bound and let the SSP gate pace the
//!   workers so scheduling overlaps compute; `0` throttles dispatch at
//!   the bound instead.
//! * `--scheduler dynamic|static|random` — which scheduling policy
//!   plans distributed rounds (routed through `SchedKind::build`, so
//!   all three policies run on the real-thread path, not just the
//!   simulator).
//! * `--sched-shards N` — scheduler-service shard threads S: each owns
//!   a fixed random J/S slice of the variables and plans its rounds
//!   (round-robin) on its own thread, pipelined ahead of execution
//!   into a bounded plan queue. `0` (default) follows `sap.shards`, so
//!   the distributed planner is identical to the engine-path scheduler
//!   built from the same config.
//! * `--sched-pipeline-depth N` — how many rounds each shard thread
//!   may plan ahead of the coordinator popping them (queue bound).
//! * `--sched-service 0|1` — `0` plans inline on the coordinator
//!   thread (the pre-service behaviour, kept for A/B runs; also the
//!   automatic fallback for problems without a scheduling oracle).
//! * `--ps-transport inproc|tcp` — the carriage between clients and
//!   the parameter server. `inproc` (default) keeps the server in this
//!   process (zero-copy `Arc` pulls); `tcp` talks the length-prefixed
//!   binary wire protocol (docs/ARCHITECTURE.md §Wire protocol) to a
//!   `strads ps-server` process at `--ps-addr`. Staleness-0 runs are
//!   bitwise identical across the two — the f32 wire is lossless — and
//!   tcp runs additionally report `socket_bytes`, the *real* traffic
//!   moved, next to the modeled `net_bytes` meter.
//! * `--ps-addr host:p1[,host:p2...]` — where that `ps-server` listens
//!   (also the default bind address for `strads ps-server --addr`). A
//!   comma-separated list routes the run over an N-server fleet: each
//!   server hosts a contiguous split of every dense segment plus a
//!   hash share of the scattered keys, and staleness-0 results stay
//!   bitwise identical for any N (`tests/ps_routed.rs`).
//! * `--obs-level 0|1|2` — the observability level (`[obs] level`):
//!   `0` = off, `1` (default) = the lock-free metrics registry (what
//!   `DistributedReport::obs_metrics` and `strads ps-stats` read),
//!   `2` = registry + per-phase span tracing. Obs settings are
//!   side-channel only: staleness-0 trajectories are bitwise identical
//!   at every level (pinned by `tests/obs.rs`).
//! * `--trace-events path.jsonl` — where span events go, one JSON
//!   object per line in the chrome://tracing event format (phases:
//!   pull, gate, compute, flush on worker tids; plan, apply, republish
//!   on the coordinator tid). Implies `--obs-level 2`.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    /// Flags consumed so far (for unknown-flag detection).
    seen: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse `std::env::args()[1..]`. Boolean flags get value "true".
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> anyhow::Result<Self> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(raw) = tok.strip_prefix("--") {
                let (key, val) = match raw.split_once('=') {
                    Some((k, v)) => (k.to_string(), v.to_string()),
                    None => {
                        // value is next token unless it's another flag
                        let takes_value =
                            it.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                        if takes_value {
                            (raw.to_string(), it.next().unwrap())
                        } else {
                            (raw.to_string(), "true".to_string())
                        }
                    }
                };
                anyhow::ensure!(
                    !out.flags.contains_key(&key),
                    "flag --{key} given more than once"
                );
                out.flags.insert(key, val);
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                anyhow::bail!("unexpected positional argument: {tok}");
            }
        }
        Ok(out)
    }

    fn raw(&self, key: &str) -> Option<&str> {
        self.seen.borrow_mut().insert(key.to_string());
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.raw(key).unwrap_or(default).to_string()
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.raw(key).map(|s| s.to_string())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.raw(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key}: {e}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.raw(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key}: {e}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.raw(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key}: {e}")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.raw(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Call after all accessors: errors on any flag never queried.
    pub fn finish(&self) -> anyhow::Result<()> {
        let seen = self.seen.borrow();
        for k in self.flags.keys() {
            anyhow::ensure!(seen.contains(k), "unknown flag --{k}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("fig4 --rounds 500 --out=results --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("fig4"));
        assert_eq!(a.usize_or("rounds", 0).unwrap(), 500);
        assert_eq!(a.str_or("out", "x"), "results");
        assert!(a.bool("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run-lasso");
        assert_eq!(a.usize_or("workers", 16).unwrap(), 16);
        assert_eq!(a.f64_or("lambda", 5e-4).unwrap(), 5e-4);
        assert!(!a.bool("artifacts"));
    }

    #[test]
    fn unknown_flag_detected() {
        let a = parse("fig1 --bogus 3");
        let _ = a.usize_or("rounds", 0);
        assert!(a.finish().is_err());
    }

    #[test]
    fn duplicate_flag_rejected() {
        assert!(Args::parse(
            ["--x", "1", "--x", "2"].into_iter().map(String::from)
        )
        .is_err());
    }

    #[test]
    fn type_errors_reported() {
        let a = parse("cmd --workers lots");
        assert!(a.usize_or("workers", 1).is_err());
    }
}
