//! Run traces: objective-vs-time series (the paper's figures are all of
//! this form), CSV emission, and summary statistics.

use std::io::Write;

/// One recorded point of a run.
#[derive(Clone, Debug)]
pub struct TracePoint {
    pub round: usize,
    /// Virtual cluster time (seconds) — the x-axis of Fig 1/4/5.
    pub vtime: f64,
    /// Real wall-clock of this process (seconds) — for perf bookkeeping.
    pub wtime: f64,
    pub objective: f64,
    /// Number of active (nonzero) variables, where meaningful.
    pub active_vars: usize,
    /// Straggler diagnostic: max block work / mean block work this round.
    pub imbalance: f64,
    /// Mean observed pull staleness (rounds behind) this round — the
    /// parameter-server path; 0 on the simulator paths.
    pub staleness: f64,
    /// Cumulative parameter-server wire bytes (worker flushes +
    /// coordinator republishes + worker pulls, with f32 epoch ranges
    /// metered at 4 bytes/cell) when this point was recorded; 0 on
    /// the simulator paths.
    pub net_bytes: u64,
    /// Seconds the coordinator spent blocked on (or inline computing)
    /// this round's plan — the scheduling stall the pipelined service
    /// exists to hide. `vtime` excludes it on the distributed path, so
    /// compute and scheduling time are separable in the trace.
    pub sched_wait: f64,
    /// Cumulative pulls that had to block at the SSP gate when this
    /// point was recorded — the per-round view of the run-level
    /// `gate_waits` aggregate; 0 on the simulator paths.
    pub gate_waits: u64,
}

/// A full run trace plus identifying metadata.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub scheduler: String,
    pub dataset: String,
    pub workers: usize,
    pub points: Vec<TracePoint>,
}

impl Trace {
    pub fn new(scheduler: &str, dataset: &str, workers: usize) -> Self {
        Trace {
            scheduler: scheduler.to_string(),
            dataset: dataset.to_string(),
            workers,
            points: Vec::new(),
        }
    }

    pub fn push(&mut self, p: TracePoint) {
        self.points.push(p);
    }

    pub fn final_objective(&self) -> f64 {
        self.points.last().map(|p| p.objective).unwrap_or(f64::NAN)
    }

    pub fn final_vtime(&self) -> f64 {
        self.points.last().map(|p| p.vtime).unwrap_or(0.0)
    }

    /// First virtual time at which the objective reaches `threshold`
    /// (the "time-to-quality" summary used in EXPERIMENTS.md tables).
    pub fn time_to_reach(&self, threshold: f64) -> Option<f64> {
        self.points.iter().find(|p| p.objective <= threshold).map(|p| p.vtime)
    }

    /// The CSV column set `append_csv` emits — one name per per-row
    /// field, in row order (pinned against the row format by test).
    pub const CSV_HEADER: &'static str = "scheduler,dataset,workers,round,vtime,wtime,objective,active_vars,imbalance,staleness,net_bytes,sched_wait,gate_waits";

    /// Append as CSV (with header if the file is new/empty).
    pub fn append_csv(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let new = !path.exists() || std::fs::metadata(path)?.len() == 0;
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        if new {
            writeln!(f, "{}", Self::CSV_HEADER)?;
        }
        for p in &self.points {
            writeln!(
                f,
                "{},{},{},{},{:.6},{:.6},{:.8e},{},{:.4},{:.4},{},{:.6},{}",
                self.scheduler,
                self.dataset,
                self.workers,
                p.round,
                p.vtime,
                p.wtime,
                p.objective,
                p.active_vars,
                p.imbalance,
                p.staleness,
                p.net_bytes,
                p.sched_wait,
                p.gate_waits
            )?;
        }
        Ok(())
    }

    /// One-line summary for terminal output, ending with the run's
    /// final staleness / wire-byte / scheduling-stall observations.
    pub fn summary(&self) -> String {
        let last = self.points.last();
        format!(
            "{:<10} {:<12} P={:<4} rounds={:<6} vtime={:>9.3}s obj={:.6e} stale={:.2} net={}B sched_wait={:.3}s",
            self.scheduler,
            self.dataset,
            self.workers,
            last.map(|p| p.round).unwrap_or(0),
            self.final_vtime(),
            self.final_objective(),
            last.map(|p| p.staleness).unwrap_or(0.0),
            last.map(|p| p.net_bytes).unwrap_or(0),
            self.points.iter().map(|p| p.sched_wait).sum::<f64>(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(objs: &[f64]) -> Trace {
        let mut t = Trace::new("dyn", "tiny", 4);
        for (i, &o) in objs.iter().enumerate() {
            t.push(TracePoint {
                round: i,
                vtime: i as f64 * 0.5,
                wtime: 0.0,
                objective: o,
                active_vars: i,
                imbalance: 1.0,
                staleness: 0.0,
                net_bytes: 0,
                sched_wait: 0.0,
                gate_waits: 0,
            });
        }
        t
    }

    #[test]
    fn time_to_reach_finds_first_crossing() {
        let t = mk(&[10.0, 5.0, 2.0, 1.0]);
        assert_eq!(t.time_to_reach(4.0), Some(1.0));
        assert_eq!(t.time_to_reach(0.5), None);
        assert_eq!(t.final_objective(), 1.0);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("strads_test_csv");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("t.csv");
        mk(&[3.0, 2.0]).append_csv(&path).unwrap();
        mk(&[1.0]).append_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 4); // header + 3 rows
        assert!(lines[0].starts_with("scheduler,"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn csv_header_columns_match_row_fields() {
        // A header/row drift here silently corrupts every downstream
        // plot, so the column counts are pinned against each other.
        let dir = std::env::temp_dir().join("strads_test_csv_header");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("t.csv");
        mk(&[3.0]).append_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        let header = lines.next().unwrap();
        let row = lines.next().unwrap();
        assert_eq!(header, Trace::CSV_HEADER);
        assert_eq!(
            header.split(',').count(),
            row.split(',').count(),
            "header {header:?} vs row {row:?}"
        );
        assert!(header.ends_with(",gate_waits"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn summary_reports_staleness_net_bytes_and_sched_wait() {
        let mut t = mk(&[3.0, 2.0]);
        t.points[0].sched_wait = 0.25;
        t.points[1].sched_wait = 0.5;
        t.points[1].staleness = 1.5;
        t.points[1].net_bytes = 4096;
        let s = t.summary();
        assert!(s.contains("stale=1.50"), "{s}");
        assert!(s.contains("net=4096B"), "{s}");
        assert!(s.contains("sched_wait=0.750s"), "{s}");
    }
}
