//! Column-major dense f32 matrix.

use super::{axpy, dot};

/// Column-major storage: element (i, j) lives at `data[j * nrows + i]`,
/// so `col(j)` is a contiguous slice — the access pattern of coordinate
/// descent, standardization, and the host->device upload.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    nrows: usize,
    ncols: usize,
    data: Vec<f32>,
}

impl DenseMatrix {
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        DenseMatrix { nrows, ncols, data: vec![0.0; nrows * ncols] }
    }

    /// Build from a closure f(i, j).
    pub fn from_fn(nrows: usize, ncols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = DenseMatrix::zeros(nrows, ncols);
        for j in 0..ncols {
            for i in 0..nrows {
                m.data[j * nrows + i] = f(i, j);
            }
        }
        m
    }

    /// Wrap an existing column-major buffer.
    pub fn from_col_major(nrows: usize, ncols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), nrows * ncols);
        DenseMatrix { nrows, ncols, data }
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[j * self.nrows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[j * self.nrows + i] = v;
    }

    /// Contiguous column slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f32] {
        &self.data[j * self.nrows..(j + 1) * self.nrows]
    }

    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f32] {
        &mut self.data[j * self.nrows..(j + 1) * self.nrows]
    }

    /// Raw column-major buffer (device upload path).
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Row-major copy (the JAX graphs take row-major [N, J] inputs).
    pub fn to_row_major(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.data.len()];
        for j in 0..self.ncols {
            let c = self.col(j);
            for i in 0..self.nrows {
                out[i * self.ncols + j] = c[i];
            }
        }
        out
    }

    /// y = A x  (column-major gemv as a sum of scaled columns).
    pub fn gemv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        y.fill(0.0);
        for j in 0..self.ncols {
            if x[j] != 0.0 {
                axpy(x[j], self.col(j), y);
            }
        }
    }

    /// Correlation (inner product) of two columns: x_j^T x_k.
    #[inline]
    pub fn col_dot(&self, j: usize, k: usize) -> f32 {
        dot(self.col(j), self.col(k))
    }

    /// Standardize every column to zero mean (over the first `live_rows`
    /// rows) and unit L2 norm; rows >= live_rows are zero padding for the
    /// Pallas row tile and are left untouched. Columns with ~zero
    /// variance are zeroed. Returns per-column scale factors applied.
    pub fn standardize_columns(&mut self, live_rows: usize) -> Vec<f32> {
        assert!(live_rows <= self.nrows);
        let mut scales = Vec::with_capacity(self.ncols);
        let nrows = self.nrows;
        for j in 0..self.ncols {
            let col = &mut self.data[j * nrows..(j + 1) * nrows];
            let mean = col[..live_rows].iter().sum::<f32>() / live_rows as f32;
            for v in col[..live_rows].iter_mut() {
                *v -= mean;
            }
            let norm = dot(&col[..live_rows], &col[..live_rows]).sqrt();
            let scale = if norm > 1e-8 { 1.0 / norm } else { 0.0 };
            for v in col[..live_rows].iter_mut() {
                *v *= scale;
            }
            scales.push(scale);
        }
        scales
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn col_major_indexing() {
        let m = DenseMatrix::from_fn(3, 2, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.get(2, 1), 21.0);
        assert_eq!(m.col(1), &[1.0, 11.0, 21.0]);
    }

    #[test]
    fn row_major_roundtrip() {
        let m = DenseMatrix::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(m.to_row_major(), vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn gemv_matches_naive() {
        let m = DenseMatrix::from_fn(4, 3, |i, j| (i + j) as f32);
        let x = [1.0f32, -1.0, 2.0];
        let mut y = [0.0f32; 4];
        m.gemv(&x, &mut y);
        for i in 0..4 {
            let want: f32 = (0..3).map(|j| m.get(i, j) * x[j]).sum();
            assert!((y[i] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn standardize_gives_unit_norm_zero_mean() {
        let mut m = DenseMatrix::from_fn(8, 3, |i, j| ((i * 7 + j * 3) % 5) as f32);
        m.standardize_columns(8);
        for j in 0..3 {
            let c = m.col(j);
            let mean: f32 = c.iter().sum::<f32>() / 8.0;
            let norm: f32 = dot(c, c);
            assert!(mean.abs() < 1e-6, "mean {mean}");
            assert!((norm - 1.0).abs() < 1e-5, "norm {norm}");
        }
    }

    #[test]
    fn standardize_preserves_zero_padding() {
        let mut m = DenseMatrix::from_fn(8, 2, |i, _| if i < 6 { (i + 1) as f32 } else { 0.0 });
        m.standardize_columns(6);
        for j in 0..2 {
            assert_eq!(m.get(6, j), 0.0);
            assert_eq!(m.get(7, j), 0.0);
        }
    }

    #[test]
    fn constant_column_is_zeroed() {
        let mut m = DenseMatrix::from_fn(4, 1, |_, _| 3.0);
        let scales = m.standardize_columns(4);
        assert_eq!(scales[0], 0.0);
        assert!(m.col(0).iter().all(|&v| v == 0.0));
    }
}
