//! Minimal dense linear algebra: a column-major f32 matrix plus the hot
//! dot/axpy/gemv primitives.
//!
//! This is the native (pure-rust) compute substrate. It serves three
//! roles: (1) the reference backend that cross-checks the AOT artifacts
//! end-to-end, (2) the worker-pool execution path (PJRT handles are not
//! Send, so OS-thread workers run native updates), and (3) the data
//! standardization pipeline. Column-major layout matches both the
//! coordinate-descent access pattern (column slices are contiguous) and
//! what we upload to the device.

pub mod dense;

pub use dense::DenseMatrix;

/// Dot product of two equal-length slices (unrolled 4-wide; the
/// autovectorizer turns this into SIMD on release builds).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let k = i * 4;
        s0 += a[k] * b[k];
        s1 += a[k + 1] * b[k + 1];
        s2 += a[k + 2] * b[k + 2];
        s3 += a[k + 3] * b[k + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for k in chunks * 4..a.len() {
        s += a[k] * b[k];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Squared L2 norm.
#[inline]
pub fn norm2_sq(a: &[f32]) -> f64 {
    a.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// L1 norm.
#[inline]
pub fn norm1(a: &[f32]) -> f64 {
    a.iter().map(|&v| (v as f64).abs()).sum()
}

/// Soft-threshold operator S(g, lam) = sign(g) * max(|g| - lam, 0).
#[inline]
pub fn soft_threshold(g: f64, lam: f64) -> f64 {
    if g > lam {
        g - lam
    } else if g < -lam {
        g + lam
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| i as f32 * 0.1).collect();
        let b: Vec<f32> = (0..103).map(|i| (102 - i) as f32 * 0.2).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < naive.abs() * 1e-5);
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 10.0, 10.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, [10.5, 11.0, 11.5]);
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn norms() {
        assert!((norm2_sq(&[3.0, 4.0]) - 25.0).abs() < 1e-9);
        assert!((norm1(&[-3.0, 4.0]) - 7.0).abs() < 1e-9);
    }
}
