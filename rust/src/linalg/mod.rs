//! Minimal dense linear algebra: a column-major f32 matrix plus the hot
//! dot/axpy/gemv primitives.
//!
//! This is the native (pure-rust) compute substrate. It serves three
//! roles: (1) the reference backend that cross-checks the AOT artifacts
//! end-to-end, (2) the worker-pool execution path (PJRT handles are not
//! Send, so OS-thread workers run native updates), and (3) the data
//! standardization pipeline. Column-major layout matches both the
//! coordinate-descent access pattern (column slices are contiguous) and
//! what we upload to the device.

pub mod dense;

pub use dense::DenseMatrix;

/// Dot product of two equal-length slices: 8 independent lane
/// accumulators over `chunks_exact(8)` blocks, so the bounds checks
/// vanish and the autovectorizer maps the lanes onto one SIMD register
/// (two on AVX) with no cross-lane dependency per step.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let a_chunks = a.chunks_exact(8);
    let b_chunks = b.chunks_exact(8);
    let a_rem = a_chunks.remainder();
    let b_rem = b_chunks.remainder();
    for (ca, cb) in a_chunks.zip(b_chunks) {
        for lane in 0..8 {
            acc[lane] += ca[lane] * cb[lane];
        }
    }
    // Pairwise lane reduction (balanced tree, not a serial chain).
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5]))
        + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    for (xi, yi) in a_rem.iter().zip(b_rem) {
        s += xi * yi;
    }
    s
}

/// y += alpha * x, in `chunks_exact` blocks of 8 so the element loop
/// compiles branch-free (elementwise: bitwise identical to the scalar
/// loop, any order).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let x_chunks = x.chunks_exact(8);
    let x_rem = x_chunks.remainder();
    let mut y_chunks = y.chunks_exact_mut(8);
    for (cy, cx) in y_chunks.by_ref().zip(x_chunks) {
        for lane in 0..8 {
            cy[lane] += alpha * cx[lane];
        }
    }
    for (yi, xi) in y_chunks.into_remainder().iter_mut().zip(x_rem) {
        *yi += alpha * xi;
    }
}

/// Squared L2 norm.
#[inline]
pub fn norm2_sq(a: &[f32]) -> f64 {
    a.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// L1 norm.
#[inline]
pub fn norm1(a: &[f32]) -> f64 {
    a.iter().map(|&v| (v as f64).abs()).sum()
}

/// Soft-threshold operator S(g, lam) = sign(g) * max(|g| - lam, 0).
#[inline]
pub fn soft_threshold(g: f64, lam: f64) -> f64 {
    if g > lam {
        g - lam
    } else if g < -lam {
        g + lam
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| i as f32 * 0.1).collect();
        let b: Vec<f32> = (0..103).map(|i| (102 - i) as f32 * 0.2).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < naive.abs() * 1e-5);
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 10.0, 10.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, [10.5, 11.0, 11.5]);
    }

    /// Property check of the chunked kernels against scalar references
    /// over randomized lengths (covering every remainder class) and
    /// values: dot within 1e-5 relative of an f64 reference, axpy
    /// bitwise equal to the scalar loop.
    #[test]
    fn chunked_kernels_match_scalar_reference() {
        let mut rng = crate::util::Rng::new(0xd07);
        for case in 0..200 {
            let len = if case < 40 { case } else { rng.below(2000) + 1 };
            let a: Vec<f32> = (0..len).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
            let b: Vec<f32> = (0..len).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
            let alpha = (rng.f64() - 0.5) as f32;

            let reference: f64 =
                a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let got = dot(&a, &b) as f64;
            let scale = a.iter().map(|&v| (v as f64).abs()).sum::<f64>().max(1.0);
            assert!(
                (got - reference).abs() <= 1e-5 * scale,
                "len {len}: dot {got} vs reference {reference}"
            );

            let mut y = b.clone();
            axpy(alpha, &a, &mut y);
            for i in 0..len {
                assert_eq!(y[i], b[i] + alpha * a[i], "len {len} elem {i}");
            }
        }
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn norms() {
        assert!((norm2_sq(&[3.0, 4.0]) - 25.0).abs() < 1e-9);
        assert!((norm1(&[-3.0, 4.0]) - 7.0).abs() < 1e-9);
    }
}
