//! Synthetic dataset generators.
//!
//! The paper evaluates on four datasets we cannot ship (the Alzheimer's
//! disease SNP panel is access-restricted; Netflix and Yahoo-Music are
//! license-encumbered). Each generator below synthesizes the *property*
//! the corresponding experiment exercises — see DESIGN.md §2 for the
//! substitution rationale:
//!
//! * [`lasso_synth`] — correlated-block designs (LD-structure-like) for
//!   the Lasso experiments: correlation blocks create the interference
//!   that SAP's dependency checker must avoid, and sparse ground-truth
//!   coefficients create the dynamic `beta_j = 0` structure that the
//!   importance distribution exploits.
//! * [`mf_powerlaw`] — Zipf-popularity bipartite ratings for the MF
//!   experiments: the power-law nnz distribution across rows/columns is
//!   exactly what makes naive uniform partitioning straggle (Fig 5).

pub mod lasso_synth;
pub mod mf_powerlaw;

/// The Pallas row tile; sample counts are padded to a multiple of this
/// (zero rows are exact for standardized regression).
pub const ROW_TILE: usize = 128;

/// Round `n` up to a multiple of [`ROW_TILE`].
pub fn pad_rows(n: usize) -> usize {
    n.div_ceil(ROW_TILE) * ROW_TILE
}
