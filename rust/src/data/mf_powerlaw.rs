//! Power-law bipartite ratings generator (Netflix / Yahoo-Music
//! stand-in).
//!
//! Observed entries are drawn with row (user) and column (item)
//! popularity following Zipf distributions; values follow a planted
//! low-rank model plus noise, so CCD actually has structure to recover.
//! The Zipf exponent is the experimental knob: the paper notes Yahoo-
//! Music's nnz are "heavily biased towards a few items (strong power-law
//! behavior)" — we model Netflix-like vs Yahoo-like purely through that
//! exponent, which is the variable Fig 5's load-balancing story depends
//! on.

use crate::sparse::{Coo, CsrMatrix};
use crate::util::rng::ZipfTable;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct MfSynthSpec {
    pub n_users: usize,
    pub m_items: usize,
    /// Planted rank of the signal.
    pub rank: usize,
    /// Target number of observed entries.
    pub nnz: usize,
    /// Zipf exponent for user activity (rows).
    pub user_exponent: f64,
    /// Zipf exponent for item popularity (columns).
    pub item_exponent: f64,
    /// Observation noise std.
    pub noise_std: f64,
}

impl MfSynthSpec {
    /// Matches the `tiny` MF artifact shapes (tests / quickstart).
    pub fn tiny() -> Self {
        MfSynthSpec {
            n_users: 256,
            m_items: 128,
            rank: 4,
            nnz: 3_000,
            user_exponent: 0.8,
            item_exponent: 0.8,
            noise_std: 0.1,
        }
    }

    /// Netflix-like regime: mild power law. Matches `rec` shapes.
    pub fn netflix_like() -> Self {
        MfSynthSpec {
            n_users: 2048,
            m_items: 1024,
            rank: 8,
            nnz: 80_000,
            user_exponent: 0.65,
            item_exponent: 0.65,
            noise_std: 0.2,
        }
    }

    /// Yahoo-Music-like regime: strong power law ("heavily biased
    /// towards a few items"). Matches `rec` shapes.
    pub fn yahoo_like() -> Self {
        MfSynthSpec {
            n_users: 2048,
            m_items: 1024,
            rank: 8,
            nnz: 80_000,
            user_exponent: 1.2,
            item_exponent: 1.8,
            noise_std: 0.2,
        }
    }
}

/// A generated MF instance: the ratings in CSR (host form) plus the
/// planted factors for diagnostics.
#[derive(Clone, Debug)]
pub struct MfData {
    pub a: CsrMatrix,
    pub rank_true: usize,
}

/// Generate Zipf-popularity observations of a planted low-rank matrix.
pub fn generate(spec: &MfSynthSpec, seed: u64) -> MfData {
    let mut rng = Rng::new(seed);

    // Planted factors: entries ~ N(0, 1/sqrt(rank)) so a_ij is O(1).
    let scale = 1.0 / (spec.rank as f64).sqrt();
    let u: Vec<f32> = (0..spec.n_users * spec.rank)
        .map(|_| (rng.normal() * scale) as f32)
        .collect();
    let v: Vec<f32> = (0..spec.m_items * spec.rank)
        .map(|_| (rng.normal() * scale) as f32)
        .collect();

    // Popularity ranks: identity permutation of users/items re-labelled
    // randomly so "hot" rows/cols are scattered, not clustered at 0.
    let mut user_label: Vec<u32> = (0..spec.n_users as u32).collect();
    let mut item_label: Vec<u32> = (0..spec.m_items as u32).collect();
    rng.shuffle(&mut user_label);
    rng.shuffle(&mut item_label);

    let user_zipf = ZipfTable::new(spec.n_users, spec.user_exponent);
    let item_zipf = ZipfTable::new(spec.m_items, spec.item_exponent);

    let mut seen = std::collections::HashSet::with_capacity(spec.nnz * 2);
    let mut coo = Coo::new(spec.n_users, spec.m_items);
    let mut attempts = 0usize;
    let max_attempts = spec.nnz * 50;
    while coo.nnz() < spec.nnz && attempts < max_attempts {
        attempts += 1;
        let i = user_label[user_zipf.sample(&mut rng)] as usize;
        let j = item_label[item_zipf.sample(&mut rng)] as usize;
        if !seen.insert((i as u32, j as u32)) {
            continue;
        }
        let mut val = 0.0f32;
        for t in 0..spec.rank {
            val += u[i * spec.rank + t] * v[j * spec.rank + t];
        }
        val += (rng.normal() * spec.noise_std) as f32;
        coo.push(i, j, val);
    }

    MfData { a: CsrMatrix::from_coo(&coo), rank_true: spec.rank }
}

/// Gini coefficient of a count histogram — our summary statistic for
/// "how power-law" a dataset is (0 = uniform, ->1 = all mass on one).
pub fn gini(counts: &[usize]) -> f64 {
    if counts.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len() as f64;
    let total: f64 = sorted.iter().sum();
    if total == 0.0 {
        return 0.0;
    }
    let weighted: f64 = sorted.iter().enumerate().map(|(i, &x)| (i as f64 + 1.0) * x).sum();
    (2.0 * weighted) / (n * total) - (n + 1.0) / n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reaches_target_nnz() {
        let d = generate(&MfSynthSpec::tiny(), 1);
        let spec = MfSynthSpec::tiny();
        assert!(d.a.nnz() >= spec.nnz * 9 / 10, "nnz {}", d.a.nnz());
        assert_eq!(d.a.nrows(), spec.n_users);
        assert_eq!(d.a.ncols(), spec.m_items);
    }

    #[test]
    fn yahoo_like_is_more_skewed_than_netflix_like() {
        let nf = generate(&MfSynthSpec { nnz: 20_000, ..MfSynthSpec::netflix_like() }, 2);
        let ym = generate(&MfSynthSpec { nnz: 20_000, ..MfSynthSpec::yahoo_like() }, 2);
        let g_nf = gini(&nf.a.col_nnz());
        let g_ym = gini(&ym.a.col_nnz());
        assert!(g_ym > g_nf + 0.1, "gini nf {g_nf} ym {g_ym}");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = generate(&MfSynthSpec::tiny(), 3);
        let b = generate(&MfSynthSpec::tiny(), 3);
        assert_eq!(a.a.nnz(), b.a.nnz());
        let ra: Vec<_> = a.a.row(0).collect();
        let rb: Vec<_> = b.a.row(0).collect();
        assert_eq!(ra, rb);
    }

    #[test]
    fn gini_extremes() {
        assert!(gini(&[5, 5, 5, 5]).abs() < 1e-9);
        assert!(gini(&[0, 0, 0, 100]) > 0.7);
    }

    #[test]
    fn planted_structure_beats_noise() {
        // The planted factors should explain most of the variance.
        let spec = MfSynthSpec::tiny();
        let d = generate(&spec, 4);
        let mut rng = Rng::new(99);
        let u: Vec<f32> = (0..spec.n_users * spec.rank).map(|_| rng.normal() as f32).collect();
        let _ = u;
        // total energy vs residual energy under zero factors: sq_error
        // with zero factors = sum a^2 > 0
        let zeros_w = vec![0.0f32; spec.n_users * spec.rank];
        let zeros_h = vec![0.0f32; spec.rank * spec.m_items];
        assert!(d.a.sq_error(&zeros_w, &zeros_h, spec.rank) > 0.0);
    }
}
