//! Correlated-block Lasso designs (the AD-dataset stand-in).
//!
//! Columns are organized into correlation blocks: within a block every
//! column shares a latent factor, giving pairwise correlation ≈ `corr`
//! after standardization; across blocks columns are independent. This
//! mimics linkage-disequilibrium structure in SNP panels — the regime
//! where naive parallel CD interferes (Shotgun's failure mode) and
//! dependency-aware scheduling pays off. Ground-truth coefficients are
//! sparse, so most β_j sit at zero during the run — the dynamic
//! structure STRADS's importance distribution exploits.

use crate::data::pad_rows;
use crate::linalg::DenseMatrix;
use crate::util::Rng;

/// Generation spec. `n_live` is the true sample count; the produced
/// matrix is zero-padded to `pad_rows(n_live)` rows.
#[derive(Clone, Debug)]
pub struct LassoSynthSpec {
    pub n_live: usize,
    pub j: usize,
    /// Columns per correlation block (1 = independent design).
    pub block_size: usize,
    /// Latent-factor loading; within-block correlation ≈ corr.
    pub corr: f64,
    /// Number of nonzero ground-truth coefficients.
    pub k_nonzero: usize,
    /// Magnitude scale of nonzero coefficients.
    pub signal: f64,
    /// Observation noise std.
    pub noise_std: f64,
    /// Rescale y so that lambda_max = max_j |x_j^T y| equals this.
    /// The paper runs the AD data at lambda = 5e-4 on its natural
    /// gene-expression scale; since that scale is not recoverable, we
    /// pin the dimensionless quantity lambda/lambda_max instead — with
    /// the default 0.01, the paper's lambda = 5e-4 sits at 5% of
    /// lambda_max, squarely in the sparse regime whose dynamic
    /// "beta_j stays zero" structure STRADS exploits.
    pub target_lambda_max: f64,
}

impl LassoSynthSpec {
    /// Matches the `tiny` artifact shapes (tests / quickstart).
    pub fn tiny() -> Self {
        LassoSynthSpec {
            n_live: 128,
            j: 256,
            block_size: 8,
            corr: 0.8,
            k_nonzero: 16,
            signal: 1.0,
            noise_std: 0.1,
            target_lambda_max: 0.01,
        }
    }

    /// AD-regime stand-in: few samples, many correlated covariates.
    /// Matches the `adlike` artifact shapes (463 live rows -> 512).
    pub fn adlike() -> Self {
        LassoSynthSpec {
            n_live: 463,
            j: 4096,
            block_size: 16,
            corr: 0.85,
            k_nonzero: 64,
            signal: 1.0,
            noise_std: 0.25,
            target_lambda_max: 0.01,
        }
    }

    /// Paper's wide synthetic regime (scaled): weakly correlated, very
    /// wide. Matches the `wide` artifact shapes.
    pub fn wide() -> Self {
        LassoSynthSpec {
            n_live: 384,
            j: 8192,
            block_size: 4,
            corr: 0.3,
            k_nonzero: 128,
            signal: 1.0,
            noise_std: 0.25,
            target_lambda_max: 0.01,
        }
    }
}

/// A generated Lasso problem instance.
#[derive(Clone, Debug)]
pub struct LassoData {
    /// Standardized design, [n_padded x j], unit-norm zero-mean columns.
    pub x: DenseMatrix,
    /// Response (zero-padded), length n_padded.
    pub y: Vec<f32>,
    /// Ground-truth coefficients (in the *generated*, pre-standardized
    /// scale — for diagnostics only, not comparable to fitted β).
    pub beta_true: Vec<f32>,
    pub n_live: usize,
}

impl LassoData {
    pub fn n(&self) -> usize {
        self.x.nrows()
    }

    pub fn j(&self) -> usize {
        self.x.ncols()
    }
}

/// Generate a correlated-block design + sparse-signal response.
pub fn generate(spec: &LassoSynthSpec, seed: u64) -> LassoData {
    let n_pad = pad_rows(spec.n_live);
    let mut rng = Rng::new(seed);
    let mut x = DenseMatrix::zeros(n_pad, spec.j);

    // Latent factor per block, shared by its member columns.
    let load = spec.corr.sqrt();
    let resid = (1.0 - spec.corr).sqrt();
    let nblocks = spec.j.div_ceil(spec.block_size);
    let mut factor = vec![0.0f64; spec.n_live];
    for b in 0..nblocks {
        for f in factor.iter_mut() {
            *f = rng.normal();
        }
        let lo = b * spec.block_size;
        let hi = (lo + spec.block_size).min(spec.j);
        for jcol in lo..hi {
            let col = x.col_mut(jcol);
            for i in 0..spec.n_live {
                col[i] = (load * factor[i] + resid * rng.normal()) as f32;
            }
        }
    }

    // Sparse ground truth: k_nonzero coefficients spread across blocks.
    let mut beta_true = vec![0.0f32; spec.j];
    for &jcol in rng.sample_distinct(spec.j, spec.k_nonzero.min(spec.j)).iter() {
        let sign = if rng.f64() < 0.5 { -1.0 } else { 1.0 };
        beta_true[jcol] = (sign * spec.signal * (0.5 + rng.f64())) as f32;
    }

    // y = X beta + noise on live rows (pre-standardization X).
    let mut y = vec![0.0f32; n_pad];
    for jcol in 0..spec.j {
        if beta_true[jcol] != 0.0 {
            let col = x.col(jcol);
            for i in 0..spec.n_live {
                y[i] += beta_true[jcol] * col[i];
            }
        }
    }
    for yi in y.iter_mut().take(spec.n_live) {
        *yi += (spec.noise_std * rng.normal()) as f32;
    }

    // Standardize columns over live rows (padding rows stay zero), then
    // standardize y to zero mean / unit norm, matching the paper's setup.
    x.standardize_columns(spec.n_live);
    let ymean = y[..spec.n_live].iter().sum::<f32>() / spec.n_live as f32;
    for v in y[..spec.n_live].iter_mut() {
        *v -= ymean;
    }
    let ynorm = crate::linalg::norm2_sq(&y[..spec.n_live]).sqrt() as f32;
    if ynorm > 1e-8 {
        for v in y[..spec.n_live].iter_mut() {
            *v /= ynorm;
        }
    }

    // Pin lambda_max = max_j |x_j^T y| (see `target_lambda_max`).
    let mut lam_max = 0.0f32;
    for jcol in 0..spec.j {
        lam_max = lam_max.max(crate::linalg::dot(x.col(jcol), &y).abs());
    }
    if lam_max > 1e-12 {
        let scale = (spec.target_lambda_max as f32) / lam_max;
        for v in y.iter_mut() {
            *v *= scale;
        }
    }

    LassoData { x, y, beta_true, n_live: spec.n_live }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dot;

    #[test]
    fn shapes_and_padding() {
        let d = generate(&LassoSynthSpec::tiny(), 1);
        assert_eq!(d.n(), 128);
        assert_eq!(d.j(), 256);
        assert_eq!(d.y.len(), 128);
    }

    #[test]
    fn adlike_pads_463_to_512() {
        let spec = LassoSynthSpec { j: 64, ..LassoSynthSpec::adlike() };
        let d = generate(&spec, 2);
        assert_eq!(d.n(), 512);
        assert_eq!(d.n_live, 463);
        for i in 463..512 {
            assert_eq!(d.y[i], 0.0);
            for j in 0..d.j() {
                assert_eq!(d.x.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn columns_are_standardized() {
        let d = generate(&LassoSynthSpec::tiny(), 3);
        for j in (0..d.j()).step_by(17) {
            let c = d.x.col(j);
            let norm = dot(c, c);
            assert!((norm - 1.0).abs() < 1e-4, "col {j} norm {norm}");
        }
    }

    #[test]
    fn within_block_correlation_exceeds_cross_block() {
        let spec = LassoSynthSpec { corr: 0.9, ..LassoSynthSpec::tiny() };
        let d = generate(&spec, 4);
        // within-block pair (0,1); cross-block pair (0, block_size)
        let within = d.x.col_dot(0, 1).abs();
        let cross = d.x.col_dot(0, spec.block_size).abs();
        assert!(within > 0.5, "within {within}");
        assert!(cross < 0.4, "cross {cross}");
        assert!(within > cross);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = generate(&LassoSynthSpec::tiny(), 7);
        let b = generate(&LassoSynthSpec::tiny(), 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = generate(&LassoSynthSpec::tiny(), 8);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn response_is_centered_and_lambda_max_pinned() {
        let spec = LassoSynthSpec::tiny();
        let d = generate(&spec, 5);
        let live = &d.y[..d.n_live];
        let mean: f32 = live.iter().sum::<f32>() / d.n_live as f32;
        assert!(mean.abs() < 1e-6);
        let lam_max = (0..d.j())
            .map(|j| crate::linalg::dot(d.x.col(j), &d.y).abs())
            .fold(0.0f32, f32::max);
        assert!(
            (lam_max - spec.target_lambda_max as f32).abs() < 1e-5,
            "lambda_max {lam_max}"
        );
    }
}
