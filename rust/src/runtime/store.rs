//! Artifact discovery + compilation cache.
//!
//! `manifest.json` (emitted by aot.py) describes every artifact: name,
//! kind, HLO file, and shape parameters. The store compiles lazily and
//! memoizes `PjRtLoadedExecutable`s, so each (graph, bucket) pays its
//! XLA compile exactly once per process.

use crate::util::Json;
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub kind: String,
    pub file: String,
    /// Shape parameters (n, j, p, c, m, k, b — kind-specific).
    params: HashMap<String, usize>,
    dataset: Option<String>,
}

impl Artifact {
    fn from_json(j: &Json) -> Result<Self> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .context("artifact missing name")?
            .to_string();
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .context("artifact missing kind")?
            .to_string();
        let file = j
            .get("file")
            .and_then(Json::as_str)
            .context("artifact missing file")?
            .to_string();
        let mut params = HashMap::new();
        let mut dataset = None;
        if let Some(Json::Obj(m)) = j.get("params") {
            for (k, v) in m {
                match v {
                    Json::Num(n) => {
                        params.insert(k.clone(), *n as usize);
                    }
                    Json::Str(s) if k == "dataset" => dataset = Some(s.clone()),
                    _ => {}
                }
            }
        }
        Ok(Artifact { name, kind, file, params, dataset })
    }

    /// Integer shape parameter accessor (n, j, p, ...).
    pub fn param(&self, key: &str) -> Option<usize> {
        self.params.get(key).copied()
    }

    pub fn dataset(&self) -> Option<&str> {
        self.dataset.as_deref()
    }
}

/// Lazy-compiling artifact store bound to one PJRT client.
pub struct ArtifactStore {
    client: xla::PjRtClient,
    dir: PathBuf,
    artifacts: Vec<Artifact>,
    compiled: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl ArtifactStore {
    /// Open `dir` (must contain manifest.json) on the CPU PJRT client.
    pub fn open(dir: &std::path::Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?}; run `make artifacts` first"))?;
        let json = Json::parse(&text).map_err(anyhow::Error::msg)?;
        let artifacts = json
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing artifacts[]")?
            .iter()
            .map(Artifact::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactStore {
            client,
            dir: dir.to_path_buf(),
            artifacts,
            compiled: RefCell::new(HashMap::new()),
        })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn artifacts(&self) -> &[Artifact] {
        &self.artifacts
    }

    /// All artifacts of `kind` for `dataset`, e.g. the bucket family of
    /// `lasso_update` for "adlike".
    pub fn family(&self, kind: &str, dataset: &str) -> Vec<&Artifact> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.dataset() == Some(dataset))
            .collect()
    }

    /// Compile (or fetch memoized) executable for artifact `name`.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.compiled.borrow().get(name) {
            return Ok(exe.clone());
        }
        let art = self
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact {name} not in manifest"))?;
        let path = self.dir.join(&art.file);
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(anyhow_xla)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp).map_err(anyhow_xla)?);
        self.compiled.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of executables compiled so far (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.compiled.borrow().len()
    }

    /// Upload an f32 tensor to the device.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).map_err(anyhow_xla)
    }

    /// Upload an i32 tensor to the device.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).map_err(anyhow_xla)
    }
}

/// The xla crate has its own error enum; fold it into anyhow.
pub fn anyhow_xla(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

/// Execute with buffer args and return the flattened output tuple as
/// host literals (the graphs are lowered with return_tuple=True, so the
/// single output buffer is a tuple).
pub fn execute_tuple(
    exe: &xla::PjRtLoadedExecutable,
    args: &[&xla::PjRtBuffer],
) -> Result<Vec<xla::Literal>> {
    let outs = exe.execute_b(args).map_err(anyhow_xla)?;
    let lit = outs[0][0].to_literal_sync().map_err(anyhow_xla)?;
    lit.to_tuple().map_err(anyhow_xla)
}
