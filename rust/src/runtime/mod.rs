//! The PJRT runtime: loads the AOT artifacts produced by
//! `make artifacts` and executes them from the rust hot path.
//!
//! Flow (see /opt/xla-example and DESIGN.md §6):
//! `python/compile/aot.py` lowers each L2 graph (with L1 Pallas kernels
//! inlined under `interpret=True`) to **HLO text**; here we parse with
//! [`xla::HloModuleProto::from_text_file`], compile once per
//! (graph, shape-bucket) on the CPU PJRT client, and call
//! `execute_b` with device-resident buffers for the large, immutable
//! inputs (the design matrix / ratings). Python never runs at serve
//! time; the binary is self-contained given `artifacts/`.

pub mod calls;
pub mod store;

pub use calls::{LassoExes, MfExes};
pub use store::{Artifact, ArtifactStore};

/// Locate the artifacts directory: explicit arg, `STRADS_ARTIFACTS`
/// env var, or `./artifacts` relative to the workspace root.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("STRADS_ARTIFACTS") {
        return dir.into();
    }
    // Walk up from cwd looking for artifacts/manifest.json (tests run
    // from target subdirs).
    let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return "artifacts".into();
        }
    }
}
