//! Typed call wrappers over the AOT artifacts: shape-bucket selection,
//! padding/masking, device-resident caching of the large immutable
//! inputs, and output unpacking.

use super::store::{execute_tuple, ArtifactStore};
use anyhow::Result;
use std::collections::BTreeMap;
use std::rc::Rc;

fn literal_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(super::store::anyhow_xla)
}

/// The Lasso artifact family for one dataset config: `lasso_update`
/// bucketed by coordinate capacity, `lasso_gram` bucketed by candidate
/// capacity, and `lasso_obj`. The design matrix X ([N, J] row-major) is
/// uploaded once and stays on device.
pub struct LassoExes {
    store: Rc<ArtifactStore>,
    dataset: String,
    pub n: usize,
    pub j: usize,
    /// capacity -> artifact name
    update_buckets: BTreeMap<usize, String>,
    gram_buckets: BTreeMap<usize, String>,
    obj_name: String,
    x_dev: xla::PjRtBuffer,
    y_dev: xla::PjRtBuffer,
}

impl LassoExes {
    /// `x` row-major [n, j]; `y` length n.
    pub fn new(store: Rc<ArtifactStore>, dataset: &str, x: &[f32], y: &[f32]) -> Result<Self> {
        let mut update_buckets = BTreeMap::new();
        let mut gram_buckets = BTreeMap::new();
        let mut dims: Option<(usize, usize)> = None;
        for a in store.family("lasso_update", dataset) {
            update_buckets.insert(a.param("p").unwrap(), a.name.clone());
            dims = Some((a.param("n").unwrap(), a.param("j").unwrap()));
        }
        for a in store.family("lasso_gram", dataset) {
            gram_buckets.insert(a.param("c").unwrap(), a.name.clone());
        }
        let obj = store
            .family("lasso_obj", dataset)
            .first()
            .map(|a| a.name.clone())
            .ok_or_else(|| anyhow::anyhow!("no lasso_obj artifact for {dataset}"))?;
        let (n, j) = dims.ok_or_else(|| anyhow::anyhow!("no lasso_update artifacts for {dataset}"))?;
        anyhow::ensure!(x.len() == n * j, "x must be [{n}, {j}] row-major, got {}", x.len());
        anyhow::ensure!(y.len() == n, "y must have n={n} entries");
        let x_dev = store.upload_f32(x, &[n, j])?;
        let y_dev = store.upload_f32(y, &[n, 1])?;
        Ok(LassoExes {
            store,
            dataset: dataset.to_string(),
            n,
            j,
            update_buckets,
            gram_buckets,
            obj_name: obj,
        x_dev,
            y_dev,
        })
    }

    pub fn dataset(&self) -> &str {
        &self.dataset
    }

    /// Smallest bucket with capacity >= need.
    fn pick(buckets: &BTreeMap<usize, String>, need: usize) -> Result<(usize, &str)> {
        buckets
            .range(need..)
            .next()
            .map(|(cap, name)| (*cap, name.as_str()))
            .ok_or_else(|| {
                anyhow::anyhow!("no bucket fits {need} (max {:?})", buckets.keys().last())
            })
    }

    /// Batched CD update over the selected coordinates, against residual
    /// `r`. Returns (beta_new, |delta|, r_new) with only the live lanes.
    pub fn update(
        &self,
        r: &[f32],
        idx: &[usize],
        beta_sel: &[f32],
        lambda: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(idx.len() == beta_sel.len());
        let live = idx.len();
        let (cap, name) = Self::pick(&self.update_buckets, live)?;
        let exe = self.store.executable(name)?;

        // Pad to capacity: idx 0 with mask 0 is exact (masked lanes keep
        // their old beta, delta = 0).
        let mut idx_p = vec![0i32; cap];
        let mut beta_p = vec![0.0f32; cap];
        let mut mask_p = vec![0.0f32; cap];
        for i in 0..live {
            idx_p[i] = idx[i] as i32;
            beta_p[i] = beta_sel[i];
            mask_p[i] = 1.0;
        }
        let r_dev = self.store.upload_f32(r, &[self.n, 1])?;
        let beta_dev = self.store.upload_f32(&beta_p, &[1, cap])?;
        let idx_dev = self.store.upload_i32(&idx_p, &[cap])?;
        let mask_dev = self.store.upload_f32(&mask_p, &[1, cap])?;
        let lam_dev = self.store.upload_f32(&[lambda], &[1, 1])?;

        let outs = execute_tuple(
            &exe,
            &[&self.x_dev, &r_dev, &beta_dev, &idx_dev, &mask_dev, &lam_dev],
        )?;
        anyhow::ensure!(outs.len() == 3, "lasso_update returns 3 outputs");
        let mut beta_new = literal_f32(&outs[0])?;
        let mut delta = literal_f32(&outs[1])?;
        let r_new = literal_f32(&outs[2])?;
        beta_new.truncate(live);
        delta.truncate(live);
        Ok((beta_new, delta, r_new))
    }

    /// Candidate Gram: |x_j^T x_k| for the candidate set (live c x c,
    /// row-major, absolute values, zero diagonal).
    pub fn gram(&self, idx: &[usize]) -> Result<Vec<f64>> {
        let live = idx.len();
        let (cap, name) = Self::pick(&self.gram_buckets, live)?;
        let exe = self.store.executable(name)?;
        let mut idx_p = vec![0i32; cap];
        for i in 0..live {
            idx_p[i] = idx[i] as i32;
        }
        let idx_dev = self.store.upload_i32(&idx_p, &[cap])?;
        let outs = execute_tuple(&exe, &[&self.x_dev, &idx_dev])?;
        let g = literal_f32(&outs[0])?;
        let mut out = vec![0.0f64; live * live];
        for i in 0..live {
            for k in 0..live {
                if i != k {
                    out[i * live + k] = g[i * cap + k].abs() as f64;
                }
            }
        }
        Ok(out)
    }

    /// Exact objective + fresh residual from the full coefficient
    /// vector (the drift-correction path).
    pub fn objective(&self, beta: &[f32], lambda: f32) -> Result<(f64, Vec<f32>)> {
        anyhow::ensure!(beta.len() == self.j);
        let exe = self.store.executable(&self.obj_name)?;
        let beta_dev = self.store.upload_f32(beta, &[self.j, 1])?;
        let lam_dev = self.store.upload_f32(&[lambda], &[1, 1])?;
        let outs = execute_tuple(&exe, &[&self.x_dev, &self.y_dev, &beta_dev, &lam_dev])?;
        anyhow::ensure!(outs.len() == 2);
        let obj = literal_f32(&outs[0])?[0] as f64;
        let r = literal_f32(&outs[1])?;
        Ok((obj, r))
    }
}

/// The MF artifact family: `mf_update_w` / `mf_update_h` bucketed by
/// block capacity, plus `mf_obj`. The ratings (values + mask, dense
/// row-major) are uploaded once; W and H round-trip per call.
pub struct MfExes {
    store: Rc<ArtifactStore>,
    pub n: usize,
    pub m: usize,
    pub k: usize,
    w_buckets: BTreeMap<usize, String>,
    h_buckets: BTreeMap<usize, String>,
    obj_name: String,
    a_dev: xla::PjRtBuffer,
    mask_dev: xla::PjRtBuffer,
}

impl MfExes {
    /// `a`, `mask` row-major [n, m].
    pub fn new(store: Rc<ArtifactStore>, dataset: &str, a: &[f32], mask: &[f32]) -> Result<Self> {
        let mut w_buckets = BTreeMap::new();
        let mut h_buckets = BTreeMap::new();
        let mut dims = None;
        for art in store.family("mf_update_w", dataset) {
            w_buckets.insert(art.param("b").unwrap(), art.name.clone());
            dims = Some((
                art.param("n").unwrap(),
                art.param("m").unwrap(),
                art.param("k").unwrap(),
            ));
        }
        for art in store.family("mf_update_h", dataset) {
            h_buckets.insert(art.param("b").unwrap(), art.name.clone());
        }
        let obj = store
            .family("mf_obj", dataset)
            .first()
            .map(|a| a.name.clone())
            .ok_or_else(|| anyhow::anyhow!("no mf_obj artifact for {dataset}"))?;
        let (n, m, k) = dims.ok_or_else(|| anyhow::anyhow!("no mf_update_w artifacts"))?;
        anyhow::ensure!(a.len() == n * m && mask.len() == n * m);
        let a_dev = store.upload_f32(a, &[n, m])?;
        let mask_dev = store.upload_f32(mask, &[n, m])?;
        Ok(MfExes { store, n, m, k, w_buckets, h_buckets, obj_name: obj, a_dev, mask_dev })
    }

    fn onehot(&self, t: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; self.k];
        v[t] = 1.0;
        v
    }

    /// Rank-t CCD update of W over a row block. `w` row-major [n, k],
    /// `h` row-major [k, m]. Returns (w_t_new per block row, |dw|, full
    /// updated W).
    pub fn update_w(
        &self,
        w: &[f32],
        h: &[f32],
        rows: &[usize],
        t: usize,
        lambda: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.update_inner(true, w, h, rows, t, lambda)
    }

    /// Rank-t CCD update of H over a column block. Returns
    /// (h_t_new per block col, |dh|, full updated H).
    pub fn update_h(
        &self,
        w: &[f32],
        h: &[f32],
        cols: &[usize],
        t: usize,
        lambda: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.update_inner(false, w, h, cols, t, lambda)
    }

    fn update_inner(
        &self,
        is_w: bool,
        w: &[f32],
        h: &[f32],
        block: &[usize],
        t: usize,
        lambda: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(w.len() == self.n * self.k && h.len() == self.k * self.m);
        anyhow::ensure!(t < self.k);
        let live = block.len();
        let buckets = if is_w { &self.w_buckets } else { &self.h_buckets };
        let (cap, name) = LassoExes::pick(buckets, live)?;
        let exe = self.store.executable(name)?;
        let mut idx_p = vec![0i32; cap];
        let mut mask_p = vec![0.0f32; cap];
        for i in 0..live {
            idx_p[i] = block[i] as i32;
            mask_p[i] = 1.0;
        }
        let w_dev = self.store.upload_f32(w, &[self.n, self.k])?;
        let h_dev = self.store.upload_f32(h, &[self.k, self.m])?;
        let idx_dev = self.store.upload_i32(&idx_p, &[cap])?;
        let bmask_dev = self.store.upload_f32(&mask_p, &[cap, 1])?;
        let t1h_dev = self.store.upload_f32(&self.onehot(t), &[self.k, 1])?;
        let lam_dev = self.store.upload_f32(&[lambda], &[1, 1])?;
        let outs = execute_tuple(
            &exe,
            &[&self.a_dev, &self.mask_dev, &w_dev, &h_dev, &idx_dev, &bmask_dev, &t1h_dev, &lam_dev],
        )?;
        anyhow::ensure!(outs.len() == 3);
        let mut new = literal_f32(&outs[0])?;
        let mut delta = literal_f32(&outs[1])?;
        let next = literal_f32(&outs[2])?;
        new.truncate(live);
        delta.truncate(live);
        Ok((new, delta, next))
    }

    /// Exact regularized objective (paper eq. 3).
    pub fn objective(&self, w: &[f32], h: &[f32], lambda: f32) -> Result<f64> {
        let exe = self.store.executable(&self.obj_name)?;
        let w_dev = self.store.upload_f32(w, &[self.n, self.k])?;
        let h_dev = self.store.upload_f32(h, &[self.k, self.m])?;
        let lam_dev = self.store.upload_f32(&[lambda], &[1, 1])?;
        let outs = execute_tuple(&exe, &[&self.a_dev, &self.mask_dev, &w_dev, &h_dev, &lam_dev])?;
        Ok(literal_f32(&outs[0])?[0] as f64)
    }
}
