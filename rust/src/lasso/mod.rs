//! Parallel Lasso via coordinate descent (paper §2.1) — both execution
//! backends:
//!
//! * [`NativeLasso`] — pure-rust reference (f32 state, f64 accumulation)
//!   used by the worker-pool path, the simulator sweeps, and as the
//!   cross-check oracle for the artifact path.
//! * [`ArtifactLasso`] — the production path: the batched CD update, the
//!   candidate Gram, and the exact objective all execute as AOT-compiled
//!   XLA artifacts (Pallas kernels inside) through PJRT.
//!
//! Both implement [`crate::problem::ModelProblem`] with identical
//! *parallel-round semantics*: every coordinate scheduled in a round
//! computes its update from the same residual snapshot (what distributed
//! workers with stale state compute), then all deltas apply at once.
//! Interference between correlated coordinates is therefore physical,
//! not simulated — the scheduler's job is to avoid it.

pub mod artifact;
pub mod native;

pub use artifact::ArtifactLasso;
pub use native::{LassoPsKernel, LassoSchedOracle, NativeLasso};
