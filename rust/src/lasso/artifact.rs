//! Artifact-backed Lasso: every numeric step (batched CD update,
//! candidate Gram, exact objective) runs as an AOT-compiled XLA
//! executable with the Pallas kernels inlined.

use crate::problem::{Block, ModelProblem, RoundResult};
use crate::runtime::LassoExes;

/// Lasso problem state with PJRT execution.
pub struct ArtifactLasso {
    exes: LassoExes,
    beta: Vec<f64>,
    r: Vec<f32>,
    lambda: f64,
    l1: f64,
    rounds_since_refresh: usize,
    /// Recompute r exactly (on device) every this many rounds to cancel
    /// f32 residual drift.
    pub refresh_every: usize,
}

impl ArtifactLasso {
    /// `y` is the (standardized, padded) response the exes were built
    /// with; the initial residual equals y since β starts at 0.
    pub fn new(exes: LassoExes, y: &[f32], lambda: f64) -> Self {
        let j = exes.j;
        ArtifactLasso {
            exes,
            beta: vec![0.0; j],
            r: y.to_vec(),
            lambda,
            l1: 0.0,
            rounds_since_refresh: 0,
            refresh_every: 256,
        }
    }

    pub fn beta(&self) -> &[f64] {
        &self.beta
    }

    pub fn residual(&self) -> &[f32] {
        &self.r
    }

    fn beta_f32(&self) -> Vec<f32> {
        self.beta.iter().map(|&b| b as f32).collect()
    }

    /// One batched update against the *current* residual snapshot. The
    /// artifact computes all proposals from the same r, then applies the
    /// combined rank-P downdate — exactly the parallel-round semantics.
    fn apply_batch(&mut self, vars: &[usize]) -> Vec<(usize, f64)> {
        // The largest bucket bounds one call; chunk if needed, but give
        // every chunk the ORIGINAL snapshot and compose the (linear)
        // residual downdates so semantics stay exact.
        const MAX_CHUNK: usize = 256;
        let r_snapshot = self.r.clone();
        let mut deltas = Vec::with_capacity(vars.len());
        let mut r_acc: Vec<f32> = r_snapshot.clone();
        for chunk in vars.chunks(MAX_CHUNK) {
            let beta_sel: Vec<f32> = chunk.iter().map(|&v| self.beta[v] as f32).collect();
            let (beta_new, delta_abs, r_new) = self
                .exes
                .update(&r_snapshot, chunk, &beta_sel, self.lambda as f32)
                .expect("lasso_update artifact call failed");
            for (pos, &v) in chunk.iter().enumerate() {
                let new = beta_new[pos] as f64;
                self.l1 += new.abs() - self.beta[v].abs();
                self.beta[v] = new;
                deltas.push((v, delta_abs[pos].abs() as f64));
            }
            // r_acc += (r_new - r_snapshot)
            for i in 0..r_acc.len() {
                r_acc[i] += r_new[i] - r_snapshot[i];
            }
        }
        self.r = r_acc;
        deltas
    }
}

impl ModelProblem for ArtifactLasso {
    fn num_vars(&self) -> usize {
        self.beta.len()
    }

    fn workload(&self, _j: usize) -> u64 {
        1
    }

    fn dependencies(&mut self, cands: &[usize]) -> Vec<f64> {
        self.exes.gram(cands).expect("lasso_gram artifact call failed")
    }

    fn update_blocks(&mut self, blocks: &[Block]) -> RoundResult {
        let vars: Vec<usize> = blocks.iter().flat_map(|b| b.vars.iter().copied()).collect();
        let mut max_work = 0u64;
        let mut total_work = 0u64;
        for b in blocks {
            max_work = max_work.max(b.work);
            total_work += b.work;
        }
        let deltas = self.apply_batch(&vars);
        self.rounds_since_refresh += 1;
        if self.rounds_since_refresh >= self.refresh_every {
            let (_, fresh_r) = self
                .exes
                .objective(&self.beta_f32(), self.lambda as f32)
                .expect("lasso_obj artifact call failed");
            self.r = fresh_r;
            self.rounds_since_refresh = 0;
        }
        let objective =
            Some(0.5 * crate::linalg::norm2_sq(&self.r) + self.lambda * self.l1);
        RoundResult { deltas, objective, max_block_work: max_work, total_work }
    }

    fn objective(&mut self) -> f64 {
        let (obj, fresh_r) = self
            .exes
            .objective(&self.beta_f32(), self.lambda as f32)
            .expect("lasso_obj artifact call failed");
        self.r = fresh_r;
        self.rounds_since_refresh = 0;
        self.l1 = self.beta.iter().map(|b| b.abs()).sum();
        obj
    }

    fn active_vars(&self) -> usize {
        self.beta.iter().filter(|b| b.abs() > 0.0).count()
    }
}
