//! Pure-rust parallel-CD Lasso (the reference backend).

use crate::data::lasso_synth::LassoData;
use crate::linalg::{axpy, dot, norm2_sq, soft_threshold, DenseMatrix};
use crate::problem::{Block, ModelProblem, RoundResult};
use crate::ps::{PsKernel, PsSnapshot, PullSpec};
use std::sync::Arc;

/// Lasso problem state with native (host) execution.
pub struct NativeLasso<'a> {
    x: &'a DenseMatrix,
    beta: Vec<f64>,
    /// Residual r = y - X β.
    r: Vec<f32>,
    /// Image of the residual as last republished to the parameter
    /// server (`ps_republish`'s incremental baseline). Starts equal to
    /// `r`, which is what the round-0 `ps_state` seed publishes.
    r_published: Vec<f32>,
    lambda: f64,
    /// Maintained Σ|β_j| for the incremental objective.
    l1: f64,
    /// Memoized pairwise |x_j^T x_k| (pairs recur across rounds because
    /// hot coordinates are resampled often). FastHashMap: ~60k probes
    /// per round make SipHash the bottleneck (see EXPERIMENTS.md §Perf).
    dep_cache: crate::util::FastHashMap<(u32, u32), f32>,
}

impl<'a> NativeLasso<'a> {
    pub fn new(data: &'a LassoData, lambda: f64) -> Self {
        NativeLasso {
            x: &data.x,
            beta: vec![0.0; data.j()],
            r: data.y.clone(),
            r_published: data.y.clone(),
            lambda,
            l1: 0.0,
            dep_cache: crate::util::FastHashMap::default(),
        }
    }

    pub fn beta(&self) -> &[f64] {
        &self.beta
    }

    pub fn residual(&self) -> &[f32] {
        &self.r
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// The CD proposal for coordinate j against the *current* residual:
    /// β_j' = S(x_j^T r + β_j, λ)  (unit-norm standardized columns).
    #[inline]
    pub fn propose(&self, j: usize) -> f64 {
        let g = dot(self.x.col(j), &self.r) as f64 + self.beta[j];
        soft_threshold(g, self.lambda)
    }

    /// Stateless form of [`Self::propose`] for remote workers that hold
    /// only a residual snapshot (the distributed service path).
    #[inline]
    pub fn propose_from(
        x: &DenseMatrix,
        r_snapshot: &[f32],
        j: usize,
        beta_j: f64,
        lambda: f64,
    ) -> f64 {
        let g = dot(x.col(j), r_snapshot) as f64 + beta_j;
        soft_threshold(g, lambda)
    }

    /// Apply worker-computed proposals (new β values) to the canonical
    /// state — phase 2 of a round, split out so a distributed
    /// coordinator can run phase 1 on remote workers.
    pub fn apply_proposals(&mut self, proposals: &[(usize, f64)]) -> RoundResult {
        let mut deltas = Vec::with_capacity(proposals.len());
        for &(j, new) in proposals {
            let delta = new - self.beta[j];
            deltas.push((j, delta.abs()));
            if delta != 0.0 {
                self.l1 += new.abs() - self.beta[j].abs();
                self.beta[j] = new;
                axpy(-(delta as f32), self.x.col(j), &mut self.r);
            }
        }
        let objective = Some(0.5 * norm2_sq(&self.r) + self.lambda * self.l1);
        RoundResult {
            deltas,
            objective,
            max_block_work: 1,
            total_work: proposals.len() as u64,
        }
    }

    /// One exact sequential CD pass over all coordinates (baseline /
    /// test oracle; not used by the schedulers).
    pub fn sequential_sweep(&mut self) {
        for j in 0..self.beta.len() {
            let new = self.propose(j);
            let delta = new - self.beta[j];
            if delta != 0.0 {
                self.l1 += new.abs() - self.beta[j].abs();
                self.beta[j] = new;
                axpy(-(delta as f32), self.x.col(j), &mut self.r);
            }
        }
    }
}

/// The Lasso scheduling oracle for the scheduler-service path: pair
/// dependencies are column correlations of the immutable design
/// matrix, so shard threads can evaluate them without the coordinator.
/// Values match [`NativeLasso::dependency_pair`] bit-for-bit (same
/// `col_dot` in the same argument order, same f32 → f64 widening) —
/// the staleness-0 bit-exactness pin depends on it.
pub struct LassoSchedOracle {
    x: DenseMatrix,
}

impl crate::sched_service::SchedOracle for LassoSchedOracle {
    fn num_vars(&self) -> usize {
        self.x.ncols()
    }

    fn workload(&self, _j: usize) -> u64 {
        1
    }

    fn dependency_pair(&self, a: usize, b: usize) -> f64 {
        let (lo, hi) = (a.min(b), a.max(b));
        self.x.col_dot(lo, hi).abs() as f64
    }
}

/// The Lasso worker compute for the parameter-server path. PS key
/// space: keys `0..n` hold the residual r (republished exactly by the
/// coordinator each round), keys `n..n+J` hold β. Workers pull the full
/// residual plus their coordinates' β, propose CD updates against that
/// (possibly stale) snapshot, and push β-deltas only.
pub struct LassoPsKernel {
    x: DenseMatrix,
    n: usize,
    lambda: f64,
}

impl PsKernel for LassoPsKernel {
    fn pull_spec(&self, vars: &[usize], _round: u64) -> PullSpec {
        // The residual as one contiguous range (a dense-segment slice
        // read — no per-key enumeration, no hash probes), then the
        // vars' β cells as scattered keys.
        let mut spec = PullSpec::from_ranges(vec![(0, self.n)]);
        spec.keys.extend(vars.iter().map(|&j| self.n + j));
        spec
    }

    fn propose(&self, snap: &PsSnapshot, vars: &[usize], _round: u64) -> Vec<(usize, f64)> {
        // The residual occupies pull positions 0..n and the vars' betas
        // positions n.. in vars order (see pull_spec) — everything is
        // addressed positionally, so the snapshot's keyed index is never
        // built. `range_f32` borrows the server's f32 epoch slab
        // directly (zero copy, zero allocation); the slab is an exact
        // image of the coordinator's f32 residual, so losing the old
        // f64 cell round-trip is lossless.
        let r = snap.range_f32(0, self.n);
        vars.iter()
            .enumerate()
            .map(|(idx, &j)| {
                let beta_j = snap.value_at(self.n + idx);
                let new = NativeLasso::propose_from(&self.x, &r, j, beta_j, self.lambda);
                (self.n + j, new - beta_j)
            })
            .collect()
    }
}

impl ModelProblem for NativeLasso<'_> {
    fn num_vars(&self) -> usize {
        self.beta.len()
    }

    fn workload(&self, _j: usize) -> u64 {
        // One coordinate update is one O(N) dot + O(N) axpy.
        1
    }

    fn dependencies(&mut self, cands: &[usize]) -> Vec<f64> {
        let c = cands.len();
        let mut out = vec![0.0f64; c * c];
        let x = self.x;
        for i in 0..c {
            for k in (i + 1)..c {
                let (a, b) = (cands[i].min(cands[k]) as u32, cands[i].max(cands[k]) as u32);
                let v = *self
                    .dep_cache
                    .entry((a, b))
                    .or_insert_with(|| x.col_dot(a as usize, b as usize).abs());
                out[i * c + k] = v as f64;
                out[k * c + i] = v as f64;
            }
        }
        out
    }

    fn supports_pair_dependency(&self) -> bool {
        true
    }

    fn dependency_pair(&mut self, a: usize, b: usize) -> f64 {
        // Bound the memo cache: 4M entries ~ 48 MB. Recurring (hot) pairs
        // repopulate within a round or two after a flush.
        if self.dep_cache.len() > 4_000_000 {
            self.dep_cache.clear();
        }
        let (lo, hi) = (a.min(b) as u32, a.max(b) as u32);
        let x = self.x;
        *self
            .dep_cache
            .entry((lo, hi))
            .or_insert_with(|| x.col_dot(lo as usize, hi as usize).abs()) as f64
    }

    fn update_blocks(&mut self, blocks: &[Block]) -> RoundResult {
        // Phase 1 (parallel semantics): every scheduled coordinate
        // proposes against the same residual snapshot.
        let mut proposals: Vec<(usize, f64)> = Vec::new();
        let mut max_work = 0u64;
        let mut total_work = 0u64;
        for b in blocks {
            max_work = max_work.max(b.work);
            total_work += b.work;
            for &j in &b.vars {
                proposals.push((j, self.propose(j)));
            }
        }
        // Phase 2: apply all deltas at once (the workers report back).
        let mut deltas = Vec::with_capacity(proposals.len());
        for (j, new) in proposals {
            let delta = new - self.beta[j];
            deltas.push((j, delta.abs()));
            if delta != 0.0 {
                self.l1 += new.abs() - self.beta[j].abs();
                self.beta[j] = new;
                axpy(-(delta as f32), self.x.col(j), &mut self.r);
            }
        }
        let objective = Some(0.5 * norm2_sq(&self.r) + self.lambda * self.l1);
        RoundResult { deltas, objective, max_block_work: max_work, total_work }
    }

    fn objective(&mut self) -> f64 {
        // Exact recompute: drift-corrects the maintained l1 and the f32
        // residual accumulation.
        self.l1 = self.beta.iter().map(|b| b.abs()).sum();
        0.5 * norm2_sq(&self.r) + self.lambda * self.l1
    }

    fn active_vars(&self) -> usize {
        self.beta.iter().filter(|b| b.abs() > 0.0).count()
    }

    fn ps_state(&self) -> Vec<f64> {
        let mut state: Vec<f64> = self.r.iter().map(|&v| v as f64).collect();
        state.extend(self.beta.iter().copied());
        state
    }

    fn ps_kernel(&self) -> Option<Arc<dyn PsKernel>> {
        Some(Arc::new(LassoPsKernel {
            x: self.x.clone(),
            n: self.r.len(),
            lambda: self.lambda,
        }))
    }

    fn sched_oracle(&self) -> Option<Arc<dyn crate::sched_service::SchedOracle>> {
        Some(Arc::new(LassoSchedOracle { x: self.x.clone() }))
    }

    fn apply_deltas(&mut self, deltas: &[(usize, f64)]) -> RoundResult {
        // Same arithmetic, in the same order, as `update_blocks` phase 2
        // — a staleness-0 distributed round is bit-identical to an
        // engine round (see workers::service).
        let n = self.r.len();
        let mut out = Vec::with_capacity(deltas.len());
        for &(key, delta) in deltas {
            if key < n {
                // Residual keys are coordinator-republished, not worker-
                // pushed; accept deltas anyway for API completeness.
                self.r[key] += delta as f32;
                continue;
            }
            let j = key - n;
            let new = self.beta[j] + delta;
            out.push((j, delta.abs()));
            if delta != 0.0 {
                self.l1 += new.abs() - self.beta[j].abs();
                self.beta[j] = new;
                axpy(-(delta as f32), self.x.col(j), &mut self.r);
            }
        }
        let total = out.len() as u64;
        let objective = Some(0.5 * norm2_sq(&self.r) + self.lambda * self.l1);
        RoundResult { deltas: out, objective, max_block_work: 1, total_work: total }
    }

    fn ps_dense_segments(&self) -> Vec<(usize, usize)> {
        // The residual is the contiguous, every-pull-reads-it range; β
        // keys stay on the hashed path (scattered, a few per round).
        vec![(0, self.r.len())]
    }

    fn ps_republish(&mut self, tol: f64, full: bool) -> Vec<(usize, f64)> {
        if full || tol < 0.0 {
            self.r_published.copy_from_slice(&self.r);
            return self.r.iter().enumerate().map(|(i, &v)| (i, v as f64)).collect();
        }
        // Incremental: only entries that moved by more than `tol` since
        // they were last published. With tol = 0.0 this is lossless —
        // workers see exactly the values a full republish would give
        // them — because a skipped entry is bitwise unchanged.
        let tol = tol as f32;
        let mut out = Vec::new();
        for (i, (&cur, published)) in self.r.iter().zip(self.r_published.iter_mut()).enumerate()
        {
            // Negated <= so a NaN entry (divergent async run) still
            // republishes instead of silently pinning a stale value.
            if !((cur - *published).abs() <= tol) {
                *published = cur;
                out.push((i, cur as f64));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::lasso_synth::{generate, LassoSynthSpec};

    fn tiny() -> LassoData {
        generate(&LassoSynthSpec::tiny(), 11)
    }

    #[test]
    fn sequential_sweeps_decrease_objective_monotonically() {
        let data = tiny();
        let mut p = NativeLasso::new(&data, 1e-3);
        let mut prev = p.objective();
        for _ in 0..10 {
            p.sequential_sweep();
            let obj = p.objective();
            assert!(obj <= prev + 1e-9, "obj {obj} prev {prev}");
            prev = obj;
        }
        assert!(p.active_vars() > 0);
    }

    #[test]
    fn single_coordinate_round_matches_sequential_step() {
        let data = tiny();
        let mut a = NativeLasso::new(&data, 1e-3);
        let mut b = NativeLasso::new(&data, 1e-3);
        // one round of the block API on coord 5 == direct proposal
        let want = a.propose(5);
        let res = a.update_blocks(&[Block::singleton(5, 1)]);
        assert_eq!(res.deltas.len(), 1);
        assert!((a.beta()[5] - want).abs() < 1e-12);
        // residual updated consistently: recomputed objective matches
        let o1 = a.objective();
        b.update_blocks(&[Block::singleton(5, 1)]);
        let o2 = b.objective();
        assert!((o1 - o2).abs() < 1e-9);
    }

    #[test]
    fn round_uses_snapshot_semantics() {
        // Two perfectly correlated coordinates updated in one round must
        // BOTH move by the same proposal (stale read), overshooting —
        // unlike sequential execution where the second sees the first.
        let data = tiny();
        let lam = 1e-4;
        // find a within-block pair (generator: block_size=8 -> 0 and 1)
        let mut par = NativeLasso::new(&data, lam);
        let p0 = par.propose(0);
        let p1 = par.propose(1);
        par.update_blocks(&[Block::singleton(0, 1), Block::singleton(1, 1)]);
        assert!((par.beta()[0] - p0).abs() < 1e-12);
        assert!((par.beta()[1] - p1).abs() < 1e-12);

        let mut seq = NativeLasso::new(&data, lam);
        seq.update_blocks(&[Block::singleton(0, 1)]);
        seq.update_blocks(&[Block::singleton(1, 1)]);
        // sequential second update differs from stale parallel one
        assert!(
            (seq.beta()[1] - par.beta()[1]).abs() > 1e-9,
            "correlated pair should interfere under parallel semantics"
        );
    }

    #[test]
    fn dependencies_match_column_correlations() {
        let data = tiny();
        let mut p = NativeLasso::new(&data, 1e-3);
        let cands = vec![0, 1, 9, 17];
        let dep = p.dependencies(&cands);
        assert_eq!(dep.len(), 16);
        for i in 0..4 {
            assert_eq!(dep[i * 4 + i], 0.0);
            for k in 0..4 {
                let want = data.x.col_dot(cands[i], cands[k]).abs() as f64;
                if i != k {
                    assert!((dep[i * 4 + k] - want).abs() < 1e-6);
                }
            }
        }
        // cached path returns same values
        let dep2 = p.dependencies(&cands);
        assert_eq!(dep, dep2);
    }

    #[test]
    fn objective_is_half_sse_plus_l1() {
        let data = tiny();
        let mut p = NativeLasso::new(&data, 0.5);
        let obj0 = p.objective();
        // beta = 0 -> objective = 0.5 ||y||^2 = 0.5 (y standardized)
        assert!((obj0 - 0.5 * norm2_sq(&data.y)).abs() < 1e-9);
    }
}
