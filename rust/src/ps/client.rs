//! The worker-side handle: `pull(spec) -> snapshot` / `push(deltas)` /
//! `flush_clock()`, the schedule/push/pull split of "Primitives for
//! Dynamic Big Model Parallelism". A [`PsClient`] owns a worker's delta
//! batch and talks to the parameter server through whichever
//! [`Transport`] the run selected (`[ps] transport = inproc|tcp`) —
//! the client is transport-agnostic; the compute itself is supplied by
//! the problem as a [`PsKernel`]. Pulls are expressed as a [`PullSpec`]
//! — contiguous ranges (served as zero-copy `Arc` views of
//! dense-segment epochs in-process, bitwise-identical owned f32 images
//! over TCP) plus scattered keys — so kernels with dense shared state
//! never enumerate per-key requests and never pay a copy for the dense
//! part on the in-process path.

use super::batch::DeltaBatch;
use super::shard::{Cell, PullSpec, RangePull};
use super::transport::{InProcTransport, Transport, TransportError};
use super::ParameterServer;
use crate::util::FastHashMap;
use std::cell::OnceCell;
use std::sync::Arc;

/// A consistent-enough view of the pulled state. Pulled ranges are
/// immutable f32 epoch views ([`RangePull`]) — for a range covered by a
/// dense segment the snapshot holds an `Arc` into the server's
/// published slab, so constructing the snapshot copied nothing and the
/// view stays bitwise stable however the server advances. Scattered
/// keys are versioned [`Cell`]s. Positional order is the spec's ranges
/// first (request order), then its scattered keys, so kernels that
/// address the snapshot purely positionally (Lasso's dense residual
/// prefix via [`PsSnapshot::range_f32`]) pay for no key lookup at all.
/// Keyed access resolves range members by binary search and scattered
/// keys through a lazily built index.
#[derive(Clone, Debug)]
pub struct PsSnapshot {
    /// `(first_key, len, range_idx)` per range, sorted by key.
    range_index: Vec<(usize, usize, usize)>,
    /// Pulled ranges in request order.
    ranges: Vec<RangePull>,
    /// `bases[i]` is `ranges[i]`'s first snapshot position.
    bases: Vec<usize>,
    /// Scattered keys, occupying positions `keys_base..`.
    keys: Vec<usize>,
    keys_base: usize,
    /// Cells for the scattered keys only (ranges carry f32 images).
    cells: Vec<Cell>,
    index: OnceCell<FastHashMap<usize, usize>>,
}

impl PsSnapshot {
    /// Scattered-keys-only snapshot (the legacy constructor).
    pub fn new(keys: Vec<usize>, cells: Vec<Cell>) -> Self {
        Self::from_pull(Vec::new(), keys, cells)
    }

    /// Snapshot over pulled ranges plus scattered keys; `cells` must
    /// hold one cell per scattered key, in key order.
    pub fn from_pull(ranges: Vec<RangePull>, keys: Vec<usize>, cells: Vec<Cell>) -> Self {
        assert_eq!(keys.len(), cells.len());
        let mut bases = Vec::with_capacity(ranges.len());
        let mut base = 0usize;
        let mut range_index = Vec::with_capacity(ranges.len());
        for (ri, r) in ranges.iter().enumerate() {
            bases.push(base);
            range_index.push((r.start(), r.len(), ri));
            base += r.len();
        }
        range_index.sort_unstable_by_key(|&(start, _, _)| start);
        PsSnapshot {
            range_index,
            ranges,
            bases,
            keys,
            keys_base: base,
            cells,
            index: OnceCell::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.keys_base + self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn index(&self) -> &FastHashMap<usize, usize> {
        self.index.get_or_init(|| {
            self.keys.iter().enumerate().map(|(i, &k)| (k, self.keys_base + i)).collect()
        })
    }

    /// The pulled range containing `key`, if any, with the in-range
    /// offset. Ranges are few and sorted: a short binary search.
    #[inline]
    fn range_of(&self, key: usize) -> Option<(usize, usize)> {
        let idx = self.range_index.partition_point(|&(start, _, _)| start <= key);
        if idx > 0 {
            let (start, len, ri) = self.range_index[idx - 1];
            if key < start + len {
                return Some((ri, key - start));
            }
        }
        None
    }

    /// Value by key (None if the key was not pulled). Range members are
    /// found arithmetically (no hashing); scattered keys through the
    /// lazy index, so purely positional kernels never build it.
    #[inline]
    pub fn get(&self, key: usize) -> Option<f64> {
        if let Some((ri, off)) = self.range_of(key) {
            return Some(self.ranges[ri].values()[off] as f64);
        }
        self.index().get(&key).map(|&pos| self.cells[pos - self.keys_base].value)
    }

    /// Version by key (None if the key was not pulled): the epoch
    /// version for range members, the cell version for scattered keys.
    #[inline]
    pub fn version(&self, key: usize) -> Option<u64> {
        if let Some((ri, _)) = self.range_of(key) {
            return Some(self.ranges[ri].version());
        }
        self.index().get(&key).map(|&pos| self.cells[pos - self.keys_base].version)
    }

    /// Value by pull position (the order the spec was declared in).
    #[inline]
    pub fn value_at(&self, pos: usize) -> f64 {
        if pos < self.keys_base {
            let ri = self.bases.partition_point(|&b| b <= pos) - 1;
            self.ranges[ri].values()[pos - self.bases[ri]] as f64
        } else {
            self.cells[pos - self.keys_base].value
        }
    }

    /// The f32 image of positions `start..start + len` — zero copy, no
    /// allocation: this borrows straight out of the pulled range's
    /// (possibly server-shared) slab. The span must lie within a single
    /// pulled range; panics otherwise (a kernel/spec mismatch).
    pub fn range_f32(&self, start: usize, len: usize) -> &[f32] {
        if len == 0 {
            return &[];
        }
        assert!(
            start < self.keys_base,
            "range_f32 position {start} is past the pulled ranges"
        );
        let ri = self.bases.partition_point(|&b| b <= start) - 1;
        let off = start - self.bases[ri];
        let values = self.ranges[ri].values();
        assert!(
            off + len <= values.len(),
            "range_f32 span {start}+{len} crosses a pulled-range boundary"
        );
        &values[off..off + len]
    }

    /// Oldest version among the pulled data (staleness diagnostics) —
    /// per-epoch metadata for ranges plus the scattered cells, so this
    /// is O(ranges + scattered keys), not a scan of every pulled value.
    pub fn min_version(&self) -> u64 {
        self.ranges
            .iter()
            .map(RangePull::version)
            .chain(self.cells.iter().map(|c| c.version))
            .min()
            .unwrap_or(0)
    }
}

/// Problem-supplied worker compute: pure, shareable across threads.
/// `round` lets problems with intrinsic round structure (e.g. MF rank
/// sweeps) decode what the round means; flat problems ignore it.
pub trait PsKernel: Send + Sync {
    /// The cells a worker must pull to process `vars` in `round`:
    /// contiguous ranges (the zero-copy dense-segment fast path) plus
    /// scattered keys.
    fn pull_spec(&self, vars: &[usize], round: u64) -> PullSpec;

    /// Compute state-space deltas for `vars` against the snapshot.
    fn propose(&self, snap: &PsSnapshot, vars: &[usize], round: u64) -> Vec<(usize, f64)>;
}

/// What the server observed while admitting one pull: the staleness
/// gap at admission, whether the SSP gate parked the caller at all, and
/// for how long (server-measured microseconds).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PullMeta {
    pub gap: u64,
    pub waited: bool,
    pub gate_us: u64,
}

/// One worker's handle onto the parameter server, over any transport.
pub struct PsClient {
    transport: Box<dyn Transport>,
    worker: usize,
    batch: DeltaBatch,
}

impl PsClient {
    /// In-process client over a shared server — the zero-copy fast
    /// path, and the constructor every same-address-space test uses.
    pub fn new(server: Arc<ParameterServer>, worker: usize) -> Self {
        Self::over(Box::new(InProcTransport::new(server, worker)), worker)
    }

    /// Client over an already-established transport (`worker` must be
    /// the id the transport was minted for — see
    /// `PsConnection::worker_transport`).
    pub fn over(transport: Box<dyn Transport>, worker: usize) -> Self {
        PsClient { transport, worker, batch: DeltaBatch::new() }
    }

    /// SSP-gated pull: blocks until the applied state is within the
    /// server's staleness bound of `round`, then reads the spec.
    /// Returns the snapshot plus the gate observation ([`PullMeta`]).
    /// The gate wait (and all metering) happens server-side, so a
    /// networked worker blocks inside the RPC exactly where an
    /// in-process one blocks on the condvar.
    pub fn pull(
        &mut self,
        spec: PullSpec,
        round: u64,
    ) -> Result<(PsSnapshot, PullMeta), TransportError> {
        let reply = self.transport.pull(&spec, round)?;
        let meta = PullMeta { gap: reply.gap, waited: reply.waited, gate_us: reply.gate_us };
        Ok((PsSnapshot::from_pull(reply.ranges, spec.keys, reply.cells), meta))
    }

    /// Accumulate deltas into the local batch (coalescing duplicates).
    pub fn push(&mut self, deltas: &[(usize, f64)]) {
        self.batch.extend(deltas);
    }

    /// End-of-round clock: flush the coalesced batch to the server
    /// (versioned at `round + 1`) for scheduling block `block`, tick
    /// this worker's clock, and return the flushed batch plus the
    /// server's verdict. `applied == false` means the server dropped
    /// the batch — another worker's copy of the reassigned block
    /// already landed, or this worker has been retired — and the
    /// coordinator must NOT fold the deltas into the canonical model.
    pub fn flush_clock(
        &mut self,
        round: u64,
        block: u64,
    ) -> Result<(Vec<(usize, f64)>, bool), TransportError> {
        let deltas = self.batch.drain();
        let applied = self.transport.flush(&deltas, round, block)?;
        Ok((deltas, applied))
    }

    pub fn worker(&self) -> usize {
        self.worker
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::StalenessPolicy;

    #[test]
    fn snapshot_positional_and_keyed_access_agree() {
        let cells = vec![
            Cell { version: 1, value: 10.0 },
            Cell { version: 2, value: 20.0 },
            Cell { version: 3, value: 30.0 },
        ];
        let snap = PsSnapshot::new(vec![5, 0, 9], cells);
        assert_eq!(snap.len(), 3);
        assert_eq!(snap.get(0), Some(20.0));
        assert_eq!(snap.get(9), Some(30.0));
        assert_eq!(snap.get(7), None);
        assert_eq!(snap.value_at(0), 10.0);
        assert_eq!(snap.version(5), Some(1));
        assert_eq!(snap.min_version(), 1);
    }

    #[test]
    fn snapshot_range_lookup_is_arithmetic() {
        // ranges (10..13) and (20..22) occupy positions 0..3 and 3..5,
        // scattered keys 99 and 3 positions 5 and 6.
        let ranges = vec![
            RangePull::owned(10, 7, vec![0.0, 1.0, 2.0]),
            RangePull::owned(20, 9, vec![3.0, 4.0]),
        ];
        let cells =
            vec![Cell { version: 5, value: 5.0 }, Cell { version: 6, value: 6.0 }];
        let snap = PsSnapshot::from_pull(ranges, vec![99, 3], cells);
        assert_eq!(snap.len(), 7);
        assert_eq!(snap.get(10), Some(0.0));
        assert_eq!(snap.get(12), Some(2.0));
        assert_eq!(snap.get(20), Some(3.0));
        assert_eq!(snap.get(21), Some(4.0));
        assert_eq!(snap.get(99), Some(5.0));
        assert_eq!(snap.get(3), Some(6.0));
        assert_eq!(snap.get(13), None, "between ranges");
        assert_eq!(snap.get(22), None, "past the last range");
        assert_eq!(snap.version(11), Some(7), "range members report the epoch version");
        assert_eq!(snap.version(99), Some(5));
        assert_eq!(snap.value_at(3), 3.0);
        assert_eq!(snap.value_at(5), 5.0);
        assert_eq!(snap.range_f32(0, 3), &[0.0f32, 1.0, 2.0]);
        assert_eq!(snap.range_f32(3, 2), &[3.0f32, 4.0]);
        assert_eq!(snap.min_version(), 5);
    }

    #[test]
    #[should_panic(expected = "crosses")]
    fn range_f32_must_not_cross_pulled_ranges() {
        let ranges = vec![
            RangePull::owned(0, 0, vec![0.0, 1.0]),
            RangePull::owned(10, 0, vec![2.0]),
        ];
        let snap = PsSnapshot::from_pull(ranges, Vec::new(), Vec::new());
        let _ = snap.range_f32(1, 2);
    }

    #[test]
    fn pull_push_flush_roundtrip() {
        let server = Arc::new(ParameterServer::new(4, 1, StalenessPolicy::Bounded(0)));
        server.store().publish_dense(&[1.0, 2.0, 3.0], 0);
        let mut client = PsClient::new(Arc::clone(&server), 0);

        let (snap, meta) = client.pull(PullSpec::from_keys(vec![0, 1, 2]), 0).unwrap();
        assert_eq!((meta.gap, meta.waited), (0, false));
        assert_eq!(snap.get(0), Some(1.0));
        assert_eq!(snap.get(2), Some(3.0));

        client.push(&[(1, 0.5), (1, 0.5), (2, -1.0)]);
        let (flushed, applied) = client.flush_clock(0, 0).unwrap();
        assert!(applied, "a unique (round, block) flush must apply");
        assert_eq!(flushed, vec![(1, 1.0), (2, -1.0)]);
        assert_eq!(server.store().read(&[1])[0].value, 3.0);
        assert_eq!(server.store().read(&[1])[0].version, 1);
        assert_eq!(server.stats().bytes_flushed.get(), 32);
        assert_eq!(server.clock().min_worker_clock(), 1);
    }

    #[test]
    fn ranged_pull_is_a_zero_copy_epoch_view() {
        let server = Arc::new(ParameterServer::with_segments(
            4,
            1,
            StalenessPolicy::Bounded(0),
            &[(0, 6)],
        ));
        let values: Vec<f64> = (0..6).map(|i| i as f64 * 2.0).collect();
        server.store().publish_dense(&values, 0);
        let mut client = PsClient::new(Arc::clone(&server), 0);
        let (snap, _) = client.pull(PullSpec::from_ranges(vec![(2, 3)]), 0).unwrap();
        assert_eq!(snap.range_f32(0, 3), &[4.0f32, 6.0, 8.0]);
        assert_eq!(snap.get(4), Some(8.0));
        assert_eq!(server.store().hash_probes(), 0, "dense pull must not hash");
        let stats = server.stats();
        assert_eq!(stats.snapshot_clones.get(), 1);
        assert_eq!(stats.cells_pulled.get(), 3);
        // 3 f32 cells + one epoch version
        assert_eq!(stats.bytes_pulled.get(), 8 + 4 * 3);
    }

    #[test]
    fn gated_pull_respects_bound() {
        let server = Arc::new(ParameterServer::new(2, 1, StalenessPolicy::Bounded(2)));
        let mut client = PsClient::new(Arc::clone(&server), 0);
        // applied = 0: rounds 0..=2 admitted without waiting
        let (_, meta) = client.pull(PullSpec::from_keys(vec![0]), 2).unwrap();
        assert_eq!((meta.gap, meta.waited), (2, false));
        // round 3 would be 3 stale -> blocks until the server advances
        let t = {
            let server = Arc::clone(&server);
            std::thread::spawn(move || {
                let mut client = PsClient::new(server, 0);
                client.pull(PullSpec::from_keys(vec![0]), 3).map(|(_, meta)| meta.gap)
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(10));
        server.clock().advance_applied(1);
        assert_eq!(t.join().unwrap().unwrap(), 2);
        assert_eq!(server.stats().max_stale_gap.get(), 2);
    }
}
