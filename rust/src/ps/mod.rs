//! The sharded parameter server with bounded-staleness (SSP) clocks —
//! the distributed execution substrate (after Petuum; the client API
//! follows the STRADS "Primitives" schedule/push/pull split).
//!
//! * [`shard`] — hash-partitioned, versioned key-value shards, each
//!   behind its own lock.
//! * [`clock`] — per-worker SSP clocks and the `StalenessBound(s)` /
//!   fully-async admission gate.
//! * [`batch`] — worker-local delta batching/coalescing with wire-byte
//!   metering.
//! * [`client`] — the worker handle (`pull` / `push` / `flush_clock`)
//!   and the [`PsKernel`] trait problems implement to run on it.
//!
//! The execution loop that wires a [`ParameterServer`] to a
//! `ModelProblem` and real worker threads lives in `workers::service`.

pub mod batch;
pub mod client;
pub mod clock;
pub mod shard;

pub use batch::{BYTES_PER_ENTRY, DeltaBatch};
pub use client::{PsClient, PsKernel, PsSnapshot};
pub use clock::{ClockShutdown, ClockTable, StalenessPolicy};
pub use shard::{Cell, ShardedStore};

use std::sync::atomic::{AtomicU64, Ordering};

/// Cross-thread run counters (all monotonic).
#[derive(Debug, Default)]
pub struct PsStats {
    /// Coalesced delta bytes flushed through the server.
    pub bytes_flushed: AtomicU64,
    /// Number of flush batches.
    pub flushes: AtomicU64,
    /// Number of pulls served.
    pub pulls: AtomicU64,
    /// Sum over pulls of the observed staleness gap (rounds behind).
    pub stale_gap_sum: AtomicU64,
    /// Pulls that had to block at the SSP gate.
    pub gate_waits: AtomicU64,
}

impl PsStats {
    /// Mean staleness gap over all pulls so far.
    pub fn mean_staleness(&self) -> f64 {
        let pulls = self.pulls.load(Ordering::Relaxed);
        if pulls == 0 {
            0.0
        } else {
            self.stale_gap_sum.load(Ordering::Relaxed) as f64 / pulls as f64
        }
    }
}

/// The server: sharded store + clock table + policy + stats. Shared
/// across worker threads behind an `Arc`.
pub struct ParameterServer {
    store: ShardedStore,
    clock: ClockTable,
    policy: StalenessPolicy,
    stats: PsStats,
}

impl ParameterServer {
    pub fn new(shards: usize, workers: usize, policy: StalenessPolicy) -> Self {
        ParameterServer {
            store: ShardedStore::new(shards),
            clock: ClockTable::new(workers),
            policy,
            stats: PsStats::default(),
        }
    }

    pub fn store(&self) -> &ShardedStore {
        &self.store
    }

    pub fn clock(&self) -> &ClockTable {
        &self.clock
    }

    pub fn policy(&self) -> StalenessPolicy {
        self.policy
    }

    pub fn stats(&self) -> &PsStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_mean_staleness() {
        let stats = PsStats::default();
        assert_eq!(stats.mean_staleness(), 0.0);
        stats.pulls.store(4, Ordering::Relaxed);
        stats.stale_gap_sum.store(6, Ordering::Relaxed);
        assert_eq!(stats.mean_staleness(), 1.5);
    }

    #[test]
    fn server_wires_components() {
        let server = ParameterServer::new(4, 2, StalenessPolicy::Async);
        assert_eq!(server.store().num_shards(), 4);
        assert_eq!(server.policy(), StalenessPolicy::Async);
        server.store().publish_dense(&[1.0], 0);
        assert_eq!(server.store().len(), 1);
    }
}
