//! The sharded parameter server with bounded-staleness (SSP) clocks —
//! the distributed execution substrate (after Petuum; the client API
//! follows the STRADS "Primitives" schedule/push/pull split).
//!
//! * [`shard`] — versioned storage in two representations behind one
//!   API. **Dense segments** (registered contiguous key ranges — the
//!   hot, every-pull-reads-it state) are stored as immutable **chunked
//!   f32 epoch slabs**: each segment is a vector of fixed-size chunks
//!   (`ps.chunk_cells` cells each; 0 = one chunk spanning the whole
//!   segment), each an `Arc<Vec<f32>>` image plus a per-chunk epoch
//!   version — 4 bytes per cell instead of the 16-byte per-cell
//!   `Cell`. A range pull pins only the chunks it covers (a
//!   single-chunk pull is an O(1) `Arc` clone, [`RangePull`] — no
//!   copy, no allocation, no lock held while the kernel consumes the
//!   data) and writes are copy-on-publish (`Arc::make_mut`): a chunk
//!   is cloned only when a reader still holds its old epoch, so a held
//!   snapshot is immutable by construction. Chunking bounds the clone
//!   unit: a publish racing a held view re-copies only the chunks it
//!   writes (`cow_clones` counts clones, `cow_bytes` their bytes),
//!   instead of the entire segment; the cost vanishes when no reader
//!   holds the epoch (workers drop their views before flushing — see
//!   `workers::service`). **Hashed shards** keep everything
//!   unregistered in Petuum-style hash-partitioned `Cell` maps (full
//!   f64, per-cell versions).
//! * [`clock`] — per-worker SSP clocks and the `StalenessBound(s)` /
//!   fully-async admission gate. Under gate-driven pipelining
//!   (`workers::service`) this gate — not coordinator dispatch — is
//!   what paces workers, so scheduling overlaps compute.
//! * [`batch`] — worker-local delta batching/coalescing with wire-byte
//!   metering.
//! * [`client`] — the worker handle (`pull` / `push` / `flush_clock`)
//!   over [`PullSpec`] requests, and the [`PsKernel`] trait problems
//!   implement to run on it. [`PsSnapshot::range_f32`] hands kernels
//!   the pulled f32 image directly.
//! * [`transport`] — the pluggable carriage under the client: the same
//!   pull/flush/publish/clock traffic through shared memory
//!   (`InProcTransport`, today's zero-copy path) or over a
//!   length-prefixed binary wire protocol to a `strads ps-server`
//!   process (`TcpTransport`). Both route through the
//!   [`ParameterServer::serve_pull`]/[`ParameterServer::serve_flush`]/
//!   [`ParameterServer::serve_publish`] helpers, so the transports are
//!   observationally identical (staleness-0 runs are bitwise equal
//!   across them — pinned by `tests/ps_transport.rs`).
//!
//! The pull-dominated STRADS loop (every worker pulls the full shared
//! state each round, pushes sparse deltas) is why the dense path is
//! read-optimized: pull traffic is metered at 4 bytes/cell + one epoch
//! version per range (`PsStats::bytes_pulled`) instead of 16-byte
//! cells, and staleness metadata (`PsSnapshot::min_version`) comes from
//! per-epoch versions rather than an O(n) cell scan per pull.
//!
//! Republish traffic (the coordinator overwriting derived state, e.g.
//! the Lasso residual) is tolerance-gated and metered separately from
//! worker flushes: entries that moved by less than `ps.republish_tol`
//! never reach the store, and the sparse republish that does arrive
//! composes with copy-on-publish — it mutates a fresh epoch clone only
//! when workers still hold the previous epoch, and updates the slab in
//! place otherwise. See `ModelProblem::ps_republish`. The execution
//! loop that wires a [`ParameterServer`] to a `ModelProblem` and real
//! worker threads lives in `workers::service`.

pub mod batch;
pub mod checkpoint;
pub mod client;
pub mod clock;
pub mod shard;
pub mod transport;

pub use batch::{wire_bytes_for, BYTES_PER_ENTRY, DeltaBatch};
pub use checkpoint::{read_checkpoint, CheckpointConfig, CheckpointImage};
pub use client::{PsClient, PsKernel, PsSnapshot, PullMeta};
pub use clock::{ClockShutdown, ClockTable, StalenessPolicy};
pub use shard::{Cell, PullSpec, RangePull, ShardedStore, SpecPull};
pub use transport::retry::{FaultPlan, RetryConfig};
pub use transport::{
    fetch_obs_stats, PsConnection, PsTcpServer, Transport, TransportError, TransportKind,
};

use crate::obs::{
    ClockView, Counter, Histogram, ObsSnapshot, Registry, OBS_SNAPSHOT_VERSION,
};
use std::sync::Arc;

/// Cross-thread run counters (all monotonic). Every field is an
/// [`obs::Counter`](crate::obs::Counter) registered by name in the
/// server's metrics [`Registry`], so the `DistributedReport` /
/// `BENCH_ps.json` fields and the live `ps-stats` snapshot are two
/// views over the same atomics.
#[derive(Debug, Default)]
pub struct PsStats {
    /// Coalesced delta bytes flushed through the server by workers.
    pub bytes_flushed: Arc<Counter>,
    /// Derived-state bytes republished by the coordinator (tolerance-
    /// gated sparse republish + periodic full re-syncs).
    pub bytes_republished: Arc<Counter>,
    /// Pull bytes served to workers: 4 bytes/cell + one 8-byte epoch
    /// version for shared f32 ranges, 16-byte cells for everything
    /// else (see `SpecPull::wire_bytes`).
    pub bytes_pulled: Arc<Counter>,
    /// Total cells covered by pulls (range members + scattered keys);
    /// `16 * cells_pulled` is what the per-cell wire format this
    /// design replaced would have moved.
    pub cells_pulled: Arc<Counter>,
    /// Range pulls served as zero-copy shared epoch views (an `Arc`
    /// clone instead of a cell copy).
    pub snapshot_clones: Arc<Counter>,
    /// Number of flush batches.
    pub flushes: Arc<Counter>,
    /// Number of pulls served.
    pub pulls: Arc<Counter>,
    /// Sum over pulls of the observed staleness gap (rounds behind).
    pub stale_gap_sum: Arc<Counter>,
    /// Largest staleness gap any pull ever observed (must stay within
    /// the SSP bound — the concurrency tests pin this).
    pub max_stale_gap: Arc<Counter>,
    /// Pulls that had to block at the SSP gate.
    pub gate_waits: Arc<Counter>,
    /// Flush batches refused by the exactly-once guard: late arrivals
    /// from retired workers (the membership fence) and losers of a
    /// `(round, block)` reassignment race. 0 in a fixed, healthy fleet.
    pub flushes_dropped: Arc<Counter>,
}

impl PsStats {
    /// Build the stats block with every counter registered by its
    /// `ps.*` name in `reg` (the server constructor path; `Default`
    /// keeps standalone unregistered counters for unit tests).
    pub fn registered(reg: &Registry) -> Self {
        PsStats {
            bytes_flushed: reg.counter("ps.bytes_flushed"),
            bytes_republished: reg.counter("ps.bytes_republished"),
            bytes_pulled: reg.counter("ps.pull_bytes"),
            cells_pulled: reg.counter("ps.cells_pulled"),
            snapshot_clones: reg.counter("ps.snapshot_clones"),
            flushes: reg.counter("ps.flushes"),
            pulls: reg.counter("ps.pulls"),
            stale_gap_sum: reg.counter("ps.stale_gap_sum"),
            max_stale_gap: reg.counter("ps.max_stale_gap"),
            gate_waits: reg.counter("ps.gate_waits"),
            flushes_dropped: reg.counter("ps.flushes_dropped"),
        }
    }

    /// Mean staleness gap over all pulls so far.
    pub fn mean_staleness(&self) -> f64 {
        let pulls = self.pulls.get();
        if pulls == 0 {
            0.0
        } else {
            self.stale_gap_sum.get() as f64 / pulls as f64
        }
    }

    /// Total wire traffic: worker flushes + coordinator republishes +
    /// worker pulls (the dominant term in the pull-heavy STRADS loop).
    pub fn net_bytes(&self) -> u64 {
        self.bytes_flushed.get() + self.bytes_republished.get() + self.bytes_pulled.get()
    }
}

/// A point-in-time copy of every server-side meter in one plain
/// struct. This is the coordinator's *only* view of the server under a
/// multi-process transport (it crosses the wire as the `Stats` RPC), so
/// everything `DistributedReport` needs lives here — including the
/// store-level `hash_probes`/`cow_clones` counters that are not part of
/// [`PsStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub bytes_flushed: u64,
    pub bytes_republished: u64,
    pub bytes_pulled: u64,
    pub cells_pulled: u64,
    pub snapshot_clones: u64,
    pub flushes: u64,
    pub pulls: u64,
    pub stale_gap_sum: u64,
    pub max_stale_gap: u64,
    pub gate_waits: u64,
    pub flushes_dropped: u64,
    pub hash_probes: u64,
    pub cow_clones: u64,
    /// Bytes those copy-on-publish clones copied (4 bytes per cloned
    /// chunk cell) — the number `chunk_cells` exists to shrink.
    pub cow_bytes: u64,
}

impl StatsSnapshot {
    /// Modeled wire traffic: flushes + republishes + pulls.
    pub fn net_bytes(&self) -> u64 {
        self.bytes_flushed + self.bytes_republished + self.bytes_pulled
    }

    /// Mean staleness gap over all pulls so far.
    pub fn mean_staleness(&self) -> f64 {
        if self.pulls == 0 {
            0.0
        } else {
            self.stale_gap_sum as f64 / self.pulls as f64
        }
    }
}

/// The server: sharded store + clock table + policy + stats + metrics
/// registry. Shared across worker threads behind an `Arc`. The
/// registry is per-server (a TCP `Init` replaces the server, so every
/// run starts from zeroed meters).
pub struct ParameterServer {
    store: ShardedStore,
    clock: ClockTable,
    policy: StalenessPolicy,
    stats: PsStats,
    registry: Registry,
    gate_wait_us: Arc<Histogram>,
    /// Exactly-once ledger for elastic reassignment: the set of
    /// `(round, block)` flushes already applied. When a lease expires
    /// and a block is re-dispatched, two workers race to flush the same
    /// `(round, block)`; the first insert wins and the loser is dropped
    /// — transport-agnostically, under one lock, so the canonical model
    /// and the PS store can never disagree about the winner. Entries
    /// for rounds below the applied clock are pruned on advance (a
    /// flush that old is a zombie and is refused by the round check
    /// alone). Cross-*restart* replay is not this ledger's job: the
    /// per-worker flush-seq dedup (PR 7) persists in checkpoints and
    /// catches it at the TCP layer.
    flush_ledger: std::sync::Mutex<std::collections::BTreeSet<(u64, u64)>>,
}

impl ParameterServer {
    pub fn new(shards: usize, workers: usize, policy: StalenessPolicy) -> Self {
        Self::with_segments(shards, workers, policy, &[])
    }

    /// Build a server whose store has the given `(start, len)` key
    /// ranges registered as dense segments (see
    /// [`ShardedStore::with_segments`]).
    pub fn with_segments(
        shards: usize,
        workers: usize,
        policy: StalenessPolicy,
        segments: &[(usize, usize)],
    ) -> Self {
        Self::with_segments_chunked(shards, workers, policy, segments, 0)
    }

    /// Build a server whose dense segments are split into
    /// `chunk_cells`-cell epoch chunks (0 = one chunk per segment; see
    /// [`ShardedStore::with_segments_chunked`]).
    pub fn with_segments_chunked(
        shards: usize,
        workers: usize,
        policy: StalenessPolicy,
        segments: &[(usize, usize)],
        chunk_cells: usize,
    ) -> Self {
        let registry = Registry::new();
        let stats = PsStats::registered(&registry);
        let gate_wait_us = registry.histogram("gate.wait_us", Histogram::us_bounds());
        ParameterServer {
            store: ShardedStore::with_segments_chunked(shards, segments, chunk_cells),
            clock: ClockTable::new(workers),
            policy,
            stats,
            registry,
            gate_wait_us,
            flush_ledger: std::sync::Mutex::new(std::collections::BTreeSet::new()),
        }
    }

    pub fn store(&self) -> &ShardedStore {
        &self.store
    }

    pub fn clock(&self) -> &ClockTable {
        &self.clock
    }

    pub fn policy(&self) -> StalenessPolicy {
        self.policy
    }

    pub fn stats(&self) -> &PsStats {
        &self.stats
    }

    /// The server's metrics registry — the checkpoint writer hooks its
    /// `ckpt.*` counters in here so `ps-stats` sees them live.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Serve one SSP-gated pull: block until `round` is admitted, read
    /// the spec, meter the traffic. Returns the pulled data plus the
    /// observed `(staleness_gap, had_to_wait, gate_wait_us)`. The gate
    /// time is measured unconditionally (two `Instant` reads around the
    /// wait; it never feeds computation, so obs-on/off parity holds by
    /// construction). This is the *single* server-side pull path — the
    /// in-process transport and the TCP server's request handler both
    /// call it, which is what keeps the two transports observationally
    /// identical.
    pub fn serve_pull(
        &self,
        worker: usize,
        spec: &PullSpec,
        round: u64,
    ) -> Result<(SpecPull, u64, bool, u64), ClockShutdown> {
        let gate_start = std::time::Instant::now();
        let (gap, waited) = self.clock.wait_admit(worker, round, self.policy)?;
        let gate_us = gate_start.elapsed().as_micros() as u64;
        self.gate_wait_us.record(gate_us);
        self.stats.pulls.inc();
        self.stats.stale_gap_sum.add(gap);
        self.stats.max_stale_gap.raise(gap);
        if waited {
            self.stats.gate_waits.inc();
        }
        let pulled = self.store.read_spec(spec);
        self.stats.bytes_pulled.add(pulled.wire_bytes());
        self.stats.cells_pulled.add(pulled.total_cells() as u64);
        self.stats.snapshot_clones.add(pulled.shared_ranges() as u64);
        Ok((pulled, gap, waited, gate_us))
    }

    /// Serve one worker flush for scheduling block `block`: decide
    /// whether it is the `(round, block)` winner, and if so meter it,
    /// apply the coalesced deltas at version `round + 1`, and tick the
    /// worker's clock. Returns the verdict — `true` iff the deltas were
    /// applied — which rides the flush reply so the coordinator keeps
    /// its canonical model in lock-step with the store. Dropped (and
    /// counted in `ps.flushes_dropped`, never applied):
    /// * flushes from retired workers — the membership fence; a worker
    ///   declared dead cannot mutate the model afterwards;
    /// * flushes for rounds the server already applied — zombies from
    ///   before a reassignment, arriving after their ledger entry was
    ///   pruned;
    /// * `(round, block)` pairs already applied — the loser of a
    ///   reassignment race (the original, slow-but-alive worker still
    ///   gets its clock ticked: it did finish its round).
    /// In a fixed healthy fleet every flush is a unique live-worker
    /// `(round, block)` winner, so this path is behaviorally identical
    /// to the pre-elastic one — contract 8 in the README.
    pub fn serve_flush(
        &self,
        worker: usize,
        block: u64,
        deltas: &[(usize, f64)],
        round: u64,
    ) -> bool {
        if !self.clock.is_live(worker) {
            self.stats.flushes_dropped.inc();
            return false;
        }
        {
            let mut ledger = self.flush_ledger.lock().expect("flush ledger poisoned");
            if round < self.clock.applied() || !ledger.insert((round, block)) {
                drop(ledger);
                self.stats.flushes_dropped.inc();
                self.clock.record_flush(worker, round);
                return false;
            }
        }
        self.stats.bytes_flushed.add(wire_bytes_for(deltas.len()));
        self.stats.flushes.inc();
        self.store.add_deltas(deltas, round + 1);
        self.clock.record_flush(worker, round);
        true
    }

    /// Serve a coordinator clock advance: ungate workers, then prune
    /// ledger entries for rounds that can no longer be legally flushed.
    pub fn serve_advance(&self, applied: u64) {
        self.clock.advance_applied(applied);
        let applied = self.clock.applied();
        let mut ledger = self.flush_ledger.lock().expect("flush ledger poisoned");
        *ledger = ledger.split_off(&(applied, 0));
    }

    /// Membership: admit worker `worker` at the clock frontier
    /// (idempotent — safe under retried Join RPCs).
    pub fn serve_join(&self, worker: usize) {
        self.clock.join(worker);
    }

    /// Membership: retire worker `worker` (idempotent). Returns true
    /// when this call flipped a live worker; wakes any parked waiter it
    /// owned.
    pub fn serve_leave(&self, worker: usize) -> bool {
        self.clock.retire(worker)
    }

    /// Serve one coordinator republish: meter it as republish traffic,
    /// then overwrite-publish the entries.
    pub fn serve_publish(&self, entries: &[(usize, f64)], version: u64) {
        self.stats.bytes_republished.add(wire_bytes_for(entries.len()));
        self.store.publish(entries, version);
    }

    /// Snapshot every meter (server stats + store counters) into the
    /// wire-crossable [`StatsSnapshot`].
    pub fn stats_snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            bytes_flushed: self.stats.bytes_flushed.get(),
            bytes_republished: self.stats.bytes_republished.get(),
            bytes_pulled: self.stats.bytes_pulled.get(),
            cells_pulled: self.stats.cells_pulled.get(),
            snapshot_clones: self.stats.snapshot_clones.get(),
            flushes: self.stats.flushes.get(),
            pulls: self.stats.pulls.get(),
            stale_gap_sum: self.stats.stale_gap_sum.get(),
            max_stale_gap: self.stats.max_stale_gap.get(),
            gate_waits: self.stats.gate_waits.get(),
            flushes_dropped: self.stats.flushes_dropped.get(),
            hash_probes: self.store.hash_probes(),
            cow_clones: self.store.cow_clones(),
            cow_bytes: self.store.cow_bytes(),
        }
    }

    /// Full introspection snapshot: the registry reading plus the
    /// store counters that live outside it, per-segment epoch versions,
    /// and the SSP clock gate state. This is what the `ObsStats` wire
    /// opcode and `strads ps-stats` serve.
    pub fn obs_snapshot(&self) -> ObsSnapshot {
        use crate::obs::MetricValue;
        let mut metrics = self.registry.snapshot();
        metrics.push((
            "store.cow_clones".to_string(),
            MetricValue::Counter(self.store.cow_clones()),
        ));
        metrics.push((
            "store.cow_bytes".to_string(),
            MetricValue::Counter(self.store.cow_bytes()),
        ));
        metrics.push((
            "store.hash_probes".to_string(),
            MetricValue::Counter(self.store.hash_probes()),
        ));
        metrics.sort_by(|a, b| a.0.cmp(&b.0));
        ObsSnapshot {
            version: OBS_SNAPSHOT_VERSION,
            metrics,
            segments: self.store.segment_versions(),
            clock: Some(ClockView {
                applied: self.clock.applied(),
                staleness_bound: self.policy.bound(),
                worker_clocks: self.clock.worker_clocks(),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_mean_staleness() {
        let stats = PsStats::default();
        assert_eq!(stats.mean_staleness(), 0.0);
        stats.pulls.set(4);
        stats.stale_gap_sum.set(6);
        assert_eq!(stats.mean_staleness(), 1.5);
    }

    #[test]
    fn stats_net_bytes_sums_flush_republish_and_pull() {
        let stats = PsStats::default();
        stats.bytes_flushed.set(100);
        stats.bytes_republished.set(40);
        stats.bytes_pulled.set(7);
        assert_eq!(stats.net_bytes(), 147);
    }

    #[test]
    fn obs_snapshot_views_the_same_counters_as_stats() {
        use crate::obs::MetricValue;
        let server =
            ParameterServer::with_segments(2, 2, StalenessPolicy::Bounded(0), &[(0, 8)]);
        server.store().publish_dense(&[1.0; 8], 0);
        let (_, gap, waited, _gate_us) =
            server.serve_pull(0, &PullSpec::from_ranges(vec![(0, 8)]), 0).unwrap();
        assert_eq!((gap, waited), (0, false));
        let snap = server.obs_snapshot();
        assert_eq!(snap.get("ps.pulls"), Some(&MetricValue::Counter(1)));
        assert_eq!(
            snap.get("ps.pull_bytes").unwrap().as_u64(),
            server.stats_snapshot().bytes_pulled,
            "report field and registry are views over the same atomic"
        );
        assert_eq!(snap.get("gate.wait_us").unwrap().as_u64(), 1, "one gate observation");
        assert_eq!(snap.segments, vec![(0, 8, 0)]);
        let clock = snap.clock.as_ref().unwrap();
        assert_eq!(clock.staleness_bound, Some(0));
        assert_eq!(clock.worker_clocks, vec![0, 0]);
        assert!(snap.get("store.hash_probes").is_some());
    }

    #[test]
    fn server_wires_components() {
        let server = ParameterServer::new(4, 2, StalenessPolicy::Async);
        assert_eq!(server.store().num_shards(), 4);
        assert_eq!(server.policy(), StalenessPolicy::Async);
        server.store().publish_dense(&[1.0], 0);
        assert_eq!(server.store().len(), 1);
    }

    #[test]
    fn flush_ledger_applies_a_reassigned_block_exactly_once() {
        let server = ParameterServer::with_segments(2, 3, StalenessPolicy::Bounded(1), &[(0, 4)]);
        server.store().publish_dense(&[0.0; 4], 0);
        // worker 0 was slow; block 7 of round 0 was reassigned to
        // worker 1, which flushed first and wins
        assert!(server.serve_flush(1, 7, &[(0, 1.0)], 0), "first flush wins");
        assert!(!server.serve_flush(0, 7, &[(0, 1.0)], 0), "the late duplicate is dropped");
        let snap = server.store().read_spec(&PullSpec::from_keys(vec![0]));
        assert_eq!(snap.cells[0].value, 1.0, "applied exactly once");
        assert_eq!(server.stats_snapshot().flushes, 1);
        assert_eq!(server.stats_snapshot().flushes_dropped, 1);
        // the slow-but-alive loser still ticked its clock
        assert_eq!(server.clock().worker_clocks()[0], 1);
        // a different block of the same round is its own ledger entry
        assert!(server.serve_flush(2, 8, &[(1, 2.0)], 0));
        // after advance, a zombie for the pruned round is refused
        server.serve_advance(1);
        assert!(!server.serve_flush(2, 7, &[(0, 5.0)], 0), "zombie round refused");
        let snap = server.store().read_spec(&PullSpec::from_keys(vec![0]));
        assert_eq!(snap.cells[0].value, 1.0);
    }

    #[test]
    fn retired_workers_are_fenced_and_joiners_admitted() {
        let server = ParameterServer::new(2, 2, StalenessPolicy::Bounded(0));
        server.store().publish_dense(&[0.0; 2], 0);
        assert!(server.serve_leave(1), "retire flips");
        assert!(!server.serve_leave(1), "idempotent");
        assert!(!server.serve_flush(1, 0, &[(0, 9.0)], 0), "fenced after leave");
        assert_eq!(server.stats_snapshot().flushes_dropped, 1);
        // a joiner gets a fresh id at the frontier and can flush
        server.serve_join(2);
        assert!(server.clock().is_live(2));
        assert!(server.serve_flush(2, 0, &[(0, 1.5)], 0));
        let snap = server.store().read_spec(&PullSpec::from_keys(vec![0]));
        assert_eq!(snap.cells[0].value, 1.5);
    }

    #[test]
    fn server_with_segments_registers_them() {
        let server =
            ParameterServer::with_segments(4, 2, StalenessPolicy::Bounded(1), &[(0, 16)]);
        assert_eq!(server.store().segments(), vec![(0, 16)]);
        assert_eq!(server.store().len(), 16, "slab slots exist from registration");
    }
}
