//! The sharded parameter server with bounded-staleness (SSP) clocks —
//! the distributed execution substrate (after Petuum; the client API
//! follows the STRADS "Primitives" schedule/push/pull split).
//!
//! * [`shard`] — versioned cell storage in two representations behind
//!   one API: **dense segments** (registered contiguous key ranges,
//!   range-partitioned into `Vec<Cell>` slabs with slice reads and
//!   publishes — zero hash-map probes) and **hashed shards** (everything
//!   else, Petuum-style hash-partitioned maps). Each slab/shard sits
//!   behind its own lock; batched ops take each touched lock once.
//! * [`clock`] — per-worker SSP clocks and the `StalenessBound(s)` /
//!   fully-async admission gate. Under gate-driven pipelining
//!   (`workers::service`) this gate — not coordinator dispatch — is
//!   what paces workers, so scheduling overlaps compute.
//! * [`batch`] — worker-local delta batching/coalescing with wire-byte
//!   metering.
//! * [`client`] — the worker handle (`pull` / `push` / `flush_clock`)
//!   over [`PullSpec`] requests (ranges + scattered keys), and the
//!   [`PsKernel`] trait problems implement to run on it.
//!
//! Republish traffic (the coordinator overwriting derived state, e.g.
//! the Lasso residual) is tolerance-gated and metered separately from
//! worker flushes: see `ModelProblem::ps_republish` and the
//! `ps.republish_tol` config knob. The execution loop that wires a
//! [`ParameterServer`] to a `ModelProblem` and real worker threads
//! lives in `workers::service`.

pub mod batch;
pub mod client;
pub mod clock;
pub mod shard;

pub use batch::{wire_bytes_for, BYTES_PER_ENTRY, DeltaBatch};
pub use client::{PsClient, PsKernel, PsSnapshot};
pub use clock::{ClockShutdown, ClockTable, StalenessPolicy};
pub use shard::{Cell, PullSpec, ShardedStore};

use std::sync::atomic::{AtomicU64, Ordering};

/// Cross-thread run counters (all monotonic).
#[derive(Debug, Default)]
pub struct PsStats {
    /// Coalesced delta bytes flushed through the server by workers.
    pub bytes_flushed: AtomicU64,
    /// Derived-state bytes republished by the coordinator (tolerance-
    /// gated sparse republish + periodic full re-syncs).
    pub bytes_republished: AtomicU64,
    /// Number of flush batches.
    pub flushes: AtomicU64,
    /// Number of pulls served.
    pub pulls: AtomicU64,
    /// Sum over pulls of the observed staleness gap (rounds behind).
    pub stale_gap_sum: AtomicU64,
    /// Largest staleness gap any pull ever observed (must stay within
    /// the SSP bound — the concurrency tests pin this).
    pub max_stale_gap: AtomicU64,
    /// Pulls that had to block at the SSP gate.
    pub gate_waits: AtomicU64,
}

impl PsStats {
    /// Mean staleness gap over all pulls so far.
    pub fn mean_staleness(&self) -> f64 {
        let pulls = self.pulls.load(Ordering::Relaxed);
        if pulls == 0 {
            0.0
        } else {
            self.stale_gap_sum.load(Ordering::Relaxed) as f64 / pulls as f64
        }
    }

    /// Total wire traffic: worker flushes + coordinator republishes.
    pub fn net_bytes(&self) -> u64 {
        self.bytes_flushed.load(Ordering::Relaxed)
            + self.bytes_republished.load(Ordering::Relaxed)
    }
}

/// The server: sharded store + clock table + policy + stats. Shared
/// across worker threads behind an `Arc`.
pub struct ParameterServer {
    store: ShardedStore,
    clock: ClockTable,
    policy: StalenessPolicy,
    stats: PsStats,
}

impl ParameterServer {
    pub fn new(shards: usize, workers: usize, policy: StalenessPolicy) -> Self {
        Self::with_segments(shards, workers, policy, &[])
    }

    /// Build a server whose store has the given `(start, len)` key
    /// ranges registered as dense segments (see
    /// [`ShardedStore::with_segments`]).
    pub fn with_segments(
        shards: usize,
        workers: usize,
        policy: StalenessPolicy,
        segments: &[(usize, usize)],
    ) -> Self {
        ParameterServer {
            store: ShardedStore::with_segments(shards, segments),
            clock: ClockTable::new(workers),
            policy,
            stats: PsStats::default(),
        }
    }

    pub fn store(&self) -> &ShardedStore {
        &self.store
    }

    pub fn clock(&self) -> &ClockTable {
        &self.clock
    }

    pub fn policy(&self) -> StalenessPolicy {
        self.policy
    }

    pub fn stats(&self) -> &PsStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_mean_staleness() {
        let stats = PsStats::default();
        assert_eq!(stats.mean_staleness(), 0.0);
        stats.pulls.store(4, Ordering::Relaxed);
        stats.stale_gap_sum.store(6, Ordering::Relaxed);
        assert_eq!(stats.mean_staleness(), 1.5);
    }

    #[test]
    fn stats_net_bytes_sums_flush_and_republish() {
        let stats = PsStats::default();
        stats.bytes_flushed.store(100, Ordering::Relaxed);
        stats.bytes_republished.store(40, Ordering::Relaxed);
        assert_eq!(stats.net_bytes(), 140);
    }

    #[test]
    fn server_wires_components() {
        let server = ParameterServer::new(4, 2, StalenessPolicy::Async);
        assert_eq!(server.store().num_shards(), 4);
        assert_eq!(server.policy(), StalenessPolicy::Async);
        server.store().publish_dense(&[1.0], 0);
        assert_eq!(server.store().len(), 1);
    }

    #[test]
    fn server_with_segments_registers_them() {
        let server =
            ParameterServer::with_segments(4, 2, StalenessPolicy::Bounded(1), &[(0, 16)]);
        assert_eq!(server.store().segments(), vec![(0, 16)]);
        assert_eq!(server.store().len(), 16, "slab slots exist from registration");
    }
}
