//! The storage layer of the parameter server: two representations
//! behind the same `publish` / `add_deltas` / `read` API.
//!
//! * **Dense segments** — registered contiguous key ranges (the Lasso
//!   residual `0..n`, MF's factor/residual arrays) live as immutable
//!   **f32 epoch slabs**, split into fixed-size **chunks**: each chunk
//!   is one `Arc<Vec<f32>>` value image plus its own `u64` epoch
//!   version (4 bytes per cell instead of the 16-byte per-cell
//!   `Cell`). Writers build the next epoch copy-on-publish —
//!   `Arc::make_mut` clones a chunk's slab only when a reader still
//!   holds that chunk's previous epoch — so a covered range pull
//!   inside one chunk is an O(1) `Arc` clone with no lock held while
//!   the data is consumed and zero allocation ([`RangePull`]), and a
//!   publish racing a held snapshot clones only the chunks it actually
//!   writes, not the whole segment. `chunk_cells = 0` (the default)
//!   keeps one chunk per segment — exactly the pre-chunking behaviour.
//!   Every key in a segment is addressed by arithmetic alone; dense
//!   traffic never touches a hash map.
//! * **Hashed shards** — unregistered keys keep the Petuum-style
//!   hash-partitioned `Cell` maps (full f64 values, per-cell versions),
//!   each behind its own `RwLock`, so sparse or unbounded key spaces
//!   need no registration.
//!
//! Batched operations group their entries by lock unit (a hashed shard
//! or a segment chunk) and take each touched lock exactly once. The
//! [`ShardedStore::hash_probes`] counter meters every probe the hashed
//! path serves (the "dense traffic never hashes" guarantee);
//! [`ShardedStore::cow_clones`] meters how often a write actually had
//! to clone a chunk because readers held it, and
//! [`ShardedStore::cow_bytes`] meters the bytes those clones copied —
//! the copy-on-publish cost pair that chunking exists to shrink.
//! Tolerance-gated sparse republish composes with this: entries under
//! `tol` are skipped before they reach the store, and the entries that
//! do arrive mutate a fresh chunk clone only when workers still hold
//! the old one; otherwise the chunk is updated in place.

use super::batch::wire_bytes_for;
use crate::util::FastHashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// One versioned parameter cell (the hashed representation, and the
/// unit scattered-key reads are reported in). `version` is the server
/// round/clock the value was last written at (0 = the initial publish).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cell {
    pub version: u64,
    pub value: f64,
}

/// Fibonacci multiplicative key spreader (same constant as
/// [`crate::util::fasthash`]): dense variable ids would otherwise pile
/// onto one shard under a plain modulus.
const SPREAD: u64 = 0x517cc1b727220a95;

/// One read request: contiguous key ranges plus scattered keys. Ranges
/// over a registered dense segment are served as zero-copy epoch views;
/// the snapshot cell order is all ranges first (in request order), then
/// the scattered keys (in request order). Ranges must be mutually
/// disjoint.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PullSpec {
    /// `(first_key, len)` contiguous runs.
    pub ranges: Vec<(usize, usize)>,
    /// Individually addressed keys.
    pub keys: Vec<usize>,
}

impl PullSpec {
    pub fn from_keys(keys: Vec<usize>) -> Self {
        PullSpec { ranges: Vec::new(), keys }
    }

    pub fn from_ranges(ranges: Vec<(usize, usize)>) -> Self {
        PullSpec { ranges, keys: Vec::new() }
    }

    /// Append a contiguous run (empty runs are dropped).
    pub fn push_range(&mut self, start: usize, len: usize) {
        if len > 0 {
            self.ranges.push((start, len));
        }
    }

    pub fn push_key(&mut self, key: usize) {
        self.keys.push(key);
    }

    /// Total number of cells this spec reads.
    pub fn total_len(&self) -> usize {
        self.ranges.iter().map(|&(_, len)| len).sum::<usize>() + self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty() && self.keys.is_empty()
    }
}

/// One pulled contiguous range: an f32 value image plus the epoch
/// version it was read at. `Shared` is the zero-copy fast path — a
/// slice view into one chunk's published epoch slab, kept alive by the
/// `Arc` and immutable by construction (writers never mutate an epoch
/// a reader holds; they clone it first). `Owned` is the materialized
/// fallback: covered ranges spanning multiple chunks (`covered =
/// true`, still 4 bytes/cell on the wire) and ranges not covered by
/// one segment (`covered = false`).
#[derive(Clone, Debug)]
pub struct RangePull {
    start: usize,
    version: u64,
    data: RangeData,
}

#[derive(Clone, Debug)]
enum RangeData {
    Shared { slab: Arc<Vec<f32>>, offset: usize, len: usize },
    Owned { values: Vec<f32>, covered: bool },
}

impl RangePull {
    /// Build an owned range view — the local-execution path
    /// (`DistMf::update_blocks`), wire decode, and tests snapshot
    /// their own state through this.
    pub fn owned(start: usize, version: u64, values: Vec<f32>) -> Self {
        RangePull { start, version, data: RangeData::Owned { values, covered: false } }
    }

    /// An owned copy assembled from a registered segment's chunks (a
    /// covered range spanning a chunk boundary): not zero-copy, but
    /// still f32-slab traffic for the wire-byte model.
    fn owned_covered(start: usize, version: u64, values: Vec<f32>) -> Self {
        RangePull { start, version, data: RangeData::Owned { values, covered: true } }
    }

    /// First key of the range.
    pub fn start(&self) -> usize {
        self.start
    }

    /// The chunk's epoch version (dense path; multi-chunk reads take
    /// the oldest touched chunk), or the oldest version across the
    /// span (fallback path; missing cells count as 0) — either way,
    /// safe input for `PsSnapshot::min_version`.
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn len(&self) -> usize {
        match &self.data {
            RangeData::Shared { len, .. } => *len,
            RangeData::Owned { values, .. } => values.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether this view shares the store's epoch slab (zero-copy).
    pub fn is_shared(&self) -> bool {
        matches!(self.data, RangeData::Shared { .. })
    }

    /// Whether the range was served entirely from dense-segment slabs
    /// (shared or assembled): such ranges move 4 bytes per cell on the
    /// wire regardless of how many chunk images backed them.
    pub fn is_covered(&self) -> bool {
        match &self.data {
            RangeData::Shared { .. } => true,
            RangeData::Owned { covered, .. } => *covered,
        }
    }

    /// The f32 value image. For `Shared` views this borrows straight
    /// out of the epoch slab — no copy was ever made.
    pub fn values(&self) -> &[f32] {
        match &self.data {
            RangeData::Shared { slab, offset, len } => &slab[*offset..offset + len],
            RangeData::Owned { values, .. } => values,
        }
    }
}

/// The result of reading a full [`PullSpec`]: one [`RangePull`] per
/// requested range (request order) plus one [`Cell`] per scattered key
/// (request order).
#[derive(Clone, Debug)]
pub struct SpecPull {
    pub ranges: Vec<RangePull>,
    pub cells: Vec<Cell>,
}

impl SpecPull {
    /// Total cells this pull covers (range members + scattered keys).
    pub fn total_cells(&self) -> usize {
        self.ranges.iter().map(|r| r.len()).sum::<usize>() + self.cells.len()
    }

    /// Ranges served zero-copy off a shared epoch slab.
    pub fn shared_ranges(&self) -> usize {
        self.ranges.iter().filter(|r| r.is_shared()).count()
    }

    /// Modeled wire bytes of this pull. Segment-covered f32 ranges
    /// (zero-copy chunk views and multi-chunk assemblies alike — the
    /// wire encodes both as one raw f32 slab) move 4 bytes per cell
    /// plus one 8-byte epoch version; fallback ranges and scattered
    /// keys move full 16-byte `(key, f64)` cells. The per-cell `Cell`
    /// path this design replaced metered every pulled cell at 16 bytes
    /// — `16 * total_cells()` is that baseline.
    pub fn wire_bytes(&self) -> u64 {
        let mut bytes = wire_bytes_for(self.cells.len());
        for r in &self.ranges {
            bytes += if r.is_covered() {
                8 + 4 * r.len() as u64
            } else {
                wire_bytes_for(r.len())
            };
        }
        bytes
    }
}

/// One epoch chunk of a dense segment: a published f32 value image
/// plus the single version covering every cell in it. The `Arc` is
/// what pulls clone; writers go through `ShardedStore::cow_values`.
struct Chunk {
    values: Arc<Vec<f32>>,
    version: u64,
}

/// One registered contiguous key range stored as a vector of epoch
/// chunk slabs. Each chunk is its own lock unit: reads inside one
/// chunk are O(1) `Arc` clones, and a publish copy-on-writes only the
/// chunks it touches. `chunk_cells` here is the *effective* chunk size
/// (`len` when the configured value is 0 — one chunk, the pre-chunking
/// behaviour, which also keeps whole-segment pulls a single zero-copy
/// view handing kernels one `&[f32]`).
struct DenseSegment {
    start: usize,
    len: usize,
    chunk_cells: usize,
    chunks: Vec<RwLock<Chunk>>,
}

impl DenseSegment {
    fn new(start: usize, len: usize, configured_chunk: usize) -> Self {
        debug_assert!(len > 0);
        let chunk_cells = if configured_chunk == 0 { len } else { configured_chunk.min(len) };
        let n_chunks = (len + chunk_cells - 1) / chunk_cells;
        let chunks = (0..n_chunks)
            .map(|c| {
                let size = ((c + 1) * chunk_cells).min(len) - c * chunk_cells;
                RwLock::new(Chunk { values: Arc::new(vec![0.0f32; size]), version: 0 })
            })
            .collect();
        DenseSegment { start, len, chunk_cells, chunks }
    }

    #[inline]
    fn contains(&self, key: usize) -> bool {
        key >= self.start && key < self.start + self.len
    }

    /// Chunk index holding segment-relative offset `off`.
    #[inline]
    fn chunk_of(&self, off: usize) -> usize {
        off / self.chunk_cells
    }

    /// Segment-relative `[lo, hi)` bounds of chunk `c`.
    #[inline]
    fn chunk_bounds(&self, c: usize) -> (usize, usize) {
        (c * self.chunk_cells, ((c + 1) * self.chunk_cells).min(self.len))
    }

    /// Copy `out.len()` cells starting at segment-relative `rel` out
    /// of the chunk images; returns the OLDEST version among the
    /// touched chunks (the staleness-diagnostic contract).
    fn read_into(&self, rel: usize, out: &mut [f32]) -> u64 {
        let mut version = u64::MAX;
        let mut pos = 0;
        let mut c = self.chunk_of(rel);
        while pos < out.len() {
            let (lo, hi) = self.chunk_bounds(c);
            let chunk = self.chunks[c].read().expect("chunk lock poisoned");
            let a = rel + pos - lo;
            let take = (hi - lo - a).min(out.len() - pos);
            out[pos..pos + take].copy_from_slice(&chunk.values[a..a + take]);
            version = version.min(chunk.version);
            pos += take;
            c += 1;
        }
        if version == u64::MAX {
            0
        } else {
            version
        }
    }
}

/// Where a key lives: a dense segment slot or a hashed shard.
#[derive(Clone, Copy, Debug)]
enum Slot {
    Dense { seg: usize, off: usize },
    Hashed { shard: usize },
}

/// One maximal sub-run of a contiguous key range, classified by where
/// it is stored (see [`ShardedStore::for_each_span`]).
enum Span {
    /// `len` keys starting at `key`, at offset `rel` inside segment `seg`.
    Dense { seg: usize, rel: usize, key: usize, len: usize },
    /// `len` unregistered keys starting at `key`.
    Hashed { key: usize, len: usize },
}

/// The sharded store. Keys are `usize` parameter ids in a flat,
/// problem-defined key space (see `ModelProblem::ps_state`).
pub struct ShardedStore {
    shards: Vec<RwLock<FastHashMap<usize, Cell>>>,
    /// Registered dense segments, sorted by start, non-overlapping.
    segments: Vec<DenseSegment>,
    /// The configured chunk size (0 = one chunk per segment); kept for
    /// introspection and server reattach shape checks.
    chunk_cells: usize,
    /// `chunk_base[seg]` = lock units consumed by segments before
    /// `seg` (prefix sum of chunk counts), so a dense slot maps to its
    /// chunk's lock unit by arithmetic.
    chunk_base: Vec<usize>,
    /// Probes served by the hashed path (dense-segment traffic never
    /// increments this — the meter behind the zero-probe guarantee).
    hash_probes: AtomicU64,
    /// Chunk clones forced by copy-on-publish: a write found readers
    /// still holding the current epoch and cloned it before mutating.
    cow_clones: AtomicU64,
    /// Bytes those clones copied (4 per cell of each cloned chunk) —
    /// the meter chunking shrinks: a racing publish re-copies only the
    /// chunks it writes, not whole segments.
    cow_bytes: AtomicU64,
}

impl ShardedStore {
    pub fn new(num_shards: usize) -> Self {
        Self::with_segments(num_shards, &[])
    }

    /// Build a store with the given `(start, len)` key ranges
    /// registered as dense segments, one epoch chunk per segment (the
    /// pre-chunking behaviour).
    pub fn with_segments(num_shards: usize, segments: &[(usize, usize)]) -> Self {
        Self::with_segments_chunked(num_shards, segments, 0)
    }

    /// Build a store with dense segments split into `chunk_cells`-cell
    /// epoch chunks (0 = one chunk per segment). Ranges must not
    /// overlap; zero-length ranges are ignored. Registration happens at
    /// construction so the store can be shared immutably across worker
    /// threads afterwards.
    pub fn with_segments_chunked(
        num_shards: usize,
        segments: &[(usize, usize)],
        chunk_cells: usize,
    ) -> Self {
        assert!(num_shards >= 1, "need at least one shard");
        let mut segs: Vec<(usize, usize)> =
            segments.iter().copied().filter(|&(_, len)| len > 0).collect();
        segs.sort_unstable_by_key(|&(start, _)| start);
        for w in segs.windows(2) {
            assert!(w[0].0 + w[0].1 <= w[1].0, "dense segments must not overlap");
        }
        let segs: Vec<DenseSegment> = segs
            .into_iter()
            .map(|(start, len)| DenseSegment::new(start, len, chunk_cells))
            .collect();
        let mut chunk_base = Vec::with_capacity(segs.len());
        let mut units = 0usize;
        for seg in &segs {
            chunk_base.push(units);
            units += seg.chunks.len();
        }
        ShardedStore {
            shards: (0..num_shards).map(|_| RwLock::new(FastHashMap::default())).collect(),
            segments: segs,
            chunk_cells,
            chunk_base,
            hash_probes: AtomicU64::new(0),
            cow_clones: AtomicU64::new(0),
            cow_bytes: AtomicU64::new(0),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The configured chunk size (0 = one chunk per segment).
    pub fn chunk_cells(&self) -> usize {
        self.chunk_cells
    }

    /// Registered dense segments as `(start, len)` pairs.
    pub fn segments(&self) -> Vec<(usize, usize)> {
        self.segments.iter().map(|s| (s.start, s.len)).collect()
    }

    /// Registered dense segments with their current epoch versions,
    /// `(start, len, epoch_version)` — the per-shard freshness view
    /// that `strads ps-stats` introspection reports. With chunking the
    /// reported version is the NEWEST chunk's (how fresh the segment
    /// has gotten anywhere).
    pub fn segment_versions(&self) -> Vec<(usize, usize, u64)> {
        self.segments
            .iter()
            .map(|s| {
                let version = s
                    .chunks
                    .iter()
                    .map(|c| c.read().expect("chunk lock poisoned").version)
                    .max()
                    .unwrap_or(0);
                (s.start, s.len, version)
            })
            .collect()
    }

    /// Checkpoint export: every segment's current image as `(start,
    /// per-chunk versions, contiguous values)`. Chunk `Arc`s are
    /// cloned under their read locks, then concatenated — immutable
    /// epochs make each chunk's capture consistent and the raw f32
    /// image bit-exact by construction.
    pub fn segment_images(&self) -> Vec<(usize, Vec<u64>, Vec<f32>)> {
        self.segments
            .iter()
            .map(|s| {
                let mut versions = Vec::with_capacity(s.chunks.len());
                let mut values = Vec::with_capacity(s.len);
                for chunk in &s.chunks {
                    let chunk = chunk.read().expect("chunk lock poisoned");
                    versions.push(chunk.version);
                    values.extend_from_slice(&chunk.values);
                }
                (s.start, versions, values)
            })
            .collect()
    }

    /// Checkpoint export: every hashed cell as `(key, cell)`, sorted by
    /// key so the serialized bytes are deterministic.
    pub fn hashed_cells(&self) -> Vec<(usize, Cell)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let map = shard.read().expect("shard lock poisoned");
            out.extend(map.iter().map(|(&k, &c)| (k, c)));
        }
        out.sort_unstable_by_key(|&(k, _)| k);
        out
    }

    /// Checkpoint restore: install a saved image into the segment
    /// starting at `start`. `versions` carries one version per chunk,
    /// or a single version to broadcast (pre-chunking v1/v2 images).
    /// Returns false (and changes nothing) if no registered segment
    /// matches the image's start/length/chunk count — the checkpoint
    /// came from a differently-shaped run.
    pub fn restore_segment(&self, start: usize, values: Vec<f32>, versions: &[u64]) -> bool {
        let Some(seg) = self.segments.iter().find(|s| s.start == start) else {
            return false;
        };
        if seg.len != values.len()
            || (versions.len() != 1 && versions.len() != seg.chunks.len())
        {
            return false;
        }
        for (c, lock) in seg.chunks.iter().enumerate() {
            let (lo, hi) = seg.chunk_bounds(c);
            let version = if versions.len() == 1 { versions[0] } else { versions[c] };
            let mut chunk = lock.write().expect("chunk lock poisoned");
            *chunk = Chunk { values: Arc::new(values[lo..hi].to_vec()), version };
        }
        true
    }

    /// Checkpoint restore: reinstall saved hashed cells, preserving
    /// their versions. Cells that now route to a dense segment (the
    /// segment layout changed) land in the slab instead.
    pub fn restore_cells(&self, cells: &[(usize, Cell)]) {
        for &(key, cell) in cells {
            match self.locate(key) {
                Slot::Hashed { shard } => {
                    self.hash_probes.fetch_add(1, Ordering::Relaxed);
                    let mut map = self.shards[shard].write().expect("shard lock poisoned");
                    map.insert(key, cell);
                }
                Slot::Dense { seg, off } => {
                    let s = &self.segments[seg];
                    let c = s.chunk_of(off);
                    let (lo, _) = s.chunk_bounds(c);
                    let mut chunk = s.chunks[c].write().expect("chunk lock poisoned");
                    let slab = self.cow_values(&mut chunk);
                    slab[off - lo] = cell.value as f32;
                    chunk.version = chunk.version.max(cell.version);
                }
            }
        }
    }

    /// Cumulative hashed-path probe count (reads and writes that went
    /// through a hash map). Dense-segment accesses never count here.
    pub fn hash_probes(&self) -> u64 {
        self.hash_probes.load(Ordering::Relaxed)
    }

    /// How many chunk slab clones copy-on-publish has performed (a
    /// write arrived while a reader held the current epoch).
    pub fn cow_clones(&self) -> u64 {
        self.cow_clones.load(Ordering::Relaxed)
    }

    /// Total bytes copied by those clones (4 per cloned-chunk cell) —
    /// the cost meter chunking shrinks.
    pub fn cow_bytes(&self) -> u64 {
        self.cow_bytes.load(Ordering::Relaxed)
    }

    /// Deterministic key -> shard routing (pure function of the key and
    /// the shard count, identical across store instances).
    #[inline]
    pub fn shard_of(&self, key: usize) -> usize {
        (((key as u64).wrapping_mul(SPREAD) >> 32) % self.shards.len() as u64) as usize
    }

    /// Total number of cells across all shards and segments. Registered
    /// dense ranges count in full: their slots exist from registration.
    pub fn len(&self) -> usize {
        let hashed: usize =
            self.shards.iter().map(|s| s.read().expect("shard lock poisoned").len()).sum();
        hashed + self.segments.iter().map(|s| s.len).sum::<usize>()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resolve a key to its storage slot. Segments are few and sorted,
    /// so this is a short binary search, then arithmetic.
    #[inline]
    fn locate(&self, key: usize) -> Slot {
        let idx = self.segments.partition_point(|s| s.start <= key);
        if idx > 0 {
            let seg = &self.segments[idx - 1];
            if seg.contains(key) {
                return Slot::Dense { seg: idx - 1, off: key - seg.start };
            }
        }
        Slot::Hashed { shard: self.shard_of(key) }
    }

    /// Lock-unit id for grouping: hashed shards first, then every
    /// segment's chunks in registration order.
    fn unit_of(&self, slot: Slot) -> usize {
        match slot {
            Slot::Hashed { shard } => shard,
            Slot::Dense { seg, off } => {
                self.shards.len() + self.chunk_base[seg] + self.segments[seg].chunk_of(off)
            }
        }
    }

    fn num_units(&self) -> usize {
        self.shards.len()
            + self.chunk_base.last().map_or(0, |&b| b)
            + self.segments.last().map_or(0, |s| s.chunks.len())
    }

    /// Index of the registered segment fully covering `start..start+len`.
    fn segment_covering(&self, start: usize, len: usize) -> Option<usize> {
        let idx = self.segments.partition_point(|s| s.start <= start);
        if idx == 0 {
            return None;
        }
        let seg = &self.segments[idx - 1];
        (start >= seg.start && start + len <= seg.start + seg.len).then_some(idx - 1)
    }

    /// Mutable access to a chunk's value image under copy-on-publish:
    /// clones the slab (and meters the clone and its bytes) only if a
    /// reader still holds the current epoch's `Arc`; otherwise mutates
    /// in place.
    fn cow_values<'a>(&self, chunk: &'a mut Chunk) -> &'a mut Vec<f32> {
        // Meter by whether make_mut actually relocated the slab — a
        // reader can drop its Arc between any pre-check and the clone
        // decision, so a strong-count probe would over-count.
        let shared = Arc::as_ptr(&chunk.values);
        let values = Arc::make_mut(&mut chunk.values);
        if !std::ptr::eq(shared, values) {
            self.cow_clones.fetch_add(1, Ordering::Relaxed);
            self.cow_bytes.fetch_add(4 * values.len() as u64, Ordering::Relaxed);
        }
        values
    }

    /// Write `src` into segment `seg` starting at segment-relative
    /// `rel`, chunk by chunk: each touched chunk takes its write lock
    /// once, goes through copy-on-publish once, and advances its
    /// version to at least `at`. Untouched chunks keep their epochs —
    /// the point of chunking.
    fn write_span<T: Copy>(
        &self,
        seg: &DenseSegment,
        rel: usize,
        src: &[T],
        at: u64,
        write: impl Fn(&mut f32, T),
    ) {
        let mut pos = 0;
        let mut c = seg.chunk_of(rel);
        while pos < src.len() {
            let (lo, hi) = seg.chunk_bounds(c);
            let a = rel + pos - lo;
            let take = (hi - lo - a).min(src.len() - pos);
            let mut chunk = seg.chunks[c].write().expect("chunk lock poisoned");
            let slab = self.cow_values(&mut chunk);
            for (dst, &v) in slab[a..a + take].iter_mut().zip(&src[pos..pos + take]) {
                write(dst, v);
            }
            chunk.version = chunk.version.max(at);
            pos += take;
            c += 1;
        }
    }

    /// Decompose the key range `start..start+len` into maximal sub-runs
    /// per storage location, in key order — segment overlaps become
    /// [`Span::Dense`] runs, gaps become [`Span::Hashed`] runs. This is
    /// how partially-covered ranges are served without materializing a
    /// per-key routing table for the whole range.
    fn for_each_span(&self, start: usize, len: usize, mut f: impl FnMut(Span)) {
        let end = start + len;
        let mut key = start;
        let mut idx = self.segments.partition_point(|s| s.start + s.len <= key);
        while key < end {
            match self.segments.get(idx) {
                Some(seg) if seg.start <= key => {
                    let take = (seg.start + seg.len).min(end) - key;
                    f(Span::Dense { seg: idx, rel: key - seg.start, key, len: take });
                    key += take;
                    if key == seg.start + seg.len {
                        idx += 1;
                    }
                }
                Some(seg) => {
                    let take = seg.start.min(end) - key;
                    f(Span::Hashed { key, len: take });
                    key += take;
                }
                None => {
                    f(Span::Hashed { key, len: end - key });
                    key = end;
                }
            }
        }
    }

    /// Overwrite-publish `(key, value)` entries at `version` (the
    /// coordinator's path: seeding the store and republishing derived
    /// state with exact canonical values). Dense-segment entries land
    /// in their chunk's f32 image and bump that chunk's epoch version.
    pub fn publish(&self, entries: &[(usize, f64)], version: u64) {
        self.for_each_slot_mut(
            entries,
            version,
            |slot, value| *slot = value as f32,
            |map, key, value| {
                map.insert(key, Cell { version, value });
            },
        );
    }

    /// Overwrite-publish the contiguous range `start..start +
    /// values.len()` at `version`. Segment-covered spans are written as
    /// slice fills into the (copy-on-publish) chunk images — zero hash
    /// probes; hashed gaps are grouped per shard.
    pub fn publish_range(&self, start: usize, values: &[f64], version: u64) {
        if values.is_empty() {
            return;
        }
        self.for_each_span(start, values.len(), |span| match span {
            Span::Dense { seg, rel, key, len } => {
                let src = &values[key - start..key - start + len];
                self.write_span(&self.segments[seg], rel, src, version, |dst, v| {
                    *dst = v as f32;
                });
            }
            Span::Hashed { key, len } => {
                // Gap keys route through the canonical grouped publish
                // (one lock take per touched shard, probes metered
                // there); the entry buffer is gap-sized, not
                // range-sized.
                let entries: Vec<(usize, f64)> =
                    (key..key + len).map(|k| (k, values[k - start])).collect();
                self.publish(&entries, version);
            }
        });
    }

    /// [`Self::publish_range`] from canonical f32 values — what the
    /// epoch slabs store natively. Segment-covered spans skip the
    /// f64 widen/narrow round trip entirely (bit-identical to
    /// publishing `v as f64`: `(v as f64) as f32 == v` for every f32
    /// including -0.0, subnormals and NaN payloads the store keeps);
    /// hashed gap keys widen, exactly as the f64 path narrows them.
    pub fn publish_range_f32(&self, start: usize, values: &[f32], version: u64) {
        if values.is_empty() {
            return;
        }
        self.for_each_span(start, values.len(), |span| match span {
            Span::Dense { seg, rel, key, len } => {
                let src = &values[key - start..key - start + len];
                self.write_span(&self.segments[seg], rel, src, version, |dst, v| *dst = v);
            }
            Span::Hashed { key, len } => {
                let entries: Vec<(usize, f64)> =
                    (key..key + len).map(|k| (k, values[k - start] as f64)).collect();
                self.publish(&entries, version);
            }
        });
    }

    /// Publish a dense state vector: key `i` gets `values[i]` (the
    /// round-0 seed and full-resync path).
    pub fn publish_dense(&self, values: &[f64], version: u64) {
        self.publish_range(0, values, version);
    }

    /// [`Self::publish_dense`] from canonical f32 state (MF's native
    /// precision) — no per-cell widen/narrow round trip.
    pub fn publish_dense_f32(&self, values: &[f32], version: u64) {
        self.publish_range_f32(0, values, version);
    }

    /// Apply additive deltas (the worker push path): `value += delta`,
    /// versions advance to at least `at`. Missing hashed keys start
    /// from 0.0 at version 0, matching an all-zero initial model.
    /// Dense-segment accumulation happens in f32 — the wire precision
    /// those segments store.
    pub fn add_deltas(&self, deltas: &[(usize, f64)], at: u64) {
        self.for_each_slot_mut(
            deltas,
            at,
            |slot, delta| *slot += delta as f32,
            |map, key, delta| {
                let cell = map.entry(key).or_default();
                cell.value += delta;
                cell.version = cell.version.max(at);
            },
        );
    }

    /// Read cells for `keys`, preserving request order. Each touched
    /// lock (shard or chunk) is taken once per call. Unpublished
    /// hashed keys read as the default cell; dense keys read their f32
    /// image at their chunk's epoch version.
    pub fn read(&self, keys: &[usize]) -> Vec<Cell> {
        let mut out = vec![Cell::default(); keys.len()];
        self.read_into(keys, &mut out);
        out
    }

    /// Read a full [`PullSpec`]: each range as a [`RangePull`] (an O(1)
    /// zero-copy epoch view where a single chunk covers it), then the
    /// scattered keys as cells.
    pub fn read_spec(&self, spec: &PullSpec) -> SpecPull {
        let ranges =
            spec.ranges.iter().map(|&(start, len)| self.read_range(start, len)).collect();
        let cells =
            if spec.keys.is_empty() { Vec::new() } else { self.read(&spec.keys) };
        SpecPull { ranges, cells }
    }

    /// Read the contiguous key range `start..start + len`. A range
    /// inside a single chunk of a registered segment returns a shared
    /// epoch view — the lock is held only long enough to clone the
    /// `Arc`, so no lock is held while the caller consumes the data
    /// (with `chunk_cells = 0` every covered range qualifies). A
    /// covered range spanning chunks assembles one owned copy from the
    /// chunk images (version = oldest touched chunk). Anything else
    /// materializes one owned f32 copy by walking the range's spans
    /// directly (segment overlaps as slice copies, hashed gaps grouped
    /// per shard — no per-key routing table is allocated).
    pub fn read_range(&self, start: usize, len: usize) -> RangePull {
        if len == 0 {
            return RangePull::owned(start, 0, Vec::new());
        }
        if let Some(seg_idx) = self.segment_covering(start, len) {
            let seg = &self.segments[seg_idx];
            let rel = start - seg.start;
            let c = seg.chunk_of(rel);
            if seg.chunk_of(rel + len - 1) == c {
                let (lo, _) = seg.chunk_bounds(c);
                let chunk = seg.chunks[c].read().expect("chunk lock poisoned");
                return RangePull {
                    start,
                    version: chunk.version,
                    data: RangeData::Shared {
                        slab: Arc::clone(&chunk.values),
                        offset: rel - lo,
                        len,
                    },
                };
            }
            let mut out = vec![0.0f32; len];
            let version = seg.read_into(rel, &mut out);
            return RangePull::owned_covered(start, version, out);
        }
        // Fallback version = the OLDEST version across the span
        // (missing hashed cells count as 0), preserving the
        // `min_version` staleness-diagnostic contract the per-cell
        // scan used to provide.
        let mut out = vec![0.0f32; len];
        let mut version = u64::MAX;
        self.for_each_span(start, len, |span| match span {
            Span::Dense { seg, rel, key, len: take } => {
                let v = self.segments[seg]
                    .read_into(rel, &mut out[key - start..key - start + take]);
                version = version.min(v);
            }
            Span::Hashed { key, len: take } => {
                // Gap keys route through the canonical grouped read;
                // the key/cell buffers are gap-sized, not range-sized.
                // Missing keys stay at the default cell (version 0).
                let keys: Vec<usize> = (key..key + take).collect();
                let mut cells = vec![Cell::default(); take];
                self.read_into(&keys, &mut cells);
                for (i, cell) in cells.iter().enumerate() {
                    out[key - start + i] = cell.value as f32;
                    version = version.min(cell.version);
                }
            }
        });
        RangePull { start, version, data: RangeData::Owned { values: out, covered: false } }
    }

    /// Grouped positional read: `out[i]` receives the cell for
    /// `keys[i]`.
    fn read_into(&self, keys: &[usize], out: &mut [Cell]) {
        debug_assert_eq!(keys.len(), out.len());
        let mut slots: Vec<Slot> = Vec::with_capacity(keys.len());
        let mut by_unit: Vec<Vec<usize>> = vec![Vec::new(); self.num_units()];
        for (pos, &key) in keys.iter().enumerate() {
            let slot = self.locate(key);
            by_unit[self.unit_of(slot)].push(pos);
            slots.push(slot);
        }
        for positions in by_unit.iter().filter(|p| !p.is_empty()) {
            match slots[positions[0]] {
                Slot::Hashed { shard } => {
                    self.hash_probes.fetch_add(positions.len() as u64, Ordering::Relaxed);
                    let map = self.shards[shard].read().expect("shard lock poisoned");
                    for &pos in positions {
                        if let Some(cell) = map.get(&keys[pos]) {
                            out[pos] = *cell;
                        }
                    }
                }
                Slot::Dense { seg, off } => {
                    let s = &self.segments[seg];
                    let c = s.chunk_of(off);
                    let (lo, _) = s.chunk_bounds(c);
                    let chunk = s.chunks[c].read().expect("chunk lock poisoned");
                    for &pos in positions {
                        let Slot::Dense { off, .. } = slots[pos] else { unreachable!() };
                        out[pos] = Cell {
                            version: chunk.version,
                            value: chunk.values[off - lo] as f64,
                        };
                    }
                }
            }
        }
    }

    /// Group `entries` by lock unit (hashed shard or segment chunk) and
    /// apply the matching mutator under each unit's write lock, taken
    /// once per touched unit. Within a unit, entries apply in request
    /// order, so duplicate keys resolve identically to a sequential
    /// application. Each touched chunk's epoch version advances to at
    /// least `at`, and its slab goes through copy-on-publish exactly
    /// once per call — untouched chunks keep their epochs.
    fn for_each_slot_mut(
        &self,
        entries: &[(usize, f64)],
        at: u64,
        mut dense: impl FnMut(&mut f32, f64),
        mut hashed: impl FnMut(&mut FastHashMap<usize, Cell>, usize, f64),
    ) {
        let mut slots: Vec<Slot> = Vec::with_capacity(entries.len());
        let mut by_unit: Vec<Vec<usize>> = vec![Vec::new(); self.num_units()];
        for (pos, &(key, _)) in entries.iter().enumerate() {
            let slot = self.locate(key);
            by_unit[self.unit_of(slot)].push(pos);
            slots.push(slot);
        }
        for positions in by_unit.iter().filter(|p| !p.is_empty()) {
            match slots[positions[0]] {
                Slot::Hashed { shard } => {
                    self.hash_probes.fetch_add(positions.len() as u64, Ordering::Relaxed);
                    let mut map = self.shards[shard].write().expect("shard lock poisoned");
                    for &pos in positions {
                        let (key, value) = entries[pos];
                        hashed(&mut map, key, value);
                    }
                }
                Slot::Dense { seg, off } => {
                    let s = &self.segments[seg];
                    let c = s.chunk_of(off);
                    let (lo, _) = s.chunk_bounds(c);
                    let mut chunk = s.chunks[c].write().expect("chunk lock poisoned");
                    let slab = self.cow_values(&mut chunk);
                    for &pos in positions {
                        let Slot::Dense { off, .. } = slots[pos] else { unreachable!() };
                        dense(&mut slab[off - lo], entries[pos].1);
                    }
                    chunk.version = chunk.version.max(at);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_deterministic_and_total() {
        let a = ShardedStore::new(8);
        let b = ShardedStore::new(8);
        for key in 0..10_000 {
            let s = a.shard_of(key);
            assert_eq!(s, b.shard_of(key), "routing must not depend on the instance");
            assert!(s < 8);
        }
    }

    #[test]
    fn routing_spreads_dense_keys() {
        let store = ShardedStore::new(8);
        let mut counts = [0usize; 8];
        for key in 0..8000 {
            counts[store.shard_of(key)] += 1;
        }
        for (shard, &c) in counts.iter().enumerate() {
            assert!(c > 400, "shard {shard} got only {c}/8000 dense keys");
        }
    }

    #[test]
    fn publish_read_roundtrip_preserves_order() {
        let store = ShardedStore::new(4);
        store.publish_dense(&[1.0, 2.0, 3.0, 4.0], 7);
        let cells = store.read(&[3, 0, 2]);
        assert_eq!(cells[0], Cell { version: 7, value: 4.0 });
        assert_eq!(cells[1], Cell { version: 7, value: 1.0 });
        assert_eq!(cells[2], Cell { version: 7, value: 3.0 });
        assert_eq!(store.len(), 4);
    }

    #[test]
    fn add_deltas_accumulates_and_bumps_version() {
        let store = ShardedStore::new(3);
        store.publish(&[(10, 1.0)], 0);
        store.add_deltas(&[(10, 0.5), (11, -2.0)], 4);
        store.add_deltas(&[(10, 0.25)], 2); // older clock: value adds, version keeps max
        let cells = store.read(&[10, 11, 12]);
        assert_eq!(cells[0], Cell { version: 4, value: 1.75 });
        assert_eq!(cells[1], Cell { version: 4, value: -2.0 });
        assert_eq!(cells[2], Cell::default(), "missing key reads as zero");
    }

    #[test]
    fn publish_overwrites() {
        let store = ShardedStore::new(2);
        store.add_deltas(&[(5, 123.0)], 1);
        store.publish(&[(5, 2.5)], 9);
        assert_eq!(store.read(&[5])[0], Cell { version: 9, value: 2.5 });
    }

    #[test]
    fn dense_segment_roundtrip_zero_hash_probes() {
        let store = ShardedStore::with_segments(4, &[(0, 100)]);
        let values: Vec<f64> = (0..100).map(|i| i as f64 * 0.5).collect();
        store.publish_dense(&values, 3);
        store.add_deltas(&[(7, 1.0), (99, -2.0), (0, 0.25)], 5);
        let cells = store.read(&[99, 0, 7, 50]);
        // One epoch version covers the whole segment: the deltas at
        // clock 5 advanced it for every cell, including untouched ones.
        assert_eq!(cells[0], Cell { version: 5, value: 99.0 * 0.5 - 2.0 });
        assert_eq!(cells[1], Cell { version: 5, value: 0.25 });
        assert_eq!(cells[2], Cell { version: 5, value: 3.5 + 1.0 });
        assert_eq!(cells[3], Cell { version: 5, value: 25.0 });
        let range = store.read_range(98, 2);
        assert!(range.is_shared(), "covered range must be a shared epoch view");
        assert_eq!(range.values(), &[49.0f32, 99.0 * 0.5 - 2.0]);
        assert_eq!(range.version(), 5);
        assert_eq!(store.len(), 100, "registered range counts in full");
        assert_eq!(store.hash_probes(), 0, "dense traffic must never hash");
    }

    #[test]
    fn segment_offset_and_epoch_version_roundtrip() {
        let store = ShardedStore::with_segments(4, &[(5, 10)]);
        let values: Vec<f64> = (0..10).map(|i| i as f64).collect();
        store.publish_range(5, &values, 1);
        let all: Vec<usize> = (5..15).collect();
        let cells = store.read(&all);
        for (i, cell) in cells.iter().enumerate() {
            assert_eq!(cell.value, i as f64, "key {}", 5 + i);
            assert_eq!(cell.version, 1);
        }
        // republish at a later version: new epoch, all cells advance
        store.publish_range(7, &[40.0, 41.0], 6);
        let cells = store.read(&[5, 7, 8, 14]);
        assert_eq!(cells[0], Cell { version: 6, value: 0.0 });
        assert_eq!(cells[1], Cell { version: 6, value: 40.0 });
        assert_eq!(cells[2], Cell { version: 6, value: 41.0 });
        assert_eq!(cells[3], Cell { version: 6, value: 9.0 });
        assert_eq!(store.hash_probes(), 0);
        assert_eq!(store.segment_versions(), vec![(5, 10, 6)]);
    }

    #[test]
    fn mixed_dense_and_hashed_keys_route_correctly() {
        let store = ShardedStore::with_segments(4, &[(10, 20)]);
        store.publish(&[(5, 1.0), (15, 2.0), (40, 3.0)], 2);
        let cells = store.read(&[5, 15, 40, 12]);
        assert_eq!(cells[0], Cell { version: 2, value: 1.0 });
        assert_eq!(cells[1], Cell { version: 2, value: 2.0 });
        assert_eq!(cells[2], Cell { version: 2, value: 3.0 });
        // in-segment unpublished key: zero value, but the segment's
        // epoch version (the publish touched its slab)
        assert_eq!(cells[3], Cell { version: 2, value: 0.0 });
        // keys 5 and 40 went through the hashed path (1 write + 1 read
        // probe each); 15 and 12 are epoch slots.
        assert_eq!(store.hash_probes(), 4);
    }

    #[test]
    fn read_spec_serves_ranges_then_keys() {
        let store = ShardedStore::with_segments(2, &[(0, 8)]);
        let values: Vec<f64> = (0..8).map(|i| i as f64).collect();
        store.publish_dense(&values, 1);
        store.publish(&[(100, 42.0)], 1);
        let spec = PullSpec { ranges: vec![(4, 2), (0, 3)], keys: vec![100, 6] };
        assert_eq!(spec.total_len(), 7);
        let pulled = store.read_spec(&spec);
        assert_eq!(pulled.total_cells(), 7);
        assert_eq!(pulled.shared_ranges(), 2, "both ranges covered by the segment");
        assert_eq!(pulled.ranges[0].values(), &[4.0f32, 5.0]);
        assert_eq!(pulled.ranges[0].start(), 4);
        assert_eq!(pulled.ranges[1].values(), &[0.0f32, 1.0, 2.0]);
        let got: Vec<f64> = pulled.cells.iter().map(|c| c.value).collect();
        assert_eq!(got, vec![42.0, 6.0]);
        // shared ranges meter 4 bytes/cell + 8/epoch; keys meter 16
        assert_eq!(pulled.wire_bytes(), (8 + 4 * 2) + (8 + 4 * 3) + 16 * 2);
        assert_eq!(store.hash_probes(), 2, "only key 100's write + read hash");
    }

    #[test]
    fn uncovered_range_read_walks_spans() {
        let store = ShardedStore::with_segments(3, &[(50, 10)]);
        store.publish(&[(48, 1.0), (49, 2.0)], 4);
        store.publish_range(50, &[3.0, 4.0], 6);
        // 48..52 spans a hashed gap and part of the segment: one owned
        // copy; the version is the OLDEST across the parts (the
        // staleness-diagnostic contract), here the hashed cells at 4
        let range = store.read_range(48, 4);
        assert!(!range.is_shared());
        assert!(!range.is_covered());
        assert_eq!(range.values(), &[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(range.version(), 4);
        // a span containing an unpublished hashed key reads as oldest 0
        assert_eq!(store.read_range(47, 5).version(), 0);
        assert!(store.hash_probes() > 0, "keys 48/49 must have hashed");
    }

    #[test]
    fn publish_range_outside_segment_falls_back() {
        let store = ShardedStore::with_segments(3, &[(50, 10)]);
        // spans hashed keys and part of the segment: span decomposition
        store.publish_range(48, &[1.0, 2.0, 3.0, 4.0], 6);
        let cells = store.read(&[48, 49, 50, 51]);
        assert_eq!(cells[0].value, 1.0);
        assert_eq!(cells[1].value, 2.0);
        assert_eq!(cells[2].value, 3.0);
        assert_eq!(cells[3].value, 4.0);
        assert!(store.hash_probes() > 0, "keys 48/49 must have hashed");
    }

    #[test]
    fn held_epoch_views_are_immutable() {
        let store = ShardedStore::with_segments(2, &[(0, 16)]);
        let values: Vec<f64> = (0..16).map(|i| i as f64).collect();
        store.publish_dense(&values, 1);
        let held = store.read_range(0, 16);
        let before: Vec<f32> = held.values().to_vec();
        assert_eq!(store.cow_clones(), 0, "publish with no readers mutates in place");
        // Writers arriving while `held` is alive must clone the epoch.
        store.add_deltas(&[(3, 100.0)], 2);
        store.publish_range(0, &vec![9.0; 16], 3);
        assert_eq!(held.values(), &before[..], "held snapshot must stay bitwise stable");
        assert_eq!(held.version(), 1);
        assert!(store.cow_clones() >= 1, "a reader-held epoch forces a clone");
        assert_eq!(
            store.cow_bytes(),
            store.cow_clones() * 4 * 16,
            "one chunk per segment: every clone copies the whole slab"
        );
        // A fresh pull sees the new epoch.
        let fresh = store.read_range(0, 16);
        assert_eq!(fresh.values()[3], 9.0);
        assert_eq!(fresh.version(), 3);
    }

    #[test]
    #[should_panic(expected = "overlap")]
    fn overlapping_segments_rejected() {
        let _ = ShardedStore::with_segments(2, &[(0, 10), (5, 10)]);
    }

    #[test]
    fn chunked_store_is_observationally_identical() {
        // Same operation stream against chunk_cells = 0 and a 7-cell
        // chunking (deliberately not dividing the segment length):
        // every read must agree bitwise. Chunking changes clone
        // granularity, never values.
        let plain = ShardedStore::with_segments(3, &[(4, 20)]);
        let chunked = ShardedStore::with_segments_chunked(3, &[(4, 20)], 7);
        assert_eq!(chunked.chunk_cells(), 7);
        let seed: Vec<f64> = (0..20).map(|i| (i as f64) * 0.25 - 2.0).collect();
        for store in [&plain, &chunked] {
            store.publish_range(4, &seed, 1);
            store.add_deltas(&[(4, 0.5), (13, -1.5), (23, 2.0), (2, 9.0)], 3);
            store.publish(&[(10, -0.0), (30, 7.5)], 4);
        }
        let keys: Vec<usize> = (0..32).collect();
        let (a, b) = (plain.read(&keys), chunked.read(&keys));
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.value.to_bits(), y.value.to_bits(), "key {i}");
        }
        // whole-segment reads agree bitwise too (one is zero-copy, the
        // other an owned multi-chunk assembly)
        let (ra, rb) = (plain.read_range(4, 20), chunked.read_range(4, 20));
        assert!(ra.is_shared() && !rb.is_shared());
        assert!(rb.is_covered(), "multi-chunk assembly still counts as covered");
        let bits = |r: &RangePull| r.values().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&ra), bits(&rb));
        assert_eq!(chunked.hash_probes(), plain.hash_probes());
    }

    #[test]
    fn chunked_partial_pull_is_zero_copy_within_a_chunk() {
        let store = ShardedStore::with_segments_chunked(2, &[(0, 64)], 16);
        store.publish_dense(&(0..64).map(|i| i as f64).collect::<Vec<_>>(), 1);
        // inside chunk 1 ([16, 32)): shared view of that chunk only
        let r = store.read_range(20, 8);
        assert!(r.is_shared());
        assert_eq!(r.values(), &(20..28).map(|i| i as f32).collect::<Vec<_>>()[..]);
        // crossing the chunk 0/1 boundary: owned assembly, same values
        let r = store.read_range(12, 8);
        assert!(!r.is_shared() && r.is_covered());
        assert_eq!(r.values(), &(12..20).map(|i| i as f32).collect::<Vec<_>>()[..]);
        assert_eq!(r.version(), 1);
        assert_eq!(store.hash_probes(), 0);
    }

    #[test]
    fn chunked_publish_clones_only_touched_chunks() {
        // The tentpole claim: a racing publish under a held reader
        // clones per-chunk, so writes confined to one chunk re-copy
        // chunk_cells * 4 bytes, not the whole segment.
        let store = ShardedStore::with_segments_chunked(2, &[(0, 64)], 16);
        store.publish_dense(&vec![1.0; 64], 1);
        // hold chunk 0's epoch (keys 0..16)
        let held = store.read_range(0, 16);
        assert!(held.is_shared());
        assert_eq!(store.cow_clones(), 0);
        // write into chunk 2 only: no reader holds it -> no clone
        store.add_deltas(&[(40, 1.0)], 2);
        assert_eq!(store.cow_clones(), 0, "untouched-by-readers chunk mutates in place");
        // write into chunk 0: exactly one 16-cell clone
        store.add_deltas(&[(3, 1.0)], 2);
        assert_eq!(store.cow_clones(), 1);
        assert_eq!(store.cow_bytes(), 4 * 16, "clone unit is the chunk, not the segment");
        assert_eq!(held.values(), &[1.0f32; 16][..], "held view stayed bitwise stable");
        // a full-segment publish against the still-held chunk 0 clones
        // chunk 0 again (the other chunks have no holders)
        store.publish_dense(&vec![2.0; 64], 3);
        assert_eq!(store.cow_clones(), 2);
        assert_eq!(store.cow_bytes(), 2 * 4 * 16);
        // per-chunk versions: reads in chunk 1 ([16,32)) saw no write
        // since the seed at 1... except the full publish at 3
        assert_eq!(store.read_range(16, 4).version(), 3);
        assert_eq!(store.segment_versions(), vec![(0, 64, 3)]);
    }

    #[test]
    fn chunked_sparse_publish_leaves_cold_chunk_versions() {
        // Per-chunk epoch versions: a sparse publish bumps only the
        // chunks it lands in, so cold chunks keep their old version
        // (and min_version over a spanning pull reports the oldest).
        let store = ShardedStore::with_segments_chunked(2, &[(0, 32)], 8);
        store.publish_dense(&vec![0.0; 32], 1);
        store.publish(&[(2, 5.0)], 9); // chunk 0 only
        assert_eq!(store.read_range(0, 8).version(), 9);
        assert_eq!(store.read_range(8, 8).version(), 1, "cold chunk keeps its epoch");
        assert_eq!(store.read_range(0, 32).version(), 1, "spanning pull reports oldest");
        assert_eq!(store.segment_versions(), vec![(0, 32, 9)], "freshness view is newest");
    }

    #[test]
    fn publish_range_f32_matches_f64_path_bitwise() {
        let a = ShardedStore::with_segments_chunked(2, &[(3, 10)], 4);
        let b = ShardedStore::with_segments_chunked(2, &[(3, 10)], 4);
        // values that stress the narrowing: -0.0, subnormal, huge
        let vals_f32: Vec<f32> =
            vec![-0.0, 1.0e-40, 3.5, -7.25, f32::MIN_POSITIVE, 1e30, -1.5, 0.0, 2.0, 4.0, 8.0, 9.0];
        let vals_f64: Vec<f64> = vals_f32.iter().map(|&v| v as f64).collect();
        // range 1..13 spans hashed keys 1,2 then the segment 3..13
        a.publish_range(1, &vals_f64, 2);
        b.publish_range_f32(1, &vals_f32, 2);
        let keys: Vec<usize> = (0..14).collect();
        for (i, (x, y)) in a.read(&keys).iter().zip(&b.read(&keys)).enumerate() {
            assert_eq!(x.value.to_bits(), y.value.to_bits(), "key {i}");
            assert_eq!(x.version, y.version, "key {i}");
        }
        assert_eq!(a.hash_probes(), b.hash_probes());
    }

    #[test]
    fn epoch_export_restore_is_bit_exact() {
        let store = ShardedStore::with_segments(4, &[(0, 8)]);
        store.publish_dense(&[0.1, -0.0, 3.5e-7, 4.0, 5.0, 6.0, 7.0, 8.0], 3);
        store.publish(&[(100, 1e-300), (50, -2.5)], 4);
        let images = store.segment_images();
        let cells = store.hashed_cells();
        assert_eq!(cells.iter().map(|&(k, _)| k).collect::<Vec<_>>(), vec![50, 100]);
        let fresh = ShardedStore::with_segments(4, &[(0, 8)]);
        for (start, versions, values) in images {
            assert!(fresh.restore_segment(start, values, &versions));
        }
        fresh.restore_cells(&cells);
        // bitwise: the f32 image and every hashed cell survive intact
        let (orig, back) = (store.read_range(0, 8), fresh.read_range(0, 8));
        let bits = |r: &RangePull| r.values().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&orig), bits(&back));
        assert_eq!(back.version(), 3);
        assert_eq!(fresh.read(&[50, 100]), store.read(&[50, 100]));
        // shape mismatch is refused, not corrupted
        assert!(!fresh.restore_segment(0, vec![0.0; 4], &[1]));
        assert!(!fresh.restore_segment(3, vec![0.0; 8], &[1]));
    }

    #[test]
    fn chunked_export_restore_roundtrips_per_chunk_versions() {
        let store = ShardedStore::with_segments_chunked(2, &[(0, 10)], 4);
        store.publish_dense(&(0..10).map(|i| i as f64 * 1.5).collect::<Vec<_>>(), 2);
        store.publish(&[(9, -0.5)], 7); // bumps only the last (2-cell) chunk
        let images = store.segment_images();
        assert_eq!(images.len(), 1);
        assert_eq!(images[0].1, vec![2, 2, 7], "per-chunk versions survive export");
        let fresh = ShardedStore::with_segments_chunked(2, &[(0, 10)], 4);
        for (start, versions, values) in images {
            assert!(fresh.restore_segment(start, values, &versions));
        }
        assert_eq!(fresh.read_range(8, 2).version(), 7);
        assert_eq!(fresh.read_range(0, 4).version(), 2);
        let bits = |r: &RangePull| r.values().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&store.read_range(0, 10)), bits(&fresh.read_range(0, 10)));
        // a single broadcast version still restores (v1/v2 images)
        let broad = ShardedStore::with_segments_chunked(2, &[(0, 10)], 4);
        assert!(broad.restore_segment(0, vec![1.0; 10], &[5]));
        assert_eq!(broad.read_range(0, 10).version(), 5);
        // chunk-count mismatch is refused
        assert!(!broad.restore_segment(0, vec![1.0; 10], &[1, 2]));
    }
}
