//! The storage layer of the parameter server: versioned cells living in
//! one of two representations behind the same `publish` / `add_deltas`
//! / `read` API.
//!
//! * **Dense segments** — registered contiguous key ranges (the Lasso
//!   residual `0..n`, MF's factor/residual arrays) are range-partitioned
//!   across the shard count as versioned `Vec<Cell>` slabs, each behind
//!   its own `RwLock`. Every key in a segment is addressed by arithmetic
//!   alone and contiguous requests ([`PullSpec`] ranges,
//!   [`ShardedStore::publish_range`]) move as slice copies — zero
//!   hash-map probes on the hot path.
//! * **Hashed shards** — unregistered keys keep the Petuum-style
//!   hash-partitioned maps, each behind its own `RwLock`, so sparse or
//!   unbounded key spaces need no registration.
//!
//! Batched operations group their entries by lock unit (a hashed shard
//! or a dense slab) and take each touched lock exactly once. The
//! [`ShardedStore::hash_probes`] counter meters every probe the hashed
//! path serves, which is how tests pin the "dense traffic never hashes"
//! guarantee.

use crate::util::FastHashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// One versioned parameter cell. `version` is the server round/clock
/// the value was last written at (0 = the initial publish).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cell {
    pub version: u64,
    pub value: f64,
}

/// Fibonacci multiplicative key spreader (same constant as
/// [`crate::util::fasthash`]): dense variable ids would otherwise pile
/// onto one shard under a plain modulus.
const SPREAD: u64 = 0x517cc1b727220a95;

/// One read request: contiguous key ranges plus scattered keys. Ranges
/// over a registered dense segment are served as slab slice copies; the
/// snapshot cell order is all ranges first (in request order), then the
/// scattered keys (in request order). Ranges must be mutually disjoint.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PullSpec {
    /// `(first_key, len)` contiguous runs.
    pub ranges: Vec<(usize, usize)>,
    /// Individually addressed keys.
    pub keys: Vec<usize>,
}

impl PullSpec {
    pub fn from_keys(keys: Vec<usize>) -> Self {
        PullSpec { ranges: Vec::new(), keys }
    }

    pub fn from_ranges(ranges: Vec<(usize, usize)>) -> Self {
        PullSpec { ranges, keys: Vec::new() }
    }

    /// Append a contiguous run (empty runs are dropped).
    pub fn push_range(&mut self, start: usize, len: usize) {
        if len > 0 {
            self.ranges.push((start, len));
        }
    }

    pub fn push_key(&mut self, key: usize) {
        self.keys.push(key);
    }

    /// Total number of cells this spec reads.
    pub fn total_len(&self) -> usize {
        self.ranges.iter().map(|&(_, len)| len).sum::<usize>() + self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty() && self.keys.is_empty()
    }
}

/// One registered contiguous key range, range-partitioned into
/// `chunk`-sized slabs (one per shard; the last may be shorter). Every
/// key in `start..start + len` is slab-addressable by arithmetic alone.
struct DenseSegment {
    start: usize,
    len: usize,
    chunk: usize,
    slabs: Vec<RwLock<Vec<Cell>>>,
}

impl DenseSegment {
    fn new(start: usize, len: usize, num_shards: usize) -> Self {
        debug_assert!(len > 0);
        let chunk = (len + num_shards - 1) / num_shards;
        let num_slabs = (len + chunk - 1) / chunk;
        let slabs = (0..num_slabs)
            .map(|s| {
                let lo = s * chunk;
                let hi = (lo + chunk).min(len);
                RwLock::new(vec![Cell::default(); hi - lo])
            })
            .collect();
        DenseSegment { start, len, chunk, slabs }
    }

    #[inline]
    fn contains(&self, key: usize) -> bool {
        key >= self.start && key < self.start + self.len
    }

    /// Decompose the in-segment range `rel..rel + len` into per-slab
    /// runs, calling `f(slab, slab_offset, run_len, taken_so_far)` for
    /// each — the one place the chunking arithmetic lives.
    fn for_each_slab(&self, rel: usize, len: usize, mut f: impl FnMut(usize, usize, usize, usize)) {
        let end = rel + len;
        let mut rel = rel;
        let mut taken = 0usize;
        while rel < end {
            let slab = rel / self.chunk;
            let off = rel % self.chunk;
            let take = (self.chunk - off).min(end - rel);
            f(slab, off, take, taken);
            rel += take;
            taken += take;
        }
    }
}

/// Where a key lives: a dense slab slot or a hashed shard.
#[derive(Clone, Copy, Debug)]
enum Slot {
    Dense { seg: usize, slab: usize, off: usize },
    Hashed { shard: usize },
}

/// The sharded store. Keys are `usize` parameter ids in a flat,
/// problem-defined key space (see `ModelProblem::ps_state`).
pub struct ShardedStore {
    shards: Vec<RwLock<FastHashMap<usize, Cell>>>,
    /// Registered dense segments, sorted by start, non-overlapping.
    segments: Vec<DenseSegment>,
    /// Probes served by the hashed path (dense-segment traffic never
    /// increments this — the meter behind the zero-probe guarantee).
    hash_probes: AtomicU64,
}

impl ShardedStore {
    pub fn new(num_shards: usize) -> Self {
        Self::with_segments(num_shards, &[])
    }

    /// Build a store with the given `(start, len)` key ranges registered
    /// as dense segments. Ranges must not overlap; zero-length ranges
    /// are ignored. Registration happens at construction so the store
    /// can be shared immutably across worker threads afterwards.
    pub fn with_segments(num_shards: usize, segments: &[(usize, usize)]) -> Self {
        assert!(num_shards >= 1, "need at least one shard");
        let mut segs: Vec<(usize, usize)> =
            segments.iter().copied().filter(|&(_, len)| len > 0).collect();
        segs.sort_unstable_by_key(|&(start, _)| start);
        for w in segs.windows(2) {
            assert!(w[0].0 + w[0].1 <= w[1].0, "dense segments must not overlap");
        }
        ShardedStore {
            shards: (0..num_shards).map(|_| RwLock::new(FastHashMap::default())).collect(),
            segments: segs
                .into_iter()
                .map(|(start, len)| DenseSegment::new(start, len, num_shards))
                .collect(),
            hash_probes: AtomicU64::new(0),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Registered dense segments as `(start, len)` pairs.
    pub fn segments(&self) -> Vec<(usize, usize)> {
        self.segments.iter().map(|s| (s.start, s.len)).collect()
    }

    /// Cumulative hashed-path probe count (reads and writes that went
    /// through a hash map). Dense-segment accesses never count here.
    pub fn hash_probes(&self) -> u64 {
        self.hash_probes.load(Ordering::Relaxed)
    }

    /// Deterministic key -> shard routing (pure function of the key and
    /// the shard count, identical across store instances).
    #[inline]
    pub fn shard_of(&self, key: usize) -> usize {
        (((key as u64).wrapping_mul(SPREAD) >> 32) % self.shards.len() as u64) as usize
    }

    /// Total number of cells across all shards and slabs. Registered
    /// dense ranges count in full: their slots exist from registration.
    pub fn len(&self) -> usize {
        let hashed: usize =
            self.shards.iter().map(|s| s.read().expect("shard lock poisoned").len()).sum();
        hashed + self.segments.iter().map(|s| s.len).sum::<usize>()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resolve a key to its storage slot. Segments are few and sorted,
    /// so this is a short binary search, then arithmetic.
    #[inline]
    fn locate(&self, key: usize) -> Slot {
        let idx = self.segments.partition_point(|s| s.start <= key);
        if idx > 0 {
            let seg = &self.segments[idx - 1];
            if seg.contains(key) {
                let rel = key - seg.start;
                return Slot::Dense { seg: idx - 1, slab: rel / seg.chunk, off: rel % seg.chunk };
            }
        }
        Slot::Hashed { shard: self.shard_of(key) }
    }

    /// Lock-unit id for grouping: hashed shards first, then each
    /// segment's slabs in registration order.
    fn unit_of(&self, slot: Slot) -> usize {
        match slot {
            Slot::Hashed { shard } => shard,
            Slot::Dense { seg, slab, .. } => {
                let mut base = self.shards.len();
                for s in &self.segments[..seg] {
                    base += s.slabs.len();
                }
                base + slab
            }
        }
    }

    fn num_units(&self) -> usize {
        self.shards.len() + self.segments.iter().map(|s| s.slabs.len()).sum::<usize>()
    }

    /// Index of the registered segment fully covering `start..start+len`.
    fn segment_covering(&self, start: usize, len: usize) -> Option<usize> {
        let idx = self.segments.partition_point(|s| s.start <= start);
        if idx == 0 {
            return None;
        }
        let seg = &self.segments[idx - 1];
        (start >= seg.start && start + len <= seg.start + seg.len).then_some(idx - 1)
    }

    /// Overwrite-publish `(key, value)` entries at `version` (the
    /// coordinator's path: seeding the store and republishing derived
    /// state with exact canonical values).
    pub fn publish(&self, entries: &[(usize, f64)], version: u64) {
        self.for_each_slot_mut(
            entries,
            |cell, value| *cell = Cell { version, value },
            |map, key, value| {
                map.insert(key, Cell { version, value });
            },
        );
    }

    /// Overwrite-publish the contiguous range `start..start +
    /// values.len()` at `version`. A range fully inside a registered
    /// segment is written as slab slice fills (zero hash probes); any
    /// other span falls back to the grouped per-key path.
    pub fn publish_range(&self, start: usize, values: &[f64], version: u64) {
        if values.is_empty() {
            return;
        }
        if let Some(seg_idx) = self.segment_covering(start, values.len()) {
            let seg = &self.segments[seg_idx];
            seg.for_each_slab(start - seg.start, values.len(), |slab, off, take, taken| {
                let mut cells = seg.slabs[slab].write().expect("slab lock poisoned");
                for (cell, &value) in
                    cells[off..off + take].iter_mut().zip(&values[taken..taken + take])
                {
                    *cell = Cell { version, value };
                }
            });
            return;
        }
        let entries: Vec<(usize, f64)> =
            values.iter().enumerate().map(|(i, &v)| (start + i, v)).collect();
        self.publish(&entries, version);
    }

    /// Publish a dense state vector: key `i` gets `values[i]` (the
    /// round-0 seed and full-resync path). Grouped per lock unit — each
    /// touched shard or slab lock is taken exactly once.
    pub fn publish_dense(&self, values: &[f64], version: u64) {
        self.publish_range(0, values, version);
    }

    /// Apply additive deltas (the worker push path): `value += delta`,
    /// `version = max(version, at)`. Missing keys start from 0.0 at
    /// version 0, matching an all-zero initial model.
    pub fn add_deltas(&self, deltas: &[(usize, f64)], at: u64) {
        self.for_each_slot_mut(
            deltas,
            |cell, delta| {
                cell.value += delta;
                cell.version = cell.version.max(at);
            },
            |map, key, delta| {
                let cell = map.entry(key).or_default();
                cell.value += delta;
                cell.version = cell.version.max(at);
            },
        );
    }

    /// Read cells for `keys`, preserving request order. Each touched
    /// lock (shard or slab) is taken once per call. Unpublished keys
    /// read as the default cell (value 0.0, version 0).
    pub fn read(&self, keys: &[usize]) -> Vec<Cell> {
        let mut out = vec![Cell::default(); keys.len()];
        self.read_into(keys, &mut out);
        out
    }

    /// Read a full [`PullSpec`]: all ranges (slice-copied where a
    /// registered segment covers them), then the scattered keys.
    pub fn read_spec(&self, spec: &PullSpec) -> Vec<Cell> {
        let mut out = Vec::with_capacity(spec.total_len());
        for &(start, len) in &spec.ranges {
            self.read_range_into(start, len, &mut out);
        }
        if !spec.keys.is_empty() {
            let base = out.len();
            out.resize(base + spec.keys.len(), Cell::default());
            self.read_into(&spec.keys, &mut out[base..]);
        }
        out
    }

    /// Read the contiguous key range `start..start + len`, appending to
    /// `out`. A range fully inside a registered segment is slice-copied
    /// slab by slab; anything else falls back to the per-key path.
    pub fn read_range_into(&self, start: usize, len: usize, out: &mut Vec<Cell>) {
        if len == 0 {
            return;
        }
        if let Some(seg_idx) = self.segment_covering(start, len) {
            let seg = &self.segments[seg_idx];
            seg.for_each_slab(start - seg.start, len, |slab, off, take, _taken| {
                let cells = seg.slabs[slab].read().expect("slab lock poisoned");
                out.extend_from_slice(&cells[off..off + take]);
            });
            return;
        }
        let keys: Vec<usize> = (start..start + len).collect();
        let base = out.len();
        out.resize(base + len, Cell::default());
        self.read_into(&keys, &mut out[base..]);
    }

    /// Grouped positional read: `out[i]` receives the cell for
    /// `keys[i]`.
    fn read_into(&self, keys: &[usize], out: &mut [Cell]) {
        debug_assert_eq!(keys.len(), out.len());
        let mut slots: Vec<Slot> = Vec::with_capacity(keys.len());
        let mut by_unit: Vec<Vec<usize>> = vec![Vec::new(); self.num_units()];
        for (pos, &key) in keys.iter().enumerate() {
            let slot = self.locate(key);
            by_unit[self.unit_of(slot)].push(pos);
            slots.push(slot);
        }
        for positions in by_unit.iter().filter(|p| !p.is_empty()) {
            match slots[positions[0]] {
                Slot::Hashed { shard } => {
                    self.hash_probes.fetch_add(positions.len() as u64, Ordering::Relaxed);
                    let map = self.shards[shard].read().expect("shard lock poisoned");
                    for &pos in positions {
                        if let Some(cell) = map.get(&keys[pos]) {
                            out[pos] = *cell;
                        }
                    }
                }
                Slot::Dense { seg, slab, .. } => {
                    let cells = self.segments[seg].slabs[slab].read().expect("slab lock poisoned");
                    for &pos in positions {
                        let Slot::Dense { off, .. } = slots[pos] else { unreachable!() };
                        out[pos] = cells[off];
                    }
                }
            }
        }
    }

    /// Group `entries` by lock unit (hashed shard or dense slab) and
    /// apply the matching mutator under each unit's write lock, taken
    /// once per touched unit. Within a unit, entries apply in request
    /// order, so duplicate keys resolve identically to a sequential
    /// application.
    fn for_each_slot_mut(
        &self,
        entries: &[(usize, f64)],
        mut dense: impl FnMut(&mut Cell, f64),
        mut hashed: impl FnMut(&mut FastHashMap<usize, Cell>, usize, f64),
    ) {
        let mut slots: Vec<Slot> = Vec::with_capacity(entries.len());
        let mut by_unit: Vec<Vec<usize>> = vec![Vec::new(); self.num_units()];
        for (pos, &(key, _)) in entries.iter().enumerate() {
            let slot = self.locate(key);
            by_unit[self.unit_of(slot)].push(pos);
            slots.push(slot);
        }
        for positions in by_unit.iter().filter(|p| !p.is_empty()) {
            match slots[positions[0]] {
                Slot::Hashed { shard } => {
                    self.hash_probes.fetch_add(positions.len() as u64, Ordering::Relaxed);
                    let mut map = self.shards[shard].write().expect("shard lock poisoned");
                    for &pos in positions {
                        let (key, value) = entries[pos];
                        hashed(&mut map, key, value);
                    }
                }
                Slot::Dense { seg, slab, .. } => {
                    let mut cells =
                        self.segments[seg].slabs[slab].write().expect("slab lock poisoned");
                    for &pos in positions {
                        let Slot::Dense { off, .. } = slots[pos] else { unreachable!() };
                        dense(&mut cells[off], entries[pos].1);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_deterministic_and_total() {
        let a = ShardedStore::new(8);
        let b = ShardedStore::new(8);
        for key in 0..10_000 {
            let s = a.shard_of(key);
            assert_eq!(s, b.shard_of(key), "routing must not depend on the instance");
            assert!(s < 8);
        }
    }

    #[test]
    fn routing_spreads_dense_keys() {
        let store = ShardedStore::new(8);
        let mut counts = [0usize; 8];
        for key in 0..8000 {
            counts[store.shard_of(key)] += 1;
        }
        for (shard, &c) in counts.iter().enumerate() {
            assert!(c > 400, "shard {shard} got only {c}/8000 dense keys");
        }
    }

    #[test]
    fn publish_read_roundtrip_preserves_order() {
        let store = ShardedStore::new(4);
        store.publish_dense(&[1.0, 2.0, 3.0, 4.0], 7);
        let cells = store.read(&[3, 0, 2]);
        assert_eq!(cells[0], Cell { version: 7, value: 4.0 });
        assert_eq!(cells[1], Cell { version: 7, value: 1.0 });
        assert_eq!(cells[2], Cell { version: 7, value: 3.0 });
        assert_eq!(store.len(), 4);
    }

    #[test]
    fn add_deltas_accumulates_and_bumps_version() {
        let store = ShardedStore::new(3);
        store.publish(&[(10, 1.0)], 0);
        store.add_deltas(&[(10, 0.5), (11, -2.0)], 4);
        store.add_deltas(&[(10, 0.25)], 2); // older clock: value adds, version keeps max
        let cells = store.read(&[10, 11, 12]);
        assert_eq!(cells[0], Cell { version: 4, value: 1.75 });
        assert_eq!(cells[1], Cell { version: 4, value: -2.0 });
        assert_eq!(cells[2], Cell::default(), "missing key reads as zero");
    }

    #[test]
    fn publish_overwrites() {
        let store = ShardedStore::new(2);
        store.add_deltas(&[(5, 123.0)], 1);
        store.publish(&[(5, 2.5)], 9);
        assert_eq!(store.read(&[5])[0], Cell { version: 9, value: 2.5 });
    }

    #[test]
    fn dense_segment_roundtrip_zero_hash_probes() {
        let store = ShardedStore::with_segments(4, &[(0, 100)]);
        let values: Vec<f64> = (0..100).map(|i| i as f64 * 0.5).collect();
        store.publish_dense(&values, 3);
        store.add_deltas(&[(7, 1.0), (99, -2.0), (0, 0.25)], 5);
        let cells = store.read(&[99, 0, 7, 50]);
        assert_eq!(cells[0], Cell { version: 5, value: 99.0 * 0.5 - 2.0 });
        assert_eq!(cells[1], Cell { version: 5, value: 0.25 });
        assert_eq!(cells[2], Cell { version: 5, value: 3.5 + 1.0 });
        assert_eq!(cells[3], Cell { version: 3, value: 25.0 });
        let mut range = Vec::new();
        store.read_range_into(98, 2, &mut range);
        assert_eq!(range[0].value, 49.0);
        assert_eq!(range[1].value, 99.0 * 0.5 - 2.0);
        assert_eq!(store.len(), 100, "registered range counts in full");
        assert_eq!(store.hash_probes(), 0, "dense traffic must never hash");
    }

    #[test]
    fn segment_slabs_partition_the_range() {
        // 10 keys over 4 shards -> chunk 3: slabs of 3, 3, 3, 1.
        let store = ShardedStore::with_segments(4, &[(5, 10)]);
        let values: Vec<f64> = (0..10).map(|i| i as f64).collect();
        store.publish_range(5, &values, 1);
        let all: Vec<usize> = (5..15).collect();
        let cells = store.read(&all);
        for (i, cell) in cells.iter().enumerate() {
            assert_eq!(cell.value, i as f64, "key {}", 5 + i);
            assert_eq!(cell.version, 1);
        }
        assert_eq!(store.hash_probes(), 0);
    }

    #[test]
    fn mixed_dense_and_hashed_keys_route_correctly() {
        let store = ShardedStore::with_segments(4, &[(10, 20)]);
        store.publish(&[(5, 1.0), (15, 2.0), (40, 3.0)], 2);
        let cells = store.read(&[5, 15, 40, 12]);
        assert_eq!(cells[0], Cell { version: 2, value: 1.0 });
        assert_eq!(cells[1], Cell { version: 2, value: 2.0 });
        assert_eq!(cells[2], Cell { version: 2, value: 3.0 });
        assert_eq!(cells[3], Cell::default(), "in-segment unpublished key reads as zero");
        // keys 5 and 40 went through the hashed path (1 write + 1 read
        // probe each); 15 and 12 are slab slots.
        assert_eq!(store.hash_probes(), 4);
    }

    #[test]
    fn read_spec_orders_ranges_then_keys() {
        let store = ShardedStore::with_segments(2, &[(0, 8)]);
        let values: Vec<f64> = (0..8).map(|i| i as f64).collect();
        store.publish_dense(&values, 1);
        store.publish(&[(100, 42.0)], 1);
        let spec = PullSpec { ranges: vec![(4, 2), (0, 3)], keys: vec![100, 6] };
        assert_eq!(spec.total_len(), 7);
        let cells = store.read_spec(&spec);
        let got: Vec<f64> = cells.iter().map(|c| c.value).collect();
        assert_eq!(got, vec![4.0, 5.0, 0.0, 1.0, 2.0, 42.0, 6.0]);
        assert_eq!(store.hash_probes(), 2, "only key 100's write + read hash");
    }

    #[test]
    fn publish_range_outside_segment_falls_back() {
        let store = ShardedStore::with_segments(3, &[(50, 10)]);
        // spans hashed keys and part of the segment: per-key fallback
        store.publish_range(48, &[1.0, 2.0, 3.0, 4.0], 6);
        let cells = store.read(&[48, 49, 50, 51]);
        assert_eq!(cells[0].value, 1.0);
        assert_eq!(cells[1].value, 2.0);
        assert_eq!(cells[2].value, 3.0);
        assert_eq!(cells[3].value, 4.0);
        assert!(store.hash_probes() > 0, "keys 48/49 must have hashed");
    }

    #[test]
    #[should_panic(expected = "overlap")]
    fn overlapping_segments_rejected() {
        let _ = ShardedStore::with_segments(2, &[(0, 10), (5, 10)]);
    }
}
