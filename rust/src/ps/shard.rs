//! Hash-partitioned, versioned key-value shards — the storage layer of
//! the parameter server (Petuum-style "sharded key-value store with
//! versioned values"). Each shard is an independent map behind its own
//! `RwLock`, so pulls from disjoint shards never contend and pushes
//! serialize only per shard.

use crate::util::FastHashMap;
use std::sync::RwLock;

/// One versioned parameter cell. `version` is the server round/clock
/// the value was last written at (0 = the initial publish).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cell {
    pub version: u64,
    pub value: f64,
}

/// Fibonacci multiplicative key spreader (same constant as
/// [`crate::util::fasthash`]): dense variable ids would otherwise pile
/// onto one shard under a plain modulus.
const SPREAD: u64 = 0x517cc1b727220a95;

/// The sharded store. Keys are `usize` parameter ids in a flat,
/// problem-defined key space (see `ModelProblem::ps_state`).
pub struct ShardedStore {
    shards: Vec<RwLock<FastHashMap<usize, Cell>>>,
}

impl ShardedStore {
    pub fn new(num_shards: usize) -> Self {
        assert!(num_shards >= 1, "need at least one shard");
        ShardedStore {
            shards: (0..num_shards).map(|_| RwLock::new(FastHashMap::default())).collect(),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Deterministic key -> shard routing (pure function of the key and
    /// the shard count, identical across store instances).
    #[inline]
    pub fn shard_of(&self, key: usize) -> usize {
        (((key as u64).wrapping_mul(SPREAD) >> 32) % self.shards.len() as u64) as usize
    }

    /// Total number of cells across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().expect("shard lock poisoned").len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Overwrite-publish `(key, value)` entries at `version` (the
    /// coordinator's path: seeding the store and republishing derived
    /// state with exact canonical values).
    pub fn publish(&self, entries: &[(usize, f64)], version: u64) {
        self.for_each_shard_mut(entries, |map, key, value| {
            map.insert(key, Cell { version, value });
        });
    }

    /// Publish a dense state vector: key `i` gets `values[i]`.
    pub fn publish_dense(&self, values: &[f64], version: u64) {
        for (key, &value) in values.iter().enumerate() {
            let shard = self.shard_of(key);
            let mut map = self.shards[shard].write().expect("shard lock poisoned");
            map.insert(key, Cell { version, value });
        }
    }

    /// Apply additive deltas (the worker push path): `value += delta`,
    /// `version = max(version, at)`. Missing keys start from 0.0 at
    /// version 0, matching an all-zero initial model.
    pub fn add_deltas(&self, deltas: &[(usize, f64)], at: u64) {
        self.for_each_shard_mut(deltas, |map, key, delta| {
            let cell = map.entry(key).or_default();
            cell.value += delta;
            cell.version = cell.version.max(at);
        });
    }

    /// Read cells for `keys`, preserving request order. Each shard's
    /// read lock is taken once per call. Unpublished keys read as the
    /// default cell (value 0.0, version 0).
    pub fn read(&self, keys: &[usize]) -> Vec<Cell> {
        let mut out = vec![Cell::default(); keys.len()];
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        for (pos, &key) in keys.iter().enumerate() {
            by_shard[self.shard_of(key)].push(pos);
        }
        for (shard, positions) in by_shard.iter().enumerate() {
            if positions.is_empty() {
                continue;
            }
            let map = self.shards[shard].read().expect("shard lock poisoned");
            for &pos in positions {
                if let Some(cell) = map.get(&keys[pos]) {
                    out[pos] = *cell;
                }
            }
        }
        out
    }

    /// Group `entries` by shard and apply `f` under each shard's write
    /// lock (taken once per touched shard).
    fn for_each_shard_mut(
        &self,
        entries: &[(usize, f64)],
        mut f: impl FnMut(&mut FastHashMap<usize, Cell>, usize, f64),
    ) {
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        for (pos, &(key, _)) in entries.iter().enumerate() {
            by_shard[self.shard_of(key)].push(pos);
        }
        for (shard, positions) in by_shard.iter().enumerate() {
            if positions.is_empty() {
                continue;
            }
            let mut map = self.shards[shard].write().expect("shard lock poisoned");
            for &pos in positions {
                let (key, value) = entries[pos];
                f(&mut map, key, value);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_deterministic_and_total() {
        let a = ShardedStore::new(8);
        let b = ShardedStore::new(8);
        for key in 0..10_000 {
            let s = a.shard_of(key);
            assert_eq!(s, b.shard_of(key), "routing must not depend on the instance");
            assert!(s < 8);
        }
    }

    #[test]
    fn routing_spreads_dense_keys() {
        let store = ShardedStore::new(8);
        let mut counts = [0usize; 8];
        for key in 0..8000 {
            counts[store.shard_of(key)] += 1;
        }
        for (shard, &c) in counts.iter().enumerate() {
            assert!(c > 400, "shard {shard} got only {c}/8000 dense keys");
        }
    }

    #[test]
    fn publish_read_roundtrip_preserves_order() {
        let store = ShardedStore::new(4);
        store.publish_dense(&[1.0, 2.0, 3.0, 4.0], 7);
        let cells = store.read(&[3, 0, 2]);
        assert_eq!(cells[0], Cell { version: 7, value: 4.0 });
        assert_eq!(cells[1], Cell { version: 7, value: 1.0 });
        assert_eq!(cells[2], Cell { version: 7, value: 3.0 });
        assert_eq!(store.len(), 4);
    }

    #[test]
    fn add_deltas_accumulates_and_bumps_version() {
        let store = ShardedStore::new(3);
        store.publish(&[(10, 1.0)], 0);
        store.add_deltas(&[(10, 0.5), (11, -2.0)], 4);
        store.add_deltas(&[(10, 0.25)], 2); // older clock: value adds, version keeps max
        let cells = store.read(&[10, 11, 12]);
        assert_eq!(cells[0], Cell { version: 4, value: 1.75 });
        assert_eq!(cells[1], Cell { version: 4, value: -2.0 });
        assert_eq!(cells[2], Cell::default(), "missing key reads as zero");
    }

    #[test]
    fn publish_overwrites() {
        let store = ShardedStore::new(2);
        store.add_deltas(&[(5, 123.0)], 1);
        store.publish(&[(5, 2.5)], 9);
        assert_eq!(store.read(&[5])[0], Cell { version: 9, value: 2.5 });
    }
}
