//! SSP (stale synchronous parallel) clocks, per Petuum: every worker
//! carries a clock it ticks once per round of pushed updates, the
//! server carries an applied-rounds clock, and a pull for worker-round
//! `r` is admitted only while the applied state is at most `s` rounds
//! behind (`r - applied <= s`). `s = 0` degenerates to BSP barriers;
//! [`StalenessPolicy::Async`] removes the gate entirely (Hogwild-style
//! total asynchrony).

use std::sync::{Condvar, Mutex};

/// How stale a pulled snapshot may be, in rounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StalenessPolicy {
    /// SSP with bound `s`: block pulls more than `s` rounds behind.
    Bounded(u64),
    /// Fully asynchronous: never block a pull.
    Async,
}

impl StalenessPolicy {
    /// Parse a CLI/config setting: an integer bound or `async`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "async" | "inf" => Ok(StalenessPolicy::Async),
            n => n
                .parse::<u64>()
                .map(StalenessPolicy::Bounded)
                .map_err(|e| anyhow::anyhow!("--staleness expects an integer or 'async': {e}")),
        }
    }

    pub fn label(&self) -> String {
        match self {
            StalenessPolicy::Bounded(s) => format!("stale={s}"),
            StalenessPolicy::Async => "stale=async".to_string(),
        }
    }

    pub fn bound(&self) -> Option<u64> {
        match self {
            StalenessPolicy::Bounded(s) => Some(*s),
            StalenessPolicy::Async => None,
        }
    }
}

/// Raised when the run is torn down while a worker waits at the gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClockShutdown;

#[derive(Debug)]
struct ClockState {
    /// Per-worker clocks: rounds flushed by each worker so far.
    worker_clocks: Vec<u64>,
    /// Rounds fully applied (and republished) by the server.
    applied: u64,
    /// Set at teardown so gate waiters wake up and exit.
    shutdown: bool,
}

/// The shared clock table: per-worker clocks + the server's applied
/// clock, with a condvar so gate waiters park instead of spinning.
#[derive(Debug)]
pub struct ClockTable {
    state: Mutex<ClockState>,
    advanced: Condvar,
}

impl ClockTable {
    pub fn new(workers: usize) -> Self {
        ClockTable {
            state: Mutex::new(ClockState {
                worker_clocks: vec![0; workers],
                applied: 0,
                shutdown: false,
            }),
            advanced: Condvar::new(),
        }
    }

    /// The pure admission rule (unit-testable core of the gate): a pull
    /// for worker-round `round` against state at `applied` rounds is
    /// admitted iff it is at most `s` rounds stale.
    pub fn admitted(round: u64, applied: u64, policy: StalenessPolicy) -> bool {
        match policy {
            StalenessPolicy::Bounded(s) => round.saturating_sub(applied) <= s,
            StalenessPolicy::Async => true,
        }
    }

    /// Block until a pull for worker-round `round` is admitted under
    /// `policy`. Returns `(staleness_gap, had_to_wait)` where the gap is
    /// `round - applied` observed at admission.
    pub fn wait_admit(
        &self,
        round: u64,
        policy: StalenessPolicy,
    ) -> Result<(u64, bool), ClockShutdown> {
        let mut state = self.state.lock().expect("clock lock poisoned");
        let mut waited = false;
        while !Self::admitted(round, state.applied, policy) {
            if state.shutdown {
                return Err(ClockShutdown);
            }
            waited = true;
            state = self.advanced.wait(state).expect("clock lock poisoned");
        }
        if state.shutdown {
            return Err(ClockShutdown);
        }
        Ok((round.saturating_sub(state.applied), waited))
    }

    /// Record that `worker` flushed its round-`round` updates (the
    /// worker's clock tick).
    pub fn record_flush(&self, worker: usize, round: u64) {
        let mut state = self.state.lock().expect("clock lock poisoned");
        let clock = &mut state.worker_clocks[worker];
        *clock = (*clock).max(round + 1);
    }

    /// Server side: rounds `0..applied` are now applied and republished.
    pub fn advance_applied(&self, applied: u64) {
        let mut state = self.state.lock().expect("clock lock poisoned");
        state.applied = state.applied.max(applied);
        drop(state);
        self.advanced.notify_all();
    }

    pub fn applied(&self) -> u64 {
        self.state.lock().expect("clock lock poisoned").applied
    }

    /// How many worker clocks this table was built for (the TCP server
    /// bounds-checks remote flush worker ids against it).
    pub fn num_workers(&self) -> usize {
        self.state.lock().expect("clock lock poisoned").worker_clocks.len()
    }

    /// Copy of every worker clock (introspection: `strads ps-stats`
    /// shows who the laggard is, not just how far behind it is).
    pub fn worker_clocks(&self) -> Vec<u64> {
        self.state.lock().expect("clock lock poisoned").worker_clocks.clone()
    }

    /// Slowest worker clock (diagnostics; the laggard that SSP protects).
    pub fn min_worker_clock(&self) -> u64 {
        let state = self.state.lock().expect("clock lock poisoned");
        state.worker_clocks.iter().copied().min().unwrap_or(0)
    }

    /// Checkpoint restore: overwrite the table with a saved clock
    /// vector + applied count, then wake any waiters so they re-check
    /// admission against the restored state.
    pub fn restore(&self, worker_clocks: &[u64], applied: u64) {
        let mut state = self.state.lock().expect("clock lock poisoned");
        assert_eq!(
            state.worker_clocks.len(),
            worker_clocks.len(),
            "restore with a different worker count"
        );
        state.worker_clocks.copy_from_slice(worker_clocks);
        state.applied = applied;
        drop(state);
        self.advanced.notify_all();
    }

    /// Wake every gate waiter for teardown.
    pub fn shutdown(&self) {
        let mut state = self.state.lock().expect("clock lock poisoned");
        state.shutdown = true;
        drop(state);
        self.advanced.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn gate_admits_at_exactly_s_and_blocks_past_it() {
        let s = 3u64;
        let policy = StalenessPolicy::Bounded(s);
        // applied = 10: rounds up to 13 are exactly within the bound
        assert!(ClockTable::admitted(10, 10, policy), "fresh pull admitted");
        assert!(ClockTable::admitted(13, 10, policy), "gap == s admitted");
        assert!(!ClockTable::admitted(14, 10, policy), "gap == s+1 must block");
        // s = 0 is a barrier
        let bsp = StalenessPolicy::Bounded(0);
        assert!(ClockTable::admitted(5, 5, bsp));
        assert!(!ClockTable::admitted(6, 5, bsp));
        // async never blocks
        assert!(ClockTable::admitted(1_000_000, 0, StalenessPolicy::Async));
    }

    #[test]
    fn wait_admit_unblocks_when_server_advances() {
        let table = Arc::new(ClockTable::new(1));
        let waiter = {
            let table = Arc::clone(&table);
            std::thread::spawn(move || table.wait_admit(2, StalenessPolicy::Bounded(0)))
        };
        // Round 2 with bound 0 needs applied >= 2.
        table.advance_applied(1);
        std::thread::sleep(std::time::Duration::from_millis(10));
        table.advance_applied(2);
        // (whether the waiter parked depends on thread scheduling; the
        // contract under test is that it returns, with a zero gap)
        let (gap, _waited) = waiter.join().unwrap().expect("no shutdown");
        assert_eq!(gap, 0);
    }

    #[test]
    fn shutdown_releases_waiters() {
        let table = Arc::new(ClockTable::new(1));
        let waiter = {
            let table = Arc::clone(&table);
            std::thread::spawn(move || table.wait_admit(100, StalenessPolicy::Bounded(1)))
        };
        std::thread::sleep(std::time::Duration::from_millis(10));
        table.shutdown();
        assert_eq!(waiter.join().unwrap(), Err(ClockShutdown));
    }

    #[test]
    fn worker_clocks_track_flushes() {
        let table = ClockTable::new(3);
        table.record_flush(0, 4);
        table.record_flush(1, 2);
        assert_eq!(table.min_worker_clock(), 0, "worker 2 has not flushed");
        table.record_flush(2, 0);
        assert_eq!(table.min_worker_clock(), 1);
    }

    #[test]
    fn restore_resumes_where_the_checkpoint_left_off() {
        let table = ClockTable::new(3);
        table.restore(&[5, 4, 6], 4);
        assert_eq!(table.applied(), 4);
        assert_eq!(table.worker_clocks(), vec![5, 4, 6]);
        assert_eq!(table.min_worker_clock(), 4);
        // a pull for round 4 at staleness 0 is admitted immediately
        let (gap, waited) = table.wait_admit(4, StalenessPolicy::Bounded(0)).unwrap();
        assert_eq!((gap, waited), (0, false));
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(StalenessPolicy::parse("0").unwrap(), StalenessPolicy::Bounded(0));
        assert_eq!(StalenessPolicy::parse("8").unwrap(), StalenessPolicy::Bounded(8));
        assert_eq!(StalenessPolicy::parse("async").unwrap(), StalenessPolicy::Async);
        assert!(StalenessPolicy::parse("fast").is_err());
        assert_eq!(StalenessPolicy::Bounded(2).label(), "stale=2");
        assert_eq!(StalenessPolicy::Async.bound(), None);
    }
}
