//! SSP (stale synchronous parallel) clocks, per Petuum: every worker
//! carries a clock it ticks once per round of pushed updates, the
//! server carries an applied-rounds clock, and a pull for worker-round
//! `r` is admitted only while the applied state is at most `s` rounds
//! behind (`r - applied <= s`). `s = 0` degenerates to BSP barriers;
//! [`StalenessPolicy::Async`] removes the gate entirely (Hogwild-style
//! total asynchrony).

use std::sync::{Condvar, Mutex};

/// How stale a pulled snapshot may be, in rounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StalenessPolicy {
    /// SSP with bound `s`: block pulls more than `s` rounds behind.
    Bounded(u64),
    /// Fully asynchronous: never block a pull.
    Async,
}

impl StalenessPolicy {
    /// Parse a CLI/config setting: an integer bound or `async`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "async" | "inf" => Ok(StalenessPolicy::Async),
            n => n
                .parse::<u64>()
                .map(StalenessPolicy::Bounded)
                .map_err(|e| anyhow::anyhow!("--staleness expects an integer or 'async': {e}")),
        }
    }

    pub fn label(&self) -> String {
        match self {
            StalenessPolicy::Bounded(s) => format!("stale={s}"),
            StalenessPolicy::Async => "stale=async".to_string(),
        }
    }

    pub fn bound(&self) -> Option<u64> {
        match self {
            StalenessPolicy::Bounded(s) => Some(*s),
            StalenessPolicy::Async => None,
        }
    }
}

/// Raised when the run is torn down while a worker waits at the gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClockShutdown;

#[derive(Debug)]
struct ClockState {
    /// Per-worker clocks: rounds flushed by each worker so far.
    worker_clocks: Vec<u64>,
    /// Membership: `live[w]` is false once worker `w` has been retired
    /// (left, or was declared dead by the supervisor). Entries are
    /// never removed — ids stay stable — only flipped, so a retired
    /// worker's slot can also be revived by an idempotent re-join.
    live: Vec<bool>,
    /// Rounds fully applied (and republished) by the server.
    applied: u64,
    /// Set at teardown so gate waiters wake up and exit.
    shutdown: bool,
}

/// The shared clock table: per-worker clocks + the server's applied
/// clock, with a condvar so gate waiters park instead of spinning.
#[derive(Debug)]
pub struct ClockTable {
    state: Mutex<ClockState>,
    advanced: Condvar,
}

impl ClockTable {
    pub fn new(workers: usize) -> Self {
        ClockTable {
            state: Mutex::new(ClockState {
                worker_clocks: vec![0; workers],
                live: vec![true; workers],
                applied: 0,
                shutdown: false,
            }),
            advanced: Condvar::new(),
        }
    }

    /// The pure admission rule (unit-testable core of the gate): a pull
    /// for worker-round `round` against state at `applied` rounds is
    /// admitted iff it is at most `s` rounds stale.
    pub fn admitted(round: u64, applied: u64, policy: StalenessPolicy) -> bool {
        match policy {
            StalenessPolicy::Bounded(s) => round.saturating_sub(applied) <= s,
            StalenessPolicy::Async => true,
        }
    }

    /// Block until a pull by `worker` for worker-round `round` is
    /// admitted under `policy`. Returns `(staleness_gap, had_to_wait)`
    /// where the gap is `round - applied` observed at admission. A
    /// worker that has been retired — including one already parked at
    /// the gate when [`ClockTable::retire`] lands — wakes with
    /// `Err(ClockShutdown)` instead of being admitted: the dead never
    /// hold nor take the gate. Ids outside the table (the coordinator
    /// link's diagnostic id) are always treated as live.
    pub fn wait_admit(
        &self,
        worker: usize,
        round: u64,
        policy: StalenessPolicy,
    ) -> Result<(u64, bool), ClockShutdown> {
        let retired =
            |state: &ClockState| worker < state.live.len() && !state.live[worker];
        let mut state = self.state.lock().expect("clock lock poisoned");
        let mut waited = false;
        while !Self::admitted(round, state.applied, policy) {
            if state.shutdown || retired(&state) {
                return Err(ClockShutdown);
            }
            waited = true;
            state = self.advanced.wait(state).expect("clock lock poisoned");
        }
        if state.shutdown || retired(&state) {
            return Err(ClockShutdown);
        }
        Ok((round.saturating_sub(state.applied), waited))
    }

    /// Record that `worker` flushed its round-`round` updates (the
    /// worker's clock tick). Ids outside the table are ignored (the
    /// coordinator link never flushes; remote ids are bounds-checked
    /// before they get here).
    pub fn record_flush(&self, worker: usize, round: u64) {
        let mut state = self.state.lock().expect("clock lock poisoned");
        if let Some(clock) = state.worker_clocks.get_mut(worker) {
            *clock = (*clock).max(round + 1);
        }
    }

    /// Membership: admit worker `worker` (idempotent — a replayed Join
    /// is a no-op). The table grows to cover the id if needed; the
    /// joiner's clock enters at the current frontier (`applied`), so
    /// under any staleness bound its very first pull is gate-legal and
    /// it never drags the diagnostic min-clock below the frontier.
    pub fn join(&self, worker: usize) {
        let mut state = self.state.lock().expect("clock lock poisoned");
        if worker >= state.worker_clocks.len() {
            let frontier = state.applied;
            state.worker_clocks.resize(worker + 1, frontier);
            state.live.resize(worker + 1, true);
        }
        state.live[worker] = true;
        let frontier = state.applied;
        let clock = &mut state.worker_clocks[worker];
        *clock = (*clock).max(frontier);
        drop(state);
        // wake waiters so anyone re-checking membership sees the join
        self.advanced.notify_all();
    }

    /// Membership: retire worker `worker` (left the run, or declared
    /// dead by the supervisor). Idempotent; returns true when this call
    /// flipped a live worker to retired. Wakes every gate waiter so a
    /// parked retired worker exits instead of sleeping forever, and the
    /// gate never parks *on* the dead — admission only reads `applied`,
    /// which the coordinator keeps advancing without the leaver.
    pub fn retire(&self, worker: usize) -> bool {
        let mut state = self.state.lock().expect("clock lock poisoned");
        let flipped = match state.live.get_mut(worker) {
            Some(live) if *live => {
                *live = false;
                true
            }
            _ => false,
        };
        drop(state);
        if flipped {
            self.advanced.notify_all();
        }
        flipped
    }

    /// Is `worker` a live member? Ids outside the table report false
    /// (they were never admitted).
    pub fn is_live(&self, worker: usize) -> bool {
        let state = self.state.lock().expect("clock lock poisoned");
        state.live.get(worker).copied().unwrap_or(false)
    }

    /// How many members are currently live.
    pub fn live_workers(&self) -> usize {
        let state = self.state.lock().expect("clock lock poisoned");
        state.live.iter().filter(|l| **l).count()
    }

    /// Copy of the membership flags (checkpointing; parallel to
    /// [`ClockTable::worker_clocks`]).
    pub fn live_flags(&self) -> Vec<bool> {
        self.state.lock().expect("clock lock poisoned").live.clone()
    }

    /// Server side: rounds `0..applied` are now applied and republished.
    pub fn advance_applied(&self, applied: u64) {
        let mut state = self.state.lock().expect("clock lock poisoned");
        state.applied = state.applied.max(applied);
        drop(state);
        self.advanced.notify_all();
    }

    pub fn applied(&self) -> u64 {
        self.state.lock().expect("clock lock poisoned").applied
    }

    /// How many worker clocks this table was built for (the TCP server
    /// bounds-checks remote flush worker ids against it).
    pub fn num_workers(&self) -> usize {
        self.state.lock().expect("clock lock poisoned").worker_clocks.len()
    }

    /// Copy of every worker clock (introspection: `strads ps-stats`
    /// shows who the laggard is, not just how far behind it is).
    pub fn worker_clocks(&self) -> Vec<u64> {
        self.state.lock().expect("clock lock poisoned").worker_clocks.clone()
    }

    /// Slowest *live* worker clock (diagnostics; the laggard that SSP
    /// protects). Retired workers stop counting the moment they leave —
    /// a dead laggard must not make the fleet look stalled.
    pub fn min_worker_clock(&self) -> u64 {
        let state = self.state.lock().expect("clock lock poisoned");
        state
            .worker_clocks
            .iter()
            .zip(state.live.iter())
            .filter(|(_, live)| **live)
            .map(|(c, _)| *c)
            .min()
            .unwrap_or(0)
    }

    /// Checkpoint restore: overwrite the table with a saved clock
    /// vector + membership + applied count, then wake any waiters so
    /// they re-check admission against the restored state. The saved
    /// census may be larger than the table was built for (workers
    /// joined before the checkpoint) — the table grows to match; it
    /// must never be smaller.
    pub fn restore(&self, worker_clocks: &[u64], live: &[bool], applied: u64) {
        assert_eq!(worker_clocks.len(), live.len(), "clock/membership length mismatch");
        let mut state = self.state.lock().expect("clock lock poisoned");
        assert!(
            worker_clocks.len() >= state.worker_clocks.len(),
            "restore with a smaller worker count"
        );
        state.worker_clocks = worker_clocks.to_vec();
        state.live = live.to_vec();
        state.applied = applied;
        drop(state);
        self.advanced.notify_all();
    }

    /// Wake every gate waiter for teardown.
    pub fn shutdown(&self) {
        let mut state = self.state.lock().expect("clock lock poisoned");
        state.shutdown = true;
        drop(state);
        self.advanced.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn gate_admits_at_exactly_s_and_blocks_past_it() {
        let s = 3u64;
        let policy = StalenessPolicy::Bounded(s);
        // applied = 10: rounds up to 13 are exactly within the bound
        assert!(ClockTable::admitted(10, 10, policy), "fresh pull admitted");
        assert!(ClockTable::admitted(13, 10, policy), "gap == s admitted");
        assert!(!ClockTable::admitted(14, 10, policy), "gap == s+1 must block");
        // s = 0 is a barrier
        let bsp = StalenessPolicy::Bounded(0);
        assert!(ClockTable::admitted(5, 5, bsp));
        assert!(!ClockTable::admitted(6, 5, bsp));
        // async never blocks
        assert!(ClockTable::admitted(1_000_000, 0, StalenessPolicy::Async));
    }

    #[test]
    fn wait_admit_unblocks_when_server_advances() {
        let table = Arc::new(ClockTable::new(1));
        let waiter = {
            let table = Arc::clone(&table);
            std::thread::spawn(move || table.wait_admit(0, 2, StalenessPolicy::Bounded(0)))
        };
        // Round 2 with bound 0 needs applied >= 2.
        table.advance_applied(1);
        std::thread::sleep(std::time::Duration::from_millis(10));
        table.advance_applied(2);
        // (whether the waiter parked depends on thread scheduling; the
        // contract under test is that it returns, with a zero gap)
        let (gap, _waited) = waiter.join().unwrap().expect("no shutdown");
        assert_eq!(gap, 0);
    }

    #[test]
    fn shutdown_releases_waiters() {
        let table = Arc::new(ClockTable::new(1));
        let waiter = {
            let table = Arc::clone(&table);
            std::thread::spawn(move || table.wait_admit(0, 100, StalenessPolicy::Bounded(1)))
        };
        std::thread::sleep(std::time::Duration::from_millis(10));
        table.shutdown();
        assert_eq!(waiter.join().unwrap(), Err(ClockShutdown));
    }

    #[test]
    fn joiner_enters_at_the_frontier_and_is_gate_legal() {
        let table = ClockTable::new(2);
        table.record_flush(0, 4);
        table.record_flush(1, 4);
        table.advance_applied(5);
        table.join(2);
        assert_eq!(table.num_workers(), 3);
        assert!(table.is_live(2));
        assert_eq!(table.worker_clocks()[2], 5, "joiner clock starts at the frontier");
        assert_eq!(table.min_worker_clock(), 5, "joiner does not look like a laggard");
        // Even at staleness 0 the joiner's first pull (for the current
        // frontier round) is admitted without waiting.
        let (gap, waited) = table.wait_admit(2, 5, StalenessPolicy::Bounded(0)).unwrap();
        assert_eq!((gap, waited), (0, false));
        // join is idempotent: a replayed Join changes nothing
        table.join(2);
        assert_eq!(table.num_workers(), 3);
        assert_eq!(table.worker_clocks()[2], 5);
    }

    #[test]
    fn retire_wakes_a_parked_waiter_and_fences_membership() {
        let table = Arc::new(ClockTable::new(2));
        let waiter = {
            let table = Arc::clone(&table);
            // round 100 at bound 0 can never be admitted here: parked
            std::thread::spawn(move || table.wait_admit(1, 100, StalenessPolicy::Bounded(0)))
        };
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(table.retire(1), "first retire flips the flag");
        assert_eq!(waiter.join().unwrap(), Err(ClockShutdown), "parked leaver wakes");
        assert!(!table.retire(1), "retire is idempotent");
        assert!(!table.is_live(1));
        assert_eq!(table.live_workers(), 1);
        assert_eq!(table.live_flags(), vec![true, false]);
        // a retired worker is refused at the gate even when admissible
        table.advance_applied(200);
        assert_eq!(
            table.wait_admit(1, 200, StalenessPolicy::Bounded(0)),
            Err(ClockShutdown)
        );
        // ...while the survivor and the out-of-range coordinator id pass
        assert!(table.wait_admit(0, 200, StalenessPolicy::Bounded(0)).is_ok());
        assert!(table.wait_admit(usize::MAX, 200, StalenessPolicy::Bounded(0)).is_ok());
    }

    #[test]
    fn min_worker_clock_skips_the_retired() {
        let table = ClockTable::new(3);
        table.record_flush(0, 9);
        table.record_flush(2, 7);
        // worker 1 never flushed; once retired it stops dragging the min
        assert_eq!(table.min_worker_clock(), 0);
        table.retire(1);
        assert_eq!(table.min_worker_clock(), 8);
    }

    #[test]
    fn worker_clocks_track_flushes() {
        let table = ClockTable::new(3);
        table.record_flush(0, 4);
        table.record_flush(1, 2);
        assert_eq!(table.min_worker_clock(), 0, "worker 2 has not flushed");
        table.record_flush(2, 0);
        assert_eq!(table.min_worker_clock(), 1);
    }

    #[test]
    fn restore_resumes_where_the_checkpoint_left_off() {
        let table = ClockTable::new(3);
        table.restore(&[5, 4, 6], &[true, true, true], 4);
        assert_eq!(table.applied(), 4);
        assert_eq!(table.worker_clocks(), vec![5, 4, 6]);
        assert_eq!(table.min_worker_clock(), 4);
        // a pull for round 4 at staleness 0 is admitted immediately
        let (gap, waited) = table.wait_admit(1, 4, StalenessPolicy::Bounded(0)).unwrap();
        assert_eq!((gap, waited), (0, false));
        // a checkpoint from after a mid-run join grows the table
        let table = ClockTable::new(2);
        table.restore(&[5, 4, 6], &[true, false, true], 4);
        assert_eq!(table.num_workers(), 3);
        assert!(!table.is_live(1));
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(StalenessPolicy::parse("0").unwrap(), StalenessPolicy::Bounded(0));
        assert_eq!(StalenessPolicy::parse("8").unwrap(), StalenessPolicy::Bounded(8));
        assert_eq!(StalenessPolicy::parse("async").unwrap(), StalenessPolicy::Async);
        assert!(StalenessPolicy::parse("fast").is_err());
        assert_eq!(StalenessPolicy::Bounded(2).label(), "stale=2");
        assert_eq!(StalenessPolicy::Async.bound(), None);
    }
}
