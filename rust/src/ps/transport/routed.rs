//! Multi-server routing: one [`Transport`] fanned out over N inner
//! transports, each carrying one server's share of the key space.
//!
//! [`RouteMap`] is the shard→server assignment: every registered dense
//! segment is split into N contiguous sub-segments (server `i` hosts
//! the `i`-th), and unregistered (hashed) keys go to
//! `fibhash(key) % N`. Each server is then a completely ordinary
//! `ps-server` hosting only its own sub-segments — checkpoints,
//! compression maps, retry wrappers, and fault plans all apply
//! per-server with no routing-specific code on the server side.
//!
//! [`RoutedTransport`] does the carriage work:
//! * **pull** — each requested range is decomposed into maximal
//!   single-owner pieces (sub-segment stretches become per-server
//!   sub-ranges; hashed gap keys become per-key cell requests to their
//!   hash owner), the fragments are pulled over the per-server links,
//!   and the replies are reassembled positionally into exactly one
//!   [`RangePull`] per requested range with the min version across
//!   fragments — the same oldest-across-the-span contract the
//!   single-server store provides.
//! * **flush / advance / join / leave** — broadcast to *every* server
//!   (a flush carries each server its owned delta subset, possibly
//!   empty) so the N per-server SSP clocks stay in lock-step: the
//!   logical clock of the fleet is the fold of the per-server gates,
//!   and at staleness 0 every server admits exactly the rounds the
//!   single server would. The flush verdict is the AND across servers.
//! * **publish / publish_range** — partitioned by owner; only owners
//!   with a non-empty share are called.
//! * **stats / obs_stats** — per-server snapshots folded into one
//!   fleet view (sums, with `max_stale_gap` as a max, clock state as
//!   the min across servers).
//!
//! Because every key has exactly one owner and the per-server clocks
//! tick in lock-step, the values a client reads through the routed
//! transport are bitwise identical to the single-server ones — pinned
//! at N=1 vs N=2 vs in-process by `tests/ps_routed.rs`.

use super::{PullReply, Transport, TransportError};
use crate::obs::{ClockView, MetricValue, ObsSnapshot};
use crate::ps::shard::{Cell, PullSpec, RangePull};
use crate::ps::StatsSnapshot;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Fibonacci multiplicative key spreader — the same constant the
/// store's hashed shards use, so gap keys spread evenly over servers.
const SPREAD: u64 = 0x517cc1b727220a95;

/// One maximal single-owner piece of a contiguous key range.
enum Piece {
    /// `len` keys starting at `start`, all inside server `server`'s
    /// sub-segment.
    Run { server: usize, start: usize, len: usize },
    /// One unregistered key, owned by hash.
    Key { server: usize, key: usize },
}

/// The shard→server assignment of a routed fleet. Built once per run
/// from the problem's registered segments and the server count; shared
/// (`Arc`) by every link the connection mints.
#[derive(Clone, Debug)]
pub struct RouteMap {
    servers: usize,
    /// `(start, len, server)` sorted by `start`: the contiguous
    /// sub-segments the run's registered segments were split into.
    segs: Vec<(usize, usize, usize)>,
}

impl RouteMap {
    /// Split `segments` across `servers`: each segment is cut into
    /// `servers` contiguous parts (ceil-split — the first `len %
    /// servers` parts get one extra cell), server `i` hosting the
    /// `i`-th part. Zero-length parts (more servers than cells) are
    /// dropped, so a tiny segment simply lives on fewer servers.
    pub fn new(segments: &[(usize, usize)], servers: usize) -> Self {
        assert!(servers > 0, "a route needs at least one server");
        let mut segs = Vec::with_capacity(segments.len() * servers);
        for &(start, len) in segments {
            let base = len / servers;
            let rem = len % servers;
            let mut at = start;
            for server in 0..servers {
                let take = base + usize::from(server < rem);
                if take > 0 {
                    segs.push((at, take, server));
                    at += take;
                }
            }
            debug_assert_eq!(at, start + len);
        }
        segs.sort_unstable();
        RouteMap { servers, segs }
    }

    /// Fleet size.
    pub fn servers(&self) -> usize {
        self.servers
    }

    /// The sub-segments server `i` hosts — what its `Init` registers
    /// and its checkpoint dumps.
    pub fn server_segments(&self, server: usize) -> Vec<(usize, usize)> {
        self.segs
            .iter()
            .filter(|&&(_, _, s)| s == server)
            .map(|&(start, len, _)| (start, len))
            .collect()
    }

    /// Which server owns `key`: its sub-segment's host for registered
    /// keys, the Fibonacci-hash bucket for the rest.
    pub fn owner_of(&self, key: usize) -> usize {
        let i = self.segs.partition_point(|&(start, len, _)| start + len <= key);
        if let Some(&(start, _, server)) = self.segs.get(i) {
            if start <= key {
                return server;
            }
        }
        self.hash_owner(key)
    }

    #[inline]
    fn hash_owner(&self, key: usize) -> usize {
        (((key as u64).wrapping_mul(SPREAD) >> 32) % self.servers as u64) as usize
    }

    /// Walk `[start, start + len)` as maximal single-owner pieces, in
    /// key order: sub-segment overlaps come out as one `Run` per
    /// (sub-segment ∩ range), hashed gaps as one `Key` per key.
    fn for_each_piece(&self, start: usize, len: usize, mut f: impl FnMut(Piece)) {
        let end = start + len;
        let mut key = start;
        let mut i = self.segs.partition_point(|&(s, l, _)| s + l <= start);
        while key < end {
            match self.segs.get(i) {
                Some(&(s, l, server)) if s <= key => {
                    let take = (s + l).min(end) - key;
                    f(Piece::Run { server, start: key, len: take });
                    key += take;
                    if key >= s + l {
                        i += 1;
                    }
                }
                seg => {
                    let gap_end = seg.map_or(end, |&(s, _, _)| s.min(end));
                    for k in key..gap_end {
                        f(Piece::Key { server: self.hash_owner(k), key: k });
                    }
                    key = gap_end;
                }
            }
        }
    }
}

/// Where one fragment of a split pull lands in the merged reply.
enum CellDst {
    /// A hashed gap key inside requested range `range`, at `offset`.
    Range { range: usize, offset: usize },
    /// The caller's scattered key number `idx`.
    Cell { idx: usize },
}

/// One server's share of a split [`PullSpec`], plus the placement map
/// that reassembles its reply.
#[derive(Default)]
struct SubSpec {
    spec: PullSpec,
    /// Per `spec.ranges` entry: destination `(range, offset)` in the
    /// merged reply.
    range_dst: Vec<(usize, usize)>,
    /// Per `spec.keys` entry: destination in the merged reply.
    key_dst: Vec<CellDst>,
}

/// N per-server links behind one [`Transport`]. See the module docs
/// for the split/merge and clock-fold contracts.
pub struct RoutedTransport {
    inner: Vec<Box<dyn Transport>>,
    route: Arc<RouteMap>,
    /// Inner RPCs issued by this link's fan-out — `route.fanout_rpcs`.
    fanout_rpcs: Arc<AtomicU64>,
}

impl RoutedTransport {
    /// Wrap `inner[i]` as the link to server `i` of `route`.
    pub fn new(
        inner: Vec<Box<dyn Transport>>,
        route: Arc<RouteMap>,
        fanout_rpcs: Arc<AtomicU64>,
    ) -> Self {
        assert_eq!(inner.len(), route.servers(), "one inner link per routed server");
        RoutedTransport { inner, route, fanout_rpcs }
    }

    fn rpc(&self) {
        self.fanout_rpcs.fetch_add(1, Ordering::Relaxed);
    }

    /// Split `spec` by owning server. Ranges decompose into sub-ranges
    /// (sub-segment stretches) plus per-key cell requests (hashed
    /// gaps); scattered keys go to their owner as keys.
    fn split_spec(&self, spec: &PullSpec) -> Vec<SubSpec> {
        let mut subs: Vec<SubSpec> = (0..self.route.servers()).map(|_| SubSpec::default()).collect();
        for (range, &(start, len)) in spec.ranges.iter().enumerate() {
            self.route.for_each_piece(start, len, |piece| match piece {
                Piece::Run { server, start: s, len: l } => {
                    subs[server].spec.push_range(s, l);
                    subs[server].range_dst.push((range, s - start));
                }
                Piece::Key { server, key } => {
                    subs[server].spec.push_key(key);
                    subs[server].key_dst.push(CellDst::Range { range, offset: key - start });
                }
            });
        }
        for (idx, &key) in spec.keys.iter().enumerate() {
            let server = self.route.owner_of(key);
            subs[server].spec.push_key(key);
            subs[server].key_dst.push(CellDst::Cell { idx });
        }
        subs
    }
}

impl Transport for RoutedTransport {
    fn pull(&mut self, spec: &PullSpec, round: u64) -> Result<PullReply, TransportError> {
        let subs = self.split_spec(spec);
        // Merged scaffolding: one owned image per requested range
        // (version starts at MAX and min-folds over the fragments —
        // zero-cell ranges fall back to 0, like the store's own
        // oldest-across-the-span read).
        let mut ranges: Vec<(u64, Vec<f32>)> =
            spec.ranges.iter().map(|&(_, len)| (u64::MAX, vec![0.0f32; len])).collect();
        let mut cells = vec![Cell::default(); spec.keys.len()];
        let (mut gap, mut waited, mut gate_us) = (0u64, false, 0u64);
        // An all-empty spec still has to consult (and possibly block
        // at) the SSP gate, like a single server would: send it to
        // server 0.
        let involved = subs.iter().any(|s| !s.spec.is_empty());
        for (server, sub) in subs.iter().enumerate() {
            if sub.spec.is_empty() && (involved || server != 0) {
                continue;
            }
            let reply = self.inner[server].pull(&sub.spec, round)?;
            self.rpc();
            gap = gap.max(reply.gap);
            waited |= reply.waited;
            gate_us += reply.gate_us;
            // Fragments come back in request order: ranges, then keys.
            for (frag, &(dst, off)) in reply.ranges.iter().zip(&sub.range_dst) {
                let (version, out) = &mut ranges[dst];
                out[off..off + frag.len()].copy_from_slice(frag.values());
                *version = (*version).min(frag.version());
            }
            for (cell, dst) in reply.cells.iter().zip(&sub.key_dst) {
                match *dst {
                    CellDst::Range { range, offset } => {
                        let (version, out) = &mut ranges[range];
                        out[offset] = cell.value as f32;
                        *version = (*version).min(cell.version);
                    }
                    CellDst::Cell { idx } => cells[idx] = *cell,
                }
            }
        }
        let ranges = spec
            .ranges
            .iter()
            .zip(ranges)
            .map(|(&(start, _), (version, values))| {
                RangePull::owned(start, if version == u64::MAX { 0 } else { version }, values)
            })
            .collect();
        Ok(PullReply { ranges, cells, gap, waited, gate_us })
    }

    fn flush(
        &mut self,
        deltas: &[(usize, f64)],
        round: u64,
        block: u64,
    ) -> Result<bool, TransportError> {
        let mut parts: Vec<Vec<(usize, f64)>> = vec![Vec::new(); self.route.servers()];
        for &(key, value) in deltas {
            parts[self.route.owner_of(key)].push((key, value));
        }
        // Broadcast — even empty shares — so every server's clock
        // ticks this worker's round and the fleet's gates stay in
        // lock-step. The verdict is the AND: the (round, block)
        // ledgers advance identically on every server, so a drop on
        // one is a drop on all.
        let mut applied = true;
        for (server, part) in parts.iter().enumerate() {
            applied &= self.inner[server].flush(part, round, block)?;
            self.rpc();
        }
        Ok(applied)
    }

    fn join(&mut self, worker: usize) -> Result<(), TransportError> {
        for link in &mut self.inner {
            link.join(worker)?;
            self.fanout_rpcs.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    fn leave(&mut self, worker: usize) -> Result<(), TransportError> {
        for link in &mut self.inner {
            link.leave(worker)?;
            self.fanout_rpcs.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    fn publish(
        &mut self,
        entries: &[(usize, f64)],
        version: u64,
    ) -> Result<(), TransportError> {
        let mut parts: Vec<Vec<(usize, f64)>> = vec![Vec::new(); self.route.servers()];
        for &(key, value) in entries {
            parts[self.route.owner_of(key)].push((key, value));
        }
        for (server, part) in parts.iter().enumerate() {
            if !part.is_empty() {
                self.inner[server].publish(part, version)?;
                self.rpc();
            }
        }
        Ok(())
    }

    fn publish_range(
        &mut self,
        start: usize,
        values: &[f64],
        version: u64,
    ) -> Result<(), TransportError> {
        let route = Arc::clone(&self.route);
        let mut runs = Vec::new();
        let mut gaps: Vec<Vec<(usize, f64)>> = vec![Vec::new(); route.servers()];
        route.for_each_piece(start, values.len(), |piece| match piece {
            Piece::Run { server, start: s, len } => runs.push((server, s, len)),
            Piece::Key { server, key } => gaps[server].push((key, values[key - start])),
        });
        for (server, s, len) in runs {
            self.inner[server].publish_range(s, &values[s - start..s - start + len], version)?;
            self.rpc();
        }
        for (server, part) in gaps.iter().enumerate() {
            if !part.is_empty() {
                self.inner[server].publish(part, version)?;
                self.rpc();
            }
        }
        Ok(())
    }

    fn publish_range_f32(
        &mut self,
        start: usize,
        values: &[f32],
        version: u64,
    ) -> Result<(), TransportError> {
        let route = Arc::clone(&self.route);
        let mut runs = Vec::new();
        let mut gaps: Vec<Vec<(usize, f64)>> = vec![Vec::new(); route.servers()];
        route.for_each_piece(start, values.len(), |piece| match piece {
            Piece::Run { server, start: s, len } => runs.push((server, s, len)),
            // Hashed cells store full f64 either way, so widening here
            // matches what the store's own f32 seed path does to them.
            Piece::Key { server, key } => gaps[server].push((key, values[key - start] as f64)),
        });
        for (server, s, len) in runs {
            self.inner[server].publish_range_f32(
                s,
                &values[s - start..s - start + len],
                version,
            )?;
            self.rpc();
        }
        for (server, part) in gaps.iter().enumerate() {
            if !part.is_empty() {
                self.inner[server].publish(part, version)?;
                self.rpc();
            }
        }
        Ok(())
    }

    fn advance_applied(&mut self, applied: u64) -> Result<(), TransportError> {
        for link in &mut self.inner {
            link.advance_applied(applied)?;
            self.fanout_rpcs.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    fn stats(&mut self) -> Result<StatsSnapshot, TransportError> {
        let mut acc = StatsSnapshot::default();
        for link in &mut self.inner {
            let s = link.stats()?;
            acc.bytes_flushed += s.bytes_flushed;
            acc.bytes_republished += s.bytes_republished;
            acc.bytes_pulled += s.bytes_pulled;
            acc.cells_pulled += s.cells_pulled;
            acc.snapshot_clones += s.snapshot_clones;
            acc.flushes += s.flushes;
            acc.pulls += s.pulls;
            acc.stale_gap_sum += s.stale_gap_sum;
            acc.max_stale_gap = acc.max_stale_gap.max(s.max_stale_gap);
            acc.gate_waits += s.gate_waits;
            acc.flushes_dropped += s.flushes_dropped;
            acc.hash_probes += s.hash_probes;
            acc.cow_clones += s.cow_clones;
            acc.cow_bytes += s.cow_bytes;
        }
        Ok(acc)
    }

    fn obs_stats(&mut self) -> Result<ObsSnapshot, TransportError> {
        let mut snaps = Vec::with_capacity(self.inner.len());
        for link in &mut self.inner {
            snaps.push(link.obs_stats()?);
        }
        Ok(merge_obs(snaps))
    }

    fn shutdown_clock(&mut self) -> Result<(), TransportError> {
        for link in &mut self.inner {
            link.shutdown_clock()?;
            self.fanout_rpcs.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }
}

/// Fold per-server introspection snapshots into one fleet view:
/// metrics sum by name (`route.index` is dropped — it differs by
/// construction; `route.servers` takes the max), segments concatenate
/// (disjoint sub-segments of a disjoint fleet) and sort, and the clock
/// folds to the most conservative reading — `applied` and each worker
/// clock as the min across servers, which is the gate the slowest
/// server enforces.
fn merge_obs(snaps: Vec<ObsSnapshot>) -> ObsSnapshot {
    let mut out = ObsSnapshot {
        version: snaps.first().map_or(0, |s| s.version),
        metrics: Vec::new(),
        segments: Vec::new(),
        clock: None,
    };
    for snap in snaps {
        for (name, value) in snap.metrics {
            if name == "route.index" {
                continue;
            }
            match out.metrics.iter_mut().find(|(n, _)| *n == name) {
                Some((_, acc)) => merge_metric(&name, acc, value),
                None => out.metrics.push((name, value)),
            }
        }
        out.segments.extend(snap.segments);
        out.clock = match (out.clock.take(), snap.clock) {
            (Some(a), Some(b)) => Some(ClockView {
                applied: a.applied.min(b.applied),
                staleness_bound: a.staleness_bound,
                worker_clocks: a
                    .worker_clocks
                    .iter()
                    .zip(&b.worker_clocks)
                    .map(|(&x, &y)| x.min(y))
                    .collect(),
            }),
            (a, b) => a.or(b),
        };
    }
    out.metrics.sort_by(|a, b| a.0.cmp(&b.0));
    out.segments.sort_unstable();
    out
}

fn merge_metric(name: &str, acc: &mut MetricValue, incoming: MetricValue) {
    match (acc, incoming) {
        (MetricValue::Counter(a), MetricValue::Counter(b)) => *a += b,
        (MetricValue::Gauge(a), MetricValue::Gauge(b)) => {
            if name == "route.servers" {
                *a = (*a).max(b);
            } else {
                *a += b;
            }
        }
        (
            MetricValue::Histogram { bounds, counts, sum, count },
            MetricValue::Histogram { bounds: b2, counts: c2, sum: s2, count: n2 },
        ) if *bounds == b2 && counts.len() == c2.len() => {
            for (a, b) in counts.iter_mut().zip(c2) {
                *a += b;
            }
            *sum += s2;
            *count += n2;
        }
        // Mismatched kinds/shapes: keep the first reading.
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::transport::InProcTransport;
    use crate::ps::{ParameterServer, StalenessPolicy};

    #[test]
    fn route_map_ceil_splits_segments_and_hashes_gaps() {
        let route = RouteMap::new(&[(0, 100), (200, 51)], 2);
        assert_eq!(route.servers(), 2);
        assert_eq!(route.server_segments(0), vec![(0, 50), (200, 26)]);
        assert_eq!(route.server_segments(1), vec![(50, 50), (226, 25)]);
        assert_eq!(route.owner_of(0), 0);
        assert_eq!(route.owner_of(49), 0);
        assert_eq!(route.owner_of(50), 1);
        assert_eq!(route.owner_of(99), 1);
        assert_eq!(route.owner_of(200), 0);
        assert_eq!(route.owner_of(226), 1);
        // gap keys spread over both servers
        let owners: std::collections::HashSet<usize> =
            (1000..1100).map(|k| route.owner_of(k)).collect();
        assert_eq!(owners.len(), 2, "hash fallback must use the whole fleet");
        // the degenerate single-server route owns everything
        let one = RouteMap::new(&[(0, 10)], 1);
        assert_eq!(one.server_segments(0), vec![(0, 10)]);
        for k in [0, 5, 9, 12345] {
            assert_eq!(one.owner_of(k), 0);
        }
    }

    #[test]
    fn tiny_segments_drop_empty_shares() {
        // 4 servers, 2 cells: only the first two get a share.
        let route = RouteMap::new(&[(10, 2)], 4);
        assert_eq!(route.server_segments(0), vec![(10, 1)]);
        assert_eq!(route.server_segments(1), vec![(11, 1)]);
        assert!(route.server_segments(2).is_empty());
        assert!(route.server_segments(3).is_empty());
    }

    fn fleet(
        segments: &[(usize, usize)],
        servers: usize,
        workers: usize,
    ) -> (RoutedTransport, Vec<Arc<ParameterServer>>, Arc<RouteMap>) {
        let route = Arc::new(RouteMap::new(segments, servers));
        let hosts: Vec<Arc<ParameterServer>> = (0..servers)
            .map(|i| {
                Arc::new(ParameterServer::with_segments(
                    2,
                    workers,
                    StalenessPolicy::Bounded(0),
                    &route.server_segments(i),
                ))
            })
            .collect();
        let inner: Vec<Box<dyn Transport>> = hosts
            .iter()
            .map(|h| Box::new(InProcTransport::new(Arc::clone(h), 0)) as Box<dyn Transport>)
            .collect();
        let routed =
            RoutedTransport::new(inner, Arc::clone(&route), Arc::new(AtomicU64::new(0)));
        (routed, hosts, route)
    }

    #[test]
    fn split_pull_reassembles_ranges_and_cells_bitwise() {
        let (mut routed, _hosts, _route) = fleet(&[(0, 16)], 2, 1);
        let values: Vec<f64> = (0..16).map(|i| i as f64 * 0.5).collect();
        routed.publish_range(0, &values, 0).unwrap();
        routed.publish(&[(100, 42.0), (101, -7.0)], 0).unwrap();
        let spec = PullSpec { ranges: vec![(4, 9)], keys: vec![101, 100] };
        let reply = routed.pull(&spec, 0).unwrap();
        assert_eq!(reply.ranges.len(), 1);
        assert_eq!(reply.ranges[0].start(), 4);
        let want: Vec<u32> = (4..13).map(|i| ((i as f32) * 0.5).to_bits()).collect();
        let got: Vec<u32> = reply.ranges[0].values().iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want, "range spanning the server cut must reassemble bitwise");
        assert_eq!(reply.cells[0].value, -7.0, "cells come back in request-key order");
        assert_eq!(reply.cells[1].value, 42.0);
    }

    #[test]
    fn pull_merges_hashed_gap_keys_into_the_range() {
        // range 48..58 covers a hashed gap (48, 49) plus the segment
        let (mut routed, _hosts, _route) = fleet(&[(50, 10)], 2, 1);
        routed.publish(&[(48, 1.0), (49, 2.0)], 0).unwrap();
        routed.publish_range(50, &[3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0, 11.0, 12.0], 0).unwrap();
        let reply = routed.pull(&PullSpec::from_ranges(vec![(48, 6)]), 0).unwrap();
        assert_eq!(reply.ranges[0].values(), &[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn flush_broadcasts_and_folds_the_verdict() {
        let (mut routed, hosts, route) = fleet(&[(0, 8)], 2, 1);
        assert!(routed.flush(&[(1, 0.5), (6, -0.5)], 0, 3).unwrap());
        // every server ticked worker 0's clock, owners got their share
        for host in &hosts {
            assert_eq!(host.clock().worker_clocks()[0], 1);
        }
        assert_eq!(hosts[route.owner_of(1)].store().read(&[1])[0].value, 0.5);
        assert_eq!(hosts[route.owner_of(6)].store().read(&[6])[0].value, -0.5);
        // a replayed (round, block) is dropped by every ledger: AND = false
        assert!(!routed.flush(&[(1, 0.5)], 0, 3).unwrap());
    }

    #[test]
    fn stats_and_obs_fold_across_the_fleet() {
        let (mut routed, _hosts, _route) = fleet(&[(0, 8)], 2, 1);
        routed.publish_range(0, &[1.0; 8], 0).unwrap();
        routed.advance_applied(0).unwrap();
        routed.pull(&PullSpec::from_ranges(vec![(0, 8)]), 0).unwrap();
        let stats = routed.stats().unwrap();
        assert_eq!(stats.pulls, 2, "one pull per involved server");
        assert_eq!(stats.cells_pulled, 8, "each cell pulled exactly once");
        let snap = routed.obs_stats().unwrap();
        assert_eq!(snap.get("ps.pulls").unwrap().as_u64(), 2);
        assert_eq!(
            snap.segments.iter().map(|&(s, l, _)| (s, l)).collect::<Vec<_>>(),
            vec![(0, 4), (4, 4)],
            "fleet segments concatenate sorted"
        );
        let clock = snap.clock.as_ref().expect("merged clock");
        assert_eq!(clock.applied, 0);
    }

    #[test]
    fn empty_pull_still_consults_one_gate() {
        let (mut routed, hosts, _route) = fleet(&[(0, 4)], 2, 1);
        routed.pull(&PullSpec::default(), 0).unwrap();
        assert_eq!(hosts[0].stats_snapshot().pulls, 1, "server 0 carries the empty pull");
        assert_eq!(hosts[1].stats_snapshot().pulls, 0);
        // and shutdown reaches every gate
        routed.shutdown_clock().unwrap();
        let err = routed.pull(&PullSpec::from_keys(vec![0]), 5).unwrap_err();
        assert!(err.is_shutdown(), "{err}");
    }
}
