//! Client-side fault tolerance: [`RetryTransport`] wraps the TCP link
//! with reconnect + capped exponential backoff, and
//! [`FaultInjectTransport`] is the deterministic fault harness that
//! proves every retry path in CI instead of by luck.
//!
//! The retry contract is *semantic invisibility*: a transient socket
//! fault must not change what the run computes. That holds because
//! every RPC is idempotent once the proto-v3 pieces are in place —
//! re-`Init` with the run's session id reattaches instead of zeroing
//! the server, a retried `Flush` reuses its per-worker seq so the
//! server applies it at most once, `Publish`/`PublishRange` overwrite,
//! and `Advance` is a monotonic max. Staleness-0 runs under injected
//! faults are therefore bitwise identical to fault-free runs (pinned
//! by `tests/ps_faults.rs`).
//!
//! Error classification: only [`TransportError::Io`] is retriable (the
//! carriage failed; the request may or may not have been processed).
//! `Protocol`/`Remote` mean the peer answered and said no — retrying
//! cannot help — and `Shutdown` is the clean end-of-run signal, never
//! retried. Backoff sleeps affect wall-clock only, never arithmetic,
//! so determinism is untouched.

use super::tcp::TcpTransport;
use super::{PullReply, Transport, TransportError};
use crate::obs::ObsSnapshot;
use crate::ps::clock::StalenessPolicy;
use crate::ps::shard::PullSpec;
use crate::ps::StatsSnapshot;
use crate::util::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Retry/backoff knobs (`[ps] retry_max` / `retry_backoff_ms`).
#[derive(Clone, Copy, Debug)]
pub struct RetryConfig {
    /// Reconnect-and-retry attempts per operation (0 = fail fast, the
    /// pre-retry behaviour).
    pub max: usize,
    /// First backoff sleep; doubles per attempt up to
    /// [`BACKOFF_CAP_MS`], jittered to 50–100% of the nominal value.
    pub backoff_ms: u64,
}

/// Ceiling on one backoff sleep.
pub const BACKOFF_CAP_MS: u64 = 2_000;

/// Which RPC an injected fault may target (`ops=` in a fault plan).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Pull,
    Flush,
    Publish,
    PublishRange,
    Advance,
    Stats,
    ObsStats,
    ShutdownClock,
}

impl Op {
    fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "pull" => Op::Pull,
            "flush" => Op::Flush,
            "publish" => Op::Publish,
            "publish_range" => Op::PublishRange,
            "advance" => Op::Advance,
            "stats" => Op::Stats,
            "obs_stats" => Op::ObsStats,
            "shutdown_clock" => Op::ShutdownClock,
            other => anyhow::bail!(
                "unknown op {other} (pull|flush|publish|publish_range|advance|stats|\
                 obs_stats|shutdown_clock)"
            ),
        })
    }
}

/// What an injected fault does to the RPC it hits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Fault {
    /// Fail *before* sending: the server never saw the request. Retry
    /// reconnects — this is what drives `net.reconnects` in tests.
    Drop,
    /// Perform the RPC, then report an I/O error anyway — the "reply
    /// lost on the wire" case that exercises server-side idempotence
    /// (a retried flush must not double-apply).
    ErrAfter,
    /// Sleep `delay_ms`, then proceed normally.
    Delay,
}

/// A deterministic fault schedule, parsed from `[ps] fault_plan` /
/// `--fault-plan`. Comma-separated `key=value` pairs:
///
/// ```text
/// seed=42,drop=0.05,err=0.02,delay=0.1,delay_ms=3,ops=pull|flush
/// seed=7,every=50,drop=1,ops=flush
/// ```
///
/// `drop`/`err`/`delay` are per-RPC probabilities drawn from a seeded
/// RNG (one draw per matching RPC; cumulative thresholds, so they must
/// sum to <= 1). `every=N` switches to a deterministic schedule — every
/// Nth matching RPC gets the highest-priority enabled kind (drop > err
/// > delay). `ops` restricts which RPCs can fault (`|`-separated;
/// unset = all). Each link's schedule is seeded `seed ^ worker_id` and
/// persists across reconnects.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub seed: u64,
    drop_p: f64,
    err_p: f64,
    delay_p: f64,
    delay_ms: u64,
    every: u64,
    /// Empty = every op is eligible.
    ops: Vec<Op>,
}

impl FaultPlan {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let mut plan = FaultPlan {
            seed: 0,
            drop_p: 0.0,
            err_p: 0.0,
            delay_p: 0.0,
            delay_ms: 1,
            every: 0,
            ops: Vec::new(),
        };
        let mut seen: Vec<String> = Vec::new();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault plan entry {part} is not key=value"))?;
            // Duplicate keys are a config error, not last-one-wins: a
            // plan with two seeds (or two drop rates) almost certainly
            // means a typo'd sweep, and silently keeping one would make
            // the "same plan string, same schedule" contract a lie.
            anyhow::ensure!(
                !seen.iter().any(|k| k == key),
                "duplicate fault plan key {key}"
            );
            seen.push(key.to_string());
            let prob = |v: &str| -> anyhow::Result<f64> {
                let p: f64 = v.parse()?;
                anyhow::ensure!((0.0..=1.0).contains(&p), "probability {p} outside [0, 1]");
                Ok(p)
            };
            match key {
                "seed" => plan.seed = value.parse()?,
                "drop" => plan.drop_p = prob(value)?,
                "err" => plan.err_p = prob(value)?,
                "delay" => plan.delay_p = prob(value)?,
                "delay_ms" => plan.delay_ms = value.parse()?,
                "every" => plan.every = value.parse()?,
                "ops" => {
                    plan.ops = value
                        .split('|')
                        .map(str::trim)
                        .filter(|p| !p.is_empty())
                        .map(Op::parse)
                        .collect::<anyhow::Result<_>>()?;
                    // An explicit `ops=` that names nothing reads as
                    // "fault no ops", but an empty filter means "fault
                    // every op" internally — refuse the ambiguity.
                    anyhow::ensure!(
                        !plan.ops.is_empty(),
                        "ops= names no operations (omit the key to fault every op)"
                    );
                }
                other => anyhow::bail!(
                    "unknown fault plan key {other} (seed|drop|err|delay|delay_ms|every|ops)"
                ),
            }
        }
        anyhow::ensure!(
            plan.drop_p + plan.err_p + plan.delay_p <= 1.0 + 1e-9,
            "drop + err + delay probabilities exceed 1"
        );
        Ok(plan)
    }

    fn applies(&self, op: Op) -> bool {
        self.ops.is_empty() || self.ops.contains(&op)
    }

    /// The kind an `every=N` schedule injects: highest-priority kind
    /// with a nonzero probability knob (the knobs double as enables),
    /// defaulting to `Drop`.
    fn primary(&self) -> Fault {
        if self.drop_p > 0.0 {
            Fault::Drop
        } else if self.err_p > 0.0 {
            Fault::ErrAfter
        } else if self.delay_p > 0.0 {
            Fault::Delay
        } else {
            Fault::Drop
        }
    }
}

/// Per-link fault progress: the matching-RPC index and the seeded RNG.
/// Lives in an `Arc<Mutex<_>>` shared with the link's retry wrapper so
/// the schedule continues across reconnects instead of restarting.
pub struct FaultState {
    rpc_index: u64,
    rng: Rng,
}

impl FaultState {
    fn new(seed: u64) -> Self {
        FaultState { rpc_index: 0, rng: Rng::new(seed) }
    }
}

fn injected_io(message: &str) -> TransportError {
    TransportError::Io(std::io::Error::new(std::io::ErrorKind::ConnectionReset, message))
}

/// Wraps any [`Transport`] and injects the plan's faults. Stacks
/// *below* [`RetryTransport`] so injected I/O errors exercise the real
/// reconnect path.
pub struct FaultInjectTransport {
    inner: Box<dyn Transport>,
    plan: Arc<FaultPlan>,
    state: Arc<Mutex<FaultState>>,
}

impl FaultInjectTransport {
    /// Wrap `inner` with a fresh schedule for `worker` (seeded
    /// `plan.seed ^ worker`).
    pub fn new(inner: Box<dyn Transport>, plan: Arc<FaultPlan>, worker: usize) -> Self {
        let state = Arc::new(Mutex::new(FaultState::new(plan.seed ^ worker as u64)));
        FaultInjectTransport { inner, plan, state }
    }

    /// Wrap `inner` continuing an existing schedule (the reconnect
    /// path: the new socket keeps the old link's fault position).
    pub fn with_state(
        inner: Box<dyn Transport>,
        plan: Arc<FaultPlan>,
        state: Arc<Mutex<FaultState>>,
    ) -> Self {
        FaultInjectTransport { inner, plan, state }
    }

    /// Handle to the schedule state, for re-wrapping after reconnect.
    pub fn state(&self) -> Arc<Mutex<FaultState>> {
        Arc::clone(&self.state)
    }

    /// Decide this RPC's fate. Only plan-matching ops consume schedule
    /// positions/draws, so `every=N` means every Nth *matching* RPC.
    fn decide(&mut self, op: Op) -> Option<Fault> {
        if !self.plan.applies(op) {
            return None;
        }
        let mut st = self.state.lock().expect("fault state lock");
        st.rpc_index += 1;
        if self.plan.every > 0 {
            return (st.rpc_index % self.plan.every == 0).then(|| self.plan.primary());
        }
        let r = st.rng.f64();
        if r < self.plan.drop_p {
            Some(Fault::Drop)
        } else if r < self.plan.drop_p + self.plan.err_p {
            Some(Fault::ErrAfter)
        } else if r < self.plan.drop_p + self.plan.err_p + self.plan.delay_p {
            Some(Fault::Delay)
        } else {
            None
        }
    }

    fn run<T>(
        &mut self,
        op: Op,
        exec: impl FnOnce(&mut dyn Transport) -> Result<T, TransportError>,
    ) -> Result<T, TransportError> {
        match self.decide(op) {
            Some(Fault::Drop) => {
                Err(injected_io("fault injection: dropped before send"))
            }
            Some(Fault::ErrAfter) => {
                exec(self.inner.as_mut())?;
                Err(injected_io("fault injection: reply lost after delivery"))
            }
            Some(Fault::Delay) => {
                std::thread::sleep(std::time::Duration::from_millis(self.plan.delay_ms));
                exec(self.inner.as_mut())
            }
            None => exec(self.inner.as_mut()),
        }
    }
}

impl Transport for FaultInjectTransport {
    fn pull(&mut self, spec: &PullSpec, round: u64) -> Result<PullReply, TransportError> {
        self.run(Op::Pull, |t| t.pull(spec, round))
    }

    fn flush(
        &mut self,
        deltas: &[(usize, f64)],
        round: u64,
        block: u64,
    ) -> Result<bool, TransportError> {
        self.run(Op::Flush, |t| t.flush(deltas, round, block))
    }

    // Membership RPCs are control-plane like `Init`: rare, idempotent,
    // and not part of the fault grammar. A real carriage fault on one
    // still exercises the retry wrapper above this layer.
    fn join(&mut self, worker: usize) -> Result<(), TransportError> {
        self.inner.join(worker)
    }

    fn leave(&mut self, worker: usize) -> Result<(), TransportError> {
        self.inner.leave(worker)
    }

    fn publish(
        &mut self,
        entries: &[(usize, f64)],
        version: u64,
    ) -> Result<(), TransportError> {
        self.run(Op::Publish, |t| t.publish(entries, version))
    }

    fn publish_range(
        &mut self,
        start: usize,
        values: &[f64],
        version: u64,
    ) -> Result<(), TransportError> {
        self.run(Op::PublishRange, |t| t.publish_range(start, values, version))
    }

    // The f32 seed path faults under the same `publish_range` op name:
    // it is the same RPC semantically, just a narrower payload.
    fn publish_range_f32(
        &mut self,
        start: usize,
        values: &[f32],
        version: u64,
    ) -> Result<(), TransportError> {
        self.run(Op::PublishRange, |t| t.publish_range_f32(start, values, version))
    }

    fn advance_applied(&mut self, applied: u64) -> Result<(), TransportError> {
        self.run(Op::Advance, |t| t.advance_applied(applied))
    }

    fn stats(&mut self) -> Result<StatsSnapshot, TransportError> {
        self.run(Op::Stats, |t| t.stats())
    }

    fn obs_stats(&mut self) -> Result<ObsSnapshot, TransportError> {
        self.run(Op::ObsStats, |t| t.obs_stats())
    }

    fn shutdown_clock(&mut self) -> Result<(), TransportError> {
        self.run(Op::ShutdownClock, |t| t.shutdown_clock())
    }
}

/// Everything a reconnect must replay to rejoin its run: the `Init`
/// shape (validated by the server against the hosted run) plus the
/// session that makes the re-`Init` idempotent.
#[derive(Clone, Debug)]
pub struct InitShape {
    pub shards: usize,
    pub workers: usize,
    pub policy: StalenessPolicy,
    pub segments: Vec<(usize, usize)>,
    /// Dense-segment chunking the run was configured with — part of
    /// the shape the server validates on reattach (a mismatch would
    /// split epochs differently than the checkpointed run).
    pub chunk_cells: usize,
    /// This link's place in a routed fleet (v6 wire): the server is
    /// `route_index` of `route_servers`. `(0, 1)` for the classic
    /// single-server topology.
    pub route_index: usize,
    /// Routed fleet size; see `route_index`.
    pub route_servers: usize,
}

/// The reconnecting TCP link: runs each operation against an inner
/// [`TcpTransport`] (optionally fault-wrapped) and, on a retriable
/// error, reconnects with capped exponential backoff + jitter, replays
/// the `Init` handshake (same session — the server reattaches) and the
/// last clock advance, then retries the operation.
pub struct RetryTransport {
    addr: String,
    worker: usize,
    session: u64,
    shape: InitShape,
    cfg: RetryConfig,
    socket_bytes: Arc<AtomicU64>,
    /// This link's monotonic flush seq, shared with every inner
    /// `TcpTransport` it ever mints so seqs survive reconnects.
    flush_seq: Arc<AtomicU64>,
    /// v5 run compression, re-enabled on every socket this link mints
    /// (the segment map + the shared `wire.runs_encoded` meter).
    compress: Option<(super::wire::SegmentMap, Arc<AtomicU64>)>,
    plan: Option<(Arc<FaultPlan>, Arc<Mutex<FaultState>>)>,
    /// `None` between a failure and the next (re)connect.
    inner: Option<Box<dyn Transport>>,
    /// Replayed after re-`Init`: a server restored from a checkpoint
    /// may hold an older applied clock, and without the replay the SSP
    /// gate would park every worker forever.
    last_advance: Option<u64>,
    /// Backoff jitter only — never feeds arithmetic.
    rng: Rng,
    /// Shared run-wide meters (`net.reconnects`, `net.retry_backoff_us`).
    reconnects: Arc<AtomicU64>,
    backoff_us: Arc<AtomicU64>,
}

/// The shared backoff arithmetic: sleep `backoff_ms * 2^(attempt-1)`
/// capped at [`BACKOFF_CAP_MS`], jittered to 50–100% by `rng`, metering
/// the slept microseconds into `meter`.
fn backoff_sleep(cfg: &RetryConfig, rng: &mut Rng, meter: &AtomicU64, attempt: usize) {
    let shift = (attempt.saturating_sub(1)).min(20) as u32;
    let nominal = cfg.backoff_ms.saturating_mul(1u64 << shift).min(BACKOFF_CAP_MS);
    let us = (nominal as f64 * 1000.0 * (0.5 + 0.5 * rng.f64())) as u64;
    meter.fetch_add(us, Ordering::Relaxed);
    std::thread::sleep(std::time::Duration::from_micros(us));
}

impl RetryTransport {
    /// Connect + `Init` for `worker`. The initial connect retries I/O
    /// failures under the same backoff budget as a reconnect (a worker
    /// may come up while the server is mid-restart); with `cfg.max`
    /// of 0 it fails fast, matching [`TcpTransport::connect`]'s
    /// posture. Connect attempts are not counted as reconnects — that
    /// meter records re-established links only.
    #[allow(clippy::too_many_arguments)]
    pub fn establish(
        addr: &str,
        worker: usize,
        session: u64,
        shape: InitShape,
        cfg: RetryConfig,
        plan: Option<Arc<FaultPlan>>,
        socket_bytes: Arc<AtomicU64>,
        reconnects: Arc<AtomicU64>,
        backoff_us: Arc<AtomicU64>,
    ) -> Result<Self, TransportError> {
        Self::establish_with_compression(
            addr,
            worker,
            session,
            shape,
            cfg,
            plan,
            socket_bytes,
            reconnects,
            backoff_us,
            None,
        )
    }

    /// [`RetryTransport::establish`] with v5 run compression enabled on
    /// every socket the link ever mints (including reconnects).
    #[allow(clippy::too_many_arguments)]
    pub fn establish_with_compression(
        addr: &str,
        worker: usize,
        session: u64,
        shape: InitShape,
        cfg: RetryConfig,
        plan: Option<Arc<FaultPlan>>,
        socket_bytes: Arc<AtomicU64>,
        reconnects: Arc<AtomicU64>,
        backoff_us: Arc<AtomicU64>,
        compress: Option<(super::wire::SegmentMap, Arc<AtomicU64>)>,
    ) -> Result<Self, TransportError> {
        let flush_seq = Arc::new(AtomicU64::new(0));
        // Jitter decorrelates concurrent reconnect storms; seeding from
        // (session, worker) keeps runs reproducible.
        let mut rng = Rng::new(session ^ (worker as u64).wrapping_mul(0x9e3779b97f4a7c15));
        let mut attempt = 0usize;
        let link = loop {
            let connected = TcpTransport::connect_with(
                addr,
                worker,
                Arc::clone(&socket_bytes),
                Arc::clone(&flush_seq),
            )
            .and_then(|mut link| {
                link.init_routed(
                    session,
                    shape.shards,
                    shape.workers,
                    shape.policy,
                    &shape.segments,
                    shape.chunk_cells,
                    shape.route_index,
                    shape.route_servers,
                )?;
                if let Some((map, runs)) = &compress {
                    link.enable_compression(map.clone(), Arc::clone(runs));
                }
                Ok(link)
            });
            match connected {
                Ok(link) => break link,
                Err(e) if Self::retriable(&e) && attempt < cfg.max => {
                    attempt += 1;
                    backoff_sleep(&cfg, &mut rng, &backoff_us, attempt);
                }
                Err(e) => return Err(e),
            }
        };
        let plan = plan.map(|p| {
            let state = Arc::new(Mutex::new(FaultState::new(p.seed ^ worker as u64)));
            (p, state)
        });
        let inner: Box<dyn Transport> = match &plan {
            Some((p, state)) => Box::new(FaultInjectTransport::with_state(
                Box::new(link),
                Arc::clone(p),
                Arc::clone(state),
            )),
            None => Box::new(link),
        };
        Ok(RetryTransport {
            addr: addr.to_string(),
            worker,
            session,
            shape,
            cfg,
            socket_bytes,
            flush_seq,
            compress,
            plan,
            inner: Some(inner),
            last_advance: None,
            rng,
            reconnects,
            backoff_us,
        })
    }

    /// Only carriage failures are worth retrying: the peer may never
    /// have seen the request. Everything else is an answer.
    fn retriable(e: &TransportError) -> bool {
        matches!(e, TransportError::Io(_))
    }

    /// Sleep `backoff_ms * 2^(attempt-1)` capped at [`BACKOFF_CAP_MS`],
    /// jittered to 50–100%, and meter the slept time.
    fn backoff(&mut self, attempt: usize) {
        backoff_sleep(&self.cfg, &mut self.rng, &self.backoff_us, attempt);
    }

    /// Fresh socket + idempotent re-`Init` (same session — the live
    /// server validates the shape and reattaches; a restarted blank
    /// server installs fresh zeroed state instead, see the module docs
    /// caveat) + replay of the last clock advance, re-wrapped with the
    /// link's persistent fault schedule.
    fn reconnect(&mut self) -> Result<(), TransportError> {
        let mut link = TcpTransport::connect_with(
            &self.addr,
            self.worker,
            Arc::clone(&self.socket_bytes),
            Arc::clone(&self.flush_seq),
        )?;
        link.init_routed(
            self.session,
            self.shape.shards,
            self.shape.workers,
            self.shape.policy,
            &self.shape.segments,
            self.shape.chunk_cells,
            self.shape.route_index,
            self.shape.route_servers,
        )?;
        if let Some((map, runs)) = &self.compress {
            link.enable_compression(map.clone(), Arc::clone(runs));
        }
        if let Some(applied) = self.last_advance {
            link.advance_applied(applied)?;
        }
        let inner: Box<dyn Transport> = match &self.plan {
            Some((p, state)) => Box::new(FaultInjectTransport::with_state(
                Box::new(link),
                Arc::clone(p),
                Arc::clone(state),
            )),
            None => Box::new(link),
        };
        self.inner = Some(inner);
        self.reconnects.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn with_retry<T>(
        &mut self,
        mut op: impl FnMut(&mut dyn Transport) -> Result<T, TransportError>,
    ) -> Result<T, TransportError> {
        let mut attempt = 0usize;
        loop {
            if self.inner.is_none() {
                match self.reconnect() {
                    Ok(()) => {}
                    Err(e) if Self::retriable(&e) && attempt < self.cfg.max => {
                        attempt += 1;
                        self.backoff(attempt);
                        continue;
                    }
                    Err(e) => return Err(e),
                }
            }
            let link = self.inner.as_mut().expect("link present after reconnect");
            match op(link.as_mut()) {
                Ok(v) => return Ok(v),
                Err(e) if Self::retriable(&e) => {
                    // The socket is suspect either way; reconnect on
                    // the next attempt (or leave it down on give-up).
                    self.inner = None;
                    if attempt >= self.cfg.max {
                        return Err(e);
                    }
                    attempt += 1;
                    self.backoff(attempt);
                }
                Err(e) => return Err(e),
            }
        }
    }
}

impl Transport for RetryTransport {
    fn pull(&mut self, spec: &PullSpec, round: u64) -> Result<PullReply, TransportError> {
        self.with_retry(|t| t.pull(spec, round))
    }

    fn flush(
        &mut self,
        deltas: &[(usize, f64)],
        round: u64,
        block: u64,
    ) -> Result<bool, TransportError> {
        // Every attempt of this flush must carry the SAME seq: rewind
        // the shared counter to its pre-attempt value so the inner
        // transport re-mints it, and the server's dedup can recognize
        // a retry whose first delivery actually landed (answering with
        // the verdict the original earned).
        let seq = Arc::clone(&self.flush_seq);
        let base = seq.load(Ordering::SeqCst);
        self.with_retry(move |t| {
            seq.store(base, Ordering::SeqCst);
            t.flush(deltas, round, block)
        })
    }

    fn join(&mut self, worker: usize) -> Result<(), TransportError> {
        self.with_retry(|t| t.join(worker))
    }

    fn leave(&mut self, worker: usize) -> Result<(), TransportError> {
        self.with_retry(|t| t.leave(worker))
    }

    fn publish(
        &mut self,
        entries: &[(usize, f64)],
        version: u64,
    ) -> Result<(), TransportError> {
        self.with_retry(|t| t.publish(entries, version))
    }

    fn publish_range(
        &mut self,
        start: usize,
        values: &[f64],
        version: u64,
    ) -> Result<(), TransportError> {
        self.with_retry(|t| t.publish_range(start, values, version))
    }

    fn publish_range_f32(
        &mut self,
        start: usize,
        values: &[f32],
        version: u64,
    ) -> Result<(), TransportError> {
        self.with_retry(|t| t.publish_range_f32(start, values, version))
    }

    fn advance_applied(&mut self, applied: u64) -> Result<(), TransportError> {
        self.with_retry(|t| t.advance_applied(applied))?;
        self.last_advance = Some(applied);
        Ok(())
    }

    fn stats(&mut self) -> Result<StatsSnapshot, TransportError> {
        self.with_retry(|t| t.stats())
    }

    fn obs_stats(&mut self) -> Result<ObsSnapshot, TransportError> {
        self.with_retry(|t| t.obs_stats())
    }

    fn shutdown_clock(&mut self) -> Result<(), TransportError> {
        self.with_retry(|t| t.shutdown_clock())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::transport::tcp::PsTcpServer;
    use crate::ps::transport::COORDINATOR_ID;

    #[test]
    fn fault_plan_parses_and_rejects_garbage() {
        let plan = FaultPlan::parse("seed=42,drop=0.1,err=0.05,delay_ms=3,ops=pull|flush").unwrap();
        assert_eq!(plan.seed, 42);
        assert_eq!(plan.delay_ms, 3);
        assert!(plan.applies(Op::Pull) && plan.applies(Op::Flush));
        assert!(!plan.applies(Op::Stats));
        assert_eq!(plan.primary(), Fault::Drop);

        let every = FaultPlan::parse("seed=7,every=50,err=1,ops=flush").unwrap();
        assert_eq!(every.every, 50);
        assert_eq!(every.primary(), Fault::ErrAfter);

        let all = FaultPlan::parse("drop=0.5").unwrap();
        assert!(all.applies(Op::ShutdownClock), "no ops filter = every op");

        assert!(FaultPlan::parse("drop=1.5").is_err(), "probability > 1");
        assert!(FaultPlan::parse("drop=0.6,err=0.6").is_err(), "probs sum > 1");
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("seed").is_err(), "not key=value");
        assert!(FaultPlan::parse("ops=carrier-pigeon").is_err());
        assert!(FaultPlan::parse("seed=1,seed=2").is_err(), "duplicate key");
        assert!(FaultPlan::parse("drop=0.1,drop=0.1").is_err(), "duplicate key, same value");
        assert!(FaultPlan::parse("ops=").is_err(), "empty ops filter is ambiguous");
        assert!(FaultPlan::parse("ops=|").is_err(), "all-separator ops filter");
        assert!(FaultPlan::parse("drop=-0.1").is_err(), "negative probability");
        assert!(FaultPlan::parse("drop=NaN").is_err(), "NaN probability");
        assert!(FaultPlan::parse("every=yes").is_err(), "non-numeric count");
        assert!(FaultPlan::parse("seed=-1").is_err(), "negative seed");
    }

    /// Satellite fuzz pass: no input string may panic the parser, and
    /// every malformed one must come back as a clean `Err`. The corpus
    /// is seeded mutations of a valid plan (byte splices from a garbage
    /// alphabet) plus raw garbage — deterministic, so a failure
    /// reproduces by seed.
    #[test]
    fn fault_plan_parser_survives_fuzzed_garbage() {
        let alphabet: &[u8] = b"=,|.0123456789abcdefghijklmnopqrstuvwxyz \t-+eE";
        let valid = "seed=42,drop=0.05,err=0.02,delay=0.1,delay_ms=3,ops=pull|flush";
        let mut rng = Rng::new(0xfa57_91a9);
        for _ in 0..2000 {
            let mut bytes = valid.as_bytes().to_vec();
            let splices = 1 + (rng.f64() * 6.0) as usize;
            for _ in 0..splices {
                let at = (rng.f64() * bytes.len() as f64) as usize % bytes.len();
                let with = alphabet[(rng.f64() * alphabet.len() as f64) as usize
                    % alphabet.len()];
                if rng.f64() < 0.5 {
                    bytes[at] = with;
                } else {
                    bytes.insert(at, with);
                }
            }
            // Must not panic; Ok or Err are both acceptable outcomes.
            let _ = FaultPlan::parse(&String::from_utf8_lossy(&bytes));
        }
        for garbage in [
            "", ",,,,", "=", "==", "=,=", "seed==1", "ops=pull||", "\u{1F980}=1",
            "drop=0.1e309", "delay_ms=99999999999999999999", "seed=0x10",
        ] {
            // Structurally hostile strings must parse to a clean error
            // or a valid plan — never a panic. (The empty plan string
            // is valid: it means "no faults".)
            let _ = FaultPlan::parse(garbage);
        }
    }

    /// Same plan string parsed twice (separately) must produce the same
    /// fault schedule for the same worker — the reproducibility pin
    /// that makes `--fault-plan` failures replayable from a log line.
    #[test]
    fn same_plan_string_yields_the_same_schedule() {
        let text = "seed=1234,drop=0.2,err=0.1,delay=0.05,delay_ms=1";
        let first = Arc::new(FaultPlan::parse(text).unwrap());
        let second = Arc::new(FaultPlan::parse(text).unwrap());
        for worker in [0usize, 3, 17] {
            let mut a = FaultInjectTransport::new(Box::new(NullTransport), Arc::clone(&first), worker);
            let mut b =
                FaultInjectTransport::new(Box::new(NullTransport), Arc::clone(&second), worker);
            let seq_a: Vec<_> = (0..256).map(|_| a.decide(Op::Flush)).collect();
            let seq_b: Vec<_> = (0..256).map(|_| b.decide(Op::Flush)).collect();
            assert_eq!(seq_a, seq_b, "worker {worker} schedule must round-trip");
        }
    }

    #[test]
    fn fault_schedule_is_deterministic_and_filtered() {
        let plan = Arc::new(FaultPlan::parse("seed=9,drop=0.3,err=0.2,ops=pull").unwrap());
        // Two harnesses over the same plan+worker produce the same
        // fault sequence; non-matching ops consume nothing.
        let mut a = FaultInjectTransport::new(Box::new(NullTransport), Arc::clone(&plan), 3);
        let mut b = FaultInjectTransport::new(Box::new(NullTransport), Arc::clone(&plan), 3);
        let seq_a: Vec<_> = (0..64)
            .map(|i| {
                if i % 4 == 0 {
                    assert_eq!(a.decide(Op::Stats), None, "filtered op never faults");
                }
                a.decide(Op::Pull)
            })
            .collect();
        let seq_b: Vec<_> = (0..64).map(|_| b.decide(Op::Pull)).collect();
        assert_eq!(seq_a, seq_b);
        assert!(seq_a.iter().any(|f| f.is_some()), "some fault fires in 64 draws");
        // every=N is exactly periodic over matching RPCs
        let every = Arc::new(FaultPlan::parse("every=3,drop=1,ops=pull").unwrap());
        let mut c = FaultInjectTransport::new(Box::new(NullTransport), every, 0);
        let fired: Vec<bool> = (0..9).map(|_| c.decide(Op::Pull).is_some()).collect();
        assert_eq!(fired, vec![false, false, true, false, false, true, false, false, true]);
    }

    /// Inert transport for schedule-only tests.
    struct NullTransport;

    impl Transport for NullTransport {
        fn pull(&mut self, _: &PullSpec, _: u64) -> Result<PullReply, TransportError> {
            Ok(PullReply { ranges: vec![], cells: vec![], gap: 0, waited: false, gate_us: 0 })
        }
        fn flush(&mut self, _: &[(usize, f64)], _: u64, _: u64) -> Result<bool, TransportError> {
            Ok(true)
        }
        fn join(&mut self, _: usize) -> Result<(), TransportError> {
            Ok(())
        }
        fn leave(&mut self, _: usize) -> Result<(), TransportError> {
            Ok(())
        }
        fn publish(&mut self, _: &[(usize, f64)], _: u64) -> Result<(), TransportError> {
            Ok(())
        }
        fn publish_range(&mut self, _: usize, _: &[f64], _: u64) -> Result<(), TransportError> {
            Ok(())
        }
        fn advance_applied(&mut self, _: u64) -> Result<(), TransportError> {
            Ok(())
        }
        fn stats(&mut self) -> Result<StatsSnapshot, TransportError> {
            Ok(StatsSnapshot::default())
        }
        fn obs_stats(&mut self) -> Result<ObsSnapshot, TransportError> {
            Err(TransportError::Remote("null".into()))
        }
        fn shutdown_clock(&mut self) -> Result<(), TransportError> {
            Ok(())
        }
    }

    #[test]
    fn dropped_rpcs_reconnect_and_lost_replies_never_double_apply() {
        let host = PsTcpServer::bind("127.0.0.1:0").unwrap();
        let addr = host.local_addr().to_string();
        let shape = InitShape {
            shards: 2,
            workers: 1,
            policy: StalenessPolicy::Bounded(0),
            segments: vec![(0, 4)],
            chunk_cells: 0,
            route_index: 0,
            route_servers: 1,
        };
        let cfg = RetryConfig { max: 4, backoff_ms: 1 };
        let reconnects = Arc::new(AtomicU64::new(0));
        let backoff_us = Arc::new(AtomicU64::new(0));
        let mut coord = RetryTransport::establish(
            &addr,
            COORDINATOR_ID,
            7001,
            shape.clone(),
            cfg,
            None,
            Arc::new(AtomicU64::new(0)),
            Arc::clone(&reconnects),
            Arc::clone(&backoff_us),
        )
        .unwrap();
        coord.publish_range(0, &[1.0, 2.0, 3.0, 4.0], 0).unwrap();

        // Worker link: drop every 2nd pull-or-flush before sending, so
        // each faulted RPC forces a real reconnect + re-Init.
        let plan = Arc::new(FaultPlan::parse("every=2,drop=1,ops=pull|flush").unwrap());
        let mut worker = RetryTransport::establish(
            &addr,
            0,
            7001,
            shape,
            cfg,
            Some(plan),
            Arc::new(AtomicU64::new(0)),
            Arc::clone(&reconnects),
            Arc::clone(&backoff_us),
        )
        .unwrap();
        let reply = worker.pull(&PullSpec::from_ranges(vec![(0, 4)]), 0).unwrap();
        assert_eq!(reply.ranges[0].values(), &[1.0f32, 2.0, 3.0, 4.0]);
        // pull #1 passed, flush is matching-RPC #2 -> dropped once,
        // retried over a fresh link with the same seq
        assert!(worker.flush(&[(0, 0.5)], 0, 0).unwrap());
        assert!(reconnects.load(Ordering::Relaxed) >= 1, "drop faults must reconnect");
        assert!(backoff_us.load(Ordering::Relaxed) > 0, "reconnects must meter backoff");

        let stats = coord.stats().unwrap();
        assert_eq!(stats.flushes, 1, "the dropped flush was applied exactly once");
        host.stop();
    }

    #[test]
    fn err_after_faults_exercise_flush_dedup() {
        let host = PsTcpServer::bind("127.0.0.1:0").unwrap();
        let addr = host.local_addr().to_string();
        let shape = InitShape {
            shards: 2,
            workers: 1,
            policy: StalenessPolicy::Async,
            segments: vec![(0, 2)],
            chunk_cells: 0,
            route_index: 0,
            route_servers: 1,
        };
        let cfg = RetryConfig { max: 4, backoff_ms: 1 };
        let zeros = || Arc::new(AtomicU64::new(0));
        let mut coord = RetryTransport::establish(
            &addr, COORDINATOR_ID, 7002, shape.clone(), cfg, None, zeros(), zeros(), zeros(),
        )
        .unwrap();
        // err=1 on flush: every flush IS delivered, then its reply is
        // "lost" — the retry resends the same seq and the server must
        // dedup it, or the deltas double-apply.
        let plan = Arc::new(FaultPlan::parse("every=2,err=1,ops=flush").unwrap());
        let mut worker = RetryTransport::establish(
            &addr, 0, 7002, shape, cfg, Some(plan), zeros(), zeros(), zeros(),
        )
        .unwrap();
        assert!(worker.flush(&[(0, 1.0)], 0, 0).unwrap()); // passes clean
        assert!(worker.flush(&[(0, 1.0)], 1, 0).unwrap()); // delivered, reply lost, resent
        assert!(worker.flush(&[(0, 1.0)], 2, 0).unwrap()); // passes clean
        assert!(worker.flush(&[(0, 1.0)], 3, 0).unwrap()); // delivered, reply lost, resent
        let reply = worker.pull(&PullSpec::from_ranges(vec![(0, 2)]), 0).unwrap();
        assert_eq!(
            reply.ranges[0].values()[0],
            4.0f32,
            "4 flushes of +1.0 must land exactly once each"
        );
        let stats = coord.stats().unwrap();
        assert_eq!(stats.flushes, 4, "deduped retries never re-apply");
        host.stop();
    }
}
