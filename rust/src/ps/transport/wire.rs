//! The length-prefixed binary wire protocol the TCP transport speaks —
//! the *normative* spec lives in `docs/ARCHITECTURE.md §Wire protocol`;
//! this module is its executable form, and the round-trip property
//! tests in `tests/ps_transport.rs` pin the two against each other.
//!
//! Every message is one **frame**: a little-endian `u32` payload length
//! followed by the payload, whose first byte is the opcode. Requests
//! and replies share the framing; a connection is a strict synchronous
//! RPC stream (one request, one reply, in order). All integers are
//! little-endian; floats are IEEE-754 little-endian bit patterns, so
//! the wire is bitwise lossless — an f32 range slab crosses as exactly
//! `4 * len` value bytes (the 4 B/cell accounting the pull meter uses
//! is literal here), and f64 cells/deltas as exact 8-byte images.

use crate::obs::{ClockView, MetricValue, ObsSnapshot};
use crate::ps::clock::StalenessPolicy;
use crate::ps::shard::{Cell, PullSpec, RangePull};
use crate::ps::StatsSnapshot;
use std::fmt;
use std::io::{Read, Write};

/// Protocol revision carried in every `Init`; the server refuses a
/// mismatch instead of misparsing traffic. Bump on any layout change.
/// v2: `PullOk` carries the gate wait time and the `ObsStats` /
/// `ObsStatsOk` introspection opcodes exist.
/// v3: `Init` carries a session id so a reconnecting client's
/// re-`Init` is idempotent, and `Flush` carries a per-worker monotonic
/// seq so a retried flush is applied exactly once.
/// v4 (elastic membership): `Init` and `Pull` carry the sending link's
/// worker id (the server tells a link's first attach from a reconnect,
/// and the gate refuses retired workers), `Flush` carries the
/// scheduling block id and is answered by `FlushOk { applied }` (the
/// server's exactly-once verdict), `Stats` gains `flushes_dropped`,
/// and the idempotent `Join`/`Leave` opcodes change the worker census
/// mid-run.
/// v5 (sparse wire compression + chunked epochs): `Init` carries the
/// store's `chunk_cells` (the dense-segment epoch chunk size the
/// server must build and a reattach must match), delta batches and
/// republishes may cross as sorted **index-delta + f32 value runs**
/// (`FlushRuns`/`PublishRuns` — dense consecutive stretches collapse
/// to offset + raw-LE f32 slab, scattered covered entries to base +
/// u32-offset/f32 pairs, uncovered keys stay full f64 pairs), and
/// segment seeds may cross as raw f32 slabs (`PublishRangeF32`).
/// Decode still accepts v4 `Init`s (chunk_cells = 0, plain opcodes
/// only), so old clients keep working; the new opcodes are a client
/// choice, not a handshake — narrowing covered entries to f32 is
/// lossless because dense slots store f32 anyway (`(v as f32) as f64`
/// re-narrows bit-identically), so staleness-0 runs are bitwise
/// identical with compression on or off.
/// v6 (multi-server routing): `Init` carries the link's place in a
/// sharded server fleet — `route_index` (which server this Init
/// addresses) and `route_servers` (fleet size) — so an N-server
/// `RoutedTransport` fan-out is negotiated in the same handshake a
/// single server uses. A v5 single-server peer's Init decodes as
/// `(route_index, route_servers) = (0, 1)`, the degenerate one-server
/// route, so the decode-back window moves to v5.
pub const PROTO_VERSION: u16 = 6;

/// Oldest `Init` protocol revision the decode side still accepts
/// (pre-routing clients: `route_index`/`route_servers` default to the
/// degenerate single-server route `(0, 1)`).
pub const MIN_PROTO_VERSION: u16 = 5;

/// Frames above this are corruption, not data (guards allocation).
pub const MAX_FRAME: u32 = 1 << 30;

/// Request opcodes (first payload byte, client -> server).
pub mod op {
    pub const INIT: u8 = 0x01;
    pub const PULL: u8 = 0x02;
    pub const FLUSH: u8 = 0x03;
    pub const PUBLISH: u8 = 0x04;
    pub const PUBLISH_RANGE: u8 = 0x05;
    pub const ADVANCE: u8 = 0x06;
    pub const STATS: u8 = 0x07;
    pub const SHUTDOWN_CLOCK: u8 = 0x08;
    pub const OBS_STATS: u8 = 0x09;
    pub const JOIN: u8 = 0x0A;
    pub const LEAVE: u8 = 0x0B;
    /// v5: `Flush` body carried as sparse value runs (decodes to the
    /// same `Request::Flush`).
    pub const FLUSH_RUNS: u8 = 0x0C;
    /// v5: `Publish` body carried as sparse value runs (decodes to the
    /// same `Request::Publish`).
    pub const PUBLISH_RUNS: u8 = 0x0D;
    /// v5: `PublishRange` with a raw f32 value slab (the canonical-f32
    /// seed path; half the bytes, no widen/narrow round trip).
    pub const PUBLISH_RANGE_F32: u8 = 0x0E;
    /// Reply opcodes (server -> client).
    pub const REPLY_OK: u8 = 0x80;
    pub const REPLY_PULL: u8 = 0x81;
    pub const REPLY_STATS: u8 = 0x82;
    pub const REPLY_OBS_STATS: u8 = 0x83;
    pub const REPLY_FLUSH: u8 = 0x84;
    pub const REPLY_ERR: u8 = 0x7f;
}

/// A decoded client -> server message.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Configure (or reset) the hosted server: the first message a
    /// coordinator sends. An `Init` whose nonzero `session` matches the
    /// hosted run's session *reattaches* (idempotent — the live store
    /// and clock are kept, so a reconnecting client resumes its run);
    /// any other `Init` replaces the previous server instance, so
    /// back-to-back runs (e.g. the staleness sweep) reuse one
    /// `ps-server` process. `worker` identifies the link (v4): the
    /// server counts a session-matching re-`Init` from a worker id it
    /// has already attached as a reconnect (`net.reconnects`), while a
    /// link's first attach is not one.
    Init {
        worker: usize,
        session: u64,
        shards: usize,
        workers: usize,
        policy: StalenessPolicy,
        segments: Vec<(usize, usize)>,
        /// Dense-segment epoch chunk size the server's store must be
        /// built with (0 = one chunk per segment). v5; a v4 `Init`
        /// decodes as 0.
        chunk_cells: usize,
        /// Which server of a routed fleet this `Init` addresses (v6):
        /// `0 <= route_index < route_servers`. Purely informational to
        /// the server (labels `ps-stats`/reporter output); the segments
        /// above are already the sub-range this server owns.
        route_index: usize,
        /// Routed fleet size (v6). 1 = the classic single-server
        /// topology; a v5 `Init` decodes as `(0, 1)`.
        route_servers: usize,
    },
    /// SSP-gated read of a [`PullSpec`] by `worker`; blocks server-side
    /// until the applied clock admits `round`. A retired worker's pull
    /// is refused (shutdown-flavored) instead of being admitted or
    /// parked forever.
    Pull { worker: usize, round: u64, spec: PullSpec },
    /// A worker's coalesced end-of-round delta batch + clock tick for
    /// scheduling block `block`.
    /// `seq` is the worker's monotonic flush counter (1-based; 0 = no
    /// dedup): the server applies each seq at most once, so a flush
    /// retried after a lost reply never double-applies its deltas.
    /// `block` keys the server's `(round, block)` exactly-once ledger —
    /// when a lease expiry re-dispatches the block to another worker,
    /// exactly one of the racing flushes is applied; the answer
    /// (`FlushOk { applied }`) tells this worker whether it won.
    Flush { worker: usize, block: u64, round: u64, seq: u64, deltas: Vec<(usize, f64)> },
    /// Coordinator republish of derived state (metered as republish
    /// traffic server-side).
    Publish { version: u64, entries: Vec<(usize, f64)> },
    /// Contiguous overwrite-publish (the round-0 seed path; unmetered,
    /// matching the in-process seeding semantics).
    PublishRange { version: u64, start: usize, values: Vec<f64> },
    /// Contiguous overwrite-publish from canonical f32 values (v5):
    /// the seed path for problems whose state is natively f32 (MF) —
    /// 4 bytes per cell on the wire and no widen/narrow round trip.
    /// Bit-identical to publishing the widened values: dense slots
    /// store f32 either way, and hashed gap keys widen exactly as the
    /// f64 path would have narrowed.
    PublishRangeF32 { version: u64, start: usize, values: Vec<f32> },
    /// Advance the server's applied clock (ungates workers).
    Advance { applied: u64 },
    /// Read a [`StatsSnapshot`] of every server meter.
    Stats,
    /// Wake every SSP gate waiter for run teardown. The server process
    /// stays up (a later `Init` starts the next run).
    ShutdownClock,
    /// Read a full [`ObsSnapshot`] (registry + segments + clock gate
    /// state). Unlike every other request, a server answers this even
    /// before any `Init` arrived (with a non-shutdown `Err`), so
    /// `strads ps-stats` can probe an idle server without parking.
    ObsStats,
    /// Membership: admit worker `worker` at the clock frontier. The
    /// coordinator picks the id (its census count), which makes the
    /// request idempotent — a Join replayed by the retry wrapper after
    /// a lost reply re-admits the same id and changes nothing.
    Join { worker: usize },
    /// Membership: retire worker `worker` (left, or declared dead by
    /// the supervisor). Idempotent; wakes the leaver if it is parked at
    /// the SSP gate and fences its late flushes.
    Leave { worker: usize },
}

/// A decoded server -> client message.
#[derive(Debug)]
pub enum Reply {
    Ok,
    /// Pull result: ranges in request order (f32 images + epoch
    /// version), then scattered cells in request-key order. `gate_us`
    /// is how long the pull blocked at the SSP gate server-side.
    Pull { gap: u64, waited: bool, gate_us: u64, ranges: Vec<RangePull>, cells: Vec<Cell> },
    /// Flush result: `applied` is the server's exactly-once verdict —
    /// false when the deltas were dropped (retired worker, or the
    /// `(round, block)` was already applied by a reassigned twin).
    Flush { applied: bool },
    Stats(StatsSnapshot),
    ObsStats(ObsSnapshot),
    /// Request failed. `shutdown` distinguishes the clean teardown path
    /// (gate waiters woken) from real errors.
    Err { shutdown: bool, message: String },
}

/// Malformed wire traffic (truncated frame, bad opcode, trailing
/// bytes). Carried up as `TransportError::Protocol`.
#[derive(Debug, PartialEq, Eq)]
pub struct WireError(pub String);

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "wire protocol error: {}", self.0)
    }
}

impl std::error::Error for WireError {}

// ---- framing ----------------------------------------------------------

/// Write one frame (`u32` LE length + payload) and flush. Returns the
/// total bytes put on the socket — the real-traffic meter's input.
/// Refuses out-of-range payloads *before* any bytes hit the wire: a
/// silently wrapped `u32` length (possible for a >= 4 GiB seed of a
/// huge model) would desynchronize the whole stream.
pub fn write_frame<W: Write>(w: &mut W, msg: &[u8]) -> std::io::Result<u64> {
    if msg.is_empty() || msg.len() > MAX_FRAME as usize {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame payload of {} bytes is out of range (1..={MAX_FRAME})", msg.len()),
        ));
    }
    w.write_all(&(msg.len() as u32).to_le_bytes())?;
    w.write_all(msg)?;
    w.flush()?;
    Ok(4 + msg.len() as u64)
}

/// Read one frame into `buf` (resized to the payload). Returns the
/// total bytes taken off the socket.
pub fn read_frame<R: Read>(r: &mut R, buf: &mut Vec<u8>) -> std::io::Result<u64> {
    let mut len_bytes = [0u8; 4];
    r.read_exact(&mut len_bytes)?;
    let len = u32::from_le_bytes(len_bytes);
    if len == 0 || len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad frame length {len}"),
        ));
    }
    buf.resize(len as usize, 0);
    r.read_exact(buf)?;
    Ok(4 + len as u64)
}

// ---- primitive writers -------------------------------------------------

fn put_u16(b: &mut Vec<u8>, v: u16) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(b: &mut Vec<u8>, v: f64) {
    b.extend_from_slice(&v.to_le_bytes());
}

// ---- primitive reader --------------------------------------------------

/// Checked sequential reader over one frame payload. Every accessor
/// fails (instead of panicking) on truncation, and [`Reader::finish`]
/// rejects trailing bytes, so a corrupt frame can never be half-read.
struct Reader<'a> {
    buf: &'a [u8],
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf }
    }

    fn remaining(&self) -> usize {
        self.buf.len()
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.buf.len() < n {
            return Err(WireError(format!(
                "truncated frame: wanted {n} more bytes, have {}",
                self.buf.len()
            )));
        }
        let (head, rest) = self.buf.split_at(n);
        self.buf = rest;
        Ok(head)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("take(2)")))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("take(4)")))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("take(8)")))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("take(8)")))
    }

    /// A `u32` element count followed by elements of `elem_bytes` each:
    /// validates the count against the remaining payload *before* any
    /// allocation, so a hostile count cannot OOM the peer.
    fn count(&mut self, elem_bytes: usize) -> Result<usize, WireError> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem_bytes) > self.remaining() {
            return Err(WireError(format!(
                "count {n} x {elem_bytes}B exceeds the {}B left in the frame",
                self.remaining()
            )));
        }
        Ok(n)
    }

    fn finish(self) -> Result<(), WireError> {
        if self.buf.is_empty() {
            Ok(())
        } else {
            Err(WireError(format!("{} trailing bytes after message", self.buf.len())))
        }
    }
}

// ---- requests ----------------------------------------------------------

fn put_pairs(b: &mut Vec<u8>, pairs: &[(usize, f64)]) {
    put_u32(b, pairs.len() as u32);
    for &(key, value) in pairs {
        put_u64(b, key as u64);
        put_f64(b, value);
    }
}

fn read_pairs(r: &mut Reader) -> Result<Vec<(usize, f64)>, WireError> {
    let n = r.count(16)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push((r.u64()? as usize, r.f64()?));
    }
    Ok(out)
}

// ---- sparse value runs (v5) -------------------------------------------
//
// A `(key, f64)` batch with unique keys (delta batches coalesce
// per-key; republishes enumerate each entry once) is encoded as sorted
// runs. Keys covered by a registered dense segment are f32-lossless —
// the store keeps them as f32 slots, so `(v as f32) as f64` re-narrows
// bit-identically on application — and ship 4-byte values; uncovered
// keys keep full f64 pairs. Layout:
//
//   u32 nruns, then per run a u8 tag:
//     0 dense f32:  u64 start, u32 count, count * raw f32 LE
//                   (consecutive covered keys start..start+count)
//     1 sparse f32: u64 base, u32 count, count * (u32 key-base, f32)
//     2 pairs f64:  u32 count, count * (u64 key, f64)
//
// Unique keys make the sort bit-stable and make application order
// irrelevant (f32 adds on distinct keys commute; versions max-merge),
// so a decoded batch applies exactly as the unsorted original would.

/// Consecutive covered keys shorter than this stay in a sparse run
/// (a dense run's 12-byte header would outweigh the 4-bytes-per-entry
/// saving on the offsets).
const MIN_DENSE_RUN: usize = 4;

/// The client-side view of the registered dense segments, for deciding
/// which keys of an outgoing batch are f32-lossless on the wire. Built
/// once at `Init` from the same `(start, len)` list the server
/// registers, so client and server classify every key identically.
#[derive(Clone, Debug, Default)]
pub struct SegmentMap {
    /// Sorted by start, non-overlapping (the store asserts the same).
    segs: Vec<(usize, usize)>,
}

impl SegmentMap {
    pub fn new(segments: &[(usize, usize)]) -> Self {
        let mut segs: Vec<(usize, usize)> =
            segments.iter().copied().filter(|&(_, len)| len > 0).collect();
        segs.sort_unstable_by_key(|&(start, _)| start);
        SegmentMap { segs }
    }

    pub fn is_empty(&self) -> bool {
        self.segs.is_empty()
    }

    /// Whether `key` lands in a registered segment (an f32 slot).
    pub fn covers(&self, key: usize) -> bool {
        let idx = self.segs.partition_point(|&(start, _)| start <= key);
        idx > 0 && {
            let (start, len) = self.segs[idx - 1];
            key < start + len
        }
    }
}

/// Append the run encoding of `entries` (unique keys) to `b`. Returns
/// the number of compressed (f32) runs emitted — the `wire.runs_encoded`
/// meter's input. Entries are sorted by key internally; the caller's
/// order never reaches the wire.
fn put_runs(b: &mut Vec<u8>, entries: &[(usize, f64)], map: &SegmentMap) -> u64 {
    let mut sorted: Vec<(usize, f64)> = entries.to_vec();
    sorted.sort_unstable_by_key(|&(key, _)| key);
    let covered: Vec<(usize, f64)> =
        sorted.iter().copied().filter(|&(key, _)| map.covers(key)).collect();
    let uncovered: Vec<(usize, f64)> =
        sorted.iter().copied().filter(|&(key, _)| !map.covers(key)).collect();

    let nruns_at = b.len();
    put_u32(b, 0); // patched below
    let mut nruns = 0u32;
    let mut compressed = 0u64;

    // Walk the covered entries as maximal consecutive-key stretches:
    // long stretches become dense runs, short ones pool into sparse
    // runs (split only if an offset would overflow its u32).
    let mut sparse: Vec<(usize, f64)> = Vec::new();
    let mut flush_sparse = |b: &mut Vec<u8>, sparse: &mut Vec<(usize, f64)>,
                            nruns: &mut u32, compressed: &mut u64| {
        if sparse.is_empty() {
            return;
        }
        let base = sparse[0].0;
        b.push(1);
        put_u64(b, base as u64);
        put_u32(b, sparse.len() as u32);
        for &(key, value) in sparse.iter() {
            put_u32(b, (key - base) as u32);
            b.extend_from_slice(&(value as f32).to_le_bytes());
        }
        sparse.clear();
        *nruns += 1;
        *compressed += 1;
    };
    let mut i = 0;
    while i < covered.len() {
        let mut j = i + 1;
        while j < covered.len() && covered[j].0 == covered[j - 1].0 + 1 {
            j += 1;
        }
        if j - i >= MIN_DENSE_RUN {
            flush_sparse(b, &mut sparse, &mut nruns, &mut compressed);
            b.push(0);
            put_u64(b, covered[i].0 as u64);
            put_u32(b, (j - i) as u32);
            for &(_, value) in &covered[i..j] {
                b.extend_from_slice(&(value as f32).to_le_bytes());
            }
            nruns += 1;
            compressed += 1;
        } else {
            for &(key, value) in &covered[i..j] {
                if !sparse.is_empty() && key - sparse[0].0 > u32::MAX as usize {
                    flush_sparse(b, &mut sparse, &mut nruns, &mut compressed);
                }
                sparse.push((key, value));
            }
        }
        i = j;
    }
    flush_sparse(b, &mut sparse, &mut nruns, &mut compressed);

    if !uncovered.is_empty() {
        b.push(2);
        put_pairs(b, &uncovered);
        nruns += 1;
    }
    b[nruns_at..nruns_at + 4].copy_from_slice(&nruns.to_le_bytes());
    compressed
}

/// Decode a run-encoded batch back into `(key, f64)` entries (sorted
/// covered entries first, then the uncovered pairs). Every count and
/// key computation is checked, so malformed run lengths and
/// overflowing bases reject cleanly instead of panicking or OOMing.
fn read_runs(r: &mut Reader) -> Result<Vec<(usize, f64)>, WireError> {
    // smallest possible run is an empty f64-pairs run: tag + u32 count
    let nruns = r.count(5)?;
    let mut out = Vec::new();
    for _ in 0..nruns {
        match r.u8()? {
            0 => {
                let start = r.u64()?;
                let count = r.count(4)?;
                if start.checked_add(count as u64).is_none() {
                    return Err(WireError(format!(
                        "dense run start {start} + count {count} overflows the key space"
                    )));
                }
                let bytes = r.take(count * 4)?;
                out.reserve(count);
                for (i, chunk) in bytes.chunks_exact(4).enumerate() {
                    let v = f32::from_le_bytes(chunk.try_into().expect("chunks_exact(4)"));
                    out.push((start as usize + i, v as f64));
                }
            }
            1 => {
                let base = r.u64()?;
                let count = r.count(8)?;
                out.reserve(count);
                for _ in 0..count {
                    let offset = r.u32()?;
                    let v = f32::from_le_bytes(r.take(4)?.try_into().expect("take(4)"));
                    let key = base.checked_add(offset as u64).ok_or_else(|| {
                        WireError(format!(
                            "sparse run base {base} + offset {offset} overflows the key space"
                        ))
                    })?;
                    out.push((key as usize, v as f64));
                }
            }
            2 => out.extend(read_pairs(r)?),
            tag => return Err(WireError(format!("unknown value-run tag {tag}"))),
        }
    }
    Ok(out)
}

// Borrowed fast-path encoders: the client encodes straight from the
// slices it already holds — no owned `Request` (and no payload clone)
// is ever materialized on the per-round hot path. `encode_request`
// delegates here, so the owned enum exists only for the decode side
// and tests.

/// Encode a `Pull` straight from a borrowed spec.
pub fn encode_pull(worker: usize, round: u64, spec: &PullSpec) -> Vec<u8> {
    let mut b = Vec::new();
    b.push(op::PULL);
    put_u32(&mut b, worker as u32);
    put_u64(&mut b, round);
    put_u32(&mut b, spec.ranges.len() as u32);
    for &(start, len) in &spec.ranges {
        put_u64(&mut b, start as u64);
        put_u64(&mut b, len as u64);
    }
    put_u32(&mut b, spec.keys.len() as u32);
    for &key in &spec.keys {
        put_u64(&mut b, key as u64);
    }
    b
}

/// Encode a `Flush` straight from the worker's coalesced batch.
pub fn encode_flush(
    worker: usize,
    block: u64,
    round: u64,
    seq: u64,
    deltas: &[(usize, f64)],
) -> Vec<u8> {
    let mut b = Vec::new();
    b.push(op::FLUSH);
    put_u32(&mut b, worker as u32);
    put_u64(&mut b, block);
    put_u64(&mut b, round);
    put_u64(&mut b, seq);
    put_pairs(&mut b, deltas);
    b
}

/// Encode a `Publish` straight from the coordinator's entry list.
pub fn encode_publish(version: u64, entries: &[(usize, f64)]) -> Vec<u8> {
    let mut b = Vec::new();
    b.push(op::PUBLISH);
    put_u64(&mut b, version);
    put_pairs(&mut b, entries);
    b
}

/// Encode a `PublishRange` straight from the seed/state slice.
pub fn encode_publish_range(version: u64, start: usize, values: &[f64]) -> Vec<u8> {
    let mut b = Vec::new();
    b.push(op::PUBLISH_RANGE);
    put_u64(&mut b, version);
    put_u64(&mut b, start as u64);
    put_u32(&mut b, values.len() as u32);
    for &v in values {
        put_f64(&mut b, v);
    }
    b
}

/// Encode a `PublishRangeF32` — a contiguous canonically-f32 state
/// slab shipped as raw little-endian f32 bytes (v5). Half the bytes of
/// [`encode_publish_range`] and no widen/narrow round trip for
/// problems whose canonical state is already f32.
pub fn encode_publish_range_f32(version: u64, start: usize, values: &[f32]) -> Vec<u8> {
    let mut b = Vec::new();
    b.push(op::PUBLISH_RANGE_F32);
    put_u64(&mut b, version);
    put_u64(&mut b, start as u64);
    put_u32(&mut b, values.len() as u32);
    for &v in values {
        b.extend_from_slice(&v.to_le_bytes());
    }
    b
}

/// Encode a `Flush` as a v5 run-compressed frame when any of its keys
/// land in a registered dense segment; fall back to the plain v4
/// [`encode_flush`] layout otherwise (an all-hashed batch gains
/// nothing from run headers). Returns the frame and the number of
/// compressed runs emitted (0 for the fallback) for the
/// `wire.runs_encoded` meter. Decodes back to the same
/// [`Request::Flush`] either way — compression is an encoding choice,
/// not a semantic one.
pub fn encode_flush_maybe_runs(
    worker: usize,
    block: u64,
    round: u64,
    seq: u64,
    deltas: &[(usize, f64)],
    map: &SegmentMap,
) -> (Vec<u8>, u64) {
    if map.is_empty() || !deltas.iter().any(|&(key, _)| map.covers(key)) {
        return (encode_flush(worker, block, round, seq, deltas), 0);
    }
    let mut b = Vec::new();
    b.push(op::FLUSH_RUNS);
    put_u32(&mut b, worker as u32);
    put_u64(&mut b, block);
    put_u64(&mut b, round);
    put_u64(&mut b, seq);
    let runs = put_runs(&mut b, deltas, map);
    (b, runs)
}

/// Encode a `Publish` as a v5 run-compressed frame; same fallback and
/// return convention as [`encode_flush_maybe_runs`].
pub fn encode_publish_maybe_runs(
    version: u64,
    entries: &[(usize, f64)],
    map: &SegmentMap,
) -> (Vec<u8>, u64) {
    if map.is_empty() || !entries.iter().any(|&(key, _)| map.covers(key)) {
        return (encode_publish(version, entries), 0);
    }
    let mut b = Vec::new();
    b.push(op::PUBLISH_RUNS);
    put_u64(&mut b, version);
    let runs = put_runs(&mut b, entries, map);
    (b, runs)
}

/// Encode a request into one frame payload (opcode + body).
pub fn encode_request(req: &Request) -> Vec<u8> {
    match req {
        Request::Init {
            worker,
            session,
            shards,
            workers,
            policy,
            segments,
            chunk_cells,
            route_index,
            route_servers,
        } => {
            let mut b = Vec::new();
            b.push(op::INIT);
            put_u16(&mut b, PROTO_VERSION);
            put_u32(&mut b, *worker as u32);
            put_u64(&mut b, *session);
            put_u32(&mut b, *shards as u32);
            put_u32(&mut b, *workers as u32);
            match policy {
                StalenessPolicy::Bounded(s) => {
                    b.push(0);
                    put_u64(&mut b, *s);
                }
                StalenessPolicy::Async => {
                    b.push(1);
                    put_u64(&mut b, 0);
                }
            }
            put_u32(&mut b, segments.len() as u32);
            for &(start, len) in segments {
                put_u64(&mut b, start as u64);
                put_u64(&mut b, len as u64);
            }
            put_u64(&mut b, *chunk_cells as u64);
            put_u32(&mut b, *route_index as u32);
            put_u32(&mut b, *route_servers as u32);
            b
        }
        Request::Pull { worker, round, spec } => encode_pull(*worker, *round, spec),
        Request::Flush { worker, block, round, seq, deltas } => {
            encode_flush(*worker, *block, *round, *seq, deltas)
        }
        Request::Publish { version, entries } => encode_publish(*version, entries),
        Request::PublishRange { version, start, values } => {
            encode_publish_range(*version, *start, values)
        }
        Request::PublishRangeF32 { version, start, values } => {
            encode_publish_range_f32(*version, *start, values)
        }
        Request::Advance { applied } => {
            let mut b = Vec::new();
            b.push(op::ADVANCE);
            put_u64(&mut b, *applied);
            b
        }
        Request::Stats => vec![op::STATS],
        Request::ShutdownClock => vec![op::SHUTDOWN_CLOCK],
        Request::ObsStats => vec![op::OBS_STATS],
        Request::Join { worker } => {
            let mut b = Vec::new();
            b.push(op::JOIN);
            put_u32(&mut b, *worker as u32);
            b
        }
        Request::Leave { worker } => {
            let mut b = Vec::new();
            b.push(op::LEAVE);
            put_u32(&mut b, *worker as u32);
            b
        }
    }
}

/// Decode one frame payload into a [`Request`].
pub fn decode_request(buf: &[u8]) -> Result<Request, WireError> {
    let mut r = Reader::new(buf);
    let opcode = r.u8()?;
    let req = match opcode {
        op::INIT => {
            let proto = r.u16()?;
            if !(MIN_PROTO_VERSION..=PROTO_VERSION).contains(&proto) {
                return Err(WireError(format!(
                    "protocol version mismatch: peer speaks v{proto}, this server \
                     v{MIN_PROTO_VERSION}..=v{PROTO_VERSION}"
                )));
            }
            let worker = r.u32()? as usize;
            let session = r.u64()?;
            let shards = r.u32()? as usize;
            let workers = r.u32()? as usize;
            let policy = match (r.u8()?, r.u64()?) {
                (0, s) => StalenessPolicy::Bounded(s),
                (1, _) => StalenessPolicy::Async,
                (tag, _) => return Err(WireError(format!("unknown policy tag {tag}"))),
            };
            let nseg = r.count(16)?;
            let mut segments = Vec::with_capacity(nseg);
            for _ in 0..nseg {
                segments.push((r.u64()? as usize, r.u64()? as usize));
            }
            let chunk_cells = r.u64()? as usize;
            // v5 peers end the frame here: the single-server route.
            let (route_index, route_servers) = if proto >= 6 {
                (r.u32()? as usize, r.u32()? as usize)
            } else {
                (0, 1)
            };
            if route_servers == 0 || route_index >= route_servers {
                return Err(WireError(format!(
                    "bad route {route_index}/{route_servers} in Init"
                )));
            }
            Request::Init {
                worker,
                session,
                shards,
                workers,
                policy,
                segments,
                chunk_cells,
                route_index,
                route_servers,
            }
        }
        op::PULL => {
            let worker = r.u32()? as usize;
            let round = r.u64()?;
            let nranges = r.count(16)?;
            let mut ranges = Vec::with_capacity(nranges);
            for _ in 0..nranges {
                ranges.push((r.u64()? as usize, r.u64()? as usize));
            }
            let nkeys = r.count(8)?;
            let mut keys = Vec::with_capacity(nkeys);
            for _ in 0..nkeys {
                keys.push(r.u64()? as usize);
            }
            Request::Pull { worker, round, spec: PullSpec { ranges, keys } }
        }
        op::FLUSH => {
            let worker = r.u32()? as usize;
            let block = r.u64()?;
            let round = r.u64()?;
            let seq = r.u64()?;
            let deltas = read_pairs(&mut r)?;
            Request::Flush { worker, block, round, seq, deltas }
        }
        op::FLUSH_RUNS => {
            let worker = r.u32()? as usize;
            let block = r.u64()?;
            let round = r.u64()?;
            let seq = r.u64()?;
            let deltas = read_runs(&mut r)?;
            Request::Flush { worker, block, round, seq, deltas }
        }
        op::PUBLISH => {
            let version = r.u64()?;
            let entries = read_pairs(&mut r)?;
            Request::Publish { version, entries }
        }
        op::PUBLISH_RUNS => {
            let version = r.u64()?;
            let entries = read_runs(&mut r)?;
            Request::Publish { version, entries }
        }
        op::PUBLISH_RANGE => {
            let version = r.u64()?;
            let start = r.u64()? as usize;
            let n = r.count(8)?;
            let mut values = Vec::with_capacity(n);
            for _ in 0..n {
                values.push(r.f64()?);
            }
            Request::PublishRange { version, start, values }
        }
        op::PUBLISH_RANGE_F32 => {
            let version = r.u64()?;
            let start = r.u64()? as usize;
            let n = r.count(4)?;
            let bytes = r.take(n * 4)?;
            let values = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().expect("chunks_exact(4)")))
                .collect();
            Request::PublishRangeF32 { version, start, values }
        }
        op::ADVANCE => Request::Advance { applied: r.u64()? },
        op::STATS => Request::Stats,
        op::SHUTDOWN_CLOCK => Request::ShutdownClock,
        op::OBS_STATS => Request::ObsStats,
        op::JOIN => Request::Join { worker: r.u32()? as usize },
        op::LEAVE => Request::Leave { worker: r.u32()? as usize },
        other => return Err(WireError(format!("unknown request opcode {other:#04x}"))),
    };
    r.finish()?;
    Ok(req)
}

// ---- replies -----------------------------------------------------------

/// Encode a reply into one frame payload. Range images are written as
/// raw f32 little-endian bytes straight off the (possibly shared) epoch
/// slab — 4 bytes per cell on the wire, exactly what the pull meter
/// charges.
pub fn encode_reply(reply: &Reply) -> Vec<u8> {
    let mut b = Vec::new();
    match reply {
        Reply::Ok => b.push(op::REPLY_OK),
        Reply::Pull { gap, waited, gate_us, ranges, cells } => {
            b.push(op::REPLY_PULL);
            put_u64(&mut b, *gap);
            b.push(u8::from(*waited));
            put_u64(&mut b, *gate_us);
            put_u32(&mut b, ranges.len() as u32);
            for range in ranges {
                put_u64(&mut b, range.start() as u64);
                put_u64(&mut b, range.version());
                let values = range.values();
                put_u32(&mut b, values.len() as u32);
                for &v in values {
                    b.extend_from_slice(&v.to_le_bytes());
                }
            }
            put_u32(&mut b, cells.len() as u32);
            for cell in cells {
                put_u64(&mut b, cell.version);
                put_f64(&mut b, cell.value);
            }
        }
        Reply::Flush { applied } => {
            b.push(op::REPLY_FLUSH);
            b.push(u8::from(*applied));
        }
        Reply::Stats(s) => {
            b.push(op::REPLY_STATS);
            for v in [
                s.bytes_flushed,
                s.bytes_republished,
                s.bytes_pulled,
                s.cells_pulled,
                s.snapshot_clones,
                s.flushes,
                s.pulls,
                s.stale_gap_sum,
                s.max_stale_gap,
                s.gate_waits,
                s.flushes_dropped,
                s.hash_probes,
                s.cow_clones,
                s.cow_bytes,
            ] {
                put_u64(&mut b, v);
            }
        }
        Reply::ObsStats(snap) => {
            b.push(op::REPLY_OBS_STATS);
            put_u16(&mut b, snap.version);
            put_u32(&mut b, snap.metrics.len() as u32);
            for (name, value) in &snap.metrics {
                put_u16(&mut b, name.len() as u16);
                b.extend_from_slice(name.as_bytes());
                match value {
                    MetricValue::Counter(v) => {
                        b.push(0);
                        put_u64(&mut b, *v);
                    }
                    MetricValue::Gauge(v) => {
                        b.push(1);
                        put_u64(&mut b, *v);
                    }
                    MetricValue::Histogram { bounds, counts, sum, count } => {
                        b.push(2);
                        put_u32(&mut b, bounds.len() as u32);
                        for &bound in bounds {
                            put_u64(&mut b, bound);
                        }
                        debug_assert_eq!(counts.len(), bounds.len() + 1);
                        for &c in counts {
                            put_u64(&mut b, c);
                        }
                        put_u64(&mut b, *sum);
                        put_u64(&mut b, *count);
                    }
                }
            }
            put_u32(&mut b, snap.segments.len() as u32);
            for &(start, len, version) in &snap.segments {
                put_u64(&mut b, start as u64);
                put_u64(&mut b, len as u64);
                put_u64(&mut b, version);
            }
            match &snap.clock {
                None => b.push(0),
                Some(clock) => {
                    b.push(1);
                    match clock.staleness_bound {
                        Some(s) => {
                            b.push(0);
                            put_u64(&mut b, s);
                        }
                        None => {
                            b.push(1);
                            put_u64(&mut b, 0);
                        }
                    }
                    put_u64(&mut b, clock.applied);
                    put_u32(&mut b, clock.worker_clocks.len() as u32);
                    for &c in &clock.worker_clocks {
                        put_u64(&mut b, c);
                    }
                }
            }
        }
        Reply::Err { shutdown, message } => {
            b.push(op::REPLY_ERR);
            b.push(u8::from(*shutdown));
            b.extend_from_slice(message.as_bytes());
        }
    }
    b
}

/// Decode one frame payload into a [`Reply`]. Pulled ranges come back
/// as owned f32 images ([`RangePull::owned`]) — bitwise identical to
/// the server's epoch slab, since f32 crosses the wire as its exact bit
/// pattern.
pub fn decode_reply(buf: &[u8]) -> Result<Reply, WireError> {
    let mut r = Reader::new(buf);
    let opcode = r.u8()?;
    let reply = match opcode {
        op::REPLY_OK => Reply::Ok,
        op::REPLY_PULL => {
            let gap = r.u64()?;
            let waited = r.u8()? != 0;
            let gate_us = r.u64()?;
            let nranges = r.count(20)?;
            let mut ranges = Vec::with_capacity(nranges);
            for _ in 0..nranges {
                let start = r.u64()? as usize;
                let version = r.u64()?;
                let len = r.count(4)?;
                let bytes = r.take(len * 4)?;
                let values = bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().expect("chunks_exact(4)")))
                    .collect();
                ranges.push(RangePull::owned(start, version, values));
            }
            let ncells = r.count(16)?;
            let mut cells = Vec::with_capacity(ncells);
            for _ in 0..ncells {
                cells.push(Cell { version: r.u64()?, value: r.f64()? });
            }
            Reply::Pull { gap, waited, gate_us, ranges, cells }
        }
        op::REPLY_FLUSH => Reply::Flush { applied: r.u8()? != 0 },
        op::REPLY_STATS => Reply::Stats(StatsSnapshot {
            bytes_flushed: r.u64()?,
            bytes_republished: r.u64()?,
            bytes_pulled: r.u64()?,
            cells_pulled: r.u64()?,
            snapshot_clones: r.u64()?,
            flushes: r.u64()?,
            pulls: r.u64()?,
            stale_gap_sum: r.u64()?,
            max_stale_gap: r.u64()?,
            gate_waits: r.u64()?,
            flushes_dropped: r.u64()?,
            hash_probes: r.u64()?,
            cow_clones: r.u64()?,
            cow_bytes: r.u64()?,
        }),
        op::REPLY_OBS_STATS => {
            let version = r.u16()?;
            // Minimum metric footprint: name_len (2) + kind (1) + one
            // u64 (8) — the hostile-count guard's element size.
            let nmetrics = r.count(11)?;
            let mut metrics = Vec::with_capacity(nmetrics);
            for _ in 0..nmetrics {
                let name_len = r.u16()? as usize;
                let name = String::from_utf8_lossy(r.take(name_len)?).into_owned();
                let value = match r.u8()? {
                    0 => MetricValue::Counter(r.u64()?),
                    1 => MetricValue::Gauge(r.u64()?),
                    2 => {
                        let nbounds = r.count(8)?;
                        let mut bounds = Vec::with_capacity(nbounds);
                        for _ in 0..nbounds {
                            bounds.push(r.u64()?);
                        }
                        let mut counts = Vec::with_capacity(nbounds + 1);
                        for _ in 0..nbounds + 1 {
                            counts.push(r.u64()?);
                        }
                        MetricValue::Histogram { bounds, counts, sum: r.u64()?, count: r.u64()? }
                    }
                    tag => return Err(WireError(format!("unknown metric kind {tag}"))),
                };
                metrics.push((name, value));
            }
            let nseg = r.count(24)?;
            let mut segments = Vec::with_capacity(nseg);
            for _ in 0..nseg {
                segments.push((r.u64()? as usize, r.u64()? as usize, r.u64()?));
            }
            let clock = match r.u8()? {
                0 => None,
                1 => {
                    let staleness_bound = match (r.u8()?, r.u64()?) {
                        (0, s) => Some(s),
                        (1, _) => None,
                        (tag, _) => {
                            return Err(WireError(format!("unknown policy tag {tag}")))
                        }
                    };
                    let applied = r.u64()?;
                    let nworkers = r.count(8)?;
                    let mut worker_clocks = Vec::with_capacity(nworkers);
                    for _ in 0..nworkers {
                        worker_clocks.push(r.u64()?);
                    }
                    Some(ClockView { applied, staleness_bound, worker_clocks })
                }
                tag => return Err(WireError(format!("unknown clock presence tag {tag}"))),
            };
            Reply::ObsStats(ObsSnapshot { version, metrics, segments, clock })
        }
        op::REPLY_ERR => {
            let shutdown = r.u8()? != 0;
            let raw = r.take(r.remaining())?;
            let message = String::from_utf8_lossy(raw).into_owned();
            Reply::Err { shutdown, message }
        }
        other => return Err(WireError(format!("unknown reply opcode {other:#04x}"))),
    };
    r.finish()?;
    Ok(reply)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_all_opcodes() {
        let reqs = vec![
            Request::Init {
                worker: u32::MAX as usize,
                session: 0xDEAD_BEEF_0000_0001,
                shards: 8,
                workers: 4,
                policy: StalenessPolicy::Bounded(2),
                segments: vec![(0, 100), (200, 50)],
                chunk_cells: 64,
                route_index: 1,
                route_servers: 2,
            },
            Request::Init {
                worker: 0,
                session: 0,
                shards: 1,
                workers: 1,
                policy: StalenessPolicy::Async,
                segments: vec![],
                chunk_cells: 0,
                route_index: 0,
                route_servers: 1,
            },
            Request::Pull {
                worker: 2,
                round: 7,
                spec: PullSpec { ranges: vec![(0, 10), (64, 3)], keys: vec![999, 3] },
            },
            Request::Flush {
                worker: 3,
                block: 11,
                round: 9,
                seq: 17,
                deltas: vec![(5, -0.25), (0, 1e300)],
            },
            Request::Publish { version: 4, entries: vec![(1, f64::MIN_POSITIVE)] },
            Request::PublishRange { version: 1, start: 16, values: vec![0.5, -0.5, 0.0] },
            Request::PublishRangeF32 {
                version: 2,
                start: 8,
                values: vec![0.5, -0.0, f32::MIN_POSITIVE],
            },
            Request::Advance { applied: u64::MAX },
            Request::Stats,
            Request::ShutdownClock,
            Request::ObsStats,
            Request::Join { worker: 4 },
            Request::Leave { worker: 1 },
        ];
        for req in reqs {
            let encoded = encode_request(&req);
            assert_eq!(decode_request(&encoded).unwrap(), req, "{req:?}");
        }
    }

    #[test]
    fn pull_reply_roundtrip_is_bitwise() {
        let reply = Reply::Pull {
            gap: 3,
            waited: true,
            gate_us: 1234,
            ranges: vec![
                RangePull::owned(5, 9, vec![1.5f32, -0.0, f32::MIN_POSITIVE]),
                RangePull::owned(100, 0, vec![]),
            ],
            cells: vec![Cell { version: 2, value: -1e-300 }],
        };
        let decoded = decode_reply(&encode_reply(&reply)).unwrap();
        let Reply::Pull { gap, waited, gate_us, ranges, cells } = decoded else {
            panic!("wrong reply kind");
        };
        assert_eq!((gap, waited, gate_us), (3, true, 1234));
        assert_eq!(ranges.len(), 2);
        assert_eq!(ranges[0].start(), 5);
        assert_eq!(ranges[0].version(), 9);
        // bitwise, not just approximate: -0.0 must survive
        let bits: Vec<u32> = ranges[0].values().iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, vec![1.5f32.to_bits(), (-0.0f32).to_bits(), f32::MIN_POSITIVE.to_bits()]);
        assert_eq!(ranges[1].len(), 0);
        assert_eq!(cells, vec![Cell { version: 2, value: -1e-300 }]);
    }

    #[test]
    fn flush_reply_roundtrip_carries_the_verdict() {
        for applied in [true, false] {
            let Reply::Flush { applied: back } =
                decode_reply(&encode_reply(&Reply::Flush { applied })).unwrap()
            else {
                panic!("wrong reply kind");
            };
            assert_eq!(back, applied);
        }
    }

    #[test]
    fn stats_and_err_roundtrip() {
        let snap = StatsSnapshot {
            bytes_flushed: 1,
            bytes_republished: 2,
            bytes_pulled: 3,
            cells_pulled: 4,
            snapshot_clones: 5,
            flushes: 6,
            pulls: 7,
            stale_gap_sum: 8,
            max_stale_gap: 9,
            gate_waits: 10,
            flushes_dropped: 13,
            hash_probes: 11,
            cow_clones: 12,
            cow_bytes: 14,
        };
        let Reply::Stats(back) = decode_reply(&encode_reply(&Reply::Stats(snap))).unwrap()
        else {
            panic!("wrong reply kind");
        };
        assert_eq!(back, snap);

        let err = Reply::Err { shutdown: true, message: "clock shutdown".into() };
        let Reply::Err { shutdown, message } = decode_reply(&encode_reply(&err)).unwrap()
        else {
            panic!("wrong reply kind");
        };
        assert!(shutdown);
        assert_eq!(message, "clock shutdown");
    }

    #[test]
    fn obs_snapshot_roundtrip_covers_every_metric_kind() {
        let snap = ObsSnapshot {
            version: 1,
            metrics: vec![
                (
                    "gate.wait_us".to_string(),
                    MetricValue::Histogram {
                        bounds: vec![10, 100, 1000],
                        counts: vec![1, 2, 3, 4],
                        sum: 999,
                        count: 10,
                    },
                ),
                ("net.socket_bytes".to_string(), MetricValue::Gauge(7)),
                ("ps.pulls".to_string(), MetricValue::Counter(42)),
            ],
            segments: vec![(0, 128, 5), (256, 64, 0)],
            clock: Some(ClockView {
                applied: 9,
                staleness_bound: Some(2),
                worker_clocks: vec![10, 9, 11],
            }),
        };
        let Reply::ObsStats(back) =
            decode_reply(&encode_reply(&Reply::ObsStats(snap.clone()))).unwrap()
        else {
            panic!("wrong reply kind");
        };
        assert_eq!(back, snap);

        // async clock and clock-less snapshots also round-trip
        let bare = ObsSnapshot {
            version: 1,
            metrics: vec![],
            segments: vec![],
            clock: Some(ClockView {
                applied: 0,
                staleness_bound: None,
                worker_clocks: vec![],
            }),
        };
        let Reply::ObsStats(back) =
            decode_reply(&encode_reply(&Reply::ObsStats(bare.clone()))).unwrap()
        else {
            panic!("wrong reply kind");
        };
        assert_eq!(back, bare);
        let none = ObsSnapshot { version: 1, metrics: vec![], segments: vec![], clock: None };
        let Reply::ObsStats(back) =
            decode_reply(&encode_reply(&Reply::ObsStats(none.clone()))).unwrap()
        else {
            panic!("wrong reply kind");
        };
        assert_eq!(back, none);
    }

    #[test]
    fn corrupt_frames_are_rejected_not_panicked() {
        // truncated
        let mut good = encode_request(&Request::Pull {
            worker: 1,
            round: 1,
            spec: PullSpec::from_keys(vec![1, 2, 3]),
        });
        good.truncate(good.len() - 3);
        assert!(decode_request(&good).is_err());
        // trailing garbage
        let mut padded = encode_request(&Request::Stats);
        padded.push(0xAB);
        assert!(decode_request(&padded).is_err());
        // bogus opcode
        assert!(decode_request(&[0x55]).is_err());
        assert!(decode_reply(&[0x55]).is_err());
        // hostile count: claims 2^31 entries in a tiny frame
        let mut hostile = vec![op::FLUSH];
        hostile.extend_from_slice(&3u32.to_le_bytes()); // worker
        hostile.extend_from_slice(&7u64.to_le_bytes()); // block
        hostile.extend_from_slice(&0u64.to_le_bytes()); // round
        hostile.extend_from_slice(&1u64.to_le_bytes()); // seq
        hostile.extend_from_slice(&0x8000_0000u32.to_le_bytes());
        assert!(decode_request(&hostile).is_err());
        // version mismatch refused
        let mut init = encode_request(&Request::Init {
            worker: 0,
            session: 1,
            shards: 1,
            workers: 1,
            policy: StalenessPolicy::Bounded(0),
            segments: vec![],
            chunk_cells: 0,
            route_index: 0,
            route_servers: 1,
        });
        init[1] = 0xFF; // clobber the proto version
        let err = decode_request(&init).unwrap_err();
        assert!(err.0.contains("version"), "{err}");
    }

    #[test]
    fn v5_init_still_decodes_without_the_route_fields() {
        // A v5 peer's Init is the v6 frame minus the two trailing
        // route u32s, with the proto field saying 5. Craft one from
        // the v6 encoder and it must decode with chunk_cells intact
        // and the degenerate single-server route (0, 1).
        let mut init = encode_request(&Request::Init {
            worker: 3,
            session: 77,
            shards: 2,
            workers: 4,
            policy: StalenessPolicy::Bounded(1),
            segments: vec![(0, 16), (32, 8)],
            chunk_cells: 9,
            route_index: 1, // dropped with the trailing bytes below
            route_servers: 2,
        });
        init.truncate(init.len() - 8);
        init[1..3].copy_from_slice(&(MIN_PROTO_VERSION).to_le_bytes());
        let back = decode_request(&init).unwrap();
        assert_eq!(
            back,
            Request::Init {
                worker: 3,
                session: 77,
                shards: 2,
                workers: 4,
                policy: StalenessPolicy::Bounded(1),
                segments: vec![(0, 16), (32, 8)],
                chunk_cells: 9,
                route_index: 0,
                route_servers: 1,
            }
        );
    }

    #[test]
    fn bogus_route_in_init_is_rejected() {
        let good = encode_request(&Request::Init {
            worker: 0,
            session: 1,
            shards: 1,
            workers: 1,
            policy: StalenessPolicy::Async,
            segments: vec![],
            chunk_cells: 0,
            route_index: 0,
            route_servers: 1,
        });
        // route_index >= route_servers: clobber the trailing 8 bytes
        let mut bad = good.clone();
        let n = bad.len();
        bad[n - 8..n - 4].copy_from_slice(&2u32.to_le_bytes());
        bad[n - 4..].copy_from_slice(&2u32.to_le_bytes());
        assert!(decode_request(&bad).unwrap_err().0.contains("route"));
        // route_servers == 0
        let mut zero = good;
        let n = zero.len();
        zero[n - 4..].copy_from_slice(&0u32.to_le_bytes());
        assert!(decode_request(&zero).unwrap_err().0.contains("route"));
    }

    /// The run codec's contract: whatever the batch, encoding then
    /// decoding yields the same entries the plain pairs layout would
    /// have applied — with covered values narrowed to f32, which is
    /// lossless for segment cells (the store narrows them anyway).
    fn assert_runs_roundtrip(entries: &[(usize, f64)], map: &SegmentMap) {
        let (frame, _) =
            encode_flush_maybe_runs(1, 2, 3, 4, entries, map);
        let Request::Flush { worker, block, round, seq, deltas } =
            decode_request(&frame).unwrap()
        else {
            panic!("wrong request kind");
        };
        assert_eq!((worker, block, round, seq), (1, 2, 3, 4));
        // decoded batches come back sorted (covered first); compare as
        // key -> f64-bits maps since application order is immaterial
        // for unique-key batches
        let narrow = |&(key, v): &(usize, f64)| {
            if map.covers(key) {
                (key, ((v as f32) as f64).to_bits())
            } else {
                (key, v.to_bits())
            }
        };
        let mut want: Vec<_> = entries.iter().map(narrow).collect();
        want.sort_unstable();
        let mut got: Vec<_> =
            deltas.iter().map(|&(key, v)| (key, v.to_bits())).collect();
        got.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn run_codec_roundtrips_the_issue_grid() {
        let map = SegmentMap::new(&[(0, 64), (100, 16)]);
        // empty batch: falls back to the plain layout, zero runs
        let (frame, runs) = encode_flush_maybe_runs(1, 2, 3, 4, &[], &map);
        assert_eq!(runs, 0);
        assert_eq!(frame[0], op::FLUSH);
        assert_runs_roundtrip(&[], &map);
        // single covered cell
        assert_runs_roundtrip(&[(5, 1.25)], &map);
        // full-dense segment, in scrambled order
        let mut dense: Vec<(usize, f64)> =
            (0..64).map(|i| (i, i as f64 * 0.5 - 3.0)).collect();
        dense.reverse();
        dense.swap(0, 40);
        let (frame, runs) = encode_flush_maybe_runs(1, 2, 3, 4, &dense, &map);
        assert_eq!(runs, 1, "one dense run for one full segment");
        // dense run: opcode + header(28) + nruns(4) + tag(1) +
        // start(8) + count(4) + 64 * 4 raw bytes — vs 29 + 4 + 64*16
        // for the pairs layout
        assert_eq!(frame.len(), 1 + 28 + 4 + 1 + 8 + 4 + 64 * 4);
        assert_runs_roundtrip(&dense, &map);
        // -0.0 and subnormals survive bitwise through the f32 narrowing
        assert_runs_roundtrip(
            &[(0, -0.0), (1, f32::MIN_POSITIVE as f64 / 4.0), (2, -1e-42), (3, 7.0)],
            &map,
        );
        // adversarial index gaps: scattered covered singles (sparse
        // run), a dense stretch, a just-too-short stretch, and hashed
        // strays far outside every segment
        assert_runs_roundtrip(
            &[
                (0, 1.0),
                (9, 2.0),
                (30, 3.0),
                (31, 4.0),
                (32, 5.0),
                (40, 6.0),
                (41, 7.0),
                (42, 8.0),
                (43, 9.0),
                (100, -1.0),
                (115, -2.0),
                (70, 1e300),
                (1 << 40, -1e-300),
            ],
            &map,
        );
        // all-uncovered batch: plain fallback, full f64 fidelity
        let (frame, runs) =
            encode_flush_maybe_runs(1, 2, 3, 4, &[(70, 1e300), (99, -1e-300)], &map);
        assert_eq!(runs, 0);
        assert_eq!(frame[0], op::FLUSH);
        // publish side shares the codec
        let (frame, runs) = encode_publish_maybe_runs(9, &dense, &map);
        assert_eq!(runs, 1);
        let Request::Publish { version, entries } = decode_request(&frame).unwrap()
        else {
            panic!("wrong request kind");
        };
        assert_eq!(version, 9);
        assert_eq!(entries.len(), 64);
    }

    #[test]
    fn run_codec_seeded_fuzz_roundtrips() {
        // deterministic xorshift so failures replay exactly
        let mut state = 0x9E37_79B9_7F4A_7C15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        let map = SegmentMap::new(&[(0, 256), (1000, 32)]);
        for _ in 0..200 {
            let n = (next() % 48) as usize;
            let mut keys = std::collections::BTreeSet::new();
            while keys.len() < n {
                let key = match next() % 4 {
                    0 => (next() % 256) as usize,          // covered, seg 0
                    1 => 1000 + (next() % 32) as usize,    // covered, seg 1
                    2 => 256 + (next() % 700) as usize,    // uncovered gap
                    _ => (next() % (1 << 50)) as usize,    // far hashed
                };
                keys.insert(key);
            }
            let entries: Vec<(usize, f64)> = keys
                .into_iter()
                .map(|key| {
                    let bits = next();
                    let v = f64::from_bits(bits);
                    (key, if v.is_nan() { 0.5 } else { v })
                })
                .collect();
            assert_runs_roundtrip(&entries, &map);
        }
    }

    #[test]
    fn hostile_run_frames_are_rejected_not_panicked() {
        let header = |opcode: u8| {
            let mut b = vec![opcode];
            put_u32(&mut b, 1); // worker
            put_u64(&mut b, 2); // block
            put_u64(&mut b, 3); // round
            put_u64(&mut b, 4); // seq
            b
        };
        // claims 2^30 runs in a tiny frame
        let mut hostile = header(op::FLUSH_RUNS);
        put_u32(&mut hostile, 1 << 30);
        assert!(decode_request(&hostile).is_err());
        // dense run promising more cells than the frame carries
        let mut short = header(op::FLUSH_RUNS);
        put_u32(&mut short, 1);
        short.push(0); // dense tag
        put_u64(&mut short, 0); // start
        put_u32(&mut short, 1000); // count, but no payload follows
        assert!(decode_request(&short).is_err());
        // dense run whose start + count overflows the key space
        let mut wrap = header(op::FLUSH_RUNS);
        put_u32(&mut wrap, 1);
        wrap.push(0);
        put_u64(&mut wrap, u64::MAX - 1);
        put_u32(&mut wrap, 4);
        wrap.extend_from_slice(&[0u8; 16]);
        assert!(decode_request(&wrap).is_err());
        // sparse run with a count its payload can't back
        let mut sparse = header(op::FLUSH_RUNS);
        put_u32(&mut sparse, 1);
        sparse.push(1); // sparse tag
        put_u64(&mut sparse, 0); // base
        put_u32(&mut sparse, 500); // count with no entries
        assert!(decode_request(&sparse).is_err());
        // unknown run tag
        let mut tagged = header(op::FLUSH_RUNS);
        put_u32(&mut tagged, 1);
        tagged.push(9);
        assert!(decode_request(&tagged).is_err());
        // publish side shares the guards
        let mut pub_hostile = vec![op::PUBLISH_RUNS];
        put_u64(&mut pub_hostile, 1); // version
        put_u32(&mut pub_hostile, 1 << 30);
        assert!(decode_request(&pub_hostile).is_err());
        // f32 range publish promising more cells than it carries
        let mut range = vec![op::PUBLISH_RANGE_F32];
        put_u64(&mut range, 1); // version
        put_u64(&mut range, 0); // start
        put_u32(&mut range, 1 << 30);
        assert!(decode_request(&range).is_err());
    }

    #[test]
    fn framing_roundtrip_and_bad_length() {
        let msg = encode_request(&Request::Advance { applied: 42 });
        let mut pipe = Vec::new();
        let written = write_frame(&mut pipe, &msg).unwrap();
        assert_eq!(written as usize, 4 + msg.len());
        let mut buf = Vec::new();
        let read = read_frame(&mut &pipe[..], &mut buf).unwrap();
        assert_eq!(read, written);
        assert_eq!(buf, msg);
        // zero-length and oversized frames are invalid data, on both
        // the read and the write side
        let zero = 0u32.to_le_bytes();
        assert!(read_frame(&mut &zero[..], &mut buf).is_err());
        let huge = (MAX_FRAME + 1).to_le_bytes();
        assert!(read_frame(&mut &huge[..], &mut buf).is_err());
        assert!(write_frame(&mut Vec::new(), &[]).is_err());
        // mid-stream EOF: the header promises more payload than the
        // stream holds — a clean Io error, never a hang or panic
        let mut eof = Vec::new();
        write_frame(&mut eof, &msg).unwrap();
        eof.truncate(eof.len() - 2);
        let err = read_frame(&mut &eof[..], &mut buf).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    }
}
