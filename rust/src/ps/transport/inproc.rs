//! The shared-memory transport: a thin adapter from [`Transport`] onto
//! an `Arc<ParameterServer>` in the same address space. This is the
//! pre-transport execution path verbatim — every operation delegates to
//! the same serve helpers the TCP server calls, so refactoring the
//! client onto the trait changed *where* the calls route, not what they
//! do (the staleness-0 trajectories are pinned unchanged by the parity
//! suite). Pulls keep their zero-copy property: a covered range comes
//! back as the store's own `Arc`-shared epoch view, untouched by any
//! serialization.

use super::{PullReply, Transport, TransportError};
use crate::obs::ObsSnapshot;
use crate::ps::shard::PullSpec;
use crate::ps::{ParameterServer, StatsSnapshot};
use std::sync::Arc;

/// One endpoint's in-process link to the server.
pub struct InProcTransport {
    server: Arc<ParameterServer>,
    worker: usize,
}

impl InProcTransport {
    pub fn new(server: Arc<ParameterServer>, worker: usize) -> Self {
        InProcTransport { server, worker }
    }

    /// The shared server (tests reach through to its store/clock).
    pub fn server(&self) -> &Arc<ParameterServer> {
        &self.server
    }
}

impl Transport for InProcTransport {
    fn pull(&mut self, spec: &PullSpec, round: u64) -> Result<PullReply, TransportError> {
        let (pulled, gap, waited, gate_us) = self
            .server
            .serve_pull(self.worker, spec, round)
            .map_err(|_| TransportError::Shutdown)?;
        Ok(PullReply { ranges: pulled.ranges, cells: pulled.cells, gap, waited, gate_us })
    }

    fn flush(
        &mut self,
        deltas: &[(usize, f64)],
        round: u64,
        block: u64,
    ) -> Result<bool, TransportError> {
        Ok(self.server.serve_flush(self.worker, block, deltas, round))
    }

    fn join(&mut self, worker: usize) -> Result<(), TransportError> {
        self.server.serve_join(worker);
        Ok(())
    }

    fn leave(&mut self, worker: usize) -> Result<(), TransportError> {
        self.server.serve_leave(worker);
        Ok(())
    }

    fn publish(
        &mut self,
        entries: &[(usize, f64)],
        version: u64,
    ) -> Result<(), TransportError> {
        self.server.serve_publish(entries, version);
        Ok(())
    }

    fn publish_range(
        &mut self,
        start: usize,
        values: &[f64],
        version: u64,
    ) -> Result<(), TransportError> {
        self.server.store().publish_range(start, values, version);
        Ok(())
    }

    fn publish_range_f32(
        &mut self,
        start: usize,
        values: &[f32],
        version: u64,
    ) -> Result<(), TransportError> {
        self.server.store().publish_range_f32(start, values, version);
        Ok(())
    }

    fn advance_applied(&mut self, applied: u64) -> Result<(), TransportError> {
        self.server.serve_advance(applied);
        Ok(())
    }

    fn stats(&mut self) -> Result<StatsSnapshot, TransportError> {
        Ok(self.server.stats_snapshot())
    }

    fn obs_stats(&mut self) -> Result<ObsSnapshot, TransportError> {
        Ok(self.server.obs_snapshot())
    }

    fn shutdown_clock(&mut self) -> Result<(), TransportError> {
        self.server.clock().shutdown();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::StalenessPolicy;

    #[test]
    fn inproc_pull_is_still_zero_copy() {
        let server = Arc::new(ParameterServer::with_segments(
            2,
            1,
            StalenessPolicy::Bounded(0),
            &[(0, 8)],
        ));
        server.store().publish_dense(&[1.0; 8], 0);
        let mut t = InProcTransport::new(Arc::clone(&server), 0);
        let reply = t.pull(&PullSpec::from_ranges(vec![(0, 8)]), 0).unwrap();
        assert!(reply.ranges[0].is_shared(), "must be the shared epoch view, not a copy");
        assert_eq!(server.stats_snapshot().snapshot_clones, 1);
    }
}
