//! The TCP transport: [`TcpTransport`] is the client side (one socket
//! per endpoint, synchronous framed RPC — see [`wire`]), and
//! [`PsTcpServer`] hosts a [`ParameterServer`] behind a listener
//! (`strads ps-server`). The server is problem-agnostic: a run's
//! coordinator sends `Init` (shape: shards, workers, policy, dense
//! segments) and then seeds state with `PublishRange`, so one server
//! process serves any `ModelProblem` and any number of back-to-back
//! runs (each `Init` replaces the previous server instance). Proto v3
//! adds fault tolerance on top: an `Init` whose nonzero session id
//! matches the hosted run *reattaches* instead of replacing (the
//! retry wrapper's idempotent re-handshake), `Flush` carries a
//! per-worker seq the server dedups, and `bind_with` can periodically
//! checkpoint the hosted run and restore it on restart.
//!
//! Threading: one OS thread per connection. This is deliberate — a
//! worker's pull legitimately *blocks* at the server-side SSP gate
//! until the applied clock admits it, exactly like the in-process gate,
//! so connections must not share an event loop. Teardown paths:
//! `ShutdownClock` wakes every gate waiter (their pulls return the
//! `shutdown` error reply, which clients surface as
//! [`TransportError::Shutdown`]); a dead client just drops its
//! connection thread; [`PsTcpServer::stop`] force-closes everything.

use super::wire::{self, Reply, Request};
use super::{PullReply, Transport, TransportError};
use crate::obs::ObsSnapshot;
use crate::ps::checkpoint::{read_checkpoint, CheckpointConfig, CheckpointImage};
use crate::ps::clock::{ClockShutdown, StalenessPolicy};
use crate::ps::shard::PullSpec;
use crate::ps::{ParameterServer, StatsSnapshot};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

// ---- client ------------------------------------------------------------

/// One endpoint's socket to a `ps-server`, counting every byte it moves
/// (frame headers included) into the shared `socket_bytes` meter.
pub struct TcpTransport {
    stream: TcpStream,
    worker: usize,
    socket_bytes: Arc<AtomicU64>,
    /// This worker's monotonic flush seq (proto v3 dedup key). Shared
    /// via [`TcpTransport::connect_with`] so a retry wrapper's
    /// replacement sockets continue the same sequence — a retried
    /// flush rewinds the counter and re-mints the *same* seq.
    flush_seq: Arc<AtomicU64>,
    /// Reusable receive buffer (frames overwrite it).
    buf: Vec<u8>,
    /// When set (see [`TcpTransport::enable_compression`]), `Flush` and
    /// `Publish` batches go out as proto-v5 sorted value runs: covered
    /// keys as f32 runs, uncovered keys as f64 pairs (see
    /// [`wire::SegmentMap`]). `None` keeps the plain v4 pair frames.
    compress: Option<wire::SegmentMap>,
    /// Compressed (f32) runs this link has encoded — summed across
    /// links into the run-wide `wire.runs_encoded` meter.
    runs_encoded: Arc<AtomicU64>,
}

impl TcpTransport {
    /// Connect to `addr`. Fails fast (no retry loop): a missing server
    /// is an operator error the caller should see immediately.
    pub fn connect(
        addr: &str,
        worker: usize,
        socket_bytes: Arc<AtomicU64>,
    ) -> Result<Self, TransportError> {
        Self::connect_with(addr, worker, socket_bytes, Arc::new(AtomicU64::new(0)))
    }

    /// [`TcpTransport::connect`] with a caller-owned flush-seq counter,
    /// so a reconnecting wrapper keeps one sequence across sockets.
    pub fn connect_with(
        addr: &str,
        worker: usize,
        socket_bytes: Arc<AtomicU64>,
        flush_seq: Arc<AtomicU64>,
    ) -> Result<Self, TransportError> {
        let stream = TcpStream::connect(addr)?;
        // One small frame per RPC: Nagle would serialize the whole run
        // onto 40ms ACK-delay ticks.
        stream.set_nodelay(true)?;
        Ok(TcpTransport {
            stream,
            worker,
            socket_bytes,
            flush_seq,
            buf: Vec::new(),
            compress: None,
            runs_encoded: Arc::new(AtomicU64::new(0)),
        })
    }

    /// Turn on v5 run compression for this link's `Flush`/`Publish`
    /// frames. `map` must mirror the segments the server registered
    /// (both sides classify keys identically); `runs_encoded` is the
    /// shared run counter the link adds its compressed runs to.
    /// Compression is a per-frame opcode choice, not a handshake — a
    /// v5 server decodes plain and run frames alike.
    pub fn enable_compression(
        &mut self,
        map: wire::SegmentMap,
        runs_encoded: Arc<AtomicU64>,
    ) {
        self.compress = Some(map);
        self.runs_encoded = runs_encoded;
    }

    /// Send `Init`, (re)configuring the hosted server for this run. A
    /// nonzero `session` matching the hosted run reattaches to it
    /// (idempotent re-`Init` after a reconnect) instead of replacing.
    /// This single-server form announces the degenerate route `(0, 1)`;
    /// routed fleets go through [`TcpTransport::init_routed`].
    pub fn init(
        &mut self,
        session: u64,
        shards: usize,
        workers: usize,
        policy: StalenessPolicy,
        segments: &[(usize, usize)],
        chunk_cells: usize,
    ) -> Result<(), TransportError> {
        self.init_routed(session, shards, workers, policy, segments, chunk_cells, 0, 1)
    }

    /// [`TcpTransport::init`] announcing this link's place in a routed
    /// fleet: the server is `route_index` of `route_servers`, and
    /// `segments` are the sub-segments it owns (see
    /// [`super::RouteMap::server_segments`]). The route is
    /// informational on the server side — it labels the reporter and
    /// `ps-stats` output via the `route.*` gauges.
    #[allow(clippy::too_many_arguments)]
    pub fn init_routed(
        &mut self,
        session: u64,
        shards: usize,
        workers: usize,
        policy: StalenessPolicy,
        segments: &[(usize, usize)],
        chunk_cells: usize,
        route_index: usize,
        route_servers: usize,
    ) -> Result<(), TransportError> {
        let req = Request::Init {
            worker: self.worker,
            session,
            shards,
            workers,
            policy,
            segments: segments.to_vec(),
            chunk_cells,
            route_index,
            route_servers,
        };
        match self.rpc(&req)? {
            Reply::Ok => Ok(()),
            other => Err(unexpected(&other)),
        }
    }

    /// One synchronous RPC from an already-encoded frame payload:
    /// frame out, frame in, meter both directions.
    fn exchange(&mut self, msg: Vec<u8>) -> Result<Reply, TransportError> {
        let sent = wire::write_frame(&mut self.stream, &msg)?;
        let received = wire::read_frame(&mut self.stream, &mut self.buf)?;
        self.socket_bytes.fetch_add(sent + received, Ordering::Relaxed);
        match wire::decode_reply(&self.buf)? {
            Reply::Err { shutdown: true, .. } => Err(TransportError::Shutdown),
            Reply::Err { shutdown: false, message } => Err(TransportError::Remote(message)),
            reply => Ok(reply),
        }
    }

    fn rpc(&mut self, req: &Request) -> Result<Reply, TransportError> {
        self.exchange(wire::encode_request(req))
    }
}

fn unexpected(reply: &Reply) -> TransportError {
    TransportError::Protocol(format!("unexpected reply kind: {reply:?}"))
}

/// ` server=i/N shards=[lo..hi)` suffix for the reporter digest: which
/// member of a routed fleet this process is and the key span it hosts
/// — the line that makes N identical-looking `ps-server` digests
/// tellable apart. Empty for a pre-v6 run with no segments.
fn shard_label(snap: &crate::obs::ObsSnapshot) -> String {
    let mut label = String::new();
    let servers = snap.get("route.servers").map(|v| v.as_u64()).unwrap_or(0);
    if servers > 0 {
        let index = snap.get("route.index").map(|v| v.as_u64()).unwrap_or(0);
        label.push_str(&format!(" server={index}/{servers}"));
    }
    if !snap.segments.is_empty() {
        let lo = snap.segments.iter().map(|&(s, _, _)| s).min().unwrap();
        let hi = snap.segments.iter().map(|&(s, l, _)| s + l).max().unwrap();
        label.push_str(&format!(" shards=[{lo}..{hi})"));
    }
    label
}

impl Transport for TcpTransport {
    fn pull(&mut self, spec: &PullSpec, round: u64) -> Result<PullReply, TransportError> {
        match self.exchange(wire::encode_pull(self.worker, round, spec))? {
            Reply::Pull { gap, waited, gate_us, ranges, cells } => {
                Ok(PullReply { ranges, cells, gap, waited, gate_us })
            }
            other => Err(unexpected(&other)),
        }
    }

    fn flush(
        &mut self,
        deltas: &[(usize, f64)],
        round: u64,
        block: u64,
    ) -> Result<bool, TransportError> {
        let seq = self.flush_seq.fetch_add(1, Ordering::SeqCst) + 1;
        let msg = match &self.compress {
            Some(map) => {
                let (msg, runs) =
                    wire::encode_flush_maybe_runs(self.worker, block, round, seq, deltas, map);
                self.runs_encoded.fetch_add(runs, Ordering::Relaxed);
                msg
            }
            None => wire::encode_flush(self.worker, block, round, seq, deltas),
        };
        match self.exchange(msg)? {
            Reply::Flush { applied } => Ok(applied),
            other => Err(unexpected(&other)),
        }
    }

    fn join(&mut self, worker: usize) -> Result<(), TransportError> {
        match self.rpc(&Request::Join { worker })? {
            Reply::Ok => Ok(()),
            other => Err(unexpected(&other)),
        }
    }

    fn leave(&mut self, worker: usize) -> Result<(), TransportError> {
        match self.rpc(&Request::Leave { worker })? {
            Reply::Ok => Ok(()),
            other => Err(unexpected(&other)),
        }
    }

    fn publish(
        &mut self,
        entries: &[(usize, f64)],
        version: u64,
    ) -> Result<(), TransportError> {
        let msg = match &self.compress {
            Some(map) => {
                let (msg, runs) = wire::encode_publish_maybe_runs(version, entries, map);
                self.runs_encoded.fetch_add(runs, Ordering::Relaxed);
                msg
            }
            None => wire::encode_publish(version, entries),
        };
        match self.exchange(msg)? {
            Reply::Ok => Ok(()),
            other => Err(unexpected(&other)),
        }
    }

    fn publish_range(
        &mut self,
        start: usize,
        values: &[f64],
        version: u64,
    ) -> Result<(), TransportError> {
        match self.exchange(wire::encode_publish_range(version, start, values))? {
            Reply::Ok => Ok(()),
            other => Err(unexpected(&other)),
        }
    }

    fn publish_range_f32(
        &mut self,
        start: usize,
        values: &[f32],
        version: u64,
    ) -> Result<(), TransportError> {
        match self.exchange(wire::encode_publish_range_f32(version, start, values))? {
            Reply::Ok => Ok(()),
            other => Err(unexpected(&other)),
        }
    }

    fn advance_applied(&mut self, applied: u64) -> Result<(), TransportError> {
        match self.rpc(&Request::Advance { applied })? {
            Reply::Ok => Ok(()),
            other => Err(unexpected(&other)),
        }
    }

    fn stats(&mut self) -> Result<StatsSnapshot, TransportError> {
        match self.rpc(&Request::Stats)? {
            Reply::Stats(s) => Ok(s),
            other => Err(unexpected(&other)),
        }
    }

    fn obs_stats(&mut self) -> Result<ObsSnapshot, TransportError> {
        match self.rpc(&Request::ObsStats)? {
            Reply::ObsStats(snap) => Ok(snap),
            other => Err(unexpected(&other)),
        }
    }

    fn shutdown_clock(&mut self) -> Result<(), TransportError> {
        match self.rpc(&Request::ShutdownClock)? {
            Reply::Ok => Ok(()),
            other => Err(unexpected(&other)),
        }
    }
}

// ---- server ------------------------------------------------------------

struct ServerState {
    /// The hosted server; `None` until the first `Init` arrives (or a
    /// checkpoint restore pre-installs one at bind time).
    server: Option<Arc<ParameterServer>>,
    /// The hosted run's session id (0 = pre-session run): the key that
    /// lets a reconnecting client's re-`Init` reattach.
    session: u64,
    /// Highest flush seq applied per worker — the dedup ledger that
    /// makes retried flushes exactly-once. Guarded by the same lock as
    /// the apply (see the `Flush` arm), and checkpointed with the run.
    /// Grows on `Join` so mid-run joiners get their own sequence slot.
    flush_seqs: Vec<u64>,
    /// The exactly-once verdict each worker's latest flush earned,
    /// parallel to `flush_seqs`: a retried duplicate is acked with the
    /// verdict of its original delivery, so the client can never see
    /// `applied = true` for deltas the store dropped (or vice versa).
    flush_verdicts: Vec<bool>,
    /// Worker ids that have attached (sent a session-matching `Init`)
    /// to the hosted run. A re-`Init` from an id already here is a
    /// *reconnect* (counted in the registry's `net.reconnects`); the
    /// first attach per link is not.
    attached: std::collections::HashSet<usize>,
    /// Applied-clock advances served for this run (periodic-checkpoint
    /// cadence counter).
    clock_ticks: u64,
}

struct ServerShared {
    state: Mutex<ServerState>,
    /// Checkpointing, when enabled (`--checkpoint-dir`).
    ckpt: Option<CheckpointConfig>,
    /// Signaled on `Init` (and on stop) so early worker connections can
    /// park until the coordinator has configured the run.
    installed: Condvar,
    stop: AtomicBool,
    /// Clones of every *live* connection keyed by connection id, so
    /// `stop` can force-close them. Entries are pruned when their
    /// handler exits — a long-lived server must not leak one fd per
    /// connection it ever served.
    conns: Mutex<std::collections::HashMap<u64, TcpStream>>,
    /// Monotonic connection-id source.
    next_conn_id: AtomicU64,
}

/// A listening parameter-server host. `bind` spawns the accept loop;
/// the process-level entry point (`strads ps-server`) then parks on
/// [`PsTcpServer::run`], while tests drive [`PsTcpServer::stop`].
pub struct PsTcpServer {
    local_addr: SocketAddr,
    shared: Arc<ServerShared>,
    accept: Option<JoinHandle<()>>,
}

impl PsTcpServer {
    /// Bind `addr` (use port 0 for an ephemeral test port) and start
    /// accepting connections.
    pub fn bind(addr: &str) -> anyhow::Result<Self> {
        Self::bind_with(addr, None)
    }

    /// [`PsTcpServer::bind`] with checkpointing: the hosted run is
    /// dumped to `ckpt.dir` every `ckpt.every` applied-clock advances
    /// and on graceful [`PsTcpServer::stop`], and if the directory
    /// already holds a checkpoint the run is restored from it *before*
    /// the first connection is accepted — reconnecting clients
    /// re-`Init` with their session id and reattach where they left
    /// off (no re-zeroed epochs, no rewound clock).
    pub fn bind_with(addr: &str, ckpt: Option<CheckpointConfig>) -> anyhow::Result<Self> {
        let restored = match ckpt.as_ref() {
            Some(cfg) => read_checkpoint(&cfg.dir)?,
            None => None,
        };
        let state = match restored {
            Some(r) => {
                eprintln!(
                    "[ckpt] restored session {} (applied clock {})",
                    r.session,
                    r.server.clock().applied()
                );
                let verdicts = vec![true; r.flush_seqs.len()];
                ServerState {
                    server: Some(Arc::new(r.server)),
                    session: r.session,
                    flush_seqs: r.flush_seqs,
                    flush_verdicts: verdicts,
                    attached: std::collections::HashSet::new(),
                    clock_ticks: 0,
                }
            }
            None => ServerState {
                server: None,
                session: 0,
                flush_seqs: Vec::new(),
                flush_verdicts: Vec::new(),
                attached: std::collections::HashSet::new(),
                clock_ticks: 0,
            },
        };
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("ps-server bind {addr}: {e}"))?;
        let local_addr = listener.local_addr()?;
        let shared = Arc::new(ServerShared {
            state: Mutex::new(state),
            ckpt,
            installed: Condvar::new(),
            stop: AtomicBool::new(false),
            conns: Mutex::new(std::collections::HashMap::new()),
            next_conn_id: AtomicU64::new(0),
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::spawn(move || accept_loop(listener, accept_shared));
        Ok(PsTcpServer { local_addr, shared, accept: Some(accept) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Start the periodic self-report (`[obs] report_secs` /
    /// `--report-secs`): a detached thread that prints a one-line
    /// registry digest to stderr every `secs` seconds. It polls the
    /// stop flag once a second so `stop()` never blocks on it, and it
    /// says so (idle) while no run has initialized the server.
    pub fn spawn_reporter(&self, secs: u64) {
        let secs = secs.max(1);
        let shared = Arc::clone(&self.shared);
        std::thread::spawn(move || loop {
            for _ in 0..secs {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                std::thread::sleep(std::time::Duration::from_secs(1));
            }
            let server = shared.state.lock().expect("state lock").server.as_ref().cloned();
            match server {
                Some(server) => {
                    let snap = server.obs_snapshot();
                    let metric = |name: &str| snap.get(name).map(|v| v.as_u64()).unwrap_or(0);
                    let applied = snap.clock.as_ref().map(|c| c.applied).unwrap_or(0);
                    eprintln!(
                        "[obs]{} applied={} pulls={} pull_bytes={} flushes={} gate_waits={} \
                         reconnects={} ckpt_writes={}",
                        shard_label(&snap),
                        applied,
                        metric("ps.pulls"),
                        metric("ps.pull_bytes"),
                        metric("ps.flushes"),
                        metric("ps.gate_waits"),
                        metric("net.reconnects"),
                        metric("ckpt.writes"),
                    );
                }
                None => eprintln!("[obs] idle (no run initialized)"),
            }
        });
    }

    /// Serve until the process dies (the `strads ps-server` loop).
    pub fn run(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Tear the server down: wake gate waiters, close every live
    /// connection (clients see a clean I/O error, never a hang), and
    /// join the accept loop. Used by tests and the kill-path suite.
    pub fn stop(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // Final checkpoint while the state is still consistent. Hard
        // kills (SIGKILL) are covered by the periodic writes instead —
        // there is no dependency-free way to catch a signal here.
        checkpoint_now(&self.shared);
        // Close the sockets *before* shutting the clock: clients (and
        // handlers parked at the SSP gate) then observe an Io error —
        // the same retriable failure a crash produces — rather than a
        // fatal shutdown reply. A retry-wrapped client can therefore
        // ride out a graceful stop + restart exactly like a kill.
        for (_, conn) in self.shared.conns.lock().expect("conns lock").drain() {
            let _ = conn.shutdown(Shutdown::Both);
        }
        if let Some(server) = self.shared.state.lock().expect("state lock").server.as_ref() {
            server.clock().shutdown();
        }
        self.shared.installed.notify_all();
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<ServerShared>) {
    for stream in listener.incoming() {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        let conn_id = shared.next_conn_id.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            shared.conns.lock().expect("conns lock").insert(conn_id, clone);
        }
        let conn_shared = Arc::clone(&shared);
        std::thread::spawn(move || {
            handle_conn(&conn_shared, stream);
            // Prune our clone so a long-lived server never accumulates
            // fds for connections that already hung up.
            conn_shared.conns.lock().expect("conns lock").remove(&conn_id);
        });
    }
}

/// Block until an `Init` has installed a server (or the host stops).
fn wait_server(shared: &ServerShared) -> Option<Arc<ParameterServer>> {
    let mut state = shared.state.lock().expect("state lock");
    loop {
        if let Some(server) = state.server.as_ref() {
            return Some(Arc::clone(server));
        }
        if shared.stop.load(Ordering::SeqCst) {
            return None;
        }
        state = shared.installed.wait(state).expect("state lock");
    }
}

fn handle_conn(shared: &ServerShared, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let mut buf = Vec::new();
    loop {
        // A read error here is the client going away — not a fault.
        if wire::read_frame(&mut stream, &mut buf).is_err() {
            return;
        }
        let reply = match wire::decode_request(&buf) {
            Ok(req) => dispatch(shared, req),
            Err(e) => Reply::Err { shutdown: false, message: e.0 },
        };
        let msg = wire::encode_reply(&reply);
        if wire::write_frame(&mut stream, &msg).is_err() {
            return;
        }
    }
}

fn dispatch(shared: &ServerShared, req: Request) -> Reply {
    // Init is the one request served without a hosted server; the
    // rebinding keeps `req` whole for the second match below.
    // ObsStats is the other: `strads ps-stats` must be able to probe an
    // idle server without parking at the installed-condvar, so a
    // pre-Init probe gets a non-shutdown error, not a hang.
    let req = match req {
        Request::ObsStats => {
            return match shared.state.lock().expect("state lock").server.as_ref() {
                Some(server) => Reply::ObsStats(server.obs_snapshot()),
                None => Reply::Err {
                    shutdown: false,
                    message: "no run has initialized this server yet".into(),
                },
            };
        }
        Request::Init {
            worker,
            session,
            shards,
            workers,
            policy,
            segments,
            chunk_cells,
            route_index,
            route_servers,
        } => {
            let mut state = shared.state.lock().expect("state lock");
            if let Some(hosted) = state.server.as_ref() {
                if session != 0 && session == state.session {
                    // Reattach: a retrying client re-sends Init after a
                    // reconnect while its run is still hosted (or was
                    // just restored from a checkpoint). Replacing here
                    // would zero the very state the client is trying to
                    // rejoin, so validate the shape and keep the run.
                    // Workers may have *joined* since the client learned
                    // the shape, so the census check is >=, not ==.
                    let same_shape = hosted.clock().num_workers() >= workers
                        && hosted.store().num_shards() == shards
                        && hosted.policy() == policy
                        && hosted.store().segments() == segments
                        && hosted.store().chunk_cells() == chunk_cells;
                    if same_shape {
                        let hosted = Arc::clone(hosted);
                        let first_attach = state.attached.insert(worker);
                        drop(state);
                        // Re-set on every attach: a checkpoint-restored
                        // server's registry starts empty, so the first
                        // reattach after a restart relabels it.
                        hosted.registry().gauge("route.index").set(route_index as u64);
                        hosted.registry().gauge("route.servers").set(route_servers as u64);
                        if !first_attach {
                            // This link attached before: a true
                            // reconnect, visible in `ps-stats` and the
                            // reporter digest server-side.
                            hosted.registry().counter("net.reconnects").inc();
                        }
                        return Reply::Ok;
                    }
                    return Reply::Err {
                        shutdown: false,
                        message: format!(
                            "re-Init for session {session} does not match the hosted \
                             run's shape"
                        ),
                    };
                }
            }
            let server = Arc::new(ParameterServer::with_segments_chunked(
                shards,
                workers,
                policy,
                &segments,
                chunk_cells,
            ));
            // Pin the fault-tolerance counters into the fresh registry
            // so `ps-stats` always lists them, even at zero.
            server.registry().counter("net.reconnects");
            server.registry().counter("ckpt.writes");
            // The fleet placement this Init announced (v5 peers decode
            // as 0/1): labels the reporter and `ps-stats` so N-server
            // fleets are tellable apart.
            server.registry().gauge("route.index").set(route_index as u64);
            server.registry().gauge("route.servers").set(route_servers as u64);
            // Replace any previous run's server: back-to-back runs (the
            // staleness sweep) each re-Init the same host process.
            // Waking the replaced clock frees any connection thread a
            // crashed client left parked at the old gate.
            state.session = session;
            state.flush_seqs = vec![0; workers];
            state.attached = std::collections::HashSet::from([worker]);
            state.clock_ticks = 0;
            let old = state.server.replace(server);
            drop(state);
            if let Some(old) = old {
                old.clock().shutdown();
            }
            shared.installed.notify_all();
            return Reply::Ok;
        }
        other => other,
    };
    let Some(server) = wait_server(shared) else {
        return Reply::Err { shutdown: true, message: "ps-server stopping".into() };
    };
    match req {
        Request::Init { .. } => unreachable!("handled above"),
        Request::Pull { worker, round, spec } => match server.serve_pull(worker, &spec, round) {
            Ok((pulled, gap, waited, gate_us)) => Reply::Pull {
                gap,
                waited,
                gate_us,
                ranges: pulled.ranges,
                cells: pulled.cells,
            },
            Err(ClockShutdown) => {
                Reply::Err { shutdown: true, message: "clock shutdown".into() }
            }
        },
        Request::Flush { worker, block, round, seq, deltas } => {
            if worker >= server.clock().num_workers() {
                return Reply::Err {
                    shutdown: false,
                    message: format!(
                        "flush from worker {worker}, but the run's census is {}",
                        server.clock().num_workers()
                    ),
                };
            }
            // Dedup ledger check AND apply under one lock: if they were
            // separate, a duplicate racing the original could pass the
            // check before the original recorded its seq, and the
            // deltas would land twice. (This serializes flushes — they
            // are one small RPC per worker-round, so the lock is cheap
            // next to the wire hop.)
            let mut state = shared.state.lock().expect("state lock");
            if !state.server.as_ref().is_some_and(|s| Arc::ptr_eq(s, &server)) {
                // A new run re-Init'd between wait_server and here; the
                // old run's flush has nowhere valid to land.
                return Reply::Err { shutdown: true, message: "the run was re-initialized".into() };
            }
            if seq != 0 {
                // A joiner admitted after Init mints ids past the
                // Init-time census; its seq slot is created on demand.
                if state.flush_seqs.len() <= worker {
                    state.flush_seqs.resize(worker + 1, 0);
                    state.flush_verdicts.resize(worker + 1, true);
                }
                if seq <= state.flush_seqs[worker] {
                    // Retried flush whose first delivery landed: the
                    // reply was lost, not the request. Ack with the
                    // verdict the original earned, don't re-apply.
                    return Reply::Flush { applied: state.flush_verdicts[worker] };
                }
                state.flush_seqs[worker] = seq;
                let applied = server.serve_flush(worker, block, &deltas, round);
                state.flush_verdicts[worker] = applied;
                return Reply::Flush { applied };
            }
            let applied = server.serve_flush(worker, block, &deltas, round);
            Reply::Flush { applied }
        }
        Request::Publish { version, entries } => {
            server.serve_publish(&entries, version);
            Reply::Ok
        }
        Request::PublishRange { version, start, values } => {
            server.store().publish_range(start, &values, version);
            Reply::Ok
        }
        Request::PublishRangeF32 { version, start, values } => {
            server.store().publish_range_f32(start, &values, version);
            Reply::Ok
        }
        Request::Advance { applied } => {
            server.serve_advance(applied);
            maybe_checkpoint(shared, &server);
            Reply::Ok
        }
        Request::Stats => Reply::Stats(server.stats_snapshot()),
        Request::ObsStats => unreachable!("handled above"),
        Request::ShutdownClock => {
            server.clock().shutdown();
            Reply::Ok
        }
        Request::Join { worker } => {
            // Admit at the frontier and mint the seq slot under the
            // state lock, so a flush racing the join finds both.
            let mut state = shared.state.lock().expect("state lock");
            if !state.server.as_ref().is_some_and(|s| Arc::ptr_eq(s, &server)) {
                return Reply::Err { shutdown: true, message: "the run was re-initialized".into() };
            }
            server.serve_join(worker);
            if state.flush_seqs.len() <= worker {
                state.flush_seqs.resize(worker + 1, 0);
                state.flush_verdicts.resize(worker + 1, true);
            }
            Reply::Ok
        }
        Request::Leave { worker } => {
            server.serve_leave(worker);
            Reply::Ok
        }
    }
}

/// Periodic checkpoint driver, called on every applied-clock advance:
/// every `every`-th tick captures a consistent image (under the state
/// lock, so no flush can interleave between the slab capture and the
/// seq-ledger capture) and writes it outside the lock.
fn maybe_checkpoint(shared: &ServerShared, server: &Arc<ParameterServer>) {
    let Some(cfg) = shared.ckpt.as_ref() else { return };
    let image = {
        let mut state = shared.state.lock().expect("state lock");
        if !state.server.as_ref().is_some_and(|s| Arc::ptr_eq(s, server)) {
            return;
        }
        state.clock_ticks += 1;
        if state.clock_ticks % cfg.every != 0 {
            return;
        }
        CheckpointImage::capture(server, state.session, &state.flush_seqs)
    };
    write_image(server, &image, cfg);
}

/// Final checkpoint on graceful stop, so a restart resumes from the
/// exact teardown state rather than the last periodic write.
fn checkpoint_now(shared: &ServerShared) {
    let Some(cfg) = shared.ckpt.as_ref() else { return };
    let captured = {
        let state = shared.state.lock().expect("state lock");
        state.server.as_ref().map(|server| {
            (Arc::clone(server), CheckpointImage::capture(server, state.session, &state.flush_seqs))
        })
    };
    if let Some((server, image)) = captured {
        write_image(&server, &image, cfg);
    }
}

fn write_image(server: &ParameterServer, image: &CheckpointImage, cfg: &CheckpointConfig) {
    match image.write_to(&cfg.dir, cfg.keep) {
        Ok(bytes) => {
            server.registry().counter("ckpt.writes").inc();
            server.registry().counter("ckpt.bytes").add(bytes);
        }
        // A failed write must never take down the serving path; the
        // previous checkpoint (if any) is still intact on disk thanks
        // to the write-then-rename protocol.
        Err(e) => eprintln!("[ckpt] write failed: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loopback() -> (PsTcpServer, String) {
        let server = PsTcpServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        (server, addr)
    }

    #[test]
    fn tcp_roundtrip_init_seed_pull_flush_stats() {
        let (host, addr) = loopback();
        let bytes = Arc::new(AtomicU64::new(0));
        let mut coord =
            TcpTransport::connect(&addr, super::super::COORDINATOR_ID, Arc::clone(&bytes))
                .unwrap();
        coord.init(1, 4, 1, StalenessPolicy::Bounded(0), &[(0, 4)], 0).unwrap();
        coord.publish_range(0, &[1.0, 2.0, 3.0, 4.0], 0).unwrap();

        let mut worker = TcpTransport::connect(&addr, 0, Arc::clone(&bytes)).unwrap();
        let reply = worker.pull(&PullSpec::from_ranges(vec![(1, 2)]), 0).unwrap();
        assert_eq!(reply.ranges[0].values(), &[2.0f32, 3.0]);
        assert_eq!(reply.gap, 0);
        assert!(worker.flush(&[(0, 0.5), (3, -1.0)], 0, 0).unwrap());
        coord.advance_applied(1).unwrap();

        let stats = coord.stats().unwrap();
        assert_eq!((stats.pulls, stats.flushes), (1, 1));
        assert!(stats.bytes_pulled > 0);
        assert!(bytes.load(Ordering::Relaxed) > 0, "socket traffic must be metered");

        let snap = coord.obs_stats().unwrap();
        assert_eq!(snap.get("ps.pulls").unwrap().as_u64(), 1);
        assert_eq!(snap.get("ps.pull_bytes").unwrap().as_u64(), stats.bytes_pulled);
        assert_eq!(snap.segments, vec![(0, 4, 1)], "the round-0 flush bumped the epoch");
        let clock = snap.clock.as_ref().expect("hosted server exposes its clock");
        assert_eq!(clock.applied, 1);
        assert_eq!(clock.staleness_bound, Some(0));
        assert_eq!(clock.worker_clocks, vec![1], "worker 0 flushed round 0");
        host.stop();
    }

    #[test]
    fn obs_stats_probe_of_an_idle_server_errors_instead_of_parking() {
        let (host, addr) = loopback();
        let err = super::super::fetch_obs_stats(&addr).unwrap_err();
        assert!(matches!(err, TransportError::Remote(_)), "want remote error, got {err}");
        host.stop();
    }

    #[test]
    fn flush_with_bogus_worker_id_is_rejected_not_a_crash() {
        let (host, addr) = loopback();
        let bytes = Arc::new(AtomicU64::new(0));
        let mut coord = TcpTransport::connect(&addr, 7, bytes).unwrap();
        coord.init(2, 2, 2, StalenessPolicy::Async, &[], 0).unwrap();
        let err = coord.flush(&[(0, 1.0)], 0, 0).unwrap_err();
        assert!(matches!(err, TransportError::Remote(_)), "{err}");
        // the connection survives the rejected request
        assert!(coord.stats().is_ok());
        host.stop();
    }

    #[test]
    fn stopping_the_host_surfaces_clean_errors() {
        let (host, addr) = loopback();
        let mut coord =
            TcpTransport::connect(&addr, 0, Arc::new(AtomicU64::new(0))).unwrap();
        coord.init(3, 2, 1, StalenessPolicy::Bounded(0), &[], 0).unwrap();
        host.stop();
        let err = coord.stats().unwrap_err();
        assert!(matches!(err, TransportError::Io(_)), "want io error, got {err}");
    }

    #[test]
    fn re_init_with_the_runs_session_reattaches_instead_of_zeroing() {
        let (host, addr) = loopback();
        let bytes = Arc::new(AtomicU64::new(0));
        let mut coord =
            TcpTransport::connect(&addr, super::super::COORDINATOR_ID, Arc::clone(&bytes))
                .unwrap();
        coord.init(41, 2, 1, StalenessPolicy::Bounded(0), &[(0, 2)], 0).unwrap();
        coord.publish_range(0, &[5.0, 6.0], 0).unwrap();
        coord.advance_applied(3).unwrap();

        // Same session: reattach — published state and clock survive.
        let mut again = TcpTransport::connect(&addr, 0, Arc::clone(&bytes)).unwrap();
        again.init(41, 2, 1, StalenessPolicy::Bounded(0), &[(0, 2)], 0).unwrap();
        let reply = again.pull(&PullSpec::from_ranges(vec![(0, 2)]), 0).unwrap();
        assert_eq!(reply.ranges[0].values(), &[5.0f32, 6.0]);

        // Reattach with a different shape is rejected without killing
        // the hosted run.
        let err = again.init(41, 2, 2, StalenessPolicy::Bounded(0), &[(0, 2)], 0).unwrap_err();
        assert!(matches!(err, TransportError::Remote(_)), "{err}");
        assert!(again.stats().is_ok(), "the run survives a rejected reattach");

        // A different session is a new run: state is replaced.
        let mut fresh =
            TcpTransport::connect(&addr, super::super::COORDINATOR_ID, bytes).unwrap();
        fresh.init(99, 2, 1, StalenessPolicy::Bounded(0), &[(0, 2)], 0).unwrap();
        let reply = fresh.pull(&PullSpec::from_ranges(vec![(0, 2)]), 0).unwrap();
        assert_eq!(reply.ranges[0].values(), &[0.0f32, 0.0], "new session starts blank");
        host.stop();
    }

    #[test]
    fn duplicate_flush_seqs_are_applied_exactly_once() {
        let (host, addr) = loopback();
        let bytes = Arc::new(AtomicU64::new(0));
        let mut coord =
            TcpTransport::connect(&addr, super::super::COORDINATOR_ID, Arc::clone(&bytes))
                .unwrap();
        coord.init(5, 2, 1, StalenessPolicy::Async, &[(0, 2)], 0).unwrap();

        // Two sockets for the same worker, each minting seqs from 1 —
        // exactly what a reconnect-and-resend looks like on the wire.
        let mut first = TcpTransport::connect(&addr, 0, Arc::clone(&bytes)).unwrap();
        let mut resend = TcpTransport::connect(&addr, 0, Arc::clone(&bytes)).unwrap();
        assert!(first.flush(&[(0, 1.0)], 0, 0).unwrap()); // seq 1: applied
        assert!(resend.flush(&[(0, 1.0)], 0, 0).unwrap()); // seq 1 again: deduped, acked
        assert!(resend.flush(&[(0, 1.0)], 1, 0).unwrap()); // seq 2: applied
        let reply = first.pull(&PullSpec::from_ranges(vec![(0, 2)]), 0).unwrap();
        assert_eq!(reply.ranges[0].values()[0], 2.0f32, "duplicate seq must not re-apply");
        let stats = coord.stats().unwrap();
        assert_eq!(stats.flushes, 2, "the deduped flush never reached the store");
        host.stop();
    }

    #[test]
    fn join_and_leave_change_the_census_over_the_wire() {
        let (host, addr) = loopback();
        let bytes = Arc::new(AtomicU64::new(0));
        let mut coord =
            TcpTransport::connect(&addr, super::super::COORDINATOR_ID, Arc::clone(&bytes))
                .unwrap();
        coord.init(77, 2, 2, StalenessPolicy::Async, &[(0, 2)], 0).unwrap();
        coord.publish_range(0, &[0.0, 0.0], 0).unwrap();

        // Before the join, worker 2 is outside the census.
        let mut w2 = TcpTransport::connect(&addr, 2, Arc::clone(&bytes)).unwrap();
        let err = w2.flush(&[(0, 1.0)], 0, 0).unwrap_err();
        assert!(matches!(err, TransportError::Remote(_)), "{err}");

        coord.join(2).unwrap();
        coord.join(2).unwrap(); // idempotent replay
        assert!(w2.flush(&[(0, 1.0)], 0, 0).unwrap(), "joiner's flush lands");
        let reply = w2.pull(&PullSpec::from_ranges(vec![(0, 2)]), 0).unwrap();
        assert_eq!(reply.ranges[0].values()[0], 1.0f32);

        // A reattach that still quotes the Init-time census (2) is
        // accepted against the grown census (3).
        let mut late = TcpTransport::connect(&addr, 0, Arc::clone(&bytes)).unwrap();
        late.init(77, 2, 2, StalenessPolicy::Async, &[(0, 2)], 0).unwrap();

        // After Leave, the worker is fenced: its flush is refused as
        // not-applied, and the deltas never reach the store.
        coord.leave(2).unwrap();
        assert!(!w2.flush(&[(1, 5.0)], 1, 1).unwrap(), "fenced after leave");
        let reply = late.pull(&PullSpec::from_ranges(vec![(0, 2)]), 0).unwrap();
        assert_eq!(reply.ranges[0].values(), &[1.0f32, 0.0]);
        host.stop();
    }

    #[test]
    fn server_counts_reconnects_not_first_attaches() {
        let (host, addr) = loopback();
        let bytes = Arc::new(AtomicU64::new(0));
        let mut coord =
            TcpTransport::connect(&addr, super::super::COORDINATOR_ID, Arc::clone(&bytes))
                .unwrap();
        coord.init(88, 1, 2, StalenessPolicy::Async, &[], 0).unwrap();
        // first attaches of two worker links: not reconnects
        let mut w0 = TcpTransport::connect(&addr, 0, Arc::clone(&bytes)).unwrap();
        w0.init(88, 1, 2, StalenessPolicy::Async, &[], 0).unwrap();
        let mut w1 = TcpTransport::connect(&addr, 1, Arc::clone(&bytes)).unwrap();
        w1.init(88, 1, 2, StalenessPolicy::Async, &[], 0).unwrap();
        let snap = coord.obs_stats().unwrap();
        assert_eq!(snap.get("net.reconnects").unwrap().as_u64(), 0, "attaches are free");
        // the same worker id re-attaching is a reconnect
        let mut again = TcpTransport::connect(&addr, 1, Arc::clone(&bytes)).unwrap();
        again.init(88, 1, 2, StalenessPolicy::Async, &[], 0).unwrap();
        let snap = coord.obs_stats().unwrap();
        assert_eq!(snap.get("net.reconnects").unwrap().as_u64(), 1);
        host.stop();
    }

    #[test]
    fn stop_checkpoints_and_bind_with_restores_the_run() {
        let dir = std::env::temp_dir().join(format!("strads_tcp_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ckpt = CheckpointConfig { dir: dir.clone(), every: 1_000_000, keep: 2 };
        let host = PsTcpServer::bind_with("127.0.0.1:0", Some(ckpt.clone())).unwrap();
        let addr = host.local_addr().to_string();
        let bytes = Arc::new(AtomicU64::new(0));
        let mut coord =
            TcpTransport::connect(&addr, super::super::COORDINATOR_ID, Arc::clone(&bytes))
                .unwrap();
        coord.init(61, 2, 1, StalenessPolicy::Bounded(1), &[(0, 3)], 0).unwrap();
        coord.publish_range(0, &[1.5, 2.5, 3.5], 0).unwrap();
        let mut worker = TcpTransport::connect(&addr, 0, Arc::clone(&bytes)).unwrap();
        assert!(worker.flush(&[(1, 0.25)], 0, 0).unwrap());
        coord.advance_applied(2).unwrap();
        host.stop(); // graceful stop writes the final checkpoint

        let host2 = PsTcpServer::bind_with("127.0.0.1:0", Some(ckpt)).unwrap();
        let addr2 = host2.local_addr().to_string();
        let mut back = TcpTransport::connect(&addr2, 0, Arc::clone(&bytes)).unwrap();
        // Reattach with the original session: restored slabs + clock,
        // not a re-zeroed run.
        back.init(61, 2, 1, StalenessPolicy::Bounded(1), &[(0, 3)], 0).unwrap();
        let reply = back.pull(&PullSpec::from_ranges(vec![(0, 3)]), 0).unwrap();
        assert_eq!(reply.ranges[0].values(), &[1.5f32, 2.75, 3.5]);
        // The dedup ledger survives the restart: a resend of the
        // pre-kill flush (seq 1) must still be dropped.
        let mut dup = TcpTransport::connect(&addr2, 0, bytes).unwrap();
        dup.flush(&[(1, 0.25)], 0, 0).unwrap();
        let reply = dup.pull(&PullSpec::from_ranges(vec![(1, 1)]), 0).unwrap();
        assert_eq!(reply.ranges[0].values(), &[2.75f32], "restored ledger deduped the resend");
        host2.stop();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
