//! The TCP transport: [`TcpTransport`] is the client side (one socket
//! per endpoint, synchronous framed RPC — see [`wire`]), and
//! [`PsTcpServer`] hosts a [`ParameterServer`] behind a listener
//! (`strads ps-server`). The server is problem-agnostic: a run's
//! coordinator sends `Init` (shape: shards, workers, policy, dense
//! segments) and then seeds state with `PublishRange`, so one server
//! process serves any `ModelProblem` and any number of back-to-back
//! runs (each `Init` replaces the previous server instance).
//!
//! Threading: one OS thread per connection. This is deliberate — a
//! worker's pull legitimately *blocks* at the server-side SSP gate
//! until the applied clock admits it, exactly like the in-process gate,
//! so connections must not share an event loop. Teardown paths:
//! `ShutdownClock` wakes every gate waiter (their pulls return the
//! `shutdown` error reply, which clients surface as
//! [`TransportError::Shutdown`]); a dead client just drops its
//! connection thread; [`PsTcpServer::stop`] force-closes everything.

use super::wire::{self, Reply, Request};
use super::{PullReply, Transport, TransportError};
use crate::obs::ObsSnapshot;
use crate::ps::clock::{ClockShutdown, StalenessPolicy};
use crate::ps::shard::PullSpec;
use crate::ps::{ParameterServer, StatsSnapshot};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

// ---- client ------------------------------------------------------------

/// One endpoint's socket to a `ps-server`, counting every byte it moves
/// (frame headers included) into the shared `socket_bytes` meter.
pub struct TcpTransport {
    stream: TcpStream,
    worker: usize,
    socket_bytes: Arc<AtomicU64>,
    /// Reusable receive buffer (frames overwrite it).
    buf: Vec<u8>,
}

impl TcpTransport {
    /// Connect to `addr`. Fails fast (no retry loop): a missing server
    /// is an operator error the caller should see immediately.
    pub fn connect(
        addr: &str,
        worker: usize,
        socket_bytes: Arc<AtomicU64>,
    ) -> Result<Self, TransportError> {
        let stream = TcpStream::connect(addr)?;
        // One small frame per RPC: Nagle would serialize the whole run
        // onto 40ms ACK-delay ticks.
        stream.set_nodelay(true)?;
        Ok(TcpTransport { stream, worker, socket_bytes, buf: Vec::new() })
    }

    /// Send `Init`, (re)configuring the hosted server for this run.
    pub fn init(
        &mut self,
        shards: usize,
        workers: usize,
        policy: StalenessPolicy,
        segments: &[(usize, usize)],
    ) -> Result<(), TransportError> {
        let req =
            Request::Init { shards, workers, policy, segments: segments.to_vec() };
        match self.rpc(&req)? {
            Reply::Ok => Ok(()),
            other => Err(unexpected(&other)),
        }
    }

    /// One synchronous RPC from an already-encoded frame payload:
    /// frame out, frame in, meter both directions.
    fn exchange(&mut self, msg: Vec<u8>) -> Result<Reply, TransportError> {
        let sent = wire::write_frame(&mut self.stream, &msg)?;
        let received = wire::read_frame(&mut self.stream, &mut self.buf)?;
        self.socket_bytes.fetch_add(sent + received, Ordering::Relaxed);
        match wire::decode_reply(&self.buf)? {
            Reply::Err { shutdown: true, .. } => Err(TransportError::Shutdown),
            Reply::Err { shutdown: false, message } => Err(TransportError::Remote(message)),
            reply => Ok(reply),
        }
    }

    fn rpc(&mut self, req: &Request) -> Result<Reply, TransportError> {
        self.exchange(wire::encode_request(req))
    }
}

fn unexpected(reply: &Reply) -> TransportError {
    TransportError::Protocol(format!("unexpected reply kind: {reply:?}"))
}

impl Transport for TcpTransport {
    fn pull(&mut self, spec: &PullSpec, round: u64) -> Result<PullReply, TransportError> {
        match self.exchange(wire::encode_pull(round, spec))? {
            Reply::Pull { gap, waited, gate_us, ranges, cells } => {
                Ok(PullReply { ranges, cells, gap, waited, gate_us })
            }
            other => Err(unexpected(&other)),
        }
    }

    fn flush(&mut self, deltas: &[(usize, f64)], round: u64) -> Result<(), TransportError> {
        match self.exchange(wire::encode_flush(self.worker, round, deltas))? {
            Reply::Ok => Ok(()),
            other => Err(unexpected(&other)),
        }
    }

    fn publish(
        &mut self,
        entries: &[(usize, f64)],
        version: u64,
    ) -> Result<(), TransportError> {
        match self.exchange(wire::encode_publish(version, entries))? {
            Reply::Ok => Ok(()),
            other => Err(unexpected(&other)),
        }
    }

    fn publish_range(
        &mut self,
        start: usize,
        values: &[f64],
        version: u64,
    ) -> Result<(), TransportError> {
        match self.exchange(wire::encode_publish_range(version, start, values))? {
            Reply::Ok => Ok(()),
            other => Err(unexpected(&other)),
        }
    }

    fn advance_applied(&mut self, applied: u64) -> Result<(), TransportError> {
        match self.rpc(&Request::Advance { applied })? {
            Reply::Ok => Ok(()),
            other => Err(unexpected(&other)),
        }
    }

    fn stats(&mut self) -> Result<StatsSnapshot, TransportError> {
        match self.rpc(&Request::Stats)? {
            Reply::Stats(s) => Ok(s),
            other => Err(unexpected(&other)),
        }
    }

    fn obs_stats(&mut self) -> Result<ObsSnapshot, TransportError> {
        match self.rpc(&Request::ObsStats)? {
            Reply::ObsStats(snap) => Ok(snap),
            other => Err(unexpected(&other)),
        }
    }

    fn shutdown_clock(&mut self) -> Result<(), TransportError> {
        match self.rpc(&Request::ShutdownClock)? {
            Reply::Ok => Ok(()),
            other => Err(unexpected(&other)),
        }
    }
}

// ---- server ------------------------------------------------------------

struct ServerState {
    /// The hosted server; `None` until the first `Init` arrives.
    server: Option<Arc<ParameterServer>>,
}

struct ServerShared {
    state: Mutex<ServerState>,
    /// Signaled on `Init` (and on stop) so early worker connections can
    /// park until the coordinator has configured the run.
    installed: Condvar,
    stop: AtomicBool,
    /// Clones of every *live* connection keyed by connection id, so
    /// `stop` can force-close them. Entries are pruned when their
    /// handler exits — a long-lived server must not leak one fd per
    /// connection it ever served.
    conns: Mutex<std::collections::HashMap<u64, TcpStream>>,
    /// Monotonic connection-id source.
    next_conn_id: AtomicU64,
}

/// A listening parameter-server host. `bind` spawns the accept loop;
/// the process-level entry point (`strads ps-server`) then parks on
/// [`PsTcpServer::run`], while tests drive [`PsTcpServer::stop`].
pub struct PsTcpServer {
    local_addr: SocketAddr,
    shared: Arc<ServerShared>,
    accept: Option<JoinHandle<()>>,
}

impl PsTcpServer {
    /// Bind `addr` (use port 0 for an ephemeral test port) and start
    /// accepting connections.
    pub fn bind(addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("ps-server bind {addr}: {e}"))?;
        let local_addr = listener.local_addr()?;
        let shared = Arc::new(ServerShared {
            state: Mutex::new(ServerState { server: None }),
            installed: Condvar::new(),
            stop: AtomicBool::new(false),
            conns: Mutex::new(std::collections::HashMap::new()),
            next_conn_id: AtomicU64::new(0),
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::spawn(move || accept_loop(listener, accept_shared));
        Ok(PsTcpServer { local_addr, shared, accept: Some(accept) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Start the periodic self-report (`[obs] report_secs` /
    /// `--report-secs`): a detached thread that prints a one-line
    /// registry digest to stderr every `secs` seconds. It polls the
    /// stop flag once a second so `stop()` never blocks on it, and it
    /// says so (idle) while no run has initialized the server.
    pub fn spawn_reporter(&self, secs: u64) {
        let secs = secs.max(1);
        let shared = Arc::clone(&self.shared);
        std::thread::spawn(move || loop {
            for _ in 0..secs {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                std::thread::sleep(std::time::Duration::from_secs(1));
            }
            let server = shared.state.lock().expect("state lock").server.as_ref().cloned();
            match server {
                Some(server) => {
                    let snap = server.obs_snapshot();
                    let metric = |name: &str| snap.get(name).map(|v| v.as_u64()).unwrap_or(0);
                    let applied = snap.clock.as_ref().map(|c| c.applied).unwrap_or(0);
                    eprintln!(
                        "[obs] applied={} pulls={} pull_bytes={} flushes={} gate_waits={}",
                        applied,
                        metric("ps.pulls"),
                        metric("ps.pull_bytes"),
                        metric("ps.flushes"),
                        metric("ps.gate_waits"),
                    );
                }
                None => eprintln!("[obs] idle (no run initialized)"),
            }
        });
    }

    /// Serve until the process dies (the `strads ps-server` loop).
    pub fn run(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Tear the server down: wake gate waiters, close every live
    /// connection (clients see a clean I/O error, never a hang), and
    /// join the accept loop. Used by tests and the kill-path suite.
    pub fn stop(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(server) = self.shared.state.lock().expect("state lock").server.as_ref() {
            server.clock().shutdown();
        }
        self.shared.installed.notify_all();
        for (_, conn) in self.shared.conns.lock().expect("conns lock").drain() {
            let _ = conn.shutdown(Shutdown::Both);
        }
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<ServerShared>) {
    for stream in listener.incoming() {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        let conn_id = shared.next_conn_id.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            shared.conns.lock().expect("conns lock").insert(conn_id, clone);
        }
        let conn_shared = Arc::clone(&shared);
        std::thread::spawn(move || {
            handle_conn(&conn_shared, stream);
            // Prune our clone so a long-lived server never accumulates
            // fds for connections that already hung up.
            conn_shared.conns.lock().expect("conns lock").remove(&conn_id);
        });
    }
}

/// Block until an `Init` has installed a server (or the host stops).
fn wait_server(shared: &ServerShared) -> Option<Arc<ParameterServer>> {
    let mut state = shared.state.lock().expect("state lock");
    loop {
        if let Some(server) = state.server.as_ref() {
            return Some(Arc::clone(server));
        }
        if shared.stop.load(Ordering::SeqCst) {
            return None;
        }
        state = shared.installed.wait(state).expect("state lock");
    }
}

fn handle_conn(shared: &ServerShared, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let mut buf = Vec::new();
    loop {
        // A read error here is the client going away — not a fault.
        if wire::read_frame(&mut stream, &mut buf).is_err() {
            return;
        }
        let reply = match wire::decode_request(&buf) {
            Ok(req) => dispatch(shared, req),
            Err(e) => Reply::Err { shutdown: false, message: e.0 },
        };
        let msg = wire::encode_reply(&reply);
        if wire::write_frame(&mut stream, &msg).is_err() {
            return;
        }
    }
}

fn dispatch(shared: &ServerShared, req: Request) -> Reply {
    // Init is the one request served without a hosted server; the
    // rebinding keeps `req` whole for the second match below.
    // ObsStats is the other: `strads ps-stats` must be able to probe an
    // idle server without parking at the installed-condvar, so a
    // pre-Init probe gets a non-shutdown error, not a hang.
    let req = match req {
        Request::ObsStats => {
            return match shared.state.lock().expect("state lock").server.as_ref() {
                Some(server) => Reply::ObsStats(server.obs_snapshot()),
                None => Reply::Err {
                    shutdown: false,
                    message: "no run has initialized this server yet".into(),
                },
            };
        }
        Request::Init { shards, workers, policy, segments } => {
            let server =
                Arc::new(ParameterServer::with_segments(shards, workers, policy, &segments));
            // Replace any previous run's server: back-to-back runs (the
            // staleness sweep) each re-Init the same host process.
            // Waking the replaced clock frees any connection thread a
            // crashed client left parked at the old gate.
            let old = shared.state.lock().expect("state lock").server.replace(server);
            if let Some(old) = old {
                old.clock().shutdown();
            }
            shared.installed.notify_all();
            return Reply::Ok;
        }
        other => other,
    };
    let Some(server) = wait_server(shared) else {
        return Reply::Err { shutdown: true, message: "ps-server stopping".into() };
    };
    match req {
        Request::Init { .. } => unreachable!("handled above"),
        Request::Pull { round, spec } => match server.serve_pull(&spec, round) {
            Ok((pulled, gap, waited, gate_us)) => Reply::Pull {
                gap,
                waited,
                gate_us,
                ranges: pulled.ranges,
                cells: pulled.cells,
            },
            Err(ClockShutdown) => {
                Reply::Err { shutdown: true, message: "clock shutdown".into() }
            }
        },
        Request::Flush { worker, round, deltas } => {
            if worker >= server.clock().num_workers() {
                return Reply::Err {
                    shutdown: false,
                    message: format!(
                        "flush from worker {worker}, but the run was initialized with {}",
                        server.clock().num_workers()
                    ),
                };
            }
            server.serve_flush(worker, &deltas, round);
            Reply::Ok
        }
        Request::Publish { version, entries } => {
            server.serve_publish(&entries, version);
            Reply::Ok
        }
        Request::PublishRange { version, start, values } => {
            server.store().publish_range(start, &values, version);
            Reply::Ok
        }
        Request::Advance { applied } => {
            server.clock().advance_applied(applied);
            Reply::Ok
        }
        Request::Stats => Reply::Stats(server.stats_snapshot()),
        Request::ObsStats => unreachable!("handled above"),
        Request::ShutdownClock => {
            server.clock().shutdown();
            Reply::Ok
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loopback() -> (PsTcpServer, String) {
        let server = PsTcpServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        (server, addr)
    }

    #[test]
    fn tcp_roundtrip_init_seed_pull_flush_stats() {
        let (host, addr) = loopback();
        let bytes = Arc::new(AtomicU64::new(0));
        let mut coord = TcpTransport::connect(&addr, super::super::COORDINATOR_ID, Arc::clone(&bytes)).unwrap();
        coord.init(4, 1, StalenessPolicy::Bounded(0), &[(0, 4)]).unwrap();
        coord.publish_range(0, &[1.0, 2.0, 3.0, 4.0], 0).unwrap();

        let mut worker = TcpTransport::connect(&addr, 0, Arc::clone(&bytes)).unwrap();
        let reply = worker.pull(&PullSpec::from_ranges(vec![(1, 2)]), 0).unwrap();
        assert_eq!(reply.ranges[0].values(), &[2.0f32, 3.0]);
        assert_eq!(reply.gap, 0);
        worker.flush(&[(0, 0.5), (3, -1.0)], 0).unwrap();
        coord.advance_applied(1).unwrap();

        let stats = coord.stats().unwrap();
        assert_eq!((stats.pulls, stats.flushes), (1, 1));
        assert!(stats.bytes_pulled > 0);
        assert!(bytes.load(Ordering::Relaxed) > 0, "socket traffic must be metered");

        let snap = coord.obs_stats().unwrap();
        assert_eq!(snap.get("ps.pulls").unwrap().as_u64(), 1);
        assert_eq!(snap.get("ps.pull_bytes").unwrap().as_u64(), stats.bytes_pulled);
        assert_eq!(snap.segments, vec![(0, 4, 1)], "the round-0 flush bumped the epoch");
        let clock = snap.clock.as_ref().expect("hosted server exposes its clock");
        assert_eq!(clock.applied, 1);
        assert_eq!(clock.staleness_bound, Some(0));
        assert_eq!(clock.worker_clocks, vec![1], "worker 0 flushed round 0");
        host.stop();
    }

    #[test]
    fn obs_stats_probe_of_an_idle_server_errors_instead_of_parking() {
        let (host, addr) = loopback();
        let err = super::super::fetch_obs_stats(&addr).unwrap_err();
        assert!(matches!(err, TransportError::Remote(_)), "want remote error, got {err}");
        host.stop();
    }

    #[test]
    fn flush_with_bogus_worker_id_is_rejected_not_a_crash() {
        let (host, addr) = loopback();
        let bytes = Arc::new(AtomicU64::new(0));
        let mut coord = TcpTransport::connect(&addr, 7, bytes).unwrap();
        coord.init(2, 2, StalenessPolicy::Async, &[]).unwrap();
        let err = coord.flush(&[(0, 1.0)], 0).unwrap_err();
        assert!(matches!(err, TransportError::Remote(_)), "{err}");
        // the connection survives the rejected request
        assert!(coord.stats().is_ok());
        host.stop();
    }

    #[test]
    fn stopping_the_host_surfaces_clean_errors() {
        let (host, addr) = loopback();
        let mut coord =
            TcpTransport::connect(&addr, 0, Arc::new(AtomicU64::new(0))).unwrap();
        coord.init(2, 1, StalenessPolicy::Bounded(0), &[]).unwrap();
        host.stop();
        let err = coord.stats().unwrap_err();
        assert!(matches!(err, TransportError::Io(_)), "want io error, got {err}");
    }
}
