//! Pluggable transport between the distributed run and its parameter
//! server: the same `pull / flush / publish / clock` traffic behind one
//! [`Transport`] trait, carried either through shared memory
//! ([`InProcTransport`] — today's single-address-space path, bit-exact
//! with the pre-transport code) or over a length-prefixed binary wire
//! protocol to a server in another process ([`tcp::TcpTransport`] +
//! `strads ps-server`, see [`wire`]).
//!
//! The split keeps the *policy* (SSP gating, byte metering, storage) in
//! one place — [`crate::ps::ParameterServer::serve_pull`] and friends —
//! and makes the transport pure carriage: both implementations call the
//! identical serve helpers, so a staleness-0 run produces the same
//! trajectory over either (the loopback parity suite in
//! `tests/ps_transport.rs` pins this bitwise; the f32 range wire is
//! lossless by construction). What the transports *do* differ in is
//! real traffic: [`PsConnection::socket_bytes`] meters the actual bytes
//! moved through sockets (0 in-process), which `BENCH_ps.json` records
//! next to the modeled `net_bytes` — the wire-byte meter becomes an
//! observable instead of a model.
//!
//! Connection topology: the coordinator holds one link (init, seed,
//! republish, clock advance, stats, teardown) and each worker thread
//! holds its own (pull + flush) — a pull can block at the server-side
//! SSP gate, so links are never shared between workers.

pub mod inproc;
pub mod retry;
pub mod routed;
pub mod tcp;
pub mod wire;

pub use inproc::InProcTransport;
pub use retry::{FaultPlan, InitShape, RetryConfig, RetryTransport};
pub use routed::{RouteMap, RoutedTransport};
pub use tcp::{PsTcpServer, TcpTransport};

use crate::config::PsConfig;
use crate::obs::ObsSnapshot;
use crate::ps::shard::{Cell, PullSpec, RangePull};
use crate::ps::{ParameterServer, StatsSnapshot};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Which carriage a run uses between clients and the parameter server.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// Shared memory within one process (the default; zero-copy pulls).
    #[default]
    InProc,
    /// Loopback/remote TCP to a `strads ps-server` process.
    Tcp,
}

impl TransportKind {
    /// Parse a `[ps] transport` / `--ps-transport` setting.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "inproc" | "in-proc" | "local" => Ok(TransportKind::InProc),
            "tcp" => Ok(TransportKind::Tcp),
            other => anyhow::bail!("unknown transport {other} (inproc|tcp)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// Why a transport operation failed.
#[derive(Debug)]
pub enum TransportError {
    /// Clean teardown: the run's SSP gate waiters were woken. Workers
    /// treat this as end-of-run, not an error.
    Shutdown,
    /// The carriage failed (connection refused, peer died mid-RPC).
    Io(std::io::Error),
    /// The peer sent bytes that don't parse as the protocol.
    Protocol(String),
    /// The server processed the request and rejected it.
    Remote(String),
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Shutdown => write!(f, "parameter server shut down"),
            TransportError::Io(e) => write!(f, "ps transport i/o: {e}"),
            TransportError::Protocol(m) => write!(f, "ps transport protocol: {m}"),
            TransportError::Remote(m) => write!(f, "ps server error: {m}"),
        }
    }
}

impl std::error::Error for TransportError {}

impl From<std::io::Error> for TransportError {
    fn from(e: std::io::Error) -> Self {
        TransportError::Io(e)
    }
}

impl From<wire::WireError> for TransportError {
    fn from(e: wire::WireError) -> Self {
        TransportError::Protocol(e.0)
    }
}

impl TransportError {
    /// True for the clean end-of-run signal (as opposed to a fault).
    pub fn is_shutdown(&self) -> bool {
        matches!(self, TransportError::Shutdown)
    }
}

/// The result of one transported pull: ranges in request order (for the
/// in-process transport these are zero-copy shared epoch views; over
/// TCP, owned bitwise-identical images), scattered cells in request-key
/// order, and the SSP gate observation.
#[derive(Debug)]
pub struct PullReply {
    pub ranges: Vec<RangePull>,
    pub cells: Vec<Cell>,
    pub gap: u64,
    pub waited: bool,
    /// Time this pull spent blocked at the server-side SSP gate, in
    /// microseconds (0 when admitted immediately). Measured on the
    /// server so remote runs see the true gate cost, not RTT.
    pub gate_us: u64,
}

/// One endpoint's view of the parameter server. Worker clients use
/// `pull`/`flush`; the coordinator uses the rest. Methods take `&mut
/// self` because a TCP link is a stateful RPC stream — each endpoint
/// owns its own transport (see the module docs on topology).
pub trait Transport: Send {
    /// SSP-gated read of `spec` for worker-round `round`; blocks until
    /// the staleness policy admits it.
    fn pull(&mut self, spec: &PullSpec, round: u64) -> Result<PullReply, TransportError>;

    /// Push this worker's coalesced round-`round` delta batch for
    /// scheduling block `block` and tick its clock. Returns whether the
    /// server *applied* the batch: `false` means it was dropped as a
    /// duplicate of an already-applied `(round, block)` (a reassignment
    /// race the other copy won), as a zombie from before the applied
    /// frontier, or because this worker has been retired from the
    /// census. Either way the worker's clock ticked, so the caller
    /// proceeds to its next item — it just must not fold a dropped
    /// batch into any canonical model state.
    fn flush(
        &mut self,
        deltas: &[(usize, f64)],
        round: u64,
        block: u64,
    ) -> Result<bool, TransportError>;

    /// Admit `worker` into the census at the applied frontier
    /// (idempotent — the coordinator proposes the id, so a retried
    /// `Join` is a no-op). Coordinator-only.
    fn join(&mut self, worker: usize) -> Result<(), TransportError>;

    /// Retire `worker` from the census: its clock stops holding the SSP
    /// gate, its parked pulls wake with `Shutdown`, and its future
    /// flushes are fenced off. Idempotent. Coordinator-only.
    fn leave(&mut self, worker: usize) -> Result<(), TransportError>;

    /// Coordinator republish of derived state at `version` (metered as
    /// republish traffic).
    fn publish(&mut self, entries: &[(usize, f64)], version: u64)
        -> Result<(), TransportError>;

    /// Contiguous overwrite-publish (the unmetered round-0 seed path).
    fn publish_range(
        &mut self,
        start: usize,
        values: &[f64],
        version: u64,
    ) -> Result<(), TransportError>;

    /// [`Transport::publish_range`] from an f32 slab — the seed path
    /// for problems whose canonical state is already f32 (half the
    /// wire bytes, no widen/narrow round trip). Bit-exact with the f64
    /// path for segment-covered keys, because dense cells narrow to
    /// f32 at the store either way. The default widens and delegates;
    /// transports with a native f32 carriage override it.
    fn publish_range_f32(
        &mut self,
        start: usize,
        values: &[f32],
        version: u64,
    ) -> Result<(), TransportError> {
        let wide: Vec<f64> = values.iter().map(|&v| v as f64).collect();
        self.publish_range(start, &wide, version)
    }

    /// Advance the server's applied clock (ungates workers).
    fn advance_applied(&mut self, applied: u64) -> Result<(), TransportError>;

    /// Snapshot every server-side meter.
    fn stats(&mut self) -> Result<StatsSnapshot, TransportError>;

    /// Full introspection snapshot: the server's metrics registry plus
    /// per-segment versions and SSP clock state (`strads ps-stats`).
    fn obs_stats(&mut self) -> Result<ObsSnapshot, TransportError>;

    /// Wake every SSP gate waiter for run teardown (the server itself
    /// stays alive — over TCP, ready for the next `Init`).
    fn shutdown_clock(&mut self) -> Result<(), TransportError>;
}

/// Worker id the coordinator's link reports on the wire. Never used for
/// clock indexing (the coordinator doesn't flush), it only marks the
/// link in diagnostics.
pub const COORDINATOR_ID: usize = u32::MAX as usize;

/// How `PsConnection` mints per-worker transports.
enum Minter {
    InProc(Arc<ParameterServer>),
    Tcp(String),
    /// TCP with the reconnecting retry wrapper (`[ps] retry_max` > 0 or
    /// a fault plan): every link shares the run's session id, retry
    /// knobs, and fault plan, plus the run-wide retry meters.
    Retry {
        addr: String,
        session: u64,
        shape: InitShape,
        retry: RetryConfig,
        plan: Option<Arc<FaultPlan>>,
    },
    /// N-server sharded fleet (`[ps] addr` is a comma-separated list):
    /// every minted link is a [`RoutedTransport`] fanning out over one
    /// per-server inner link each — plain TCP, or retry/fault-wrapped
    /// per server when those knobs are set. Per-server shapes carry
    /// each server's own sub-segments and route position, and each
    /// server gets its own compression map and byte/reconnect meters.
    Routed {
        addrs: Vec<String>,
        session: u64,
        route: Arc<RouteMap>,
        shapes: Vec<InitShape>,
        retry: Option<RetryConfig>,
        plan: Option<Arc<FaultPlan>>,
        compress: Vec<Option<wire::SegmentMap>>,
    },
}

/// Session ids distinguish "this run reconnecting" from "a new run" at
/// the server's `Init` handler. `pid << 32 | counter` is unique across
/// processes on one host and across back-to-back runs in one process —
/// no wall clock or OS randomness, so runs stay reproducible.
fn mint_session() -> u64 {
    static SESSION_COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = SESSION_COUNTER.fetch_add(1, Ordering::Relaxed) + 1;
    ((std::process::id() as u64) << 32) | (n & 0xffff_ffff)
}

/// A run's connection to its parameter server: the coordinator link
/// plus a factory for per-worker links, selected by `[ps] transport`.
/// This is the only place `workers::service` touches transport-kind
/// specifics — everything downstream speaks [`Transport`].
pub struct PsConnection {
    coord: Box<dyn Transport>,
    minter: Minter,
    socket_bytes: Arc<AtomicU64>,
    /// Successful reconnects across every link (0 without the retry
    /// wrapper) — surfaced as `net.reconnects`.
    reconnects: Arc<AtomicU64>,
    /// Total backoff sleep across every link, µs — `net.retry_backoff_us`.
    retry_backoff_us: Arc<AtomicU64>,
    /// The v5 run-compression segment map, enabled on every TCP link
    /// this connection mints (`[ps] wire_compress`; `None` in-process —
    /// compression only exists where real bytes move). Routed fleets
    /// keep per-server maps in the minter instead.
    compress: Option<wire::SegmentMap>,
    /// Compressed f32 runs encoded across every link — `wire.runs_encoded`.
    runs_encoded: Arc<AtomicU64>,
    /// The shard→server map of a routed fleet; `None` single-server.
    route: Option<Arc<RouteMap>>,
    /// Inner RPCs the routed fan-out issued — `route.fanout_rpcs`.
    fanout_rpcs: Arc<AtomicU64>,
    /// Per-server socket byte meters (one per fleet member; empty for
    /// single-server connections, where `socket_bytes` is the total).
    per_server_bytes: Vec<Arc<AtomicU64>>,
    /// Per-server reconnect meters (same shape as `per_server_bytes`).
    per_server_reconnects: Vec<Arc<AtomicU64>>,
}

impl PsConnection {
    /// Establish the coordinator's link for a run: in-process builds
    /// the server here; TCP connects to `cfg.addr` and (re)initializes
    /// the hosted server with this run's shape. Either way the server
    /// comes up empty — seed it with `publish_range` before spawning
    /// workers.
    pub fn establish(
        cfg: &PsConfig,
        workers: usize,
        segments: &[(usize, usize)],
    ) -> Result<Self, TransportError> {
        let socket_bytes = Arc::new(AtomicU64::new(0));
        let reconnects = Arc::new(AtomicU64::new(0));
        let retry_backoff_us = Arc::new(AtomicU64::new(0));
        let runs_encoded = Arc::new(AtomicU64::new(0));
        let addrs = cfg.addrs();
        if addrs.len() > 1 && cfg.transport != TransportKind::Tcp {
            return Err(TransportError::Protocol(format!(
                "[ps] addr lists {} servers, which needs transport = tcp",
                addrs.len()
            )));
        }
        match cfg.transport {
            TransportKind::InProc => {
                let server = Arc::new(ParameterServer::with_segments_chunked(
                    cfg.shards,
                    workers,
                    cfg.policy(),
                    segments,
                    cfg.chunk_cells,
                ));
                Ok(PsConnection {
                    coord: Box::new(InProcTransport::new(Arc::clone(&server), COORDINATOR_ID)),
                    minter: Minter::InProc(server),
                    socket_bytes,
                    reconnects,
                    retry_backoff_us,
                    compress: None,
                    runs_encoded,
                    route: None,
                    fanout_rpcs: Arc::new(AtomicU64::new(0)),
                    per_server_bytes: Vec::new(),
                    per_server_reconnects: Vec::new(),
                })
            }
            TransportKind::Tcp if addrs.len() > 1 => {
                Self::establish_routed(cfg, workers, segments, addrs)
            }
            TransportKind::Tcp => {
                let session = mint_session();
                let compress = cfg.wire_compress.then(|| wire::SegmentMap::new(segments));
                // The retry wrapper engages when retries are enabled OR
                // a fault plan is set (injected faults without retries
                // would just kill the run).
                if cfg.retry_max > 0 || !cfg.fault_plan.is_empty() {
                    let plan = if cfg.fault_plan.is_empty() {
                        None
                    } else {
                        Some(Arc::new(FaultPlan::parse(&cfg.fault_plan).map_err(|e| {
                            TransportError::Protocol(format!("bad [ps] fault_plan: {e}"))
                        })?))
                    };
                    let retry =
                        RetryConfig { max: cfg.retry_max, backoff_ms: cfg.retry_backoff_ms };
                    let shape = InitShape {
                        shards: cfg.shards,
                        workers,
                        policy: cfg.policy(),
                        segments: segments.to_vec(),
                        chunk_cells: cfg.chunk_cells,
                        route_index: 0,
                        route_servers: 1,
                    };
                    let coord = RetryTransport::establish_with_compression(
                        &cfg.addr,
                        COORDINATOR_ID,
                        session,
                        shape.clone(),
                        retry,
                        plan.clone(),
                        Arc::clone(&socket_bytes),
                        Arc::clone(&reconnects),
                        Arc::clone(&retry_backoff_us),
                        compress.clone().map(|m| (m, Arc::clone(&runs_encoded))),
                    )?;
                    return Ok(PsConnection {
                        coord: Box::new(coord),
                        minter: Minter::Retry {
                            addr: cfg.addr.clone(),
                            session,
                            shape,
                            retry,
                            plan,
                        },
                        socket_bytes,
                        reconnects,
                        retry_backoff_us,
                        compress,
                        runs_encoded,
                        route: None,
                        fanout_rpcs: Arc::new(AtomicU64::new(0)),
                        per_server_bytes: Vec::new(),
                        per_server_reconnects: Vec::new(),
                    });
                }
                let mut coord = TcpTransport::connect(
                    &cfg.addr,
                    COORDINATOR_ID,
                    Arc::clone(&socket_bytes),
                )?;
                coord.init(
                    session,
                    cfg.shards,
                    workers,
                    cfg.policy(),
                    segments,
                    cfg.chunk_cells,
                )?;
                if let Some(map) = &compress {
                    coord.enable_compression(map.clone(), Arc::clone(&runs_encoded));
                }
                Ok(PsConnection {
                    coord: Box::new(coord),
                    minter: Minter::Tcp(cfg.addr.clone()),
                    socket_bytes,
                    reconnects,
                    retry_backoff_us,
                    compress,
                    runs_encoded,
                    route: None,
                    fanout_rpcs: Arc::new(AtomicU64::new(0)),
                    per_server_bytes: Vec::new(),
                    per_server_reconnects: Vec::new(),
                })
            }
        }
    }

    /// The N-server variant of [`PsConnection::establish`]: split the
    /// run's segments across the fleet with a [`RouteMap`], bring up
    /// one link per server (retry/fault-wrapped when those knobs are
    /// set — budgets and plans apply per server, so one member's crash
    /// is retried on its link alone), and hand back a
    /// [`RoutedTransport`] as the coordinator's view. Every server is
    /// `Init`ed with its own sub-segments, so its store — and
    /// therefore its checkpoint — holds exactly the shards it owns.
    fn establish_routed(
        cfg: &PsConfig,
        workers: usize,
        segments: &[(usize, usize)],
        addrs: Vec<String>,
    ) -> Result<Self, TransportError> {
        let n = addrs.len();
        let session = mint_session();
        let route = Arc::new(RouteMap::new(segments, n));
        let shapes: Vec<InitShape> = (0..n)
            .map(|i| InitShape {
                shards: cfg.shards,
                workers,
                policy: cfg.policy(),
                segments: route.server_segments(i),
                chunk_cells: cfg.chunk_cells,
                route_index: i,
                route_servers: n,
            })
            .collect();
        // Per-server compression maps: each side of a link classifies
        // keys against the segments *that server* registered.
        let compress: Vec<Option<wire::SegmentMap>> = shapes
            .iter()
            .map(|s| cfg.wire_compress.then(|| wire::SegmentMap::new(&s.segments)))
            .collect();
        let plan = if cfg.fault_plan.is_empty() {
            None
        } else {
            Some(Arc::new(FaultPlan::parse(&cfg.fault_plan).map_err(|e| {
                TransportError::Protocol(format!("bad [ps] fault_plan: {e}"))
            })?))
        };
        let retry = (cfg.retry_max > 0 || plan.is_some())
            .then_some(RetryConfig { max: cfg.retry_max, backoff_ms: cfg.retry_backoff_ms });
        let per_server_bytes: Vec<Arc<AtomicU64>> =
            (0..n).map(|_| Arc::new(AtomicU64::new(0))).collect();
        let per_server_reconnects: Vec<Arc<AtomicU64>> =
            (0..n).map(|_| Arc::new(AtomicU64::new(0))).collect();
        let retry_backoff_us = Arc::new(AtomicU64::new(0));
        let runs_encoded = Arc::new(AtomicU64::new(0));
        let fanout_rpcs = Arc::new(AtomicU64::new(0));
        let coord = mint_routed_link(
            &addrs,
            COORDINATOR_ID,
            session,
            &route,
            &shapes,
            retry,
            &plan,
            &compress,
            &per_server_bytes,
            &per_server_reconnects,
            &retry_backoff_us,
            &runs_encoded,
            &fanout_rpcs,
        )?;
        Ok(PsConnection {
            coord: Box::new(coord),
            minter: Minter::Routed {
                addrs,
                session,
                route: Arc::clone(&route),
                shapes,
                retry,
                plan,
                compress,
            },
            socket_bytes: Arc::new(AtomicU64::new(0)),
            reconnects: Arc::new(AtomicU64::new(0)),
            retry_backoff_us,
            compress: None,
            runs_encoded,
            route: Some(route),
            fanout_rpcs,
            per_server_bytes,
            per_server_reconnects,
        })
    }

    /// Mint `worker`'s own link (an `Arc` clone in-process, a fresh
    /// socket over TCP). Call on the coordinator thread so connection
    /// failures surface before any worker is spawned.
    pub fn worker_transport(&self, worker: usize) -> Result<Box<dyn Transport>, TransportError> {
        match &self.minter {
            Minter::InProc(server) => {
                Ok(Box::new(InProcTransport::new(Arc::clone(server), worker)))
            }
            Minter::Tcp(addr) => {
                let mut link =
                    TcpTransport::connect(addr, worker, Arc::clone(&self.socket_bytes))?;
                if let Some(map) = &self.compress {
                    link.enable_compression(map.clone(), Arc::clone(&self.runs_encoded));
                }
                Ok(Box::new(link))
            }
            Minter::Retry { addr, session, shape, retry, plan } => {
                Ok(Box::new(RetryTransport::establish_with_compression(
                    addr,
                    worker,
                    *session,
                    shape.clone(),
                    *retry,
                    plan.clone(),
                    Arc::clone(&self.socket_bytes),
                    Arc::clone(&self.reconnects),
                    Arc::clone(&self.retry_backoff_us),
                    self.compress.clone().map(|m| (m, Arc::clone(&self.runs_encoded))),
                )?))
            }
            Minter::Routed { addrs, session, route, shapes, retry, plan, compress } => {
                Ok(Box::new(mint_routed_link(
                    addrs,
                    worker,
                    *session,
                    route,
                    shapes,
                    *retry,
                    plan,
                    compress,
                    &self.per_server_bytes,
                    &self.per_server_reconnects,
                    &self.retry_backoff_us,
                    &self.runs_encoded,
                    &self.fanout_rpcs,
                )?))
            }
        }
    }

    /// The coordinator's link.
    pub fn coord(&mut self) -> &mut dyn Transport {
        &mut *self.coord
    }

    /// Real bytes moved through sockets so far, summed over every link
    /// this connection minted (0 for the in-process transport). This is
    /// measured traffic — frame headers included — as opposed to the
    /// modeled `net_bytes` meter.
    pub fn socket_bytes(&self) -> u64 {
        self.socket_bytes.load(Ordering::Relaxed)
            + self.per_server_bytes.iter().map(|b| b.load(Ordering::Relaxed)).sum::<u64>()
    }

    /// Successful reconnects across every link this connection minted
    /// (0 unless the retry wrapper is engaged).
    pub fn reconnects(&self) -> u64 {
        self.reconnects.load(Ordering::Relaxed)
            + self.per_server_reconnects.iter().map(|r| r.load(Ordering::Relaxed)).sum::<u64>()
    }

    /// Total retry backoff slept across every link, in microseconds.
    pub fn retry_backoff_us(&self) -> u64 {
        self.retry_backoff_us.load(Ordering::Relaxed)
    }

    /// Compressed f32 value runs encoded onto the wire across every
    /// link this connection minted (0 in-process or with
    /// `wire_compress = off`) — surfaced as `wire.runs_encoded`.
    pub fn runs_encoded(&self) -> u64 {
        self.runs_encoded.load(Ordering::Relaxed)
    }

    /// Fleet size: the number of `[ps] addr` servers this connection
    /// routes over (1 for in-process and single-server TCP).
    pub fn route_servers(&self) -> usize {
        self.route.as_ref().map_or(1, |r| r.servers())
    }

    /// Inner RPCs the routed fan-out issued across every link this
    /// connection minted (0 single-server) — `route.fanout_rpcs`.
    pub fn route_fanout_rpcs(&self) -> u64 {
        self.fanout_rpcs.load(Ordering::Relaxed)
    }

    /// Per-server socket bytes, indexed like `[ps] addr`. Single-server
    /// connections report their one total.
    pub fn socket_bytes_per_server(&self) -> Vec<u64> {
        if self.per_server_bytes.is_empty() {
            vec![self.socket_bytes()]
        } else {
            self.per_server_bytes.iter().map(|b| b.load(Ordering::Relaxed)).collect()
        }
    }

    /// Per-server reconnects, indexed like `[ps] addr` — the meter the
    /// chaos suite reads to pin *which* server's links died.
    pub fn reconnects_per_server(&self) -> Vec<u64> {
        if self.per_server_reconnects.is_empty() {
            vec![self.reconnects()]
        } else {
            self.per_server_reconnects.iter().map(|r| r.load(Ordering::Relaxed)).collect()
        }
    }
}

/// Mint one routed link for `worker`: a [`RoutedTransport`] over one
/// inner link per fleet member — retry/fault-wrapped per server when
/// `retry` is set, plain `TcpTransport` otherwise — each wired to its
/// server's own byte/reconnect meters and compression map.
#[allow(clippy::too_many_arguments)]
fn mint_routed_link(
    addrs: &[String],
    worker: usize,
    session: u64,
    route: &Arc<RouteMap>,
    shapes: &[InitShape],
    retry: Option<RetryConfig>,
    plan: &Option<Arc<FaultPlan>>,
    compress: &[Option<wire::SegmentMap>],
    per_server_bytes: &[Arc<AtomicU64>],
    per_server_reconnects: &[Arc<AtomicU64>],
    retry_backoff_us: &Arc<AtomicU64>,
    runs_encoded: &Arc<AtomicU64>,
    fanout_rpcs: &Arc<AtomicU64>,
) -> Result<RoutedTransport, TransportError> {
    let mut inner: Vec<Box<dyn Transport>> = Vec::with_capacity(addrs.len());
    for (i, addr) in addrs.iter().enumerate() {
        let link: Box<dyn Transport> = match retry {
            Some(rcfg) => Box::new(RetryTransport::establish_with_compression(
                addr,
                worker,
                session,
                shapes[i].clone(),
                rcfg,
                plan.clone(),
                Arc::clone(&per_server_bytes[i]),
                Arc::clone(&per_server_reconnects[i]),
                Arc::clone(retry_backoff_us),
                compress[i].clone().map(|m| (m, Arc::clone(runs_encoded))),
            )?),
            None => {
                let mut link =
                    TcpTransport::connect(addr, worker, Arc::clone(&per_server_bytes[i]))?;
                link.init_routed(
                    session,
                    shapes[i].shards,
                    shapes[i].workers,
                    shapes[i].policy,
                    &shapes[i].segments,
                    shapes[i].chunk_cells,
                    shapes[i].route_index,
                    shapes[i].route_servers,
                )?;
                if let Some(map) = &compress[i] {
                    link.enable_compression(map.clone(), Arc::clone(runs_encoded));
                }
                Box::new(link)
            }
        };
        inner.push(link);
    }
    Ok(RoutedTransport::new(inner, Arc::clone(route), Arc::clone(fanout_rpcs)))
}

/// One-shot introspection fetch for `strads ps-stats`: open a fresh
/// link to a running `ps-server` and ask it for its registry snapshot.
/// Works against an idle (pre-`Init`) server too — that case comes back
/// as [`TransportError::Remote`] with a message saying so.
pub fn fetch_obs_stats(addr: &str) -> Result<ObsSnapshot, TransportError> {
    let mut link = TcpTransport::connect(addr, COORDINATOR_ID, Arc::new(AtomicU64::new(0)))?;
    link.obs_stats()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("inproc").unwrap(), TransportKind::InProc);
        assert_eq!(TransportKind::parse("tcp").unwrap(), TransportKind::Tcp);
        assert!(TransportKind::parse("carrier-pigeon").is_err());
        assert_eq!(TransportKind::default().name(), "inproc");
        assert_eq!(TransportKind::Tcp.name(), "tcp");
    }

    #[test]
    fn inproc_connection_serves_the_full_coordinator_surface() {
        let cfg = PsConfig::default();
        let mut conn = PsConnection::establish(&cfg, 2, &[(0, 4)]).unwrap();
        conn.coord().publish_range(0, &[1.0, 2.0, 3.0, 4.0], 0).unwrap();
        conn.coord().publish(&[(2, 9.0)], 1).unwrap();
        conn.coord().advance_applied(1).unwrap();

        let mut w0 = conn.worker_transport(0).unwrap();
        let reply = w0.pull(&PullSpec::from_ranges(vec![(0, 4)]), 1).unwrap();
        assert_eq!(reply.ranges[0].values(), &[1.0f32, 2.0, 9.0, 4.0]);
        assert!(w0.flush(&[(0, 0.5)], 1, 0).unwrap(), "unique flush must apply");
        assert!(
            !w0.flush(&[(0, 0.5)], 1, 0).unwrap(),
            "replaying the same (round, block) must be dropped by the ledger"
        );

        let stats = conn.coord().stats().unwrap();
        assert_eq!(stats.pulls, 1);
        assert_eq!(stats.flushes, 1);
        assert!(stats.bytes_republished > 0, "publish must meter");
        assert_eq!(conn.socket_bytes(), 0, "in-process moves no socket bytes");

        let snap = conn.coord().obs_stats().unwrap();
        assert_eq!(snap.get("ps.pulls").unwrap().as_u64(), 1, "registry views the same pull");

        conn.coord().shutdown_clock().unwrap();
        let err = w0.pull(&PullSpec::from_keys(vec![0]), 100).unwrap_err();
        assert!(err.is_shutdown(), "{err}");
    }
}
