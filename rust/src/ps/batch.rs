//! Delta batching/coalescing: workers accumulate `(key, δ)` pairs
//! locally and flush one coalesced batch per round, so a key touched by
//! many updates in a round crosses the (simulated) wire once. The flush
//! also meters wire traffic for the `metrics` trace.

use crate::util::FastHashMap;

/// Wire cost of one coalesced entry: 8-byte key + 8-byte f64 delta.
pub const BYTES_PER_ENTRY: u64 = 16;

/// Wire bytes for `entries` sparse `(key, value)` pairs — shared by the
/// flush meter below and the coordinator's republish meter, so both
/// sides of the `net_bytes` trace column use the same cost model.
pub fn wire_bytes_for(entries: usize) -> u64 {
    entries as u64 * BYTES_PER_ENTRY
}

/// A worker-local accumulation of parameter deltas.
///
/// Coalescing sums deltas for duplicate keys; drain order is first-
/// insertion order, which keeps the flushed batch deterministic (the
/// coordinator's canonical apply relies on this for reproducibility).
#[derive(Debug, Default)]
pub struct DeltaBatch {
    acc: FastHashMap<usize, f64>,
    order: Vec<usize>,
}

impl DeltaBatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of distinct keys currently batched.
    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Accumulate one delta (coalesces with any batched delta for `key`).
    pub fn add(&mut self, key: usize, delta: f64) {
        use std::collections::hash_map::Entry;
        match self.acc.entry(key) {
            Entry::Occupied(mut e) => *e.get_mut() += delta,
            Entry::Vacant(e) => {
                e.insert(delta);
                self.order.push(key);
            }
        }
    }

    pub fn extend(&mut self, deltas: &[(usize, f64)]) {
        for &(key, delta) in deltas {
            self.add(key, delta);
        }
    }

    /// Drain into a coalesced `(key, δ)` list in first-insertion order,
    /// leaving the batch empty for the next round.
    pub fn drain(&mut self) -> Vec<(usize, f64)> {
        let out = self
            .order
            .drain(..)
            .map(|key| (key, self.acc.remove(&key).expect("order/acc in sync")))
            .collect();
        debug_assert!(self.acc.is_empty());
        out
    }

    /// Wire bytes the current batch would cost to flush.
    pub fn wire_bytes(&self) -> u64 {
        wire_bytes_for(self.order.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coalesces_duplicate_keys() {
        let mut b = DeltaBatch::new();
        b.extend(&[(3, 1.0), (7, 2.0), (3, 0.5), (7, -2.0), (3, 0.25)]);
        assert_eq!(b.len(), 2);
        assert_eq!(b.wire_bytes(), 2 * BYTES_PER_ENTRY);
        let flushed = b.drain();
        assert_eq!(flushed, vec![(3, 1.75), (7, 0.0)]);
        assert!(b.is_empty());
    }

    #[test]
    fn drain_preserves_first_insertion_order() {
        let mut b = DeltaBatch::new();
        for &k in &[9, 1, 5, 1, 9, 2] {
            b.add(k, 1.0);
        }
        let keys: Vec<usize> = b.drain().into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec![9, 1, 5, 2]);
    }

    #[test]
    fn reusable_after_drain() {
        let mut b = DeltaBatch::new();
        b.add(0, 1.0);
        assert_eq!(b.drain(), vec![(0, 1.0)]);
        b.add(0, 2.0);
        b.add(4, 3.0);
        assert_eq!(b.drain(), vec![(0, 2.0), (4, 3.0)]);
    }
}
