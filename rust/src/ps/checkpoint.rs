//! Server-side checkpoint/restore: the durability rung of the fault-
//! tolerance ladder (ROADMAP; the format spec lives in
//! `docs/ARCHITECTURE.md §Fault tolerance`).
//!
//! A checkpoint is one consistent image of everything a run would lose
//! if the `ps-server` process died: the dense segments' chunked epoch
//! slabs (raw little-endian f32 — bit-exact by construction — plus one
//! epoch version per chunk), the hashed cells, the SSP clock vector,
//! and the per-worker flush-dedup seqs. Immutable epochs make the
//! capture cheap — each chunk's slab is copied under its read lock,
//! held only for that memcpy; serialization happens afterwards with no
//! server lock held.
//!
//! Writes are crash-safe **and durable**: the image goes to
//! `ps.ckpt.tmp`, is fsynced, `rename`d over `ps.ckpt`, and then the
//! *directory* is fsynced too — without the directory sync a power cut
//! can lose the rename itself, leaving the previous (or no) checkpoint
//! behind a file the process already reported written. Each write also
//! hard-links a versioned image `ps-<applied>.ckpt` and prunes to the
//! newest `checkpoint_keep` of those, so one corrupted latest image
//! does not erase the whole durability ladder. The TCP server writes
//! one every `checkpoint_every` clock ticks and at graceful stop; on
//! bind it restores `ps.ckpt` (if present) so reconnecting clients
//! resume the run where the clock left off.

use super::clock::StalenessPolicy;
use super::shard::Cell;
use super::ParameterServer;
use std::io::Write;
use std::path::Path;

/// Leading bytes of every checkpoint file.
pub const CKPT_MAGIC: &[u8; 8] = b"STRADSCK";
/// Bump on any layout change; a reader refuses newer versions. v2
/// added the membership (live) bitmap after the flush seqs; v3 added
/// the store's `chunk_cells` and per-chunk epoch versions inside each
/// segment record. Older files are still read (v1's census is presumed
/// fully live; v1/v2's single segment version is broadcast to every
/// chunk).
pub const CKPT_VERSION: u32 = 3;
/// The checkpoint file name inside `--checkpoint-dir` (always the
/// newest image; versioned `ps-<applied>.ckpt` hard links sit beside
/// it, pruned to `checkpoint_keep`).
pub const CKPT_FILE: &str = "ps.ckpt";

/// Where and how often the TCP server checkpoints.
#[derive(Clone, Debug)]
pub struct CheckpointConfig {
    /// Directory holding `ps.ckpt` (created if missing).
    pub dir: std::path::PathBuf,
    /// Write every N `Advance` clock ticks (>= 1).
    pub every: u64,
    /// Versioned images retained besides `ps.ckpt` (>= 1).
    pub keep: usize,
}

/// A captured, not-yet-serialized checkpoint: `Arc` views of the epoch
/// slabs plus plain copies of the small state. Capture is cheap and
/// consistent; [`CheckpointImage::write_to`] does the actual I/O.
pub struct CheckpointImage {
    session: u64,
    shards: usize,
    workers: usize,
    policy: StalenessPolicy,
    applied: u64,
    worker_clocks: Vec<u64>,
    /// Membership bitmap, parallel to `worker_clocks` (v2+): retired
    /// workers must stay retired across a restore, or the rebuilt gate
    /// would park every survivor on a clock that died before the crash.
    live: Vec<bool>,
    flush_seqs: Vec<u64>,
    /// The store's chunk size (v3+): restores rebuild the same chunk
    /// geometry so per-chunk versions land where they were captured.
    chunk_cells: usize,
    /// `(start, per-chunk versions, concatenated slab)` per dense
    /// segment.
    segments: Vec<(usize, Vec<u64>, Vec<f32>)>,
    /// Hashed cells, sorted by key (deterministic bytes).
    cells: Vec<(usize, Cell)>,
}

/// What [`read_checkpoint`] rebuilds: a server primed with the saved
/// store + clock, plus the session and flush seqs the TCP host needs
/// to reattach reconnecting clients without double-applying flushes.
pub struct Restored {
    pub server: ParameterServer,
    pub session: u64,
    pub flush_seqs: Vec<u64>,
}

impl CheckpointImage {
    /// Snapshot `server` (plus the transport-layer `session` and
    /// `flush_seqs`). Each chunk's slab is copied under its own read
    /// lock, so the image is immutable from here on and the caller can
    /// serialize without any server lock held. The caller is
    /// responsible for pairing this with the flush path (the TCP host
    /// captures under its state mutex) so `flush_seqs` and the applied
    /// deltas agree.
    pub fn capture(server: &ParameterServer, session: u64, flush_seqs: &[u64]) -> Self {
        CheckpointImage {
            session,
            shards: server.store().num_shards(),
            workers: server.clock().num_workers(),
            policy: server.policy(),
            applied: server.clock().applied(),
            worker_clocks: server.clock().worker_clocks(),
            live: server.clock().live_flags(),
            flush_seqs: flush_seqs.to_vec(),
            chunk_cells: server.store().chunk_cells(),
            segments: server.store().segment_images(),
            cells: server.store().hashed_cells(),
        }
    }

    /// Serialize to `dir/ps.ckpt` via write-temp-fsync-rename, fsync
    /// the directory (the rename itself is not durable until the
    /// directory entry is), hard-link the versioned `ps-<applied>.ckpt`
    /// beside it, and prune versioned images beyond the newest `keep`.
    /// Returns the bytes written.
    pub fn write_to(&self, dir: &Path, keep: usize) -> std::io::Result<u64> {
        std::fs::create_dir_all(dir)?;
        let bytes = self.to_bytes();
        let tmp = dir.join(format!("{CKPT_FILE}.tmp"));
        let latest = dir.join(CKPT_FILE);
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&bytes)?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, &latest)?;
        // The versioned image shares the inode just made durable; two
        // checkpoints at the same applied tick overwrite (remove first:
        // hard_link refuses to replace).
        let versioned = dir.join(format!("ps-{:020}.ckpt", self.applied));
        let _ = std::fs::remove_file(&versioned);
        std::fs::hard_link(&latest, &versioned)?;
        // Directory fsync covers the rename, the new link, and (below)
        // the prunes — one sync at the end would leave a window where
        // the rename is reported durable but is not, so sync here first.
        std::fs::File::open(dir)?.sync_all()?;
        prune_versioned(dir, keep.max(1))?;
        Ok(bytes.len() as u64)
    }

    fn to_bytes(&self) -> Vec<u8> {
        let slab_bytes: usize =
            self.segments.iter().map(|(_, vs, s)| 20 + 8 * vs.len() + 4 * s.len()).sum();
        let mut b = Vec::with_capacity(72 + 16 * self.workers + slab_bytes + 24 * self.cells.len());
        b.extend_from_slice(CKPT_MAGIC);
        b.extend_from_slice(&CKPT_VERSION.to_le_bytes());
        b.extend_from_slice(&self.session.to_le_bytes());
        b.extend_from_slice(&(self.shards as u32).to_le_bytes());
        b.extend_from_slice(&(self.workers as u32).to_le_bytes());
        b.extend_from_slice(&(self.chunk_cells as u64).to_le_bytes());
        match self.policy {
            StalenessPolicy::Bounded(s) => {
                b.push(0);
                b.extend_from_slice(&s.to_le_bytes());
            }
            StalenessPolicy::Async => {
                b.push(1);
                b.extend_from_slice(&0u64.to_le_bytes());
            }
        }
        b.extend_from_slice(&self.applied.to_le_bytes());
        debug_assert_eq!(self.worker_clocks.len(), self.workers);
        debug_assert_eq!(self.live.len(), self.workers);
        debug_assert_eq!(self.flush_seqs.len(), self.workers);
        for &c in &self.worker_clocks {
            b.extend_from_slice(&c.to_le_bytes());
        }
        for &l in &self.live {
            b.push(u8::from(l));
        }
        for &s in &self.flush_seqs {
            b.extend_from_slice(&s.to_le_bytes());
        }
        b.extend_from_slice(&(self.segments.len() as u32).to_le_bytes());
        for (start, versions, slab) in &self.segments {
            b.extend_from_slice(&(*start as u64).to_le_bytes());
            b.extend_from_slice(&(slab.len() as u64).to_le_bytes());
            b.extend_from_slice(&(versions.len() as u32).to_le_bytes());
            for &v in versions.iter() {
                b.extend_from_slice(&v.to_le_bytes());
            }
            for &v in slab.iter() {
                b.extend_from_slice(&v.to_le_bytes());
            }
        }
        b.extend_from_slice(&(self.cells.len() as u32).to_le_bytes());
        for &(key, cell) in &self.cells {
            b.extend_from_slice(&(key as u64).to_le_bytes());
            b.extend_from_slice(&cell.version.to_le_bytes());
            b.extend_from_slice(&cell.value.to_le_bytes());
        }
        b
    }
}

/// Checked sequential reader over the checkpoint bytes (same posture
/// as the wire decoder: truncation is an error, never a panic).
struct Rd<'a> {
    buf: &'a [u8],
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(self.buf.len() >= n, "truncated checkpoint: wanted {n} more bytes");
        let (head, rest) = self.buf.split_at(n);
        self.buf = rest;
        Ok(head)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("take(4)")))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("take(8)")))
    }

    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("take(8)")))
    }

    /// A count whose elements occupy at least `elem_bytes` each —
    /// validated against the remaining bytes before any allocation.
    fn count(&mut self, n: usize, elem_bytes: usize) -> anyhow::Result<usize> {
        anyhow::ensure!(
            n.saturating_mul(elem_bytes) <= self.buf.len(),
            "checkpoint count {n} x {elem_bytes}B exceeds the {}B left",
            self.buf.len()
        );
        Ok(n)
    }
}

/// Restore `dir/ps.ckpt` into a fresh [`ParameterServer`]. `Ok(None)`
/// when no checkpoint exists (a cold start); a corrupt or wrong-version
/// file is an error rather than silent data loss.
pub fn read_checkpoint(dir: &Path) -> anyhow::Result<Option<Restored>> {
    let path = dir.join(CKPT_FILE);
    let bytes = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let mut r = Rd { buf: &bytes };
    anyhow::ensure!(r.take(8)? == CKPT_MAGIC, "{} is not a checkpoint file", path.display());
    let version = r.u32()?;
    anyhow::ensure!(
        version >= 1 && version <= CKPT_VERSION,
        "checkpoint version {version} unsupported (this build reads v1..=v{CKPT_VERSION})"
    );
    let session = r.u64()?;
    let shards = r.u32()? as usize;
    let workers = r.u32()? as usize;
    // v1/v2 predate chunked slabs: whole-segment chunks.
    let chunk_cells = if version >= 3 { r.u64()? as usize } else { 0 };
    let policy = match (r.u8()?, r.u64()?) {
        (0, s) => StalenessPolicy::Bounded(s),
        (1, _) => StalenessPolicy::Async,
        (tag, _) => anyhow::bail!("unknown policy tag {tag} in checkpoint"),
    };
    let applied = r.u64()?;
    let nworkers = r.count(workers, 16)?;
    let mut worker_clocks = Vec::with_capacity(nworkers);
    for _ in 0..nworkers {
        worker_clocks.push(r.u64()?);
    }
    // v1 predates elastic membership: its whole census is live.
    let live = if version >= 2 {
        r.take(nworkers)?.iter().map(|&b| b != 0).collect()
    } else {
        vec![true; nworkers]
    };
    let mut flush_seqs = Vec::with_capacity(nworkers);
    for _ in 0..nworkers {
        flush_seqs.push(r.u64()?);
    }
    let nseg = r.u32()? as usize;
    let nseg = r.count(nseg, 20)?;
    let mut segments = Vec::with_capacity(nseg);
    for _ in 0..nseg {
        let start = r.u64()? as usize;
        let len = r.u64()? as usize;
        let len = r.count(len, 4)?;
        // v1/v2 carried one version for the whole segment; the restore
        // broadcasts a length-1 version list to every chunk.
        let versions: Vec<u64> = if version >= 3 {
            let nchunks = r.u32()? as usize;
            let nchunks = r.count(nchunks, 8)?;
            (0..nchunks).map(|_| r.u64()).collect::<anyhow::Result<_>>()?
        } else {
            vec![r.u64()?]
        };
        let values: Vec<f32> = r
            .take(len * 4)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().expect("chunks_exact(4)")))
            .collect();
        segments.push((start, versions, values));
    }
    let server = ParameterServer::with_segments_chunked(
        shards,
        workers,
        policy,
        &segments.iter().map(|(s, _, v)| (*s, v.len())).collect::<Vec<_>>(),
        chunk_cells,
    );
    for (start, versions, values) in segments {
        anyhow::ensure!(
            server.store().restore_segment(start, values, &versions),
            "checkpoint segment at key {start} does not fit the rebuilt store"
        );
    }
    let ncells = r.u32()? as usize;
    let ncells = r.count(ncells, 24)?;
    let mut cells = Vec::with_capacity(ncells);
    for _ in 0..ncells {
        cells.push((r.u64()? as usize, Cell { version: r.u64()?, value: r.f64()? }));
    }
    server.store().restore_cells(&cells);
    server.clock().restore(&worker_clocks, &live, applied);
    anyhow::ensure!(r.buf.is_empty(), "{} trailing bytes after checkpoint", r.buf.len());
    Ok(Some(Restored { server, session, flush_seqs }))
}

/// Delete versioned `ps-*.ckpt` images beyond the newest `keep`.
/// `ps.ckpt` itself (the newest image's other name) is never touched.
/// Zero-padded applied counts make the lexical order the numeric one.
fn prune_versioned(dir: &Path, keep: usize) -> std::io::Result<()> {
    let mut versioned: Vec<std::path::PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("ps-") && n.ends_with(".ckpt"))
                .unwrap_or(false)
        })
        .collect();
    versioned.sort();
    let excess = versioned.len().saturating_sub(keep);
    for old in &versioned[..excess] {
        std::fs::remove_file(old)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::shard::PullSpec;

    #[test]
    fn checkpoint_roundtrip_is_bit_exact() {
        let dir = std::env::temp_dir().join(format!("strads_ckpt_rt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(read_checkpoint(&dir).unwrap().is_none(), "no file = cold start");

        let server =
            ParameterServer::with_segments(4, 3, StalenessPolicy::Bounded(2), &[(0, 6), (10, 2)]);
        server.store().publish_dense(&[0.1, -0.0, 3.5e-7, 4.0, -5.5, 6.25], 3);
        server.store().publish(&[(100, 1e-300), (50, -2.5)], 4);
        server.clock().record_flush(0, 4);
        server.clock().record_flush(2, 3);
        server.clock().advance_applied(4);
        // Membership must survive the roundtrip: a worker retired
        // before the crash has to stay retired after the restore.
        server.clock().retire(1);
        let image = CheckpointImage::capture(&server, 77, &[5, 4, 4]);
        let bytes = image.write_to(&dir, 2).unwrap();
        assert!(bytes > 0);

        let restored = read_checkpoint(&dir).unwrap().expect("checkpoint present");
        assert_eq!(restored.session, 77);
        assert_eq!(restored.flush_seqs, vec![5, 4, 4]);
        assert_eq!(restored.server.policy(), StalenessPolicy::Bounded(2));
        assert_eq!(restored.server.store().num_shards(), 4);
        assert_eq!(restored.server.clock().applied(), 4);
        assert_eq!(restored.server.clock().worker_clocks(), vec![5, 0, 4]);
        assert_eq!(restored.server.clock().live_flags(), vec![true, false, true]);
        // bitwise store equality: segment images and hashed cells
        let spec = PullSpec { ranges: vec![(0, 6), (10, 2)], keys: vec![50, 100] };
        let (orig, back) =
            (server.store().read_spec(&spec), restored.server.store().read_spec(&spec));
        for (a, b) in orig.ranges.iter().zip(&back.ranges) {
            let bits = |r: &crate::ps::RangePull| {
                r.values().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            };
            assert_eq!(bits(a), bits(b));
            assert_eq!(a.version(), b.version());
        }
        assert_eq!(orig.cells, back.cells);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_checkpoints_are_refused() {
        let dir = std::env::temp_dir().join(format!("strads_ckpt_bad_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(CKPT_FILE), b"not a checkpoint").unwrap();
        assert!(read_checkpoint(&dir).is_err(), "bad magic must error, not restore");

        let server = ParameterServer::with_segments(1, 1, StalenessPolicy::Bounded(0), &[(0, 4)]);
        let image = CheckpointImage::capture(&server, 1, &[0]);
        image.write_to(&dir, 2).unwrap();
        let mut bytes = std::fs::read(dir.join(CKPT_FILE)).unwrap();
        bytes.truncate(bytes.len() - 3);
        std::fs::write(dir.join(CKPT_FILE), &bytes).unwrap();
        assert!(read_checkpoint(&dir).is_err(), "truncation must error");

        // A future version must be refused, not half-read.
        let mut future = image.to_bytes();
        future[8..12].copy_from_slice(&(CKPT_VERSION + 1).to_le_bytes());
        std::fs::write(dir.join(CKPT_FILE), &future).unwrap();
        assert!(read_checkpoint(&dir).is_err(), "future version must error");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn v1_and_v2_checkpoints_still_restore() {
        let dir = std::env::temp_dir().join(format!("strads_ckpt_v1_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();

        let server = ParameterServer::with_segments(2, 3, StalenessPolicy::Bounded(1), &[(0, 4)]);
        server.store().publish_dense(&[1.5, -2.0, 0.25, 8.0], 2);
        server.clock().advance_applied(2);
        let v3 = CheckpointImage::capture(&server, 9, &[1, 2, 3]).to_bytes();
        // Rewrite the v3 image as v2 by splicing out what v3 added: the
        // per-segment chunk count (one chunk here, so the single
        // version that follows doubles as v2's segment version) and the
        // global chunk_cells after the worker count. Offsets: header
        // 8+4+8+4+4, chunk_cells 8, policy 9, applied 8, clocks 24,
        // live 3, seqs 24, nseg 4, start+len 16, then nchunks 4.
        let mut v2 = v3.clone();
        v2[8..12].copy_from_slice(&2u32.to_le_bytes());
        v2.drain(124..128); // nchunks u32 before the lone chunk version
        v2.drain(28..36); // chunk_cells u64
        std::fs::write(dir.join(CKPT_FILE), &v2).unwrap();
        let restored = read_checkpoint(&dir).unwrap().expect("v2 readable");
        assert_eq!(restored.session, 9);
        assert_eq!(
            restored.server.store().segment_images(),
            server.store().segment_images(),
            "a v2 single segment version broadcasts to the one chunk"
        );

        // v1 additionally lacks the live bitmap after the clocks.
        let mut v1 = v2;
        v1[8..12].copy_from_slice(&1u32.to_le_bytes());
        v1.drain(69..72); // live bytes (3 workers) in the v1/v2 layout
        std::fs::write(dir.join(CKPT_FILE), &v1).unwrap();
        let restored = read_checkpoint(&dir).unwrap().expect("v1 readable");
        assert_eq!(restored.flush_seqs, vec![1, 2, 3]);
        assert_eq!(
            restored.server.clock().live_flags(),
            vec![true, true, true],
            "a pre-elastic census is presumed fully live"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn chunked_checkpoints_keep_per_chunk_versions() {
        let dir = std::env::temp_dir().join(format!("strads_ckpt_chunk_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let server = ParameterServer::with_segments_chunked(
            2,
            1,
            StalenessPolicy::Bounded(0),
            &[(0, 7)],
            3,
        );
        server.store().publish_dense(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0], 1);
        // Touch only the middle chunk so versions diverge: [1, 2, 1].
        server.store().publish_range(3, &[-4.0, -5.0], 2);
        let before = server.store().segment_images();
        assert_eq!(before[0].1, vec![1, 2, 1], "precondition: versions diverged");
        CheckpointImage::capture(&server, 5, &[0]).write_to(&dir, 1).unwrap();

        let restored = read_checkpoint(&dir).unwrap().expect("present");
        assert_eq!(restored.server.store().chunk_cells(), 3, "geometry restored");
        assert_eq!(restored.server.store().segment_images(), before);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn versioned_images_are_pruned_to_keep() {
        let dir = std::env::temp_dir().join(format!("strads_ckpt_keep_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let server = ParameterServer::with_segments(1, 1, StalenessPolicy::Bounded(0), &[(0, 2)]);
        for tick in 1..=5u64 {
            server.clock().advance_applied(tick);
            CheckpointImage::capture(&server, 1, &[0]).write_to(&dir, 2).unwrap();
        }
        let mut names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        names.sort();
        assert_eq!(
            names,
            vec![
                format!("ps-{:020}.ckpt", 4),
                format!("ps-{:020}.ckpt", 5),
                CKPT_FILE.to_string(),
            ],
            "only the newest keep=2 versioned images (plus ps.ckpt) survive"
        );
        // ps.ckpt always restores to the newest image.
        let restored = read_checkpoint(&dir).unwrap().expect("present");
        assert_eq!(restored.server.clock().applied(), 5);
        // Overwriting the same applied tick is fine (restart at a tick).
        CheckpointImage::capture(&server, 1, &[0]).write_to(&dir, 2).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
