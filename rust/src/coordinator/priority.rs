//! SAP step 1 + 4: the importance distribution p(j) and its updates.
//!
//! Paper (§2.1, §4): p(j) ∝ δβ_j^(t-1) + η, with the initialization
//! trick β^(t_j - 2) = C (a huge constant) so that *untouched*
//! coordinates carry maximal weight — every variable is visited early,
//! after which measured progress takes over. Theorem 1 shows the
//! squared variant p(j) ∝ ½(δβ_j)² approximately maximizes the expected
//! per-iteration objective decrease; both are provided.

use crate::util::{Fenwick, Rng};

/// Which transform of |δβ| feeds the sampling weight.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PriorityKind {
    /// w_j = |δβ_j| + η (the paper's practical choice).
    Linear,
    /// w_j = ½ δβ_j² + η (the Theorem-1 optimal form).
    Squared,
}

/// Importance distribution over a set of variables (one per shard).
#[derive(Clone, Debug)]
pub struct PriorityDist {
    fenwick: Fenwick,
    eta: f64,
    kind: PriorityKind,
    /// Variables never yet updated keep `init` weight (the C trick).
    touched: Vec<bool>,
    untouched_left: usize,
}

impl PriorityDist {
    pub fn new(n: usize, eta: f64, init: f64, kind: PriorityKind) -> Self {
        let weights = vec![init.max(eta); n];
        PriorityDist {
            fenwick: Fenwick::from_weights(&weights),
            eta,
            kind,
            touched: vec![false; n],
            untouched_left: n,
        }
    }

    pub fn len(&self) -> usize {
        self.fenwick.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fenwick.is_empty()
    }

    /// SAP step 4: record measured progress |δ| for local variable `i`.
    pub fn report(&mut self, i: usize, delta_abs: f64) {
        if !self.touched[i] {
            self.touched[i] = true;
            self.untouched_left -= 1;
        }
        let w = match self.kind {
            PriorityKind::Linear => delta_abs + self.eta,
            PriorityKind::Squared => 0.5 * delta_abs * delta_abs + self.eta,
        };
        self.fenwick.set(i, w);
    }

    /// SAP step 1: draw `k` distinct candidates ∝ current weights.
    pub fn sample_candidates(&mut self, k: usize, rng: &mut Rng) -> Vec<usize> {
        self.fenwick.sample_distinct(k.min(self.len()), rng)
    }

    /// Current weight of variable `i` (diagnostics / tests).
    pub fn weight(&self, i: usize) -> f64 {
        self.fenwick.get(i)
    }

    /// Fraction of variables updated at least once — the paper's "early
    /// sharp drop" happens right after this reaches 1.0 (§5.1).
    pub fn coverage(&self) -> f64 {
        1.0 - self.untouched_left as f64 / self.touched.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn untouched_variables_dominate_sampling() {
        let mut p = PriorityDist::new(100, 1e-6, 1e3, PriorityKind::Linear);
        // Touch 0..90 with tiny progress; 90..100 stay at init weight.
        for i in 0..90 {
            p.report(i, 1e-5);
        }
        let mut rng = Rng::new(1);
        let mut hits = 0;
        for _ in 0..200 {
            let c = p.sample_candidates(5, &mut rng);
            hits += c.iter().filter(|&&i| i >= 90).count();
        }
        // 10 untouched vars hold ~1e4x the weight of 90 touched ones.
        assert!(hits as f64 > 0.95 * 200.0 * 5.0, "hits {hits}");
    }

    #[test]
    fn progress_reweights_sampling() {
        let mut p = PriorityDist::new(10, 1e-6, 1.0, PriorityKind::Linear);
        for i in 0..10 {
            p.report(i, if i == 3 { 10.0 } else { 0.001 });
        }
        let mut rng = Rng::new(2);
        let mut count3 = 0;
        for _ in 0..1000 {
            if p.sample_candidates(1, &mut rng)[0] == 3 {
                count3 += 1;
            }
        }
        assert!(count3 > 900, "count3 {count3}");
    }

    #[test]
    fn squared_kind_amplifies_large_deltas() {
        let mut lin = PriorityDist::new(2, 1e-9, 1.0, PriorityKind::Linear);
        let mut sq = PriorityDist::new(2, 1e-9, 1.0, PriorityKind::Squared);
        for p in [&mut lin, &mut sq] {
            p.report(0, 2.0);
            p.report(1, 1.0);
        }
        let lin_ratio = lin.weight(0) / lin.weight(1);
        let sq_ratio = sq.weight(0) / sq.weight(1);
        assert!((lin_ratio - 2.0).abs() < 1e-6);
        assert!((sq_ratio - 4.0).abs() < 1e-6);
    }

    #[test]
    fn coverage_tracks_touched() {
        let mut p = PriorityDist::new(4, 1e-6, 1.0, PriorityKind::Linear);
        assert_eq!(p.coverage(), 0.0);
        p.report(0, 0.1);
        p.report(0, 0.2); // re-touch is idempotent
        p.report(1, 0.1);
        assert!((p.coverage() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn zero_delta_keeps_eta_floor() {
        let mut p = PriorityDist::new(3, 1e-4, 1.0, PriorityKind::Linear);
        p.report(0, 0.0);
        assert!(p.weight(0) > 0.0);
        let mut rng = Rng::new(3);
        // still sampleable
        let c = p.sample_candidates(3, &mut rng);
        assert_eq!(c.len(), 3);
    }
}
