//! The SAP scheduling primitives (paper §2–§3) — the pieces STRADS's
//! scheduling stack is built from.
//!
//! The four SAP steps map to submodules:
//!
//! 1. **[`priority`]** — the importance distribution p(j) ∝ δβ_j + η,
//!    Fenwick-backed so sampling and updating are O(log n).
//! 2. **[`depcheck`]** — ρ-constrained greedy block selection over the
//!    sampled candidate set (the argmin program of §4 step 2).
//! 3. **[`balance`]** — workload-equalizing block merging (the
//!    "curse of the last reducer" fix, used heavily by MF).
//! 4. progress monitoring lives in `priority::PriorityDist::report`.
//!
//! **[`shard`]** holds the §3 fixed random ownership partition. The
//! composition of all four steps into per-shard planners — used both
//! synchronously by the engine-path schedulers ([`crate::schedulers`])
//! and as rotating shard *threads* by the pipelined scheduler service
//! on the distributed path — lives in [`crate::sched_service`]: one
//! scheduling stack, two execution shapes.

pub mod balance;
pub mod depcheck;
pub mod priority;
pub mod shard;

pub use balance::{merge_balanced, partition_balanced, partition_uniform};
pub use depcheck::select_independent;
pub use priority::PriorityDist;
pub use shard::partition_owned;

/// Cost accounting for one scheduling decision, consumed by the virtual
/// cluster's cost model (the scheduler must never be the bottleneck —
/// §2's closing requirement — and we *charge* for it rather than wishing
/// it away).
#[derive(Clone, Copy, Debug, Default)]
pub struct SchedCost {
    /// Candidates drawn from p(j).
    pub candidates: usize,
    /// Pairwise dependency evaluations performed.
    pub dep_checks: usize,
}

impl SchedCost {
    pub fn add(&mut self, other: SchedCost) {
        self.candidates += other.candidates;
        self.dep_checks += other.dep_checks;
    }
}
