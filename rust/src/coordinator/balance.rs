//! SAP step 3: workload-balanced block formation.
//!
//! Two entry points:
//!
//! * [`merge_balanced`] — SAP's online form: merge the per-round blocks
//!   until every worker gets a similar total workload.
//! * [`partition_balanced`] — the MF form (paper §2.2 step 3): partition
//!   *all* rows/columns into exactly P blocks with near-equal nnz. The
//!   baseline [`partition_uniform`] splits by count, oblivious to nnz —
//!   the "no load balancing" scheduler of Fig 5.
//!
//! Balancing uses LPT (longest-processing-time-first greedy into the
//! currently-lightest bin), the classic 4/3-approximation to makespan
//! minimization — cheap enough to run every round.

use crate::problem::Block;

/// Merge blocks into at most `p` blocks with near-equal total work.
/// Order within a block is preserved; blocks are LPT-packed into bins.
pub fn merge_balanced(blocks: Vec<Block>, p: usize) -> Vec<Block> {
    assert!(p >= 1);
    if blocks.len() <= p {
        return blocks;
    }
    let mut order: Vec<usize> = (0..blocks.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(blocks[i].work));
    let mut bins: Vec<Block> = (0..p).map(|_| Block { vars: Vec::new(), work: 0 }).collect();
    for i in order {
        // lightest bin
        let b = bins
            .iter_mut()
            .min_by_key(|b| b.work)
            .expect("p >= 1 bins");
        b.vars.extend_from_slice(&blocks[i].vars);
        b.work += blocks[i].work;
    }
    bins.retain(|b| !b.vars.is_empty());
    bins
}

/// Partition items 0..n (with per-item weights) into exactly `p` blocks
/// of near-equal total weight (LPT greedy). Used by the MF scheduler
/// where items are rows/columns and weights are nnz.
pub fn partition_balanced(weights: &[u64], p: usize) -> Vec<Block> {
    assert!(p >= 1);
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(weights[i]));
    let mut bins: Vec<Block> = (0..p.min(weights.len().max(1)))
        .map(|_| Block { vars: Vec::new(), work: 0 })
        .collect();
    for i in order {
        let b = bins.iter_mut().min_by_key(|b| b.work).expect("bins nonempty");
        b.vars.push(i);
        b.work += weights[i];
    }
    bins.retain(|b| !b.vars.is_empty());
    bins
}

/// Baseline: partition items 0..n into `p` contiguous count-equal blocks,
/// ignoring weights (the "no load balancing" scheduler).
pub fn partition_uniform(weights: &[u64], p: usize) -> Vec<Block> {
    assert!(p >= 1);
    let n = weights.len();
    let mut out = Vec::with_capacity(p);
    let base = n / p;
    let extra = n % p;
    let mut start = 0;
    for b in 0..p {
        let len = base + usize::from(b < extra);
        if len == 0 {
            continue;
        }
        let vars: Vec<usize> = (start..start + len).collect();
        let work = vars.iter().map(|&i| weights[i]).sum();
        out.push(Block { vars, work });
        start += len;
    }
    out
}

/// Straggler ratio of a block set: max work / mean work (1.0 = perfect).
pub fn imbalance(blocks: &[Block]) -> f64 {
    if blocks.is_empty() {
        return 1.0;
    }
    let total: u64 = blocks.iter().map(|b| b.work).sum();
    let max = blocks.iter().map(|b| b.work).max().unwrap_or(0);
    let mean = total as f64 / blocks.len() as f64;
    if mean == 0.0 {
        1.0
    } else {
        max as f64 / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn works(blocks: &[Block]) -> Vec<u64> {
        blocks.iter().map(|b| b.work).collect()
    }

    #[test]
    fn merge_noop_when_few_blocks() {
        let blocks = vec![Block::singleton(0, 5), Block::singleton(1, 1)];
        let out = merge_balanced(blocks.clone(), 4);
        assert_eq!(out, blocks);
    }

    #[test]
    fn merge_balances_workloads() {
        let blocks: Vec<Block> = (0..16).map(|i| Block::singleton(i, (i % 4 + 1) as u64)).collect();
        let out = merge_balanced(blocks, 4);
        assert_eq!(out.len(), 4);
        let w = works(&out);
        let total: u64 = w.iter().sum();
        assert_eq!(total, 40);
        assert!(imbalance(&out) < 1.15, "imbalance {}", imbalance(&out));
        // all 16 vars present exactly once
        let mut vars: Vec<usize> = out.iter().flat_map(|b| b.vars.clone()).collect();
        vars.sort();
        assert_eq!(vars, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn partition_balanced_beats_uniform_on_powerlaw() {
        // Zipf-ish weights: first item huge, rest tiny.
        let mut weights = vec![1u64; 100];
        weights[0] = 200;
        weights[1] = 100;
        let bal = partition_balanced(&weights, 4);
        let uni = partition_uniform(&weights, 4);
        assert!(imbalance(&bal) < imbalance(&uni));
        // uniform puts both heavy items in block 0 -> severe straggler
        assert!(imbalance(&uni) > 2.0, "uniform imbalance {}", imbalance(&uni));
    }

    #[test]
    fn partition_covers_all_items_once() {
        let weights: Vec<u64> = (0..53).map(|i| (i * 7 % 13) as u64 + 1).collect();
        for p in [1, 2, 5, 8] {
            for blocks in [partition_balanced(&weights, p), partition_uniform(&weights, p)] {
                let mut vars: Vec<usize> = blocks.iter().flat_map(|b| b.vars.clone()).collect();
                vars.sort();
                assert_eq!(vars, (0..53).collect::<Vec<_>>(), "p={p}");
                for b in &blocks {
                    let w: u64 = b.vars.iter().map(|&i| weights[i]).sum();
                    assert_eq!(w, b.work);
                }
            }
        }
    }

    #[test]
    fn partition_more_bins_than_items() {
        let weights = vec![3u64, 1];
        let blocks = partition_balanced(&weights, 8);
        assert_eq!(blocks.len(), 2);
    }

    #[test]
    fn uniform_partition_is_contiguous() {
        let weights = vec![1u64; 10];
        let blocks = partition_uniform(&weights, 3);
        assert_eq!(blocks[0].vars, vec![0, 1, 2, 3]);
        assert_eq!(blocks[1].vars, vec![4, 5, 6]);
        assert_eq!(blocks[2].vars, vec![7, 8, 9]);
    }

    #[test]
    fn single_heavy_item_bounds_balance() {
        // one item with most of the mass: imbalance is inherent, but
        // balanced partition must still isolate it.
        let mut weights = vec![1u64; 20];
        weights[7] = 1000;
        let blocks = partition_balanced(&weights, 4);
        let heavy = blocks.iter().find(|b| b.vars.contains(&7)).unwrap();
        assert_eq!(heavy.vars.len(), 1, "heavy item should be isolated");
    }
}
