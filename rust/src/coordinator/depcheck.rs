//! SAP step 2: ρ-constrained selection of nearly-independent variables.
//!
//! Paper §4 step 2 poses the selection as
//!
//! ```text
//!   argmin_{v_1..v_P ⊂ candidates}  Σ_{j,k} |x_j^T x_k|
//!   s.t. |x_j^T x_k| ≤ ρ  for all j ≠ k
//! ```
//!
//! which is NP-hard in general (max-weight independent set); STRADS (and
//! we) use the natural greedy relaxation: visit candidates in priority
//! order and accept each one whose dependency to *every* already-
//! accepted variable is ≤ ρ. This inherits the constraint exactly
//! (correctness) and approximates the argmin (the greedy order favors
//! high-priority variables, which is what Theorem 1 actually needs).

/// Greedy ρ-constrained selection.
///
/// * `cands` — candidate variable ids, in descending priority order.
/// * `dep` — row-major `c x c` matrix of |d(x_j, x_k)| over `cands`.
/// * `rho` — coupling threshold.
/// * `limit` — max variables to accept (P).
///
/// Returns indices *into `cands`* of the accepted variables, preserving
/// priority order. O(c * P) pair checks.
pub fn select_independent(cands: &[usize], dep: &[f64], rho: f64, limit: usize) -> Vec<usize> {
    let c = cands.len();
    debug_assert_eq!(dep.len(), c * c, "dep matrix must be c x c");
    let mut accepted: Vec<usize> = Vec::with_capacity(limit.min(c));
    for i in 0..c {
        if accepted.len() >= limit {
            break;
        }
        let ok = accepted.iter().all(|&a| dep[i * c + a] <= rho);
        if ok {
            accepted.push(i);
        }
    }
    accepted
}

/// Lazy variant: `dep(a, b)` is queried on demand with early exit on
/// the first conflict, so the expected cost is far below the dense
/// O(c²) materialization (the selection is identical — same greedy
/// order, same constraint).
pub fn select_independent_lazy(
    cands: &[usize],
    mut dep: impl FnMut(usize, usize) -> f64,
    rho: f64,
    limit: usize,
) -> Vec<usize> {
    let c = cands.len();
    let mut accepted: Vec<usize> = Vec::with_capacity(limit.min(c));
    for i in 0..c {
        if accepted.len() >= limit {
            break;
        }
        let ok = accepted.iter().all(|&a| dep(cands[i], cands[a]) <= rho);
        if ok {
            accepted.push(i);
        }
    }
    accepted
}

/// Verify that a selection satisfies the pairwise constraint — used by
/// tests and debug assertions (the correctness invariant of step 2).
pub fn is_rho_independent(selected: &[usize], dep: &[f64], c: usize, rho: f64) -> bool {
    for (a_pos, &a) in selected.iter().enumerate() {
        for &b in &selected[a_pos + 1..] {
            if dep[a * c + b] > rho {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a dep matrix from an explicit conflict list.
    fn dep_from_conflicts(c: usize, conflicts: &[(usize, usize)]) -> Vec<f64> {
        let mut d = vec![0.0; c * c];
        for &(a, b) in conflicts {
            d[a * c + b] = 1.0;
            d[b * c + a] = 1.0;
        }
        d
    }

    #[test]
    fn independent_candidates_all_accepted() {
        let cands = [10, 20, 30];
        let dep = dep_from_conflicts(3, &[]);
        let sel = select_independent(&cands, &dep, 0.1, 3);
        assert_eq!(sel, vec![0, 1, 2]);
    }

    #[test]
    fn conflicting_pair_keeps_higher_priority() {
        let cands = [10, 20, 30];
        let dep = dep_from_conflicts(3, &[(0, 1)]);
        let sel = select_independent(&cands, &dep, 0.1, 3);
        assert_eq!(sel, vec![0, 2]); // candidate 1 conflicts with accepted 0
    }

    #[test]
    fn limit_is_respected() {
        let cands: Vec<usize> = (0..10).collect();
        let dep = dep_from_conflicts(10, &[]);
        let sel = select_independent(&cands, &dep, 0.1, 4);
        assert_eq!(sel.len(), 4);
        assert_eq!(sel, vec![0, 1, 2, 3]);
    }

    #[test]
    fn threshold_is_inclusive() {
        // dep exactly rho is allowed (constraint is <=)
        let cands = [0, 1];
        let dep = vec![0.0, 0.1, 0.1, 0.0];
        let sel = select_independent(&cands, &dep, 0.1, 2);
        assert_eq!(sel.len(), 2);
    }

    #[test]
    fn selection_always_satisfies_invariant() {
        // dense random-ish dep matrix, checked against the validator
        let c = 12;
        let mut dep = vec![0.0; c * c];
        for i in 0..c {
            for j in 0..c {
                if i != j {
                    let v = (((i * 31 + j * 17) % 100) as f64) / 100.0;
                    dep[i * c + j] = v;
                    dep[j * c + i] = v;
                }
            }
        }
        // symmetrize properly (the loop above writes both ways per pair)
        let cands: Vec<usize> = (100..100 + c).collect();
        for rho in [0.05, 0.3, 0.7] {
            let sel = select_independent(&cands, &dep, rho, c);
            assert!(is_rho_independent(&sel, &dep, c, rho), "rho {rho}");
            assert!(!sel.is_empty());
        }
    }

    #[test]
    fn empty_candidates() {
        let sel = select_independent(&[], &[], 0.1, 4);
        assert!(sel.is_empty());
    }
}
