//! §3's fixed random variable-ownership partition: each scheduler
//! shard owns a random J/S slice of the variables (assigned once,
//! never migrated) and only ever co-schedules variables it owns, so
//! cross-shard dependency checks are unnecessary — blocks from
//! different shards execute in *different* rounds (the staleness
//! argument of §3).
//!
//! The partition itself lives here as a primitive; the shard planners
//! built on top of it (local importance state, per-shard RNG streams,
//! round-robin rotation, and the threaded pipelined service) are in
//! [`crate::sched_service`] — one scheduling stack shared by the
//! engine path and the distributed path.

use crate::util::Rng;

/// Randomly partition `num_vars` variables across `s` shards (paper:
/// "each thread s is randomly assigned J/S variables ... these
/// assignments remain fixed throughout"). `s` is clamped to
/// `[1, num_vars]` so no shard is empty.
///
/// Returns the per-shard owned lists (global ids) plus the inverse
/// table: global id -> (shard, local index).
pub fn partition_owned(
    num_vars: usize,
    s: usize,
    rng: &mut Rng,
) -> (Vec<Vec<usize>>, Vec<(u32, u32)>) {
    let s = s.max(1).min(num_vars.max(1));
    let mut perm: Vec<usize> = (0..num_vars).collect();
    rng.shuffle(&mut perm);
    let mut owned_lists: Vec<Vec<usize>> = Vec::with_capacity(s);
    let mut owner = vec![(0u32, 0u32); num_vars];
    let base = num_vars / s;
    let extra = num_vars % s;
    let mut cursor = 0;
    for si in 0..s {
        let len = base + usize::from(si < extra);
        let owned: Vec<usize> = perm[cursor..cursor + len].to_vec();
        cursor += len;
        for (li, &g) in owned.iter().enumerate() {
            owner[g] = (si as u32, li as u32);
        }
        owned_lists.push(owned);
    }
    (owned_lists, owner)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ownership_is_a_partition_with_balanced_sizes() {
        let mut rng = Rng::new(9);
        let (lists, owner) = partition_owned(103, 4, &mut rng);
        let mut all: Vec<usize> = lists.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        let sizes: Vec<usize> = lists.iter().map(|l| l.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        // inverse table is consistent
        for (si, list) in lists.iter().enumerate() {
            for (li, &g) in list.iter().enumerate() {
                assert_eq!(owner[g], (si as u32, li as u32));
            }
        }
    }

    #[test]
    fn more_shards_than_vars_clamps() {
        let mut rng = Rng::new(9);
        let (lists, _) = partition_owned(3, 10, &mut rng);
        assert_eq!(lists.len(), 3);
        assert!(lists.iter().all(|l| l.len() == 1));
    }

    #[test]
    fn partition_is_seed_deterministic() {
        let (a, _) = partition_owned(50, 4, &mut Rng::new(7));
        let (b, _) = partition_owned(50, 4, &mut Rng::new(7));
        assert_eq!(a, b);
    }
}
