//! §3's distributed scheduler design: S shards, fixed variable
//! ownership, round-robin dispatch turns.
//!
//! Each shard owns a random J/S slice of the variables (assigned once,
//! never migrated) and maintains its own local importance distribution
//! p_s(j). Shards take strict turns producing dispatch plans; because a
//! shard only co-schedules variables it owns, cross-shard dependency
//! checks are unnecessary — blocks from different shards execute in
//! *different* rounds (the staleness argument of §3). [`ShardSet`]
//! encapsulates ownership, local<->global id translation, and the
//! rotation.

use crate::coordinator::priority::{PriorityDist, PriorityKind};
use crate::util::Rng;

/// One scheduler shard: owned variables + local importance distribution.
#[derive(Clone, Debug)]
pub struct Shard {
    /// Global variable ids owned by this shard.
    pub owned: Vec<usize>,
    /// Importance distribution over local indices (0..owned.len()).
    pub priority: PriorityDist,
}

/// The full shard set with round-robin rotation state.
#[derive(Clone, Debug)]
pub struct ShardSet {
    shards: Vec<Shard>,
    /// Global variable id -> (shard, local index).
    owner: Vec<(u32, u32)>,
    /// Whose turn it is to dispatch next.
    turn: usize,
}

impl ShardSet {
    /// Randomly assign `num_vars` variables to `s` shards (paper: "each
    /// thread s is randomly assigned J/S variables ... these assignments
    /// remain fixed throughout").
    pub fn new(
        num_vars: usize,
        s: usize,
        eta: f64,
        init_priority: f64,
        kind: PriorityKind,
        rng: &mut Rng,
    ) -> Self {
        let s = s.max(1).min(num_vars.max(1));
        let mut perm: Vec<usize> = (0..num_vars).collect();
        rng.shuffle(&mut perm);
        let mut shards: Vec<Shard> = Vec::with_capacity(s);
        let mut owner = vec![(0u32, 0u32); num_vars];
        let base = num_vars / s;
        let extra = num_vars % s;
        let mut cursor = 0;
        for si in 0..s {
            let len = base + usize::from(si < extra);
            let owned: Vec<usize> = perm[cursor..cursor + len].to_vec();
            cursor += len;
            for (li, &g) in owned.iter().enumerate() {
                owner[g] = (si as u32, li as u32);
            }
            shards.push(Shard {
                priority: PriorityDist::new(owned.len(), eta, init_priority, kind),
                owned,
            });
        }
        ShardSet { shards, owner, turn: 0 }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, i: usize) -> &Shard {
        &self.shards[i]
    }

    /// The shard whose turn it is; advances the rotation.
    pub fn next_turn(&mut self) -> usize {
        let t = self.turn;
        self.turn = (self.turn + 1) % self.shards.len();
        t
    }

    /// Draw `k` distinct candidates (global ids) from shard `si`'s local
    /// importance distribution, in descending-weight-ish sample order.
    pub fn sample_candidates(&mut self, si: usize, k: usize, rng: &mut Rng) -> Vec<usize> {
        let shard = &mut self.shards[si];
        let locals = shard.priority.sample_candidates(k, rng);
        locals.into_iter().map(|li| shard.owned[li]).collect()
    }

    /// SAP step 4: report measured progress for a *global* variable id.
    pub fn report(&mut self, global: usize, delta_abs: f64) {
        let (si, li) = self.owner[global];
        self.shards[si as usize].priority.report(li as usize, delta_abs);
    }

    /// Fraction of all variables updated at least once.
    pub fn coverage(&self) -> f64 {
        let total: usize = self.shards.iter().map(|s| s.owned.len()).sum();
        if total == 0 {
            return 1.0;
        }
        let covered: f64 =
            self.shards.iter().map(|s| s.priority.coverage() * s.owned.len() as f64).sum();
        covered / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(num_vars: usize, s: usize) -> ShardSet {
        let mut rng = Rng::new(9);
        ShardSet::new(num_vars, s, 1e-6, 1e3, PriorityKind::Linear, &mut rng)
    }

    #[test]
    fn ownership_is_a_partition() {
        let set = mk(103, 4);
        let mut all: Vec<usize> =
            (0..4).flat_map(|i| set.shard(i).owned.clone()).collect();
        all.sort();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        // sizes differ by at most 1
        let sizes: Vec<usize> = (0..4).map(|i| set.shard(i).owned.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn round_robin_rotation() {
        let mut set = mk(10, 3);
        let turns: Vec<usize> = (0..7).map(|_| set.next_turn()).collect();
        assert_eq!(turns, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn candidates_come_from_owning_shard() {
        let mut set = mk(100, 5);
        let mut rng = Rng::new(1);
        for si in 0..5 {
            let cands = set.sample_candidates(si, 8, &mut rng);
            let owned: std::collections::HashSet<_> =
                set.shard(si).owned.iter().copied().collect();
            assert!(cands.iter().all(|c| owned.contains(c)));
            // distinct
            let set2: std::collections::HashSet<_> = cands.iter().collect();
            assert_eq!(set2.len(), cands.len());
        }
    }

    #[test]
    fn report_routes_to_owner() {
        let mut set = mk(50, 4);
        // find a var owned by shard 2 and bump it hugely
        let g = set.shard(2).owned[0];
        for v in 0..50 {
            set.report(v, 1e-9); // touch everything
        }
        set.report(g, 100.0);
        let (si, li) = set.owner[g];
        assert_eq!(si, 2);
        assert!(set.shards[2].priority.weight(li as usize) > 99.0);
    }

    #[test]
    fn more_shards_than_vars_clamps() {
        let set = mk(3, 10);
        assert_eq!(set.num_shards(), 3);
    }

    #[test]
    fn coverage_aggregates_across_shards() {
        let mut set = mk(40, 4);
        assert_eq!(set.coverage(), 0.0);
        for v in 0..20 {
            set.report(v, 0.1);
        }
        assert!((set.coverage() - 0.5).abs() < 1e-9);
    }
}
