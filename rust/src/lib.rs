//! # STRADS — STRucture-Aware Dynamic Scheduler for parallel ML
//!
//! A production-quality reproduction of *"Structure-Aware Dynamic
//! Scheduler for Parallel Machine Learning"* (Lee, Kim, Ho, Gibson,
//! Xing; CMU, 2013) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: the SAP scheduling
//!   primitives ([`coordinator`]), the sharded pipelined scheduler
//!   service and its planner core ([`sched_service`]), the baseline
//!   schedulers over that core ([`schedulers`]), the sharded parameter
//!   server with bounded-staleness clocks behind a pluggable
//!   in-process/TCP transport ([`ps`], `strads ps-server`), the worker
//!   pool that runs any [`problem::ModelProblem`] over it ([`workers`]), the
//!   virtual cluster simulator ([`sim`]), data generators ([`data`]),
//!   the experiment drivers, and the unified observability layer
//!   ([`obs`]: metrics registry, span tracing, live introspection).
//! * **L2/L1 (python/, build-time only)** — JAX update graphs calling
//!   Pallas kernels, AOT-lowered to HLO text by `make artifacts`.
//! * **[`runtime`]** — loads the HLO artifacts through the PJRT C API
//!   (`xla` crate) and executes them from the rust hot path. Python is
//!   never on the request path.
//!
//! Quickstart:
//!
//! ```no_run
//! # // no_run: rustdoc test binaries miss the libstdc++ rpath that the
//! # // workspace build config injects for the PJRT shared library.
//! use strads::prelude::*;
//! use strads::sim::CostModel;
//!
//! let cfg = strads::config::RunConfig::default();
//! let data = strads::data::lasso_synth::generate(&LassoSynthSpec::tiny(), 42);
//! let mut problem = strads::lasso::NativeLasso::new(&data, 1e-3);
//! let mut sched = DynamicScheduler::new(problem.num_vars(), &cfg.sap, 7);
//! let mut cluster = VirtualCluster::new(16, cfg.sap.shards, CostModel::new(&cfg.cost));
//! let mut trace = Trace::new("dynamic", "tiny", 16);
//! let engine_cfg = EngineConfig { max_rounds: 50, ..Default::default() };
//! run_rounds(&mut problem, &mut sched, &mut cluster, &engine_cfg, &mut trace);
//! assert!(trace.final_objective().is_finite());
//! ```

pub mod benchutil;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod experiments;
pub mod lasso;
pub mod linalg;
pub mod metrics;
pub mod mf;
pub mod obs;
pub mod problem;
pub mod ps;
pub mod runtime;
pub mod sched_service;
pub mod schedulers;
pub mod sim;
pub mod sparse;
pub mod util;
pub mod workers;

/// Convenience re-exports for examples and binaries.
pub mod prelude {
    pub use crate::config::{EngineConfig, SapConfig};
    pub use crate::data::lasso_synth::LassoSynthSpec;
    pub use crate::data::mf_powerlaw::MfSynthSpec;
    pub use crate::engine::run_rounds;
    pub use crate::metrics::Trace;
    pub use crate::problem::{Block, ModelProblem, RoundResult};
    pub use crate::ps::{StalenessPolicy, TransportKind};
    pub use crate::sched_service::{SchedOracle, SchedService};
    pub use crate::schedulers::{
        DynamicScheduler, RandomScheduler, SchedKind, Scheduler, StaticBlockScheduler,
    };
    pub use crate::sim::VirtualCluster;
    pub use crate::workers::run_distributed;
}
