//! The three scheduling models compared in the paper's evaluation, as
//! synchronous engine-path drivers over the *one* shared planning core
//! in [`crate::sched_service::planner`] (the distributed path runs the
//! identical planners on dedicated shard threads — see
//! [`crate::sched_service::SchedService`]):
//!
//! * [`DynamicScheduler`] — STRADS / SAP: importance-sampled candidates,
//!   ρ-constrained dependency checking, load-balanced dispatch, sharded
//!   round-robin (the paper's contribution).
//! * [`StaticBlockScheduler`] — "static block structures": candidates
//!   drawn uniformly at random, the same a-priori ρ dependency check,
//!   but no importance distribution (block structure never adapts to
//!   runtime values).
//! * [`RandomScheduler`] — Shotgun (Bradley et al. 2011): uniformly
//!   random selection, no structure at all.
//!
//! [`SchedKind`] is the selector every entry point (CLI, experiment
//! drivers, the distributed coordinator) routes construction through,
//! so `--scheduler static|random` works identically on the simulated
//! and the real-thread paths.

mod dynamic;
mod random;
mod static_block;

pub use dynamic::DynamicScheduler;
pub use random::RandomScheduler;
pub use static_block::StaticBlockScheduler;

use crate::config::SapConfig;
use crate::coordinator::SchedCost;
use crate::problem::{Block, ModelProblem, RoundResult};

/// A round-based variable scheduler.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Plan the next round: at most `p` blocks for `p` workers.
    fn plan(&mut self, problem: &mut dyn ModelProblem, p: usize) -> Vec<Block>;

    /// SAP step 4: observe the round's measured progress.
    fn observe(&mut self, result: &RoundResult);

    /// Scheduling work performed by the last `plan` call (cost model).
    fn last_cost(&self) -> SchedCost;
}

/// Scheduler selector shared by the CLI, the experiment drivers, and
/// the distributed coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedKind {
    Dynamic,
    Static,
    Random,
}

impl SchedKind {
    pub fn name(self) -> &'static str {
        match self {
            SchedKind::Dynamic => "dynamic",
            SchedKind::Static => "static",
            SchedKind::Random => "random",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "dynamic" | "strads" => Ok(SchedKind::Dynamic),
            "static" => Ok(SchedKind::Static),
            "random" | "shotgun" => Ok(SchedKind::Random),
            other => anyhow::bail!("unknown scheduler {other}"),
        }
    }

    /// Build the engine-path (synchronous) scheduler of this kind.
    pub fn build(self, num_vars: usize, sap: &SapConfig, seed: u64) -> Box<dyn Scheduler> {
        match self {
            SchedKind::Dynamic => Box::new(DynamicScheduler::new(num_vars, sap, seed)),
            SchedKind::Static => Box::new(StaticBlockScheduler::new(sap, seed)),
            SchedKind::Random => Box::new(RandomScheduler::new(seed)),
        }
    }
}
