//! The three scheduling models compared in the paper's evaluation:
//!
//! * [`DynamicScheduler`] — STRADS / SAP: importance-sampled candidates,
//!   ρ-constrained dependency checking, load-balanced dispatch, sharded
//!   round-robin (the paper's contribution).
//! * [`StaticBlockScheduler`] — "static block structures": candidates
//!   drawn uniformly at random, the same a-priori ρ dependency check,
//!   but no importance distribution (block structure never adapts to
//!   runtime values).
//! * [`RandomScheduler`] — Shotgun (Bradley et al. 2011): uniformly
//!   random selection, no structure at all.

mod dynamic;
mod random;
mod static_block;

pub use dynamic::DynamicScheduler;
pub use random::RandomScheduler;
pub use static_block::StaticBlockScheduler;

use crate::coordinator::SchedCost;
use crate::problem::{Block, ModelProblem, RoundResult};

/// A round-based variable scheduler.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Plan the next round: at most `p` blocks for `p` workers.
    fn plan(&mut self, problem: &mut dyn ModelProblem, p: usize) -> Vec<Block>;

    /// SAP step 4: observe the round's measured progress.
    fn observe(&mut self, result: &RoundResult);

    /// Scheduling work performed by the last `plan` call (cost model).
    fn last_cost(&self) -> SchedCost;
}
