//! The unstructured baseline: Shotgun (Bradley et al., 2011). Variables
//! are selected uniformly at random with no dependency checking — the
//! paper's "no structures" scheduler, which suffers interference when
//! correlated variables collide in a round. Runs on the shared planner
//! core's random policy (one unsharded planner).

use crate::config::SapConfig;
use crate::coordinator::priority::PriorityKind;
use crate::coordinator::SchedCost;
use crate::problem::{Block, ModelProblem, RoundResult};
use crate::sched_service::{PlannerSet, ProblemDeps};
use crate::schedulers::{SchedKind, Scheduler};

pub struct RandomScheduler {
    seed: u64,
    set: Option<PlannerSet>,
}

impl RandomScheduler {
    pub fn new(seed: u64) -> Self {
        RandomScheduler { seed, set: None }
    }
}

impl Scheduler for RandomScheduler {
    fn name(&self) -> &'static str {
        "random"
    }

    fn plan(&mut self, problem: &mut dyn ModelProblem, p: usize) -> Vec<Block> {
        if self.set.is_none() {
            self.set = Some(PlannerSet::new(
                problem.num_vars(),
                1,
                SchedKind::Random,
                PriorityKind::Linear,
                &SapConfig::default(),
                self.seed,
            ));
        }
        self.set.as_mut().expect("just built").plan_turn(&mut ProblemDeps(problem), p)
    }

    fn observe(&mut self, _result: &RoundResult) {}

    fn last_cost(&self) -> SchedCost {
        self.set.as_ref().map(|s| s.last_cost()).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Nop {
        n: usize,
    }
    impl ModelProblem for Nop {
        fn num_vars(&self) -> usize {
            self.n
        }
        fn workload(&self, _j: usize) -> u64 {
            1
        }
        fn dependencies(&mut self, cands: &[usize]) -> Vec<f64> {
            vec![0.0; cands.len() * cands.len()]
        }
        fn update_blocks(&mut self, _blocks: &[Block]) -> RoundResult {
            RoundResult::default()
        }
        fn objective(&mut self) -> f64 {
            0.0
        }
    }

    #[test]
    fn exactly_p_distinct_singletons() {
        let mut problem = Nop { n: 100 };
        let mut s = RandomScheduler::new(4);
        let blocks = s.plan(&mut problem, 16);
        assert_eq!(blocks.len(), 16);
        let vars: Vec<usize> = blocks.iter().flat_map(|b| b.vars.clone()).collect();
        let set: std::collections::HashSet<_> = vars.iter().collect();
        assert_eq!(set.len(), 16);
    }

    #[test]
    fn never_performs_dep_checks() {
        let mut problem = Nop { n: 100 };
        let mut s = RandomScheduler::new(4);
        s.plan(&mut problem, 8);
        assert_eq!(s.last_cost().dep_checks, 0);
    }

    #[test]
    fn p_larger_than_n_clamps() {
        let mut problem = Nop { n: 5 };
        let mut s = RandomScheduler::new(4);
        let blocks = s.plan(&mut problem, 16);
        assert_eq!(blocks.len(), 5);
    }
}
