//! The "static block structures" baseline (paper §5.1): candidates are
//! drawn uniformly at random and filtered by the same a-priori ρ
//! dependency check, but there is no importance distribution — the
//! block structure reflects only the (static) data correlations, never
//! the runtime values of β. Load balancing is kept (it too is static:
//! workloads don't change).

use crate::config::SapConfig;
use crate::coordinator::depcheck::select_independent_lazy;
use crate::coordinator::{merge_balanced, select_independent, SchedCost};
use crate::problem::{Block, ModelProblem, RoundResult};
use crate::schedulers::Scheduler;
use crate::util::Rng;

pub struct StaticBlockScheduler {
    cfg: SapConfig,
    rng: Rng,
    last_cost: SchedCost,
}

impl StaticBlockScheduler {
    pub fn new(cfg: &SapConfig, seed: u64) -> Self {
        StaticBlockScheduler { cfg: cfg.clone(), rng: Rng::new(seed), last_cost: SchedCost::default() }
    }
}

impl Scheduler for StaticBlockScheduler {
    fn name(&self) -> &'static str {
        "static"
    }

    fn plan(&mut self, problem: &mut dyn ModelProblem, p: usize) -> Vec<Block> {
        let n = problem.num_vars();
        let p_prime = (p * self.cfg.p_prime_factor).min(n);
        // Uniform candidates: the static scheduler has no notion of
        // which variables currently matter.
        let cands = self.rng.sample_distinct(n, p_prime);
        let picked = if problem.supports_pair_dependency() {
            let mut checks = 0usize;
            let picked = select_independent_lazy(
                &cands,
                |a, b| {
                    checks += 1;
                    problem.dependency_pair(a, b)
                },
                self.cfg.rho,
                p,
            );
            self.last_cost = SchedCost { candidates: cands.len(), dep_checks: checks };
            picked
        } else {
            let dep = problem.dependencies(&cands);
            let picked = select_independent(&cands, &dep, self.cfg.rho, p);
            self.last_cost = SchedCost {
                candidates: cands.len(),
                dep_checks: cands.len() * picked.len().max(1),
            };
            picked
        };
        let blocks: Vec<Block> = picked
            .iter()
            .map(|&ci| {
                let v = cands[ci];
                Block::singleton(v, problem.workload(v))
            })
            .collect();
        merge_balanced(blocks, p)
    }

    fn observe(&mut self, _result: &RoundResult) {
        // Static: runtime progress never feeds back into selection.
    }

    fn last_cost(&self) -> SchedCost {
        self.last_cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Dense {
        n: usize,
        rho_pairs: f64,
    }

    impl ModelProblem for Dense {
        fn num_vars(&self) -> usize {
            self.n
        }
        fn workload(&self, _j: usize) -> u64 {
            1
        }
        fn dependencies(&mut self, cands: &[usize]) -> Vec<f64> {
            let c = cands.len();
            let mut d = vec![self.rho_pairs; c * c];
            for i in 0..c {
                d[i * c + i] = 0.0;
            }
            d
        }
        fn update_blocks(&mut self, _blocks: &[Block]) -> RoundResult {
            RoundResult::default()
        }
        fn objective(&mut self) -> f64 {
            0.0
        }
    }

    #[test]
    fn fully_coupled_problem_yields_one_var_per_round() {
        // every pair conflicts above rho -> only one variable passes
        let mut problem = Dense { n: 100, rho_pairs: 0.9 };
        let mut s = StaticBlockScheduler::new(&SapConfig::default(), 1);
        let blocks = s.plan(&mut problem, 8);
        let vars: Vec<usize> = blocks.iter().flat_map(|b| b.vars.clone()).collect();
        assert_eq!(vars.len(), 1);
    }

    #[test]
    fn uncoupled_problem_fills_all_workers() {
        let mut problem = Dense { n: 100, rho_pairs: 0.0 };
        let mut s = StaticBlockScheduler::new(&SapConfig::default(), 2);
        let blocks = s.plan(&mut problem, 8);
        let vars: Vec<usize> = blocks.iter().flat_map(|b| b.vars.clone()).collect();
        assert_eq!(vars.len(), 8);
    }

    #[test]
    fn observe_is_a_noop_for_selection_statistics() {
        let mut problem = Dense { n: 50, rho_pairs: 0.0 };
        let mk = || StaticBlockScheduler::new(&SapConfig::default(), 77);
        let mut a = mk();
        let mut b = mk();
        // b observes huge progress on var 5; a observes nothing
        b.observe(&RoundResult { deltas: vec![(5, 1e9)], ..Default::default() });
        // identical RNG stream -> identical plans regardless of observe
        for _ in 0..5 {
            let pa = a.plan(&mut problem, 4);
            let pb = b.plan(&mut problem, 4);
            assert_eq!(pa, pb);
        }
    }
}
