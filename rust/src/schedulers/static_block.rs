//! The "static block structures" baseline (paper §5.1): candidates are
//! drawn uniformly at random and filtered by the same a-priori ρ
//! dependency check, but there is no importance distribution — the
//! block structure reflects only the (static) data correlations, never
//! the runtime values of β. Load balancing is kept (it too is static:
//! workloads don't change). Runs on the shared planner core (one
//! unsharded planner; the distributed service shards the same policy).

use crate::config::SapConfig;
use crate::coordinator::priority::PriorityKind;
use crate::coordinator::SchedCost;
use crate::problem::{Block, ModelProblem, RoundResult};
use crate::sched_service::{PlannerSet, ProblemDeps};
use crate::schedulers::{SchedKind, Scheduler};

pub struct StaticBlockScheduler {
    cfg: SapConfig,
    seed: u64,
    /// Built lazily on the first plan (the variable count comes from
    /// the problem).
    set: Option<PlannerSet>,
}

impl StaticBlockScheduler {
    pub fn new(cfg: &SapConfig, seed: u64) -> Self {
        StaticBlockScheduler { cfg: cfg.clone(), seed, set: None }
    }
}

impl Scheduler for StaticBlockScheduler {
    fn name(&self) -> &'static str {
        "static"
    }

    fn plan(&mut self, problem: &mut dyn ModelProblem, p: usize) -> Vec<Block> {
        if self.set.is_none() {
            self.set = Some(PlannerSet::new(
                problem.num_vars(),
                1,
                SchedKind::Static,
                PriorityKind::Linear,
                &self.cfg,
                self.seed,
            ));
        }
        self.set.as_mut().expect("just built").plan_turn(&mut ProblemDeps(problem), p)
    }

    fn observe(&mut self, _result: &RoundResult) {
        // Static: runtime progress never feeds back into selection
        // (the planner's static policy discards reports anyway).
    }

    fn last_cost(&self) -> SchedCost {
        self.set.as_ref().map(|s| s.last_cost()).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Dense {
        n: usize,
        rho_pairs: f64,
    }

    impl ModelProblem for Dense {
        fn num_vars(&self) -> usize {
            self.n
        }
        fn workload(&self, _j: usize) -> u64 {
            1
        }
        fn dependencies(&mut self, cands: &[usize]) -> Vec<f64> {
            let c = cands.len();
            let mut d = vec![self.rho_pairs; c * c];
            for i in 0..c {
                d[i * c + i] = 0.0;
            }
            d
        }
        fn update_blocks(&mut self, _blocks: &[Block]) -> RoundResult {
            RoundResult::default()
        }
        fn objective(&mut self) -> f64 {
            0.0
        }
    }

    #[test]
    fn fully_coupled_problem_yields_one_var_per_round() {
        // every pair conflicts above rho -> only one variable passes
        let mut problem = Dense { n: 100, rho_pairs: 0.9 };
        let mut s = StaticBlockScheduler::new(&SapConfig::default(), 1);
        let blocks = s.plan(&mut problem, 8);
        let vars: Vec<usize> = blocks.iter().flat_map(|b| b.vars.clone()).collect();
        assert_eq!(vars.len(), 1);
    }

    #[test]
    fn uncoupled_problem_fills_all_workers() {
        let mut problem = Dense { n: 100, rho_pairs: 0.0 };
        let mut s = StaticBlockScheduler::new(&SapConfig::default(), 2);
        let blocks = s.plan(&mut problem, 8);
        let vars: Vec<usize> = blocks.iter().flat_map(|b| b.vars.clone()).collect();
        assert_eq!(vars.len(), 8);
    }

    #[test]
    fn observe_is_a_noop_for_selection_statistics() {
        let mut problem = Dense { n: 50, rho_pairs: 0.0 };
        let mk = || StaticBlockScheduler::new(&SapConfig::default(), 77);
        let mut a = mk();
        let mut b = mk();
        // b observes huge progress on var 5; a observes nothing
        b.observe(&RoundResult { deltas: vec![(5, 1e9)], ..Default::default() });
        // identical RNG stream -> identical plans regardless of observe
        for _ in 0..5 {
            let pa = a.plan(&mut problem, 4);
            let pb = b.plan(&mut problem, 4);
            assert_eq!(pa, pb);
        }
    }
}
