//! The STRADS dynamic scheduler: the full SAP loop over sharded
//! importance distributions (paper §2 + §3), as a synchronous wrapper
//! over the shared planner core. The distributed scheduler service
//! runs the *same* [`PlannerSet`] planners on shard threads, so at
//! lock-step observation delivery the two paths produce bit-identical
//! plan sequences.

use crate::config::SapConfig;
use crate::coordinator::priority::PriorityKind;
use crate::coordinator::SchedCost;
use crate::problem::{Block, ModelProblem, RoundResult};
use crate::sched_service::{PlannerSet, ProblemDeps};
use crate::schedulers::{SchedKind, Scheduler};

pub struct DynamicScheduler {
    set: PlannerSet,
}

impl DynamicScheduler {
    pub fn new(num_vars: usize, cfg: &SapConfig, seed: u64) -> Self {
        Self::with_kind(num_vars, cfg, seed, PriorityKind::Linear)
    }

    /// Theorem-1 variant: p(j) ∝ ½ δβ² + η.
    pub fn new_squared(num_vars: usize, cfg: &SapConfig, seed: u64) -> Self {
        Self::with_kind(num_vars, cfg, seed, PriorityKind::Squared)
    }

    fn with_kind(num_vars: usize, cfg: &SapConfig, seed: u64, kind: PriorityKind) -> Self {
        DynamicScheduler {
            set: PlannerSet::new(num_vars, cfg.shards, SchedKind::Dynamic, kind, cfg, seed),
        }
    }

    /// Fraction of variables updated at least once (drives the paper's
    /// "early sharp drop" diagnostic).
    pub fn coverage(&self) -> f64 {
        self.set.coverage()
    }
}

impl Scheduler for DynamicScheduler {
    fn name(&self) -> &'static str {
        "dynamic"
    }

    fn plan(&mut self, problem: &mut dyn ModelProblem, p: usize) -> Vec<Block> {
        // The shard whose turn it is samples its candidates, runs the
        // ρ-constrained greedy selection, and LPT-merges — see
        // `sched_service::planner` for the shared implementation.
        self.set.plan_turn(&mut ProblemDeps(problem), p)
    }

    fn observe(&mut self, result: &RoundResult) {
        // Step 4: fold measured |δ| into the owning shard's p_s(j).
        self.set.observe(result);
    }

    fn last_cost(&self) -> SchedCost {
        self.set.last_cost()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SapConfig;
    use crate::coordinator::depcheck::is_rho_independent;

    /// A toy problem: 2d chain where adjacent variables conflict.
    struct Chain {
        n: usize,
    }

    impl ModelProblem for Chain {
        fn num_vars(&self) -> usize {
            self.n
        }
        fn workload(&self, _j: usize) -> u64 {
            1
        }
        fn dependencies(&mut self, cands: &[usize]) -> Vec<f64> {
            let c = cands.len();
            let mut d = vec![0.0; c * c];
            for i in 0..c {
                for j in 0..c {
                    if i != j && cands[i].abs_diff(cands[j]) == 1 {
                        d[i * c + j] = 1.0;
                    }
                }
            }
            d
        }
        fn update_blocks(&mut self, blocks: &[Block]) -> RoundResult {
            let deltas =
                blocks.iter().flat_map(|b| b.vars.iter().map(|&v| (v, 0.1))).collect();
            RoundResult { deltas, objective: None, max_block_work: 1, total_work: 1 }
        }
        fn objective(&mut self) -> f64 {
            0.0
        }
    }

    #[test]
    fn plan_never_coschedules_adjacent_vars() {
        let mut problem = Chain { n: 200 };
        let cfg = SapConfig { shards: 1, ..SapConfig::default() };
        let mut s = DynamicScheduler::new(200, &cfg, 3);
        for _ in 0..20 {
            let blocks = s.plan(&mut problem, 8);
            assert!(blocks.len() <= 8);
            let vars: Vec<usize> = blocks.iter().flat_map(|b| b.vars.clone()).collect();
            // no two scheduled vars adjacent
            for (i, &a) in vars.iter().enumerate() {
                for &b in &vars[i + 1..] {
                    assert!(a.abs_diff(b) != 1, "adjacent {a},{b} co-scheduled");
                }
            }
            let result = problem.update_blocks(&blocks);
            s.observe(&result);
        }
    }

    #[test]
    fn respects_worker_limit_and_distinctness() {
        let mut problem = Chain { n: 1000 };
        let mut s = DynamicScheduler::new(1000, &SapConfig::default(), 1);
        let blocks = s.plan(&mut problem, 16);
        let vars: Vec<usize> = blocks.iter().flat_map(|b| b.vars.clone()).collect();
        assert!(vars.len() <= 16);
        let set: std::collections::HashSet<_> = vars.iter().collect();
        assert_eq!(set.len(), vars.len());
    }

    #[test]
    fn observe_reprioritizes() {
        let mut problem = Chain { n: 64 };
        let cfg = SapConfig { shards: 1, init_priority: 1e-6, ..SapConfig::default() };
        let mut s = DynamicScheduler::new(64, &cfg, 5);
        // report huge progress on var 10 only
        s.observe(&RoundResult {
            deltas: (0..64).map(|v| (v, if v == 10 { 100.0 } else { 1e-9 })).collect(),
            ..Default::default()
        });
        let mut hits = 0;
        for _ in 0..50 {
            let blocks = s.plan(&mut problem, 1);
            if blocks.iter().any(|b| b.vars.contains(&10)) {
                hits += 1;
            }
        }
        assert!(hits > 45, "hits {hits}");
    }

    #[test]
    fn coords_per_worker_extension_schedules_larger_rounds() {
        // paper §6 future work: bigger dispatched blocks, same rho control
        let mut problem = Chain { n: 2000 };
        let cfg = SapConfig { shards: 1, coords_per_worker: 4, ..SapConfig::default() };
        let mut s = DynamicScheduler::new(2000, &cfg, 13);
        let blocks = s.plan(&mut problem, 8);
        assert!(blocks.len() <= 8);
        let vars: Vec<usize> = blocks.iter().flat_map(|b| b.vars.clone()).collect();
        assert!(vars.len() > 8, "should schedule more than one coord per worker: {}", vars.len());
        assert!(vars.len() <= 32);
        // every scheduled pair still rho-independent (no adjacent vars)
        for (i, &a) in vars.iter().enumerate() {
            for &b in &vars[i + 1..] {
                assert!(a.abs_diff(b) != 1, "adjacent {a},{b} co-scheduled");
            }
        }
    }

    #[test]
    fn selection_invariant_via_validator() {
        let mut problem = Chain { n: 100 };
        let cfg = SapConfig { shards: 2, ..SapConfig::default() };
        let mut s = DynamicScheduler::new(100, &cfg, 7);
        for _ in 0..10 {
            let blocks = s.plan(&mut problem, 6);
            let vars: Vec<usize> = blocks.iter().flat_map(|b| b.vars.clone()).collect();
            let dep = problem.dependencies(&vars);
            let idx: Vec<usize> = (0..vars.len()).collect();
            assert!(is_rho_independent(&idx, &dep, vars.len(), 0.1));
        }
    }
}
