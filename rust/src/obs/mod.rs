//! Unified observability: a low-overhead metrics registry (atomic
//! counters, gauges, and fixed-bucket latency histograms registered by
//! name), bounded structured span tracing (chrome://tracing-loadable
//! JSONL), and the snapshot type the live `ObsStats` wire opcode and
//! `strads ps-stats` serve.
//!
//! The contract that makes this layer safe to leave on: **observability
//! never feeds computation**. Recording is relaxed atomic adds and
//! buffered event pushes; no arithmetic, RNG draw, or apply order ever
//! reads a metric back, so obs-on vs obs-off staleness-0 runs stay
//! bitwise identical (pinned by `tests/obs.rs`).
//!
//! Registry names in use across the crate:
//!
//! | name                  | kind      | recorded by                         |
//! |-----------------------|-----------|-------------------------------------|
//! | `ps.pulls`            | counter   | `ParameterServer::serve_pull`       |
//! | `ps.pull_bytes`       | counter   | modeled wire bytes per pull         |
//! | `ps.cells_pulled`     | counter   | cells covered per pull              |
//! | `ps.snapshot_clones`  | counter   | zero-copy epoch views handed out    |
//! | `ps.flushes`          | counter   | `ParameterServer::serve_flush`      |
//! | `ps.flushes_dropped`  | counter   | fenced / duplicate / zombie flushes |
//! | `ps.bytes_flushed`    | counter   | modeled wire bytes per flush        |
//! | `ps.bytes_republished`| counter   | modeled wire bytes per republish    |
//! | `ps.stale_gap_sum`    | counter   | sum of admitted staleness gaps      |
//! | `ps.max_stale_gap`    | counter   | watermark of the largest gap        |
//! | `ps.gate_waits`       | counter   | pulls that blocked on the SSP gate  |
//! | `gate.wait_us`        | histogram | SSP clock gate block time           |
//! | `sched.plan_wait_us`  | histogram | coordinator `pop_plan` block time   |
//! | `net.socket_bytes`    | gauge     | transport bytes moved (0 in-proc)   |
//! | `net.reconnects`      | counter   | retry-wrapper reconnects (all links)|
//! | `net.retry_backoff_us`| counter   | total retry backoff slept, µs       |
//! | `ckpt.writes`         | counter   | ps-server checkpoints written       |
//! | `ckpt.bytes`          | counter   | ps-server checkpoint bytes written  |
//! | `sup.heartbeats`      | counter   | worker flushes seen by the supervisor|
//! | `sup.leases_expired`  | counter   | dispatched-block leases that timed out|
//! | `sup.reassigns`       | counter   | blocks re-dispatched to live workers|
//! | `sup.workers_live`    | gauge     | current live worker census          |
//! | `store.hash_probes`   | counter   | hashed-path probes (snapshot view)  |
//! | `store.cow_clones`    | counter   | copy-on-publish clones (snapshot)   |

use crate::util::json::Json;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Version tag carried by every [`ObsSnapshot`] (and its wire form), so
/// the introspection surface can evolve independently of the protocol.
pub const OBS_SNAPSHOT_VERSION: u16 = 1;

/// Relaxed atomic counter. `set`/`raise` exist for meters that mirror
/// externally computed values (seeding in tests, watermarks).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Raise to at least `v` (the watermark update).
    #[inline]
    pub fn raise(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Self {
        Gauge(AtomicU64::new(0))
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket histogram: `bounds` are inclusive upper bounds in
/// strictly increasing order, with an implicit overflow bucket after
/// the last. Recording is three relaxed atomic adds — cheap enough to
/// leave on the pull gate and plan-pop hot paths unconditionally.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<u64>,
    counts: Vec<AtomicU64>,
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub fn new(bounds: &[u64]) -> Self {
        assert!(!bounds.is_empty(), "histogram needs at least one bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing"
        );
        Histogram {
            bounds: bounds.to_vec(),
            counts: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Microsecond latency bounds spanning 1µs .. 10s — the default for
    /// every `*_us` histogram in the crate.
    pub fn us_bounds() -> &'static [u64] {
        &[1, 10, 100, 1_000, 10_000, 100_000, 1_000_000, 10_000_000]
    }

    /// Record one observation: it lands in the first bucket whose bound
    /// is ≥ `v`, or the overflow bucket.
    pub fn record(&self, v: u64) {
        let idx = self.bounds.partition_point(|&b| b < v);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn value(&self) -> MetricValue {
        MetricValue::Histogram {
            bounds: self.bounds.clone(),
            counts: self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            sum: self.sum.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time reading of one metric (what snapshots carry over the
/// wire and what tests compare).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(u64),
    Histogram { bounds: Vec<u64>, counts: Vec<u64>, sum: u64, count: u64 },
}

impl MetricValue {
    /// Scalar reading for counters and gauges; a histogram's total
    /// observation count.
    pub fn as_u64(&self) -> u64 {
        match self {
            MetricValue::Counter(v) | MetricValue::Gauge(v) => *v,
            MetricValue::Histogram { count, .. } => *count,
        }
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn value(&self) -> MetricValue {
        match self {
            Metric::Counter(c) => MetricValue::Counter(c.get()),
            Metric::Gauge(g) => MetricValue::Gauge(g.get()),
            Metric::Histogram(h) => h.value(),
        }
    }
}

/// Name → metric registry. Accessors get-or-create: callers clone the
/// `Arc` once at setup and record lock-free afterwards; the registry
/// lock is only taken at registration and snapshot time.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().expect("registry lock poisoned");
        let metric = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::new())));
        match metric {
            Metric::Counter(c) => Arc::clone(c),
            _ => panic!("metric {name} already registered with a different kind"),
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().expect("registry lock poisoned");
        let metric =
            m.entry(name.to_string()).or_insert_with(|| Metric::Gauge(Arc::new(Gauge::new())));
        match metric {
            Metric::Gauge(g) => Arc::clone(g),
            _ => panic!("metric {name} already registered with a different kind"),
        }
    }

    /// Get-or-create a histogram. `bounds` only applies on first
    /// registration; later callers receive the existing instance.
    pub fn histogram(&self, name: &str, bounds: &[u64]) -> Arc<Histogram> {
        let mut m = self.metrics.lock().expect("registry lock poisoned");
        let metric = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new(bounds))));
        match metric {
            Metric::Histogram(h) => Arc::clone(h),
            _ => panic!("metric {name} already registered with a different kind"),
        }
    }

    /// Point-in-time reading of every registered metric, sorted by name.
    pub fn snapshot(&self) -> Vec<(String, MetricValue)> {
        let m = self.metrics.lock().expect("registry lock poisoned");
        m.iter().map(|(name, metric)| (name.clone(), metric.value())).collect()
    }
}

/// The SSP clock's gate state as seen by introspection.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClockView {
    /// Rounds fully applied at the server.
    pub applied: u64,
    /// The staleness bound; `None` = fully asynchronous (no gate).
    pub staleness_bound: Option<u64>,
    /// Per-worker flush clocks.
    pub worker_clocks: Vec<u64>,
}

/// What the `ObsStats` opcode serves and `strads ps-stats` renders: the
/// registry reading plus the store/clock state that lives outside it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ObsSnapshot {
    pub version: u16,
    /// Sorted `(name, value)` registry reading.
    pub metrics: Vec<(String, MetricValue)>,
    /// Registered dense segments as `(start, len, epoch_version)`.
    pub segments: Vec<(usize, usize, u64)>,
    pub clock: Option<ClockView>,
}

impl ObsSnapshot {
    pub fn get(&self, name: &str) -> Option<&MetricValue> {
        self.metrics.iter().find(|(n, _)| n == name).map(|(_, v)| v)
    }

    /// Human-readable `name = value` dump — the `strads ps-stats`
    /// output; CI greps these lines for liveness.
    pub fn render(&self) -> String {
        let mut out = format!("obs snapshot v{}\n", self.version);
        // Shard-range banner: which slice of the key space this server
        // hosts — the line that tells the members of a routed N-server
        // fleet apart (`strads ps-stats` against each member).
        if !self.segments.is_empty() {
            let lo = self.segments.iter().map(|&(s, _, _)| s).min().unwrap();
            let hi = self.segments.iter().map(|&(s, l, _)| s + l).max().unwrap();
            out.push_str(&format!("shards = [{lo}..{hi})\n"));
        }
        for (name, v) in &self.metrics {
            match v {
                MetricValue::Counter(n) => out.push_str(&format!("{name} = {n}\n")),
                MetricValue::Gauge(n) => out.push_str(&format!("{name} = {n}\n")),
                MetricValue::Histogram { bounds, counts, sum, count } => {
                    let mut buckets = Vec::new();
                    for (i, c) in counts.iter().enumerate() {
                        if *c == 0 {
                            continue;
                        }
                        match bounds.get(i) {
                            Some(b) => buckets.push(format!("<={b}:{c}")),
                            None => buckets.push(format!("inf:{c}")),
                        }
                    }
                    out.push_str(&format!(
                        "{name} = count={count} sum={sum} buckets=[{}]\n",
                        buckets.join(" ")
                    ));
                }
            }
        }
        for (i, (start, len, version)) in self.segments.iter().enumerate() {
            out.push_str(&format!(
                "segment[{i}] = start={start} len={len} version={version}\n"
            ));
        }
        if let Some(clock) = &self.clock {
            let bound = match clock.staleness_bound {
                Some(s) => s.to_string(),
                None => "async".to_string(),
            };
            out.push_str(&format!("clock.applied = {}\n", clock.applied));
            out.push_str(&format!("clock.bound = {bound}\n"));
            out.push_str(&format!("clock.workers = {:?}\n", clock.worker_clocks));
        }
        out
    }
}

/// The seven phases a distributed round decomposes into. Workers emit
/// the first four; the coordinator emits the last three.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Pull,
    Gate,
    Compute,
    Flush,
    Plan,
    Apply,
    Republish,
}

impl Phase {
    pub const ALL: [Phase; 7] = [
        Phase::Pull,
        Phase::Gate,
        Phase::Compute,
        Phase::Flush,
        Phase::Plan,
        Phase::Apply,
        Phase::Republish,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Pull => "pull",
            Phase::Gate => "gate",
            Phase::Compute => "compute",
            Phase::Flush => "flush",
            Phase::Plan => "plan",
            Phase::Apply => "apply",
            Phase::Republish => "republish",
        }
    }

    pub fn parse(s: &str) -> Option<Phase> {
        Phase::ALL.iter().copied().find(|p| p.name() == s)
    }
}

/// One completed span: `phase` ran for `dur_us` starting at `start_us`
/// (microseconds on the sink's time axis) on thread `worker` during
/// `round`. The coordinator uses `worker = P` (one past the last worker
/// id) as its own lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanEvent {
    pub phase: Phase,
    pub round: u64,
    pub worker: usize,
    pub start_us: u64,
    pub dur_us: u64,
}

impl SpanEvent {
    /// One compact chrome://tracing "complete" event (`"ph":"X"`), the
    /// JSONL line format `--trace-events` files hold.
    pub fn to_json_line(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"ph\":\"X\",\"pid\":0,\"tid\":{},\"ts\":{},\"dur\":{},\
             \"args\":{{\"round\":{}}}}}",
            self.phase.name(),
            self.worker,
            self.start_us,
            self.dur_us,
            self.round
        )
    }

    /// Parse one event back out of its JSON form (the schema round-trip
    /// direction tests and tooling use).
    pub fn from_json(j: &Json) -> Option<SpanEvent> {
        let phase = Phase::parse(j.get("name")?.as_str()?)?;
        let worker = j.get("tid")?.as_usize()?;
        let start_us = j.get("ts")?.as_f64()? as u64;
        let dur_us = j.get("dur")?.as_f64()? as u64;
        let round = j.get("args")?.get("round")?.as_f64()? as u64;
        Some(SpanEvent { phase, round, worker, start_us, dur_us })
    }
}

#[derive(Default)]
struct SinkInner {
    ring: VecDeque<SpanEvent>,
    dropped: u64,
}

/// Bounded ring of span events shared by every thread in a run. The cap
/// bounds memory for arbitrarily long runs: when full, the oldest event
/// is evicted (and counted) rather than blocking a recorder.
pub struct EventSink {
    epoch: Instant,
    cap: usize,
    inner: Mutex<SinkInner>,
}

impl EventSink {
    pub const DEFAULT_CAP: usize = 65_536;

    pub fn new(cap: usize) -> Self {
        EventSink { epoch: Instant::now(), cap: cap.max(1), inner: Mutex::default() }
    }

    /// Microseconds since this sink was created — the shared time axis
    /// every recorded span uses.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    pub fn record(&self, ev: SpanEvent) {
        let mut inner = self.inner.lock().expect("event sink lock poisoned");
        if inner.ring.len() == self.cap {
            inner.ring.pop_front();
            inner.dropped += 1;
        }
        inner.ring.push_back(ev);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("event sink lock poisoned").ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events evicted because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().expect("event sink lock poisoned").dropped
    }

    /// Drain the ring into JSONL text, oldest first.
    pub fn drain_jsonl(&self) -> String {
        let mut inner = self.inner.lock().expect("event sink lock poisoned");
        let mut out = String::with_capacity(inner.ring.len() * 96);
        for ev in inner.ring.drain(..) {
            out.push_str(&ev.to_json_line());
            out.push('\n');
        }
        out
    }

    /// Drain the ring and append it to `path` as JSONL; returns the
    /// number of events written. Appending lets several runs (e.g. the
    /// four staleness-sweep settings) share one trace file.
    pub fn flush_jsonl(&self, path: &std::path::Path) -> std::io::Result<usize> {
        use std::io::Write;
        let text = self.drain_jsonl();
        if text.is_empty() {
            return Ok(0);
        }
        let n = text.lines().count();
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        f.write_all(text.as_bytes())?;
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.add(3);
        c.inc();
        assert_eq!(c.get(), 4);
        c.raise(2); // below current: no-op
        assert_eq!(c.get(), 4);
        c.raise(10);
        assert_eq!(c.get(), 10);
        c.set(7);
        assert_eq!(c.get(), 7);
        let g = Gauge::new();
        g.set(42);
        assert_eq!(g.get(), 42);
    }

    #[test]
    fn histogram_bucket_boundaries() {
        let h = Histogram::new(&[10, 100, 1000]);
        // At a bound → that bucket; one past → the next; past the last
        // bound → overflow. Zero lands in the first bucket.
        for v in [0, 10, 11, 100, 101, 1000, 1001, u64::MAX] {
            h.record(v);
        }
        let MetricValue::Histogram { bounds, counts, sum: _, count } = h.value() else {
            panic!("histogram value kind");
        };
        assert_eq!(bounds, vec![10, 100, 1000]);
        assert_eq!(counts, vec![2, 2, 2, 2], "≤10, ≤100, ≤1000, overflow");
        assert_eq!(count, 8);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn histogram_rejects_unsorted_bounds() {
        let _ = Histogram::new(&[10, 10]);
    }

    #[test]
    fn registry_get_or_create_returns_same_instance() {
        let reg = Registry::new();
        let a = reg.counter("ps.pulls");
        let b = reg.counter("ps.pulls");
        a.add(5);
        assert_eq!(b.get(), 5, "same underlying counter");
        let h = reg.histogram("gate.wait_us", Histogram::us_bounds());
        h.record(3);
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 2);
        // BTreeMap ordering: sorted by name.
        assert_eq!(snap[0].0, "gate.wait_us");
        assert_eq!(snap[1].0, "ps.pulls");
        assert_eq!(snap[1].1, MetricValue::Counter(5));
        assert_eq!(snap[0].1.as_u64(), 1);
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn registry_rejects_kind_change() {
        let reg = Registry::new();
        let _ = reg.counter("x");
        let _ = reg.gauge("x");
    }

    #[test]
    fn snapshot_render_and_lookup() {
        let reg = Registry::new();
        reg.counter("ps.pulls").add(12);
        reg.gauge("net.socket_bytes").set(99);
        reg.histogram("gate.wait_us", &[10, 100]).record(5);
        let snap = ObsSnapshot {
            version: OBS_SNAPSHOT_VERSION,
            metrics: reg.snapshot(),
            segments: vec![(0, 64, 7)],
            clock: Some(ClockView {
                applied: 3,
                staleness_bound: Some(2),
                worker_clocks: vec![4, 3],
            }),
        };
        assert_eq!(snap.get("ps.pulls"), Some(&MetricValue::Counter(12)));
        assert_eq!(snap.get("missing"), None);
        let text = snap.render();
        assert!(text.contains("ps.pulls = 12"), "{text}");
        assert!(text.contains("net.socket_bytes = 99"), "{text}");
        assert!(text.contains("gate.wait_us = count=1 sum=5 buckets=[<=10:1]"), "{text}");
        assert!(text.contains("segment[0] = start=0 len=64 version=7"), "{text}");
        assert!(text.contains("clock.bound = 2"), "{text}");
        assert!(text.contains("clock.workers = [4, 3]"), "{text}");
    }

    #[test]
    fn ring_evicts_oldest_and_counts_drops() {
        let sink = EventSink::new(2);
        for round in 0..5u64 {
            sink.record(SpanEvent {
                phase: Phase::Pull,
                round,
                worker: 0,
                start_us: round,
                dur_us: 1,
            });
        }
        assert_eq!(sink.len(), 2);
        assert_eq!(sink.dropped(), 3);
        let text = sink.drain_jsonl();
        let rounds: Vec<u64> = text
            .lines()
            .map(|l| {
                let j = Json::parse(l).unwrap();
                j.get("args").unwrap().get("round").unwrap().as_f64().unwrap() as u64
            })
            .collect();
        assert_eq!(rounds, vec![3, 4], "oldest events evicted first");
        assert!(sink.is_empty(), "drain empties the ring");
    }

    #[test]
    fn seeded_event_jsonl_roundtrip() {
        // Deterministic LCG so the schema round-trip covers a spread of
        // field values (bounded to 50 bits: the parser goes through f64).
        let mut state = 0x243F6A8885A308D3u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            state >> 14
        };
        for i in 0..200 {
            let ev = SpanEvent {
                phase: Phase::ALL[i % Phase::ALL.len()],
                round: next(),
                worker: (next() % 4096) as usize,
                start_us: next(),
                dur_us: next(),
            };
            let line = ev.to_json_line();
            let parsed = Json::parse(&line).unwrap_or_else(|e| panic!("line {line}: {e}"));
            assert_eq!(parsed.get("ph").unwrap().as_str(), Some("X"));
            let back = SpanEvent::from_json(&parsed).expect("schema round-trip");
            assert_eq!(back, ev);
        }
    }

    #[test]
    fn flush_appends_jsonl_to_file() {
        let sink = EventSink::new(EventSink::DEFAULT_CAP);
        let path = std::env::temp_dir()
            .join(format!("strads_obs_flush_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        sink.record(SpanEvent {
            phase: Phase::Plan,
            round: 1,
            worker: 8,
            start_us: 10,
            dur_us: 2,
        });
        assert_eq!(sink.flush_jsonl(&path).unwrap(), 1);
        sink.record(SpanEvent {
            phase: Phase::Apply,
            round: 2,
            worker: 8,
            start_us: 20,
            dur_us: 3,
        });
        assert_eq!(sink.flush_jsonl(&path).unwrap(), 1, "second flush appends");
        assert_eq!(sink.flush_jsonl(&path).unwrap(), 0, "empty ring writes nothing");
        let text = std::fs::read_to_string(&path).unwrap();
        let phases: Vec<String> = text
            .lines()
            .map(|l| {
                Json::parse(l).unwrap().get("name").unwrap().as_str().unwrap().to_string()
            })
            .collect();
        assert_eq!(phases, vec!["plan", "apply"]);
        let _ = std::fs::remove_file(&path);
    }
}
