//! Experiment drivers: one function per paper figure, shared by the
//! CLI (`strads fig1|fig4|fig5`), the examples, and the criterion
//! benches so every entry point runs the identical protocol.

use crate::config::{EngineConfig, RunConfig};
use crate::data::lasso_synth::{self, LassoData, LassoSynthSpec};
use crate::data::mf_powerlaw::{self, MfSynthSpec};
use crate::engine::run_rounds;
use crate::lasso::NativeLasso;
use crate::metrics::Trace;
use crate::mf::{run_mf, DistMf, MfPartition, NativeMf};
use crate::problem::ModelProblem;
use crate::sim::{CostModel, VirtualCluster};

// Re-exported for back-compat: the selector moved next to the
// schedulers themselves so the distributed coordinator can route
// construction through it without a module cycle.
pub use crate::schedulers::SchedKind;

/// Lasso dataset selector.
pub fn lasso_spec(name: &str) -> anyhow::Result<LassoSynthSpec> {
    match name {
        "tiny" => Ok(LassoSynthSpec::tiny()),
        "adlike" => Ok(LassoSynthSpec::adlike()),
        "wide" => Ok(LassoSynthSpec::wide()),
        other => anyhow::bail!("unknown lasso dataset {other} (tiny|adlike|wide)"),
    }
}

/// MF dataset selector.
pub fn mf_spec(name: &str) -> anyhow::Result<MfSynthSpec> {
    match name {
        "tiny" => Ok(MfSynthSpec::tiny()),
        "netflix" => Ok(MfSynthSpec::netflix_like()),
        "yahoo" => Ok(MfSynthSpec::yahoo_like()),
        other => anyhow::bail!("unknown mf dataset {other} (tiny|netflix|yahoo)"),
    }
}

/// One Lasso run on the native backend + virtual cluster.
pub fn run_lasso_native(
    data: &LassoData,
    dataset: &str,
    sched: SchedKind,
    cfg: &RunConfig,
) -> Trace {
    let mut problem = NativeLasso::new(data, cfg.lambda);
    let mut scheduler = sched.build(problem.num_vars(), &cfg.sap, cfg.engine.seed);
    // Every scheduler gets the same S-shard latency hiding: it is an
    // infrastructure property (rotating scheduler threads), not part of
    // the policy under comparison.
    let mut cluster =
        VirtualCluster::new(cfg.workers, cfg.sap.shards, CostModel::new(&cfg.cost));
    let mut trace = Trace::new(sched.name(), dataset, cfg.workers);
    run_rounds(&mut problem, scheduler.as_mut(), &mut cluster, &cfg.engine, &mut trace);
    trace
}

/// Fig 1: STRADS vs Shotgun on the AD-regime dataset, λ = 5e-4.
pub fn fig1(cfg_base: &RunConfig, out_csv: Option<&std::path::Path>) -> Vec<Trace> {
    let data = lasso_synth::generate(&LassoSynthSpec::adlike(), cfg_base.engine.seed);
    let mut traces = Vec::new();
    for sched in [SchedKind::Dynamic, SchedKind::Random] {
        let cfg = cfg_base.clone();
        let t = run_lasso_native(&data, "adlike", sched, &cfg);
        if let Some(p) = out_csv {
            t.append_csv(p).expect("csv write");
        }
        println!("{}", t.summary());
        traces.push(t);
    }
    traces
}

/// Fig 4: {dynamic, static, random} x {adlike, wide} x {60, 120, 240}
/// virtual cores — the paper's 6-panel distributed Lasso comparison.
pub fn fig4(cfg_base: &RunConfig, out_csv: Option<&std::path::Path>) -> Vec<Trace> {
    let mut traces = Vec::new();
    for dataset in ["adlike", "wide"] {
        let data = lasso_synth::generate(&lasso_spec(dataset).unwrap(), cfg_base.engine.seed);
        for &workers in &[60usize, 120, 240] {
            for sched in [SchedKind::Dynamic, SchedKind::Static, SchedKind::Random] {
                let mut cfg = cfg_base.clone();
                cfg.workers = workers;
                let t = run_lasso_native(&data, dataset, sched, &cfg);
                if let Some(p) = out_csv {
                    t.append_csv(p).expect("csv write");
                }
                println!("{}", t.summary());
                traces.push(t);
            }
        }
    }
    traces
}

/// Fig 5: {balanced (STRADS), uniform (no LB)} x {netflix-like,
/// yahoo-like} x {4, 8, 16} cores — single-machine parallel MF.
pub fn fig5(cfg_base: &RunConfig, out_csv: Option<&std::path::Path>) -> Vec<Trace> {
    let mut traces = Vec::new();
    for dataset in ["netflix", "yahoo"] {
        let data = mf_powerlaw::generate(&mf_spec(dataset).unwrap(), cfg_base.engine.seed);
        for &workers in &[4usize, 8, 16] {
            for partition in [MfPartition::Balanced, MfPartition::Uniform] {
                let mut backend =
                    NativeMf::new(&data.a, data.rank_true, 0.05, cfg_base.engine.seed + 1);
                let cfg = EngineConfig {
                    max_rounds: cfg_base.engine.max_rounds.min(30),
                    record_every: 1,
                    ..cfg_base.engine.clone()
                };
                let mut t = Trace::new(partition.name(), dataset, workers);
                run_mf(&mut backend, partition, workers, &cfg, &cfg_base.cost, &mut t);
                if let Some(p) = out_csv {
                    t.append_csv(p).expect("csv write");
                }
                println!("{}", t.summary());
                traces.push(t);
            }
        }
    }
    traces
}

/// Ablation sweep over the two SAP design knobs DESIGN.md calls out:
/// the dependency threshold ρ (correctness vs parallelism trade) and
/// the scheduler shard count S (latency hiding). Prints one row per
/// setting; returns (label, trace) pairs.
pub fn ablation(cfg_base: &RunConfig, out_csv: Option<&std::path::Path>) -> Vec<(String, Trace)> {
    let data = lasso_synth::generate(&LassoSynthSpec::adlike(), cfg_base.engine.seed);
    let mut out = Vec::new();
    println!("-- rho sweep (P={}, shards={}) --", cfg_base.workers, cfg_base.sap.shards);
    for rho in [0.02, 0.05, 0.1, 0.2, 0.5, 1.0] {
        let mut cfg = cfg_base.clone();
        cfg.sap.rho = rho;
        let mut t = run_lasso_native(&data, "adlike", SchedKind::Dynamic, &cfg);
        t.scheduler = format!("rho={rho}");
        println!("  {}", t.summary());
        if let Some(p) = out_csv {
            t.append_csv(p).expect("csv write");
        }
        out.push((format!("rho={rho}"), t));
    }
    println!("-- shard sweep (rho={}) --", cfg_base.sap.rho);
    for shards in [1usize, 2, 4, 8] {
        let mut cfg = cfg_base.clone();
        cfg.sap.shards = shards;
        let mut t = run_lasso_native(&data, "adlike", SchedKind::Dynamic, &cfg);
        t.scheduler = format!("shards={shards}");
        println!("  {}", t.summary());
        if let Some(p) = out_csv {
            t.append_csv(p).expect("csv write");
        }
        out.push((format!("shards={shards}"), t));
    }
    out
}

/// Staleness sweep (the Petuum-style "fresh vs stale" curve): run the
/// same distributed workload — Lasso AND MF, both paper models —
/// through the parameter server at staleness bounds 0, 2, 8 and
/// fully-async, recording objective-vs-round traces with per-round
/// staleness and net-bytes columns. When `out_json` is given, also
/// emit a `BENCH_ps.json` perf snapshot per (workload, staleness)
/// setting (bytes flushed / republished / pulled, pull bytes per round
/// against the 16-byte-cell baseline, zero-copy snapshot-clone and
/// copy-on-publish counts *and bytes*, compressed wire runs, mean
/// staleness, wall-clock per round, plus the run's transport and the
/// *real* socket bytes it moved — 0 in-process, measured traffic under
/// `--ps-transport tcp`) so successive PRs have a trajectory to
/// compare against.
pub fn staleness_sweep(
    cfg_base: &RunConfig,
    dataset: &str,
    rounds: usize,
    out_csv: Option<&std::path::Path>,
    out_json: Option<&std::path::Path>,
) -> anyhow::Result<Vec<Trace>> {
    let lasso_data = lasso_synth::generate(&lasso_spec(dataset)?, cfg_base.engine.seed);
    // The MF leg reuses the dataset name when it names an MF spec
    // (netflix|yahoo|tiny), and falls back to tiny for the
    // lasso-specific ones (adlike|wide).
    let mf_dataset = if mf_spec(dataset).is_ok() { dataset } else { "tiny" };
    let mf_data = mf_powerlaw::generate(&mf_spec(mf_dataset)?, cfg_base.engine.seed);
    let mut traces = Vec::new();
    let mut rows = String::new();
    for workload in ["lasso", "mf"] {
        for setting in ["0", "2", "8", "async"] {
            let mut cfg = cfg_base.clone();
            cfg.ps.set_staleness_arg(setting)?;
            let wall = std::time::Instant::now();
            let mut report = match workload {
                "lasso" => {
                    let mut problem = NativeLasso::new(&lasso_data, cfg.lambda);
                    crate::workers::run_distributed(&mut problem, &cfg, rounds, dataset)?
                }
                _ => {
                    // Canonical MF regularization (fig 5's 0.05), not
                    // the sweep's lasso lambda.
                    let mut problem = DistMf::new(
                        &mf_data.a,
                        mf_data.rank_true,
                        0.05,
                        cfg.engine.seed + 1,
                    );
                    crate::workers::run_distributed(&mut problem, &cfg, rounds, mf_dataset)?
                }
            };
            if workload == "mf" {
                // Distinguish the two workloads' rows in the shared CSV.
                report.trace.scheduler = format!("mf-{}", report.trace.scheduler);
            }
            let elapsed = wall.elapsed().as_secs_f64();
            let sec_per_round =
                if report.rounds > 0 { elapsed / report.rounds as f64 } else { 0.0 };
            let pull_bytes_per_round = if report.rounds > 0 {
                report.pull_bytes as f64 / report.rounds as f64
            } else {
                0.0
            };
            // What the replaced 16-byte-per-cell wire format would have
            // moved for the same pulls — the bandwidth-halving baseline.
            let pull_bytes_cell_equiv = 16 * report.cells_pulled;
            println!(
                "[{workload}] {}  (flushed={}B republished={}B pulled={}B [{:.1}x under cell \
                 wire] socket={}B/{} runs_encoded={} snapshot_clones={} cow_clones={} \
                 cow_bytes={} gate_waits={} mean_staleness={:.2} sched_wait={:.3}s \
                 queue_depth={:.2} {:.3}ms/round)",
                report.trace.summary(),
                report.bytes_flushed,
                report.bytes_republished,
                report.pull_bytes,
                pull_bytes_cell_equiv as f64 / (report.pull_bytes.max(1)) as f64,
                report.socket_bytes,
                report.transport,
                report.runs_encoded,
                report.snapshot_clones,
                report.cow_clones,
                report.cow_bytes,
                report.gate_waits,
                report.mean_staleness,
                report.sched_wait_total,
                report.plan_queue_depth,
                sec_per_round * 1e3
            );
            if !rows.is_empty() {
                rows.push_str(",\n");
            }
            rows.push_str(&format!(
                "    {{\"workload\": \"{}\", \"staleness\": \"{}\", \"rounds\": {}, \
                 \"bytes_flushed\": {}, \
                 \"bytes_republished\": {}, \"pull_bytes\": {}, \"pull_bytes_per_round\": {:.1}, \
                 \"pull_bytes_cell_equiv\": {}, \"socket_bytes\": {}, \"runs_encoded\": {}, \
                 \"snapshot_clones\": {}, \
                 \"cow_clones\": {}, \"cow_bytes\": {}, \"mean_staleness\": {:.4}, \
                 \"max_staleness\": {}, \
                 \"gate_waits\": {}, \"hash_probes\": {}, \"wall_sec_per_round\": {:.6e}, \
                 \"sched_wait_total\": {:.6e}, \"plan_queue_depth\": {:.2}, \
                 \"reconnects\": {}, \"route_fanout_rpcs\": {}, \
                 \"sup.heartbeats\": {}, \"sup.leases_expired\": {}, \
                 \"sup.reassigns\": {}, \"sup.workers_live\": {}, \
                 \"final_objective\": {:.8e}}}",
                workload,
                setting,
                report.rounds,
                report.bytes_flushed,
                report.bytes_republished,
                report.pull_bytes,
                pull_bytes_per_round,
                pull_bytes_cell_equiv,
                report.socket_bytes,
                report.runs_encoded,
                report.snapshot_clones,
                report.cow_clones,
                report.cow_bytes,
                report.mean_staleness,
                report.max_stale_gap,
                report.gate_waits,
                report.hash_probes,
                sec_per_round,
                report.sched_wait_total,
                report.plan_queue_depth,
                report.reconnects,
                report.route_fanout_rpcs,
                report.sup_heartbeats,
                report.sup_leases_expired,
                report.sup_reassigns,
                report.sup_workers_live,
                report.trace.final_objective()
            ));
            if let Some(p) = out_csv {
                report.trace.append_csv(p).expect("csv write");
            }
            traces.push(report.trace);
        }
    }
    if let Some(p) = out_json {
        let tol_json = if cfg_base.ps.republish_auto {
            "\"auto\"".to_string()
        } else {
            format!("{:e}", cfg_base.ps.republish_tol)
        };
        // Fleet size the sweep routed over: the `[ps] addr` list length
        // for TCP runs, 1 in-process. CI's two-server smoke greps this.
        let route_servers = match cfg_base.ps.transport {
            crate::ps::TransportKind::Tcp => cfg_base.ps.addrs().len().max(1),
            crate::ps::TransportKind::InProc => 1,
        };
        let body = format!(
            "{{\n  \"bench\": \"ps_staleness_sweep\",\n  \"dataset\": \"{dataset}\",\n  \
             \"workers\": {},\n  \"republish_tol\": {},\n  \"chunk_cells\": {},\n  \
             \"wire_compress\": {},\n  \"dense_segments\": {},\n  \
             \"pipeline\": {},\n  \"transport\": \"{}\",\n  \"route_servers\": {},\n  \
             \"scheduler\": \"{}\",\n  \
             \"sched_shards\": {},\n  \"settings\": [\n{rows}\n  ]\n}}\n",
            cfg_base.workers,
            tol_json,
            cfg_base.ps.chunk_cells,
            cfg_base.ps.wire_compress,
            cfg_base.ps.dense_segments,
            cfg_base.ps.pipeline,
            cfg_base.ps.transport.name(),
            route_servers,
            cfg_base.sched.kind.name(),
            cfg_base.sched.effective_shards(&cfg_base.sap)
        );
        std::fs::write(p, body)?;
    }
    Ok(traces)
}

/// Calibrate the cost model's `sec_per_work_unit` by timing native
/// coordinate updates on this host (see EXPERIMENTS.md §Calibration).
pub fn calibrate_lasso(data: &LassoData, lambda: f64) -> f64 {
    let problem = NativeLasso::new(data, lambda);
    let n_updates = 20_000.min(data.j() * 4);
    let start = std::time::Instant::now();
    let mut acc = 0.0f64;
    for i in 0..n_updates {
        acc += problem.propose(i % data.j());
    }
    std::hint::black_box(acc);
    start.elapsed().as_secs_f64() / n_updates as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sched_kind_parse() {
        assert_eq!(SchedKind::parse("strads").unwrap(), SchedKind::Dynamic);
        assert_eq!(SchedKind::parse("shotgun").unwrap(), SchedKind::Random);
        assert!(SchedKind::parse("bogus").is_err());
    }

    #[test]
    fn tiny_lasso_run_decreases_objective() {
        let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 5);
        let cfg = RunConfig {
            workers: 8,
            lambda: 1e-3,
            engine: EngineConfig { max_rounds: 200, ..Default::default() },
            ..Default::default()
        };
        let t = run_lasso_native(&data, "tiny", SchedKind::Dynamic, &cfg);
        assert!(t.final_objective() < t.points[0].objective * 0.9);
    }

    #[test]
    fn calibration_returns_sane_value() {
        let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 6);
        let s = calibrate_lasso(&data, 1e-3);
        assert!(s > 0.0 && s < 1e-2, "sec/update {s}");
    }
}
