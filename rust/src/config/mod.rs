//! Typed configuration for every experiment: loadable from `configs/`
//! presets (key=value format, see [`crate::util::KvConf`]), CLI-
//! overridable, with defaults matching the paper's settings.

use crate::util::KvConf;

/// SAP scheduling parameters (paper §2, §4).
#[derive(Clone, Debug, PartialEq)]
pub struct SapConfig {
    /// Candidate multiplier: P' = p_prime_factor * P (step 1).
    pub p_prime_factor: usize,
    /// Dependency threshold ρ: pairs with |x_j^T x_k| > ρ are never
    /// co-scheduled (step 2). Paper uses 0.1 for Lasso.
    pub rho: f64,
    /// Smoothing η in p(j) ∝ δβ_j + η (keeps dormant coordinates alive).
    pub eta: f64,
    /// Initial priority weight (the paper's "β^(t-2) = C for large C"
    /// trick: every coordinate looks maximally important until touched
    /// once, forcing full coverage early).
    pub init_priority: f64,
    /// Number of scheduler shards S (paper §3); each owns J/S variables
    /// and they dispatch round-robin.
    pub shards: usize,
    /// Coordinates dispatched per worker block (paper §6 future work:
    /// "increasing the size of blocks to be dispatched while still
    /// tightly controlling interference" — every selected coordinate
    /// still passes the pairwise ρ check; blocks are then LPT-merged to
    /// P). 1 = the paper's evaluated configuration.
    pub coords_per_worker: usize,
}

impl Default for SapConfig {
    fn default() -> Self {
        SapConfig {
            p_prime_factor: 2,
            rho: 0.1,
            eta: 1e-6,
            init_priority: 1e3,
            shards: 4,
            coords_per_worker: 1,
        }
    }
}

/// Driver parameters shared by all experiments.
#[derive(Clone, Debug, PartialEq)]
pub struct EngineConfig {
    /// Record a trace point every `record_every` rounds.
    pub record_every: usize,
    /// Recompute the exact objective (artifact/native full pass) every
    /// `objective_every` rounds; between those, incremental values are
    /// used where the problem maintains them.
    pub objective_every: usize,
    /// Stop after this many rounds.
    pub max_rounds: usize,
    /// Stop early once the relative objective improvement over a
    /// `record_every` window falls below this (0 disables) — the
    /// "automatic stopping condition" the paper invokes in §5.1.
    pub rel_tol: f64,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            record_every: 1,
            objective_every: 50,
            max_rounds: 1_000,
            rel_tol: 0.0,
            seed: 42,
        }
    }
}

/// Scheduler-service parameters (the distributed path's planning side,
/// `sched_service::`).
#[derive(Clone, Debug, PartialEq)]
pub struct SchedConfig {
    /// Which scheduling policy plans distributed rounds (selects the
    /// shared planner-core policy on both the service and the inline
    /// path, so `static` and `random` run distributed too).
    pub kind: crate::schedulers::SchedKind,
    /// Scheduler shard (thread) count S for the service. `0` (the
    /// default) follows `sap.shards`, keeping the distributed planner
    /// identical to the engine-path scheduler built from the same
    /// config — the staleness-0 bit-exactness contract.
    pub shards: usize,
    /// Bounded per-shard plan-queue depth: how many rounds each shard
    /// thread may plan ahead of the coordinator popping them.
    pub pipeline_depth: usize,
    /// Run planning on dedicated shard threads (the pipelined service).
    /// Off = plan inline on the coordinator thread (the pre-service
    /// behaviour, kept for A/B runs; also the automatic fallback for
    /// problems without a thread-shareable scheduling oracle).
    pub service: bool,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            kind: crate::schedulers::SchedKind::Dynamic,
            shards: 0,
            pipeline_depth: 2,
            service: true,
        }
    }
}

impl SchedConfig {
    /// The effective scheduler shard count (0 = follow `sap.shards`).
    pub fn effective_shards(&self, sap: &SapConfig) -> usize {
        if self.shards == 0 {
            sap.shards
        } else {
            self.shards
        }
    }
}

/// Parameter-server parameters (the distributed path, `ps::`).
#[derive(Clone, Debug, PartialEq)]
pub struct PsConfig {
    /// SSP staleness bound s: a worker's pull may read state at most s
    /// rounds behind its own round (0 = BSP barrier semantics).
    pub staleness: usize,
    /// Fully asynchronous mode: the gate never blocks and the
    /// coordinator pipelines rounds freely (`staleness` is ignored).
    pub asynchronous: bool,
    /// Number of server shards: hash partitions for unregistered keys.
    /// Dense segments are epoch slabs (one per segment) and ignore
    /// this — their read concurrency comes from `Arc`-shared epochs,
    /// not partitioning.
    pub shards: usize,
    /// Incremental-republish tolerance: after each applied round the
    /// coordinator republishes only derived-state entries that moved by
    /// more than this since their last publish (plus a periodic full
    /// re-sync). `0.0` is lossless (skip only bitwise-unchanged
    /// entries); `< 0` restores full republish every round. Composes
    /// with the store's copy-on-publish epochs: the sparse entries that
    /// do get republished mutate a fresh epoch clone only when workers
    /// still hold the previous one, and update the slab in place
    /// otherwise.
    pub republish_tol: f64,
    /// `republish_tol = auto` in the conf / CLI: scale the tolerance
    /// with the run instead of hand-tuning it. Each applied round the
    /// coordinator sets the effective tolerance to
    /// `1e-7 * sqrt(2*|objective|/n)` — a fixed relative fraction of
    /// the RMS entry magnitude a quadratic objective implies — and
    /// uses lossless `0.0` until the first objective value exists.
    /// When set, [`PsConfig::republish_tol`] is ignored.
    pub republish_auto: bool,
    /// Cells per chunk in dense epoch slabs: each segment's f32 state
    /// is split into `chunk_cells`-sized chunks with independent
    /// `Arc`-shared epochs and versions, so a racing publish clones
    /// only the chunks it writes and a partial pull pins only the
    /// chunks it covers. `0` (the default) = one chunk per segment,
    /// today's exact whole-slab behaviour. Staleness-0 results are
    /// bitwise identical for any value (pinned by test).
    pub chunk_cells: usize,
    /// Encode flush/publish batches on the TCP wire as sorted
    /// index-delta + f32 value runs (dense stretches ship as one raw
    /// little-endian slab) instead of per-entry (key, f64) pairs.
    /// Lossless for dense-segment keys — f32 cells round-trip through
    /// f32 exactly — and bitwise-invisible to results; only
    /// `socket_bytes` shrinks. Off = the uncompressed v4-style frames.
    pub wire_compress: bool,
    /// Register the problem's contiguous key ranges as dense segment
    /// slabs (zero hash probes on those ranges). Off = hashed-only
    /// storage, kept for A/B and equivalence testing.
    pub dense_segments: bool,
    /// Gate-driven pipelining: with a staleness bound s > 0, dispatch
    /// rounds beyond the bound and let the SSP gate pace the workers so
    /// scheduling overlaps compute. Off = dispatch throttling at the
    /// bound. No effect at s = 0 (lock-step is required for engine-path
    /// bit-exactness) or in async mode (always pipelined).
    pub pipeline: bool,
    /// Which carriage moves pull/flush/publish/clock traffic between
    /// the run and its parameter server: `inproc` (shared memory in one
    /// process — the default, zero-copy pulls) or `tcp` (a length-
    /// prefixed binary protocol to a `strads ps-server` process at
    /// [`PsConfig::addr`]). Staleness-0 runs are bitwise identical
    /// across transports (the f32 wire is lossless).
    pub transport: crate::ps::TransportKind,
    /// `host:port` of the `ps-server` process (`tcp` transport only).
    /// A comma-separated list (`host:p1,host:p2`) shards the parameter
    /// state across an N-server fleet: each server hosts a contiguous
    /// split of every registered segment plus a hash share of the
    /// unregistered keys, and the client routes per key (wire v6).
    /// Staleness-0 runs are bitwise identical for any N.
    pub addr: String,
    /// Reconnect-and-retry attempts per RPC after a transport I/O fault
    /// (`tcp` only). 0 = fail fast, the pre-retry behaviour. Retried
    /// operations are exactly-once: re-`Init` reattaches by session id
    /// and retried flushes are deduped by seq, so staleness-0 runs stay
    /// bitwise identical under faults.
    pub retry_max: usize,
    /// First retry backoff sleep in milliseconds; doubles per attempt
    /// (capped at 2s) with deterministic jitter.
    pub retry_backoff_ms: u64,
    /// Deterministic fault-injection schedule for the retry harness
    /// (testing only; empty = no faults). Format:
    /// `seed=S,drop=P,err=P,delay=P,delay_ms=D,every=N,ops=pull|flush`.
    pub fault_plan: String,
    /// `strads ps-server` only: directory for periodic checkpoints of
    /// the hosted run (empty = checkpointing off). On restart the
    /// server restores the run from it before accepting connections.
    pub checkpoint_dir: String,
    /// Checkpoint every K applied-clock advances (ps-server only).
    pub checkpoint_every: u64,
    /// Versioned checkpoint images kept on disk (`ps-<applied>.ckpt`
    /// hard links next to the always-newest `ps.ckpt`); older images
    /// are pruned. Must be >= 1.
    pub checkpoint_keep: usize,
    /// Elastic membership: the coordinator supervises workers with
    /// per-dispatched-block leases, reassigns the blocks of dead or
    /// wedged workers to live ones, and admits mid-run joiners. With a
    /// fixed fleet (nobody dies or joins) results are bitwise identical
    /// to elastic = 0 — supervision is pure observation until a
    /// membership event fires. Implied on when `worker_kill_plan` is
    /// set.
    pub elastic: bool,
    /// Deterministic membership-chaos schedule (testing; empty = none).
    /// Format: `seed=S,kill=W@R,kill=@R,join=@R` — kill worker W (or a
    /// seeded victim) when round R dispatches, or admit a new worker.
    pub worker_kill_plan: String,
    /// Lease duration per dispatched block, in milliseconds: a block
    /// with no flush after this long is presumed stuck and reassigned
    /// to another live worker (elastic mode only). The server's flush
    /// ledger keeps late duplicates from double-applying.
    pub lease_ms: u64,
}

impl Default for PsConfig {
    fn default() -> Self {
        PsConfig {
            staleness: 0,
            asynchronous: false,
            shards: 8,
            republish_tol: 0.0,
            republish_auto: false,
            chunk_cells: 0,
            wire_compress: true,
            dense_segments: true,
            pipeline: true,
            transport: crate::ps::TransportKind::InProc,
            addr: "127.0.0.1:37021".to_string(),
            retry_max: 0,
            retry_backoff_ms: 50,
            fault_plan: String::new(),
            checkpoint_dir: String::new(),
            checkpoint_every: 16,
            checkpoint_keep: 2,
            elastic: false,
            worker_kill_plan: String::new(),
            lease_ms: 30_000,
        }
    }
}

impl PsConfig {
    /// Whether the run supervises membership: opted in explicitly or
    /// implied by a chaos schedule.
    pub fn elastic_enabled(&self) -> bool {
        self.elastic || !self.worker_kill_plan.is_empty()
    }
    /// The clock policy this config selects.
    pub fn policy(&self) -> crate::ps::StalenessPolicy {
        if self.asynchronous {
            crate::ps::StalenessPolicy::Async
        } else {
            crate::ps::StalenessPolicy::Bounded(self.staleness as u64)
        }
    }

    /// Apply a `--republish-tol` / `[ps] republish_tol` setting: a
    /// float tolerance, or `auto` for the objective-scaled tolerance.
    pub fn set_republish_tol_arg(&mut self, arg: &str) -> anyhow::Result<()> {
        if arg.trim() == "auto" {
            self.republish_auto = true;
        } else {
            self.republish_tol = arg
                .trim()
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("republish_tol must be a float or `auto`: {arg}"))?;
            self.republish_auto = false;
        }
        Ok(())
    }

    /// Apply a `--staleness` CLI setting: an integer bound or `async`.
    pub fn set_staleness_arg(&mut self, arg: &str) -> anyhow::Result<()> {
        match crate::ps::StalenessPolicy::parse(arg)? {
            crate::ps::StalenessPolicy::Bounded(s) => {
                self.staleness = s as usize;
                self.asynchronous = false;
            }
            crate::ps::StalenessPolicy::Async => self.asynchronous = true,
        }
        Ok(())
    }

    /// The `[ps] addr` server list: one entry per fleet member, in
    /// route order (trimmed; `host:p1,host:p2` → two servers).
    pub fn addrs(&self) -> Vec<String> {
        self.addr
            .split(',')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect()
    }
}

/// Observability parameters (`obs::` — the metrics registry, span
/// tracing, and the ps-server self-report). All of it is side-channel
/// only: obs settings never change a run's arithmetic (staleness-0
/// trajectories are bitwise identical at every level, pinned by test).
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// 0 = off, 1 = metrics registry only (the default), 2 = metrics +
    /// per-phase span tracing into `events_path`.
    pub level: usize,
    /// Where span events go as JSONL (chrome://tracing loadable);
    /// empty = don't write events even at level 2. `--trace-events`
    /// sets this and raises the level to at least 2.
    pub events_path: String,
    /// `strads ps-server` self-report period in seconds (0 = off).
    pub report_secs: u64,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig { level: 1, events_path: String::new(), report_secs: 0 }
    }
}

impl ObsConfig {
    /// Whether span events should be recorded and flushed.
    pub fn tracing(&self) -> bool {
        self.level >= 2 && !self.events_path.is_empty()
    }
}

/// Virtual-cluster cost model (see `sim::` for the formula and
/// DESIGN.md §2 for why the time axis is simulated).
#[derive(Clone, Debug, PartialEq)]
pub struct CostModelConfig {
    /// Seconds per workload unit on a worker core (lasso: one
    /// coordinate update's O(N) dot; MF: one rated entry).
    pub sec_per_work_unit: f64,
    /// Fixed per-round network/dispatch latency (seconds).
    pub round_overhead_sec: f64,
    /// Scheduler-side seconds per candidate scored (sampling + gram
    /// row + greedy pass, amortized).
    pub sched_sec_per_candidate: f64,
}

impl Default for CostModelConfig {
    fn default() -> Self {
        CostModelConfig {
            // Calibrated against the native updater on this host (see
            // EXPERIMENTS.md §Calibration and `strads calibrate`).
            sec_per_work_unit: 4.5e-7,
            round_overhead_sec: 1e-3,
            sched_sec_per_candidate: 2e-6,
        }
    }
}

/// Top-level experiment config (what the `configs/` presets load into).
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub sap: SapConfig,
    pub engine: EngineConfig,
    pub cost: CostModelConfig,
    pub ps: PsConfig,
    pub sched: SchedConfig,
    pub obs: ObsConfig,
    /// Worker (core) count P.
    pub workers: usize,
    /// Regularization λ.
    pub lambda: f64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            sap: SapConfig::default(),
            engine: EngineConfig::default(),
            cost: CostModelConfig::default(),
            ps: PsConfig::default(),
            sched: SchedConfig::default(),
            obs: ObsConfig::default(),
            workers: 16,
            lambda: 5e-4,
        }
    }
}

macro_rules! load {
    ($conf:expr, $target:expr, usize: $($key:literal => $field:expr),* $(,)?) => {
        $(if let Some(v) = $conf.get_usize($key).map_err(anyhow::Error::msg)? { $field = v; })*
    };
    ($conf:expr, $target:expr, f64: $($key:literal => $field:expr),* $(,)?) => {
        $(if let Some(v) = $conf.get_f64($key).map_err(anyhow::Error::msg)? { $field = v; })*
    };
}

impl RunConfig {
    /// Load a preset, starting from defaults; unknown keys are errors
    /// (they are always typos).
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Self> {
        let conf = KvConf::from_file(path).map_err(anyhow::Error::msg)?;
        Self::from_kvconf(&conf)
    }

    pub fn from_kvconf(conf: &KvConf) -> anyhow::Result<Self> {
        const KNOWN: &[&str] = &[
            "workers",
            "lambda",
            "sap.p_prime_factor",
            "sap.rho",
            "sap.eta",
            "sap.init_priority",
            "sap.shards",
            "sap.coords_per_worker",
            "engine.record_every",
            "engine.objective_every",
            "engine.max_rounds",
            "engine.rel_tol",
            "engine.seed",
            "cost.sec_per_work_unit",
            "cost.round_overhead_sec",
            "cost.sched_sec_per_candidate",
            "ps.staleness",
            "ps.async",
            "ps.shards",
            "ps.republish_tol",
            "ps.chunk_cells",
            "ps.wire_compress",
            "ps.dense_segments",
            "ps.pipeline",
            "ps.transport",
            "ps.addr",
            "ps.retry_max",
            "ps.retry_backoff_ms",
            "ps.fault_plan",
            "ps.checkpoint_dir",
            "ps.checkpoint_every",
            "ps.checkpoint_keep",
            "ps.elastic",
            "ps.worker_kill_plan",
            "ps.lease_ms",
            "sched.scheduler",
            "sched.shards",
            "sched.pipeline_depth",
            "sched.service",
            "obs.level",
            "obs.events_path",
            "obs.report_secs",
        ];
        for k in conf.keys() {
            anyhow::ensure!(KNOWN.contains(&k), "unknown config key: {k}");
        }
        let mut c = RunConfig::default();
        load!(conf, c, usize:
            "workers" => c.workers,
            "sap.p_prime_factor" => c.sap.p_prime_factor,
            "sap.shards" => c.sap.shards,
            "sap.coords_per_worker" => c.sap.coords_per_worker,
            "engine.record_every" => c.engine.record_every,
            "engine.objective_every" => c.engine.objective_every,
            "engine.max_rounds" => c.engine.max_rounds,
            "ps.staleness" => c.ps.staleness,
            "ps.shards" => c.ps.shards,
            "ps.chunk_cells" => c.ps.chunk_cells,
            "ps.retry_max" => c.ps.retry_max,
            "sched.shards" => c.sched.shards,
            "sched.pipeline_depth" => c.sched.pipeline_depth,
            "obs.level" => c.obs.level,
        );
        if let Some(v) = conf.get("sched.scheduler") {
            c.sched.kind = crate::schedulers::SchedKind::parse(v)?;
        }
        if let Some(v) = conf.get_usize("sched.service").map_err(anyhow::Error::msg)? {
            c.sched.service = v != 0;
        }
        if let Some(v) = conf.get_usize("ps.async").map_err(anyhow::Error::msg)? {
            c.ps.asynchronous = v != 0;
        }
        if let Some(v) = conf.get("ps.republish_tol") {
            c.ps.set_republish_tol_arg(v)?;
        }
        if let Some(v) = conf.get_usize("ps.wire_compress").map_err(anyhow::Error::msg)? {
            c.ps.wire_compress = v != 0;
        }
        if let Some(v) = conf.get_usize("ps.dense_segments").map_err(anyhow::Error::msg)? {
            c.ps.dense_segments = v != 0;
        }
        if let Some(v) = conf.get_usize("ps.pipeline").map_err(anyhow::Error::msg)? {
            c.ps.pipeline = v != 0;
        }
        if let Some(v) = conf.get("ps.transport") {
            c.ps.transport = crate::ps::TransportKind::parse(v)?;
        }
        if let Some(v) = conf.get("ps.addr") {
            c.ps.addr = v.to_string();
        }
        if let Some(v) = conf.get_u64("ps.retry_backoff_ms").map_err(anyhow::Error::msg)? {
            c.ps.retry_backoff_ms = v;
        }
        if let Some(v) = conf.get("ps.fault_plan") {
            c.ps.fault_plan = v.to_string();
        }
        if let Some(v) = conf.get("ps.checkpoint_dir") {
            c.ps.checkpoint_dir = v.to_string();
        }
        if let Some(v) = conf.get_u64("ps.checkpoint_every").map_err(anyhow::Error::msg)? {
            c.ps.checkpoint_every = v;
        }
        if let Some(v) = conf.get_usize("ps.checkpoint_keep").map_err(anyhow::Error::msg)? {
            c.ps.checkpoint_keep = v;
        }
        if let Some(v) = conf.get_usize("ps.elastic").map_err(anyhow::Error::msg)? {
            c.ps.elastic = v != 0;
        }
        if let Some(v) = conf.get("ps.worker_kill_plan") {
            c.ps.worker_kill_plan = v.to_string();
        }
        if let Some(v) = conf.get_u64("ps.lease_ms").map_err(anyhow::Error::msg)? {
            c.ps.lease_ms = v;
        }
        if let Some(v) = conf.get("obs.events_path") {
            c.obs.events_path = v.to_string();
        }
        if let Some(v) = conf.get_u64("obs.report_secs").map_err(anyhow::Error::msg)? {
            c.obs.report_secs = v;
        }
        load!(conf, c, f64:
            "lambda" => c.lambda,
            "sap.rho" => c.sap.rho,
            "sap.eta" => c.sap.eta,
            "sap.init_priority" => c.sap.init_priority,
            "engine.rel_tol" => c.engine.rel_tol,
            "cost.sec_per_work_unit" => c.cost.sec_per_work_unit,
            "cost.round_overhead_sec" => c.cost.round_overhead_sec,
            "cost.sched_sec_per_candidate" => c.cost.sched_sec_per_candidate,
        );
        if let Some(v) = conf.get_u64("engine.seed").map_err(anyhow::Error::msg)? {
            c.engine.seed = v;
        }
        c.validate()?;
        Ok(c)
    }

    /// Serialize back to the preset format.
    pub fn to_conf_string(&self) -> String {
        format!(
            "workers = {}\nlambda = {:e}\n\n[sap]\np_prime_factor = {}\nrho = {}\neta = {:e}\ninit_priority = {:e}\nshards = {}\ncoords_per_worker = {}\n\n[engine]\nrecord_every = {}\nobjective_every = {}\nmax_rounds = {}\nrel_tol = {:e}\nseed = {}\n\n[cost]\nsec_per_work_unit = {:e}\nround_overhead_sec = {:e}\nsched_sec_per_candidate = {:e}\n\n[ps]\nstaleness = {}\nasync = {}\nshards = {}\nrepublish_tol = {}\nchunk_cells = {}\nwire_compress = {}\ndense_segments = {}\npipeline = {}\ntransport = {}\naddr = {}\nretry_max = {}\nretry_backoff_ms = {}\nfault_plan = \"{}\"\ncheckpoint_dir = \"{}\"\ncheckpoint_every = {}\ncheckpoint_keep = {}\nelastic = {}\nworker_kill_plan = \"{}\"\nlease_ms = {}\n\n[sched]\nscheduler = {}\nshards = {}\npipeline_depth = {}\nservice = {}\n\n[obs]\nlevel = {}\nevents_path = \"{}\"\nreport_secs = {}\n",
            self.workers,
            self.lambda,
            self.sap.p_prime_factor,
            self.sap.rho,
            self.sap.eta,
            self.sap.init_priority,
            self.sap.shards,
            self.sap.coords_per_worker,
            self.engine.record_every,
            self.engine.objective_every,
            self.engine.max_rounds,
            self.engine.rel_tol,
            self.engine.seed,
            self.cost.sec_per_work_unit,
            self.cost.round_overhead_sec,
            self.cost.sched_sec_per_candidate,
            self.ps.staleness,
            usize::from(self.ps.asynchronous),
            self.ps.shards,
            if self.ps.republish_auto {
                "auto".to_string()
            } else {
                format!("{:e}", self.ps.republish_tol)
            },
            self.ps.chunk_cells,
            usize::from(self.ps.wire_compress),
            usize::from(self.ps.dense_segments),
            usize::from(self.ps.pipeline),
            self.ps.transport.name(),
            self.ps.addr,
            self.ps.retry_max,
            self.ps.retry_backoff_ms,
            self.ps.fault_plan,
            self.ps.checkpoint_dir,
            self.ps.checkpoint_every,
            self.ps.checkpoint_keep,
            usize::from(self.ps.elastic),
            self.ps.worker_kill_plan,
            self.ps.lease_ms,
            self.sched.kind.name(),
            self.sched.shards,
            self.sched.pipeline_depth,
            usize::from(self.sched.service),
            self.obs.level,
            self.obs.events_path,
            self.obs.report_secs,
        )
    }

    /// Validate invariants that would otherwise surface as confusing
    /// runtime behaviour.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.workers >= 1, "workers must be >= 1");
        anyhow::ensure!(self.sap.p_prime_factor >= 1, "p_prime_factor must be >= 1");
        anyhow::ensure!(self.sap.shards >= 1, "shards must be >= 1");
        anyhow::ensure!(self.sap.coords_per_worker >= 1, "coords_per_worker must be >= 1");
        anyhow::ensure!((0.0..=1.0).contains(&self.sap.rho), "rho must be in [0, 1]");
        anyhow::ensure!(self.sap.eta > 0.0, "eta must be > 0");
        anyhow::ensure!(self.lambda >= 0.0, "lambda must be >= 0");
        anyhow::ensure!(self.ps.shards >= 1, "ps.shards must be >= 1");
        anyhow::ensure!(self.sched.pipeline_depth >= 1, "sched.pipeline_depth must be >= 1");
        anyhow::ensure!(
            self.ps.republish_tol.is_finite(),
            "ps.republish_tol must be finite (negative = full republish)"
        );
        anyhow::ensure!(
            !self.ps.addr.is_empty(),
            "ps.addr must be a host:port (required by the tcp transport)"
        );
        anyhow::ensure!(
            !self.ps.addrs().is_empty()
                && self.ps.addr.split(',').all(|a| !a.trim().is_empty()),
            "ps.addr must be a host:port or a comma-separated list of them \
             (no empty entries)"
        );
        anyhow::ensure!(
            self.ps.checkpoint_every >= 1,
            "ps.checkpoint_every must be >= 1 (ticks between checkpoints)"
        );
        anyhow::ensure!(
            self.ps.checkpoint_keep >= 1,
            "ps.checkpoint_keep must be >= 1 (the newest image is always kept)"
        );
        anyhow::ensure!(
            self.ps.lease_ms >= 1,
            "ps.lease_ms must be >= 1 (a zero lease reassigns every block instantly)"
        );
        if !self.ps.worker_kill_plan.is_empty() {
            crate::workers::KillPlan::parse(&self.ps.worker_kill_plan)
                .map_err(|e| anyhow::anyhow!("bad [ps] worker_kill_plan: {e}"))?;
        }
        anyhow::ensure!(
            self.obs.level <= 2,
            "obs.level must be 0 (off), 1 (metrics), or 2 (metrics + tracing)"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conf_roundtrip() {
        let cfg = RunConfig { workers: 240, ..Default::default() };
        let s = cfg.to_conf_string();
        let back = RunConfig::from_kvconf(&KvConf::parse(&s).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn unknown_key_is_rejected() {
        let conf = KvConf::parse("wrokers = 8\n").unwrap();
        assert!(RunConfig::from_kvconf(&conf).is_err());
    }

    #[test]
    fn validation_rejects_bad_rho() {
        let conf = KvConf::parse("[sap]\nrho = 1.5\n").unwrap();
        assert!(RunConfig::from_kvconf(&conf).is_err());
    }

    #[test]
    fn validation_accepts_default() {
        assert!(RunConfig::default().validate().is_ok());
    }

    #[test]
    fn partial_preset_overrides_defaults() {
        let conf = KvConf::parse("workers = 60\n[sap]\nrho = 0.2\n").unwrap();
        let c = RunConfig::from_kvconf(&conf).unwrap();
        assert_eq!(c.workers, 60);
        assert_eq!(c.sap.rho, 0.2);
        assert_eq!(c.sap.shards, SapConfig::default().shards);
    }

    #[test]
    fn ps_section_roundtrips_and_validates() {
        let conf = KvConf::parse("[ps]\nstaleness = 4\nasync = 0\nshards = 16\n").unwrap();
        let c = RunConfig::from_kvconf(&conf).unwrap();
        assert_eq!(
            c.ps,
            PsConfig { staleness: 4, asynchronous: false, shards: 16, ..Default::default() }
        );
        assert_eq!(c.ps.policy(), crate::ps::StalenessPolicy::Bounded(4));

        let conf = KvConf::parse("[ps]\nasync = 1\n").unwrap();
        let c = RunConfig::from_kvconf(&conf).unwrap();
        assert_eq!(c.ps.policy(), crate::ps::StalenessPolicy::Async);

        let bad = KvConf::parse("[ps]\nshards = 0\n").unwrap();
        assert!(RunConfig::from_kvconf(&bad).is_err());
    }

    #[test]
    fn ps_dense_republish_pipeline_keys_parse() {
        let conf = KvConf::parse(
            "[ps]\nrepublish_tol = 1e-7\ndense_segments = 0\npipeline = 0\n",
        )
        .unwrap();
        let c = RunConfig::from_kvconf(&conf).unwrap();
        assert_eq!(c.ps.republish_tol, 1e-7);
        assert!(!c.ps.dense_segments);
        assert!(!c.ps.pipeline);
        // defaults: lossless incremental republish, dense + pipelined on
        let d = PsConfig::default();
        assert_eq!(d.republish_tol, 0.0);
        assert!(d.dense_segments && d.pipeline);
        // negative tolerance (= full republish) is a legal setting
        let conf = KvConf::parse("[ps]\nrepublish_tol = -1\n").unwrap();
        let c = RunConfig::from_kvconf(&conf).unwrap();
        assert_eq!(c.ps.republish_tol, -1.0);
        // `auto` selects the objective-scaled tolerance
        let conf = KvConf::parse("[ps]\nrepublish_tol = auto\n").unwrap();
        let c = RunConfig::from_kvconf(&conf).unwrap();
        assert!(c.ps.republish_auto);
        assert!(!PsConfig::default().republish_auto, "auto must be opt-in");
        let bad = KvConf::parse("[ps]\nrepublish_tol = soonish\n").unwrap();
        assert!(RunConfig::from_kvconf(&bad).is_err());
        // auto survives the conf round trip
        let cfg = RunConfig {
            ps: PsConfig { republish_auto: true, ..Default::default() },
            ..Default::default()
        };
        let back = RunConfig::from_kvconf(&KvConf::parse(&cfg.to_conf_string()).unwrap());
        assert_eq!(back.unwrap(), cfg);
    }

    #[test]
    fn ps_hot_path_keys_parse() {
        let conf = KvConf::parse("[ps]\nchunk_cells = 4096\nwire_compress = 0\n").unwrap();
        let c = RunConfig::from_kvconf(&conf).unwrap();
        assert_eq!(c.ps.chunk_cells, 4096);
        assert!(!c.ps.wire_compress);
        // defaults: whole-slab chunks, compressed wire
        let d = PsConfig::default();
        assert_eq!(d.chunk_cells, 0, "0 must mean one chunk per segment");
        assert!(d.wire_compress, "run encoding is on by default");
    }

    #[test]
    fn ps_transport_keys_parse() {
        let conf = KvConf::parse("[ps]\ntransport = tcp\naddr = 127.0.0.1:4100\n").unwrap();
        let c = RunConfig::from_kvconf(&conf).unwrap();
        assert_eq!(c.ps.transport, crate::ps::TransportKind::Tcp);
        assert_eq!(c.ps.addr, "127.0.0.1:4100");
        // default carriage is in-process shared memory
        assert_eq!(PsConfig::default().transport, crate::ps::TransportKind::InProc);
        let bad = KvConf::parse("[ps]\ntransport = smoke-signals\n").unwrap();
        assert!(RunConfig::from_kvconf(&bad).is_err());
        let bad = KvConf::parse("[ps]\naddr = \"\"\n").unwrap();
        assert!(RunConfig::from_kvconf(&bad).is_err());
    }

    #[test]
    fn ps_fault_tolerance_keys_parse() {
        let conf = KvConf::parse(
            "[ps]\nretry_max = 5\nretry_backoff_ms = 10\nfault_plan = \"seed=1,drop=0.1\"\ncheckpoint_dir = \"results/ckpt\"\ncheckpoint_every = 4\n",
        )
        .unwrap();
        let c = RunConfig::from_kvconf(&conf).unwrap();
        assert_eq!(c.ps.retry_max, 5);
        assert_eq!(c.ps.retry_backoff_ms, 10);
        assert_eq!(c.ps.fault_plan, "seed=1,drop=0.1");
        assert_eq!(c.ps.checkpoint_dir, "results/ckpt");
        assert_eq!(c.ps.checkpoint_every, 4);
        // defaults: fail fast, no faults, no checkpoints
        let d = PsConfig::default();
        assert_eq!(d.retry_max, 0, "retry must be opt-in (fail-fast default)");
        assert!(d.fault_plan.is_empty() && d.checkpoint_dir.is_empty());
        assert_eq!((d.retry_backoff_ms, d.checkpoint_every), (50, 16));
        // checkpoint_every = 0 would divide by zero in the cadence check
        let bad = KvConf::parse("[ps]\ncheckpoint_every = 0\n").unwrap();
        assert!(RunConfig::from_kvconf(&bad).is_err());
    }

    #[test]
    fn ps_elastic_keys_parse() {
        let conf = KvConf::parse(
            "[ps]\nelastic = 1\nworker_kill_plan = \"seed=7,kill=@3\"\nlease_ms = 500\ncheckpoint_keep = 4\n",
        )
        .unwrap();
        let c = RunConfig::from_kvconf(&conf).unwrap();
        assert!(c.ps.elastic && c.ps.elastic_enabled());
        assert_eq!(c.ps.worker_kill_plan, "seed=7,kill=@3");
        assert_eq!(c.ps.lease_ms, 500);
        assert_eq!(c.ps.checkpoint_keep, 4);
        // defaults: supervision off, no chaos, 30s leases, keep 2 images
        let d = PsConfig::default();
        assert!(!d.elastic && !d.elastic_enabled(), "elasticity must be opt-in");
        assert!(d.worker_kill_plan.is_empty());
        assert_eq!((d.lease_ms, d.checkpoint_keep), (30_000, 2));
        // a kill plan implies supervision even without elastic = 1
        let implied =
            PsConfig { worker_kill_plan: "kill=0@1".into(), ..Default::default() };
        assert!(implied.elastic_enabled());
        // the plan grammar is validated at config load, not mid-run
        let bad = KvConf::parse("[ps]\nworker_kill_plan = \"kill=zero@1\"\n").unwrap();
        assert!(RunConfig::from_kvconf(&bad).is_err());
        let bad = KvConf::parse("[ps]\nlease_ms = 0\n").unwrap();
        assert!(RunConfig::from_kvconf(&bad).is_err());
        let bad = KvConf::parse("[ps]\ncheckpoint_keep = 0\n").unwrap();
        assert!(RunConfig::from_kvconf(&bad).is_err());
    }

    #[test]
    fn sched_section_parses_and_defaults() {
        let conf = KvConf::parse(
            "[sched]\nscheduler = static\nshards = 2\npipeline_depth = 4\nservice = 0\n",
        )
        .unwrap();
        let c = RunConfig::from_kvconf(&conf).unwrap();
        assert_eq!(c.sched.kind, crate::schedulers::SchedKind::Static);
        assert_eq!(c.sched.shards, 2);
        assert_eq!(c.sched.pipeline_depth, 4);
        assert!(!c.sched.service);
        // defaults: dynamic policy, shards follow sap.shards, service on
        let d = SchedConfig::default();
        assert_eq!(d.kind, crate::schedulers::SchedKind::Dynamic);
        assert_eq!(d.effective_shards(&SapConfig::default()), SapConfig::default().shards);
        assert!(d.service);
        // explicit shard count overrides sap.shards
        assert_eq!(
            SchedConfig { shards: 7, ..Default::default() }
                .effective_shards(&SapConfig::default()),
            7
        );
        // depth 0 is rejected
        let bad = KvConf::parse("[sched]\npipeline_depth = 0\n").unwrap();
        assert!(RunConfig::from_kvconf(&bad).is_err());
        // bogus policy is rejected
        let bad = KvConf::parse("[sched]\nscheduler = bogus\n").unwrap();
        assert!(RunConfig::from_kvconf(&bad).is_err());
    }

    #[test]
    fn obs_section_parses_and_validates() {
        let conf = KvConf::parse(
            "[obs]\nlevel = 2\nevents_path = \"results/events.jsonl\"\nreport_secs = 5\n",
        )
        .unwrap();
        let c = RunConfig::from_kvconf(&conf).unwrap();
        assert_eq!(c.obs.level, 2);
        assert_eq!(c.obs.events_path, "results/events.jsonl");
        assert_eq!(c.obs.report_secs, 5);
        assert!(c.obs.tracing());
        // defaults: metrics on, no tracing, no self-report
        let d = ObsConfig::default();
        assert_eq!((d.level, d.report_secs), (1, 0));
        assert!(!d.tracing(), "level 1 must not trace");
        assert!(
            !ObsConfig { level: 2, ..Default::default() }.tracing(),
            "tracing needs a path"
        );
        // levels past 2 are typos
        let bad = KvConf::parse("[obs]\nlevel = 3\n").unwrap();
        assert!(RunConfig::from_kvconf(&bad).is_err());
    }

    #[test]
    fn staleness_cli_arg_parses() {
        let mut ps = PsConfig::default();
        ps.set_staleness_arg("8").unwrap();
        assert_eq!((ps.staleness, ps.asynchronous), (8, false));
        ps.set_staleness_arg("async").unwrap();
        assert!(ps.asynchronous);
        assert!(ps.set_staleness_arg("soon").is_err());
    }
}
