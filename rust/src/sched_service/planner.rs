//! The shard planner core — the one implementation of SAP planning
//! shared by the synchronous engine-path schedulers
//! ([`crate::schedulers`]) and the threaded scheduler service
//! ([`super::SchedService`]).
//!
//! A [`ShardPlanner`] owns one shard of the variable space (a fixed
//! random J/S slice, assigned once), its local importance state, its
//! own deterministic RNG stream (forked from the run seed, so the
//! serial rotation and the threaded service consume *identical*
//! per-shard streams), and a memo cache of pairwise dependencies.
//! [`PlannerSet`] is the serial composition: round-robin turns over the
//! shard planners, exactly the paper's §3 rotation. The service splits
//! a `PlannerSet` into its shard planners and runs each on its own
//! thread; because every planner's inputs (candidate stream, priority
//! state, dependency values) are per-shard, the two execution shapes
//! produce bit-identical plan sequences at lock-step observation
//! delivery.
//!
//! Dependency and workload queries go through [`PlanDeps`], with two
//! adapters: [`ProblemDeps`] borrows the `&mut dyn ModelProblem` the
//! engine path already holds; [`OracleDeps`] reads a thread-shareable
//! [`SchedOracle`] (immutable data, e.g. the Lasso design matrix) so
//! shard threads can plan without touching the coordinator's canonical
//! state. Both return the same values for the same pair, which is what
//! the staleness-0 bit-exactness pin relies on.

use crate::config::SapConfig;
use crate::coordinator::depcheck::select_independent_lazy;
use crate::coordinator::priority::{PriorityDist, PriorityKind};
use crate::coordinator::shard::partition_owned;
use crate::coordinator::{merge_balanced, select_independent, SchedCost};
use crate::problem::{Block, ModelProblem, RoundResult};
use crate::schedulers::SchedKind;
use crate::util::{FastHashMap, Rng};
use std::sync::Arc;

/// Memo-cache flush threshold (entries): bounds planner memory the same
/// way `NativeLasso` bounds its own dependency cache.
const MEMO_CAP: usize = 2_000_000;

/// Thread-shareable scheduling-side view of a problem: everything a
/// shard planner needs to plan without the coordinator's `&mut`
/// problem. Implementations hold immutable data only (e.g. a clone of
/// the design matrix), exactly like [`crate::ps::PsKernel`] does for
/// the worker side.
pub trait SchedOracle: Send + Sync {
    /// Number of schedulable variables J.
    fn num_vars(&self) -> usize;

    /// Workload units of variable `j` (load-balanced merge input).
    fn workload(&self, _j: usize) -> u64 {
        1
    }

    /// Pairwise dependency strength |d(x_a, x_b)|. Must return the
    /// same value as the problem's own `dependency_pair` for the
    /// staleness-0 path to stay bit-exact with the engine path.
    fn dependency_pair(&self, a: usize, b: usize) -> f64;
}

/// What a planner queries while planning: dependency strengths and
/// workloads. One trait, two sources (problem or oracle).
pub trait PlanDeps {
    fn workload(&mut self, j: usize) -> u64;
    /// Whether on-demand pair queries are cheap (lazy greedy) or the
    /// dense candidate matrix should be materialized in one call.
    fn supports_pair(&self) -> bool;
    fn dep_pair(&mut self, a: usize, b: usize) -> f64;
    fn dep_matrix(&mut self, cands: &[usize]) -> Vec<f64>;
}

/// Engine-path adapter: plan against the problem itself.
pub struct ProblemDeps<'a>(pub &'a mut dyn ModelProblem);

impl PlanDeps for ProblemDeps<'_> {
    fn workload(&mut self, j: usize) -> u64 {
        self.0.workload(j)
    }

    fn supports_pair(&self) -> bool {
        self.0.supports_pair_dependency()
    }

    fn dep_pair(&mut self, a: usize, b: usize) -> f64 {
        self.0.dependency_pair(a, b)
    }

    fn dep_matrix(&mut self, cands: &[usize]) -> Vec<f64> {
        self.0.dependencies(cands)
    }
}

/// Service-path adapter: plan against a shared immutable oracle.
pub struct OracleDeps<'a>(pub &'a dyn SchedOracle);

impl PlanDeps for OracleDeps<'_> {
    fn workload(&mut self, j: usize) -> u64 {
        self.0.workload(j)
    }

    fn supports_pair(&self) -> bool {
        true
    }

    fn dep_pair(&mut self, a: usize, b: usize) -> f64 {
        self.0.dependency_pair(a, b)
    }

    fn dep_matrix(&mut self, cands: &[usize]) -> Vec<f64> {
        let c = cands.len();
        let mut out = vec![0.0f64; c * c];
        for i in 0..c {
            for k in (i + 1)..c {
                let v = self.0.dependency_pair(cands[i], cands[k]);
                out[i * c + k] = v;
                out[k * c + i] = v;
            }
        }
        out
    }
}

/// Per-shard selection policy — the three scheduling models of the
/// paper's evaluation, sharded uniformly.
enum PlanPolicy {
    /// STRADS/SAP: importance-sampled candidates + ρ depcheck.
    Dynamic(PriorityDist),
    /// Static blocks: uniform candidates + the same ρ depcheck, no
    /// importance feedback.
    Static,
    /// Shotgun: uniform selection, no structure at all.
    Random,
}

/// One scheduler shard: owned variables, local importance state, a
/// private RNG stream, and a dependency memo cache.
pub struct ShardPlanner {
    index: usize,
    /// Global variable ids owned by this shard (fixed for the run).
    owned: Vec<usize>,
    policy: PlanPolicy,
    rng: Rng,
    cfg: SapConfig,
    memo: FastHashMap<(u32, u32), f64>,
    last_cost: SchedCost,
}

impl ShardPlanner {
    pub fn index(&self) -> usize {
        self.index
    }

    pub fn owned(&self) -> &[usize] {
        &self.owned
    }

    pub fn last_cost(&self) -> SchedCost {
        self.last_cost
    }

    /// SAP step 4 for one owned variable (local index).
    fn report_local(&mut self, li: usize, delta_abs: f64) {
        if let PlanPolicy::Dynamic(dist) = &mut self.policy {
            dist.report(li, delta_abs);
        }
    }

    /// Fold a round's progress report: every delta whose variable this
    /// shard owns (per the shared `owner` table) updates the local
    /// importance state. Non-dynamic policies ignore progress.
    pub fn absorb(&mut self, owner: &[(u32, u32)], deltas: &[(usize, f64)]) {
        if !matches!(self.policy, PlanPolicy::Dynamic(_)) {
            return;
        }
        let me = self.index as u32;
        for &(v, d) in deltas {
            let (si, li) = owner[v];
            if si == me {
                self.report_local(li as usize, d);
            }
        }
    }

    /// Fraction of owned variables updated at least once.
    pub fn coverage(&self) -> f64 {
        match &self.policy {
            PlanPolicy::Dynamic(dist) => dist.coverage(),
            _ => 1.0,
        }
    }

    /// Plan one round from this shard: candidate draw (policy-specific)
    /// → ρ-constrained greedy selection → LPT merge to ≤ `p` blocks.
    pub fn plan(&mut self, deps: &mut dyn PlanDeps, p: usize) -> Vec<Block> {
        // Step 1: draw candidates from this shard's partition.
        let (cands, limit) = match &mut self.policy {
            PlanPolicy::Dynamic(dist) => {
                // P' = factor * limit importance-sampled candidates;
                // Fenwick sampling-without-replacement returns
                // high-weight candidates earlier on average, which is
                // the priority order the greedy step-2 pass wants.
                let limit = p * self.cfg.coords_per_worker;
                let p_prime = limit * self.cfg.p_prime_factor;
                let locals = dist.sample_candidates(p_prime, &mut self.rng);
                let cands: Vec<usize> = locals.into_iter().map(|li| self.owned[li]).collect();
                (cands, limit)
            }
            PlanPolicy::Static => {
                let n = self.owned.len();
                let p_prime = (p * self.cfg.p_prime_factor).min(n);
                let locals = self.rng.sample_distinct(n, p_prime);
                let cands: Vec<usize> = locals.into_iter().map(|li| self.owned[li]).collect();
                (cands, p)
            }
            PlanPolicy::Random => {
                // Shotgun: uniform distinct singletons, no depcheck, no
                // merge — every selected variable is its own block.
                let n = self.owned.len();
                let locals = self.rng.sample_distinct(n, p.min(n));
                let blocks: Vec<Block> = locals
                    .into_iter()
                    .map(|li| {
                        let v = self.owned[li];
                        Block::singleton(v, deps.workload(v))
                    })
                    .collect();
                self.last_cost = SchedCost { candidates: blocks.len(), dep_checks: 0 };
                return blocks;
            }
        };

        // Step 2: ρ-constrained greedy selection, memoizing pair
        // strengths (hot pairs recur across rounds — identical values
        // either way, so memoization never changes the selection).
        let rho = self.cfg.rho;
        let picked = if deps.supports_pair() {
            if self.memo.len() > MEMO_CAP {
                self.memo.clear();
            }
            let memo = &mut self.memo;
            let mut checks = 0usize;
            let picked = select_independent_lazy(
                &cands,
                |a, b| {
                    checks += 1;
                    let key = (a.min(b) as u32, a.max(b) as u32);
                    match memo.get(&key) {
                        Some(&v) => v,
                        None => {
                            let v = deps.dep_pair(a, b);
                            memo.insert(key, v);
                            v
                        }
                    }
                },
                rho,
                limit,
            );
            self.last_cost = SchedCost { candidates: cands.len(), dep_checks: checks };
            picked
        } else {
            let dep = deps.dep_matrix(&cands);
            let picked = select_independent(&cands, &dep, rho, limit);
            self.last_cost = SchedCost {
                candidates: cands.len(),
                dep_checks: cands.len() * picked.len().max(1),
            };
            picked
        };

        // Step 3: load-balanced merge down to <= p worker blocks.
        let blocks: Vec<Block> = picked
            .iter()
            .map(|&ci| {
                let v = cands[ci];
                Block::singleton(v, deps.workload(v))
            })
            .collect();
        merge_balanced(blocks, p)
    }
}

/// The full shard-planner set with round-robin rotation — the serial
/// execution shape (engine path). The threaded service consumes the
/// same planners via [`PlannerSet::into_parts`].
pub struct PlannerSet {
    planners: Vec<ShardPlanner>,
    /// Global variable id -> (shard, local index), shared with the
    /// service's shard threads for progress routing.
    owner: Arc<Vec<(u32, u32)>>,
    turn: usize,
}

impl PlannerSet {
    /// Build `shards` planners over `num_vars` variables (random fixed
    /// ownership, per-shard RNG streams forked from `seed` in shard
    /// order — construction is a pure function of its arguments).
    pub fn new(
        num_vars: usize,
        shards: usize,
        kind: SchedKind,
        pkind: PriorityKind,
        sap: &SapConfig,
        seed: u64,
    ) -> Self {
        let mut master = Rng::new(seed);
        let (owned_lists, owner) = partition_owned(num_vars, shards, &mut master);
        let planners = owned_lists
            .into_iter()
            .enumerate()
            .map(|(si, owned)| {
                let rng = master.fork(si as u64);
                let policy = match kind {
                    SchedKind::Dynamic => PlanPolicy::Dynamic(PriorityDist::new(
                        owned.len(),
                        sap.eta,
                        sap.init_priority,
                        pkind,
                    )),
                    SchedKind::Static => PlanPolicy::Static,
                    SchedKind::Random => PlanPolicy::Random,
                };
                ShardPlanner {
                    index: si,
                    owned,
                    policy,
                    rng,
                    cfg: sap.clone(),
                    memo: FastHashMap::default(),
                    last_cost: SchedCost::default(),
                }
            })
            .collect();
        PlannerSet { planners, owner: Arc::new(owner), turn: 0 }
    }

    pub fn num_shards(&self) -> usize {
        self.planners.len()
    }

    /// Split into the per-thread planners + the shared ownership table
    /// (the service's construction path).
    pub fn into_parts(self) -> (Vec<ShardPlanner>, Arc<Vec<(u32, u32)>>) {
        (self.planners, self.owner)
    }

    /// Plan the next round: the shard whose turn it is plans; the
    /// rotation advances.
    pub fn plan_turn(&mut self, deps: &mut dyn PlanDeps, p: usize) -> Vec<Block> {
        let si = self.turn;
        self.turn = (self.turn + 1) % self.planners.len();
        self.planners[si].plan(deps, p)
    }

    /// SAP step 4: route measured progress to the owning shards.
    pub fn observe(&mut self, result: &RoundResult) {
        for &(v, d) in &result.deltas {
            let (si, li) = self.owner[v];
            self.planners[si as usize].report_local(li as usize, d);
        }
    }

    /// Scheduling cost of the most recent plan (the shard that planned
    /// last — the rotation means exactly one shard worked per round).
    pub fn last_cost(&self) -> SchedCost {
        let prev = (self.turn + self.planners.len() - 1) % self.planners.len();
        self.planners[prev].last_cost()
    }

    /// Fraction of all variables updated at least once.
    pub fn coverage(&self) -> f64 {
        let total: usize = self.planners.iter().map(|s| s.owned.len()).sum();
        if total == 0 {
            return 1.0;
        }
        let covered: f64 =
            self.planners.iter().map(|s| s.coverage() * s.owned.len() as f64).sum();
        covered / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Oracle over a 1-d chain: adjacent variables conflict.
    struct ChainOracle {
        n: usize,
    }

    impl SchedOracle for ChainOracle {
        fn num_vars(&self) -> usize {
            self.n
        }
        fn dependency_pair(&self, a: usize, b: usize) -> f64 {
            if a.abs_diff(b) == 1 {
                1.0
            } else {
                0.0
            }
        }
    }

    fn mk(num_vars: usize, s: usize, kind: SchedKind, seed: u64) -> PlannerSet {
        PlannerSet::new(num_vars, s, kind, PriorityKind::Linear, &SapConfig::default(), seed)
    }

    #[test]
    fn ownership_is_a_partition() {
        let set = mk(103, 4, SchedKind::Dynamic, 9);
        let mut all: Vec<usize> =
            set.planners.iter().flat_map(|p| p.owned.clone()).collect();
        all.sort();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        let sizes: Vec<usize> = set.planners.iter().map(|p| p.owned.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn more_shards_than_vars_clamps() {
        let set = mk(3, 10, SchedKind::Dynamic, 9);
        assert_eq!(set.num_shards(), 3);
    }

    #[test]
    fn rotation_planning_is_deterministic() {
        // Same seed + shard count => identical plan streams, regardless
        // of interleaved observations being identical too.
        let oracle = ChainOracle { n: 200 };
        let mut a = mk(200, 3, SchedKind::Dynamic, 5);
        let mut b = mk(200, 3, SchedKind::Dynamic, 5);
        for round in 0..12 {
            let pa = a.plan_turn(&mut OracleDeps(&oracle), 4);
            let pb = b.plan_turn(&mut OracleDeps(&oracle), 4);
            assert_eq!(pa, pb, "round {round} diverged");
            let deltas: Vec<(usize, f64)> =
                pa.iter().flat_map(|blk| blk.vars.iter().map(|&v| (v, 0.1))).collect();
            let result = RoundResult { deltas, ..Default::default() };
            a.observe(&result);
            b.observe(&result);
        }
    }

    #[test]
    fn plans_respect_rho_on_every_policy_with_depcheck() {
        let oracle = ChainOracle { n: 300 };
        for kind in [SchedKind::Dynamic, SchedKind::Static] {
            let mut set = mk(300, 2, kind, 7);
            for _ in 0..10 {
                let blocks = set.plan_turn(&mut OracleDeps(&oracle), 8);
                let vars: Vec<usize> =
                    blocks.iter().flat_map(|b| b.vars.clone()).collect();
                for (i, &x) in vars.iter().enumerate() {
                    for &y in &vars[i + 1..] {
                        assert!(x.abs_diff(y) != 1, "{kind:?} co-scheduled {x},{y}");
                    }
                }
            }
        }
    }

    #[test]
    fn observe_routes_to_owner_and_reprioritizes() {
        let oracle = ChainOracle { n: 64 };
        let sap = SapConfig { shards: 1, init_priority: 1e-6, ..SapConfig::default() };
        let mut set =
            PlannerSet::new(64, 1, SchedKind::Dynamic, PriorityKind::Linear, &sap, 5);
        set.observe(&RoundResult {
            deltas: (0..64).map(|v| (v, if v == 10 { 100.0 } else { 1e-9 })).collect(),
            ..Default::default()
        });
        let mut hits = 0;
        for _ in 0..50 {
            let blocks = set.plan_turn(&mut OracleDeps(&oracle), 1);
            if blocks.iter().any(|b| b.vars.contains(&10)) {
                hits += 1;
            }
        }
        assert!(hits > 45, "hits {hits}");
    }

    #[test]
    fn coverage_aggregates_across_shards() {
        let mut set = mk(40, 4, SchedKind::Dynamic, 9);
        assert_eq!(set.coverage(), 0.0);
        let result = RoundResult {
            deltas: (0..20).map(|v| (v, 0.1)).collect(),
            ..Default::default()
        };
        set.observe(&result);
        assert!((set.coverage() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn random_policy_never_dep_checks_and_fills_workers() {
        let oracle = ChainOracle { n: 100 };
        let mut set = mk(100, 1, SchedKind::Random, 4);
        let blocks = set.plan_turn(&mut OracleDeps(&oracle), 16);
        assert_eq!(blocks.len(), 16);
        assert_eq!(set.last_cost().dep_checks, 0);
        let vars: Vec<usize> = blocks.iter().flat_map(|b| b.vars.clone()).collect();
        let distinct: std::collections::HashSet<_> = vars.iter().collect();
        assert_eq!(distinct.len(), vars.len());
    }
}
