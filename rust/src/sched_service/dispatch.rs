//! Measured, least-loaded worker dispatch (the dynamic load-balancing
//! half of the scheduler service).
//!
//! The old distributed path assigned block `i` of every round to worker
//! `i % p` — blind to both block workloads and worker speed, so one
//! slow core (or one heavy block landing on an already-busy worker)
//! stalls the round. [`Dispatcher`] keeps, per worker, an EWMA of the
//! *measured* seconds-per-work-unit (from worker-reported compute
//! times) and the expected seconds of work already queued, and sends
//! each block to the worker with the earliest expected completion.
//! Assignment only moves timing, never results: deltas are reassembled
//! in block order regardless of which worker computed them, so the
//! staleness-0 path stays bit-exact under any dispatch policy.

/// EWMA weight given to each new service-rate measurement.
const RATE_ALPHA: f64 = 0.3;

/// Least-loaded worker assignment over measured service rates.
///
/// Membership is dynamic: workers can be removed mid-round (supervisor
/// declared them dead or a `Leave` fired) and added mid-round (an
/// elastic `Join`). Slots are never reindexed — `active` flips instead
/// — so worker ids stay stable for the clock table, and a fixed fleet
/// walks exactly the pre-elastic pick order (the `active` filter is a
/// no-op when nobody ever leaves).
pub struct Dispatcher {
    /// Expected seconds of dispatched-but-unfinished work per worker.
    backlog: Vec<f64>,
    /// EWMA seconds per work unit per worker (seeded from the cost
    /// model's calibrated rate until real measurements arrive).
    rate: Vec<f64>,
    /// Dispatch eligibility per slot; removed workers stay indexed but
    /// are never picked again.
    active: Vec<bool>,
    /// The rate new joiners start from (the same calibrated seed the
    /// founding fleet got — a joiner has no history yet).
    seed_rate: f64,
}

impl Dispatcher {
    pub fn new(workers: usize, default_sec_per_unit: f64) -> Self {
        let seed_rate = if default_sec_per_unit > 0.0 { default_sec_per_unit } else { 1e-6 };
        Dispatcher {
            backlog: vec![0.0; workers],
            rate: vec![seed_rate; workers],
            active: vec![true; workers],
            seed_rate,
        }
    }

    /// Pick the active worker with the earliest expected completion for
    /// a block of `work` units; charge its backlog. Returns the worker
    /// and the charged estimate (echoed back at completion so the
    /// backlog can be released exactly), or `None` when no worker is
    /// active. Ties break to the lowest index, so dispatch is
    /// deterministic given the same history and membership.
    pub fn pick(&mut self, work: u64) -> Option<(usize, f64)> {
        self.pick_filtered(work, None)
    }

    /// [`Self::pick`], excluding one worker — the reassignment path: a
    /// block whose lease expired must go to a *different* worker than
    /// its (possibly wedged, possibly dead) current holder. `None` when
    /// nobody else is active.
    pub fn pick_excluding(&mut self, work: u64, excluded: usize) -> Option<(usize, f64)> {
        self.pick_filtered(work, Some(excluded))
    }

    fn pick_filtered(&mut self, work: u64, excluded: Option<usize>) -> Option<(usize, f64)> {
        let mut best = None;
        let mut best_t = f64::INFINITY;
        for w in 0..self.backlog.len() {
            if !self.active[w] || Some(w) == excluded {
                continue;
            }
            let t = self.backlog[w] + work as f64 * self.rate[w];
            if t < best_t {
                best_t = t;
                best = Some(w);
            }
        }
        let best = best?;
        let est = work as f64 * self.rate[best];
        self.backlog[best] += est;
        Some((best, est))
    }

    /// A block completed on `worker`: release its backlog charge and
    /// fold the measured compute seconds into the worker's rate.
    pub fn complete(&mut self, worker: usize, work: u64, est_sec: f64, measured_sec: f64) {
        self.backlog[worker] = (self.backlog[worker] - est_sec).max(0.0);
        if work > 0 && measured_sec >= 0.0 {
            let obs = measured_sec / work as f64;
            self.rate[worker] = (1.0 - RATE_ALPHA) * self.rate[worker] + RATE_ALPHA * obs;
        }
    }

    /// Remove `worker` from the pool (death or `Leave`) and zero its
    /// backlog — its in-flight blocks are being reassigned, so keeping
    /// the charge would haunt nobody. Idempotent; ids are not reused.
    pub fn remove_worker(&mut self, worker: usize) {
        if let Some(a) = self.active.get_mut(worker) {
            *a = false;
            self.backlog[worker] = 0.0;
        }
    }

    /// Admit `worker` to the pool mid-run, growing the slot table if
    /// this is a brand-new id. A joiner starts at the calibrated seed
    /// rate with an empty backlog — least-loaded dispatch then feeds
    /// it immediately. Idempotent for already-active ids.
    pub fn add_worker(&mut self, worker: usize) {
        if worker >= self.backlog.len() {
            self.backlog.resize(worker + 1, 0.0);
            self.rate.resize(worker + 1, self.seed_rate);
            self.active.resize(worker + 1, false);
        }
        self.active[worker] = true;
        self.backlog[worker] = 0.0;
    }

    /// Whether `worker` is currently dispatchable.
    pub fn is_active(&self, worker: usize) -> bool {
        self.active.get(worker).copied().unwrap_or(false)
    }

    /// Number of currently dispatchable workers.
    pub fn active_workers(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Current measured seconds-per-unit estimates (diagnostics).
    pub fn rates(&self) -> &[f64] {
        &self.rate
    }
}

/// Measured straggler ratio of one round: max per-worker busy seconds
/// over the mean, across the workers that actually computed blocks
/// this round (1.0 = perfectly level, same convention as the planned
/// [`crate::coordinator::balance::imbalance`]).
pub fn measured_imbalance(samples: &[(usize, f64)]) -> f64 {
    if samples.is_empty() {
        return 1.0;
    }
    let mut busy = std::collections::BTreeMap::<usize, f64>::new();
    for &(w, sec) in samples {
        *busy.entry(w).or_insert(0.0) += sec;
    }
    let max = busy.values().cloned().fold(0.0f64, f64::max);
    let mean = busy.values().sum::<f64>() / busy.len() as f64;
    if mean <= 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_workers_round_robin_under_uniform_blocks() {
        // With identical rates and equal work, least-loaded + lowest-
        // index tie-break walks the workers in order.
        let mut d = Dispatcher::new(4, 1.0);
        let picks: Vec<usize> = (0..8).map(|_| d.pick(1).unwrap().0).collect();
        assert_eq!(picks, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn removed_workers_are_never_picked_and_joiners_absorb_load() {
        let mut d = Dispatcher::new(3, 1.0);
        d.remove_worker(1);
        assert!(!d.is_active(1));
        assert_eq!(d.active_workers(), 2);
        let picks: Vec<usize> = (0..6).map(|_| d.pick(1).unwrap().0).collect();
        assert_eq!(picks, vec![0, 2, 0, 2, 0, 2], "slot 1 must be skipped, ids stable");
        // A joiner gets a brand-new slot at the seed rate with zero
        // backlog, so least-loaded dispatch feeds it first.
        d.add_worker(3);
        assert_eq!(d.active_workers(), 3);
        assert_eq!(d.pick(1).unwrap().0, 3, "empty-backlog joiner wins the next pick");
        // Removal is idempotent and terminal until re-added.
        d.remove_worker(1);
        d.remove_worker(0);
        d.remove_worker(2);
        d.remove_worker(3);
        assert_eq!(d.active_workers(), 0);
        assert!(d.pick(1).is_none(), "an empty pool picks nobody");
        d.add_worker(2);
        assert_eq!(d.pick(1).unwrap().0, 2);
    }

    #[test]
    fn pick_excluding_skips_the_current_holder() {
        let mut d = Dispatcher::new(2, 1.0);
        // Worker 0 is idle and would normally win; excluded, the block
        // must go to worker 1.
        assert_eq!(d.pick_excluding(1, 0).unwrap().0, 1);
        // With only the excluded worker active, there is no candidate.
        d.remove_worker(1);
        assert!(d.pick_excluding(1, 0).is_none());
        assert!(d.pick(1).is_some(), "unfiltered pick still sees worker 0");
    }

    #[test]
    fn slow_worker_gets_less_work() {
        let mut d = Dispatcher::new(2, 1e-3);
        // Worker 0 measures 10x slower than worker 1.
        for _ in 0..20 {
            d.complete(0, 1, 0.0, 10e-3);
            d.complete(1, 1, 0.0, 1e-3);
        }
        let mut counts = [0usize; 2];
        for _ in 0..22 {
            let (w, est) = d.pick(1).unwrap();
            counts[w] += 1;
            // complete immediately so backlog reflects rate only
            d.complete(w, 1, est, if w == 0 { 10e-3 } else { 1e-3 });
        }
        assert!(
            counts[1] > counts[0] * 3,
            "fast worker must absorb most blocks: {counts:?}"
        );
    }

    #[test]
    fn backlog_releases_exactly() {
        let mut d = Dispatcher::new(1, 1.0);
        let (w, est) = d.pick(5).unwrap();
        assert_eq!(w, 0);
        assert!(est > 0.0);
        d.complete(0, 5, est, 5.0);
        // backlog fully released (clamped at zero regardless)
        let (_, est2) = d.pick(1).unwrap();
        assert!(est2 > 0.0);
    }

    #[test]
    fn heavy_block_avoids_loaded_worker() {
        let mut d = Dispatcher::new(2, 1.0);
        let (w0, _) = d.pick(100).unwrap(); // loads worker 0
        assert_eq!(w0, 0);
        let (w1, _) = d.pick(100).unwrap();
        assert_eq!(w1, 1, "second heavy block must go to the idle worker");
    }

    #[test]
    fn measured_imbalance_math() {
        assert_eq!(measured_imbalance(&[]), 1.0);
        assert_eq!(measured_imbalance(&[(0, 2.0), (1, 2.0)]), 1.0);
        // worker 0 busy 3s, worker 1 busy 1s -> max/mean = 3/2
        let v = measured_imbalance(&[(0, 1.0), (0, 2.0), (1, 1.0)]);
        assert!((v - 1.5).abs() < 1e-12);
        // all-zero measurements degrade to 1.0, not NaN
        assert_eq!(measured_imbalance(&[(0, 0.0), (1, 0.0)]), 1.0);
    }
}
