//! The scheduler service: sharded, pipelined SAP planning off the
//! coordinator's critical path (paper §3; Lee et al. 2014's
//! "scheduler threads" primitive).
//!
//! [`planner`] holds the shared planning core — per-shard planners
//! over the fixed ownership partition, used synchronously by the
//! engine-path schedulers. [`SchedService`] runs the *same* planners
//! on S dedicated threads: each shard plans its rounds (round-robin,
//! shard s owns rounds r with r mod S = s) into a bounded per-shard
//! plan queue, consuming round progress reports ([`crate::problem::RoundResult`]
//! deltas) asynchronously from an observation channel. The coordinator
//! pops the next round's plan (measuring `sched_wait`, the time it
//! actually blocked) and broadcasts each applied round's deltas back.
//!
//! **Observation contract.** A shard may plan its round `r` only after
//! folding observations through round `r − 1 − lookahead`. At
//! `lookahead = 0` (staleness 0) that is *all* observations through
//! `r − 1` — exactly the serial rotation — so the lock-step
//! distributed path stays bit-exact with the engine path (plans are a
//! pure function of seed + observation prefix; pinned by test). With a
//! staleness bound the lookahead equals the dispatch window, so shards
//! plan ahead while workers compute and the queue, not the planner, is
//! what the coordinator touches per round.
//!
//! [`dispatch`] is the worker-assignment side: measured per-worker
//! service rates feed a least-loaded dispatcher replacing the old
//! `block_idx % p` round-robin.

pub mod dispatch;
pub mod planner;

pub use dispatch::{measured_imbalance, Dispatcher};
pub use planner::{OracleDeps, PlanDeps, PlannerSet, ProblemDeps, SchedOracle, ShardPlanner};

use crate::config::SapConfig;
use crate::coordinator::priority::PriorityKind;
use crate::problem::Block;
use crate::schedulers::SchedKind;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Progress report broadcast to every shard thread: one applied
/// round's (variable, |δ|) deltas, shared rather than copied.
type ObsMsg = Arc<Vec<(usize, f64)>>;

/// The running scheduler service: S shard threads planning ahead into
/// bounded queues. Dropping the service shuts the threads down.
pub struct SchedService {
    shards: usize,
    plan_rxs: Vec<mpsc::Receiver<Vec<Block>>>,
    obs_txs: Vec<mpsc::Sender<ObsMsg>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Next round index to pop (service-local numbering: only rounds
    /// the service plans — problem-planned rounds never enter it).
    next: u64,
    /// Plans produced minus plans popped, across all shard queues.
    queued: Arc<AtomicI64>,
    wait_total: f64,
    depth_sum: f64,
    depth_samples: u64,
}

impl SchedService {
    /// Spawn `shards` shard-planner threads over `oracle`'s variable
    /// space. `p` is the worker count plans are sized for;
    /// `lookahead` is the observation slack (0 = lock-step, see module
    /// docs); `depth` bounds each shard's plan queue (≥ 1).
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        oracle: Arc<dyn SchedOracle>,
        kind: SchedKind,
        pkind: PriorityKind,
        sap: &SapConfig,
        seed: u64,
        shards: usize,
        p: usize,
        lookahead: u64,
        depth: usize,
    ) -> Self {
        let set = PlannerSet::new(oracle.num_vars(), shards, kind, pkind, sap, seed);
        let (planners, owner) = set.into_parts();
        let s = planners.len();
        let depth = depth.max(1);
        let queued = Arc::new(AtomicI64::new(0));
        let mut plan_rxs = Vec::with_capacity(s);
        let mut obs_txs = Vec::with_capacity(s);
        let mut handles = Vec::with_capacity(s);
        for mut planner in planners {
            let si = planner.index() as u64;
            let (plan_tx, plan_rx) = mpsc::sync_channel::<Vec<Block>>(depth);
            let (obs_tx, obs_rx) = mpsc::channel::<ObsMsg>();
            plan_rxs.push(plan_rx);
            obs_txs.push(obs_tx);
            let oracle = Arc::clone(&oracle);
            let owner = Arc::clone(&owner);
            let queued = Arc::clone(&queued);
            handles.push(std::thread::spawn(move || {
                let mut folded: u64 = 0; // observation rounds folded
                let mut round = si; // rounds this shard plans: si, si+S, ...
                loop {
                    // Gate: round r needs observations through
                    // r - 1 - lookahead folded (see module docs).
                    while folded < round.saturating_sub(lookahead) {
                        match obs_rx.recv() {
                            Ok(deltas) => {
                                planner.absorb(&owner, &deltas);
                                folded += 1;
                            }
                            Err(_) => return, // coordinator gone
                        }
                    }
                    // Freshness: fold anything else already delivered
                    // before planning (never blocks; at lookahead 0
                    // nothing newer can exist, so this keeps the
                    // lock-step path deterministic).
                    while let Ok(deltas) = obs_rx.try_recv() {
                        planner.absorb(&owner, &deltas);
                        folded += 1;
                    }
                    let blocks = planner.plan(&mut OracleDeps(&*oracle), p);
                    queued.fetch_add(1, Ordering::Relaxed);
                    if plan_tx.send(blocks).is_err() {
                        return; // coordinator gone
                    }
                    round += s as u64;
                }
            }));
        }
        SchedService {
            shards: s,
            plan_rxs,
            obs_txs,
            handles,
            next: 0,
            queued,
            wait_total: 0.0,
            depth_sum: 0.0,
            depth_samples: 0,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards
    }

    /// Pop the next round's plan (blocking — the round-robin rotation
    /// fixes which shard it comes from). Returns the plan and the
    /// seconds this call actually blocked (`sched_wait`).
    pub fn pop_plan(&mut self) -> anyhow::Result<(Vec<Block>, f64)> {
        let si = (self.next % self.shards as u64) as usize;
        let t = Instant::now();
        let blocks = self.plan_rxs[si]
            .recv()
            .map_err(|_| anyhow::anyhow!("scheduler shard {si} thread died"))?;
        let wait = t.elapsed().as_secs_f64();
        self.next += 1;
        let depth = self.queued.fetch_sub(1, Ordering::Relaxed) - 1;
        self.depth_sum += depth.max(0) as f64;
        self.depth_samples += 1;
        self.wait_total += wait;
        Ok((blocks, wait))
    }

    /// Broadcast one applied round's progress deltas to every shard.
    pub fn observe(&mut self, deltas: ObsMsg) {
        for tx in &self.obs_txs {
            // A dead shard thread surfaces on the next pop; ignore here.
            let _ = tx.send(Arc::clone(&deltas));
        }
    }

    /// Total coordinator seconds spent blocked waiting for plans.
    pub fn sched_wait_total(&self) -> f64 {
        self.wait_total
    }

    /// Mean plan-queue depth observed across pops (how far ahead the
    /// shards were, in plans, each time the coordinator came asking).
    pub fn mean_queue_depth(&self) -> f64 {
        if self.depth_samples == 0 {
            0.0
        } else {
            self.depth_sum / self.depth_samples as f64
        }
    }
}

impl Drop for SchedService {
    fn drop(&mut self) {
        // Closing both channel sides unblocks every shard thread state
        // (gate recv errors; full-queue send errors), then join.
        self.plan_rxs.clear();
        self.obs_txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::RoundResult;

    struct ChainOracle {
        n: usize,
    }

    impl SchedOracle for ChainOracle {
        fn num_vars(&self) -> usize {
            self.n
        }
        fn dependency_pair(&self, a: usize, b: usize) -> f64 {
            if a.abs_diff(b) == 1 {
                1.0
            } else {
                0.0
            }
        }
    }

    fn deltas_for(blocks: &[Block]) -> Vec<(usize, f64)> {
        blocks.iter().flat_map(|b| b.vars.iter().map(|&v| (v, 0.1))).collect()
    }

    #[test]
    fn lockstep_service_matches_serial_rotation() {
        // lookahead 0: the threaded service must reproduce the serial
        // PlannerSet rotation plan-for-plan (the bit-exactness core).
        let oracle = Arc::new(ChainOracle { n: 150 });
        let sap = SapConfig::default();
        let mut svc = SchedService::spawn(
            Arc::clone(&oracle) as Arc<dyn SchedOracle>,
            SchedKind::Dynamic,
            PriorityKind::Linear,
            &sap,
            11,
            3,
            4,
            0,
            2,
        );
        let mut serial = PlannerSet::new(150, 3, SchedKind::Dynamic, PriorityKind::Linear, &sap, 11);
        for round in 0..15 {
            let (svc_plan, _wait) = svc.pop_plan().unwrap();
            let serial_plan = serial.plan_turn(&mut OracleDeps(&*oracle), 4);
            assert_eq!(svc_plan, serial_plan, "round {round} diverged");
            let deltas = Arc::new(deltas_for(&svc_plan));
            svc.observe(Arc::clone(&deltas));
            serial.observe(&RoundResult {
                deltas: (*deltas).clone(),
                ..Default::default()
            });
        }
        assert!(svc.sched_wait_total() >= 0.0);
    }

    #[test]
    fn pipelined_service_plans_ahead() {
        // With slack, shards fill their queues without observations.
        let oracle = Arc::new(ChainOracle { n: 100 });
        let mut svc = SchedService::spawn(
            oracle,
            SchedKind::Dynamic,
            PriorityKind::Linear,
            &SapConfig::default(),
            3,
            2,
            4,
            u64::MAX,
            2,
        );
        // Give the shard threads a moment to prime the queues, then
        // pop a full wave without ever observing.
        for _ in 0..8 {
            let (plan, _) = svc.pop_plan().unwrap();
            assert!(!plan.is_empty());
        }
        assert!(svc.mean_queue_depth() >= 0.0);
    }

    #[test]
    fn drop_shuts_down_blocked_threads() {
        let oracle = Arc::new(ChainOracle { n: 50 });
        let svc = SchedService::spawn(
            oracle,
            SchedKind::Dynamic,
            PriorityKind::Linear,
            &SapConfig::default(),
            5,
            2,
            2,
            0,
            1,
        );
        // Shard 0 has planned round 0 (gate 0 ≤ 0) and may be blocked
        // sending round 2; shard 1 is gated on observations. Drop must
        // unblock and join all of them without hanging.
        drop(svc);
    }
}
