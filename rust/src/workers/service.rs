//! Coordinator + worker threads over mpsc channels.
//!
//! (The vendored offline crate set has no async runtime; OS threads +
//! channels give the same message-passing architecture — and the paper's
//! own implementation was likewise thread-per-worker over 0MQ sockets.)

use crate::config::RunConfig;
use crate::data::lasso_synth::LassoData;
use crate::lasso::NativeLasso;
use crate::linalg::DenseMatrix;
use crate::metrics::{Trace, TracePoint};
use crate::problem::ModelProblem;
use crate::schedulers::{DynamicScheduler, Scheduler};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// Work shipped to one worker for one round.
struct WorkItem {
    round: usize,
    /// (coordinate, current beta_j) pairs to propose updates for.
    coords: Vec<(usize, f64)>,
    /// The stale residual replica this worker computes against.
    r_snapshot: Arc<Vec<f32>>,
}

/// A worker's reply: proposed new beta values.
struct WorkerReply {
    round: usize,
    proposals: Vec<(usize, f64)>,
}

/// Summary of a distributed run.
#[derive(Debug, Clone)]
pub struct DistributedReport {
    pub trace: Trace,
    pub rounds: usize,
    pub proposals_processed: usize,
}

/// Run `rounds` SAP rounds of parallel Lasso on `p` real worker
/// threads. Wall-clock, not virtual time (this is the architecture demo
/// / correctness path; the core-count sweeps use the simulator).
pub fn run_distributed(
    data: &LassoData,
    cfg: &RunConfig,
    rounds: usize,
) -> anyhow::Result<DistributedReport> {
    let p = cfg.workers;
    let x: Arc<DenseMatrix> = Arc::new(data.x.clone());
    let lambda = cfg.lambda;

    // Worker threads: private work channel in, shared reply channel out.
    let (reply_tx, reply_rx) = mpsc::channel::<WorkerReply>();
    let mut work_txs = Vec::with_capacity(p);
    let mut handles = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        work_txs.push(tx);
        let reply_tx = reply_tx.clone();
        let x = Arc::clone(&x);
        handles.push(std::thread::spawn(move || {
            while let Ok(item) = rx.recv() {
                let proposals = item
                    .coords
                    .iter()
                    .map(|&(j, beta_j)| {
                        (j, NativeLasso::propose_from(&x, &item.r_snapshot, j, beta_j, lambda))
                    })
                    .collect();
                if reply_tx.send(WorkerReply { round: item.round, proposals }).is_err() {
                    break;
                }
            }
        }));
    }
    drop(reply_tx);

    // Coordinator: canonical state + sharded SAP scheduler.
    let mut problem = NativeLasso::new(data, lambda);
    let mut scheduler = DynamicScheduler::new(problem.num_vars(), &cfg.sap, cfg.engine.seed);
    let mut trace = Trace::new("distributed", "lasso", p);
    let wall = Instant::now();
    let mut proposals_processed = 0usize;
    let mut rounds_done = 0usize;

    for round in 0..rounds {
        let blocks = scheduler.plan(&mut problem, p);
        if blocks.is_empty() {
            break;
        }
        rounds_done = round + 1;
        let snapshot = Arc::new(problem.residual().to_vec());
        let mut outstanding = 0usize;
        for (widx, block) in blocks.iter().enumerate() {
            let coords: Vec<(usize, f64)> =
                block.vars.iter().map(|&j| (j, problem.beta()[j])).collect();
            work_txs[widx % p]
                .send(WorkItem { round, coords, r_snapshot: Arc::clone(&snapshot) })
                .map_err(|_| anyhow::anyhow!("worker channel closed"))?;
            outstanding += 1;
        }
        // Barrier: collect every worker's proposals for this round.
        let mut proposals = Vec::new();
        while outstanding > 0 {
            let reply = reply_rx.recv().map_err(|_| anyhow::anyhow!("workers hung up"))?;
            debug_assert_eq!(reply.round, round);
            proposals.extend(reply.proposals);
            outstanding -= 1;
        }
        proposals_processed += proposals.len();
        let result = problem.apply_proposals(&proposals);
        scheduler.observe(&result);

        if round % cfg.engine.record_every == 0 {
            trace.push(TracePoint {
                round,
                vtime: wall.elapsed().as_secs_f64(),
                wtime: wall.elapsed().as_secs_f64(),
                objective: result.objective.unwrap_or_else(|| problem.objective()),
                active_vars: problem.active_vars(),
                imbalance: 1.0,
            });
        }
    }

    // Final exact objective, then shut workers down.
    let obj = problem.objective();
    trace.push(TracePoint {
        round: rounds_done,
        vtime: wall.elapsed().as_secs_f64(),
        wtime: wall.elapsed().as_secs_f64(),
        objective: obj,
        active_vars: problem.active_vars(),
        imbalance: 1.0,
    });
    drop(work_txs);
    for h in handles {
        let _ = h.join();
    }
    Ok(DistributedReport { trace, rounds: rounds_done, proposals_processed })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::lasso_synth::{generate, LassoSynthSpec};

    #[test]
    fn distributed_run_converges_like_local() {
        let data = generate(&LassoSynthSpec::tiny(), 21);
        let mut cfg = RunConfig { workers: 4, lambda: 1e-3, ..Default::default() };
        cfg.sap.shards = 2;
        let report = run_distributed(&data, &cfg, 300).unwrap();
        let first = report.trace.points.first().unwrap().objective;
        let last = report.trace.final_objective();
        assert!(last < first * 0.8, "first {first} last {last}");
        assert!(report.proposals_processed > 0);
    }

    #[test]
    fn distributed_matches_engine_semantics() {
        // Same seed, same scheduler config, 1 worker: the distributed
        // path must produce the same final objective as the local
        // engine (proposals computed against the same snapshots).
        let data = generate(&LassoSynthSpec::tiny(), 22);
        let mut cfg = RunConfig { workers: 1, lambda: 1e-3, ..Default::default() };
        cfg.sap.shards = 1;
        let report = run_distributed(&data, &cfg, 50).unwrap();

        let mut problem = NativeLasso::new(&data, cfg.lambda);
        let mut sched = DynamicScheduler::new(problem.num_vars(), &cfg.sap, cfg.engine.seed);
        for _ in 0..50 {
            let blocks = sched.plan(&mut problem, 1);
            if blocks.is_empty() {
                break;
            }
            let res = problem.update_blocks(&blocks);
            sched.observe(&res);
        }
        let local_obj = problem.objective();
        let dist_obj = report.trace.final_objective();
        assert!(
            (local_obj - dist_obj).abs() < 1e-6 * local_obj.abs().max(1.0),
            "local {local_obj} dist {dist_obj}"
        );
    }

    #[test]
    fn many_workers_few_blocks_is_safe() {
        let data = generate(&LassoSynthSpec::tiny(), 23);
        let cfg = RunConfig { workers: 16, lambda: 1e-2, ..Default::default() };
        let report = run_distributed(&data, &cfg, 20).unwrap();
        assert!(report.rounds > 0);
    }
}
