//! The distributed execution loop: a coordinator thread driving any
//! [`ModelProblem`] over real worker threads through the sharded
//! parameter server (`ps::`) and the sharded pipelined scheduler
//! service (`sched_service::`). All parameter-server traffic routes
//! through the run's configured transport (`[ps] transport`, see
//! `ps::transport`): in-process shared memory by default, or TCP to a
//! `strads ps-server` process — the loop below is identical either
//! way, and `DistributedReport::socket_bytes` records the real bytes a
//! networked run moved next to the modeled `net_bytes` meter.
//!
//! Per round the coordinator obtains a plan — the problem's own round
//! structure if it has one, otherwise the configured scheduler
//! (`sched.scheduler`, routed through
//! [`crate::schedulers::SchedKind`]): by default the
//! [`SchedService`]'s shard threads, which plan rounds *ahead* into
//! bounded queues concurrently with worker execution, consuming round
//! progress asynchronously; problems without a thread-shareable
//! [`crate::sched_service::SchedOracle`] (or `sched.service = 0`) fall
//! back to inline planning on the coordinator thread. Either way the
//! time the coordinator actually spends blocked on (or computing) a
//! plan is measured per round as `sched_wait`; the trace's `vtime`
//! excludes it, so compute and scheduling stalls are separable.
//!
//! Blocks are dispatched by measured load ([`Dispatcher`]): each
//! worker's service rate is estimated from its reported per-block
//! compute seconds, and every block goes to the worker with the
//! earliest expected completion (replacing the old `block_idx % p`
//! round-robin). Each worker, per block: SSP-gated `pull` of the spec
//! its kernel needs (contiguous ranges arrive as zero-copy `Arc` views
//! of dense-segment f32 epochs — an O(1) clone, no allocation),
//! `propose` deltas against that (possibly stale) snapshot, `push`
//! them into its coalescing batch, and `flush_clock` — which applies
//! the batch to the server shards and forwards it (plus the measured
//! compute seconds) to the coordinator. The coordinator applies
//! complete rounds in block order to the canonical model
//! (`apply_deltas`), broadcasts the round's progress deltas to the
//! scheduler shards (SAP step 4), republishes derived state
//! (tolerance-gated — see `ModelProblem::ps_republish` and
//! `ps.republish_tol`), and advances the applied clock that gates the
//! workers.
//!
//! Staleness discipline is **gate-driven**: the client-side SSP gate
//! (`ClockTable::wait_admit`) is the mechanism that bounds how stale a
//! pull can be, exactly as a networked deployment would rely on it.
//! With `ps.pipeline` set and `StalenessPolicy::Bounded(s > 0)`, the
//! coordinator dispatches a few rounds *beyond* the bound so worker
//! queues are always primed, and the scheduler shards plan with the
//! same observation slack — scheduling overlaps compute end to end.
//! `s = 0` keeps lock-step dispatch, and the service's observation
//! contract (plans for round `r` consume *all* observations through
//! round `r - 1`) makes the whole path reproduce the engine semantics
//! exactly: same plans, same snapshots, same apply order, same
//! arithmetic (pinned by test). `Async` removes the gate and pipelines
//! a fixed window of rounds. With `ps.pipeline = 0`, bounded runs fall
//! back to dispatch throttling at the bound (the pre-pipelining
//! behaviour, kept for A/B runs).

use crate::config::RunConfig;
use crate::coordinator::balance::imbalance;
use crate::coordinator::priority::PriorityKind;
use crate::metrics::{Trace, TracePoint};
use crate::obs::{Counter, EventSink, Histogram, MetricValue, Phase, Registry, SpanEvent};
use crate::problem::ModelProblem;
use crate::ps::{PsClient, PsConnection, PsKernel, StalenessPolicy};
use crate::sched_service::{
    measured_imbalance, Dispatcher, PlannerSet, ProblemDeps, SchedService,
};
use crate::util::Rng;
use crate::workers::supervisor::{KillPlan, Lease, LeaseTable, MembershipEvent};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Rounds kept in flight in fully-asynchronous mode.
const ASYNC_PIPELINE_DEPTH: u64 = 16;

/// Extra rounds dispatched beyond the staleness bound under gate-driven
/// pipelining: deep enough that the gate (not an empty queue) is what
/// paces workers, small enough to bound reassembly-buffer memory.
const GATE_PIPELINE_AHEAD: u64 = 4;

/// Full re-sync period for tolerance-gated republish: every this many
/// applied rounds the coordinator republishes the complete derived
/// state, bounding any drift the tolerance admitted.
const FULL_RESYNC_EVERY: u64 = 32;

/// `[ps] republish_tol = auto`: the tolerance is this fraction of the
/// RMS entry magnitude the objective implies — `sqrt(2*|obj|/n)`,
/// exact for a pure quadratic ½‖r‖² and a usable scale proxy
/// otherwise. 1e-7 sits just below f32's relative precision, so auto
/// suppresses only republishes the f32 wire could barely express
/// anyway. Until the first objective value exists the tolerance is a
/// lossless 0.0.
const AUTO_TOL_REL: f64 = 1e-7;

/// One block of one round, shipped to a worker.
struct WorkItem {
    round: u64,
    block_idx: usize,
    vars: Vec<usize>,
    /// Workload units (dispatch accounting, echoed back on flush).
    work: u64,
    /// The dispatcher's backlog charge for this block (echoed back).
    est_sec: f64,
    /// The worker this block was assigned to (echoed back).
    worker: usize,
}

/// A worker's flushed, coalesced delta batch for one block.
struct FlushMsg {
    round: u64,
    block_idx: usize,
    worker: usize,
    work: u64,
    est_sec: f64,
    /// Measured seconds from snapshot-in-hand to flush complete (gate
    /// wait excluded) — the dispatcher's service-rate signal and the
    /// measured-imbalance input.
    compute_sec: f64,
    deltas: Vec<(usize, f64)>,
    stale_gap: u64,
    /// Whether this block's pull had to block at the SSP gate (the
    /// per-round `gate_waits` trace column counts these).
    waited: bool,
    /// The server's verdict: whether this batch was applied to the
    /// store, or dropped by the flush ledger (another copy of the
    /// reassigned block won, or this worker was retired mid-flight).
    /// The coordinator folds only applied batches into the canonical
    /// model — the exactly-once contract.
    applied: bool,
}

/// What a worker thread reports back to the coordinator.
enum WorkerMsg {
    Flush(FlushMsg),
    /// The worker's transport failed mid-run, or its thread panicked (a
    /// real fault, not the clean end-of-run shutdown). Without this
    /// poison message a fixed-fleet coordinator would wait forever for
    /// a flush that can never come; an elastic one retires the worker
    /// and reassigns its leases.
    Failed { worker: usize, error: String },
}

/// Send-on-unwind guard: if a worker thread panics anywhere in its
/// loop, the coordinator still hears a `Failed` for it (in-proc panic
/// capture — the thread-exit analog of a dead TCP peer).
struct PanicSentinel {
    worker: usize,
    tx: mpsc::Sender<WorkerMsg>,
}

impl Drop for PanicSentinel {
    fn drop(&mut self) {
        if std::thread::panicking() {
            let _ = self.tx.send(WorkerMsg::Failed {
                worker: self.worker,
                error: "worker thread panicked".to_string(),
            });
        }
    }
}

/// Spawn one worker thread over its own transport link. Returns the
/// worker's private work-queue sender, the kill flag the elastic
/// supervisor raises for a deterministic coordinator-initiated death,
/// and the join handle. Used both for the initial fleet and for
/// mid-run joiners (`worker_kill_plan` `join=@R` events).
fn spawn_worker(
    worker: usize,
    mut client: PsClient,
    kernel: Arc<dyn PsKernel>,
    events: Option<Arc<EventSink>>,
    flush_tx: mpsc::Sender<WorkerMsg>,
) -> (mpsc::Sender<WorkItem>, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel::<WorkItem>();
    let dead = Arc::new(AtomicBool::new(false));
    let dead_flag = Arc::clone(&dead);
    let handle = std::thread::spawn(move || {
        // If this thread panics anywhere below, the coordinator still
        // hears a `Failed` (the in-proc analog of a dead TCP peer).
        let _sentinel = PanicSentinel { worker, tx: flush_tx.clone() };
        // A shutdown error is the clean end-of-run signal (break
        // silently); any other transport error is a fault the
        // coordinator must hear about, or it would wait forever
        // for this worker's flush.
        let fail = |worker: usize, e: crate::ps::TransportError| {
            if !e.is_shutdown() {
                let _ = flush_tx.send(WorkerMsg::Failed { worker, error: e.to_string() });
            }
        };
        while let Ok(item) = rx.recv() {
            // A raised kill flag simulates a crash: the thread stops
            // dead between items, leaving queued work unprocessed. No
            // message is sent — the supervisor already knows (it raised
            // the flag) and reassigns off this worker's leases.
            if dead_flag.load(Ordering::Relaxed) {
                return;
            }
            let spec = kernel.pull_spec(&item.vars, item.round);
            let pull_start = events.as_ref().map(|s| s.now_us());
            let (snap, meta) = match client.pull(spec, item.round) {
                Ok(pulled) => pulled,
                Err(e) => {
                    fail(item.worker, e);
                    break;
                }
            };
            if let (Some(sink), Some(start)) = (events.as_ref(), pull_start) {
                // One RPC interval, split into the server-measured
                // gate wait and the transfer that followed. The
                // gate span is emitted even at 0µs so a staleness-0
                // timeline still carries every phase.
                let total = sink.now_us().saturating_sub(start);
                let gate = meta.gate_us.min(total);
                sink.record(SpanEvent {
                    phase: Phase::Gate,
                    round: item.round,
                    worker: item.worker,
                    start_us: start,
                    dur_us: gate,
                });
                sink.record(SpanEvent {
                    phase: Phase::Pull,
                    round: item.round,
                    worker: item.worker,
                    start_us: start + gate,
                    dur_us: total - gate,
                });
            }
            // Compute clock starts once the snapshot is in hand:
            // gate wait is staleness discipline, not service time.
            let compute_start = Instant::now();
            let compute_start_us = events.as_ref().map(|s| s.now_us());
            let proposals = kernel.propose(&snap, &item.vars, item.round);
            // Release the epoch views before flushing: a worker
            // must never force copy-on-publish clones (its own
            // flush, or a peer's) with a snapshot it is done with.
            drop(snap);
            if let (Some(sink), Some(start)) = (events.as_ref(), compute_start_us) {
                sink.record(SpanEvent {
                    phase: Phase::Compute,
                    round: item.round,
                    worker: item.worker,
                    start_us: start,
                    dur_us: sink.now_us().saturating_sub(start),
                });
            }
            let flush_start_us = events.as_ref().map(|s| s.now_us());
            client.push(&proposals);
            let (deltas, applied) =
                match client.flush_clock(item.round, item.block_idx as u64) {
                    Ok(flushed) => flushed,
                    Err(e) => {
                        fail(item.worker, e);
                        break;
                    }
                };
            if let (Some(sink), Some(start)) = (events.as_ref(), flush_start_us) {
                sink.record(SpanEvent {
                    phase: Phase::Flush,
                    round: item.round,
                    worker: item.worker,
                    start_us: start,
                    dur_us: sink.now_us().saturating_sub(start),
                });
            }
            let msg = FlushMsg {
                round: item.round,
                block_idx: item.block_idx,
                worker: item.worker,
                work: item.work,
                est_sec: item.est_sec,
                compute_sec: compute_start.elapsed().as_secs_f64(),
                deltas,
                stale_gap: meta.gap,
                waited: meta.waited,
                applied,
            };
            if flush_tx.send(WorkerMsg::Flush(msg)).is_err() {
                break;
            }
        }
    });
    (tx, dead, handle)
}

/// Retire `victim` from the run — raise its kill flag, retire its SSP
/// clock at the server (parked survivors wake instead of waiting on a
/// clock that will never tick), drop it from the dispatch pool, and
/// re-dispatch every lease it held to the best other live worker.
/// Idempotent: retiring an already-dead worker is a no-op.
#[allow(clippy::too_many_arguments)]
fn retire_and_reassign(
    victim: usize,
    conn: &mut PsConnection,
    dispatcher: &mut Dispatcher,
    leases: &mut LeaseTable,
    work_txs: &mut [Option<mpsc::Sender<WorkItem>>],
    dead_flags: &[Arc<AtomicBool>],
    lease_len: Duration,
    sup_reassigns: &Counter,
) -> anyhow::Result<()> {
    if !dispatcher.is_active(victim) {
        return Ok(());
    }
    // Order matters: flag first (the thread stops taking work), then
    // retire the clock (the gate recomputes over survivors), then drop
    // the work queue (senders to the dead are nulled, never reused).
    if let Some(flag) = dead_flags.get(victim) {
        flag.store(true, Ordering::Relaxed);
    }
    conn.coord().leave(victim)?;
    dispatcher.remove_worker(victim);
    work_txs[victim] = None;
    anyhow::ensure!(
        dispatcher.active_workers() > 0,
        "no live workers remain (worker {victim} was the last)"
    );
    // Every lease the victim held — queued or in flight — moves to
    // another live worker. If its flush for a block already landed the
    // lease was already released; if it lands later, the server's
    // ledger drops it as the reassignment-race loser.
    for (round, block) in leases.held_by(victim) {
        if reassign_block(round, block, victim, dispatcher, leases, work_txs, lease_len)? {
            sup_reassigns.inc();
        }
    }
    Ok(())
}

/// Re-dispatch one leased block to the best live worker other than
/// `previous` (its current holder). If nobody else is live and the
/// holder is still alive (a slow worker whose lease merely expired),
/// the lease deadline is extended in place instead. Returns whether the
/// block was actually re-dispatched.
fn reassign_block(
    round: u64,
    block: u64,
    previous: usize,
    dispatcher: &mut Dispatcher,
    leases: &mut LeaseTable,
    work_txs: &[Option<mpsc::Sender<WorkItem>>],
    lease_len: Duration,
) -> anyhow::Result<bool> {
    let lease = leases.get(round, block).expect("reassigning an unleased block").clone();
    let Some((worker, est_sec)) = dispatcher.pick_excluding(lease.work, previous) else {
        if dispatcher.is_active(previous) {
            let mut extended = lease;
            extended.deadline = Instant::now() + lease_len;
            leases.grant(round, block, extended);
            return Ok(false);
        }
        anyhow::bail!("no live worker can take block {block} of round {round}");
    };
    let item = WorkItem {
        round,
        block_idx: block as usize,
        vars: lease.vars.clone(),
        work: lease.work,
        est_sec,
        worker,
    };
    leases.grant(
        round,
        block,
        Lease {
            worker,
            vars: lease.vars,
            work: lease.work,
            est_sec,
            deadline: Instant::now() + lease_len,
        },
    );
    work_txs[worker]
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("reassignment picked a retired worker"))?
        .send(item)
        .map_err(|_| anyhow::anyhow!("worker channel closed"))?;
    Ok(true)
}

/// Per-round reassembly buffer on the coordinator.
struct RoundBuf {
    parts: Vec<Option<Vec<(usize, f64)>>>,
    received: usize,
    /// Planned (workload-unit) straggler ratio — the fallback when a
    /// round completes too fast for timing to mean anything.
    planned_imbalance: f64,
    /// (worker, compute_sec) per completed block.
    timings: Vec<(usize, f64)>,
    problem_planned: bool,
    /// Seconds the coordinator was blocked obtaining this round's plan.
    sched_wait: f64,
    stale_gap_sum: u64,
    /// Pulls in this round that had to block at the SSP gate.
    gate_waits: u64,
}

impl RoundBuf {
    fn new(blocks: usize, planned_imbalance: f64, problem_planned: bool, sched_wait: f64) -> Self {
        RoundBuf {
            parts: (0..blocks).map(|_| None).collect(),
            received: 0,
            planned_imbalance,
            timings: Vec::with_capacity(blocks),
            problem_planned,
            sched_wait,
            stale_gap_sum: 0,
            gate_waits: 0,
        }
    }

    fn store(&mut self, msg: FlushMsg) {
        debug_assert!(self.parts[msg.block_idx].is_none(), "duplicate flush for a block");
        self.parts[msg.block_idx] = Some(msg.deltas);
        self.received += 1;
        self.stale_gap_sum += msg.stale_gap;
        self.gate_waits += u64::from(msg.waited);
        self.timings.push((msg.worker, msg.compute_sec));
    }

    fn complete(&self) -> bool {
        self.received == self.parts.len()
    }

    fn mean_staleness(&self) -> f64 {
        if self.parts.is_empty() {
            0.0
        } else {
            self.stale_gap_sum as f64 / self.parts.len() as f64
        }
    }

    /// Measured straggler ratio (per-worker busy seconds); falls back
    /// to the planned workload ratio when nothing measurable happened.
    fn round_imbalance(&self) -> f64 {
        let measured = measured_imbalance(&self.timings);
        if self.timings.iter().any(|&(_, s)| s > 0.0) {
            measured
        } else {
            self.planned_imbalance
        }
    }

    /// Concatenate the parts in block order — the deterministic apply
    /// order that matches the engine path's block iteration.
    fn into_ordered(self) -> Vec<(usize, f64)> {
        self.parts.into_iter().flat_map(|p| p.expect("round complete")).collect()
    }
}

/// The coordinator's planning source for scheduler rounds. Both arms
/// run the identical planner set (same policy, same shard count, same
/// seed), so `sched.service` toggles only *where* planning happens —
/// the A/B contract the inline-parity test pins for every scheduler
/// kind.
enum Planner {
    /// Pipelined shard threads (the scheduler service).
    Service(SchedService),
    /// The same shard planners, rotated inline on the coordinator
    /// thread (no oracle, or `sched.service = 0`).
    Inline(PlannerSet),
}

/// Summary of a distributed run.
#[derive(Debug, Clone)]
pub struct DistributedReport {
    pub trace: Trace,
    pub rounds: usize,
    /// State-space deltas applied to the canonical model.
    pub deltas_applied: usize,
    /// Coalesced delta bytes flushed through the server by workers.
    pub bytes_flushed: u64,
    /// Derived-state bytes republished by the coordinator (tolerance-
    /// gated; the incremental-republish regression tests pin this).
    pub bytes_republished: u64,
    /// Pulls that had to block at the SSP gate.
    pub gate_waits: u64,
    /// Mean staleness gap over all pulls.
    pub mean_staleness: f64,
    /// Largest staleness gap any pull observed (always <= the bound).
    pub max_stale_gap: u64,
    /// Hash-map probes the store served — dense-segment traffic never
    /// counts here, so this is the fast-path acceptance meter.
    pub hash_probes: u64,
    /// Pull bytes served to workers (f32 epoch ranges at 4 bytes/cell
    /// + one epoch version each; everything else as 16-byte cells).
    pub pull_bytes: u64,
    /// Total cells covered by pulls — `16 * cells_pulled` is what the
    /// replaced per-cell wire format would have moved.
    pub cells_pulled: u64,
    /// Range pulls served as zero-copy shared epoch views.
    pub snapshot_clones: u64,
    /// Epoch slab clones copy-on-publish performed because a reader
    /// still held the old epoch.
    pub cow_clones: u64,
    /// Bytes those copy-on-publish clones actually copied (4 bytes per
    /// cloned cell) — the quantity `[ps] chunk_cells` shrinks: cloning
    /// one written chunk instead of the whole segment slab.
    pub cow_bytes: u64,
    /// Compressed f32 value runs encoded onto the TCP wire across
    /// every link (0 in-process or with `wire_compress = off`).
    pub runs_encoded: u64,
    /// Total coordinator seconds blocked on (or inline computing)
    /// plans — the quantity scheduler sharding + pipelining shrinks.
    pub sched_wait_total: f64,
    /// Mean plan-queue depth the service showed at each pop (0.0 on
    /// the inline path: there is no queue).
    pub plan_queue_depth: f64,
    /// Whether the pipelined scheduler service planned this run (false
    /// = inline fallback).
    pub sched_service_used: bool,
    /// Real bytes moved through transport sockets (frame headers
    /// included) — 0 for the in-process transport. Compare against the
    /// modeled `net_bytes`: this is the observable the TCP transport
    /// turns the wire meter into.
    pub socket_bytes: u64,
    /// Successful transport reconnects across every link (0 unless
    /// `[ps] retry_max` engaged the retry wrapper and a fault hit).
    pub reconnects: u64,
    /// Total retry backoff slept across every link, in microseconds.
    pub retry_backoff_us: u64,
    /// Parameter-server fleet size this run routed over (1 = the
    /// classic single server, in-process or TCP).
    pub route_servers: usize,
    /// Inner RPCs the routed fan-out issued (0 single-server) — the
    /// cost of splitting each pull/flush/publish across the fleet.
    pub route_fanout_rpcs: u64,
    /// Real socket bytes per fleet member, indexed like `[ps] addr`
    /// (one entry holding the total for single-server runs).
    pub socket_bytes_per_server: Vec<u64>,
    /// Reconnects per fleet member, indexed like `[ps] addr` — the
    /// chaos suite pins that a kill shows up on exactly the killed
    /// server's links.
    pub reconnects_per_server: Vec<u64>,
    /// Which transport carried the run (`inproc` | `tcp`).
    pub transport: &'static str,
    /// Flush heartbeats the supervisor observed (one per worker flush,
    /// whatever the server's verdict on the batch).
    pub sup_heartbeats: u64,
    /// Dispatched-block leases whose deadline passed with no flush.
    pub sup_leases_expired: u64,
    /// Blocks re-dispatched to another live worker after a death or a
    /// lease expiry (0 for a fixed fleet — pinned by the elastic
    /// bitwise-identity test).
    pub sup_reassigns: u64,
    /// Live workers at teardown (`== workers` for a fixed fleet).
    pub sup_workers_live: usize,
    /// Full registry snapshot at teardown — the server's metrics (via
    /// the `ObsStats` RPC, so a TCP run exercises the same introspection
    /// path `strads ps-stats` uses) plus the coordinator-side metrics
    /// (`sched.plan_wait_us`, `net.socket_bytes`). Empty at
    /// `obs.level = 0`.
    pub obs_metrics: Vec<(String, MetricValue)>,
}

/// Run up to `rounds` rounds of `problem` on `cfg.workers` real worker
/// threads through a parameter server configured by `cfg.ps`, planned
/// by the scheduler `cfg.sched` selects.
/// Wall-clock, not virtual time (this is the architecture/correctness
/// path; the core-count sweeps use the simulator).
pub fn run_distributed(
    problem: &mut dyn ModelProblem,
    cfg: &RunConfig,
    rounds: usize,
    dataset: &str,
) -> anyhow::Result<DistributedReport> {
    let p = cfg.workers;
    let policy = cfg.ps.policy();
    let kernel = problem
        .ps_kernel()
        .ok_or_else(|| anyhow::anyhow!("problem does not provide a parameter-server kernel"))?;

    // Elastic membership: leases + supervision are armed by `[ps]
    // elastic` (or implied by a non-empty kill plan). A fixed-fleet run
    // takes the exact recv path it always took — and an elastic run
    // with no membership events is bitwise identical to it, because
    // supervision only observes (leases, heartbeats) until a death or
    // expiry actually fires.
    let elastic = cfg.ps.elastic_enabled();
    let kill_plan = KillPlan::parse(&cfg.ps.worker_kill_plan)
        .map_err(|e| anyhow::anyhow!("bad [ps] worker_kill_plan: {e}"))?;
    let mut chaos_rng = Rng::new(kill_plan.seed);
    let lease_len = Duration::from_millis(cfg.ps.lease_ms.max(1));
    // Poll granularity bounds how late an expiry is noticed; capped so
    // tiny lease_ms settings (tests) still poll responsively.
    let lease_poll = Duration::from_millis((cfg.ps.lease_ms / 2).clamp(5, 250));
    let mut leases = LeaseTable::new();

    // Establish the run's connection to its parameter server over the
    // configured transport — in-process (the server is built here) or
    // TCP to a `strads ps-server` process (the server is initialized
    // remotely) — register the problem's contiguous key ranges as dense
    // segments (unless disabled), and seed the full state.
    let segments =
        if cfg.ps.dense_segments { problem.ps_dense_segments() } else { Vec::new() };
    let mut conn = PsConnection::establish(&cfg.ps, p, &segments)?;
    // Seed the full state. Problems whose canonical state is already
    // f32 (MF) ship it raw — no widen-to-f64/narrow-back round trip —
    // bit-identical because dense cells store f32 either way.
    let state_len = match problem.ps_state_f32() {
        Some(state) => {
            conn.coord().publish_range_f32(0, &state, 0)?;
            state.len()
        }
        None => {
            let state = problem.ps_state();
            conn.coord().publish_range(0, &state, 0)?;
            state.len()
        }
    };

    // Observability is side-channel only: the coordinator registry and
    // the (optional) span sink absorb observations that never feed back
    // into planning, dispatch, or arithmetic — the obs-level parity
    // test pins staleness-0 trajectories bitwise across levels.
    let registry = Registry::new();
    let plan_wait_us = registry.histogram("sched.plan_wait_us", Histogram::us_bounds());
    let sup_heartbeats = registry.counter("sup.heartbeats");
    let sup_leases_expired = registry.counter("sup.leases_expired");
    let sup_reassigns = registry.counter("sup.reassigns");
    let sup_workers_live = registry.gauge("sup.workers_live");
    sup_workers_live.set(p as u64);
    let events = if cfg.obs.tracing() {
        Some(Arc::new(EventSink::new(EventSink::DEFAULT_CAP)))
    } else {
        None
    };

    // Worker threads: private work queue in, shared flush channel out.
    // Each worker gets its own transport link, minted here so a
    // connection failure surfaces before any thread spawns. Senders are
    // slot-indexed by worker id and nulled on death/leave — slots are
    // never reused, so ids stay stable for the clock table.
    let (flush_tx, flush_rx) = mpsc::channel::<WorkerMsg>();
    let mut work_txs: Vec<Option<mpsc::Sender<WorkItem>>> = Vec::with_capacity(p);
    let mut dead_flags: Vec<Arc<AtomicBool>> = Vec::with_capacity(p);
    let mut handles = Vec::with_capacity(p);
    for worker in 0..p {
        let client = PsClient::over(conn.worker_transport(worker)?, worker);
        let (tx, dead, handle) =
            spawn_worker(worker, client, Arc::clone(&kernel), events.clone(), flush_tx.clone());
        work_txs.push(Some(tx));
        dead_flags.push(dead);
        handles.push(handle);
    }
    // Elastic runs keep a spare sender so the flush channel stays open
    // for mid-run joiners; their hang protection is lease expiry, not
    // channel disconnect.
    let spare_flush_tx = if elastic { Some(flush_tx.clone()) } else { None };
    drop(flush_tx);

    let window = match policy {
        // s = 0: plan(r) depends on round r-1's observations — lock-step
        // dispatch, bit-exact with the engine path.
        StalenessPolicy::Bounded(0) => 0,
        // Gate-driven pipelining: dispatch past the bound, let the SSP
        // gate pace the workers.
        StalenessPolicy::Bounded(s) if cfg.ps.pipeline => s + GATE_PIPELINE_AHEAD,
        // Legacy dispatch throttling (pipeline disabled).
        StalenessPolicy::Bounded(s) => s,
        StalenessPolicy::Async => ASYNC_PIPELINE_DEPTH,
    };

    // Planning source: the threaded shard service when the problem
    // exposes a scheduling oracle (and the config allows it), the same
    // planner set rotated inline otherwise. Both honor the configured
    // `sched.scheduler` kind, so `--scheduler static|random` works
    // distributed too. The oracle (a design-matrix clone for Lasso) is
    // only materialized when the service will actually use it.
    let sched_shards = cfg.sched.effective_shards(&cfg.sap);
    let mut sap = cfg.sap.clone();
    sap.shards = sched_shards;
    let oracle = if cfg.sched.service { problem.sched_oracle() } else { None };
    let mut planner = match oracle {
        Some(oracle) => Planner::Service(SchedService::spawn(
            oracle,
            cfg.sched.kind,
            PriorityKind::Linear,
            &sap,
            cfg.engine.seed,
            sched_shards,
            p,
            window,
            cfg.sched.pipeline_depth,
        )),
        None => Planner::Inline(PlannerSet::new(
            problem.num_vars(),
            sched_shards,
            cfg.sched.kind,
            PriorityKind::Linear,
            &sap,
            cfg.engine.seed,
        )),
    };
    let service_used = matches!(planner, Planner::Service(_));

    let rounds = rounds as u64;
    let mut planned = 0u64;
    let mut applied = 0u64;
    let mut converged = false;
    let mut pending: BTreeMap<u64, RoundBuf> = BTreeMap::new();
    let mut dispatcher = Dispatcher::new(p, cfg.cost.sec_per_work_unit);
    let mut trace = Trace::new(&format!("dist-{}", policy.label()), dataset, p);
    let mut deltas_applied = 0usize;
    let mut sched_wait_cum = 0.0f64;
    let mut gate_waits_cum = 0u64;
    // Latest objective value seen (incremental or recorded) — the
    // scale signal `republish_tol = auto` derives its tolerance from.
    let mut last_obj: Option<f64> = None;
    let wall = Instant::now();

    loop {
        // Dispatch every round the pipeline window admits.
        while !converged && planned < rounds && planned <= applied + window {
            // Membership chaos fires at dispatch time of the plan's
            // round — deterministic given the plan string, whatever the
            // workers' timing. Joins fire *before* the round's blocks
            // go out (a joiner can be handed work this very round);
            // kills fire *after* (below), so the victim dies holding
            // leases and the reassignment path is actually exercised —
            // even at staleness 0, where nothing else is ever in
            // flight at a round boundary.
            let membership_now = kill_plan.events_at(planned);
            for event in &membership_now {
                if *event == MembershipEvent::Join {
                    // Ids are minted monotonically and never reused;
                    // the census (clock table, dispatcher, sender
                    // table) all grow in lockstep.
                    let id = work_txs.len();
                    conn.coord().join(id)?;
                    let client = PsClient::over(conn.worker_transport(id)?, id);
                    let (tx, dead, handle) = spawn_worker(
                        id,
                        client,
                        Arc::clone(&kernel),
                        events.clone(),
                        spare_flush_tx.clone().expect("join events imply elastic mode"),
                    );
                    work_txs.push(Some(tx));
                    dead_flags.push(dead);
                    handles.push(handle);
                    dispatcher.add_worker(id);
                    sup_workers_live.set(dispatcher.active_workers() as u64);
                }
            }
            let (blocks, problem_planned, sched_wait) =
                match problem.plan_round(planned as usize, p) {
                    Some(blocks) => (blocks, true, 0.0),
                    None => {
                        let (blocks, wait) = match &mut planner {
                            Planner::Service(svc) => svc.pop_plan()?,
                            Planner::Inline(set) => {
                                let t = Instant::now();
                                let blocks = set.plan_turn(&mut ProblemDeps(problem), p);
                                (blocks, t.elapsed().as_secs_f64())
                            }
                        };
                        (blocks, false, wait)
                    }
                };
            if blocks.is_empty() {
                converged = true;
                break;
            }
            sched_wait_cum += sched_wait;
            plan_wait_us.record((sched_wait * 1e6) as u64);
            if let Some(sink) = events.as_ref() {
                // The plan span's duration IS the measured sched_wait,
                // so the timeline cross-checks against the trace column
                // by construction.
                let dur = (sched_wait * 1e6) as u64;
                let now = sink.now_us();
                sink.record(SpanEvent {
                    phase: Phase::Plan,
                    round: planned,
                    worker: p,
                    start_us: now.saturating_sub(dur),
                    dur_us: dur,
                });
            }
            pending.insert(
                planned,
                RoundBuf::new(blocks.len(), imbalance(&blocks), problem_planned, sched_wait),
            );
            for (block_idx, block) in blocks.into_iter().enumerate() {
                let (worker, est_sec) = dispatcher
                    .pick(block.work)
                    .ok_or_else(|| anyhow::anyhow!("no live workers to dispatch to"))?;
                if elastic {
                    leases.grant(
                        planned,
                        block_idx as u64,
                        Lease {
                            worker,
                            vars: block.vars.clone(),
                            work: block.work,
                            est_sec,
                            deadline: Instant::now() + lease_len,
                        },
                    );
                }
                work_txs[worker]
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("dispatched to a retired worker"))?
                    .send(WorkItem {
                        round: planned,
                        block_idx,
                        vars: block.vars,
                        work: block.work,
                        est_sec,
                        worker,
                    })
                    .map_err(|_| anyhow::anyhow!("worker channel closed"))?;
            }
            for event in membership_now {
                if let kill @ MembershipEvent::Kill(_) = event {
                    let live: Vec<usize> =
                        (0..work_txs.len()).filter(|&w| dispatcher.is_active(w)).collect();
                    let Some(victim) = KillPlan::choose_victim(kill, &live, &mut chaos_rng)
                    else {
                        continue;
                    };
                    retire_and_reassign(
                        victim,
                        &mut conn,
                        &mut dispatcher,
                        &mut leases,
                        &mut work_txs,
                        &dead_flags,
                        lease_len,
                        &sup_reassigns,
                    )?;
                    sup_workers_live.set(dispatcher.active_workers() as u64);
                }
            }
            planned += 1;
        }
        if applied == planned {
            break; // all dispatched rounds applied (or nothing planned)
        }

        // Collect one flush (elastic runs poll, so a lease expiry is
        // noticed even when no flush arrives), then apply every
        // now-complete round in order.
        let received = if elastic {
            match flush_rx.recv_timeout(lease_poll) {
                Ok(msg) => Some(msg),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("workers hung up")
                }
            }
        } else {
            Some(flush_rx.recv().map_err(|_| anyhow::anyhow!("workers hung up"))?)
        };
        if elastic {
            // Expired lease = dead-or-wedged holder: re-dispatch to the
            // best other live worker. If the holder was merely slow its
            // late flush loses the ledger race and is dropped.
            for (round, block) in leases.expired(Instant::now()) {
                sup_leases_expired.inc();
                let holder = leases.get(round, block).expect("expired lease exists").worker;
                if reassign_block(
                    round,
                    block,
                    holder,
                    &mut dispatcher,
                    &mut leases,
                    &work_txs,
                    lease_len,
                )? {
                    sup_reassigns.inc();
                }
            }
        }
        let msg = match received {
            Some(WorkerMsg::Flush(msg)) => msg,
            Some(WorkerMsg::Failed { worker, error }) => {
                if elastic {
                    // Supervision: retire the failed worker, move its
                    // leases, keep the run going on the survivors.
                    eprintln!("[sup] worker {worker} failed ({error}); reassigning its leases");
                    retire_and_reassign(
                        worker,
                        &mut conn,
                        &mut dispatcher,
                        &mut leases,
                        &mut work_txs,
                        &dead_flags,
                        lease_len,
                        &sup_reassigns,
                    )?;
                    sup_workers_live.set(dispatcher.active_workers() as u64);
                    continue;
                }
                anyhow::bail!("worker {worker} lost its parameter-server link: {error}")
            }
            None => continue,
        };
        // Every flush is a liveness heartbeat and a service-rate sample,
        // whatever the server's verdict on the batch itself.
        sup_heartbeats.inc();
        dispatcher.complete(msg.worker, msg.work, msg.est_sec, msg.compute_sec);
        if !msg.applied {
            // The server's ledger dropped this batch (reassignment-race
            // loser, or a retired worker's zombie): the winning copy is
            // what carries the round forward — folding this one too
            // would double-apply the block.
            continue;
        }
        if elastic {
            leases.release(msg.round, msg.block_idx as u64);
        }
        pending.get_mut(&msg.round).expect("flush for unplanned round").store(msg);
        while pending.get(&applied).map(RoundBuf::complete).unwrap_or(false) {
            let buf = pending.remove(&applied).expect("checked above");
            let round_imbalance = buf.round_imbalance();
            let round_staleness = buf.mean_staleness();
            let round_sched_wait = buf.sched_wait;
            let problem_planned = buf.problem_planned;
            gate_waits_cum += buf.gate_waits;
            let ordered = buf.into_ordered();
            deltas_applied += ordered.len();
            let apply_start_us = events.as_ref().map(|s| s.now_us());
            let mut result = problem.apply_deltas(&ordered);
            if !problem_planned {
                // SAP step 4: feed measured progress back to whichever
                // planner is running (the service broadcasts it to
                // every shard thread — taking the deltas, since only
                // the objective is read below, keeps the coordinator's
                // apply loop copy-free).
                match &mut planner {
                    Planner::Service(svc) => {
                        svc.observe(Arc::new(std::mem::take(&mut result.deltas)));
                    }
                    Planner::Inline(set) => set.observe(&result),
                }
            }
            if let (Some(sink), Some(start)) = (events.as_ref(), apply_start_us) {
                sink.record(SpanEvent {
                    phase: Phase::Apply,
                    round: applied,
                    worker: p,
                    start_us: start,
                    dur_us: sink.now_us().saturating_sub(start),
                });
            }
            if let Some(obj) = result.objective {
                last_obj = Some(obj);
            }
            // The effective tolerance: fixed from the config, or (auto)
            // scaled to the objective's implied RMS entry magnitude —
            // lossless 0.0 until the first objective value arrives.
            let effective_tol = if cfg.ps.republish_auto {
                last_obj
                    .map(|o| AUTO_TOL_REL * (2.0 * o.abs() / state_len.max(1) as f64).sqrt())
                    .unwrap_or(0.0)
            } else {
                cfg.ps.republish_tol
            };
            // Periodic full re-syncs only matter when a positive
            // tolerance admits drift; tol <= 0 republishes are already
            // exact (0 = bitwise incremental, < 0 = full every round).
            let full_resync = (cfg.ps.republish_auto || cfg.ps.republish_tol > 0.0)
                && (applied + 1) % FULL_RESYNC_EVERY == 0;
            let republish_start_us = events.as_ref().map(|s| s.now_us());
            let republish = problem.ps_republish(effective_tol, full_resync);
            if !republish.is_empty() {
                // Metered as republish traffic server-side (the
                // transport carries it to wherever the store lives).
                conn.coord().publish(&republish, applied + 1)?;
            }
            conn.coord().advance_applied(applied + 1)?;
            if let (Some(sink), Some(start)) = (events.as_ref(), republish_start_us) {
                // Recorded even for skipped republishes (dur ≈ the
                // tolerance scan + clock advance) so the phase always
                // appears in the timeline.
                sink.record(SpanEvent {
                    phase: Phase::Republish,
                    round: applied,
                    worker: p,
                    start_us: start,
                    dur_us: sink.now_us().saturating_sub(start),
                });
            }

            if (applied as usize) % cfg.engine.record_every == 0 {
                let obj_now = result.objective.unwrap_or_else(|| problem.objective());
                last_obj = Some(obj_now);
                trace.push(TracePoint {
                    round: applied as usize,
                    // vtime excludes scheduling stalls so the trace
                    // separates compute from plan waits.
                    vtime: wall.elapsed().as_secs_f64() - sched_wait_cum,
                    wtime: wall.elapsed().as_secs_f64(),
                    objective: obj_now,
                    active_vars: problem.active_vars(),
                    imbalance: round_imbalance,
                    staleness: round_staleness,
                    net_bytes: conn.coord().stats()?.net_bytes(),
                    sched_wait: round_sched_wait,
                    gate_waits: gate_waits_cum,
                });
            }
            applied += 1;
        }
    }

    // Final exact objective, then shut the workers down.
    let obj = problem.objective();
    let final_stats = conn.coord().stats()?;
    trace.push(TracePoint {
        round: applied as usize,
        vtime: wall.elapsed().as_secs_f64() - sched_wait_cum,
        wtime: wall.elapsed().as_secs_f64(),
        objective: obj,
        active_vars: problem.active_vars(),
        imbalance: trace.points.last().map(|pt| pt.imbalance).unwrap_or(1.0),
        staleness: final_stats.mean_staleness(),
        net_bytes: final_stats.net_bytes(),
        sched_wait: 0.0,
        gate_waits: final_stats.gate_waits,
    });
    // One accumulator serves both the report and the vtime exclusion,
    // so the two can never desynchronize.
    let sched_wait_total = sched_wait_cum;
    let plan_queue_depth = match &planner {
        Planner::Service(svc) => svc.mean_queue_depth(),
        Planner::Inline(_) => 0.0,
    };
    drop(planner); // join the shard threads before the workers
    drop(work_txs);
    conn.coord().shutdown_clock()?;
    for h in handles {
        let _ = h.join();
    }
    // Joined workers can no longer flush/pull: this snapshot is final.
    let stats = conn.coord().stats()?;
    let obs_metrics = if cfg.obs.level > 0 {
        // The same RPC `strads ps-stats` issues — every obs-enabled run
        // exercises the introspection path over its own transport —
        // merged with the coordinator-side registry.
        registry.gauge("net.socket_bytes").set(conn.socket_bytes());
        registry.counter("net.reconnects").set(conn.reconnects());
        registry.counter("net.retry_backoff_us").set(conn.retry_backoff_us());
        registry.gauge("wire.runs_encoded").set(conn.runs_encoded());
        registry.gauge("route.servers").set(conn.route_servers() as u64);
        registry.counter("route.fanout_rpcs").set(conn.route_fanout_rpcs());
        if conn.route_servers() > 1 {
            // Per-member traffic, indexed like `[ps] addr`, so a fleet
            // run shows where its bytes (and reconnects) went.
            for (i, bytes) in conn.socket_bytes_per_server().iter().enumerate() {
                registry.gauge(&format!("net.socket_bytes_s{i}")).set(*bytes);
            }
            for (i, r) in conn.reconnects_per_server().iter().enumerate() {
                registry.gauge(&format!("net.reconnects_s{i}")).set(*r);
            }
        }
        let mut metrics = conn.coord().obs_stats()?.metrics;
        metrics.extend(registry.snapshot());
        metrics.sort_by(|a, b| a.0.cmp(&b.0));
        metrics
    } else {
        Vec::new()
    };
    if let Some(sink) = events.as_ref() {
        let written = sink.flush_jsonl(std::path::Path::new(&cfg.obs.events_path))?;
        if sink.dropped() > 0 {
            eprintln!(
                "[obs] event ring overflowed: kept {written} spans, dropped {}",
                sink.dropped()
            );
        }
    }
    Ok(DistributedReport {
        trace,
        rounds: applied as usize,
        deltas_applied,
        bytes_flushed: stats.bytes_flushed,
        bytes_republished: stats.bytes_republished,
        gate_waits: stats.gate_waits,
        mean_staleness: stats.mean_staleness(),
        max_stale_gap: stats.max_stale_gap,
        hash_probes: stats.hash_probes,
        pull_bytes: stats.bytes_pulled,
        cells_pulled: stats.cells_pulled,
        snapshot_clones: stats.snapshot_clones,
        cow_clones: stats.cow_clones,
        cow_bytes: stats.cow_bytes,
        runs_encoded: conn.runs_encoded(),
        sched_wait_total,
        plan_queue_depth,
        sched_service_used: service_used,
        socket_bytes: conn.socket_bytes(),
        reconnects: conn.reconnects(),
        retry_backoff_us: conn.retry_backoff_us(),
        route_servers: conn.route_servers(),
        route_fanout_rpcs: conn.route_fanout_rpcs(),
        socket_bytes_per_server: conn.socket_bytes_per_server(),
        reconnects_per_server: conn.reconnects_per_server(),
        transport: cfg.ps.transport.name(),
        sup_heartbeats: sup_heartbeats.get(),
        sup_leases_expired: sup_leases_expired.get(),
        sup_reassigns: sup_reassigns.get(),
        sup_workers_live: dispatcher.active_workers(),
        obs_metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::lasso_synth::{generate, LassoSynthSpec};
    use crate::lasso::NativeLasso;
    use crate::schedulers::DynamicScheduler;

    #[test]
    fn distributed_run_converges_like_local() {
        let data = generate(&LassoSynthSpec::tiny(), 21);
        let mut cfg = RunConfig { workers: 4, lambda: 1e-3, ..Default::default() };
        cfg.sap.shards = 2;
        let mut problem = NativeLasso::new(&data, cfg.lambda);
        let report = run_distributed(&mut problem, &cfg, 300, "tiny").unwrap();
        let first = report.trace.points.first().unwrap().objective;
        let last = report.trace.final_objective();
        assert!(last < first * 0.8, "first {first} last {last}");
        assert!(report.deltas_applied > 0);
        assert!(report.bytes_flushed > 0, "flushes must be metered");
        assert!(report.sched_service_used, "lasso exposes an oracle: the service must plan");
    }

    #[test]
    fn distributed_matches_engine_semantics() {
        // Same seed, same scheduler config, staleness 0: the distributed
        // path must produce the same final objective as the local engine
        // semantics (proposals computed against identical snapshots,
        // applied in identical order).
        let data = generate(&LassoSynthSpec::tiny(), 22);
        let mut cfg = RunConfig { workers: 1, lambda: 1e-3, ..Default::default() };
        cfg.sap.shards = 1;
        let mut problem = NativeLasso::new(&data, cfg.lambda);
        let report = run_distributed(&mut problem, &cfg, 50, "tiny").unwrap();

        let mut local = NativeLasso::new(&data, cfg.lambda);
        let mut sched = DynamicScheduler::new(local.num_vars(), &cfg.sap, cfg.engine.seed);
        for _ in 0..50 {
            let blocks = sched.plan(&mut local, 1);
            if blocks.is_empty() {
                break;
            }
            let res = local.update_blocks(&blocks);
            sched.observe(&res);
        }
        let local_obj = local.objective();
        let dist_obj = report.trace.final_objective();
        assert!(
            (local_obj - dist_obj).abs() < 1e-6 * local_obj.abs().max(1.0),
            "local {local_obj} dist {dist_obj}"
        );
    }

    #[test]
    fn dense_segments_skip_residual_hashing() {
        // With the residual registered (the default), store traffic for
        // the residual range is slab-addressed: only the scattered β
        // keys ever hash. Turning registration off must not change the
        // result — only the probe count.
        let data = generate(&LassoSynthSpec::tiny(), 24);
        let mut on_cfg = RunConfig { workers: 2, lambda: 1e-3, ..Default::default() };
        on_cfg.sap.shards = 2;
        let mut off_cfg = on_cfg.clone();
        off_cfg.ps.dense_segments = false;

        let mut on_problem = NativeLasso::new(&data, on_cfg.lambda);
        let on = run_distributed(&mut on_problem, &on_cfg, 40, "tiny").unwrap();
        let mut off_problem = NativeLasso::new(&data, off_cfg.lambda);
        let off = run_distributed(&mut off_problem, &off_cfg, 40, "tiny").unwrap();

        assert_eq!(
            on.trace.final_objective(),
            off.trace.final_objective(),
            "storage representation must be observationally invisible"
        );
        assert!(
            on.hash_probes < off.hash_probes / 10,
            "dense segments must eliminate residual hashing: on={} off={}",
            on.hash_probes,
            off.hash_probes
        );
    }

    #[test]
    fn many_workers_few_blocks_is_safe() {
        let data = generate(&LassoSynthSpec::tiny(), 23);
        let cfg = RunConfig { workers: 16, lambda: 1e-2, ..Default::default() };
        let mut problem = NativeLasso::new(&data, cfg.lambda);
        let report = run_distributed(&mut problem, &cfg, 20, "tiny").unwrap();
        assert!(report.rounds > 0);
    }

    #[test]
    fn kernel_less_problem_is_rejected() {
        struct NoPs;
        impl ModelProblem for NoPs {
            fn num_vars(&self) -> usize {
                1
            }
            fn workload(&self, _j: usize) -> u64 {
                1
            }
            fn dependencies(&mut self, cands: &[usize]) -> Vec<f64> {
                vec![0.0; cands.len() * cands.len()]
            }
            fn update_blocks(
                &mut self,
                _blocks: &[crate::problem::Block],
            ) -> crate::problem::RoundResult {
                Default::default()
            }
            fn objective(&mut self) -> f64 {
                0.0
            }
        }
        let cfg = RunConfig::default();
        assert!(run_distributed(&mut NoPs, &cfg, 10, "none").is_err());
    }

    #[test]
    fn obs_metrics_view_the_run_without_changing_it() {
        let data = generate(&LassoSynthSpec::tiny(), 26);
        let mut cfg = RunConfig { workers: 2, lambda: 1e-3, ..Default::default() };
        cfg.sap.shards = 2;
        let mut problem = NativeLasso::new(&data, cfg.lambda);
        let report = run_distributed(&mut problem, &cfg, 30, "tiny").unwrap();
        let get = |name: &str| {
            report.obs_metrics.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_u64())
        };
        assert!(get("ps.pulls").unwrap() > 0);
        assert_eq!(get("ps.pull_bytes").unwrap(), report.pull_bytes);
        assert!(get("sched.plan_wait_us").unwrap() > 0, "one sample per planned round");
        assert!(get("net.socket_bytes").is_some());
        assert!(get("gate.wait_us").is_some());
        // staleness 0 without pipelining never parks a pull
        let last = report.trace.points.last().unwrap();
        assert_eq!(last.gate_waits, report.gate_waits);

        let mut cfg0 = cfg.clone();
        cfg0.obs.level = 0;
        let mut problem0 = NativeLasso::new(&data, cfg0.lambda);
        let off = run_distributed(&mut problem0, &cfg0, 30, "tiny").unwrap();
        assert!(off.obs_metrics.is_empty(), "level 0 must collect nothing");
        assert_eq!(
            off.trace.final_objective(),
            report.trace.final_objective(),
            "obs level must be observationally invisible"
        );
    }

    #[test]
    fn sched_wait_is_recorded_and_vtime_excludes_it() {
        let data = generate(&LassoSynthSpec::tiny(), 25);
        let mut cfg = RunConfig { workers: 2, lambda: 1e-3, ..Default::default() };
        cfg.sap.shards = 2;
        let mut problem = NativeLasso::new(&data, cfg.lambda);
        let report = run_distributed(&mut problem, &cfg, 60, "tiny").unwrap();
        // Lock-step planning always blocks at least briefly per round.
        assert!(report.sched_wait_total > 0.0, "sched_wait must be measured");
        for pt in &report.trace.points {
            assert!(pt.sched_wait >= 0.0);
            assert!(
                pt.vtime <= pt.wtime + 1e-12,
                "vtime {} must exclude sched_wait (wtime {})",
                pt.vtime,
                pt.wtime
            );
        }
    }
}
