//! Coordinator-side supervision for elastic worker membership: the
//! seeded membership-chaos plan (`[ps] worker_kill_plan`) and the
//! per-dispatched-block lease table that makes worker death (or a
//! wedged worker) survivable.
//!
//! The liveness design piggy-backs on traffic the run already moves:
//! every `FlushMsg` a worker delivers is a heartbeat
//! (`sup.heartbeats`), and a block whose lease deadline passes with no
//! flush is *reassigned* to another live worker (`sup.leases_expired`,
//! `sup.reassigns`). Reassignment is safe without any rendezvous
//! because the parameter server's `(round, block)` flush ledger applies
//! at most one copy — the loser's flush is acknowledged with
//! `applied = false` and the coordinator discards it (see
//! `ParameterServer::serve_flush`). Killed or failed workers are
//! retired from the SSP census (`Transport::leave`) so the gate never
//! parks a survivor on a clock that will not advance; joiners enter at
//! the applied frontier (`Transport::join`) and are immediately
//! gate-legal.
//!
//! Chaos is **coordinator-initiated and deterministic**: the plan fires
//! at dispatch time of the named round, so a seeded plan replays the
//! same membership schedule every run — the same grammar discipline as
//! `[ps] fault_plan`.

use crate::util::Rng;
use std::collections::BTreeMap;
use std::time::Instant;

/// One membership change the plan schedules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MembershipEvent {
    /// Kill a worker when the named round is dispatched: `Some(w)` for
    /// an explicit victim, `None` for a seeded draw over the workers
    /// alive at fire time.
    Kill(Option<usize>),
    /// Admit a brand-new worker (next unused id) when the named round
    /// is dispatched.
    Join,
}

/// A deterministic membership-chaos schedule, parsed from
/// `[ps] worker_kill_plan` / `--worker-kill-plan`. Comma-separated
/// `key=value` pairs, same discipline as `fault_plan`:
///
/// ```text
/// seed=42,kill=1@5            # kill worker 1 when round 5 dispatches
/// seed=7,kill=@3,kill=@9      # two seeded-victim kills
/// seed=7,join=@4,kill=@8      # join a worker at round 4, kill one at 8
/// ```
///
/// `kill=`/`join=` entries repeat freely; `seed=` may appear once.
/// Victims for `kill=@R` are drawn from the seeded RNG over the ids
/// live at fire time, so the same plan string replays the same
/// schedule.
#[derive(Clone, Debug)]
pub struct KillPlan {
    pub seed: u64,
    /// `(round, event)` in plan order.
    events: Vec<(u64, MembershipEvent)>,
}

impl KillPlan {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let mut plan = KillPlan { seed: 0, events: Vec::new() };
        let mut saw_seed = false;
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("kill plan entry {part} is not key=value"))?;
            match key {
                "seed" => {
                    anyhow::ensure!(!saw_seed, "duplicate kill plan key seed");
                    saw_seed = true;
                    plan.seed = value.parse()?;
                }
                "kill" => {
                    let (victim, round) = Self::parse_at(value)?;
                    plan.events.push((round, MembershipEvent::Kill(victim)));
                }
                "join" => {
                    let (victim, round) = Self::parse_at(value)?;
                    anyhow::ensure!(
                        victim.is_none(),
                        "join=@R takes no worker id (ids are minted at join time)"
                    );
                    plan.events.push((round, MembershipEvent::Join));
                }
                other => {
                    anyhow::bail!("unknown kill plan key {other} (seed|kill|join)")
                }
            }
        }
        Ok(plan)
    }

    /// Parse `W@R` / `@R` into `(victim, round)`.
    fn parse_at(value: &str) -> anyhow::Result<(Option<usize>, u64)> {
        let (who, round) = value
            .split_once('@')
            .ok_or_else(|| anyhow::anyhow!("{value} is not [worker]@round"))?;
        let victim = if who.is_empty() { None } else { Some(who.parse()?) };
        Ok((victim, round.parse()?))
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Every event scheduled for `round`, in plan order.
    pub fn events_at(&self, round: u64) -> Vec<MembershipEvent> {
        self.events.iter().filter(|&&(r, _)| r == round).map(|&(_, e)| e).collect()
    }

    /// Resolve a `Kill` victim against the live set: the explicit id if
    /// the plan named one (even if already dead — that kill is then a
    /// no-op), otherwise a seeded draw over `live` (None when nobody is
    /// left to kill). `live` must be sorted for reproducibility; the
    /// caller's active-id scan produces it sorted already.
    pub fn choose_victim(
        event: MembershipEvent,
        live: &[usize],
        rng: &mut Rng,
    ) -> Option<usize> {
        match event {
            MembershipEvent::Join => None,
            MembershipEvent::Kill(Some(w)) => Some(w),
            MembershipEvent::Kill(None) => {
                if live.is_empty() {
                    None
                } else {
                    Some(live[(rng.f64() * live.len() as f64) as usize % live.len()])
                }
            }
        }
    }
}

/// One dispatched block's lease: who holds it, everything needed to
/// re-dispatch it verbatim, and when the supervisor may presume the
/// holder dead-or-wedged.
#[derive(Clone, Debug)]
pub struct Lease {
    pub worker: usize,
    pub vars: Vec<usize>,
    pub work: u64,
    pub est_sec: f64,
    pub deadline: Instant,
}

/// The coordinator's outstanding leases, keyed by `(round, block)` —
/// the same key the server's flush ledger dedups on, so a lease, its
/// reassigned copies, and the at-most-once application all speak about
/// the same unit of work.
#[derive(Default)]
pub struct LeaseTable {
    leases: BTreeMap<(u64, u64), Lease>,
}

impl LeaseTable {
    pub fn new() -> Self {
        LeaseTable { leases: BTreeMap::new() }
    }

    /// Record (or overwrite, on reassignment) the lease for a block.
    pub fn grant(&mut self, round: u64, block: u64, lease: Lease) {
        self.leases.insert((round, block), lease);
    }

    /// The block was applied — its lease is dead regardless of holder.
    pub fn release(&mut self, round: u64, block: u64) -> Option<Lease> {
        self.leases.remove(&(round, block))
    }

    pub fn get(&self, round: u64, block: u64) -> Option<&Lease> {
        self.leases.get(&(round, block))
    }

    /// Keys (sorted) of every lease held by `worker` — the blocks to
    /// reassign when it dies.
    pub fn held_by(&self, worker: usize) -> Vec<(u64, u64)> {
        self.leases
            .iter()
            .filter(|(_, l)| l.worker == worker)
            .map(|(&k, _)| k)
            .collect()
    }

    /// Keys (sorted) of every lease whose deadline has passed.
    pub fn expired(&self, now: Instant) -> Vec<(u64, u64)> {
        self.leases
            .iter()
            .filter(|(_, l)| now >= l.deadline)
            .map(|(&k, _)| k)
            .collect()
    }

    pub fn len(&self) -> usize {
        self.leases.len()
    }

    pub fn is_empty(&self) -> bool {
        self.leases.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn kill_plan_parses_and_rejects_garbage() {
        let plan = KillPlan::parse("seed=42,kill=1@5,kill=@9,join=@4").unwrap();
        assert_eq!(plan.seed, 42);
        assert!(!plan.is_empty());
        assert_eq!(plan.events_at(5), vec![MembershipEvent::Kill(Some(1))]);
        assert_eq!(plan.events_at(9), vec![MembershipEvent::Kill(None)]);
        assert_eq!(plan.events_at(4), vec![MembershipEvent::Join]);
        assert!(plan.events_at(6).is_empty());

        let empty = KillPlan::parse("").unwrap();
        assert!(empty.is_empty(), "empty plan = no chaos");
        let two = KillPlan::parse("kill=@3,kill=@3").unwrap();
        assert_eq!(two.events_at(3).len(), 2, "two kills may share a round");

        assert!(KillPlan::parse("seed=1,seed=2").is_err(), "duplicate seed");
        assert!(KillPlan::parse("kill=5").is_err(), "missing @round");
        assert!(KillPlan::parse("kill=x@3").is_err(), "non-numeric victim");
        assert!(KillPlan::parse("kill=1@").is_err(), "missing round");
        assert!(KillPlan::parse("join=2@3").is_err(), "join takes no id");
        assert!(KillPlan::parse("revive=1@2").is_err(), "unknown key");
        assert!(KillPlan::parse("kill").is_err(), "not key=value");
    }

    #[test]
    fn seeded_victim_draws_replay() {
        let plan = KillPlan::parse("seed=7,kill=@2,kill=@4").unwrap();
        let draw = |plan: &KillPlan| {
            let mut rng = Rng::new(plan.seed);
            let mut live = vec![0usize, 1, 2, 3];
            let mut victims = Vec::new();
            for round in [2u64, 4] {
                for ev in plan.events_at(round) {
                    let v = KillPlan::choose_victim(ev, &live, &mut rng).unwrap();
                    live.retain(|&w| w != v);
                    victims.push(v);
                }
            }
            victims
        };
        assert_eq!(draw(&plan), draw(&plan), "same plan string, same victims");
        assert_eq!(
            KillPlan::choose_victim(MembershipEvent::Kill(None), &[], &mut Rng::new(1)),
            None,
            "nobody left to kill"
        );
        assert_eq!(
            KillPlan::choose_victim(MembershipEvent::Kill(Some(9)), &[0], &mut Rng::new(1)),
            Some(9),
            "explicit victims pass through"
        );
    }

    #[test]
    fn lease_table_tracks_holders_and_deadlines() {
        let mut t = LeaseTable::new();
        let now = Instant::now();
        let lease = |worker: usize, deadline: Instant| Lease {
            worker,
            vars: vec![1, 2],
            work: 2,
            est_sec: 0.0,
            deadline,
        };
        t.grant(0, 0, lease(1, now + Duration::from_secs(60)));
        t.grant(0, 1, lease(2, now));
        t.grant(1, 0, lease(1, now + Duration::from_secs(60)));
        assert_eq!(t.len(), 3);
        assert_eq!(t.held_by(1), vec![(0, 0), (1, 0)]);
        assert_eq!(t.expired(now), vec![(0, 1)], "deadline passed = expired");
        // Reassignment overwrites the holder under the same key.
        t.grant(0, 1, lease(3, now + Duration::from_secs(60)));
        assert_eq!(t.len(), 3, "reassignment is an overwrite, not a new lease");
        assert_eq!(t.get(0, 1).unwrap().worker, 3);
        assert!(t.expired(now).is_empty());
        assert!(t.release(0, 0).is_some());
        assert!(t.release(0, 0).is_none(), "release is idempotent");
        assert_eq!(t.held_by(1), vec![(1, 0)]);
    }
}
