//! The distributed runtime: coordinator + worker OS threads over mpsc
//! channels and the sharded parameter server (paper Fig. 3 / §3,
//! generalized to the Petuum SSP architecture).
//!
//! One coordinator owns the canonical model state; the sharded SAP
//! scheduler runs as a pipelined thread-per-shard service
//! ([`crate::sched_service`]) planning rounds ahead of execution; P
//! worker threads own nothing but the problem's immutable
//! [`crate::ps::PsKernel`] data (design matrix / ratings). Workers pull
//! versioned, staleness-bounded snapshots from the parameter server
//! ([`crate::ps`]), compute update deltas, and push coalesced delta
//! batches back; the coordinator applies complete rounds to the
//! canonical model and advances the SSP clock. Any
//! [`crate::problem::ModelProblem`] with a PS kernel runs here — Lasso
//! and MF both do. (The vendored offline crate set has no async
//! runtime; OS threads + channels give the same message-passing
//! architecture, and the paper's own implementation was likewise
//! thread-per-worker over 0MQ sockets.)

pub mod service;
pub mod supervisor;

pub use service::{run_distributed, DistributedReport};
pub use supervisor::{KillPlan, Lease, LeaseTable, MembershipEvent};
