//! The distributed runtime: an in-process, message-passing realization
//! of the STRADS architecture (paper Fig. 3 / §3) on tokio.
//!
//! One coordinator task owns the canonical model state and the sharded
//! SAP scheduler; P worker tasks own nothing but the (shared, immutable)
//! design matrix. Per round the coordinator plans blocks, ships each
//! worker its block plus a *residual snapshot* (what a remote worker's
//! stale replica would hold), the workers compute CD proposals and send
//! them back, and the coordinator applies all proposals at once — the
//! same parallel semantics the simulator models, here executed by real
//! concurrent tasks over channels. The paper's 0MQ sockets become tokio
//! mpsc channels; everything else is structurally identical.

pub mod service;

pub use service::{run_distributed, DistributedReport};
