//! Tiny benchmarking harness for the `cargo bench` targets (the
//! offline vendor set has no criterion). Median-of-runs wall timing
//! with warmup, plus a table printer, is all the figure benches need —
//! the statistically careful numbers live in the experiment CSVs.

use std::time::Instant;

/// Time `f` with `warmup` throwaway calls and `runs` measured calls;
/// returns (median, min, max) seconds per call.
pub fn time_fn<F: FnMut()>(warmup: usize, runs: usize, mut f: F) -> (f64, f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (samples[samples.len() / 2], samples[0], *samples.last().unwrap())
}

/// Human-friendly duration formatting for bench tables.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:8.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:8.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:8.2} ms", s * 1e3)
    } else {
        format!("{s:8.3} s ")
    }
}

/// Print one bench row: name, median, min-max range.
pub fn report(name: &str, med: f64, min: f64, max: f64) {
    println!("{name:<44} {} (min {}, max {})", fmt_secs(med), fmt_secs(min), fmt_secs(max));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_positive_and_ordered() {
        let (med, min, max) = time_fn(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(min <= med && med <= max);
        assert!(min >= 0.0);
    }

    #[test]
    fn fmt_picks_unit() {
        assert!(fmt_secs(2e-9).contains("ns"));
        assert!(fmt_secs(2e-6).contains("us"));
        assert!(fmt_secs(2e-3).contains("ms"));
        assert!(fmt_secs(2.0).contains("s "));
    }
}
