//! Parallel matrix factorization via cyclic coordinate descent
//! (paper §2.2, eqs. 3–5), in the CCD++ arrangement of Yu et al. 2012:
//! for each rank t, sweep the column w_t over row blocks, then the row
//! h_t over column blocks. Within a sweep the coordinates are mutually
//! independent (d ≡ 0 — paper §2.2 step 2), so STRADS's only lever is
//! step 3: load-balanced block formation over the power-law nnz.
//!
//! * [`NativeMf`] — host CSR implementation (reference + sweeps).
//! * [`ArtifactMf`] — the PJRT path over the mf_update_w/h artifacts.
//! * [`DistMf`] — MF as a `ModelProblem` over the parameter server
//!   (`ps::`), for real-thread distributed runs.
//! * [`run_mf`] — the Fig-5 driver: runs CCD with either balanced or
//!   uniform blocks on a virtual cluster and records the trace.

pub mod artifact;
pub mod dist;
pub mod native;

pub use artifact::ArtifactMf;
pub use dist::{DistMf, MfPsKernel};
pub use native::NativeMf;

use crate::config::{CostModelConfig, EngineConfig};
use crate::coordinator::balance::{imbalance, partition_balanced, partition_uniform};
use crate::metrics::{Trace, TracePoint};
use crate::problem::Block;
use crate::sim::{CostModel, VirtualCluster};
use std::time::Instant;

/// An MF execution backend: rank-t sweeps over row/column blocks.
pub trait MfBackend {
    fn n(&self) -> usize;
    fn m(&self) -> usize;
    fn k(&self) -> usize;
    /// Update w_t for the given row block (independent rows).
    fn sweep_w_block(&mut self, t: usize, rows: &[usize]);
    /// Update h_t for the given column block (independent columns).
    fn sweep_h_block(&mut self, t: usize, cols: &[usize]);
    /// Called once per rank before its sweeps (residual bookkeeping).
    fn begin_rank(&mut self, t: usize);
    /// Called once per rank after both sweeps.
    fn end_rank(&mut self, t: usize);
    fn objective(&mut self) -> f64;
    /// nnz per row / per column (the load-balance weights).
    fn row_weights(&self) -> Vec<u64>;
    fn col_weights(&self) -> Vec<u64>;
}

/// Block partitioning policy for the MF sweeps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MfPartition {
    /// STRADS: equal-nnz blocks (paper §2.2 step 3).
    Balanced,
    /// Baseline: equal-count contiguous blocks ("no load balancing").
    Uniform,
}

impl MfPartition {
    pub fn name(self) -> &'static str {
        match self {
            MfPartition::Balanced => "balanced",
            MfPartition::Uniform => "uniform",
        }
    }

    fn partition(self, weights: &[u64], p: usize) -> Vec<Block> {
        match self {
            MfPartition::Balanced => partition_balanced(weights, p),
            MfPartition::Uniform => partition_uniform(weights, p),
        }
    }
}

/// Run CCD for `cfg.max_rounds` outer iterations on `p` virtual
/// workers, recording objective vs virtual time.
pub fn run_mf(
    backend: &mut dyn MfBackend,
    partition: MfPartition,
    p: usize,
    cfg: &EngineConfig,
    cost_cfg: &CostModelConfig,
    trace: &mut Trace,
) {
    let wall = Instant::now();
    let mut cluster = VirtualCluster::new(p, 1, CostModel::new(cost_cfg));
    // Block structure is a function of the (static) nnz histogram; both
    // policies compute it once up front.
    let row_blocks = partition.partition(&backend.row_weights(), p);
    let col_blocks = partition.partition(&backend.col_weights(), p);
    let imb = imbalance(&row_blocks).max(imbalance(&col_blocks));

    for outer in 0..cfg.max_rounds {
        for t in 0..backend.k() {
            backend.begin_rank(t);
            // W sweep: one dispatch wave of row blocks.
            for b in &row_blocks {
                backend.sweep_w_block(t, &b.vars);
            }
            cluster.advance_round(&row_blocks, 0.0);
            // H sweep: one dispatch wave of column blocks.
            for b in &col_blocks {
                backend.sweep_h_block(t, &b.vars);
            }
            cluster.advance_round(&col_blocks, 0.0);
            backend.end_rank(t);
        }
        if outer % cfg.record_every == 0 || outer + 1 == cfg.max_rounds {
            trace.push(TracePoint {
                round: outer,
                vtime: cluster.now(),
                wtime: wall.elapsed().as_secs_f64(),
                objective: backend.objective(),
                active_vars: backend.n() + backend.m(),
                imbalance: imb,
                staleness: 0.0,
                net_bytes: 0,
                sched_wait: 0.0,
                gate_waits: 0,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::mf_powerlaw::{generate, MfSynthSpec};

    #[test]
    fn balanced_partition_finishes_sooner_in_vtime() {
        let spec = MfSynthSpec { nnz: 5000, ..MfSynthSpec::yahoo_like() };
        let spec = MfSynthSpec { n_users: 256, m_items: 128, rank: 4, ..spec };
        let data = generate(&spec, 3);
        let cfg = EngineConfig { max_rounds: 3, record_every: 1, ..Default::default() };
        let cost = CostModelConfig::default();

        let mut t_bal = Trace::new("balanced", "tiny", 8);
        let mut b1 = NativeMf::new(&data.a, 4, 0.05, 7);
        run_mf(&mut b1, MfPartition::Balanced, 8, &cfg, &cost, &mut t_bal);

        let mut t_uni = Trace::new("uniform", "tiny", 8);
        let mut b2 = NativeMf::new(&data.a, 4, 0.05, 7);
        run_mf(&mut b2, MfPartition::Uniform, 8, &cfg, &cost, &mut t_uni);

        // Same number of outer iterations, same updates — balanced
        // blocks must cost less virtual time (smaller straggler).
        assert!(t_bal.final_vtime() < t_uni.final_vtime());
        // and identical final objective trajectory shape: both decrease
        assert!(t_bal.final_objective() < t_bal.points[0].objective);
        assert!(t_uni.final_objective() < t_uni.points[0].objective);
    }
}
