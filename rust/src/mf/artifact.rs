//! Artifact-backed MF: the rank-t sweeps execute as AOT-compiled XLA
//! graphs (masked rank-1 Pallas kernel inside) through PJRT. W and H
//! round-trip host<->device per block call; the ratings + mask stay
//! device-resident.
//!
//! Unlike the native backend, the artifact graphs recompute the
//! residual from (A, W, H) on the fly (rt = A - WH + w_t h_t^T inside
//! the graph), so there is no host residual bookkeeping at all —
//! `begin_rank`/`end_rank` are no-ops and the factors are the only
//! state. Rows within a sweep are independent, so chaining block calls
//! (each receiving the previous call's W) is exactly the parallel
//! semantics.

use super::MfBackend;
use crate::runtime::MfExes;
use crate::sparse::CsrMatrix;
use crate::util::Rng;

pub struct ArtifactMf {
    exes: MfExes,
    pub w: Vec<f32>,
    pub h: Vec<f32>,
    lambda: f32,
    row_nnz: Vec<u64>,
    col_nnz: Vec<u64>,
}

impl ArtifactMf {
    pub fn new(exes: MfExes, a: &CsrMatrix, lambda: f32, seed: u64) -> Self {
        assert_eq!(a.nrows(), exes.n);
        assert_eq!(a.ncols(), exes.m);
        let k = exes.k;
        let mut rng = Rng::new(seed);
        let scale = 1.0 / (k as f64).sqrt();
        let w: Vec<f32> = (0..exes.n * k).map(|_| (rng.normal() * scale) as f32).collect();
        let h: Vec<f32> = (0..k * exes.m).map(|_| (rng.normal() * scale) as f32).collect();
        let row_nnz = (0..a.nrows()).map(|i| a.row_nnz(i) as u64).collect();
        let col_nnz = a.col_nnz().into_iter().map(|c| c as u64).collect();
        ArtifactMf { exes, w, h, lambda, row_nnz, col_nnz }
    }
}

impl MfBackend for ArtifactMf {
    fn n(&self) -> usize {
        self.exes.n
    }

    fn m(&self) -> usize {
        self.exes.m
    }

    fn k(&self) -> usize {
        self.exes.k
    }

    fn begin_rank(&mut self, _t: usize) {}

    fn end_rank(&mut self, _t: usize) {}

    fn sweep_w_block(&mut self, t: usize, rows: &[usize]) {
        let (_w_new, _dw, w_next) = self
            .exes
            .update_w(&self.w, &self.h, rows, t, self.lambda)
            .expect("mf_update_w artifact call failed");
        self.w = w_next;
    }

    fn sweep_h_block(&mut self, t: usize, cols: &[usize]) {
        let (_h_new, _dh, h_next) = self
            .exes
            .update_h(&self.w, &self.h, cols, t, self.lambda)
            .expect("mf_update_h artifact call failed");
        self.h = h_next;
    }

    fn objective(&mut self) -> f64 {
        self.exes
            .objective(&self.w, &self.h, self.lambda)
            .expect("mf_obj artifact call failed")
    }

    fn row_weights(&self) -> Vec<u64> {
        self.row_nnz.clone()
    }

    fn col_weights(&self) -> Vec<u64> {
        self.col_nnz.clone()
    }
}
