//! Host CSR implementation of the CCD++ sweeps (reference backend).

use super::MfBackend;
use crate::sparse::CsrMatrix;
use crate::util::Rng;

/// Native MF state: W row-major [n, k], H row-major [k, m], plus the
/// observed-entry residual kept aligned with the CSR (and its
/// transpose, for the column sweeps).
pub struct NativeMf {
    a: CsrMatrix,
    at: CsrMatrix,
    pub w: Vec<f32>,
    pub h: Vec<f32>,
    k: usize,
    lambda: f32,
    /// rt_ij = r_ij + w_ti h_tj for the rank currently being swept,
    /// stored per observed entry in CSR order...
    rt: Vec<f32>,
    /// ... and in CSC (transposed CSR) order for the H sweep.
    rt_t: Vec<f32>,
    /// Residual r_ij = a_ij - w_i . h_j in CSR order.
    r: Vec<f32>,
}

impl NativeMf {
    pub fn new(a: &CsrMatrix, k: usize, lambda: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let scale = 1.0 / (k as f64).sqrt();
        let w: Vec<f32> = (0..a.nrows() * k).map(|_| (rng.normal() * scale) as f32).collect();
        let h: Vec<f32> = (0..k * a.ncols()).map(|_| (rng.normal() * scale) as f32).collect();
        let at = a.transpose();
        let mut s = NativeMf {
            a: a.clone(),
            at,
            w,
            h,
            k,
            lambda,
            rt: Vec::new(),
            rt_t: Vec::new(),
            r: Vec::new(),
        };
        s.recompute_residual();
        s
    }

    /// r_ij = a_ij - w_i . h_j over observed entries (CSR order).
    fn recompute_residual(&mut self) {
        let k = self.k;
        let m = self.a.ncols();
        let mut r = Vec::with_capacity(self.a.nnz());
        for i in 0..self.a.nrows() {
            let wi = &self.w[i * k..(i + 1) * k];
            for (j, aij) in self.a.row(i) {
                let mut pred = 0.0f32;
                for t in 0..k {
                    pred += wi[t] * self.h[t * m + j];
                }
                r.push(aij - pred);
            }
        }
        self.r = r;
    }

    /// Scatter the CSR-ordered `rt` into CSC (transposed) order.
    fn rt_to_transposed(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rt.len()];
        // cursor[j] = start offset of column j of A in CSC (= row j of
        // A^T) order; advance as entries stream by in CSR order.
        let mut cursor: Vec<usize> =
            (0..self.at.nrows()).map(|j| self.at.row_start(j)).collect();
        let mut pos = 0usize;
        for i in 0..self.a.nrows() {
            for (j, _) in self.a.row(i) {
                out[cursor[j]] = self.rt[pos];
                cursor[j] += 1;
                pos += 1;
            }
        }
        out
    }

    /// Gather CSC-ordered values back into CSR order.
    fn transposed_to_rt(&self, rt_t: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; rt_t.len()];
        let mut cursor: Vec<usize> =
            (0..self.at.nrows()).map(|j| self.at.row_start(j)).collect();
        let mut pos = 0usize;
        for i in 0..self.a.nrows() {
            for (j, _) in self.a.row(i) {
                out[pos] = rt_t[cursor[j]];
                cursor[j] += 1;
                pos += 1;
            }
        }
        out
    }
}

impl MfBackend for NativeMf {
    fn n(&self) -> usize {
        self.a.nrows()
    }

    fn m(&self) -> usize {
        self.a.ncols()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn begin_rank(&mut self, t: usize) {
        // rt_ij = r_ij + w_ti h_tj  (CSR order), and its CSC mirror.
        let k = self.k;
        let m = self.a.ncols();
        let mut rt = Vec::with_capacity(self.r.len());
        let mut pos = 0usize;
        for i in 0..self.a.nrows() {
            let wti = self.w[i * k + t];
            for (j, _) in self.a.row(i) {
                rt.push(self.r[pos] + wti * self.h[t * m + j]);
                pos += 1;
            }
        }
        self.rt = rt;
        self.rt_t = self.rt_to_transposed();
    }

    fn sweep_w_block(&mut self, t: usize, rows: &[usize]) {
        // Eq. (4): w_ti <- sum_j rt_ij h_tj / (lambda + sum_j h_tj^2).
        // Rows are independent; this block's updates read only rt and
        // h_t, both frozen for the rank — snapshot semantics hold for
        // any block interleaving.
        let k = self.k;
        let m = self.a.ncols();
        for &i in rows {
            let mut num = 0.0f32;
            let mut den = self.lambda;
            let lo: usize = self.a_row_start(i);
            let mut pos = lo;
            for (j, _) in self.a.row(i) {
                let htj = self.h[t * m + j];
                num += self.rt[pos] * htj;
                den += htj * htj;
                pos += 1;
            }
            self.w[i * k + t] = num / den;
        }
    }

    fn sweep_h_block(&mut self, t: usize, cols: &[usize]) {
        // Eq. (5) with the *updated* w_t (CCD++ ordering), over the
        // transposed storage.
        let k = self.k;
        for &j in cols {
            let mut num = 0.0f32;
            let mut den = self.lambda;
            let lo = self.at_row_start(j);
            let mut pos = lo;
            for (i, _) in self.at.row(j) {
                let wti = self.w[i * k + t];
                num += self.rt_t[pos] * wti;
                den += wti * wti;
                pos += 1;
            }
            self.h[t * self.a.ncols() + j] = num / den;
        }
    }

    fn end_rank(&mut self, t: usize) {
        // Pull the (possibly h-sweep-updated) rt_t back to CSR order,
        // then r_ij = rt_ij - w_ti h_tj with the new factors.
        self.rt = self.transposed_to_rt(&self.rt_t);
        let k = self.k;
        let m = self.a.ncols();
        let mut pos = 0usize;
        for i in 0..self.a.nrows() {
            let wti = self.w[i * k + t];
            for (j, _) in self.a.row(i) {
                self.r[pos] = self.rt[pos] - wti * self.h[t * m + j];
                pos += 1;
            }
        }
    }

    fn objective(&mut self) -> f64 {
        // Exact recompute (drift-corrects the maintained residual).
        self.recompute_residual();
        let sse: f64 = self.r.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let reg: f64 = self.w.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            + self.h.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
        sse + self.lambda as f64 * reg
    }

    fn row_weights(&self) -> Vec<u64> {
        (0..self.a.nrows()).map(|i| self.a.row_nnz(i) as u64).collect()
    }

    fn col_weights(&self) -> Vec<u64> {
        (0..self.at.nrows()).map(|j| self.at.row_nnz(j) as u64).collect()
    }
}

impl NativeMf {
    #[inline]
    fn a_row_start(&self, i: usize) -> usize {
        self.a.row_start(i)
    }

    #[inline]
    fn at_row_start(&self, j: usize) -> usize {
        self.at.row_start(j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::mf_powerlaw::{generate, MfSynthSpec};

    fn tiny_backend(seed: u64) -> NativeMf {
        let data = generate(&MfSynthSpec::tiny(), seed);
        NativeMf::new(&data.a, 4, 0.05, seed + 1)
    }

    fn full_ccd_iteration(b: &mut NativeMf) {
        let n = b.n();
        let m = b.m();
        let rows: Vec<usize> = (0..n).collect();
        let cols: Vec<usize> = (0..m).collect();
        for t in 0..b.k() {
            b.begin_rank(t);
            b.sweep_w_block(t, &rows);
            b.sweep_h_block(t, &cols);
            b.end_rank(t);
        }
    }

    #[test]
    fn objective_decreases_over_iterations() {
        let mut b = tiny_backend(1);
        let mut prev = b.objective();
        for it in 0..5 {
            full_ccd_iteration(&mut b);
            let obj = b.objective();
            assert!(obj < prev + 1e-6, "iter {it}: {obj} vs {prev}");
            prev = obj;
        }
    }

    #[test]
    fn block_interleaving_does_not_change_result() {
        // rows are independent: any block split gives identical factors
        let mut whole = tiny_backend(2);
        let mut split = tiny_backend(2);
        let n = whole.n();
        let m = whole.m();
        let all_rows: Vec<usize> = (0..n).collect();
        let all_cols: Vec<usize> = (0..m).collect();
        for t in 0..whole.k() {
            whole.begin_rank(t);
            whole.sweep_w_block(t, &all_rows);
            whole.sweep_h_block(t, &all_cols);
            whole.end_rank(t);

            split.begin_rank(t);
            split.sweep_w_block(t, &all_rows[..n / 3]);
            split.sweep_w_block(t, &all_rows[n / 3..2 * n / 3]);
            split.sweep_w_block(t, &all_rows[2 * n / 3..]);
            split.sweep_h_block(t, &all_cols[m / 2..]);
            split.sweep_h_block(t, &all_cols[..m / 2]);
            split.end_rank(t);
        }
        assert_eq!(whole.w, split.w);
        assert_eq!(whole.h, split.h);
    }

    #[test]
    fn recovers_planted_structure() {
        let mut b = tiny_backend(3);
        let start = b.objective();
        for _ in 0..10 {
            full_ccd_iteration(&mut b);
        }
        let end = b.objective();
        assert!(end < 0.3 * start, "start {start} end {end}");
    }

    #[test]
    fn weights_match_csr() {
        let data = generate(&MfSynthSpec::tiny(), 4);
        let b = NativeMf::new(&data.a, 4, 0.05, 5);
        let rw = b.row_weights();
        assert_eq!(rw.len(), data.a.nrows());
        assert_eq!(rw.iter().sum::<u64>() as usize, data.a.nnz());
        let cw = b.col_weights();
        assert_eq!(cw.iter().sum::<u64>() as usize, data.a.nnz());
    }
}
