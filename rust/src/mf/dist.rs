//! MF as a [`ModelProblem`] over the parameter server: CCD++ rank
//! sweeps decomposed into PS rounds so matrix factorization runs on
//! real worker threads (workers::service) like Lasso does.
//!
//! Round structure: round `2q` updates `w_t` (rank `t = q mod k`) over
//! load-balanced row blocks, round `2q+1` updates `h_t` over column
//! blocks — with staleness 0 this is exactly the CCD++ ordering of Yu
//! et al. (the H sweep sees the freshly applied `w_t`), and with a
//! staleness bound `s` it is bounded-stale CCD.
//!
//! PS key space: `0..n*k` is W row-major (`w[i*k+t]`),
//! `n*k..n*k+k*m` is H rank-major (`h[t*m+j]`), and the tail
//! `base_r..base_r+nnz` is the observed-entry residual in A's CSR
//! order. W, H and R are registered as three dense f32 epoch segments,
//! and the canonical coordinator arrays are themselves f32: at
//! staleness 0 a server cell and its canonical counterpart see the
//! identical sequence of f32 additions (blocks partition rows/columns,
//! so every key is touched by at most one worker per round, and the
//! SSP gate serializes rounds), which keeps the epoch slabs bitwise in
//! lockstep with the coordinator and means nothing needs republishing.
//! Under staleness >= 1 flushes from different rounds can reach the
//! server out of the coordinator's apply order, so (addition not being
//! associative) server cells may drift from the canonical arrays by
//! rounding — at f32 ulp scale now, exactly as the previous f64 cells
//! drifted at f64 ulp scale; bounded-stale CCD is stochastic in that
//! regime and no test or invariant relies on stale-run lockstep.
//! Workers push f64 deltas for the factor they updated plus the
//! implied residual deltas.
//!
//! The lockstep argument assumes the dense segments are registered
//! (the default). With `ps.dense_segments = 0` the hashed cells
//! accumulate the same deltas in f64 while the coordinator rounds to
//! f32, so pulled values can differ from the canonical arrays by ulps
//! and staleness-0 parity with the local executor is approximate
//! rather than bitwise (the A/B knob remains bitwise-faithful for
//! Lasso, whose residual is coordinator-republished, not
//! worker-accumulated).
//!
//! The f32 state is a deliberate precision trade scoped to this PS
//! wrapper: it buys the 4-byte wire and the bitwise server lockstep.
//! [`crate::mf::NativeMf`] remains the full-precision (f64) local
//! CCD++ backend for engine-path runs that never touch the parameter
//! server.

use crate::problem::{Block, ModelProblem, RoundResult};
use crate::ps::{Cell, PsKernel, PsSnapshot, PullSpec, RangePull};
use crate::sparse::CsrMatrix;
use crate::util::Rng;
use std::sync::Arc;

/// Decode a PS round into (rank, is_w_phase). Shared by the planner,
/// the kernel, and the local executor — they must agree exactly.
#[inline]
fn rank_phase(round: u64, k: usize) -> (usize, bool) {
    (((round / 2) as usize) % k, round % 2 == 0)
}

/// Shared immutable data + compute for the MF worker side.
pub struct MfPsKernel {
    a: Arc<CsrMatrix>,
    at: Arc<CsrMatrix>,
    /// At-order entry index -> A-order CSR position (for residual keys).
    at_to_a_pos: Arc<Vec<usize>>,
    n: usize,
    m: usize,
    k: usize,
    lambda: f64,
}

impl MfPsKernel {
    #[inline]
    fn base_h(&self) -> usize {
        self.n * self.k
    }

    #[inline]
    fn base_r(&self) -> usize {
        self.n * self.k + self.k * self.m
    }
}

impl PsKernel for MfPsKernel {
    fn pull_spec(&self, vars: &[usize], round: u64) -> PullSpec {
        let (t, w_phase) = rank_phase(round, self.k);
        let (base_h, base_r) = (self.base_h(), self.base_r());
        let mut spec = PullSpec::default();
        if w_phase {
            // The whole h_t row is one contiguous range; so is each
            // row's residual run (A CSR order). Only the per-row w cell
            // is scattered. `propose` addresses everything by key, so
            // range-vs-key placement is free to differ.
            spec.push_range(base_h + t * self.m, self.m);
            for &i in vars {
                spec.push_key(i * self.k + t);
                spec.push_range(base_r + self.a.row_start(i), self.a.row_nnz(i));
            }
        } else {
            // The w_t column is k-strided and each column's residual
            // entries live in A order via the transpose mapping — both
            // scattered (but still hash-free under a dense segment).
            spec.keys.extend((0..self.n).map(|i| i * self.k + t));
            for &v in vars {
                let j = v - self.n;
                spec.push_key(base_h + t * self.m + j);
                let lo = self.at.row_start(j);
                spec.keys.extend(
                    (lo..lo + self.at.row_nnz(j)).map(|e| base_r + self.at_to_a_pos[e]),
                );
            }
        }
        spec
    }

    fn propose(&self, snap: &PsSnapshot, vars: &[usize], round: u64) -> Vec<(usize, f64)> {
        let (t, w_phase) = rank_phase(round, self.k);
        let (base_h, base_r) = (self.base_h(), self.base_r());
        let mut deltas = Vec::new();
        if w_phase {
            // Eq. (4): w_ti <- sum_j rt_ij h_tj / (lambda + sum_j h_tj^2)
            // with rt_ij = r_ij + w_ti h_tj.
            for &i in vars {
                let w_key = i * self.k + t;
                let w_ti = snap.get(w_key).unwrap_or(0.0);
                let mut num = 0.0f64;
                let mut den = self.lambda;
                let mut pos = self.a.row_start(i);
                let mut touched: Vec<(usize, f64)> = Vec::with_capacity(self.a.row_nnz(i));
                for (j, _) in self.a.row(i) {
                    let htj = snap.get(base_h + t * self.m + j).unwrap_or(0.0);
                    let rt = snap.get(base_r + pos).unwrap_or(0.0) + w_ti * htj;
                    num += rt * htj;
                    den += htj * htj;
                    touched.push((pos, htj));
                    pos += 1;
                }
                let dw = num / den - w_ti;
                deltas.push((w_key, dw));
                for (pos, htj) in touched {
                    deltas.push((base_r + pos, -dw * htj));
                }
            }
        } else {
            // Eq. (5) with the (freshly applied, staleness permitting)
            // w_t: h_tj <- sum_i rt_ij w_ti / (lambda + sum_i w_ti^2).
            for &v in vars {
                let j = v - self.n;
                let h_key = base_h + t * self.m + j;
                let h_tj = snap.get(h_key).unwrap_or(0.0);
                let mut num = 0.0f64;
                let mut den = self.lambda;
                let mut e = self.at.row_start(j);
                let mut touched: Vec<(usize, f64)> = Vec::with_capacity(self.at.row_nnz(j));
                for (i, _) in self.at.row(j) {
                    let w_ti = snap.get(i * self.k + t).unwrap_or(0.0);
                    let pos = self.at_to_a_pos[e];
                    let rt = snap.get(base_r + pos).unwrap_or(0.0) + w_ti * h_tj;
                    num += rt * w_ti;
                    den += w_ti * w_ti;
                    touched.push((pos, w_ti));
                    e += 1;
                }
                let dh = num / den - h_tj;
                deltas.push((h_key, dh));
                for (pos, w_ti) in touched {
                    deltas.push((base_r + pos, -w_ti * dh));
                }
            }
        }
        deltas
    }
}

/// The coordinator-side MF state. The arrays are f32 — the dense
/// segment wire precision — so the server's additive epoch slabs stay
/// bitwise identical to the canonical arrays (same f32 additions in
/// the same per-key order), and the local executor reproduces the
/// distributed staleness-0 run exactly.
pub struct DistMf {
    kernel: Arc<MfPsKernel>,
    w: Vec<f32>,
    h: Vec<f32>,
    /// Residual r_ij = a_ij - w_i . h_j per observed entry, A CSR order.
    r: Vec<f32>,
    /// Row/column nnz, the load-balance weights.
    row_weights: Vec<u64>,
    col_weights: Vec<u64>,
    /// Round counter for the local (engine-path) executor only.
    local_round: u64,
}

impl DistMf {
    pub fn new(a: &CsrMatrix, k: usize, lambda: f64, seed: u64) -> Self {
        let n = a.nrows();
        let m = a.ncols();
        let mut rng = Rng::new(seed);
        let scale = 1.0 / (k as f64).sqrt();
        let w: Vec<f32> = (0..n * k).map(|_| (rng.normal() * scale) as f32).collect();
        let h: Vec<f32> = (0..k * m).map(|_| (rng.normal() * scale) as f32).collect();

        let at = a.transpose();
        // At entry index -> A CSR position (cursor scatter, same trick
        // as NativeMf::rt_to_transposed).
        let mut cursor: Vec<usize> = (0..at.nrows()).map(|j| at.row_start(j)).collect();
        let mut at_to_a_pos = vec![0usize; a.nnz()];
        let mut pos = 0usize;
        for i in 0..n {
            for (j, _) in a.row(i) {
                at_to_a_pos[cursor[j]] = pos;
                cursor[j] += 1;
                pos += 1;
            }
        }

        // Initial residual from the fresh factors (f64 accumulation,
        // stored at the f32 state precision).
        let mut r = Vec::with_capacity(a.nnz());
        for i in 0..n {
            let wi = &w[i * k..(i + 1) * k];
            for (j, aij) in a.row(i) {
                let pred: f64 = (0..k).map(|t| wi[t] as f64 * h[t * m + j] as f64).sum();
                r.push((aij as f64 - pred) as f32);
            }
        }

        let row_weights = (0..n).map(|i| a.row_nnz(i) as u64).collect();
        let col_weights = (0..m).map(|j| at.row_nnz(j) as u64).collect();
        let kernel = Arc::new(MfPsKernel {
            a: Arc::new(a.clone()),
            at: Arc::new(at),
            at_to_a_pos: Arc::new(at_to_a_pos),
            n,
            m,
            k,
            lambda,
        });
        DistMf { kernel, w, h, r, row_weights, col_weights, local_round: 0 }
    }

    pub fn n(&self) -> usize {
        self.kernel.n
    }

    pub fn m(&self) -> usize {
        self.kernel.m
    }

    pub fn k(&self) -> usize {
        self.kernel.k
    }

    /// Rounds for `iters` full CCD iterations (k ranks x 2 phases).
    pub fn rounds_for_iters(&self, iters: usize) -> usize {
        iters * self.kernel.k * 2
    }

    #[inline]
    fn state_f32(&self, key: usize) -> f32 {
        let (base_h, base_r) = (self.kernel.base_h(), self.kernel.base_r());
        if key < base_h {
            self.w[key]
        } else if key < base_r {
            self.h[key - base_h]
        } else {
            self.r[key - base_r]
        }
    }
}

impl ModelProblem for DistMf {
    fn num_vars(&self) -> usize {
        self.kernel.n + self.kernel.m
    }

    fn workload(&self, v: usize) -> u64 {
        if v < self.kernel.n {
            self.row_weights[v]
        } else {
            self.col_weights[v - self.kernel.n]
        }
    }

    fn dependencies(&mut self, cands: &[usize]) -> Vec<f64> {
        // Within a phase the coordinates are mutually independent
        // (paper §2.2 step 2): d == 0.
        vec![0.0; cands.len() * cands.len()]
    }

    fn update_blocks(&mut self, blocks: &[Block]) -> RoundResult {
        // Local (engine-path) execution of one PS round: snapshot own
        // state through the same range-view representation the
        // distributed pull produces, run the kernel, apply — identical
        // math to the distributed path at staleness 0.
        let round = self.local_round;
        self.local_round += 1;
        let vars: Vec<usize> = blocks.iter().flat_map(|b| b.vars.iter().copied()).collect();
        let spec = self.kernel.pull_spec(&vars, round);
        let ranges: Vec<RangePull> = spec
            .ranges
            .iter()
            .map(|&(start, len)| {
                let values: Vec<f32> =
                    (start..start + len).map(|key| self.state_f32(key)).collect();
                RangePull::owned(start, 0, values)
            })
            .collect();
        let cells: Vec<Cell> = spec
            .keys
            .iter()
            .map(|&key| Cell { version: 0, value: self.state_f32(key) as f64 })
            .collect();
        let snap = PsSnapshot::from_pull(ranges, spec.keys, cells);
        let deltas = self.kernel.propose(&snap, &vars, round);
        let mut result = self.apply_deltas(&deltas);
        result.max_block_work = blocks.iter().map(|b| b.work).max().unwrap_or(0);
        result.total_work = blocks.iter().map(|b| b.work).sum();
        result
    }

    fn objective(&mut self) -> f64 {
        // Exact f64 recompute from the factors, non-destructive: the
        // maintained residual stays additive so it remains in lockstep
        // with the PS cells.
        let (n, m, k) = (self.kernel.n, self.kernel.m, self.kernel.k);
        let mut sse = 0.0f64;
        for i in 0..n {
            let wi = &self.w[i * k..(i + 1) * k];
            for (j, aij) in self.kernel.a.row(i) {
                let pred: f64 =
                    (0..k).map(|t| wi[t] as f64 * self.h[t * m + j] as f64).sum();
                let e = aij as f64 - pred;
                sse += e * e;
            }
        }
        let reg: f64 = self.w.iter().map(|&v| v as f64 * v as f64).sum::<f64>()
            + self.h.iter().map(|&v| v as f64 * v as f64).sum::<f64>();
        sse + self.kernel.lambda * reg
    }

    fn active_vars(&self) -> usize {
        self.kernel.n + self.kernel.m
    }

    fn ps_state(&self) -> Vec<f64> {
        let mut state: Vec<f64> = self.w.iter().map(|&v| v as f64).collect();
        state.extend(self.h.iter().map(|&v| v as f64));
        state.extend(self.r.iter().map(|&v| v as f64));
        state
    }

    fn ps_state_f32(&self) -> Option<Vec<f32>> {
        // The factors and residuals are canonically f32 already: ship
        // them raw. Bit-identical to the f64 path (widen then narrow
        // is the identity on f32 values), minus two full-state copies.
        let mut state = Vec::with_capacity(self.w.len() + self.h.len() + self.r.len());
        state.extend_from_slice(&self.w);
        state.extend_from_slice(&self.h);
        state.extend_from_slice(&self.r);
        Some(state)
    }

    fn ps_kernel(&self) -> Option<Arc<dyn PsKernel>> {
        Some(Arc::clone(&self.kernel) as Arc<dyn PsKernel>)
    }

    fn ps_dense_segments(&self) -> Vec<(usize, usize)> {
        // W, H and the per-entry residual are all contiguous and all
        // touched every sweep. Three segments (not one) so a phase's
        // copy-on-publish clones only the slabs it writes, and no pull
        // range ever spans a factor/residual boundary.
        let (base_h, base_r) = (self.kernel.base_h(), self.kernel.base_r());
        vec![(0, base_h), (base_h, base_r - base_h), (base_r, self.r.len())]
    }

    fn apply_deltas(&mut self, deltas: &[(usize, f64)]) -> RoundResult {
        let (base_h, base_r) = (self.kernel.base_h(), self.kernel.base_r());
        let (k, m, n) = (self.kernel.k, self.kernel.m, self.kernel.n);
        let mut out = Vec::new();
        for &(key, delta) in deltas {
            // f32 accumulation, matching the server's epoch slabs bit
            // for bit (same delta, same order, same precision).
            if key < base_h {
                self.w[key] += delta as f32;
                out.push((key / k, delta.abs()));
            } else if key < base_r {
                let idx = key - base_h;
                self.h[idx] += delta as f32;
                out.push((n + idx % m, delta.abs()));
            } else {
                self.r[key - base_r] += delta as f32;
            }
        }
        let total = out.len() as u64;
        RoundResult { deltas: out, objective: None, max_block_work: 1, total_work: total }
    }

    fn plan_round(&mut self, round: usize, p: usize) -> Option<Vec<Block>> {
        use crate::coordinator::balance::partition_balanced;
        let (_, w_phase) = rank_phase(round as u64, self.kernel.k);
        if w_phase {
            Some(partition_balanced(&self.row_weights, p))
        } else {
            let mut blocks = partition_balanced(&self.col_weights, p);
            for b in &mut blocks {
                for v in &mut b.vars {
                    *v += self.kernel.n;
                }
            }
            Some(blocks)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::mf_powerlaw::{generate, MfSynthSpec};

    fn tiny(seed: u64) -> DistMf {
        let data = generate(&MfSynthSpec::tiny(), seed);
        DistMf::new(&data.a, 4, 0.05, seed + 1)
    }

    /// Drive full CCD iterations through the plan_round/update_blocks
    /// pair (the engine-path execution of the PS round structure).
    fn run_rounds_local(p: &mut DistMf, rounds: usize, workers: usize) {
        for round in 0..rounds {
            let blocks = p.plan_round(round, workers).expect("MF plans its own rounds");
            p.update_blocks(&blocks);
        }
    }

    #[test]
    fn objective_decreases_over_ccd_iterations() {
        let mut p = tiny(11);
        let one_iter = p.rounds_for_iters(1);
        let mut prev = p.objective();
        for it in 0..4 {
            run_rounds_local(&mut p, one_iter, 4);
            let obj = p.objective();
            // 1e-6 slack: each f32-rounded update sits within O(eps^2)
            // of the per-coordinate minimizer, so tiny upticks are
            // rounding, not regressions.
            assert!(obj < prev + 1e-6, "iter {it}: {obj} vs {prev}");
            prev = obj;
        }
    }

    #[test]
    fn recovers_planted_structure() {
        let mut p = tiny(12);
        let rounds = p.rounds_for_iters(10);
        let start = p.objective();
        run_rounds_local(&mut p, rounds, 8);
        let end = p.objective();
        assert!(end < 0.3 * start, "start {start} end {end}");
    }

    #[test]
    fn plan_round_alternates_rows_and_columns() {
        let mut p = tiny(13);
        let n = p.n();
        let w_blocks = p.plan_round(0, 4).unwrap();
        assert!(w_blocks.iter().all(|b| b.vars.iter().all(|&v| v < n)));
        let rows: usize = w_blocks.iter().map(|b| b.vars.len()).sum();
        assert_eq!(rows, n, "every row scheduled exactly once");
        let h_blocks = p.plan_round(1, 4).unwrap();
        assert!(h_blocks.iter().all(|b| b.vars.iter().all(|&v| v >= n)));
        let cols: usize = h_blocks.iter().map(|b| b.vars.len()).sum();
        assert_eq!(cols, p.m());
    }

    #[test]
    fn residual_stays_consistent_with_factors() {
        // After updates, the maintained additive residual must match
        // a_ij - w_i . h_j to f32 accumulation accuracy.
        let mut p = tiny(14);
        let rounds = p.rounds_for_iters(2);
        run_rounds_local(&mut p, rounds, 4);
        let (k, m) = (p.k(), p.m());
        let mut pos = 0usize;
        let a = Arc::clone(&p.kernel.a);
        for i in 0..p.n() {
            for (j, aij) in a.row(i) {
                let pred: f64 = (0..k)
                    .map(|t| p.w[i * k + t] as f64 * p.h[t * m + j] as f64)
                    .sum();
                let want = aij as f64 - pred;
                assert!(
                    (p.r[pos] as f64 - want).abs() < 1e-4,
                    "entry ({i},{j}): maintained {} vs exact {want}",
                    p.r[pos]
                );
                pos += 1;
            }
        }
    }

    #[test]
    fn block_split_does_not_change_result() {
        // Rows/cols within a phase are independent: 1-worker and
        // 8-worker plans must produce bitwise identical factors at
        // staleness 0 (same snapshots, same f32 additions per key).
        let mut a1 = tiny(15);
        let mut a8 = tiny(15);
        let rounds = a1.rounds_for_iters(2);
        run_rounds_local(&mut a1, rounds, 1);
        run_rounds_local(&mut a8, rounds, 8);
        for (x, y) in a1.w.iter().zip(a8.w.iter()) {
            assert_eq!(x, y);
        }
        for (x, y) in a1.h.iter().zip(a8.h.iter()) {
            assert_eq!(x, y);
        }
    }
}
