//! The `ModelProblem` abstraction: what an ML program must expose for
//! SAP to schedule it (paper §2's `p(j)` / `d(x_j, x_k)` programming
//! interface, plus a parallel-round executor).
//!
//! A *round* is one SAP iteration: the scheduler hands the problem a set
//! of variable blocks; the problem applies all updates with parallel
//! semantics — every block reads the same state snapshot, exactly what P
//! distributed workers holding a stale copy would compute — and reports
//! per-variable progress δ for step 4.
//!
//! The `ps_*` family of hooks is the distributed counterpart: a problem
//! that also exposes its shared state as a flat key space plus a
//! thread-shareable [`PsKernel`] can run on real worker threads through
//! the sharded parameter server (`ps::`), with the coordinator applying
//! the flushed deltas to the canonical state via [`ModelProblem::apply_deltas`].

use crate::ps::PsKernel;
use std::sync::Arc;

/// A block of variables dispatched to one worker.
#[derive(Clone, Debug, PartialEq)]
pub struct Block {
    /// Variable ids (coordinates for Lasso; rows/columns for MF).
    pub vars: Vec<usize>,
    /// Total workload units (cost-model input; nnz for MF, |vars| * 1
    /// for Lasso).
    pub work: u64,
}

impl Block {
    pub fn singleton(var: usize, work: u64) -> Self {
        Block { vars: vec![var], work }
    }
}

/// What one parallel round produced.
#[derive(Clone, Debug, Default)]
pub struct RoundResult {
    /// (variable, |δ|) progress magnitudes — feeds p(j) (SAP step 4).
    pub deltas: Vec<(usize, f64)>,
    /// Cheap objective value if the problem maintains one incrementally
    /// (None forces the engine to call `objective()` on record rounds).
    pub objective: Option<f64>,
    /// Workload of the largest block (straggler) and the total.
    pub max_block_work: u64,
    pub total_work: u64,
}

/// A schedulable ML program.
pub trait ModelProblem {
    /// Number of schedulable variables J.
    fn num_vars(&self) -> usize;

    /// Workload units of variable `j` (drives load balancing, step 3).
    fn workload(&self, j: usize) -> u64;

    /// Pairwise dependency strengths |d(x_j, x_k)| over a candidate set;
    /// row-major `c x c` with 0 diagonal (step 2's input). Problems with
    /// independent variables (MF) return all zeros.
    fn dependencies(&mut self, cands: &[usize]) -> Vec<f64>;

    /// Whether [`Self::dependency_pair`] is cheap. When true the greedy
    /// selection queries pairs on demand (O(c·P) with early exit,
    /// typically far less) instead of materializing the dense c x c
    /// matrix — the native backend's host dots want this; the artifact
    /// backend prefers one bulk Gram call on the device.
    fn supports_pair_dependency(&self) -> bool {
        false
    }

    /// Single-pair dependency |d(x_a, x_b)| (only called when
    /// [`Self::supports_pair_dependency`] is true).
    fn dependency_pair(&mut self, _a: usize, _b: usize) -> f64 {
        unimplemented!("problem does not support pair dependency queries")
    }

    /// Apply one parallel round over the given blocks.
    fn update_blocks(&mut self, blocks: &[Block]) -> RoundResult;

    /// Exact objective value (may be expensive; engine calls sparingly).
    fn objective(&mut self) -> f64;

    /// Number of currently-active (nonzero) variables, for the trace.
    fn active_vars(&self) -> usize {
        0
    }

    // --- Parameter-server hooks (the distributed path, `ps::`) ------

    /// Full shared state as a dense vector: key `i` of the PS key space
    /// holds `state[i]`. The coordinator publishes this once at round 0.
    /// Problems without a distributed path return an empty vector.
    fn ps_state(&self) -> Vec<f64> {
        Vec::new()
    }

    /// [`ModelProblem::ps_state`] as raw f32, for problems whose
    /// canonical state already is f32 (MF): the coordinator seeds the
    /// server from this without the widen-to-f64/narrow-back round
    /// trip. Must narrow to exactly the same bits as `ps_state` would
    /// (dense cells store f32 either way — pinned by test). `None`
    /// (the default) = seed through the f64 path.
    fn ps_state_f32(&self) -> Option<Vec<f32>> {
        None
    }

    /// The thread-shareable worker compute over PS snapshots. `None`
    /// (the default) means the problem cannot run distributed.
    fn ps_kernel(&self) -> Option<Arc<dyn PsKernel>> {
        None
    }

    /// Apply one round of worker-flushed, state-space deltas to the
    /// canonical model. The returned [`RoundResult`] carries progress in
    /// *variable* space (same contract as [`Self::update_blocks`]) so the
    /// scheduler's step 4 works unchanged.
    fn apply_deltas(&mut self, _deltas: &[(usize, f64)]) -> RoundResult {
        unimplemented!("problem does not support the parameter-server path")
    }

    /// Contiguous PS key ranges `(start, len)` worth registering as
    /// dense segments so reads/publishes of those ranges go through
    /// `Vec<Cell>` slabs instead of hash probes (e.g. the Lasso residual
    /// `0..n`). Ranges must be disjoint. The default (no ranges) keeps
    /// the whole key space on the hashed path.
    fn ps_dense_segments(&self) -> Vec<(usize, usize)> {
        Vec::new()
    }

    /// Derived state to overwrite-republish after [`Self::apply_deltas`]
    /// (exact canonical values, version = the applied round + 1). Lasso
    /// republishes its residual this way; problems whose PS cells stay
    /// exact under additive worker pushes return nothing.
    ///
    /// The contract is *incremental*: return only entries whose value
    /// moved by more than `tol` since they were last returned (the
    /// implementation owns the last-published image), so unchanged
    /// derived state never re-crosses the wire. `tol = 0.0` republishes
    /// exactly the entries that changed at all (lossless); `tol < 0`
    /// must republish everything (the pre-incremental behaviour, kept
    /// as a baseline). When `full` is set the coordinator is forcing a
    /// periodic full re-sync to bound accumulated drift: republish
    /// every entry and reset the image.
    fn ps_republish(&mut self, _tol: f64, _full: bool) -> Vec<(usize, f64)> {
        Vec::new()
    }

    /// Problems with intrinsic round structure (e.g. MF rank sweeps)
    /// plan their own blocks for `round`; `None` (the default) lets the
    /// coordinator's scheduler plan instead.
    fn plan_round(&mut self, _round: usize, _p: usize) -> Option<Vec<Block>> {
        None
    }

    /// Thread-shareable scheduling-side view (dependency strengths +
    /// workloads over immutable data) so the pipelined scheduler
    /// service can plan on dedicated shard threads. It must agree with
    /// [`Self::dependency_pair`] / [`Self::workload`] value-for-value —
    /// that agreement is what keeps the staleness-0 distributed path
    /// bit-exact with the engine path. `None` (the default) makes the
    /// distributed coordinator plan inline instead.
    fn sched_oracle(&self) -> Option<Arc<dyn crate::sched_service::SchedOracle>> {
        None
    }
}
