//! `strads` — leader entrypoint / CLI.
//!
//! Subcommands map one-to-one onto the paper's experiments plus the
//! operational utilities a user of the framework needs:
//!
//! ```text
//! strads fig1|fig4|fig5          # regenerate each paper figure -> CSV
//! strads run-lasso ...           # one configurable lasso run
//! strads run-mf ...              # one configurable MF run
//! strads distributed ...         # real worker threads over the sharded
//!                                #   parameter server (ps::), lasso or mf,
//!                                #   with --staleness N|async --ps-shards N
//!                                #   --ps-transport inproc|tcp
//! strads ps-server ...           # host the parameter server in its own
//!                                #   process (the tcp transport's far end)
//! strads ps-stats ...            # live registry snapshot from a running
//!                                #   ps-server (the ObsStats introspection op)
//! strads staleness-sweep ...     # fresh-vs-stale convergence curves
//! strads calibrate               # fit the cost model to this host
//! strads artifacts-info          # inspect the AOT artifact store
//! ```
//!
//! Common flags: `--config <preset>` loads a `configs/*.conf` preset;
//! `--out <dir>` selects the results directory (default `results/`).

use std::path::PathBuf;
use std::rc::Rc;
use strads::cli::Args;
use strads::config::RunConfig;
use strads::data::{lasso_synth, mf_powerlaw};
use strads::experiments::{self, SchedKind};
use strads::lasso::NativeLasso;
use strads::metrics::Trace;
use strads::mf::{run_mf, ArtifactMf, DistMf, MfPartition, NativeMf};
use strads::runtime::{default_artifacts_dir, ArtifactStore, LassoExes, MfExes};
use strads::workers::run_distributed;

const USAGE: &str = "usage: strads <fig1|fig4|fig5|ablation|run-lasso|run-mf|distributed|ps-server|ps-stats|staleness-sweep|calibrate|artifacts-info> [flags]
  global: --config <preset.conf>  --out <dir>  --seed <u64>
  fig1:        --workers N --rounds N
  fig4:        --rounds N
  fig5:        --iters N
  run-lasso:   --dataset tiny|adlike|wide --scheduler dynamic|static|random
               --workers N --rounds N --lambda F --artifacts
  run-mf:      --dataset tiny|netflix|yahoo --partition balanced|uniform
               --workers N --iters N --lambda F --artifacts
  distributed: --problem lasso|mf --dataset ... --workers N --rounds N --lambda F
               --scheduler dynamic|static|random (plans distributed rounds)
               --staleness N|async (SSP bound: pulls at most N rounds stale;
                                    'async' = no gate)  --ps-shards N
               --republish-tol F|auto (republish only derived entries that
                                  moved > F since last publish; <0 = full each
                                  round; auto = objective-scaled tolerance)
               --chunk-cells N (cells per dense-slab chunk: partial pulls pin
                                and racing publishes clone only the chunks
                                touched; 0 [default] = one chunk per segment)
               --wire-compress on|off (tcp: flush/publish batches as sorted
                                       index-delta + f32 value runs; on by
                                       default, bitwise-invisible to results)
               --dense-segments 0|1 (contiguous key ranges as dense slabs)
               --pipeline 0|1 (dispatch past the bound; SSP gate paces workers)
               --sched-shards N (scheduler service shard threads; 0 = follow
                                 sap.shards)  --sched-pipeline-depth N
               --sched-service 0|1 (0 = plan inline on the coordinator)
               --ps-transport inproc|tcp (carriage to the parameter server;
                                          tcp talks to a ps-server process)
               --ps-addr host:p1[,host:p2...] (where that ps-server listens;
                              a comma-separated list shards the parameter
                              state across an N-server fleet, wire v6)
               --retry-max N (tcp: reconnect-and-retry attempts per RPC after
                              an I/O fault; 0 [default] = fail fast)
               --retry-backoff-ms N (first backoff; doubles per attempt, 2s cap)
               --fault-plan spec (deterministic fault injection for testing:
                                  seed=S,drop=P,err=P,delay=P,delay_ms=D,
                                  every=N,ops=pull|flush)
               --obs-level 0|1|2 (0 = off, 1 = metrics registry [default],
                                  2 = metrics + per-phase span tracing)
               --trace-events path.jsonl (write span events as chrome://tracing
                                          JSONL; implies --obs-level 2)
               --elastic 0|1 (supervise workers: per-block leases, death
                              detection, reassignment, mid-run join/leave)
               --worker-kill-plan spec (deterministic membership chaos, implies
                                        --elastic: seed=S,kill=W@R,kill=@R,
                                        join=@R — fires when round R dispatches)
               --lease-ms N (block lease before a holder is presumed dead)
  ps-server:   --addr host:port (default from [ps] addr; port 0 = ephemeral)
               --report-secs N (print an [obs] digest line every N seconds)
               --checkpoint-dir dir (periodically checkpoint the hosted run
                                     there, and restore from it on restart)
               --checkpoint-every K (clock advances between checkpoints)
               --checkpoint-keep N (versioned images retained; default 2)
               hosts the sharded store + SSP clock; serves any number of
               back-to-back runs (each run re-inits it); stop with SIGTERM
  ps-stats:    --addr host:port  print a live registry snapshot (metrics,
               per-segment versions, clock state) from a running ps-server
  staleness-sweep: --dataset tiny|adlike|wide --workers N --rounds N --lambda F
               --scheduler dynamic|static|random --sched-shards N
               --republish-tol F|auto --chunk-cells N --wire-compress on|off
               --dense-segments 0|1 --pipeline 0|1
               --ps-transport inproc|tcp --ps-addr host:p1[,host:p2...]
               --retry-max N --retry-backoff-ms N --fault-plan spec
               --elastic 0|1 --worker-kill-plan spec --lease-ms N
               --obs-level 0|1|2 --trace-events path.jsonl
               (runs staleness 0, 2, 8, async for lasso AND mf through the
                parameter server; writes staleness_sweep.csv + BENCH_ps.json
                to --out)";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        eprintln!("{USAGE}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let out_dir = PathBuf::from(args.str_or("out", "results"));
    std::fs::create_dir_all(&out_dir)?;

    let mut cfg = match args.opt_str("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(&path))?,
        None => RunConfig::default(),
    };
    cfg.engine.seed = args.u64_or("seed", cfg.engine.seed)?;
    cfg.validate()?;

    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    match sub.as_str() {
        "fig1" => {
            cfg.workers = args.usize_or("workers", 32)?;
            cfg.engine.max_rounds = args.usize_or("rounds", 3000)?;
            cfg.lambda = args.f64_or("lambda", 5e-4)?;
            args.finish()?;
            let csv = out_dir.join("fig1_lasso.csv");
            let _ = std::fs::remove_file(&csv);
            experiments::fig1(&cfg, Some(&csv));
            println!("wrote {}", csv.display());
        }
        "fig4" => {
            cfg.engine.max_rounds = args.usize_or("rounds", 3000)?;
            cfg.lambda = args.f64_or("lambda", 5e-4)?;
            args.finish()?;
            let csv = out_dir.join("fig4_lasso.csv");
            let _ = std::fs::remove_file(&csv);
            experiments::fig4(&cfg, Some(&csv));
            println!("wrote {}", csv.display());
        }
        "fig5" => {
            cfg.engine.max_rounds = args.usize_or("iters", 30)?;
            args.finish()?;
            let csv = out_dir.join("fig5_mf.csv");
            let _ = std::fs::remove_file(&csv);
            experiments::fig5(&cfg, Some(&csv));
            println!("wrote {}", csv.display());
        }
        "run-lasso" => {
            let dataset = args.str_or("dataset", "tiny");
            let sched = SchedKind::parse(&args.str_or("scheduler", "dynamic"))?;
            cfg.workers = args.usize_or("workers", 16)?;
            cfg.engine.max_rounds = args.usize_or("rounds", 1000)?;
            cfg.lambda = args.f64_or("lambda", 5e-4)?;
            let use_artifacts = args.bool("artifacts");
            args.finish()?;
            let data = lasso_synth::generate(&experiments::lasso_spec(&dataset)?, cfg.engine.seed);
            let trace = if use_artifacts {
                run_lasso_artifacts(&data, &dataset, sched, &cfg)?
            } else {
                experiments::run_lasso_native(&data, &dataset, sched, &cfg)
            };
            println!("{}", trace.summary());
            let csv = out_dir.join("run_lasso.csv");
            trace.append_csv(&csv)?;
            println!("appended {}", csv.display());
        }
        "run-mf" => {
            let dataset = args.str_or("dataset", "tiny");
            let part = match args.str_or("partition", "balanced").as_str() {
                "balanced" | "strads" => MfPartition::Balanced,
                "uniform" | "none" => MfPartition::Uniform,
                other => anyhow::bail!("unknown partition {other}"),
            };
            let workers = args.usize_or("workers", 8)?;
            cfg.engine.max_rounds = args.usize_or("iters", 10)?;
            let lambda = args.f64_or("lambda", 0.05)?;
            let use_artifacts = args.bool("artifacts");
            args.finish()?;
            let data = mf_powerlaw::generate(&experiments::mf_spec(&dataset)?, cfg.engine.seed);
            let mut trace = Trace::new(part.name(), &dataset, workers);
            if use_artifacts {
                let store = Rc::new(ArtifactStore::open(&default_artifacts_dir())?);
                let mf_ds = if dataset == "tiny" { "tiny" } else { "rec" };
                let (a_dense, mask) = data.a.to_dense_row_major();
                let exes = MfExes::new(store, mf_ds, &a_dense, &mask)?;
                let mut backend =
                    ArtifactMf::new(exes, &data.a, lambda as f32, cfg.engine.seed + 1);
                run_mf(&mut backend, part, workers, &cfg.engine, &cfg.cost, &mut trace);
            } else {
                let mut backend =
                    NativeMf::new(&data.a, data.rank_true, lambda as f32, cfg.engine.seed + 1);
                run_mf(&mut backend, part, workers, &cfg.engine, &cfg.cost, &mut trace);
            }
            println!("{}", trace.summary());
            let csv = out_dir.join("run_mf.csv");
            trace.append_csv(&csv)?;
            println!("appended {}", csv.display());
        }
        "distributed" => {
            let problem_kind = args.str_or("problem", "lasso");
            let dataset = args.str_or("dataset", "tiny");
            cfg.workers = args.usize_or("workers", 4)?;
            // per-problem default regularization (lasso: engine tests'
            // 1e-3; mf: the CCD runs' 0.05)
            let lambda_default = if problem_kind == "mf" { 0.05 } else { 1e-3 };
            cfg.lambda = args.f64_or("lambda", lambda_default)?;
            let rounds = args.usize_or("rounds", 500)?;
            // only override the preset's staleness when the flag is given
            if let Some(staleness) = args.opt_str("staleness") {
                cfg.ps.set_staleness_arg(&staleness)?;
            }
            cfg.ps.shards = args.usize_or("ps-shards", cfg.ps.shards)?;
            if let Some(tol) = args.opt_str("republish-tol") {
                cfg.ps.set_republish_tol_arg(&tol)?;
            }
            cfg.ps.chunk_cells = args.usize_or("chunk-cells", cfg.ps.chunk_cells)?;
            if let Some(v) = args.opt_str("wire-compress") {
                cfg.ps.wire_compress = parse_on_off("wire-compress", &v)?;
            }
            cfg.ps.dense_segments =
                args.usize_or("dense-segments", usize::from(cfg.ps.dense_segments))? != 0;
            cfg.ps.pipeline = args.usize_or("pipeline", usize::from(cfg.ps.pipeline))? != 0;
            if let Some(kind) = args.opt_str("ps-transport") {
                cfg.ps.transport = strads::ps::TransportKind::parse(&kind)?;
            }
            cfg.ps.addr = args.str_or("ps-addr", &cfg.ps.addr);
            cfg.ps.retry_max = args.usize_or("retry-max", cfg.ps.retry_max)?;
            cfg.ps.retry_backoff_ms =
                args.u64_or("retry-backoff-ms", cfg.ps.retry_backoff_ms)?;
            cfg.ps.fault_plan = args.str_or("fault-plan", &cfg.ps.fault_plan);
            cfg.ps.elastic = args.usize_or("elastic", usize::from(cfg.ps.elastic))? != 0;
            cfg.ps.worker_kill_plan =
                args.str_or("worker-kill-plan", &cfg.ps.worker_kill_plan);
            cfg.ps.lease_ms = args.u64_or("lease-ms", cfg.ps.lease_ms)?;
            if let Some(kind) = args.opt_str("scheduler") {
                cfg.sched.kind = SchedKind::parse(&kind)?;
            }
            cfg.sched.shards = args.usize_or("sched-shards", cfg.sched.shards)?;
            cfg.sched.pipeline_depth =
                args.usize_or("sched-pipeline-depth", cfg.sched.pipeline_depth)?;
            cfg.sched.service =
                args.usize_or("sched-service", usize::from(cfg.sched.service))? != 0;
            apply_obs_flags(&args, &mut cfg)?;
            args.finish()?;
            cfg.validate()?;
            let report = match problem_kind.as_str() {
                "lasso" => {
                    let data = lasso_synth::generate(
                        &experiments::lasso_spec(&dataset)?,
                        cfg.engine.seed,
                    );
                    let mut problem = NativeLasso::new(&data, cfg.lambda);
                    run_distributed(&mut problem, &cfg, rounds, &dataset)?
                }
                "mf" => {
                    let data =
                        mf_powerlaw::generate(&experiments::mf_spec(&dataset)?, cfg.engine.seed);
                    let mut problem =
                        DistMf::new(&data.a, data.rank_true, cfg.lambda, cfg.engine.seed + 1);
                    run_distributed(&mut problem, &cfg, rounds, &dataset)?
                }
                other => anyhow::bail!("unknown problem {other} (lasso|mf)"),
            };
            println!("{}", report.trace.summary());
            println!(
                "transport={} socket_bytes={} wire.runs_encoded={} (real; metered net_bytes={})",
                report.transport,
                report.socket_bytes,
                report.runs_encoded,
                report.bytes_flushed + report.bytes_republished + report.pull_bytes
            );
            println!(
                "rounds={} deltas={} bytes_flushed={} bytes_republished={} pull_bytes={} \
                 snapshot_clones={} cow_clones={} cow_bytes={} gate_waits={} \
                 mean_staleness={:.2} max_staleness={} hash_probes={} sched_wait={:.3}s \
                 plan_queue_depth={:.2} sched_service={}",
                report.rounds,
                report.deltas_applied,
                report.bytes_flushed,
                report.bytes_republished,
                report.pull_bytes,
                report.snapshot_clones,
                report.cow_clones,
                report.cow_bytes,
                report.gate_waits,
                report.mean_staleness,
                report.max_stale_gap,
                report.hash_probes,
                report.sched_wait_total,
                report.plan_queue_depth,
                report.sched_service_used
            );
            if cfg.ps.elastic_enabled() {
                println!(
                    "sup: heartbeats={} leases_expired={} reassigns={} workers_live={}",
                    report.sup_heartbeats,
                    report.sup_leases_expired,
                    report.sup_reassigns,
                    report.sup_workers_live
                );
            }
        }
        "staleness-sweep" => {
            let dataset = args.str_or("dataset", "tiny");
            cfg.workers = args.usize_or("workers", 4)?;
            cfg.lambda = args.f64_or("lambda", 1e-3)?;
            if let Some(tol) = args.opt_str("republish-tol") {
                cfg.ps.set_republish_tol_arg(&tol)?;
            }
            cfg.ps.chunk_cells = args.usize_or("chunk-cells", cfg.ps.chunk_cells)?;
            if let Some(v) = args.opt_str("wire-compress") {
                cfg.ps.wire_compress = parse_on_off("wire-compress", &v)?;
            }
            cfg.ps.dense_segments =
                args.usize_or("dense-segments", usize::from(cfg.ps.dense_segments))? != 0;
            cfg.ps.pipeline = args.usize_or("pipeline", usize::from(cfg.ps.pipeline))? != 0;
            if let Some(kind) = args.opt_str("ps-transport") {
                cfg.ps.transport = strads::ps::TransportKind::parse(&kind)?;
            }
            cfg.ps.addr = args.str_or("ps-addr", &cfg.ps.addr);
            cfg.ps.retry_max = args.usize_or("retry-max", cfg.ps.retry_max)?;
            cfg.ps.retry_backoff_ms =
                args.u64_or("retry-backoff-ms", cfg.ps.retry_backoff_ms)?;
            cfg.ps.fault_plan = args.str_or("fault-plan", &cfg.ps.fault_plan);
            cfg.ps.elastic = args.usize_or("elastic", usize::from(cfg.ps.elastic))? != 0;
            cfg.ps.worker_kill_plan =
                args.str_or("worker-kill-plan", &cfg.ps.worker_kill_plan);
            cfg.ps.lease_ms = args.u64_or("lease-ms", cfg.ps.lease_ms)?;
            if let Some(kind) = args.opt_str("scheduler") {
                cfg.sched.kind = SchedKind::parse(&kind)?;
            }
            cfg.sched.shards = args.usize_or("sched-shards", cfg.sched.shards)?;
            let rounds = args.usize_or("rounds", 300)?;
            apply_obs_flags(&args, &mut cfg)?;
            args.finish()?;
            cfg.validate()?;
            let csv = out_dir.join("staleness_sweep.csv");
            let _ = std::fs::remove_file(&csv);
            let json = out_dir.join("BENCH_ps.json");
            experiments::staleness_sweep(&cfg, &dataset, rounds, Some(&csv), Some(&json))?;
            println!("wrote {} and {}", csv.display(), json.display());
        }
        "ablation" => {
            cfg.workers = args.usize_or("workers", 64)?;
            cfg.engine.max_rounds = args.usize_or("rounds", 800)?;
            cfg.lambda = args.f64_or("lambda", 5e-4)?;
            args.finish()?;
            let csv = out_dir.join("ablation_lasso.csv");
            let _ = std::fs::remove_file(&csv);
            experiments::ablation(&cfg, Some(&csv));
            println!("wrote {}", csv.display());
        }
        "ps-server" => {
            let addr = args.str_or("addr", &cfg.ps.addr);
            let report_secs = args.u64_or("report-secs", cfg.obs.report_secs)?;
            let ckpt_dir = args.str_or("checkpoint-dir", &cfg.ps.checkpoint_dir);
            let ckpt_every = args.u64_or("checkpoint-every", cfg.ps.checkpoint_every)?;
            let ckpt_keep = args.usize_or("checkpoint-keep", cfg.ps.checkpoint_keep)?;
            args.finish()?;
            anyhow::ensure!(ckpt_every >= 1, "--checkpoint-every must be >= 1");
            anyhow::ensure!(ckpt_keep >= 1, "--checkpoint-keep must be >= 1");
            let ckpt = (!ckpt_dir.is_empty()).then(|| strads::ps::CheckpointConfig {
                dir: PathBuf::from(&ckpt_dir),
                every: ckpt_every,
                keep: ckpt_keep,
            });
            let server = strads::ps::PsTcpServer::bind_with(&addr, ckpt)?;
            println!("ps-server listening on {}", server.local_addr());
            println!("  (problem-agnostic: each run's coordinator re-inits it; kill to stop)");
            if !ckpt_dir.is_empty() {
                println!(
                    "  (checkpointing to {ckpt_dir} every {ckpt_every} clock advances; \
                     restores from it on restart)"
                );
            }
            if report_secs > 0 {
                server.spawn_reporter(report_secs);
            }
            server.run();
        }
        "ps-stats" => {
            let addr = args.str_or("addr", &cfg.ps.addr);
            args.finish()?;
            let snap = strads::ps::fetch_obs_stats(&addr)?;
            print!("{}", snap.render());
        }
        "calibrate" => {
            args.finish()?;
            let data = lasso_synth::generate(&lasso_synth::LassoSynthSpec::adlike(), 1);
            let sec = experiments::calibrate_lasso(&data, 5e-4);
            println!("# measured on this host: one coordinate update (N={})", data.n());
            println!("[cost]");
            println!("sec_per_work_unit = {sec:.3e}");
            println!("round_overhead_sec = 1e-3");
            println!("sched_sec_per_candidate = 2e-6");
        }
        "artifacts-info" => {
            args.finish()?;
            let dir = default_artifacts_dir();
            let store = ArtifactStore::open(&dir)?;
            println!("artifact store: {} ({} artifacts)", dir.display(), store.artifacts().len());
            for a in store.artifacts() {
                println!("  {:<28} kind={:<14} file={}", a.name, a.kind, a.file);
            }
        }
        "help" | "--help" | "-h" => println!("{USAGE}"),
        other => anyhow::bail!("unknown subcommand {other}"),
    }
    Ok(())
}

/// `--wire-compress`-style switches: `on`/`1` or `off`/`0`.
fn parse_on_off(flag: &str, v: &str) -> anyhow::Result<bool> {
    match v {
        "on" | "1" => Ok(true),
        "off" | "0" => Ok(false),
        other => anyhow::bail!("--{flag} must be on|off, got {other}"),
    }
}

/// `--obs-level` / `--trace-events` for the distributed subcommands.
/// `--trace-events` names the JSONL output and implies span tracing
/// (level >= 2); the file is removed first so one invocation's timeline
/// never appends onto a previous run's (a staleness sweep's settings DO
/// share it — each run within the invocation appends).
fn apply_obs_flags(args: &Args, cfg: &mut RunConfig) -> anyhow::Result<()> {
    cfg.obs.level = args.usize_or("obs-level", cfg.obs.level)?;
    if let Some(path) = args.opt_str("trace-events") {
        cfg.obs.events_path = path;
        cfg.obs.level = cfg.obs.level.max(2);
    }
    if cfg.obs.tracing() {
        if let Some(dir) = std::path::Path::new(&cfg.obs.events_path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let _ = std::fs::remove_file(&cfg.obs.events_path);
    }
    Ok(())
}

/// Artifact-backed lasso run (PJRT hot path).
fn run_lasso_artifacts(
    data: &lasso_synth::LassoData,
    dataset: &str,
    sched: SchedKind,
    cfg: &RunConfig,
) -> anyhow::Result<Trace> {
    use strads::engine::run_rounds;
    use strads::lasso::ArtifactLasso;
    use strads::problem::ModelProblem;
    use strads::sim::{CostModel, VirtualCluster};

    let store = Rc::new(ArtifactStore::open(&default_artifacts_dir())?);
    let exes = LassoExes::new(store, dataset, &data.x.to_row_major(), &data.y)?;
    let mut problem = ArtifactLasso::new(exes, &data.y, cfg.lambda);
    let mut scheduler = sched.build(problem.num_vars(), &cfg.sap, cfg.engine.seed);
    let mut cluster = VirtualCluster::new(cfg.workers, cfg.sap.shards, CostModel::new(&cfg.cost));
    let mut trace = Trace::new(sched.name(), dataset, cfg.workers);
    run_rounds(&mut problem, scheduler.as_mut(), &mut cluster, &cfg.engine, &mut trace);
    Ok(trace)
}
