//! The round-driving engine: glue between a [`Scheduler`], a
//! [`ModelProblem`], and the [`VirtualCluster`] time axis, producing the
//! objective-vs-time [`Trace`]s that the paper's figures plot.

use crate::config::EngineConfig;
use crate::coordinator::balance::imbalance;
use crate::metrics::{Trace, TracePoint};
use crate::problem::ModelProblem;
use crate::schedulers::Scheduler;
use crate::sim::VirtualCluster;
use std::time::Instant;

/// Run `max_rounds` SAP rounds (or fewer on convergence / empty plans),
/// recording a trace point every `cfg.record_every` rounds.
pub fn run_rounds(
    problem: &mut dyn ModelProblem,
    scheduler: &mut dyn Scheduler,
    cluster: &mut VirtualCluster,
    cfg: &EngineConfig,
    trace: &mut Trace,
) {
    let wall_start = Instant::now();
    let p = cluster.workers();
    let mut last_recorded_obj = f64::INFINITY;
    let mut last_imbalance = 1.0;

    for round in 0..cfg.max_rounds {
        let plan_start = Instant::now();
        let blocks = scheduler.plan(problem, p);
        let sched_secs = plan_start.elapsed().as_secs_f64();
        if blocks.is_empty() {
            // Nothing schedulable (e.g. all weights zero) — converged.
            break;
        }
        last_imbalance = imbalance(&blocks);
        let result = problem.update_blocks(&blocks);
        scheduler.observe(&result);
        cluster.advance_round(&blocks, sched_secs);

        // Divergence guard: unstructured parallel CD can genuinely blow
        // up (interference — the paper's correctness motivation). Record
        // the event and stop rather than looping on NaNs.
        if let Some(obj) = result.objective {
            if !obj.is_finite() {
                trace.push(TracePoint {
                    round,
                    vtime: cluster.now(),
                    wtime: wall_start.elapsed().as_secs_f64(),
                    objective: f64::INFINITY,
                    active_vars: problem.active_vars(),
                    imbalance: last_imbalance,
                    staleness: 0.0,
                    net_bytes: 0,
                    sched_wait: sched_secs,
                    gate_waits: 0,
                });
                return;
            }
        }

        if round % cfg.record_every == 0 || round + 1 == cfg.max_rounds {
            // Exact objective on the cadence, incremental in between.
            let obj = if round % cfg.objective_every == 0 || result.objective.is_none() {
                problem.objective()
            } else {
                result.objective.unwrap()
            };
            trace.push(TracePoint {
                round,
                vtime: cluster.now(),
                wtime: wall_start.elapsed().as_secs_f64(),
                objective: obj,
                active_vars: problem.active_vars(),
                imbalance: last_imbalance,
                staleness: 0.0,
                net_bytes: 0,
                sched_wait: sched_secs,
                gate_waits: 0,
            });

            // Automatic stopping condition (paper §5.1: "a minimum
            // threshold on change in objective value").
            if cfg.rel_tol > 0.0 && last_recorded_obj.is_finite() {
                let rel = (last_recorded_obj - obj).abs() / last_recorded_obj.abs().max(1e-30);
                if rel < cfg.rel_tol {
                    break;
                }
            }
            last_recorded_obj = obj;
        }
    }

    // Always end on an exact objective so `final_objective` is trustworthy.
    let obj = problem.objective();
    if trace.points.last().map(|p| p.objective != obj).unwrap_or(true) {
        trace.push(TracePoint {
            round: cfg.max_rounds,
            vtime: cluster.now(),
            wtime: wall_start.elapsed().as_secs_f64(),
            objective: obj,
            active_vars: problem.active_vars(),
            imbalance: last_imbalance,
            staleness: 0.0,
            net_bytes: 0,
            sched_wait: 0.0,
            gate_waits: 0,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostModelConfig;
    use crate::problem::{Block, RoundResult};
    use crate::schedulers::RandomScheduler;
    use crate::sim::CostModel;

    /// Quadratic toy: objective = sum x_i^2, each update halves x_i.
    struct Quad {
        x: Vec<f64>,
    }

    impl ModelProblem for Quad {
        fn num_vars(&self) -> usize {
            self.x.len()
        }
        fn workload(&self, _j: usize) -> u64 {
            1
        }
        fn dependencies(&mut self, cands: &[usize]) -> Vec<f64> {
            vec![0.0; cands.len() * cands.len()]
        }
        fn update_blocks(&mut self, blocks: &[Block]) -> RoundResult {
            let mut deltas = Vec::new();
            for b in blocks {
                for &v in &b.vars {
                    let old = self.x[v];
                    self.x[v] *= 0.5;
                    deltas.push((v, (old - self.x[v]).abs()));
                }
            }
            RoundResult { deltas, objective: None, max_block_work: 1, total_work: 1 }
        }
        fn objective(&mut self) -> f64 {
            self.x.iter().map(|v| v * v).sum()
        }
        fn active_vars(&self) -> usize {
            self.x.iter().filter(|v| v.abs() > 1e-12).count()
        }
    }

    #[test]
    fn objective_decreases_and_trace_is_recorded() {
        let mut problem = Quad { x: vec![1.0; 32] };
        let mut sched = RandomScheduler::new(1);
        let mut cluster =
            VirtualCluster::new(8, 1, CostModel::new(&CostModelConfig::default()));
        let cfg = EngineConfig { max_rounds: 100, record_every: 5, ..Default::default() };
        let mut trace = Trace::new("random", "quad", 8);
        run_rounds(&mut problem, &mut sched, &mut cluster, &cfg, &mut trace);
        assert!(trace.points.len() >= 10);
        let first = trace.points.first().unwrap().objective;
        let last = trace.final_objective();
        assert!(last < first * 0.01, "first {first} last {last}");
        // vtime strictly increasing
        for w in trace.points.windows(2) {
            assert!(w[1].vtime >= w[0].vtime);
        }
    }

    /// Problem whose objective blows up after a few rounds.
    struct Exploder {
        step: usize,
    }

    impl ModelProblem for Exploder {
        fn num_vars(&self) -> usize {
            8
        }
        fn workload(&self, _j: usize) -> u64 {
            1
        }
        fn dependencies(&mut self, cands: &[usize]) -> Vec<f64> {
            vec![0.0; cands.len() * cands.len()]
        }
        fn update_blocks(&mut self, _blocks: &[Block]) -> RoundResult {
            self.step += 1;
            let obj = if self.step > 5 { f64::NAN } else { 1.0 / self.step as f64 };
            RoundResult { objective: Some(obj), ..Default::default() }
        }
        fn objective(&mut self) -> f64 {
            f64::NAN
        }
    }

    #[test]
    fn divergence_guard_stops_and_records_inf() {
        let mut problem = Exploder { step: 0 };
        let mut sched = RandomScheduler::new(1);
        let mut cluster =
            VirtualCluster::new(4, 1, CostModel::new(&CostModelConfig::default()));
        let cfg = EngineConfig { max_rounds: 10_000, record_every: 1, ..Default::default() };
        let mut trace = Trace::new("random", "exploder", 4);
        run_rounds(&mut problem, &mut sched, &mut cluster, &cfg, &mut trace);
        let last = trace.points.last().unwrap();
        assert!(last.objective.is_infinite(), "divergence must be recorded as inf");
        assert!(last.round < 20, "must stop promptly, stopped at {}", last.round);
    }

    #[test]
    fn rel_tol_stops_early() {
        let mut problem = Quad { x: vec![0.0; 16] }; // already converged
        let mut sched = RandomScheduler::new(1);
        let mut cluster =
            VirtualCluster::new(4, 1, CostModel::new(&CostModelConfig::default()));
        let cfg = EngineConfig {
            max_rounds: 10_000,
            record_every: 1,
            rel_tol: 1e-9,
            ..Default::default()
        };
        let mut trace = Trace::new("random", "quad", 4);
        run_rounds(&mut problem, &mut sched, &mut cluster, &cfg, &mut trace);
        assert!(trace.points.last().unwrap().round < 100);
    }

    #[test]
    fn final_trace_point_carries_last_round_imbalance() {
        // Uneven workloads give imbalance > 1; the trailing exact-
        // objective point must carry the measured value, not a 1.0
        // placeholder.
        struct Skewed {
            obj_calls: usize,
        }
        impl ModelProblem for Skewed {
            fn num_vars(&self) -> usize {
                8
            }
            fn workload(&self, j: usize) -> u64 {
                if j == 0 {
                    100
                } else {
                    1
                }
            }
            fn dependencies(&mut self, cands: &[usize]) -> Vec<f64> {
                vec![0.0; cands.len() * cands.len()]
            }
            fn update_blocks(&mut self, blocks: &[Block]) -> RoundResult {
                let deltas =
                    blocks.iter().flat_map(|b| b.vars.iter().map(|&v| (v, 1.0))).collect();
                RoundResult { deltas, objective: Some(1.0), ..Default::default() }
            }
            fn objective(&mut self) -> f64 {
                // strictly decreasing across exact calls, so the final
                // exact value always differs from the last recorded one
                // and the trailing trace point is always pushed
                self.obj_calls += 1;
                1.0 / self.obj_calls as f64
            }
        }
        let mut problem = Skewed { obj_calls: 0 };
        let mut sched = RandomScheduler::new(9);
        // p > num_vars: the random scheduler schedules every variable
        // each round, so every round deterministically contains the
        // 100x-work straggler.
        let mut cluster =
            VirtualCluster::new(16, 1, CostModel::new(&CostModelConfig::default()));
        let cfg = EngineConfig { max_rounds: 3, record_every: 10, ..Default::default() };
        let mut trace = Trace::new("random", "skewed", 16);
        run_rounds(&mut problem, &mut sched, &mut cluster, &cfg, &mut trace);
        // The exact final objective differs from every earlier value,
        // so a trailing point was pushed — it must carry the measured
        // straggler ratio (100 / mean ~ 7.5), not a 1.0 placeholder.
        let last = trace.points.last().unwrap();
        assert_eq!(last.round, cfg.max_rounds);
        assert!(last.imbalance > 1.5, "placeholder imbalance: {}", last.imbalance);
    }
}
