//! Virtual cluster: discrete-event time accounting for P workers.
//!
//! The paper's Figs 1/4/5 plot objective against cluster wall-clock on
//! 60–240 cores. This host has a single core, so the *time axis* is
//! simulated while the *algorithm* runs exactly (see DESIGN.md §2): all
//! P updates of a round are computed against the same state snapshot —
//! precisely what P distributed workers holding stale copies compute —
//! and the clock advances by what that round would have cost:
//!
//! ```text
//! t_round = max_b( work(b) * sec_per_work_unit )         // straggler
//!         + round_overhead_sec                           // dispatch RTT
//!         + max(0, t_sched/S - (t_worker + overhead))    // exposed sched
//! ```
//!
//! The third term models §3's latency hiding: with S scheduler shards
//! rotating, each shard has S full rounds (dispatch + compute +
//! collect) to prepare its next plan; only scheduler time exceeding
//! that budget lands on the critical path. The straggler max is what
//! load balancing (Fig 5) attacks.

pub mod cost;

pub use cost::CostModel;

use crate::problem::Block;

/// Discrete-event clock over P virtual workers.
#[derive(Clone, Debug)]
pub struct VirtualCluster {
    workers: usize,
    shards: usize,
    cost: CostModel,
    now: f64,
}

impl VirtualCluster {
    pub fn new(workers: usize, shards: usize, cost: CostModel) -> Self {
        VirtualCluster { workers: workers.max(1), shards: shards.max(1), cost, now: 0.0 }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Account one round; returns the round's duration.
    ///
    /// `sched_secs` is the *measured* wall time the scheduler spent
    /// planning this round on this host (one virtual core ~ one real
    /// core here, so measured scheduler time needs no scaling). Each
    /// of the S shards gets S rounds to prepare its next plan, so only
    /// time exceeding the worker phase is exposed.
    pub fn advance_round(&mut self, blocks: &[Block], sched_secs: f64) -> f64 {
        let t_worker = blocks
            .iter()
            .map(|b| self.cost.block_secs(b.work))
            .fold(0.0f64, f64::max);
        let t_round = t_worker + self.cost.round_overhead();
        let t_sched = sched_secs / self.shards as f64;
        let exposed_sched = (t_sched - t_round).max(0.0);
        let dt = t_round + exposed_sched;
        self.now += dt;
        dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostModelConfig;

    fn cluster(p: usize, s: usize) -> VirtualCluster {
        let cfg = CostModelConfig {
            sec_per_work_unit: 1.0,
            round_overhead_sec: 0.5,
            sched_sec_per_candidate: 0.1,
        };
        VirtualCluster::new(p, s, CostModel::new(&cfg))
    }

    fn blocks(works: &[u64]) -> Vec<Block> {
        works.iter().enumerate().map(|(i, &w)| Block::singleton(i, w)).collect()
    }

    #[test]
    fn straggler_dominates_round_time() {
        let mut c = cluster(4, 1);
        let dt = c.advance_round(&blocks(&[1, 1, 1, 10]), 0.0);
        assert!((dt - 10.5).abs() < 1e-9, "dt {dt}");
    }

    #[test]
    fn balanced_blocks_are_faster_than_skewed() {
        let mut a = cluster(4, 1);
        let mut b = cluster(4, 1);
        let t_skew = a.advance_round(&blocks(&[13, 1, 1, 1]), 0.0);
        let t_bal = b.advance_round(&blocks(&[4, 4, 4, 4]), 0.0);
        assert!(t_bal < t_skew);
    }

    #[test]
    fn scheduler_time_hidden_by_shards() {
        // 10s of scheduling; workers take 4s.
        let mut one = cluster(4, 1);
        let mut four = cluster(4, 4);
        let t1 = one.advance_round(&blocks(&[4, 4]), 10.0);
        let t4 = four.advance_round(&blocks(&[4, 4]), 10.0);
        // S=1: exposed = 10 - 4.5 = 5.5 -> 4.5 + 5.5 = 10
        assert!((t1 - 10.0).abs() < 1e-9, "t1 {t1}");
        // S=4: per-shard 2.5s < 4.5s round time -> fully hidden
        assert!((t4 - 4.5).abs() < 1e-9, "t4 {t4}");
    }

    #[test]
    fn clock_is_monotone() {
        let mut c = cluster(2, 1);
        let mut last = 0.0;
        for i in 0..10 {
            c.advance_round(&blocks(&[i + 1]), 0.0);
            assert!(c.now() > last);
            last = c.now();
        }
    }
}
