//! Cost model translating workload units into virtual seconds.
//!
//! Calibration: `sec_per_work_unit` is measured on this host by timing
//! the native updater (see `strads calibrate` and EXPERIMENTS.md
//! §Calibration), so one *virtual* core ≈ one core of this machine.
//! Absolute times therefore differ from the paper's AMD Opteron
//! cluster, but relative comparisons across schedulers and core counts
//! — which is what the figures claim — are preserved.

use crate::config::CostModelConfig;
use crate::coordinator::SchedCost;

#[derive(Clone, Debug)]
pub struct CostModel {
    sec_per_work_unit: f64,
    round_overhead_sec: f64,
    sched_sec_per_candidate: f64,
}

impl CostModel {
    pub fn new(cfg: &CostModelConfig) -> Self {
        CostModel {
            sec_per_work_unit: cfg.sec_per_work_unit,
            round_overhead_sec: cfg.round_overhead_sec,
            sched_sec_per_candidate: cfg.sched_sec_per_candidate,
        }
    }

    /// Worker time for a block of `work` units.
    #[inline]
    pub fn block_secs(&self, work: u64) -> f64 {
        work as f64 * self.sec_per_work_unit
    }

    /// Scheduler time for one plan (sampling + dependency checking).
    /// Dep checks are charged at the same per-candidate rate scaled by
    /// the check fan-out.
    #[inline]
    pub fn sched_secs(&self, cost: SchedCost) -> f64 {
        (cost.candidates as f64 + 0.1 * cost.dep_checks as f64) * self.sched_sec_per_candidate
    }

    #[inline]
    pub fn round_overhead(&self) -> f64 {
        self.round_overhead_sec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_in_work() {
        let m = CostModel::new(&CostModelConfig {
            sec_per_work_unit: 2.0,
            round_overhead_sec: 0.0,
            sched_sec_per_candidate: 0.0,
        });
        assert_eq!(m.block_secs(5), 10.0);
        assert_eq!(m.block_secs(0), 0.0);
    }

    #[test]
    fn sched_cost_includes_dep_checks() {
        let m = CostModel::new(&CostModelConfig {
            sec_per_work_unit: 0.0,
            round_overhead_sec: 0.0,
            sched_sec_per_candidate: 1.0,
        });
        let base = m.sched_secs(SchedCost { candidates: 10, dep_checks: 0 });
        let with = m.sched_secs(SchedCost { candidates: 10, dep_checks: 100 });
        assert!(with > base);
    }
}
