//! Cross-module integration: full experiment pipelines over the native
//! backend (the artifact path has its own suite in runtime_roundtrip).

use strads::config::{EngineConfig, RunConfig};
use strads::data::lasso_synth::{generate, LassoSynthSpec};
use strads::data::mf_powerlaw::{self, MfSynthSpec};
use strads::experiments::{self, SchedKind};
use strads::metrics::Trace;
use strads::mf::{run_mf, MfPartition, NativeMf};
use strads::util::KvConf;

fn tiny_cfg(workers: usize, rounds: usize) -> RunConfig {
    RunConfig {
        workers,
        lambda: 5e-4,
        engine: EngineConfig { max_rounds: rounds, ..Default::default() },
        ..Default::default()
    }
}

#[test]
fn fig1_shape_dynamic_beats_random() {
    // The Fig 1 claim is about convergence *speed*: at a mid-run round
    // budget (before everything converges — a tiny problem converges
    // under any scheduler eventually), STRADS sits at a lower objective,
    // and reaches random's final quality in fewer rounds/virtual time.
    let data = generate(&LassoSynthSpec::tiny(), 42);
    let mut mid = tiny_cfg(16, 120);
    // tiny N=128: the cross-column correlation noise floor is
    // 1/sqrt(128) ~ 0.09, so the paper's rho = 0.1 would reject nearly
    // every benign pair. Scale rho above the noise floor but below the
    // within-block correlation (0.8).
    mid.sap.rho = 0.25;
    let dy = experiments::run_lasso_native(&data, "tiny", SchedKind::Dynamic, &mid);
    let rn = experiments::run_lasso_native(&data, "tiny", SchedKind::Random, &mid);
    assert!(
        dy.final_objective() < rn.final_objective(),
        "mid-run: dynamic {:.4e} vs random {:.4e}",
        dy.final_objective(),
        rn.final_objective()
    );
    // time-to-quality: dynamic reaches random's mid-run quality sooner.
    let threshold = rn.final_objective();
    let t_dy = dy.time_to_reach(threshold).expect("dynamic reaches threshold");
    let t_rn = rn.time_to_reach(threshold).expect("random reaches its own final");
    assert!(t_dy <= t_rn, "dynamic t {t_dy} vs random t {t_rn}");
}

#[test]
fn fig4_shape_static_sits_between_at_high_core_count() {
    // At high P the ordering dynamic < static < random (final
    // objective) should hold on the correlated dataset.
    let data = generate(
        &LassoSynthSpec { j: 512, block_size: 16, corr: 0.85, ..LassoSynthSpec::tiny() },
        43,
    );
    // mid-run budget: the orderings are about convergence rate;
    // rho above the N=128 noise floor (see fig1_shape test)
    let mut cfg = tiny_cfg(48, 120);
    cfg.sap.rho = 0.25;
    let dy = experiments::run_lasso_native(&data, "t", SchedKind::Dynamic, &cfg);
    let st = experiments::run_lasso_native(&data, "t", SchedKind::Static, &cfg);
    let rn = experiments::run_lasso_native(&data, "t", SchedKind::Random, &cfg);
    assert!(dy.final_objective() <= st.final_objective() * 1.02);
    assert!(st.final_objective() <= rn.final_objective() * 1.02);
}

#[test]
fn coverage_driven_early_drop_exists() {
    // §5.1 phenomenon 1: once every variable has been touched, STRADS
    // prioritizes by actual progress -> the objective drop between
    // round k and 2k is much bigger than for random scheduling.
    let data = generate(&LassoSynthSpec::tiny(), 44);
    let cfg = tiny_cfg(16, 300);
    let dy = experiments::run_lasso_native(&data, "tiny", SchedKind::Dynamic, &cfg);
    // objective is monotone-ish decreasing and the trace is ordered
    let objs: Vec<f64> = dy.points.iter().map(|p| p.objective).collect();
    assert!(objs.last().unwrap() < &objs[0]);
}

#[test]
fn fig5_shape_balanced_wins_and_gap_grows_with_skew() {
    let iters = 4;
    let cost = strads::config::CostModelConfig::default();
    let ecfg = EngineConfig { max_rounds: iters, record_every: 1, ..Default::default() };
    let mut speedup = Vec::new(); // uniform_time / balanced_time
    for spec in [
        MfSynthSpec { n_users: 512, m_items: 256, nnz: 10_000, ..MfSynthSpec::netflix_like() },
        MfSynthSpec { n_users: 512, m_items: 256, nnz: 10_000, ..MfSynthSpec::yahoo_like() },
    ] {
        let data = mf_powerlaw::generate(&spec, 7);
        let mut times = Vec::new();
        for part in [MfPartition::Balanced, MfPartition::Uniform] {
            let mut backend = NativeMf::new(&data.a, 4, 0.05, 8);
            let mut t = Trace::new(part.name(), "mf", 8);
            run_mf(&mut backend, part, 8, &ecfg, &cost, &mut t);
            times.push(t.final_vtime());
        }
        assert!(times[0] < times[1], "balanced {} vs uniform {}", times[0], times[1]);
        speedup.push(times[1] / times[0]);
    }
    // Yahoo-like (heavier tail) benefits more from load balancing
    assert!(
        speedup[1] > speedup[0],
        "LB speedup should grow with skew: netflix {:.2} yahoo {:.2}",
        speedup[0],
        speedup[1]
    );
}

#[test]
fn mf_objective_identical_across_partitions() {
    // Load balancing changes time, never math: both partitions run the
    // same per-rank updates, so factors and objectives must agree.
    let data = mf_powerlaw::generate(
        &MfSynthSpec { n_users: 256, m_items: 128, nnz: 4_000, ..MfSynthSpec::tiny() },
        9,
    );
    let cost = strads::config::CostModelConfig::default();
    let ecfg = EngineConfig { max_rounds: 3, record_every: 1, ..Default::default() };
    let mut finals = Vec::new();
    for part in [MfPartition::Balanced, MfPartition::Uniform] {
        let mut backend = NativeMf::new(&data.a, 4, 0.05, 10);
        let mut t = Trace::new(part.name(), "mf", 4);
        run_mf(&mut backend, part, 4, &ecfg, &cost, &mut t);
        finals.push(t.final_objective());
    }
    assert!(
        (finals[0] - finals[1]).abs() < 1e-6 * finals[0].abs().max(1.0),
        "balanced {} vs uniform {}",
        finals[0],
        finals[1]
    );
}

#[test]
fn config_presets_load_and_apply() {
    for preset in ["fig1", "fig4", "fig5", "quickstart", "distributed"] {
        let path = format!("configs/{preset}.conf");
        let cfg = RunConfig::from_file(std::path::Path::new(&path))
            .unwrap_or_else(|e| panic!("preset {preset}: {e}"));
        cfg.validate().unwrap();
    }
    // fig4 preset pins the paper's lasso settings
    let cfg = RunConfig::from_file(std::path::Path::new("configs/fig4.conf")).unwrap();
    assert_eq!(cfg.sap.rho, 0.1);
    assert_eq!(cfg.lambda, 5e-4);
    // distributed preset documents the ps knobs end-to-end
    let cfg = RunConfig::from_file(std::path::Path::new("configs/distributed.conf")).unwrap();
    assert_eq!(cfg.ps.staleness, 2);
    assert_eq!(cfg.ps.republish_tol, 1e-8);
    assert!(!cfg.ps.republish_auto, "the preset documents the numeric form");
    assert_eq!(cfg.ps.chunk_cells, 0, "documented at the whole-segment default");
    assert!(cfg.ps.wire_compress, "v5 run encoding documented on by default");
    assert!(cfg.ps.dense_segments && cfg.ps.pipeline);
    assert_eq!(cfg.ps.transport, strads::ps::TransportKind::InProc);
    assert_eq!(cfg.ps.addr, "127.0.0.1:37021");
    assert_eq!(
        cfg.ps.addrs(),
        ["127.0.0.1:37021"],
        "the preset documents the degenerate one-server fleet"
    );
    // ...including the fault-tolerance knobs (documented at defaults:
    // retries off, fault injection off, checkpointing off)
    assert_eq!(cfg.ps.retry_max, 0);
    assert_eq!(cfg.ps.retry_backoff_ms, 50);
    assert_eq!(cfg.ps.fault_plan, "");
    assert_eq!(cfg.ps.checkpoint_dir, "");
    assert_eq!(cfg.ps.checkpoint_every, 16);
    assert_eq!(cfg.ps.checkpoint_keep, 2);
    // ...and the elastic-membership knobs (documented at defaults:
    // supervision off, no kill plan, 30 s leases)
    assert!(!cfg.ps.elastic && !cfg.ps.elastic_enabled());
    assert_eq!(cfg.ps.worker_kill_plan, "");
    assert_eq!(cfg.ps.lease_ms, 30_000);
}

#[test]
fn kvconf_rejects_typos_end_to_end() {
    let conf = KvConf::parse("[sap]\nrho = 0.1\nsharsd = 2\n").unwrap();
    assert!(RunConfig::from_kvconf(&conf).is_err());
}

#[test]
fn csv_output_has_all_series() {
    let dir = std::env::temp_dir().join("strads_integration_csv");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("fig1.csv");
    let mut cfg = tiny_cfg(8, 40);
    cfg.engine.record_every = 10;
    // miniature fig1 via the same driver the CLI uses
    let data = generate(&LassoSynthSpec::tiny(), 45);
    for kind in [SchedKind::Dynamic, SchedKind::Random] {
        let t = experiments::run_lasso_native(&data, "tiny", kind, &cfg);
        t.append_csv(&csv).unwrap();
    }
    let text = std::fs::read_to_string(&csv).unwrap();
    assert!(text.lines().next().unwrap().starts_with("scheduler,"));
    assert!(text.contains("\ndynamic,tiny,8,"));
    assert!(text.contains("\nrandom,tiny,8,"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn scheduler_never_stalls_on_tiny_problems() {
    // p > num_vars, shards > num_vars, etc. must all keep planning
    let data = generate(
        &LassoSynthSpec { j: 8, k_nonzero: 4, block_size: 2, ..LassoSynthSpec::tiny() },
        46,
    );
    let mut cfg = tiny_cfg(32, 50);
    cfg.sap.shards = 16; // more shards than sensible
    let t = experiments::run_lasso_native(&data, "t", SchedKind::Dynamic, &cfg);
    assert!(t.points.len() > 5);
    assert!(t.final_objective().is_finite());
}
