//! End-to-end coverage of the parameter-server path: any ModelProblem
//! on real worker threads, staleness-0 parity with the engine
//! semantics, staleness sweeps, and the new trace metrics.

use strads::config::RunConfig;
use strads::data::lasso_synth::{self, LassoSynthSpec};
use strads::data::mf_powerlaw::{self, MfSynthSpec};
use strads::lasso::NativeLasso;
use strads::mf::DistMf;
use strads::prelude::*;

fn lasso_cfg(workers: usize) -> RunConfig {
    let mut cfg = RunConfig { workers, lambda: 1e-3, ..Default::default() };
    cfg.sap.shards = 2;
    cfg
}

#[test]
fn lasso_multiworker_staleness0_matches_engine_path() {
    // With staleness 0, every pull reads the exact canonical state, so
    // the distributed run must reproduce the engine path bit-for-bit:
    // same plans, same proposals, same apply order.
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 42);
    let cfg = lasso_cfg(4);
    let rounds = 120;

    let mut dist_problem = NativeLasso::new(&data, cfg.lambda);
    let report =
        strads::workers::run_distributed(&mut dist_problem, &cfg, rounds, "tiny").unwrap();

    let mut local = NativeLasso::new(&data, cfg.lambda);
    let mut sched = DynamicScheduler::new(local.num_vars(), &cfg.sap, cfg.engine.seed);
    for _ in 0..rounds {
        let blocks = sched.plan(&mut local, cfg.workers);
        if blocks.is_empty() {
            break;
        }
        let res = local.update_blocks(&blocks);
        sched.observe(&res);
    }
    let local_obj = local.objective();
    let dist_obj = report.trace.final_objective();
    assert!(
        (local_obj - dist_obj).abs() < 1e-6 * local_obj.abs().max(1.0),
        "local {local_obj} dist {dist_obj}"
    );
    assert!(dist_obj < report.trace.points[0].objective * 0.9, "must actually converge");
}

#[test]
fn lasso_staleness_sweep_runs_end_to_end() {
    // The acceptance sweep: bounds 0, 2, 8 and async all run end-to-end
    // with metered flushes. Bounded runs must also converge; the async
    // run has no convergence guarantee (unbounded staleness is exactly
    // the interference regime the paper warns about), so it is only
    // required to complete.
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 7);
    for setting in ["0", "2", "8", "async"] {
        let mut cfg = lasso_cfg(4);
        cfg.ps.set_staleness_arg(setting).unwrap();
        let mut problem = NativeLasso::new(&data, cfg.lambda);
        let report =
            strads::workers::run_distributed(&mut problem, &cfg, 200, "tiny").unwrap();
        assert!(report.bytes_flushed > 0, "staleness={setting}: no flushes metered");
        assert_eq!(report.rounds, 200, "staleness={setting} stopped early");
        if setting != "async" {
            let first = report.trace.points.first().unwrap().objective;
            let last = report.trace.final_objective();
            assert!(last.is_finite(), "staleness={setting} diverged to non-finite");
            assert!(last < first * 0.9, "staleness={setting}: first {first} last {last}");
        }
    }
}

#[test]
fn trace_records_staleness_and_flushed_bytes() {
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 9);
    let mut cfg = lasso_cfg(4);
    cfg.ps.set_staleness_arg("2").unwrap();
    let mut problem = NativeLasso::new(&data, cfg.lambda);
    let report = strads::workers::run_distributed(&mut problem, &cfg, 60, "tiny").unwrap();
    let points = &report.trace.points;
    assert!(points.len() >= 2);
    // net_bytes is cumulative and must be positive and nondecreasing
    assert!(points.last().unwrap().net_bytes > 0);
    for w in points.windows(2) {
        assert!(w[1].net_bytes >= w[0].net_bytes, "net_bytes must be cumulative");
    }
    // per-round staleness stays within the configured bound
    for p in points {
        assert!(p.staleness.is_finite() && p.staleness >= 0.0);
        assert!(p.staleness <= 2.0 + 1e-9, "staleness {} exceeds bound", p.staleness);
    }
    // the scheduler label carries the policy
    assert_eq!(report.trace.scheduler, "dist-stale=2");
}

#[test]
fn mf_distributed_staleness0_matches_local_rounds() {
    // MF through the same generic path: CCD++ rank sweeps as PS rounds.
    // At staleness 0 the distributed factors follow the local execution
    // of the identical round structure exactly.
    let data = mf_powerlaw::generate(&MfSynthSpec::tiny(), 31);
    let mut dist = DistMf::new(&data.a, 4, 0.05, 32);
    let rounds = dist.rounds_for_iters(3);
    let cfg = RunConfig { workers: 4, ..Default::default() };
    let report = strads::workers::run_distributed(&mut dist, &cfg, rounds, "tiny").unwrap();
    let dist_obj = report.trace.final_objective();

    let mut local = DistMf::new(&data.a, 4, 0.05, 32);
    for round in 0..rounds {
        let blocks = local.plan_round(round, cfg.workers).expect("mf plans its own rounds");
        local.update_blocks(&blocks);
    }
    let local_obj = local.objective();
    assert!(
        (local_obj - dist_obj).abs() < 1e-6 * local_obj.abs().max(1.0),
        "local {local_obj} dist {dist_obj}"
    );
    // and it genuinely optimizes
    assert!(
        dist_obj < report.trace.points[0].objective * 0.9,
        "distributed MF failed to converge: {dist_obj}"
    );
    assert_eq!(report.rounds, rounds);
}

#[test]
fn mf_distributed_stale_runs_complete() {
    let data = mf_powerlaw::generate(&MfSynthSpec::tiny(), 33);
    for setting in ["2", "async"] {
        let mut cfg = RunConfig { workers: 4, ..Default::default() };
        cfg.ps.set_staleness_arg(setting).unwrap();
        let mut dist = DistMf::new(&data.a, 4, 0.05, 34);
        let rounds = dist.rounds_for_iters(4);
        let report =
            strads::workers::run_distributed(&mut dist, &cfg, rounds, "tiny").unwrap();
        assert_eq!(report.rounds, rounds, "staleness={setting} stopped early");
        if setting != "async" {
            // bounded-stale CCD still optimizes; async only has to finish
            let first = report.trace.points.first().unwrap().objective;
            let last = report.trace.final_objective();
            assert!(last.is_finite());
            assert!(last < first, "staleness={setting}: first {first} last {last}");
        }
    }
}
