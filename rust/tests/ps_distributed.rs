//! End-to-end coverage of the parameter-server path: any ModelProblem
//! on real worker threads, staleness-0 parity with the engine
//! semantics, staleness sweeps, trace metrics, the gate-driven
//! pipelined loop under deliberately skewed workers, and the
//! incremental-republish byte regression.

use std::sync::Arc;
use std::time::Duration;
use strads::config::RunConfig;
use strads::data::lasso_synth::{self, LassoSynthSpec};
use strads::data::mf_powerlaw::{self, MfSynthSpec};
use strads::lasso::NativeLasso;
use strads::mf::DistMf;
use strads::prelude::*;
use strads::ps::{PsKernel, PsSnapshot, PullSpec};
use strads::workers::DistributedReport;

fn lasso_cfg(workers: usize) -> RunConfig {
    let mut cfg = RunConfig { workers, lambda: 1e-3, ..Default::default() };
    cfg.sap.shards = 2;
    cfg
}

#[test]
fn lasso_multiworker_staleness0_matches_engine_path() {
    // With staleness 0, every pull reads the exact canonical state, so
    // the distributed run must reproduce the engine path bit-for-bit:
    // same plans, same proposals, same apply order.
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 42);
    let cfg = lasso_cfg(4);
    let rounds = 120;

    let mut dist_problem = NativeLasso::new(&data, cfg.lambda);
    let report =
        strads::workers::run_distributed(&mut dist_problem, &cfg, rounds, "tiny").unwrap();

    let mut local = NativeLasso::new(&data, cfg.lambda);
    let mut sched = DynamicScheduler::new(local.num_vars(), &cfg.sap, cfg.engine.seed);
    for _ in 0..rounds {
        let blocks = sched.plan(&mut local, cfg.workers);
        if blocks.is_empty() {
            break;
        }
        let res = local.update_blocks(&blocks);
        sched.observe(&res);
    }
    let local_obj = local.objective();
    let dist_obj = report.trace.final_objective();
    assert!(
        (local_obj - dist_obj).abs() < 1e-6 * local_obj.abs().max(1.0),
        "local {local_obj} dist {dist_obj}"
    );
    assert!(dist_obj < report.trace.points[0].objective * 0.9, "must actually converge");
}

#[test]
fn lasso_staleness_sweep_runs_end_to_end() {
    // The acceptance sweep: bounds 0, 2, 8 and async all run end-to-end
    // with metered flushes. Bounded runs must also converge; the async
    // run has no convergence guarantee (unbounded staleness is exactly
    // the interference regime the paper warns about), so it is only
    // required to complete.
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 7);
    for setting in ["0", "2", "8", "async"] {
        let mut cfg = lasso_cfg(4);
        cfg.ps.set_staleness_arg(setting).unwrap();
        let mut problem = NativeLasso::new(&data, cfg.lambda);
        let report =
            strads::workers::run_distributed(&mut problem, &cfg, 200, "tiny").unwrap();
        assert!(report.bytes_flushed > 0, "staleness={setting}: no flushes metered");
        assert_eq!(report.rounds, 200, "staleness={setting} stopped early");
        if let Ok(bound) = setting.parse::<u64>() {
            // the gate, not dispatch throttling, enforces the bound
            // under pipelining — no pull may ever exceed it
            assert!(
                report.max_stale_gap <= bound,
                "staleness={setting}: observed gap {}",
                report.max_stale_gap
            );
        }
        if setting != "async" {
            let first = report.trace.points.first().unwrap().objective;
            let last = report.trace.final_objective();
            assert!(last.is_finite(), "staleness={setting} diverged to non-finite");
            assert!(last < first * 0.9, "staleness={setting}: first {first} last {last}");
        }
    }
}

#[test]
fn trace_records_staleness_and_flushed_bytes() {
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 9);
    let mut cfg = lasso_cfg(4);
    cfg.ps.set_staleness_arg("2").unwrap();
    let mut problem = NativeLasso::new(&data, cfg.lambda);
    let report = strads::workers::run_distributed(&mut problem, &cfg, 60, "tiny").unwrap();
    let points = &report.trace.points;
    assert!(points.len() >= 2);
    // net_bytes is cumulative and must be positive and nondecreasing
    assert!(points.last().unwrap().net_bytes > 0);
    for w in points.windows(2) {
        assert!(w[1].net_bytes >= w[0].net_bytes, "net_bytes must be cumulative");
    }
    // per-round staleness stays within the configured bound
    for p in points {
        assert!(p.staleness.is_finite() && p.staleness >= 0.0);
        assert!(p.staleness <= 2.0 + 1e-9, "staleness {} exceeds bound", p.staleness);
    }
    // the scheduler label carries the policy
    assert_eq!(report.trace.scheduler, "dist-stale=2");
}

#[test]
fn mf_distributed_staleness0_matches_local_rounds() {
    // MF through the same generic path: CCD++ rank sweeps as PS rounds.
    // At staleness 0 the distributed factors follow the local execution
    // of the identical round structure exactly.
    let data = mf_powerlaw::generate(&MfSynthSpec::tiny(), 31);
    let mut dist = DistMf::new(&data.a, 4, 0.05, 32);
    let rounds = dist.rounds_for_iters(3);
    let cfg = RunConfig { workers: 4, ..Default::default() };
    let report = strads::workers::run_distributed(&mut dist, &cfg, rounds, "tiny").unwrap();
    let dist_obj = report.trace.final_objective();

    let mut local = DistMf::new(&data.a, 4, 0.05, 32);
    for round in 0..rounds {
        let blocks = local.plan_round(round, cfg.workers).expect("mf plans its own rounds");
        local.update_blocks(&blocks);
    }
    let local_obj = local.objective();
    assert!(
        (local_obj - dist_obj).abs() < 1e-6 * local_obj.abs().max(1.0),
        "local {local_obj} dist {dist_obj}"
    );
    // and it genuinely optimizes
    assert!(
        dist_obj < report.trace.points[0].objective * 0.9,
        "distributed MF failed to converge: {dist_obj}"
    );
    assert_eq!(report.rounds, rounds);
}

/// Toy kernel with deliberately skewed per-block compute: block `b`
/// sleeps proportionally to `b mod 4`, so fast workers race rounds
/// ahead of the stragglers and pile up on the SSP gate — the
/// concurrency regime gate-driven pipelining must keep correct.
struct SkewKernel;

impl PsKernel for SkewKernel {
    fn pull_spec(&self, vars: &[usize], _round: u64) -> PullSpec {
        PullSpec::from_keys(vars.to_vec())
    }

    fn propose(&self, _snap: &PsSnapshot, vars: &[usize], _round: u64) -> Vec<(usize, f64)> {
        let skew = vars.first().copied().unwrap_or(0) as u64 % 4;
        std::thread::sleep(Duration::from_micros(300 * skew));
        vars.iter().map(|&v| (v, 1.0)).collect()
    }
}

/// Coordinator side of the skew problem: every var gains exactly +1.0
/// per round, so the final state is a staleness-independent invariant
/// the stress test can assert bit-exactly.
struct SkewProblem {
    state: Vec<f64>,
    kernel: Arc<SkewKernel>,
}

impl SkewProblem {
    fn new(vars: usize) -> Self {
        SkewProblem { state: vec![0.0; vars], kernel: Arc::new(SkewKernel) }
    }
}

impl ModelProblem for SkewProblem {
    fn num_vars(&self) -> usize {
        self.state.len()
    }

    fn workload(&self, _j: usize) -> u64 {
        1
    }

    fn dependencies(&mut self, cands: &[usize]) -> Vec<f64> {
        vec![0.0; cands.len() * cands.len()]
    }

    fn update_blocks(&mut self, blocks: &[Block]) -> RoundResult {
        let mut deltas = Vec::new();
        for b in blocks {
            for &v in &b.vars {
                self.state[v] += 1.0;
                deltas.push((v, 1.0));
            }
        }
        RoundResult { deltas, objective: Some(0.0), max_block_work: 1, total_work: 1 }
    }

    fn objective(&mut self) -> f64 {
        -self.state.iter().sum::<f64>()
    }

    fn ps_state(&self) -> Vec<f64> {
        self.state.clone()
    }

    fn ps_kernel(&self) -> Option<Arc<dyn PsKernel>> {
        Some(Arc::clone(&self.kernel) as Arc<dyn PsKernel>)
    }

    fn ps_dense_segments(&self) -> Vec<(usize, usize)> {
        vec![(0, self.state.len())]
    }

    fn apply_deltas(&mut self, deltas: &[(usize, f64)]) -> RoundResult {
        let mut out = Vec::with_capacity(deltas.len());
        for &(key, delta) in deltas {
            self.state[key] += delta;
            out.push((key, delta.abs()));
        }
        let total = out.len() as u64;
        RoundResult {
            deltas: out,
            objective: Some(-self.state.iter().sum::<f64>()),
            max_block_work: 1,
            total_work: total,
        }
    }

    fn plan_round(&mut self, _round: usize, p: usize) -> Option<Vec<Block>> {
        // Round-robin vars over p blocks: block index == skew class, and
        // every var is scheduled exactly once per round.
        let mut blocks: Vec<Block> =
            (0..p).map(|_| Block { vars: Vec::new(), work: 0 }).collect();
        for v in 0..self.state.len() {
            blocks[v % p].vars.push(v);
            blocks[v % p].work += 1;
        }
        blocks.retain(|b| !b.vars.is_empty());
        Some(blocks)
    }
}

#[test]
fn skewed_workers_respect_staleness_bound_and_terminate() {
    // N seeded worker threads with skewed per-round compute: the run
    // must terminate, no pull may ever observe state more than s rounds
    // stale, and the accumulated state must equal the lock-step result
    // exactly (the updates commute, so bounded staleness cannot change
    // the answer — only the overlap).
    let rounds = 60usize;
    for (s, pipeline) in [(0u64, true), (1, true), (3, true), (2, false)] {
        let mut cfg = RunConfig { workers: 4, ..Default::default() };
        cfg.ps.staleness = s as usize;
        cfg.ps.pipeline = pipeline;
        let mut problem = SkewProblem::new(16);
        let report =
            strads::workers::run_distributed(&mut problem, &cfg, rounds, "skew").unwrap();
        assert_eq!(report.rounds, rounds, "s={s} pipeline={pipeline}: must terminate");
        assert!(
            report.max_stale_gap <= s,
            "s={s} pipeline={pipeline}: observed gap {}",
            report.max_stale_gap
        );
        for (v, &x) in problem.state.iter().enumerate() {
            assert_eq!(x, rounds as f64, "s={s}: var {v} saw {x}");
        }
        assert_eq!(
            report.hash_probes, 0,
            "whole key space is dense-registered: nothing may hash"
        );
        if s == 0 {
            assert_eq!(report.max_stale_gap, 0, "BSP pulls read exact state");
        }
    }
}

#[test]
fn incremental_republish_converges_and_cuts_net_bytes() {
    // The perf-win pin: tolerance-gated residual republish must track
    // the full-republish objective and move strictly fewer bytes.
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 42);
    let rounds = 400;
    let run = |tol: f64| -> DistributedReport {
        let mut cfg = lasso_cfg(4);
        cfg.ps.republish_tol = tol;
        let mut problem = NativeLasso::new(&data, cfg.lambda);
        strads::workers::run_distributed(&mut problem, &cfg, rounds, "tiny").unwrap()
    };
    let full = run(-1.0); // republish the entire residual every round
    let exact = run(0.0); // skip only bitwise-unchanged entries
    let gated = run(1e-8); // tolerance-gated (~1 f32 ulp at residual scale)

    let full_obj = full.trace.final_objective();
    // tol = 0 is lossless: workers see identical snapshots, so the
    // entire run is bit-identical to full republish.
    assert_eq!(exact.trace.final_objective(), full_obj);
    // tolerance-gated drift is bounded by tol + the periodic full
    // re-sync: within 1e-9 of the full-republish objective.
    let gated_obj = gated.trace.final_objective();
    assert!(
        (gated_obj - full_obj).abs() < 1e-9 * full_obj.abs().max(1.0),
        "full {full_obj} gated {gated_obj}"
    );
    // The republished traffic shrinks...
    assert!(
        exact.bytes_republished < full.bytes_republished,
        "exact {} vs full {}",
        exact.bytes_republished,
        full.bytes_republished
    );
    assert!(gated.bytes_republished <= exact.bytes_republished);
    // ...while worker flush traffic is untouched by the knob...
    assert_eq!(exact.bytes_flushed, full.bytes_flushed);
    // ...so the trace's cumulative net_bytes column ends strictly lower.
    let net = |r: &DistributedReport| r.trace.points.last().unwrap().net_bytes;
    assert!(net(&exact) < net(&full), "exact {} vs full {}", net(&exact), net(&full));
    assert!(net(&gated) < net(&full), "gated {} vs full {}", net(&gated), net(&full));
}

#[test]
fn f32_epoch_wire_is_lossless_and_cuts_pull_bytes() {
    // The zero-copy regression pin for the f32 epoch wire. A
    // staleness-0 run with dense segments ON (f32 epoch slabs) must be
    // bitwise identical to the same run with them OFF (f64 cells
    // holding exact images of the coordinator's f32 residual): if the
    // f32 slab lost anything, the two trajectories would diverge. The
    // dense run must also move less than half the pull bytes — both
    // against the cells-off run and against the 16-byte-per-cell
    // accounting of the representation it replaced.
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 42);
    let rounds = 120;
    let mut on_cfg = lasso_cfg(4);
    let mut off_cfg = on_cfg.clone();
    off_cfg.ps.dense_segments = false;

    let mut on_problem = NativeLasso::new(&data, on_cfg.lambda);
    let on = strads::workers::run_distributed(&mut on_problem, &on_cfg, rounds, "tiny").unwrap();
    let mut off_problem = NativeLasso::new(&data, off_cfg.lambda);
    let off =
        strads::workers::run_distributed(&mut off_problem, &off_cfg, rounds, "tiny").unwrap();

    assert_eq!(
        on.trace.final_objective(),
        off.trace.final_objective(),
        "f32 epoch wire must be bit-lossless vs the f64 cell wire"
    );
    for (j, (a, b)) in on_problem.beta().iter().zip(off_problem.beta()).enumerate() {
        assert_eq!(a, b, "beta[{j}] diverged between storage representations");
    }
    assert!(
        on.pull_bytes * 2 < off.pull_bytes,
        "f32 ranges must at least halve pull traffic: on={} off={}",
        on.pull_bytes,
        off.pull_bytes
    );
    assert!(
        on.pull_bytes * 2 < 16 * on.cells_pulled,
        "pull bytes {} must undercut half the 16B/cell baseline ({} cells)",
        on.pull_bytes,
        on.cells_pulled
    );
    assert!(on.snapshot_clones > 0, "residual pulls must be served as epoch views");
    assert_eq!(off.snapshot_clones, 0, "hashed fallback ranges are owned copies");

    // And the dense run still matches the engine path itself: beta
    // reconstruction (beta += delta) is the only rounding difference,
    // so the agreement is far tighter than the convergence tolerance.
    let mut local = NativeLasso::new(&data, on_cfg.lambda);
    let mut sched = DynamicScheduler::new(local.num_vars(), &on_cfg.sap, on_cfg.engine.seed);
    for _ in 0..rounds {
        let blocks = sched.plan(&mut local, on_cfg.workers);
        if blocks.is_empty() {
            break;
        }
        let res = local.update_blocks(&blocks);
        sched.observe(&res);
    }
    let local_obj = local.objective();
    let dist_obj = on.trace.final_objective();
    assert!(
        (local_obj - dist_obj).abs() < 1e-12 * local_obj.abs().max(1.0),
        "engine {local_obj} vs distributed {dist_obj}"
    );
}

#[test]
fn chunked_slabs_are_bitwise_invisible_for_lasso() {
    // The tentpole contract, inproc side: splitting the dense segments
    // into fixed-size epoch chunks must not change a single bit of the
    // trajectory — chunking only changes what a racing publish clones
    // and what a partial pull pins, never any arithmetic. The modeled
    // pull meter counts payload cells, so it must not move either.
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 42);
    let rounds = 120;
    let run = |chunk_cells: usize| {
        let mut cfg = lasso_cfg(4);
        cfg.ps.chunk_cells = chunk_cells;
        let mut problem = NativeLasso::new(&data, cfg.lambda);
        let report =
            strads::workers::run_distributed(&mut problem, &cfg, rounds, "tiny").unwrap();
        let beta: Vec<f64> = problem.beta().to_vec();
        (report, beta)
    };
    let (whole, whole_beta) = run(0);
    let (chunked, chunked_beta) = run(16);
    assert_eq!(
        whole.trace.final_objective().to_bits(),
        chunked.trace.final_objective().to_bits(),
        "chunk_cells must be bitwise invisible to the Lasso trajectory"
    );
    for (j, (a, b)) in whole_beta.iter().zip(&chunked_beta).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "beta[{j}] diverged under chunking: {a} vs {b}");
    }
    assert_eq!(whole.pull_bytes, chunked.pull_bytes, "modeled pull meter is chunk-invariant");
    assert_eq!(whole.bytes_flushed, chunked.bytes_flushed);
    assert_eq!(whole.bytes_republished, chunked.bytes_republished);
}

#[test]
fn chunked_slabs_are_bitwise_invisible_for_mf() {
    // Same contract on the MF workload, whose windowed factor
    // republishes are exactly the write pattern chunking exists for.
    let data = mf_powerlaw::generate(&MfSynthSpec::tiny(), 31);
    let run = |chunk_cells: usize| {
        let mut cfg = RunConfig { workers: 4, ..Default::default() };
        cfg.ps.chunk_cells = chunk_cells;
        let mut dist = DistMf::new(&data.a, 4, 0.05, 32);
        let rounds = dist.rounds_for_iters(3);
        let report =
            strads::workers::run_distributed(&mut dist, &cfg, rounds, "tiny").unwrap();
        let state = dist.ps_state();
        (report, state)
    };
    let (whole, whole_state) = run(0);
    let (chunked, chunked_state) = run(16);
    assert_eq!(
        whole.trace.final_objective().to_bits(),
        chunked.trace.final_objective().to_bits(),
        "chunk_cells must be bitwise invisible to the MF trajectory"
    );
    assert_eq!(whole_state.len(), chunked_state.len());
    for (j, (a, b)) in whole_state.iter().zip(&chunked_state).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "factor cell {j} diverged under chunking");
    }
    assert_eq!(whole.pull_bytes, chunked.pull_bytes, "modeled pull meter is chunk-invariant");
}

#[test]
fn adaptive_republish_tol_converges_and_cuts_republish_bytes() {
    // `republish_tol = auto` scales the tolerance with the objective's
    // RMS cell magnitude: it must track the lossless trajectory to the
    // same tolerance-drift bound as a hand-picked tol, and move fewer
    // republish bytes than full republish.
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 42);
    let rounds = 400;
    let run = |auto: bool, tol: f64| -> DistributedReport {
        let mut cfg = lasso_cfg(4);
        if auto {
            cfg.ps.set_republish_tol_arg("auto").unwrap();
        } else {
            cfg.ps.republish_tol = tol;
        }
        let mut problem = NativeLasso::new(&data, cfg.lambda);
        strads::workers::run_distributed(&mut problem, &cfg, rounds, "tiny").unwrap()
    };
    let full = run(false, -1.0);
    let auto = run(true, 0.0);
    let full_obj = full.trace.final_objective();
    let auto_obj = auto.trace.final_objective();
    // The auto tolerance is ~1e-7 of the RMS cell magnitude — coarser
    // than the hand-picked 1e-8 pin above, so the drift bound is
    // correspondingly looser while still far inside convergence noise.
    assert!(
        (auto_obj - full_obj).abs() < 1e-6 * full_obj.abs().max(1.0),
        "full {full_obj} auto {auto_obj}"
    );
    assert!(
        auto.bytes_republished < full.bytes_republished,
        "auto {} vs full {}",
        auto.bytes_republished,
        full.bytes_republished
    );
    assert_eq!(auto.bytes_flushed, full.bytes_flushed, "the knob must not touch flush traffic");
}

#[test]
fn mf_distributed_stale_runs_complete() {
    let data = mf_powerlaw::generate(&MfSynthSpec::tiny(), 33);
    for setting in ["2", "async"] {
        let mut cfg = RunConfig { workers: 4, ..Default::default() };
        cfg.ps.set_staleness_arg(setting).unwrap();
        let mut dist = DistMf::new(&data.a, 4, 0.05, 34);
        let rounds = dist.rounds_for_iters(4);
        let report =
            strads::workers::run_distributed(&mut dist, &cfg, rounds, "tiny").unwrap();
        assert_eq!(report.rounds, rounds, "staleness={setting} stopped early");
        if setting != "async" {
            // bounded-stale CCD still optimizes; async only has to finish
            let first = report.trace.points.first().unwrap().objective;
            let last = report.trace.final_objective();
            assert!(last.is_finite());
            assert!(last < first, "staleness={setting}: first {first} last {last}");
        }
    }
}
