//! Elastic-membership acceptance suite: supervision, per-block leases,
//! and mid-run join/leave over the in-process transport.
//!
//! The contracts pinned here:
//! - **Elasticity is free for a fixed fleet.** With `[ps] elastic = 1`
//!   but no membership events, supervision only observes (leases,
//!   heartbeats) — staleness-0 Lasso and MF trajectories are bitwise
//!   identical to the plain run (README contract 8).
//! - **Worker death is survivable.** A seeded `worker_kill_plan` that
//!   kills workers mid-run still completes every round: the victims'
//!   leased blocks are reassigned to live workers (`sup.reassigns`) and
//!   the run lands within tolerance of the uninterrupted objective.
//! - **Joiners work.** A mid-run joiner enters at the applied frontier
//!   (immediately gate-legal) and can carry the run alone after every
//!   founding worker is killed.
//! - **Exactly-once.** The server's `(round, block)` flush ledger makes
//!   duplicate application impossible, however many copies of a block
//!   the reassignment race produces.

use strads::config::RunConfig;
use strads::data::lasso_synth::{self, LassoSynthSpec};
use strads::data::mf_powerlaw::{self, MfSynthSpec};
use strads::lasso::NativeLasso;
use strads::mf::DistMf;
use strads::ps::{PsConnection, PullSpec, Transport};
use strads::workers::{run_distributed, DistributedReport};

fn lasso_cfg(workers: usize) -> RunConfig {
    let mut cfg = RunConfig { workers, lambda: 1e-3, ..Default::default() };
    cfg.sap.shards = 2;
    cfg
}

fn run_lasso(cfg: &RunConfig, rounds: usize, seed: u64) -> (DistributedReport, Vec<f64>) {
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), seed);
    let mut problem = NativeLasso::new(&data, cfg.lambda);
    let report = run_distributed(&mut problem, cfg, rounds, "tiny").unwrap();
    (report, problem.beta().to_vec())
}

fn obj_bits(report: &DistributedReport) -> Vec<u64> {
    report.trace.points.iter().map(|p| p.objective.to_bits()).collect()
}

fn assert_close(got: f64, base: f64, tol: f64, what: &str) {
    assert!(
        ((got - base) / base).abs() < tol,
        "{what}: got {got}, baseline {base} (tol {tol})"
    );
}

#[test]
fn elastic_with_no_membership_events_is_bitwise_free_for_lasso() {
    // README contract 8: flipping `[ps] elastic = 1` on a fixed fleet
    // changes nothing — leases and heartbeats are observation only.
    let rounds = 80;
    let (fixed, fixed_beta) = run_lasso(&lasso_cfg(4), rounds, 42);
    let mut cfg = lasso_cfg(4);
    cfg.ps.elastic = true;
    let (elastic, elastic_beta) = run_lasso(&cfg, rounds, 42);

    assert_eq!(
        obj_bits(&fixed),
        obj_bits(&elastic),
        "elastic supervision must be bitwise invisible on a fixed fleet"
    );
    for (j, (a, b)) in fixed_beta.iter().zip(&elastic_beta).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "beta[{j}] diverged under elasticity: {a} vs {b}");
    }
    assert_eq!(elastic.rounds, fixed.rounds);
    assert_eq!(elastic.sup_workers_live, 4, "nobody died");
    assert_eq!(elastic.sup_reassigns, 0, "nothing to reassign on a healthy fleet");
    assert!(elastic.sup_heartbeats > 0, "every flush is a heartbeat");
}

#[test]
fn elastic_with_no_membership_events_is_bitwise_free_for_mf() {
    // The same freeness pin for the second problem family (CCD++ MF).
    let data = mf_powerlaw::generate(&MfSynthSpec::tiny(), 31);
    let run = |elastic: bool| {
        let mut cfg = RunConfig { workers: 4, ..Default::default() };
        cfg.ps.elastic = elastic;
        let mut problem = DistMf::new(&data.a, 4, 0.05, 32);
        let rounds = problem.rounds_for_iters(2);
        run_distributed(&mut problem, &cfg, rounds, "tiny").unwrap()
    };
    let fixed = run(false);
    let elastic = run(true);
    assert_eq!(
        obj_bits(&fixed),
        obj_bits(&elastic),
        "MF trajectory must survive elasticity bitwise"
    );
    assert_eq!(fixed.rounds, elastic.rounds);
}

#[test]
fn aggressive_lease_expiry_is_semantically_invisible() {
    // A pathologically short lease makes the supervisor re-dispatch
    // blocks that are merely in flight. Every extra copy loses the
    // server's ledger race, so the trajectory still cannot move.
    let rounds = 60;
    let (fixed, _) = run_lasso(&lasso_cfg(4), rounds, 5);
    let mut cfg = lasso_cfg(4);
    cfg.ps.elastic = true;
    cfg.ps.lease_ms = 1;
    let (churned, _) = run_lasso(&cfg, rounds, 5);
    assert_eq!(
        obj_bits(&fixed),
        obj_bits(&churned),
        "lease churn must be semantically invisible (exactly-once application)"
    );
    assert_eq!(churned.sup_workers_live, 4);
}

#[test]
fn seeded_kills_mid_run_complete_and_converge() {
    // Acceptance (b): kill K of P workers mid-run via the seeded plan.
    // Kills fire after their round's blocks are dispatched, so the
    // victim dies holding leases; the run must reassign them, complete
    // every round, and land within 5% of the uninterrupted objective.
    let rounds = 80;
    let (baseline, _) = run_lasso(&lasso_cfg(4), rounds, 7);
    let base_obj = baseline.trace.final_objective();

    let mut cfg = lasso_cfg(4);
    cfg.ps.worker_kill_plan = "seed=3,kill=@5".to_string(); // implies elastic
    let (one_dead, _) = run_lasso(&cfg, rounds, 7);
    assert_eq!(one_dead.rounds, baseline.rounds, "every round must still complete");
    assert!(one_dead.sup_reassigns > 0, "the victim's leases must be reassigned");
    assert_eq!(one_dead.sup_workers_live, 3);
    assert_close(one_dead.trace.final_objective(), base_obj, 0.05, "1-kill objective");

    let mut cfg = lasso_cfg(4);
    cfg.ps.worker_kill_plan = "seed=9,kill=@4,kill=@9".to_string();
    let (two_dead, _) = run_lasso(&cfg, rounds, 7);
    assert_eq!(two_dead.rounds, baseline.rounds, "2 survivors must finish all rounds");
    assert!(two_dead.sup_reassigns > 0);
    assert_eq!(two_dead.sup_workers_live, 2);
    assert_close(two_dead.trace.final_objective(), base_obj, 0.05, "2-kill objective");
}

#[test]
fn mid_run_joiner_can_carry_the_whole_run() {
    // Acceptance (c): a worker joins at round 3 (entering at the
    // applied frontier — immediately gate-legal at staleness 0), then
    // both founders are killed. Only the joiner is left: the run
    // completing at the baseline objective proves the joiner was
    // dispatched (all) the work.
    let rounds = 60;
    let (baseline, _) = run_lasso(&lasso_cfg(2), rounds, 11);
    let mut cfg = lasso_cfg(2);
    cfg.ps.worker_kill_plan = "seed=1,join=@3,kill=0@6,kill=1@9".to_string();
    let (elastic, _) = run_lasso(&cfg, rounds, 11);

    assert_eq!(elastic.sup_workers_live, 1, "only the joiner survives");
    assert_eq!(elastic.rounds, baseline.rounds, "the joiner must finish every round");
    assert!(elastic.sup_reassigns > 0, "the founders' leases moved to the joiner");
    assert_close(
        elastic.trace.final_objective(),
        baseline.trace.final_objective(),
        0.05,
        "joiner-carried objective",
    );
}

#[test]
fn kills_under_a_staleness_bound_still_converge() {
    // Satellite: membership change while the SSP gate may be parked
    // (staleness 2, pipelined dispatch). Retiring the victim must wake
    // any waiter parked on its clock, not hang the run.
    let rounds = 80;
    let mut cfg = lasso_cfg(4);
    cfg.ps.set_staleness_arg("2").unwrap();
    cfg.ps.worker_kill_plan = "seed=13,kill=@6".to_string();
    let (report, _) = run_lasso(&cfg, rounds, 21);
    assert_eq!(report.rounds, rounds, "the gated run must not stall after the kill");
    assert!(report.sup_reassigns > 0);
    let first = report.trace.points.first().unwrap().objective;
    let last = report.trace.final_objective();
    assert!(last < first * 0.8, "no progress under staleness-2 chaos: {first} -> {last}");
}

#[test]
fn killing_the_last_worker_is_a_clean_error_not_a_hang() {
    // Satellite: the degenerate end of elasticity. When the plan kills
    // the final live worker the run must fail fast with a clear error —
    // the alternative is a coordinator waiting forever for flushes.
    let mut cfg = lasso_cfg(2);
    cfg.ps.worker_kill_plan = "seed=1,kill=@2,kill=@4".to_string();
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 3);
    let mut problem = NativeLasso::new(&data, cfg.lambda);
    let err = run_distributed(&mut problem, &cfg, 40, "tiny").unwrap_err();
    assert!(
        err.to_string().contains("no live workers"),
        "last-worker death must name the condition, got: {err}"
    );
}

#[test]
fn duplicate_flush_application_is_impossible() {
    // Acceptance (d): the exactly-once contract at the transport level.
    // However many copies of a (round, block) the reassignment race
    // produces — another worker's copy or the winner's own replay —
    // only the first application lands; every loser is acked with
    // `applied = false` and counted by `ps.flushes_dropped`.
    let cfg = RunConfig::default();
    let mut conn = PsConnection::establish(&cfg.ps, 2, &[(0, 4)]).unwrap();
    conn.coord().publish_range(0, &[0.0, 0.0, 0.0, 0.0], 0).unwrap();
    let mut w0 = conn.worker_transport(0).unwrap();
    let mut w1 = conn.worker_transport(1).unwrap();

    assert!(w0.flush(&[(1, 0.5)], 0, 0).unwrap(), "the first copy applies");
    assert!(
        !w1.flush(&[(1, 0.5)], 0, 0).unwrap(),
        "a reassigned copy of the same (round, block) must be dropped"
    );
    assert!(
        !w0.flush(&[(1, 0.5)], 0, 0).unwrap(),
        "the winner replaying its own flush must be dropped too"
    );

    let reply = conn.coord().pull(&PullSpec::from_ranges(vec![(0, 4)]), 0).unwrap();
    assert_eq!(
        reply.ranges[0].values()[1],
        0.5f32,
        "exactly one application of the 0.5 delta"
    );
    let metrics = conn.coord().obs_stats().unwrap().metrics;
    let dropped = metrics
        .iter()
        .find(|(n, _)| n == "ps.flushes_dropped")
        .expect("ps.flushes_dropped must be registered")
        .1
        .as_u64();
    assert_eq!(dropped, 2, "both duplicate copies counted as dropped");
}
