//! Empirical validation of Theorem 1 and the paper's scalability claims.
//!
//! Theorem 1: the sampling distribution p(j) ∝ ½(δβ_j)² approximately
//! maximizes a lower bound on the expected objective decrease per
//! round. We cannot check the bound symbolically, but we can check its
//! operational content on random Lasso instances: given identical
//! state, one round scheduled by δβ-priority decreases the objective
//! more (in expectation over seeds) than one round scheduled uniformly
//! at random — and whole runs dominate accordingly.

use strads::config::{RunConfig, SapConfig};
use strads::data::lasso_synth::{generate, LassoData, LassoSynthSpec};
use strads::engine::run_rounds;
use strads::lasso::NativeLasso;
use strads::metrics::Trace;
use strads::problem::{Block, ModelProblem};
use strads::schedulers::{DynamicScheduler, RandomScheduler, Scheduler};
use strads::sim::{CostModel, VirtualCluster};

/// Replay a recorded block sequence to clone a problem state.
fn replay<'d>(data: &'d LassoData, lambda: f64, history: &[Vec<Block>]) -> NativeLasso<'d> {
    let mut p = NativeLasso::new(data, lambda);
    for blocks in history {
        p.update_blocks(blocks);
    }
    p
}

#[test]
fn thm1_priority_round_beats_uniform_round_in_expectation() {
    let spec = LassoSynthSpec::tiny();
    let data = generate(&spec, 71);
    let lambda = 1e-3;
    let p_workers = 8;

    // Warm up with the squared-priority scheduler so δβ estimates are
    // populated, recording the block history to clone the state later.
    let cfg = SapConfig { shards: 1, ..SapConfig::default() };
    let mut warm = NativeLasso::new(&data, lambda);
    let mut sched = DynamicScheduler::new_squared(warm.num_vars(), &cfg, 5);
    // Warm until coverage is complete (the init-priority phase visits
    // every coordinate once; Theorem 1 is about the *measured-progress*
    // regime after that).
    let mut history: Vec<Vec<Block>> = Vec::new();
    let mut rounds = 0;
    while sched.coverage() < 1.0 && rounds < 2_000 {
        let blocks = sched.plan(&mut warm, p_workers);
        let res = warm.update_blocks(&blocks);
        sched.observe(&res);
        history.push(blocks);
        rounds += 1;
    }
    for _ in 0..20 {
        let blocks = sched.plan(&mut warm, p_workers);
        let res = warm.update_blocks(&blocks);
        sched.observe(&res);
        history.push(blocks);
    }
    let base_obj = warm.objective();

    // From the identical state, compare expected one-round decrease:
    // (a) the scheduler's priority plan, (b) uniform random plans.
    let mut prio_dec = 0.0f64;
    let mut unif_dec = 0.0f64;
    let trials = 20;
    for t in 0..trials {
        // (a) priority plan — scheduler clone is deterministic given
        // identical observe history, so re-plan from the warm scheduler
        // (each trial advances its RNG -> different draw from p(j)).
        let mut prob_a = replay(&data, lambda, &history);
        let blocks_a = sched.plan(&mut prob_a, p_workers);
        prob_a.update_blocks(&blocks_a);
        prio_dec += base_obj - prob_a.objective();

        // (b) uniform plan
        let mut prob_b = replay(&data, lambda, &history);
        let mut rand_sched = RandomScheduler::new(1000 + t as u64);
        let blocks_b = rand_sched.plan(&mut prob_b, p_workers);
        prob_b.update_blocks(&blocks_b);
        unif_dec += base_obj - prob_b.objective();
    }
    prio_dec /= trials as f64;
    unif_dec /= trials as f64;
    assert!(
        prio_dec > unif_dec,
        "priority round decrease {prio_dec:.3e} should beat uniform {unif_dec:.3e}"
    );
}

#[test]
fn whole_run_dynamic_dominates_random_at_equal_rounds() {
    let data = generate(&LassoSynthSpec::tiny(), 72);
    let lambda = 5e-4;
    let rounds = 400;
    let mut finals = Vec::new();
    for dynamic in [true, false] {
        let cfg = RunConfig {
            workers: 8,
            lambda,
            ..Default::default()
        };
        let mut problem = NativeLasso::new(&data, lambda);
        let mut sched: Box<dyn Scheduler> = if dynamic {
            Box::new(DynamicScheduler::new(problem.num_vars(), &cfg.sap, 3))
        } else {
            Box::new(RandomScheduler::new(3))
        };
        let mut cluster = VirtualCluster::new(8, 1, CostModel::new(&cfg.cost));
        let mut trace = Trace::new("x", "tiny", 8);
        let mut ecfg = cfg.engine.clone();
        ecfg.max_rounds = rounds;
        run_rounds(&mut problem, sched.as_mut(), &mut cluster, &ecfg, &mut trace);
        finals.push(trace.final_objective());
    }
    assert!(
        finals[0] < finals[1],
        "dynamic {:.6e} should beat random {:.6e} at equal rounds",
        finals[0],
        finals[1]
    );
}

#[test]
fn rho_constraint_prevents_interference_divergence() {
    // On a highly correlated design, unchecked parallel updates make
    // much slower per-update progress than rho-checked updates (the §2
    // correctness story). With enough correlated coordinates updated
    // simultaneously, Shotgun-style scheduling can even increase the
    // objective on some rounds; SAP must never do so here (lasso CD
    // rounds with rho small are near-sequential quality).
    let spec = LassoSynthSpec {
        block_size: 32,
        corr: 0.95,
        j: 256,
        k_nonzero: 32,
        ..LassoSynthSpec::tiny()
    };
    let data = generate(&spec, 73);
    let lambda = 1e-4;
    let cfg = SapConfig { rho: 0.1, shards: 1, p_prime_factor: 4, ..SapConfig::default() };

    let mut dyn_prob = NativeLasso::new(&data, lambda);
    let mut dyn_sched = DynamicScheduler::new(dyn_prob.num_vars(), &cfg, 11);
    let mut dyn_increases = 0usize;
    let mut prev = dyn_prob.objective();
    for _ in 0..150 {
        let blocks = dyn_sched.plan(&mut dyn_prob, 16);
        let res = dyn_prob.update_blocks(&blocks);
        dyn_sched.observe(&res);
        let obj = res.objective.unwrap();
        if obj > prev + 1e-9 {
            dyn_increases += 1;
        }
        prev = obj;
    }

    let mut rnd_prob = NativeLasso::new(&data, lambda);
    let mut rnd_sched = RandomScheduler::new(11);
    let mut rnd_increases = 0usize;
    let mut prev = rnd_prob.objective();
    for _ in 0..150 {
        let blocks = rnd_sched.plan(&mut rnd_prob, 16);
        let res = rnd_prob.update_blocks(&blocks);
        let obj = res.objective.unwrap();
        if obj > prev + 1e-9 {
            rnd_increases += 1;
        }
        prev = obj;
    }
    assert!(
        dyn_increases <= rnd_increases,
        "rho-checked rounds should regress no more often: dyn {dyn_increases} rnd {rnd_increases}"
    );
    // final objective also better under the structure-aware scheduler
    assert!(dyn_prob.objective() < rnd_prob.objective());
}

#[test]
fn squared_and_linear_priority_both_converge() {
    // Theorem 1 derives the squared form; the paper implements the
    // linear form. Both must converge to comparable objectives.
    let data = generate(&LassoSynthSpec::tiny(), 74);
    let lambda = 1e-3;
    let cfg = SapConfig { shards: 2, ..SapConfig::default() };
    let mut finals = Vec::new();
    for squared in [false, true] {
        let mut problem = NativeLasso::new(&data, lambda);
        let mut sched = if squared {
            DynamicScheduler::new_squared(problem.num_vars(), &cfg, 9)
        } else {
            DynamicScheduler::new(problem.num_vars(), &cfg, 9)
        };
        for _ in 0..300 {
            let blocks = sched.plan(&mut problem, 8);
            let res = problem.update_blocks(&blocks);
            sched.observe(&res);
        }
        finals.push(problem.objective());
    }
    let ratio = finals[0] / finals[1];
    assert!(
        (0.5..2.0).contains(&ratio),
        "linear {:.4e} vs squared {:.4e} diverged",
        finals[0],
        finals[1]
    );
}
