//! Property-based tests on the coordinator invariants (routing, block
//! formation, load balancing, sampling). The offline vendor set has no
//! proptest, so cases are driven by the crate's own deterministic RNG —
//! several hundred random instances per property, seeds printed on
//! failure.

use strads::config::SapConfig;
use strads::coordinator::balance::{imbalance, merge_balanced, partition_balanced, partition_uniform};
use strads::coordinator::depcheck::{is_rho_independent, select_independent};
use strads::coordinator::partition_owned;
use strads::coordinator::priority::{PriorityDist, PriorityKind};
use strads::problem::{Block, RoundResult};
use strads::sched_service::PlannerSet;
use strads::schedulers::SchedKind;
use strads::util::{Fenwick, Rng};

fn rand_weights(rng: &mut Rng, n: usize, heavy_tail: bool) -> Vec<u64> {
    (0..n)
        .map(|_| {
            if heavy_tail && rng.f64() < 0.05 {
                rng.below(1000) as u64 + 100
            } else {
                rng.below(10) as u64 + 1
            }
        })
        .collect()
}

#[test]
fn prop_partition_covers_every_item_exactly_once() {
    let mut rng = Rng::new(1001);
    for case in 0..200 {
        let n = rng.below(200) + 1;
        let p = rng.below(16) + 1;
        let weights = rand_weights(&mut rng, n, case % 2 == 0);
        for blocks in [partition_balanced(&weights, p), partition_uniform(&weights, p)] {
            let mut seen: Vec<usize> = blocks.iter().flat_map(|b| b.vars.clone()).collect();
            seen.sort();
            assert_eq!(seen, (0..n).collect::<Vec<_>>(), "case {case} n={n} p={p}");
            for b in &blocks {
                let w: u64 = b.vars.iter().map(|&i| weights[i]).sum();
                assert_eq!(w, b.work, "case {case}: work field inconsistent");
            }
        }
    }
}

#[test]
fn prop_lpt_respects_makespan_bound() {
    // LPT greedy guarantees makespan <= (4/3 - 1/3p) * OPT, and
    // OPT >= max(total/p, w_max). Check the (looser) 4/3 bound.
    let mut rng = Rng::new(1002);
    for case in 0..300 {
        let n = rng.below(150) + 1;
        let p = rng.below(12) + 1;
        let weights = rand_weights(&mut rng, n, true);
        let blocks = partition_balanced(&weights, p);
        let makespan = blocks.iter().map(|b| b.work).max().unwrap() as f64;
        let total: u64 = weights.iter().sum();
        let wmax = *weights.iter().max().unwrap() as f64;
        let lb = (total as f64 / p as f64).max(wmax);
        assert!(
            makespan <= 4.0 / 3.0 * lb + 1e-9,
            "case {case}: makespan {makespan} > 4/3 * {lb}"
        );
    }
}

#[test]
fn prop_balanced_never_worse_than_uniform_on_makespan() {
    let mut rng = Rng::new(1003);
    for case in 0..200 {
        let n = rng.below(300) + 2;
        let p = rng.below(16) + 1;
        let weights = rand_weights(&mut rng, n, true);
        let bal = partition_balanced(&weights, p);
        let uni = partition_uniform(&weights, p);
        let ms = |bs: &[Block]| bs.iter().map(|b| b.work).max().unwrap_or(0);
        assert!(
            ms(&bal) <= ms(&uni),
            "case {case}: balanced {} > uniform {}",
            ms(&bal),
            ms(&uni)
        );
    }
}

#[test]
fn prop_merge_balanced_preserves_vars_and_bounds_count() {
    let mut rng = Rng::new(1004);
    for case in 0..200 {
        let nblocks = rng.below(50) + 1;
        let p = rng.below(8) + 1;
        let blocks: Vec<Block> = (0..nblocks)
            .map(|i| Block::singleton(i, rng.below(100) as u64 + 1))
            .collect();
        let before: u64 = blocks.iter().map(|b| b.work).sum();
        let merged = merge_balanced(blocks, p);
        assert!(merged.len() <= p.max(1), "case {case}");
        let after: u64 = merged.iter().map(|b| b.work).sum();
        assert_eq!(before, after);
        let mut vars: Vec<usize> = merged.iter().flat_map(|b| b.vars.clone()).collect();
        vars.sort();
        assert_eq!(vars, (0..nblocks).collect::<Vec<_>>());
        if nblocks >= p * 4 {
            assert!(imbalance(&merged) < 2.0, "case {case}: imbalance {}", imbalance(&merged));
        }
    }
}

#[test]
fn prop_greedy_selection_is_rho_independent_and_maximal() {
    let mut rng = Rng::new(1005);
    for case in 0..200 {
        let c = rng.below(40) + 1;
        let rho = rng.f64() * 0.5;
        // random symmetric dep matrix
        let mut dep = vec![0.0f64; c * c];
        for i in 0..c {
            for k in (i + 1)..c {
                let v = rng.f64();
                dep[i * c + k] = v;
                dep[k * c + i] = v;
            }
        }
        let cands: Vec<usize> = (0..c).collect();
        let limit = rng.below(c) + 1;
        let sel = select_independent(&cands, &dep, rho, limit);
        assert!(is_rho_independent(&sel, &dep, c, rho), "case {case}: constraint violated");
        assert!(sel.len() <= limit);
        // maximality: if under limit, every unselected candidate must
        // conflict with something selected
        if sel.len() < limit {
            let in_sel: std::collections::HashSet<_> = sel.iter().copied().collect();
            for i in 0..c {
                if !in_sel.contains(&i) {
                    let conflicts = sel.iter().any(|&a| dep[i * c + a] > rho);
                    assert!(conflicts, "case {case}: candidate {i} wrongly rejected");
                }
            }
        }
    }
}

#[test]
fn prop_fenwick_matches_naive_prefix_sums() {
    let mut rng = Rng::new(1006);
    for _case in 0..100 {
        let n = rng.below(100) + 1;
        let mut naive = vec![0.0f64; n];
        let mut fen = Fenwick::new(n);
        for _op in 0..50 {
            let i = rng.below(n);
            let w = rng.f64() * 10.0;
            naive[i] = w;
            fen.set(i, w);
        }
        for i in 0..=n {
            let want: f64 = naive[..i].iter().sum();
            assert!((fen.prefix_sum(i) - want).abs() < 1e-9);
        }
        // search: every item with positive weight is reachable
        let total = fen.total();
        if total > 0.0 {
            for _ in 0..20 {
                let t = rng.f64() * total;
                let idx = fen.search(t + f64::MIN_POSITIVE);
                assert!(idx < n);
            }
        }
    }
}

#[test]
fn prop_priority_sampling_respects_weight_ordering() {
    // heavier variables must not be sampled less often (statistically)
    let mut rng = Rng::new(1007);
    for case in 0..10 {
        let n = 50;
        let mut p = PriorityDist::new(n, 1e-9, 1.0, PriorityKind::Linear);
        for i in 0..n {
            p.report(i, if i < 5 { 10.0 } else { 0.01 });
        }
        let mut heavy_hits = 0usize;
        let trials = 500;
        for _ in 0..trials {
            let c = p.sample_candidates(1, &mut rng);
            if c[0] < 5 {
                heavy_hits += 1;
            }
        }
        // heavy mass fraction = 50 / (50 + 0.45) ~ 99%
        assert!(heavy_hits > trials * 9 / 10, "case {case}: {heavy_hits}/{trials}");
    }
}

#[test]
fn prop_shard_partition_and_routing_are_consistent() {
    let mut rng = Rng::new(1008);
    for case in 0..50 {
        let num_vars = rng.below(500) + 10;
        let s = rng.below(8) + 1;
        // The ownership primitive: every global var lands in exactly
        // one shard, and the inverse table agrees.
        let (lists, owner) = partition_owned(num_vars, s, &mut rng);
        let mut owned_count = vec![0usize; num_vars];
        for (si, list) in lists.iter().enumerate() {
            for (li, &g) in list.iter().enumerate() {
                owned_count[g] += 1;
                assert_eq!(owner[g], (si as u32, li as u32), "case {case}");
            }
        }
        assert!(owned_count.iter().all(|&c| c == 1), "case {case}");
        // The planner set built on it routes reports without panicking
        // and coverage reaches 1.0 once everything is touched.
        let seed = rng.next_u64();
        let mut set = PlannerSet::new(
            num_vars,
            s,
            SchedKind::Dynamic,
            PriorityKind::Linear,
            &SapConfig::default(),
            seed,
        );
        set.observe(&RoundResult {
            deltas: (0..num_vars).map(|g| (g, 0.5)).collect(),
            ..Default::default()
        });
        assert!((set.coverage() - 1.0).abs() < 1e-9, "case {case}");
    }
}

#[test]
fn prop_rng_streams_are_stable_across_forks() {
    // forking must not disturb the parent stream's determinism
    let mut a = Rng::new(99);
    let mut b = Rng::new(99);
    let _fork = a.fork(7);
    let _ = b.fork(7);
    for _ in 0..100 {
        assert_eq!(a.next_u64(), b.next_u64());
    }
}
