//! End-to-end runtime correctness: the AOT artifacts (JAX + Pallas,
//! compiled through PJRT) must agree with the native rust
//! implementations on identical inputs. This pins all three layers
//! together: Pallas == jnp oracle is checked in pytest; here we check
//! artifact == rust-native.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use std::rc::Rc;
use strads::data::lasso_synth::{self, LassoSynthSpec};
use strads::data::mf_powerlaw::{self, MfSynthSpec};
use strads::lasso::{ArtifactLasso, NativeLasso};
use strads::mf::{ArtifactMf, MfBackend, NativeMf};
use strads::problem::{Block, ModelProblem};
use strads::runtime::{default_artifacts_dir, ArtifactStore, LassoExes, MfExes};

fn store() -> Option<Rc<ArtifactStore>> {
    let dir = default_artifacts_dir();
    match ArtifactStore::open(&dir) {
        Ok(s) => Some(Rc::new(s)),
        Err(e) => {
            eprintln!("SKIP: no artifact store ({e}); run `make artifacts`");
            None
        }
    }
}

fn lasso_pair(seed: u64, lambda: f64) -> Option<(NativeLasso<'static>, ArtifactLasso)> {
    let store = store()?;
    let data = Box::leak(Box::new(lasso_synth::generate(&LassoSynthSpec::tiny(), seed)));
    let exes =
        LassoExes::new(store, "tiny", &data.x.to_row_major(), &data.y).expect("LassoExes::new");
    let native = NativeLasso::new(data, lambda);
    let artifact = ArtifactLasso::new(exes, &data.y, lambda);
    Some((native, artifact))
}

#[test]
fn lasso_update_artifact_matches_native() {
    let Some((mut native, mut artifact)) = lasso_pair(31, 1e-3) else { return };
    // Several rounds over assorted coordinate batches, including
    // single-coordinate and full-bucket (16) rounds.
    let batches: Vec<Vec<usize>> = vec![
        vec![0],
        vec![5, 9, 200, 31],
        (16..32).collect(),
        vec![255, 3, 77],
        (100..110).collect(),
    ];
    for (i, batch) in batches.iter().enumerate() {
        let blocks: Vec<Block> = batch.iter().map(|&v| Block::singleton(v, 1)).collect();
        let rn = native.update_blocks(&blocks);
        let ra = artifact.update_blocks(&blocks);
        // per-variable |delta| agree
        assert_eq!(rn.deltas.len(), ra.deltas.len());
        for ((vn, dn), (va, da)) in rn.deltas.iter().zip(ra.deltas.iter()) {
            assert_eq!(vn, va);
            assert!((dn - da).abs() < 1e-4, "round {i} var {vn}: native {dn} artifact {da}");
        }
        // betas agree
        for &v in batch {
            let bn = native.beta()[v];
            let ba = artifact.beta()[v];
            assert!((bn - ba).abs() < 1e-4, "round {i} beta[{v}]: {bn} vs {ba}");
        }
    }
    // objectives agree after everything
    let on = native.objective();
    let oa = artifact.objective();
    assert!((on - oa).abs() < 1e-3 * on.abs().max(1.0), "native {on} artifact {oa}");
}

#[test]
fn lasso_gram_artifact_matches_native() {
    let Some((mut native, mut artifact)) = lasso_pair(32, 1e-3) else { return };
    let cands: Vec<usize> = vec![0, 1, 2, 9, 17, 33, 128, 255];
    let dn = native.dependencies(&cands);
    let da = artifact.dependencies(&cands);
    assert_eq!(dn.len(), da.len());
    for (i, (a, b)) in dn.iter().zip(da.iter()).enumerate() {
        assert!((a - b).abs() < 1e-4, "dep[{i}]: native {a} artifact {b}");
    }
}

#[test]
fn lasso_objective_artifact_matches_native() {
    let Some((mut native, mut artifact)) = lasso_pair(33, 5e-4) else { return };
    // beta = 0 objective: 0.5 ||y||^2
    let on = native.objective();
    let oa = artifact.objective();
    assert!((on - oa).abs() < 1e-5, "zero-beta objective: {on} vs {oa}");
    // after some updates
    let blocks: Vec<Block> = (0..16).map(|v| Block::singleton(v * 3, 1)).collect();
    native.update_blocks(&blocks);
    artifact.update_blocks(&blocks);
    let on = native.objective();
    let oa = artifact.objective();
    assert!((on - oa).abs() < 1e-3 * on.max(1.0), "post-update objective: {on} vs {oa}");
}

#[test]
fn mf_sweeps_artifact_matches_native() {
    let Some(store) = store() else { return };
    let data = mf_powerlaw::generate(&MfSynthSpec::tiny(), 41);
    let (a_dense, mask) = data.a.to_dense_row_major();
    let exes = MfExes::new(store, "tiny", &a_dense, &mask).expect("MfExes::new");

    let mut art = ArtifactMf::new(exes, &data.a, 0.05, 7);
    let mut nat = NativeMf::new(&data.a, 4, 0.05, 7);
    // identical init (same seed/scale path)
    assert_eq!(art.w, nat.w);
    assert_eq!(art.h, nat.h);

    let n = nat.n();
    let m = nat.m();
    let rows: Vec<usize> = (0..n).collect();
    let cols: Vec<usize> = (0..m).collect();
    for t in 0..nat.k() {
        nat.begin_rank(t);
        nat.sweep_w_block(t, &rows[..n / 2]);
        nat.sweep_w_block(t, &rows[n / 2..]);
        nat.sweep_h_block(t, &cols);
        nat.end_rank(t);

        art.begin_rank(t);
        art.sweep_w_block(t, &rows[..n / 2]);
        art.sweep_w_block(t, &rows[n / 2..]);
        art.sweep_h_block(t, &cols);
        art.end_rank(t);
    }
    for (i, (a, b)) in nat.w.iter().zip(art.w.iter()).enumerate() {
        assert!((a - b).abs() < 2e-3, "w[{i}]: native {a} artifact {b}");
    }
    for (i, (a, b)) in nat.h.iter().zip(art.h.iter()).enumerate() {
        assert!((a - b).abs() < 2e-3, "h[{i}]: native {a} artifact {b}");
    }
    let on = nat.objective();
    let oa = art.objective();
    assert!((on - oa).abs() < 1e-2 * on.max(1.0), "objective: native {on} artifact {oa}");
}

#[test]
fn mf_objective_artifact_matches_native() {
    let Some(store) = store() else { return };
    let data = mf_powerlaw::generate(&MfSynthSpec::tiny(), 42);
    let (a_dense, mask) = data.a.to_dense_row_major();
    let exes = MfExes::new(store, "tiny", &a_dense, &mask).expect("MfExes::new");
    let mut art = ArtifactMf::new(exes, &data.a, 0.05, 9);
    let mut nat = NativeMf::new(&data.a, 4, 0.05, 9);
    let oa = art.objective();
    let on = nat.objective();
    assert!((on - oa).abs() < 1e-3 * on.max(1.0), "objective: native {on} artifact {oa}");
}

#[test]
fn mf_driver_over_artifacts_converges_and_balances() {
    // the full fig5 driver running on the PJRT backend end-to-end
    use strads::config::{CostModelConfig, EngineConfig};
    use strads::metrics::Trace;
    use strads::mf::{run_mf, MfPartition};

    let Some(store) = store() else { return };
    let data = mf_powerlaw::generate(
        &MfSynthSpec { item_exponent: 1.6, ..MfSynthSpec::tiny() },
        43,
    );
    let (a_dense, mask) = data.a.to_dense_row_major();
    let ecfg = EngineConfig { max_rounds: 2, record_every: 1, ..Default::default() };
    let cost = CostModelConfig::default();
    let mut finals = Vec::new();
    let mut vtimes = Vec::new();
    for part in [MfPartition::Balanced, MfPartition::Uniform] {
        let exes = MfExes::new(Rc::clone(&store), "tiny", &a_dense, &mask).unwrap();
        let mut backend = ArtifactMf::new(exes, &data.a, 0.05, 11);
        let mut t = Trace::new(part.name(), "tiny", 8);
        run_mf(&mut backend, part, 8, &ecfg, &cost, &mut t);
        assert!(t.final_objective() < t.points[0].objective * 1.01);
        finals.push(t.final_objective());
        vtimes.push(t.final_vtime());
    }
    // identical math, balanced finishes sooner
    assert!((finals[0] - finals[1]).abs() < 1e-5 * finals[0].abs().max(1.0));
    assert!(vtimes[0] < vtimes[1]);
}

#[test]
fn bucket_padding_is_exact() {
    // Padding slots (idx 0, mask 0) must not perturb live lanes or any
    // untouched coordinate — verified against the native implementation
    // on the same batch.
    let Some((mut native, mut artifact)) = lasso_pair(34, 1e-3) else { return };
    let batch = vec![10usize, 40, 90];
    let blocks: Vec<Block> = batch.iter().map(|&v| Block::singleton(v, 1)).collect();
    native.update_blocks(&blocks);
    artifact.update_blocks(&blocks);
    for &v in &batch {
        assert!((native.beta()[v] - artifact.beta()[v]).abs() < 1e-4);
    }
    // untouched coordinates stay exactly zero (no padding leakage)
    for v in [0usize, 11, 41, 91, 200] {
        assert_eq!(artifact.beta()[v], 0.0, "beta[{v}] perturbed by padding");
    }
}

#[test]
fn artifact_store_inventory_is_complete() {
    let Some(store) = store() else { return };
    // every kind present for the tiny dataset
    for kind in ["lasso_update", "lasso_gram", "lasso_obj"] {
        assert!(!store.family(kind, "tiny").is_empty(), "missing {kind} for tiny");
    }
    for kind in ["mf_update_w", "mf_update_h", "mf_obj"] {
        assert!(!store.family(kind, "tiny").is_empty(), "missing {kind} for tiny");
    }
    // executables compile lazily and memoize
    let before = store.compiled_count();
    let name = &store.family("lasso_obj", "tiny")[0].name.clone();
    store.executable(name).unwrap();
    store.executable(name).unwrap();
    assert_eq!(store.compiled_count(), before + 1);
}
