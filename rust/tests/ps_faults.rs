//! Fault-tolerance suite for the parameter-server wire: seeded fault
//! injection (dropped RPCs, lost replies, delays) rides under the
//! retry/backoff wrapper and must be *semantically invisible* — a
//! staleness-0 run under a random fault schedule converges bitwise
//! identical to the fault-free run, because every RPC is idempotent
//! under retry (re-`Init` reattaches by session, `Flush` is deduped by
//! seq, publishes overwrite, `Advance` is a monotonic max). Also pins
//! the crash path end to end: a server stopped mid-run and restarted
//! from its checkpoint is rejoined by the retrying workers and the run
//! completes, and hostile bytes on a live socket yield clean error
//! replies without taking the server down.

use std::io::{Read, Write};
use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use strads::config::RunConfig;
use strads::data::lasso_synth::{self, LassoSynthSpec};
use strads::data::mf_powerlaw::{self, MfSynthSpec};
use strads::lasso::NativeLasso;
use strads::mf::DistMf;
use strads::ps::transport::tcp::TcpTransport;
use strads::ps::transport::wire::{self, Reply};
use strads::ps::transport::Transport;
use strads::ps::{CheckpointConfig, PsTcpServer, PullSpec, StalenessPolicy, TransportKind};
use strads::workers::{run_distributed, DistributedReport};

/// A fresh loopback server on an ephemeral port.
fn loopback_host() -> (PsTcpServer, String) {
    let host = PsTcpServer::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = host.local_addr().to_string();
    (host, addr)
}

/// A TCP run config pointed at `addr`, with the PR's fault knobs off
/// (callers flip them on per test).
fn tcp_cfg(workers: usize, addr: &str) -> RunConfig {
    let mut cfg = RunConfig { workers, lambda: 1e-3, ..Default::default() };
    cfg.sap.shards = 2;
    cfg.ps.transport = TransportKind::Tcp;
    cfg.ps.addr = addr.to_string();
    cfg
}

fn run_lasso(cfg: &RunConfig, rounds: usize, seed: u64) -> (DistributedReport, Vec<f64>) {
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), seed);
    let mut problem = NativeLasso::new(&data, cfg.lambda);
    let report = run_distributed(&mut problem, cfg, rounds, "tiny").unwrap();
    (report, problem.beta().to_vec())
}

fn obj_bits(report: &DistributedReport) -> Vec<u64> {
    report.trace.points.iter().map(|p| p.objective.to_bits()).collect()
}

#[test]
fn lasso_staleness0_random_faults_are_bitwise_invisible() {
    // The acceptance pin: a seeded schedule of drops (connection lost
    // before send), lost replies (delivered, then the ack vanishes)
    // and delays over the pull/flush traffic changes *nothing* — the
    // objective trajectory and final beta are bit-for-bit the
    // fault-free run's. ~12% of the ~1000 matching RPCs fault, so the
    // run provably reconnected and replayed.
    let rounds = 120;
    let (host, addr) = loopback_host();
    let (clean, clean_beta) = run_lasso(&tcp_cfg(4, &addr), rounds, 42);
    host.stop();

    let (host, addr) = loopback_host();
    let mut cfg = tcp_cfg(4, &addr);
    cfg.ps.retry_max = 6;
    cfg.ps.retry_backoff_ms = 1;
    cfg.ps.fault_plan =
        "seed=11,drop=0.05,err=0.03,delay=0.04,delay_ms=1,ops=pull|flush".to_string();
    let (faulted, faulted_beta) = run_lasso(&cfg, rounds, 42);
    host.stop();

    assert!(faulted.reconnects > 0, "the fault plan must have forced reconnects");
    assert!(faulted.retry_backoff_us > 0, "reconnects must have metered backoff sleep");
    assert_eq!(clean.reconnects, 0, "the clean run must not retry anything");
    assert_eq!(
        obj_bits(&clean),
        obj_bits(&faulted),
        "fault-injected staleness-0 trajectory must be bitwise identical"
    );
    assert_eq!(clean.rounds, faulted.rounds);
    for (j, (a, b)) in clean_beta.iter().zip(&faulted_beta).enumerate() {
        assert!(
            a.to_bits() == b.to_bits(),
            "beta[{j}] diverged under fault injection: {a} vs {b}"
        );
    }
}

#[test]
fn mf_staleness0_random_faults_are_bitwise_invisible() {
    // Same pin for the second problem family (CCD++ MF): the f32
    // factor slabs cross a faulty wire and still land bit-exact.
    let data = mf_powerlaw::generate(&MfSynthSpec::tiny(), 31);
    let run = |cfg: &RunConfig| {
        let mut problem = DistMf::new(&data.a, 4, 0.05, 32);
        let rounds = problem.rounds_for_iters(3);
        run_distributed(&mut problem, cfg, rounds, "tiny").unwrap()
    };

    let (host, addr) = loopback_host();
    let mut clean_cfg = RunConfig { workers: 4, ..Default::default() };
    clean_cfg.ps.transport = TransportKind::Tcp;
    clean_cfg.ps.addr = addr;
    let clean = run(&clean_cfg);
    host.stop();

    let (host, addr) = loopback_host();
    let mut cfg = RunConfig { workers: 4, ..Default::default() };
    cfg.ps.transport = TransportKind::Tcp;
    cfg.ps.addr = addr;
    cfg.ps.retry_max = 6;
    cfg.ps.retry_backoff_ms = 1;
    cfg.ps.fault_plan = "seed=23,drop=0.08,err=0.04,ops=pull|flush".to_string();
    let faulted = run(&cfg);
    host.stop();

    assert!(faulted.reconnects > 0, "the fault plan must have forced reconnects");
    assert_eq!(
        clean.trace.final_objective().to_bits(),
        faulted.trace.final_objective().to_bits(),
        "MF objective must survive fault injection bitwise: {} vs {}",
        clean.trace.final_objective(),
        faulted.trace.final_objective()
    );
    assert_eq!(obj_bits(&clean), obj_bits(&faulted));
    assert_eq!(clean.rounds, faulted.rounds);
}

#[test]
fn every_nth_rpc_faults_at_staleness_2_still_converge() {
    // Deterministic stress: every 7th pull/flush on every link is
    // dropped, under a staleness bound of 2. The run must ride out the
    // churn (~14% of its RPCs reconnect) and still make progress.
    let (host, addr) = loopback_host();
    let mut cfg = tcp_cfg(3, &addr);
    cfg.ps.set_staleness_arg("2").unwrap();
    cfg.ps.retry_max = 8;
    cfg.ps.retry_backoff_ms = 1;
    cfg.ps.fault_plan = "seed=5,every=7,drop=1,ops=pull|flush".to_string();
    let (report, _) = run_lasso(&cfg, 120, 9);
    host.stop();

    assert_eq!(report.rounds, 120, "the faulted run must not stop early");
    assert!(report.reconnects > 0);
    let first = report.trace.points.first().unwrap().objective;
    let last = report.trace.final_objective();
    assert!(last < first, "no progress under faults: {first} -> {last}");
}

#[test]
fn obs_on_and_off_stay_bitwise_identical_with_retries() {
    // PR-6's freeness contract extended to the retry path: full
    // observability over a fault-injected run changes nothing, and the
    // registry's view of the new counters matches the report's.
    let rounds = 80;
    let run = |level: usize| {
        let (host, addr) = loopback_host();
        let mut cfg = tcp_cfg(4, &addr);
        cfg.obs.level = level;
        cfg.ps.retry_max = 6;
        cfg.ps.retry_backoff_ms = 1;
        cfg.ps.fault_plan = "seed=29,drop=0.04,err=0.04,ops=pull|flush".to_string();
        let out = run_lasso(&cfg, rounds, 7);
        host.stop();
        out
    };
    let (r_on, beta_on) = run(2);
    let (r_off, beta_off) = run(0);

    assert_eq!(obj_bits(&r_on), obj_bits(&r_off), "observation must stay free under faults");
    for (j, (a, b)) in beta_on.iter().zip(&beta_off).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "beta[{j}] diverged under observation: {a} vs {b}");
    }
    assert!(r_on.reconnects > 0 && r_off.reconnects > 0);
    assert_eq!(r_on.reconnects, r_off.reconnects, "the fault schedule is seeded, not timed");

    // The fault-tolerance counters surface through the registry.
    assert!(r_off.obs_metrics.is_empty());
    let metric = |name: &str| {
        r_on.obs_metrics
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("registry must export {name}"))
            .1
            .as_u64()
    };
    assert_eq!(metric("net.reconnects"), r_on.reconnects);
    assert_eq!(metric("net.retry_backoff_us"), r_on.retry_backoff_us);
    assert!(r_on.retry_backoff_us > 0);
}

#[test]
fn server_restart_mid_run_resumes_from_checkpoint_and_converges() {
    // The crash pin, run-level: stop the checkpointing server while a
    // retry-wrapped run is mid-flight (clients see the same Io errors
    // a SIGKILL produces), restart it from the checkpoint on the same
    // address, and the workers reconnect, reattach their session, and
    // finish every round — landing within tolerance of the
    // uninterrupted run. A re-zeroed clock would deadlock the SSP gate
    // and a re-zeroed model would blow up the objective, so finishing
    // close to baseline pins both restores.
    let rounds = 1500;
    let (host, addr) = loopback_host();
    let (baseline, _) = run_lasso(&tcp_cfg(3, &addr), rounds, 17);
    host.stop();

    let dir = std::env::temp_dir().join(format!("strads_faults_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let ckpt = CheckpointConfig { dir: dir.clone(), every: 2, keep: 2 };
    let host = PsTcpServer::bind_with("127.0.0.1:0", Some(ckpt.clone())).unwrap();
    let addr = host.local_addr().to_string();
    let mut cfg = tcp_cfg(3, &addr);
    cfg.ps.retry_max = 40;
    cfg.ps.retry_backoff_ms = 10;
    let runner = std::thread::spawn(move || run_lasso(&cfg, rounds, 17));

    // Wait for the run to produce its first checkpoint (proof it is
    // underway), let it advance a little further, then pull the rug.
    let ckpt_file = dir.join("ps.ckpt");
    let begin = std::time::Instant::now();
    while !ckpt_file.exists() {
        assert!(
            begin.elapsed() < std::time::Duration::from_secs(30),
            "the run never produced a checkpoint"
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    std::thread::sleep(std::time::Duration::from_millis(100));
    host.stop();
    let host2 = PsTcpServer::bind_with(&addr, Some(ckpt)).expect("rebind the crashed address");

    let (report, _) = runner.join().expect("the interrupted run must not panic");
    host2.stop();
    let _ = std::fs::remove_dir_all(&dir);

    assert_eq!(report.rounds, rounds, "the interrupted run must complete every round");
    assert!(report.reconnects > 0, "the restart must have forced reconnects");
    let base = baseline.trace.final_objective();
    let got = report.trace.final_objective();
    assert!(
        ((got - base) / base).abs() < 0.05,
        "restored run must land near the uninterrupted objective: {got} vs {base}"
    );
    let first = report.trace.points.first().unwrap().objective;
    assert!(got < first, "no progress across the restart: {first} -> {got}");
}

#[test]
fn hostile_frames_get_clean_errors_and_leave_the_server_serving() {
    // Server-side hardening: garbage on a live socket must produce a
    // clean error reply (decode failures) or a dropped connection
    // (framing violations) — never a hang, a panic, or a poisoned
    // server. A healthy client keeps working throughout.
    let (host, addr) = loopback_host();
    let bytes = Arc::new(AtomicU64::new(0));
    let mut coord = TcpTransport::connect(&addr, 0, Arc::clone(&bytes)).unwrap();
    coord.init(9, 1, 1, StalenessPolicy::Bounded(0), &[(0, 4)], 0).unwrap();
    coord.publish_range(0, &[1.0, 2.0, 3.0, 4.0], 0).unwrap();

    // Unknown opcode inside a well-formed frame: a clean, non-fatal
    // error reply on the same connection.
    let mut raw = std::net::TcpStream::connect(&addr).unwrap();
    wire::write_frame(&mut raw, &[0x55]).unwrap();
    let mut buf = Vec::new();
    wire::read_frame(&mut raw, &mut buf).unwrap();
    match wire::decode_reply(&buf).unwrap() {
        Reply::Err { shutdown, message } => {
            assert!(!shutdown, "a bad frame must not read as a shutdown");
            assert!(message.contains("opcode"), "unhelpful error: {message}");
        }
        other => panic!("hostile frame must yield Reply::Err, got {other:?}"),
    }

    // Oversized length prefix: the server drops the connection.
    raw.write_all(&(wire::MAX_FRAME + 1).to_le_bytes()).unwrap();
    let mut probe = [0u8; 16];
    assert!(
        matches!(raw.read(&mut probe), Ok(0) | Err(_)),
        "the server must close a connection that violates framing"
    );

    // Mid-stream EOF: promise a payload, send a sliver, vanish. The
    // handler must just reap the connection.
    let mut eof = std::net::TcpStream::connect(&addr).unwrap();
    eof.write_all(&64u32.to_le_bytes()).unwrap();
    eof.write_all(&[1, 2, 3]).unwrap();
    drop(eof);

    // Through it all the server keeps serving the real run.
    let reply = coord.pull(&PullSpec::from_ranges(vec![(0, 4)]), 0).unwrap();
    assert_eq!(reply.ranges[0].values(), &[1.0f32, 2.0, 3.0, 4.0]);
    assert!(coord.stats().unwrap().pulls >= 1);
    host.stop();
}
