//! Property-style tests for the parameter-server storage layer under
//! the f32-epoch dense representation: for randomized
//! publish/delta/range-publish/read sequences, a `ShardedStore` must
//! agree exactly with a transparent reference model that applies the
//! same operations with the same precision rules — f32 values and one
//! epoch version per chunk for dense-segment keys (a whole-segment
//! chunk when `chunk_cells` is 0), f64 `Cell`s for hashed keys. Seeded
//! deterministic RNG (`strads::util::Rng`), no proptest dependency.

use std::sync::Arc;
use strads::ps::{Cell, PullSpec, ShardedStore};
use strads::util::Rng;

const KEY_SPACE: usize = 160;
/// Reads also probe past the written key space (misses included).
const MODEL_SPACE: usize = KEY_SPACE + 20;

/// The executable spec of the store's observable behaviour: dense keys
/// are f32 slots sharing one monotone version per epoch chunk (the
/// whole segment when `chunk_cells` is 0); hashed keys are f64 cells
/// with per-cell versions (publish overwrites them, deltas max them).
struct RefModel {
    segs: Vec<(usize, usize)>,
    chunk_cells: usize,
    dense_vals: Vec<f32>,
    chunk_ver: Vec<Vec<u64>>,
    hash_vals: Vec<f64>,
    hash_ver: Vec<u64>,
    hash_present: Vec<bool>,
}

impl RefModel {
    fn new(segs: &[(usize, usize)], chunk_cells: usize) -> Self {
        let chunk_ver = segs
            .iter()
            .map(|&(_, len)| {
                let cc = if chunk_cells == 0 { len } else { chunk_cells };
                vec![0u64; (len + cc - 1) / cc]
            })
            .collect();
        RefModel {
            segs: segs.to_vec(),
            chunk_cells,
            dense_vals: vec![0.0; MODEL_SPACE],
            chunk_ver,
            hash_vals: vec![0.0; MODEL_SPACE],
            hash_ver: vec![0; MODEL_SPACE],
            hash_present: vec![false; MODEL_SPACE],
        }
    }

    fn seg_of(&self, key: usize) -> Option<usize> {
        self.segs.iter().position(|&(s, l)| key >= s && key < s + l)
    }

    /// The chunk index `key` falls in within segment `s`.
    fn chunk_of(&self, s: usize, key: usize) -> usize {
        let (start, len) = self.segs[s];
        let cc = if self.chunk_cells == 0 { len } else { self.chunk_cells };
        (key - start) / cc
    }

    fn dense_ver(&self, s: usize, key: usize) -> u64 {
        self.chunk_ver[s][self.chunk_of(s, key)]
    }

    fn bump_dense_ver(&mut self, s: usize, key: usize, version: u64) {
        let c = self.chunk_of(s, key);
        self.chunk_ver[s][c] = self.chunk_ver[s][c].max(version);
    }

    fn publish(&mut self, entries: &[(usize, f64)], version: u64) {
        for &(key, value) in entries {
            match self.seg_of(key) {
                Some(s) => {
                    self.dense_vals[key] = value as f32;
                    self.bump_dense_ver(s, key, version);
                }
                None => {
                    self.hash_vals[key] = value;
                    self.hash_ver[key] = version;
                    self.hash_present[key] = true;
                }
            }
        }
    }

    fn add_deltas(&mut self, deltas: &[(usize, f64)], at: u64) {
        for &(key, delta) in deltas {
            match self.seg_of(key) {
                Some(s) => {
                    self.dense_vals[key] += delta as f32;
                    self.bump_dense_ver(s, key, at);
                }
                None => {
                    self.hash_vals[key] += delta;
                    self.hash_ver[key] = self.hash_ver[key].max(at);
                    self.hash_present[key] = true;
                }
            }
        }
    }

    fn publish_range(&mut self, start: usize, values: &[f64], version: u64) {
        let entries: Vec<(usize, f64)> =
            values.iter().enumerate().map(|(i, &v)| (start + i, v)).collect();
        self.publish(&entries, version);
    }

    fn expected_cell(&self, key: usize) -> Cell {
        match self.seg_of(key) {
            Some(s) => {
                Cell { version: self.dense_ver(s, key), value: self.dense_vals[key] as f64 }
            }
            None if self.hash_present[key] => {
                Cell { version: self.hash_ver[key], value: self.hash_vals[key] }
            }
            None => Cell::default(),
        }
    }

    /// Expected f32 image + version of a contiguous range read. The
    /// version is the OLDEST across the range — a dense key contributes
    /// its chunk's epoch version, a hashed cell its own, and a missing
    /// hashed cell 0 — matching the staleness-diagnostic contract.
    fn expected_range(&self, start: usize, len: usize) -> (Vec<f32>, u64) {
        let mut values = Vec::with_capacity(len);
        let mut version = u64::MAX;
        for key in start..start + len {
            match self.seg_of(key) {
                Some(s) => {
                    values.push(self.dense_vals[key]);
                    version = version.min(self.dense_ver(s, key));
                }
                None if self.hash_present[key] => {
                    values.push(self.hash_vals[key] as f32);
                    version = version.min(self.hash_ver[key]);
                }
                None => {
                    values.push(0.0);
                    version = 0;
                }
            }
        }
        (values, if len == 0 { 0 } else { version })
    }
}

/// Drive an identical randomized op sequence through the store and the
/// reference model and compare every read — per-key cells, contiguous
/// range views, and full spec pulls.
fn run_model_equivalence(seed: u64, segs: &[(usize, usize)], chunk_cells: usize) {
    let store = ShardedStore::with_segments_chunked(5, segs, chunk_cells);
    let mut model = RefModel::new(segs, chunk_cells);
    let mut rng = Rng::new(seed);
    for step in 0..400 {
        match rng.below(5) {
            0 => {
                // sparse publish (duplicate keys allowed: last-in-batch
                // wins identically on both sides)
                let n = rng.below(24) + 1;
                let entries: Vec<(usize, f64)> = (0..n)
                    .map(|_| (rng.below(KEY_SPACE), rng.f64() * 2.0 - 1.0))
                    .collect();
                let version = rng.below(64) as u64;
                store.publish(&entries, version);
                model.publish(&entries, version);
            }
            1 => {
                // additive deltas at a random clock
                let n = rng.below(24) + 1;
                let deltas: Vec<(usize, f64)> = (0..n)
                    .map(|_| (rng.below(KEY_SPACE), rng.f64() - 0.5))
                    .collect();
                let at = rng.below(64) as u64;
                store.add_deltas(&deltas, at);
                model.add_deltas(&deltas, at);
            }
            2 => {
                // contiguous range publish at a random offset
                let start = rng.below(KEY_SPACE - 1);
                let len = rng.below(KEY_SPACE - start) + 1;
                let values: Vec<f64> = (0..len).map(|_| rng.f64()).collect();
                let version = rng.below(64) as u64;
                store.publish_range(start, &values, version);
                model.publish_range(start, &values, version);
            }
            3 => {
                // read a random key set (duplicates + misses included),
                // preserving request order
                let n = rng.below(40) + 1;
                let keys: Vec<usize> =
                    (0..n).map(|_| rng.below(MODEL_SPACE)).collect();
                let got = store.read(&keys);
                for (&key, cell) in keys.iter().zip(&got) {
                    assert_eq!(
                        *cell,
                        model.expected_cell(key),
                        "step {step}: read divergence for key {key}"
                    );
                }
            }
            _ => {
                // contiguous range read (covered, partial, or hashed)
                let start = rng.below(MODEL_SPACE - 1);
                let len = rng.below(MODEL_SPACE - start) + 1;
                let got = store.read_range(start, len);
                let (values, version) = model.expected_range(start, len);
                assert_eq!(got.values(), &values[..], "step {step}: range ({start},{len})");
                assert_eq!(got.version(), version, "step {step}: range ({start},{len})");
            }
        }
    }
    // Full-sweep read: every cell agrees in value, version, and order.
    let all: Vec<usize> = (0..MODEL_SPACE).collect();
    let got = store.read(&all);
    for (key, cell) in got.iter().enumerate() {
        assert_eq!(*cell, model.expected_cell(key), "final sweep diverged at key {key}");
    }
    // Spec reads (ranges + scattered keys) agree with the model too.
    let spec = PullSpec { ranges: vec![(3, 40), (70, 25)], keys: vec![1, 150, 9, 9] };
    let pulled = store.read_spec(&spec);
    assert_eq!(pulled.total_cells(), spec.total_len());
    for (rp, &(start, len)) in pulled.ranges.iter().zip(&spec.ranges) {
        let (values, version) = model.expected_range(start, len);
        assert_eq!(rp.values(), &values[..], "spec range ({start},{len}) diverged");
        assert_eq!(rp.version(), version);
        assert_eq!(rp.start(), start);
    }
    for (&key, cell) in spec.keys.iter().zip(&pulled.cells) {
        assert_eq!(*cell, model.expected_cell(key), "spec key {key} diverged");
    }
}

#[test]
fn randomized_ops_match_reference_model() {
    for seed in [1u64, 7, 42] {
        // segments covering parts of the key space (mixed routing)
        run_model_equivalence(seed, &[(3, 50), (70, 40)], 0);
        // one segment covering everything touched
        run_model_equivalence(seed ^ 0xfeed, &[(0, MODEL_SPACE)], 0);
        // no segments: the hashed-only path against the same model
        run_model_equivalence(seed ^ 0xbeef, &[], 0);
    }
}

#[test]
fn randomized_ops_match_reference_model_chunked() {
    // Same equivalence with the segments split into epoch chunks —
    // values must be untouched and versions must now track per chunk,
    // including the odd-size remainder chunk (50 = 3×16 + 2).
    for seed in [1u64, 7, 42] {
        run_model_equivalence(seed, &[(3, 50), (70, 40)], 16);
        run_model_equivalence(seed ^ 0xfeed, &[(0, MODEL_SPACE)], 7);
        // chunk larger than any segment: one chunk each, same as 0
        run_model_equivalence(seed ^ 0xcafe, &[(3, 50), (70, 40)], 4096);
    }
}

#[test]
fn hashed_only_stores_agree_across_shard_counts() {
    // With no segments registered, two stores with different shard
    // counts must be observationally identical cell for cell (routing
    // is an implementation detail).
    let a = ShardedStore::new(5);
    let b = ShardedStore::new(7);
    let mut rng = Rng::new(1234);
    for _ in 0..200 {
        let n = rng.below(16) + 1;
        let entries: Vec<(usize, f64)> =
            (0..n).map(|_| (rng.below(KEY_SPACE), rng.f64())).collect();
        match rng.below(3) {
            0 => {
                let v = rng.below(16) as u64;
                a.publish(&entries, v);
                b.publish(&entries, v);
            }
            1 => {
                let at = rng.below(16) as u64;
                a.add_deltas(&entries, at);
                b.add_deltas(&entries, at);
            }
            _ => {
                let keys: Vec<usize> = entries.iter().map(|&(k, _)| k).collect();
                assert_eq!(a.read(&keys), b.read(&keys));
            }
        }
    }
    let all: Vec<usize> = (0..MODEL_SPACE).collect();
    assert_eq!(a.read(&all), b.read(&all), "final sweep diverged");
}

#[test]
fn dense_only_traffic_never_hashes() {
    // A store whose registered segment covers every touched key serves
    // the whole randomized sequence with zero hash-map probes — the
    // unit-level acceptance meter for the dense fast path.
    let store = ShardedStore::with_segments(4, &[(0, KEY_SPACE)]);
    let mut rng = Rng::new(99);
    for _ in 0..100 {
        let n = rng.below(16) + 1;
        let entries: Vec<(usize, f64)> =
            (0..n).map(|_| (rng.below(KEY_SPACE), rng.f64())).collect();
        match rng.below(3) {
            0 => store.publish(&entries, rng.below(16) as u64),
            1 => store.add_deltas(&entries, rng.below(16) as u64),
            _ => {
                let keys: Vec<usize> = entries.iter().map(|&(k, _)| k).collect();
                let _ = store.read(&keys);
                let pulled = store.read_spec(&PullSpec::from_ranges(vec![(0, KEY_SPACE)]));
                assert_eq!(pulled.shared_ranges(), 1, "covered range must be zero-copy");
            }
        }
    }
    assert_eq!(store.hash_probes(), 0, "registered-range traffic must never hash");
}

#[test]
fn unpublished_cells_read_as_default_on_both_paths() {
    let dense = ShardedStore::with_segments(3, &[(10, 30)]);
    let hashed = ShardedStore::new(3);
    let keys: Vec<usize> = (0..60).collect();
    let d = dense.read(&keys);
    let h = hashed.read(&keys);
    assert_eq!(d, h);
    assert!(d.iter().all(|&c| c == Cell::default()));
}

#[test]
fn held_snapshot_is_bitwise_stable_under_concurrent_writes() {
    // Epoch isolation: a worker's held range view must stay bitwise
    // identical while the coordinator full-resyncs and other workers
    // push deltas concurrently — the writers clone the epoch instead of
    // mutating what the reader holds.
    const N: usize = 4096;
    let store = Arc::new(ShardedStore::with_segments(4, &[(0, N)]));
    let seed: Vec<f64> = (0..N).map(|i| (i as f64 * 0.01).cos()).collect();
    store.publish_dense(&seed, 0);

    let held = store.read_spec(&PullSpec::from_ranges(vec![(0, N)]));
    let before: Vec<f32> = held.ranges[0].values().to_vec();
    assert_eq!(held.ranges[0].version(), 0);

    let mut handles = Vec::new();
    for t in 0..4u64 {
        let store = Arc::clone(&store);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(1000 + t);
            for round in 1..200u64 {
                if t == 0 {
                    // the coordinator: full re-syncs with values that
                    // differ from the seed every round, so any epoch
                    // mutated in place would be caught immediately
                    let resync: Vec<f64> =
                        (0..N).map(|i| i as f64 + round as f64).collect();
                    store.publish_dense(&resync, round);
                } else {
                    // a worker: scattered delta pushes
                    let deltas: Vec<(usize, f64)> =
                        (0..32).map(|_| (rng.below(N), rng.f64() - 0.5)).collect();
                    store.add_deltas(&deltas, round);
                }
            }
        }));
    }
    // While the writers churn epochs, the held view must not move.
    for _ in 0..100 {
        assert_eq!(held.ranges[0].values(), &before[..]);
        assert_eq!(held.ranges[0].version(), 0);
        std::thread::yield_now();
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(held.ranges[0].values(), &before[..], "held epoch mutated");
    assert!(store.cow_clones() >= 1, "writes against a held epoch must clone");
    // A fresh pull observes a post-write epoch instead.
    let fresh = store.read_range(0, N);
    assert_eq!(fresh.version(), 199);
    assert_eq!(store.hash_probes(), 0, "all traffic was dense");
}
