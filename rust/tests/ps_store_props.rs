//! Property-style equivalence tests for the parameter-server storage
//! layer: for randomized key/delta/publish sequences, a `ShardedStore`
//! with dense segments registered must be observationally identical —
//! values, versions, read order — to the hashed-only store. Seeded
//! deterministic RNG (`strads::util::Rng`), no proptest dependency.

use strads::ps::{Cell, PullSpec, ShardedStore};
use strads::util::Rng;

const KEY_SPACE: usize = 160;

/// Drive an identical randomized op sequence through both stores and
/// compare every read. `segs` is registered on `dense` only; the two
/// stores also use different shard counts, so the comparison covers
/// routing independence as well.
fn run_equivalence(seed: u64, segs: &[(usize, usize)]) {
    let dense = ShardedStore::with_segments(5, segs);
    let hashed = ShardedStore::new(7);
    let mut rng = Rng::new(seed);
    for step in 0..300 {
        match rng.below(4) {
            0 => {
                // sparse publish (duplicate keys allowed: last-in-batch
                // wins identically on both paths)
                let n = rng.below(24) + 1;
                let entries: Vec<(usize, f64)> = (0..n)
                    .map(|_| (rng.below(KEY_SPACE), rng.f64() * 2.0 - 1.0))
                    .collect();
                let version = rng.below(64) as u64;
                dense.publish(&entries, version);
                hashed.publish(&entries, version);
            }
            1 => {
                // additive deltas at a random clock
                let n = rng.below(24) + 1;
                let deltas: Vec<(usize, f64)> = (0..n)
                    .map(|_| (rng.below(KEY_SPACE), rng.f64() - 0.5))
                    .collect();
                let at = rng.below(64) as u64;
                dense.add_deltas(&deltas, at);
                hashed.add_deltas(&deltas, at);
            }
            2 => {
                // contiguous range publish at a random offset
                let start = rng.below(KEY_SPACE - 1);
                let len = rng.below(KEY_SPACE - start) + 1;
                let values: Vec<f64> = (0..len).map(|_| rng.f64()).collect();
                let version = rng.below(64) as u64;
                dense.publish_range(start, &values, version);
                hashed.publish_range(start, &values, version);
            }
            _ => {
                // read a random key set (duplicates + misses included),
                // preserving request order
                let n = rng.below(40) + 1;
                let keys: Vec<usize> =
                    (0..n).map(|_| rng.below(KEY_SPACE + 20)).collect();
                assert_eq!(
                    dense.read(&keys),
                    hashed.read(&keys),
                    "step {step}: read divergence for keys {keys:?}"
                );
            }
        }
    }
    // Full-sweep read: every cell agrees in value, version, and order.
    let all: Vec<usize> = (0..KEY_SPACE + 20).collect();
    assert_eq!(dense.read(&all), hashed.read(&all), "final sweep diverged");
    // Spec reads (ranges + scattered keys) agree with per-key reads on
    // both stores and with each other.
    let spec = PullSpec { ranges: vec![(3, 40), (70, 25)], keys: vec![1, 150, 9, 9] };
    let dense_cells = dense.read_spec(&spec);
    assert_eq!(dense_cells, hashed.read_spec(&spec), "spec read diverged");
    let mut flat_keys: Vec<usize> = (3..43).collect();
    flat_keys.extend(70..95);
    flat_keys.extend([1, 150, 9, 9]);
    assert_eq!(dense_cells, dense.read(&flat_keys), "spec order != flat key order");
}

#[test]
fn randomized_ops_dense_segments_match_hashed_store() {
    for seed in [1u64, 7, 42] {
        // segments covering parts of the key space (mixed routing)
        run_equivalence(seed, &[(3, 50), (70, 40)]);
        // one segment covering everything touched
        run_equivalence(seed ^ 0xfeed, &[(0, KEY_SPACE + 20)]);
        // no segments on either side: the harness itself is neutral
        run_equivalence(seed ^ 0xbeef, &[]);
    }
}

#[test]
fn dense_only_traffic_never_hashes() {
    // A store whose registered segment covers every touched key serves
    // the whole randomized sequence with zero hash-map probes — the
    // unit-level acceptance meter for the dense fast path.
    let store = ShardedStore::with_segments(4, &[(0, KEY_SPACE)]);
    let mut rng = Rng::new(99);
    for _ in 0..100 {
        let n = rng.below(16) + 1;
        let entries: Vec<(usize, f64)> =
            (0..n).map(|_| (rng.below(KEY_SPACE), rng.f64())).collect();
        match rng.below(3) {
            0 => store.publish(&entries, rng.below(16) as u64),
            1 => store.add_deltas(&entries, rng.below(16) as u64),
            _ => {
                let keys: Vec<usize> = entries.iter().map(|&(k, _)| k).collect();
                let _ = store.read(&keys);
                let _ = store.read_spec(&PullSpec::from_ranges(vec![(0, KEY_SPACE)]));
            }
        }
    }
    assert_eq!(store.hash_probes(), 0, "registered-range traffic must never hash");
}

#[test]
fn unpublished_cells_read_as_default_on_both_paths() {
    let dense = ShardedStore::with_segments(3, &[(10, 30)]);
    let hashed = ShardedStore::new(3);
    let keys: Vec<usize> = (0..60).collect();
    let d = dense.read(&keys);
    let h = hashed.read(&keys);
    assert_eq!(d, h);
    assert!(d.iter().all(|&c| c == Cell::default()));
}
